"""Benchmark regression guard: fail when a fresh run regresses vs HEAD.

CI regenerates each BENCH_*.json in place (``benchmarks.run --json``); this
script then diffs the fresh rows against the version committed at ``HEAD``
(via ``git show``) and exits non-zero when any row's ``us_per_call`` grew by
more than ``--threshold`` (default 1.5x) — catching per-row perf
regressions the correctness suite cannot see, PR over PR.

Fresh runs land on different hardware (and different load) than the
committed baselines, and uniform host-speed drift routinely exceeds any
usable per-row band, so by default each row's fresh/committed ratio is
NORMALIZED by the median ratio across all common rows before the threshold
applies: a machine that is uniformly 2x slower passes, while one kernel row
that regressed 1.5x relative to its siblings fails.  ``--absolute``
disables the normalization for same-host comparisons (the median is then
reported but unused).

Rows present only in the fresh run are new benchmarks (allowed); rows that
exist at HEAD but vanished from the fresh run fail the guard (a silently
dropped benchmark looks exactly like a deleted regression).

  python scripts/bench_guard.py --path BENCH_kernels.json
  python scripts/bench_guard.py --path BENCH_kernels.json --fresh other.json
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys


def committed_rows(path: str, ref: str = 'HEAD') -> dict:
    """``name -> us_per_call`` of the benchmark file committed at ``ref``."""
    blob = subprocess.run(
        ['git', 'show', f'{ref}:{path}'], capture_output=True, text=True,
        check=True).stdout
    return {r['name']: r['us_per_call'] for r in json.loads(blob)['results']}


def fresh_rows(path: str) -> dict:
    with open(path) as f:
        return {r['name']: r['us_per_call'] for r in json.load(f)['results']}


def _median(xs: list) -> float:
    xs = sorted(xs)
    n = len(xs)
    return xs[n // 2] if n % 2 else (xs[n // 2 - 1] + xs[n // 2]) / 2


def diff(committed: dict, fresh: dict, threshold: float,
         normalize: bool = True) -> tuple:
    """Return (failure lines, host-drift median).  Empty lines = pass."""
    common = sorted(set(committed) & set(fresh))
    ratios = {n: (fresh[n] / committed[n] if committed[n] else float('inf'))
              for n in common}
    drift = _median(list(ratios.values())) if common else 1.0
    scale = drift if (normalize and drift > 0) else 1.0
    failures = []
    for name in sorted(committed):
        if name not in fresh:
            failures.append(f'{name}: row missing from fresh run '
                            f'(was {committed[name]:.1f} us at HEAD)')
            continue
        rel = ratios[name] / scale
        if rel > threshold:
            failures.append(
                f'{name}: {committed[name]:.1f} us -> {fresh[name]:.1f} us '
                f'({ratios[name]:.2f}x raw, {rel:.2f}x vs suite median '
                f'{drift:.2f}x > {threshold:.2f}x threshold)')
    return failures, drift


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument('--path', required=True,
                    help='committed benchmark JSON (looked up at HEAD)')
    ap.add_argument('--fresh', default=None,
                    help='fresh benchmark JSON (default: --path on disk)')
    ap.add_argument('--threshold', type=float, default=1.5,
                    help='max allowed per-row regression (after host-drift '
                         'normalization unless --absolute)')
    ap.add_argument('--absolute', action='store_true',
                    help='compare raw ratios (same-host runs only)')
    ap.add_argument('--ref', default='HEAD',
                    help='git ref holding the baseline file')
    args = ap.parse_args(argv)

    committed = committed_rows(args.path, args.ref)
    fresh = fresh_rows(args.fresh or args.path)
    failures, drift = diff(committed, fresh, args.threshold,
                           normalize=not args.absolute)
    new = sorted(set(fresh) - set(committed))
    if new:
        print(f'new rows (no baseline): {", ".join(new)}')
    for name in sorted(set(fresh) & set(committed)):
        ratio = fresh[name] / committed[name]
        print(f'  {name}: {committed[name]:.1f} -> {fresh[name]:.1f} us '
              f'({ratio:.2f}x)')
    mode = 'raw' if args.absolute else f'median-normalized ({drift:.2f}x drift)'
    if failures:
        print(f'\nbench_guard FAILED ({len(failures)} row(s) regressed '
              f'>{args.threshold}x, {mode}):', file=sys.stderr)
        for line in failures:
            print(f'  {line}', file=sys.stderr)
        return 1
    print(f'\nbench_guard OK: {len(set(fresh) & set(committed))} rows '
          f'within {args.threshold}x of HEAD ({mode})')
    return 0


if __name__ == '__main__':
    raise SystemExit(main())
