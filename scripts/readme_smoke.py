"""Execute every command in README.md's ```bash blocks (the CI smoke gate).

Keeps the README honest: a command that rots fails CI.  Rules:
  * only fenced blocks tagged ``bash`` are considered;
  * backslash line continuations are joined into one command first (the
    serving commands wrap for readability);
  * blank lines and comment lines are skipped;
  * lines containing ``pytest`` are skipped — the tier-1 gate runs in its own
    CI job and would double the wall-clock here for no extra signal.

Usage: python scripts/readme_smoke.py  (from the repo root or anywhere)
"""
import pathlib
import re
import subprocess
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
README = REPO / 'README.md'


def readme_commands():
    blocks = re.findall(r'```bash\n(.*?)```', README.read_text(), re.S)
    cmds = []
    for block in blocks:
        # join backslash continuations before filtering, so a wrapped
        # command is executed (and skipped) as one unit
        block = re.sub(r'\\\n\s*', ' ', block)
        for line in block.splitlines():
            line = line.strip()
            if not line or line.startswith('#') or 'pytest' in line:
                continue
            cmds.append(line)
    return cmds


def main() -> int:
    cmds = readme_commands()
    if not cmds:
        print('no README commands found — README.md missing bash blocks?')
        return 1
    failures = []
    for cmd in cmds:
        print(f'[smoke] $ {cmd}', flush=True)
        t0 = time.time()
        proc = subprocess.run(cmd, shell=True, cwd=REPO, timeout=1800)
        status = 'ok' if proc.returncode == 0 else f'FAIL({proc.returncode})'
        print(f'[smoke] {status} in {time.time() - t0:.1f}s', flush=True)
        if proc.returncode != 0:
            failures.append(cmd)
    print(f'[smoke] {len(cmds) - len(failures)}/{len(cmds)} README commands '
          f'passed')
    for cmd in failures:
        print(f'[smoke] failed: {cmd}')
    return 1 if failures else 0


if __name__ == '__main__':
    sys.exit(main())
