"""Render the §Dry-run / §Roofline tables of EXPERIMENTS.md from results/dryrun."""
import json
import pathlib
import sys

DRY = pathlib.Path(__file__).resolve().parent.parent / 'results' / 'dryrun'


def fmt(v, n=3):
    return f'{v:.{n}f}' if v is not None else '—'


def table(mesh_suffix):
    rows = []
    for f in sorted(DRY.glob(f'*__{mesh_suffix}.json')):
        r = json.loads(f.read_text())
        if r.get('status') != 'ok':
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL |  |  |  |  |  |  |")
            continue
        ro = r['roofline']
        rows.append(
            f"| {r['arch']} | {r['shape']} | {ro['bottleneck']} "
            f"| {fmt(ro['compute_s'])} | {fmt(ro['memory_s'])} "
            f"| {fmt(ro['collective_s'])} "
            f"| {fmt(ro['useful_flops_fraction'], 3)} "
            f"| {fmt(ro['roofline_fraction'], 4)} "
            f"| {r['compile_s']:.0f}s |")
    return '\n'.join(rows)


def memtable(mesh_suffix):
    rows = []
    for f in sorted(DRY.glob(f'*__{mesh_suffix}.json')):
        r = json.loads(f.read_text())
        if r.get('status') != 'ok':
            continue
        m = r['memory']
        gb = 1 << 30

        def g(k):
            v = m.get(k)
            return f'{v / gb:.2f}' if v else '—'
        coll = r['roofline']['per_collective']
        top = max(coll, key=coll.get) if coll else '—'
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['params'] / 1e9:.2f}B "
            f"| {g('argument_size_bytes')} | {g('output_size_bytes')} "
            f"| {g('temp_size_bytes')} | {top} |")
    return '\n'.join(rows)


if __name__ == '__main__':
    which = sys.argv[1] if len(sys.argv) > 1 else 'sp'
    if which == 'mem':
        print(memtable('sp'))
    else:
        print(table(which))
