"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

One row per (arch x shape x mesh): the three terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs, and the roofline fraction.  Written to
results/roofline.csv and summarised on stdout.
"""
import json
import pathlib

from .common import emit

DRYRUN = pathlib.Path(__file__).resolve().parent.parent / 'results' / 'dryrun'
OUT = DRYRUN.parent / 'roofline.csv'


def rows():
    out = []
    for f in sorted(DRYRUN.glob('*.json')):
        rec = json.loads(f.read_text())
        if rec.get('status') != 'ok':
            out.append({'arch': rec['arch'], 'shape': rec['shape'],
                        'mesh': rec.get('mesh', '?'), 'status': 'fail'})
            continue
        r = rec['roofline']
        out.append({
            'arch': rec['arch'], 'shape': rec['shape'], 'mesh': rec['mesh'],
            'status': 'ok', 'kind': rec['kind'],
            'compute_s': r['compute_s'], 'memory_s': r['memory_s'],
            'collective_s': r['collective_s'], 'bottleneck': r['bottleneck'],
            'useful_flops_fraction': r['useful_flops_fraction'],
            'roofline_fraction': r['roofline_fraction'],
        })
    return out


def run():
    data = rows()
    if not data:
        emit('roofline/no_dryrun_artifacts', 0.0, 'run repro.launch.dryrun first')
        return 0
    hdr = ('arch,shape,mesh,status,bottleneck,compute_s,memory_s,'
           'collective_s,useful_flops_fraction,roofline_fraction')
    lines = [hdr]
    ok = 0
    for r in data:
        if r['status'] != 'ok':
            lines.append(f"{r['arch']},{r['shape']},{r['mesh']},fail,,,,,,")
            continue
        ok += 1
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},ok,{r['bottleneck']},"
            f"{r['compute_s']:.5f},{r['memory_s']:.5f},{r['collective_s']:.5f},"
            f"{(r['useful_flops_fraction'] or 0):.4f},"
            f"{(r['roofline_fraction'] or 0):.4f}")
    OUT.write_text('\n'.join(lines))
    emit('roofline/cells_ok', 0.0, f'{ok}/{len(data)} -> {OUT}')
    for r in data:
        if r['status'] == 'ok':
            emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh'][:2]}",
                 r['compute_s'] * 1e6,
                 f"bottleneck={r['bottleneck']} "
                 f"frac={(r['roofline_fraction'] or 0):.4f}")
    return ok
