"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  table1_efficiency   — Table 1 (peak perf / energy / area efficiency)
  table2_ctc          — Table 2 (CTC-3L-421H-UNI on 3 tile configs, 2 voltages)
  fig5_shmoo          — Fig. 5 (voltage shmoo curves)
  systolic_equivalence— Sec. 3 dataflow equivalence + int8 accuracy/timing
  kernel_bench        — kernel-layer reference timings
  roofline_report     — roofline table from the multi-pod dry-run artifacts
"""


def main() -> None:
    from . import (fig5_shmoo, kernel_bench, roofline_report,
                   systolic_equivalence, table1_efficiency, table2_ctc)

    print('name,us_per_call,derived')
    table1_efficiency.run()
    table2_ctc.run()
    fig5_shmoo.run()
    systolic_equivalence.run()
    kernel_bench.run()
    roofline_report.run()


if __name__ == '__main__':
    main()
