"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json`` additionally writes
the rows (plus environment metadata) to a JSON file so the perf trajectory is
tracked PR over PR.

  table1_efficiency   — Table 1 (peak perf / energy / area efficiency)
  table2_ctc          — Table 2 (CTC-3L-421H-UNI on 3 tile configs, 2 voltages)
  fig5_shmoo          — Fig. 5 (voltage shmoo curves)
  systolic_equivalence— Sec. 3 dataflow equivalence + int8 accuracy/timing
  kernel_bench        — kernel-layer reference timings (incl. the per-step vs
                        whole-sequence LSTM kernel comparison and the
                        layerwise vs fused whole-stack wavefront rows)
  systolic_scaleout   — DESIGN.md §6: per-step vs persistent *distributed*
                        execution on a multi-device mesh (subprocess with a
                        forced host device count), incl. a scaled-down
                        graves-75 configuration
  streaming           — DESIGN.md §7: packed multi-stream engine vs the
                        per-slot batch-1 serving baseline on the 123→421
                        CTC topology
  roofline_report     — roofline table from the multi-pod dry-run artifacts

  python -m benchmarks.run --suite kernels --json BENCH_kernels.json
  python -m benchmarks.run --suite scaleout --json BENCH_systolic.json
  python -m benchmarks.run --suite streaming --json BENCH_streaming.json
"""
import argparse
import json
import platform


def _suites():
    from . import (fig5_shmoo, kernel_bench, roofline_report, streaming,
                   systolic_equivalence, systolic_scaleout, table1_efficiency,
                   table2_ctc)
    return {
        'table1': table1_efficiency.run,
        'table2': table2_ctc.run,
        'fig5': fig5_shmoo.run,
        'systolic': systolic_equivalence.run,
        'kernels': kernel_bench.run,
        'scaleout': systolic_scaleout.run,
        'streaming': streaming.run,
        'roofline': roofline_report.run,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument('--suite', action='append', default=None,
                    help='suite name(s); default: all')
    ap.add_argument('--json', nargs='?', const='BENCH_kernels.json',
                    default=None, metavar='PATH',
                    help='also write results to a JSON file')
    args = ap.parse_args(argv)

    import jax
    from . import common

    common.RESULTS.clear()        # idempotent across in-process invocations
    suites = _suites()
    names = args.suite or list(suites)
    unknown = [n for n in names if n not in suites]
    if unknown:
        raise SystemExit(f'unknown suite(s) {unknown}; have {list(suites)}')

    print('name,us_per_call,derived')
    for n in names:
        suites[n]()

    if args.json:
        payload = {
            'backend': jax.default_backend(),
            'device_count': jax.device_count(),
            'jax_version': jax.__version__,
            'python': platform.python_version(),
            'suites': names,
            'results': common.RESULTS,
        }
        with open(args.json, 'w') as f:
            json.dump(payload, f, indent=2)
        print(f'wrote {len(common.RESULTS)} rows to {args.json}')


if __name__ == '__main__':
    main()
