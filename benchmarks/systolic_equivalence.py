"""Sec. 3 correctness/throughput: systolic dataflow vs dense LSTM oracle.

Times (CPU wall-clock, indicative) the dense cell, the float tiled systolic
cell, and the bit-accurate int8 path on the paper's CTC layer geometry, and
reports the int8 accuracy loss — the cost of contribution C2.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lstm, quant, systolic

from .common import emit, time_call


def run():
    n_x, n_h, B, T = 123, 421, 8, 32          # paper layer-1 geometry
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), n_x, n_h)
    xs = jax.random.normal(jax.random.PRNGKey(1), (T, B, n_x)) * 0.5

    dense = jax.jit(lambda pp, x: lstm.lstm_layer(pp, x)[0])
    hs_ref = dense(p, xs)

    plan = systolic.SystolicPlan(n_x, n_h, tile=96)
    packed = systolic.pack_lstm(p, plan)
    # plan_shape is static metadata -> close over it, pass arrays as args
    tiled = jax.jit(lambda t, pe, b, x: systolic.systolic_layer_tiled(
        systolic.PackedLSTM(t, pe, b, packed.plan_shape), x))
    hs_tiled = tiled(packed.tiles, packed.peep, packed.bias, xs)

    qp = systolic.quantize_packed(packed)
    xs_q = quant.quantize(xs, quant.STATE_FMT)
    quantized = jax.jit(lambda t, pe, b, sl, tl, x:
                        systolic.systolic_layer_quantized(
                            systolic.QuantizedPackedLSTM(
                                t, pe, b, sl, tl, qp.plan_shape), x))
    q_args = (qp.tiles_q, qp.peep_q, qp.bias_q, qp.sig_lut, qp.tanh_lut, xs_q)
    hs_q = quant.dequantize(quantized(*q_args), quant.STATE_FMT)

    t_dense = time_call(dense, p, xs)
    t_tiled = time_call(tiled, packed.tiles, packed.peep, packed.bias, xs)
    t_q = time_call(quantized, *q_args)
    tile_err = float(jnp.max(jnp.abs(hs_tiled - hs_ref)))
    q_err = float(jnp.mean(jnp.abs(hs_q - hs_ref)))

    emit('systolic/dense_lstm', t_dense, f'T={T} B={B} 123->421')
    emit('systolic/tiled_float', t_tiled,
         f'{plan.rows}x{plan.cols} engines, max_err={tile_err:.2e}')
    emit('systolic/int8_bitaccurate', t_q,
         f'mean_err={q_err:.4f} ({q_err / quant.STATE_FMT.scale:.2f} LSB)')
    assert tile_err < 1e-4
    assert q_err < 4 * quant.STATE_FMT.scale
    return q_err
