"""Paper Table 1 — peak performance / energy / area efficiency of one engine.

Reproduces the CHIPMUNK column of Table 1 from the calibrated silicon model
and reports the deviation from the published values.
"""
from repro.core import perf_model as pm

from .common import emit

PAPER = {
    'peak_gops_1.24V': 32.3, 'peak_gops_0.75V': 3.8,
    'eff_gops_mw_1.24V': 1.11, 'eff_gops_mw_0.75V': 3.08,
    'area_eff_gops_mm2': 34.4,
    'power_mw_1.24V': 29.03, 'power_mw_0.75V': 1.24,
}


def run():
    ours = {
        'peak_gops_1.24V': pm.peak_gops(1.24),
        'peak_gops_0.75V': pm.peak_gops(0.75),
        'eff_gops_mw_1.24V': pm.efficiency_gops_per_mw(1.24),
        'eff_gops_mw_0.75V': pm.efficiency_gops_per_mw(0.75),
        'area_eff_gops_mm2': pm.area_efficiency_gops_per_mm2(),
        'power_mw_1.24V': pm.power_w(1.24) * 1e3,
        'power_mw_0.75V': pm.power_w(0.75) * 1e3,
    }
    worst = 0.0
    for k, paper_v in PAPER.items():
        err = (ours[k] - paper_v) / paper_v * 100
        worst = max(worst, abs(err))
        emit(f'table1/{k}', 0.0,
             f'ours={ours[k]:.3f} paper={paper_v} err={err:+.1f}%')
    emit('table1/worst_abs_err_pct', 0.0, f'{worst:.2f}')
    return worst
