"""Streaming-engine benchmark (DESIGN.md §7 acceptance rows).

Packed multi-stream serving vs the pre-engine baseline, on the paper's
123→421 CTC topology (3 layers, full width): the old ``SlotServer`` pattern
issued one batch-1 jit call PER SLOT per step, so S concurrent streams paid
S weight fetches and S dispatch overheads per chunk; the
``serving.StreamingEngine`` packs all S streams into ONE batched chunked
call to the whole-sequence LSTM path (per-stream state carried via h0/c0,
ragged tails masked), so the resident weights are read once per chunk for
the entire slot grid.

Both paths run the same arithmetic per stream (the per-slot baseline calls
the identical ``stream_forward`` with batch 1), so the ratio isolates the
packing win.  Timings interleave the two paths per iteration — like
``benchmarks/systolic_scaleout.py`` — because wall-clock A-vs-B ratios on a
loaded 2-core host flip when one path monopolises a busy window.  Reported:
frames/s (tok/s analogue) and p50 per-chunk latency for S = 4 and 8
concurrent streams.

The ``streaming/guard_*`` rows are the DESIGN.md §10 acceptance pair: the
fault-tolerant engine's non-finite quarantine guard is fused into the jitted
chunk call, and its clean-path cost — guard-on vs guard-off on two
persistent engines, interleaved — must stay under 5%.  ``python -m
benchmarks.streaming --faults`` runs just that pair standalone.

The ``streaming/overlap_*`` + ``streaming/*_arrival_chunk`` rows are the
DESIGN.md §11 acceptance set: the same full engine drain (submit S streams,
``run()`` to idle) with blocking vs deferred-commit dispatch.  On a
multi-core host the async win at equal chunk is true host/device overlap;
this repo's CI host is a SINGLE core, so the same-chunk pair is expected
near parity (the derived strings record the measured ratio honestly) and
the committed ≥1.2x win comes from what deferred commit buys a serving
deployment: the async engine can run the deadline-aware ``ChunkSizePolicy``
at its fully-amortised ``chunk_max`` operating point — the control plane
stays responsive because nothing blocks behind the in-flight chunk — where
a blocking server must pin a small fixed arrival chunk to bound emission
and admission latency, paying per-chunk dispatch + packing overhead on
every tiny chunk.  ``python -m benchmarks.streaming --overlap`` runs just
this set standalone.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit

N_X, N_H, LAYERS = 123, 421, 3     # the paper's CTC-3L-421H-UNI topology
T, CHUNK = 64, 16                  # frames per stream / frames per engine step


def _chunked_serve(fwd, params, states0, frames, n_chunks, valid):
    """Drive `fwd` chunk by chunk, carrying the packed state."""
    states = states0
    outs = []
    for k in range(n_chunks):
        lp, states = fwd(params, states,
                         frames[:, k * CHUNK:(k + 1) * CHUNK], valid)
        outs.append(lp)
    jax.block_until_ready(outs[-1])
    return outs


def run_guard_overhead():
    """DESIGN.md §10 acceptance row: clean-path cost of the fused non-finite
    quarantine guard.  Two persistent ``StreamingEngine`` instances on the
    full 123→421x3 topology — guard off (no fault config) vs guard on —
    time their jitted packed chunk call interleaved; the guard adds one
    fused reduction over the new states, so the overhead must stay <5%."""
    from repro.configs import get_config
    from repro.models import get_bundle
    from repro.runtime import ServingFaultConfig
    from repro.serving import StreamingEngine

    cfg = get_config('chipmunk-ctc')
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    S = 4
    eng_off = StreamingEngine(cfg, params, max_streams=S, chunk=CHUNK)
    eng_on = StreamingEngine(cfg, params, max_streams=S, chunk=CHUNK,
                             faults=ServingFaultConfig(guard_nonfinite=True))

    rng = np.random.RandomState(0)
    frames = jnp.asarray(rng.randn(S, CHUNK, N_X).astype(np.float32) * 0.5)
    valid = jnp.full((S,), CHUNK, jnp.int32)

    def call(eng):
        lp, st, finite = eng._fwd(params, eng.states, frames, valid)
        jax.block_until_ready((lp, finite))

    call(eng_off); call(eng_on)            # warm both jit caches
    t_off, t_on = [], []
    for _ in range(9):                     # interleaved timing
        t0 = time.perf_counter(); call(eng_off)
        t_off.append(time.perf_counter() - t0)
        t0 = time.perf_counter(); call(eng_on)
        t_on.append(time.perf_counter() - t0)
    us_off = sorted(t_off)[len(t_off) // 2] * 1e6
    us_on = sorted(t_on)[len(t_on) // 2] * 1e6
    pct = (us_on / us_off - 1.0) * 100.0
    emit(f'streaming/guard_off_S{S}', us_off,
         f'S={S} chunk={CHUNK} 123->421x3: packed chunk call, no fault '
         f'config (non-finite guard compiled out)')
    emit(f'streaming/guard_on_S{S}', us_on,
         f'S={S} chunk={CHUNK} 123->421x3: fused non-finite quarantine '
         f'guard on; overhead {pct:+.1f}% vs guard_off (<5% required)')


def run_async_overlap():
    """DESIGN.md §11 acceptance rows: blocking vs deferred-commit dispatch
    on full engine drains (S=8, T=64, 123->421x3), plus the serving-policy
    pair — blocking server at its latency-bounded 2-frame arrival chunk vs
    async engine under the deadline-aware chunk policy at the Table-2
    10 ms/frame arrival budget (slack 1.0).  Asserts all variants are
    bit-equal per stream (§7 chunk-boundary invariance) and that the policy
    run commits with ZERO deadline_miss events at the silicon budget."""
    from repro.configs import get_config
    from repro.models import get_bundle
    from repro.runtime import ChunkSizePolicy
    from repro.serving import StreamingEngine

    cfg = get_config('chipmunk-ctc')
    params, _ = get_bundle(cfg).init(jax.random.PRNGKey(0))
    S = 8
    rng = np.random.RandomState(0)
    utts = [rng.randn(T, N_X).astype(np.float32) * 0.5 for _ in range(S)]
    policy_kw = dict(chunk_max=CHUNK, chunk_min=2, slack=1.0)

    def mk(async_mode, chunk, with_policy=False):
        pol = ChunkSizePolicy(**policy_kw) if with_policy else None
        eng = StreamingEngine(cfg, params, max_streams=S, chunk=chunk,
                              async_dispatch=async_mode, chunk_policy=pol)
        return eng, with_policy

    def drain(pair):
        eng, with_policy = pair
        if with_policy:      # fresh policy state per measured drain
            eng._policy = ChunkSizePolicy(**policy_kw)
        sess = [eng.submit(u, sid=i) for i, u in enumerate(utts)]
        t0 = time.perf_counter()
        eng.run()
        return time.perf_counter() - t0, eng, sess

    variants = {
        'overlap_off': mk(False, CHUNK),
        'overlap_on': mk(True, CHUNK),
        'sync_arrival_chunk': mk(False, 2),
        'async_deadline_policy': mk(True, CHUNK, with_policy=True),
    }
    # warm every engine's jit cache AND check §7 bit-equality across
    # variants: chunk boundaries (and the policy moving them) must not
    # change any stream's output bits.
    ref = None
    for pair in variants.values():
        _, _, sess = drain(pair)
        got = [np.asarray(s.full_log_probs()) for s in sess]
        if ref is None:
            ref = got
        else:
            for r, g in zip(ref, got):
                np.testing.assert_array_equal(r, g)

    times = {k: [] for k in variants}
    for _ in range(5):                     # interleaved timing
        for k, pair in variants.items():
            dt, eng, _ = drain(pair)
            times[k].append(dt)
    med = {k: sorted(v)[len(v) // 2] for k, v in times.items()}
    fps = {k: S * T / med[k] for k in med}

    _, eng_pol, _ = drain(variants['async_deadline_policy'])
    misses = eng_pol.stats()['deadline_misses']
    assert misses == 0, f'deadline misses at Table-2 budget: {misses}'

    emit(f'streaming/overlap_off_S{S}', med['overlap_off'] * 1e6,
         f'S={S} T={T} chunk={CHUNK} 123->421x3: {fps["overlap_off"]:.0f} '
         f'frames/s, blocking engine drain (commit waits on every chunk)')
    emit(f'streaming/overlap_on_S{S}', med['overlap_on'] * 1e6,
         f'S={S} T={T} chunk={CHUNK} 123->421x3: {fps["overlap_on"]:.0f} '
         f'frames/s, deferred-commit async drain, '
         f'{med["overlap_off"] / med["overlap_on"]:.2f}x vs blocking at '
         f'equal chunk (single-core host: bounded by host-side share)')
    emit(f'streaming/sync_arrival_chunk_S{S}', med['sync_arrival_chunk'] * 1e6,
         f'S={S} T={T} chunk=2 123->421x3: {fps["sync_arrival_chunk"]:.0f} '
         f'frames/s, blocking server at its latency-bounded 2-frame '
         f'arrival chunk (20 ms sensor time; admission blocks per chunk)')
    emit(f'streaming/async_deadline_policy_S{S}',
         med['async_deadline_policy'] * 1e6,
         f'S={S} T={T} chunk_max={CHUNK} 123->421x3: '
         f'{fps["async_deadline_policy"]:.0f} frames/s, async + deadline '
         f'chunk policy at the Table-2 10ms/frame budget (slack 1.0): '
         f'{med["sync_arrival_chunk"] / med["async_deadline_policy"]:.2f}x '
         f'vs sync arrival-chunk (>=1.2x required), deadline_misses=0')


def run_promotion_overhead():
    """DESIGN.md §14 acceptance rows: what the elastic recovery runtime
    costs.  Two pairs on the full 123->421x3 topology:

      * steady state — a recovery-armed engine (fault config attached:
        health tracker, rung ladder, promotion poll every step) vs the bare
        engine, interleaved full drains on persistent (warm) engines; the
        armed engine must stay within 5% (at the home rung the poll is a
        dict probe and the canary capture is OFF, so the §14 machinery is
        near-free until a fault actually lands).
      * promote cycle — a full fail -> degrade -> heal -> climb-back drain
        with the canary ON vs OFF, fresh engines (the cycle re-jits the
        demoted and promoted rungs either way, so the on/off delta isolates
        the shadow replay + host compare the canary adds).  Both variants
        must end back on the home rung with a ``promote`` event."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import get_bundle
    from repro.runtime import ServingFaultConfig
    from repro.serving import StreamingEngine

    cfg = get_config('chipmunk-ctc')
    params, _ = get_bundle(cfg).init(jax.random.PRNGKey(0))
    S = 4
    rng = np.random.RandomState(0)
    utts = [rng.randn(T, N_X).astype(np.float32) * 0.5 for _ in range(S)]

    # -- steady state: persistent engines, no injected faults ------------
    eng_off = StreamingEngine(cfg, params, max_streams=S, chunk=CHUNK)
    eng_armed = StreamingEngine(
        cfg, params, max_streams=S, chunk=CHUNK,
        faults=ServingFaultConfig(recover_at={}, promote_hysteresis=4))

    def drain(eng):
        for u in utts:
            eng.submit(u)
        t0 = time.perf_counter()
        eng.run()
        return time.perf_counter() - t0

    drain(eng_off); drain(eng_armed)       # warm both jit caches
    t_off, t_armed = [], []
    for _ in range(5):                     # interleaved timing
        t_off.append(drain(eng_off))
        t_armed.append(drain(eng_armed))
    us_off = sorted(t_off)[len(t_off) // 2] * 1e6
    us_armed = sorted(t_armed)[len(t_armed) // 2] * 1e6
    pct = (us_armed / us_off - 1.0) * 100.0
    emit(f'streaming/recovery_off_S{S}', us_off,
         f'S={S} T={T} chunk={CHUNK} 123->421x3: full drain, no fault '
         f'config (no tracker, no rung ladder, no promotion poll)')
    emit(f'streaming/recovery_armed_S{S}', us_armed,
         f'S={S} T={T} chunk={CHUNK} 123->421x3: recovery-armed drain '
         f'(tracker + rungs + per-step promotion poll, zero faults); '
         f'overhead {pct:+.1f}% vs recovery_off (<5% required)')

    # -- promote cycle: fail -> heal -> climb back, canary on vs off -----
    # pallas_seq <-> xla_scan is a cross-arithmetic-class pair at the full
    # 421-wide hidden size (summation order differs), so the canary runs
    # under the explicit allclose opt-in rather than the bitwise default.
    cyc_cfg = dataclasses.replace(cfg, lstm_backend='pallas_seq')

    def cycle(canary):
        eng = StreamingEngine(
            cyc_cfg, params, max_streams=S, chunk=CHUNK,
            faults=ServingFaultConfig(fail_at={1: 1}, recover_at={2: 1},
                                      promote_hysteresis=1, canary=canary,
                                      canary_rtol=1e-3, backoff_s=0.0))
        home = eng.backend
        for u in utts:
            eng.submit(u)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        counts = eng.stats()['event_counts']
        assert counts.get('promote', 0) == 1, counts
        assert counts.get('promote_canary', 0) == (1 if canary else 0)
        assert eng.backend == home, (eng.backend, home)
        return dt

    cycle(True); cycle(False)              # warm the per-variant jit work
    t_on, t_off2 = [], []
    for _ in range(3):                     # interleaved timing
        t_on.append(cycle(True))
        t_off2.append(cycle(False))
    us_on = sorted(t_on)[len(t_on) // 2] * 1e6
    us_off2 = sorted(t_off2)[len(t_off2) // 2] * 1e6
    pct2 = (us_on / us_off2 - 1.0) * 100.0
    emit(f'streaming/promote_cycle_canary_off_S{S}', us_off2,
         f'S={S} T={T} chunk={CHUNK} 123->421x3: fail@1 heal@2 climb-back '
         f'drain, promotion on capacity+hysteresis alone (rung re-jits '
         f'included)')
    emit(f'streaming/promote_cycle_canary_on_S{S}', us_on,
         f'S={S} T={T} chunk={CHUNK} 123->421x3: same cycle with the '
         f'shadow-replay canary validating the healed rung (allclose '
         f'rtol=1e-3, cross-class pair); {pct2:+.1f}% vs canary_off (one '
         f'committed-chunk replay + host compare per promotion)')


def run():
    from repro.configs import get_config
    from repro.models import chipmunk_net, get_bundle

    cfg = get_config('chipmunk-ctc')
    assert (cfg.lstm_inputs, cfg.lstm_hidden, cfg.n_layers) == (N_X, N_H, LAYERS)
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))

    def fwd(p, st, fr, vl):
        return chipmunk_net.stream_forward(cfg, p, st, fr, valid_len=vl)

    fwd_j = jax.jit(fwd)

    rng = np.random.RandomState(0)
    n_chunks = T // CHUNK
    for S in (4, 8):
        frames = jnp.asarray(rng.randn(S, T, N_X).astype(np.float32) * 0.5)
        valid = jnp.full((S,), CHUNK, jnp.int32)
        valid1 = jnp.full((1,), CHUNK, jnp.int32)

        def states(n):
            return tuple((jnp.zeros((n, N_H)), jnp.zeros((n, N_H)))
                         for _ in range(LAYERS))

        def packed():
            return _chunked_serve(fwd_j, params, states(S), frames,
                                  n_chunks, valid)

        def per_slot():
            # the pre-engine SlotServer pattern: one batch-1 call per slot
            outs = []
            for s in range(S):
                outs.append(_chunked_serve(fwd_j, params, states(1),
                                           frames[s:s + 1], n_chunks, valid1))
            return outs

        # equivalence first: packing must not change any stream's output
        got = np.concatenate([np.asarray(o) for o in packed()], axis=1)
        ref = np.concatenate(
            [np.concatenate([np.asarray(o) for o in outs], axis=1)
             for outs in per_slot()], axis=0)
        err = float(np.max(np.abs(got - ref)))
        assert err < 1e-4, err

        packed(); per_slot()               # warm both jit caches
        t_packed, t_slot = [], []
        for _ in range(5):                 # interleaved timing
            t0 = time.perf_counter(); packed()
            t_packed.append(time.perf_counter() - t0)
            t0 = time.perf_counter(); per_slot()
            t_slot.append(time.perf_counter() - t0)
        us_p = sorted(t_packed)[len(t_packed) // 2] * 1e6
        us_s = sorted(t_slot)[len(t_slot) // 2] * 1e6
        fps_p = S * T / (us_p / 1e6)
        fps_s = S * T / (us_s / 1e6)
        chunk_p50_p = us_p / n_chunks / 1e3
        chunk_p50_s = us_s / n_chunks / 1e3
        emit(f'streaming/per_slot_batch1_S{S}', us_s,
             f'S={S} T={T} chunk={CHUNK} 123->421x3: {fps_s:.0f} frames/s, '
             f'p50 chunk {chunk_p50_s:.2f} ms (one batch-1 call per slot)')
        emit(f'streaming/packed_engine_S{S}', us_p,
             f'S={S} T={T} chunk={CHUNK} 123->421x3: {fps_p:.0f} frames/s, '
             f'p50 chunk {chunk_p50_p:.2f} ms, {us_s / us_p:.2f}x vs '
             f'per-slot (one packed call, max_err={err:.1e})')

    run_guard_overhead()
    run_async_overlap()
    run_promotion_overhead()


if __name__ == '__main__':
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument('--faults', action='store_true',
                    help='run only the §10 guard-overhead pair')
    ap.add_argument('--overlap', action='store_true',
                    help='run only the §11 async overlap/policy rows')
    ap.add_argument('--promotion', action='store_true',
                    help='run only the §14 recovery/promotion-overhead rows')
    a = ap.parse_args()
    if a.faults:
        run_guard_overhead()
    elif a.overlap:
        run_async_overlap()
    elif a.promotion:
        run_promotion_overhead()
    else:
        run()
