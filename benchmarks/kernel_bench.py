"""Kernel-layer microbenchmarks (CPU wall-clock of the jnp reference paths;
Pallas kernels are TPU-targeted and correctness-checked here via interpret
mode.  Interpret timings are an emulation, but the per-step vs whole-sequence
LSTM comparison is still structurally meaningful: the per-step path pays T
kernel invocations and T weight re-streams, the sequence kernel one — the
same ratio that dominates on hardware.  Likewise the layerwise-vs-fused
stack comparison: the layerwise path pays L launches and L inter-layer
hidden-sequence round-trips per utterance, the fused wavefront one)."""
import time

import jax
import jax.numpy as jnp

from repro.core import lstm, quant
from repro.kernels.flash_attention import attention_ref
from repro.kernels.lstm_gates import lstm_gates_ref
from repro.kernels.lstm_gates import lstm_layer_fused as lstm_layer_step
from repro.kernels.lstm_seq import lstm_layer_seq
from repro.kernels.quant_matmul import quant_matmul_ref
from repro.models.layers import chunked_attention

from .common import emit, time_call


def _lstm_seq_vs_step(T: int = 128, B: int = 8):
    """The paper's CTC layer (123->421) over a T-frame utterance: old per-step
    scan path vs the persistent whole-sequence kernel (acceptance row)."""
    p = lstm.init_lstm_params(jax.random.PRNGKey(42), 123, 421)
    xs = jax.random.normal(jax.random.PRNGKey(43), (T, B, 123)) * 0.5
    tag = f'T={T} B={B} 123->421'

    f_scan = jax.jit(lambda q, x: lstm.lstm_layer(q, x)[0])
    t_scan = time_call(f_scan, p, xs, warmup=1, iters=3)
    emit('kernels/lstm_layer_xla_scan', t_scan, tag)

    f_step = jax.jit(lambda q, x: lstm_layer_step(q, x, interpret=True))
    t_step = time_call(f_step, p, xs, warmup=1, iters=3)
    emit('kernels/lstm_layer_pallas_step', t_step,
         f'{tag} (T kernel launches, W re-streamed per step)')

    f_seq = jax.jit(lambda q, x: lstm_layer_seq(q, x, interpret=True)[0])
    t_seq = time_call(f_seq, p, xs, warmup=1, iters=3)
    emit('kernels/lstm_layer_pallas_seq', t_seq,
         f'{tag} (1 launch, weight-stationary; '
         f'{t_step / t_seq:.2f}x vs per-step)')


def _lstm_stack_fused_vs_layerwise(T: int = 128):
    """The paper's full CTC stack (123->421x3) over a T-frame utterance:
    layerwise persistent kernels (one launch per layer, hidden sequence
    round-tripping between launches) vs the fused whole-stack wavefront
    kernel (one launch, inter-layer handover in scratch) — the §8
    acceptance rows.  B=8 is the packed-serving shape, B=1 the decode
    point.  The two paths are timed interleaved (like
    ``benchmarks/streaming.py``) because A-vs-B wall-clock ratios on a
    loaded 2-core host flip when one path monopolises a busy window."""
    stack = lstm.init_lstm_stack(jax.random.PRNGKey(7), 123, 421, 3)
    for B in (8, 1):
        xs = jax.random.normal(jax.random.PRNGKey(8), (T, B, 123)) * 0.5
        tag = f'T={T} B={B} 123->421x3'
        f_lw = jax.jit(
            lambda q, x: lstm.lstm_stack_apply(q, x, backend='pallas_seq')[0])
        f_fu = jax.jit(lambda q, x: lstm.lstm_stack_apply(
            q, x, backend='pallas_seq_fused')[0])
        err = float(jnp.max(jnp.abs(f_lw(stack, xs) - f_fu(stack, xs))))
        t_lw, t_fu = [], []
        for _ in range(5):                     # interleaved timing
            t0 = time.perf_counter()
            jax.block_until_ready(f_lw(stack, xs))
            t_lw.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(f_fu(stack, xs))
            t_fu.append(time.perf_counter() - t0)
        us_lw = sorted(t_lw)[len(t_lw) // 2] * 1e6
        us_fu = sorted(t_fu)[len(t_fu) // 2] * 1e6
        emit(f'kernels/lstm_stack_layerwise_seq_B{B}', us_lw,
             f'{tag} (3 launches, hidden seq round-trips between layers)')
        emit(f'kernels/lstm_stack_fused_wavefront_B{B}', us_fu,
             f'{tag} (1 launch, wavefront, inter-layer h in scratch; '
             f'{us_lw / us_fu:.2f}x vs layerwise, max_err={err:.1e})')


def _lstm_stack_quantized_fused(T: int = 32, B: int = 4):
    """int8 whole-stack wavefront vs chaining the per-layer int8 kernel, at
    a CI-friendly 48->96x3 geometry (tile=48: a 2x4-engine plan per layer).
    The fused kernel batches each diagonal's layers into ONE dot_general
    (grid D*R*C — the pre-batching kernel ran one layer per grid step,
    D*L*R*C, and measured 1.45x slower at exactly these dims), keeping the
    serial saturating hop replay per layer inside the accumulator rows.
    Both rows are bit-identical to the silicon reference scan; interpret
    timings weight per-grid-step overhead, which is what the batching
    removes."""
    from repro.core import systolic
    from repro.kernels.lstm_seq import (lstm_layer_seq_quantized,
                                        lstm_stack_seq_quantized)
    n_x, n_h, tile, L = 48, 96, 48, 3
    stack = lstm.init_lstm_stack(jax.random.PRNGKey(7), n_x, n_h, L)
    qps = []
    for l, lp in enumerate(stack.layers):
        plan = systolic.SystolicPlan(n_x if l == 0 else n_h, n_h, tile)
        qps.append(systolic.quantize_packed(systolic.pack_lstm(lp, plan)))
    xs = jax.random.normal(jax.random.PRNGKey(8), (T, B, n_x)) * 0.5
    xs_q = quant.quantize(xs, quant.STATE_FMT)
    tag = f'T={T} B={B} 48->96x3 tile=48 int8'

    def chain(x):
        h = x
        for qp in qps:
            h = lstm_layer_seq_quantized(qp, h, interpret=True)
        return h

    f_lw = jax.jit(chain)
    f_fu = jax.jit(lambda x: lstm_stack_seq_quantized(qps, x, interpret=True))
    same = bool(jnp.all(f_lw(xs_q) == f_fu(xs_q)))
    assert same, 'int8 fused stack must be bit-identical to the chain'
    t_lw, t_fu = [], []
    for _ in range(5):                     # interleaved timing
        t0 = time.perf_counter()
        jax.block_until_ready(f_lw(xs_q))
        t_lw.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(f_fu(xs_q))
        t_fu.append(time.perf_counter() - t0)
    us_lw = sorted(t_lw)[len(t_lw) // 2] * 1e6
    us_fu = sorted(t_fu)[len(t_fu) // 2] * 1e6
    emit('kernels/lstm_stack_q_layerwise_seq', us_lw,
         f'{tag} (L launches, hidden codes round-trip between layers)')
    emit('kernels/lstm_stack_q_fused_wavefront', us_fu,
         f'{tag} (1 launch, diagonal-batched D*R*C grid — L-wide '
         f'dot_general per hop, serial hop replay per layer; bit-identical '
         f'to the chain; pre-batching D*L*R*C kernel was 1.45x slower here)')


def run():
    key = jax.random.PRNGKey(0)

    # fused LSTM gates ref (123->421 paper layer)
    p = lstm.init_lstm_params(key, 123, 421)
    xh = jax.random.normal(key, (8, 123 + 421))
    w = jnp.concatenate([p.w_x, p.w_h], -1)
    c0 = jnp.zeros((8, 421))
    f = jax.jit(lstm_gates_ref)
    emit('kernels/lstm_gates_ref', time_call(f, xh, w, p.w_peep, p.b, c0),
         'B=8 123->421')

    # int8 matmul ref vs f32 matmul
    x = jax.random.normal(key, (256, 512))
    wq = jax.random.normal(jax.random.PRNGKey(1), (512, 512))
    xs, ws = quant.abs_max_scale(x, -1), quant.abs_max_scale(wq, 0)
    x_q, w_q = quant.quantize_scaled(x, xs), quant.quantize_scaled(wq, ws)
    f_int8 = jax.jit(quant_matmul_ref)
    f_f32 = jax.jit(lambda a, b: a @ b)
    emit('kernels/int8_matmul_ref', time_call(f_int8, x_q, w_q, xs, ws),
         '256x512x512')
    emit('kernels/f32_matmul', time_call(f_f32, x, wq), '256x512x512')

    # chunked flash-style attention vs naive (the prefill-path workhorse)
    B, H, S, D = 1, 8, 1024, 64
    q = jax.random.normal(key, (B, H, S, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, H, S, D))
    f_naive = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    f_chunk = jax.jit(
        lambda q, k, v: chunked_attention(q, k, v, causal=True, chunk=256))
    t_n = time_call(f_naive, q, k, v)
    t_c = time_call(f_chunk, q, k, v)
    err = float(jnp.max(jnp.abs(f_naive(q, k, v) - f_chunk(q, k, v))))
    emit('kernels/attention_naive', t_n, f'S={S}')
    emit('kernels/attention_chunked', t_c,
         f'S={S} chunk=256 max_err={err:.1e} (O(S) memory)')

    _lstm_seq_vs_step()
    _lstm_stack_fused_vs_layerwise()
    _lstm_stack_quantized_fused()
    return t_c
