"""Systolic scale-out benchmark (DESIGN.md §6 and §9 acceptance rows).

Compares, on a real multi-device ("row","col") mesh, the per-step distributed
scan (``systolic_lstm_shard_map`` — packed ``[x|h]`` column re-assembled and
the x-region re-MACed every timestep) against the persistent distributed
sequence kernel (``systolic_lstm_seq`` — ``W_x @ x`` hoisted once, per-device
weight blocks tile-stationary for all T steps), on the paper's 123->421 CTC
layer at T=128, plus a scaled-down graves-75 (3-layer) configuration.

A second subprocess benches the STAGED scale-out (§9) on the full CTC stack:
the same 50 engines either as ONE flat 5x10 grid running the three layers
back to back (layerwise ``pallas_seq_systolic`` — the best a stage-1
placement can do with that much silicon, and the paper's Sec. 3.3 argument
against flat scaling: the accumulation chain and h-broadcast spans keep
growing) or as TWO pipelined 5x5 stages (``pallas_seq_fused_systolic`` —
stage 0 holds layers {0,1}, stage 1 layer {2}, chunks handed over by
ppermute).  Same arithmetic either way; the staged path wins on rounds
(2(T+Tc) vs 3T sequential steps) and on per-step collective span (5-wide
within a stage vs 10-wide across the flat grid) — the same levers as the
silicon's 3x(5x5) Table-2 row.

A third row times the staged stack with the in-stage diagonals BATCHED
(``in_stage='batched'`` — each stage retires its whole layer block as one
wavefront of Tc+Lb-1 rounds instead of Lb sequential Tc-loops).  On silicon
(and in the cycle model, ``staged_wavefront_cycles(in_stage_batched=True)``)
that trades round count for concurrency and wins ~1.9x; on this host the 50
"devices" time-slice ONE core, the emulation is FLOP-bound, and the
sequential order's hoisted full-width below-GEMMs are FLOP-optimal — so the
measured ratio lands BELOW 1.  The row reports that honestly; the measured-
schedule autotuner (repro.tune) is the per-host decider, and the committed
tuned_schedules.json carries this host's measured winner.  The model/
measurement bracket is pinned in tests/test_perf_model.py.

A fourth subprocess runs the §13 geometry shmoo (`repro.tune.tune_geometry`)
over the same 50-engine budget: every admissible `stages x (rows x cols)`
factorization and stage split in the balanced reference's bit-equality
class, interleaved-timed against the 2x(5x5) Table-2 default.  The winner
row records the measured best honestly even when it IS the default (1.00x).

The driver process must keep seeing a single device (smoke tests/benches run
in it), so this suite spawns subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the same pattern as
tests/_subproc.py — and re-emits the rows they print.  CPU host devices make
the absolute times an emulation, but the compared pairs share per-step
structure, so the ratios are structurally meaningful.
"""
import os
import pathlib
import subprocess
import sys

from .common import emit

REPO = pathlib.Path(__file__).resolve().parent.parent
N_DEVICES = 20      # the 123->421 plan at tile=128 is a 4x5 engine grid
N_DEVICES_STAGED = 50   # 2 stages x (5x5) == one flat 5x10 grid

_SNIPPET = r"""
import time
import jax, jax.numpy as jnp
from repro.core import lstm, systolic


def t_med(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


n_x, n_h, T, B = 123, 421, 128, 8
p = lstm.init_lstm_params(jax.random.PRNGKey(42), n_x, n_h)
xs = jax.random.normal(jax.random.PRNGKey(43), (T, B, n_x)) * 0.5
plan = systolic.SystolicPlan(n_x, n_h, tile=128)           # 4x5 engine grid
mesh = systolic.make_systolic_mesh(plan.rows, plan.cols)
packed = systolic.shard_packed_lstm(systolic.pack_lstm(p, plan), mesh)
xs_pad = jnp.zeros((T, B, plan.padded_in), xs.dtype).at[..., :n_x].set(xs)

f_step = jax.jit(lambda x: systolic.systolic_lstm_shard_map(packed, mesh, x))
f_seq = jax.jit(lambda x: systolic.systolic_lstm_seq(p, mesh, x)[0])

hs_step = f_step(xs_pad)
hs_seq = f_seq(xs)
err = float(jnp.max(jnp.abs(hs_seq - hs_step)))
assert err < 1e-4, err

# Alternate the two paths per iteration so host-load drift hits both equally
# (back-to-back t_med calls bias whichever runs during a busy window).
steps, seqs = [], []
for _ in range(5):
    t0 = time.perf_counter(); jax.block_until_ready(f_step(xs_pad))
    steps.append(time.perf_counter() - t0)
    t0 = time.perf_counter(); jax.block_until_ready(f_seq(xs))
    seqs.append(time.perf_counter() - t0)
us_step = sorted(steps)[len(steps) // 2] * 1e6
us_seq = sorted(seqs)[len(seqs) // 2] * 1e6
grid = f'{plan.rows}x{plan.cols}'
print(f'ROW|scaleout/per_step_shard_map|{us_step:.1f}|'
      f'T={T} B={B} 123->421 on {grid} mesh '
      f'([x|h] column re-packed + x-region re-MACed every step)')
print(f'ROW|scaleout/persistent_seq|{us_seq:.1f}|'
      f'T={T} B={B} 123->421 on {grid} mesh '
      f'(hoisted W_x@x, tile-stationary blocks; '
      f'{us_step / us_seq:.2f}x vs per-step, max_err={err:.1e})')

# Scaled-down graves-75: the paper's real-time phoneme topology is a 3-stage
# pipeline of 5x5 grids (75 tiles); here the 3 layers run back to back on a
# 2x2 mesh each at ~1:4 width — the same dataflow at CI-friendly scale.
keys = jax.random.split(jax.random.PRNGKey(7), 3)
n_hg, Tg = 104, 64
layers = [lstm.init_lstm_params(keys[0], n_x, n_hg)] + [
    lstm.init_lstm_params(k, n_hg, n_hg) for k in keys[1:]]
mesh_g = systolic.make_systolic_mesh(2, 2)


def stack(x):
    for lp in layers:
        x, _ = systolic.systolic_lstm_seq(lp, mesh_g, x)
    return x


f_g = jax.jit(stack)
xg = jax.random.normal(jax.random.PRNGKey(8), (Tg, B, n_x)) * 0.5
hs_g = f_g(xg)
ref = xg
for lp in layers:
    ref, _ = lstm.lstm_layer(lp, ref)
err_g = float(jnp.max(jnp.abs(hs_g - ref)))
assert err_g < 1e-4, err_g
us_g = t_med(f_g, xg)
print(f'ROW|scaleout/graves_scaled|{us_g:.1f}|'
      f'3 layers {n_x}->{n_hg} T={Tg} B={B}, 2x2 mesh per layer '
      f'(graves-75 = 3x(5x5) topology at 1:4 width, max_err={err_g:.1e})')
"""


_STAGED_SNIPPET = r"""
import time
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, systolic

n_x, n_h, T, B, Tc = 123, 421, 128, 8, 16
stack = lstm.init_lstm_stack(jax.random.PRNGKey(42), n_x, n_h, 3)
xs = jax.random.normal(jax.random.PRNGKey(43), (T, B, n_x)) * 0.5
mesh_flat = systolic.make_systolic_mesh(5, 10)          # one flat 5x10 grid
mesh_staged = systolic.make_systolic_mesh(5, 5, stage=2)  # 2 x (5x5) stages


def layerwise(x):
    h = x
    for lp in stack.layers:
        h, _ = systolic.systolic_lstm_seq(lp, mesh_flat, h)
    return h


f_lw = jax.jit(layerwise)
f_st = jax.jit(lambda x: systolic.systolic_lstm_stack_seq(
    stack, mesh_staged, x, chunk=Tc, in_stage='sequential')[0])
f_bt = jax.jit(lambda x: systolic.systolic_lstm_stack_seq(
    stack, mesh_staged, x, chunk=Tc, in_stage='batched')[0])
r_lw = np.asarray(jax.block_until_ready(f_lw(xs)))
r_st = np.asarray(jax.block_until_ready(f_st(xs)))
r_bt = np.asarray(jax.block_until_ready(f_bt(xs)))
err = float(np.abs(r_lw - r_st).max())
assert err < 1e-4, err
np.testing.assert_array_equal(r_bt, r_st)   # schedule change, not numerics

# Alternate the three paths per iteration so host-load drift hits all equally.
lws, sts, bts = [], [], []
for _ in range(5):
    t0 = time.perf_counter(); jax.block_until_ready(f_lw(xs))
    lws.append(time.perf_counter() - t0)
    t0 = time.perf_counter(); jax.block_until_ready(f_st(xs))
    sts.append(time.perf_counter() - t0)
    t0 = time.perf_counter(); jax.block_until_ready(f_bt(xs))
    bts.append(time.perf_counter() - t0)
us_lw = sorted(lws)[len(lws) // 2] * 1e6
us_st = sorted(sts)[len(sts) // 2] * 1e6
us_bt = sorted(bts)[len(bts) // 2] * 1e6
print(f'ROW|scaleout/stack_layerwise_systolic|{us_lw:.1f}|'
      f'T={T} B={B} 123->421x3 on one flat 5x10 grid (50 engines; 3 '
      f'sequential whole-sequence launches, 10-wide psum chain per step)')
print(f'ROW|scaleout/stack_fused_systolic|{us_st:.1f}|'
      f'T={T} B={B} 123->421x3 on a 2-stage 2x(5x5) mesh (same 50 engines; '
      f'layer blocks stage-stationary, Tc={Tc} chunks ppermute-pipelined, '
      f'5-wide collectives; sequential in-stage slot loop; '
      f'{us_lw / us_st:.2f}x vs layerwise flat grid, max_err={err:.1e})')
print(f'ROW|scaleout/stack_fused_systolic_batched|{us_bt:.1f}|'
      f'T={T} B={B} 123->421x3, same 2-stage 2x(5x5) mesh and Tc={Tc} but '
      f'in-stage diagonals batched (Tc+Lb-1 rounds/macro-step vs Lb*Tc); '
      f'{us_st / us_bt:.2f}x vs sequential in-stage, '
      f'{us_lw / us_bt:.2f}x vs layerwise flat grid; bit-equal outputs; '
      f'single-core FLOP-bound emulation, silicon model predicts the '
      f'batched win -- repro.tune picks per host)')
"""


_GEOMETRY_SNIPPET = r"""
import jax
from repro.core import lstm
from repro.tune import ScheduleCache
from repro.tune.autotune import tune_geometry

n_x, n_h, L, T, B = 123, 421, 3, 128, 8
stack = lstm.init_lstm_stack(jax.random.PRNGKey(42), n_x, n_h, L)
xs = jax.random.normal(jax.random.PRNGKey(43), (T, B, n_x)) * 0.5

# Same 50-engine budget as the staged rows above; the balanced 2x(5x5)
# Table-2 placement anchors the baseline AND the bit-equality class.
# tune_geometry interleaves the trials (ref/cand/ref/cand) and asserts
# bitwise-equal outputs inside the class before any clock is read.
entry, records, base_us = tune_geometry(
    stack, xs, devices=50, ref=(2, 5, 5), cache=ScheduleCache(),
    top_k=3, iters=3, warmup=1)
win_us = entry.measured_us
print(f'ROW|scaleout/geometry_balanced_ref|{base_us:.1f}|'
      f'T={T} B={B} 123->421x3 balanced 2x(5x5) dispatch default '
      f'(blocks=2,1 Tc=16; the interleaved baseline arm of the shmoo)')
print(f'ROW|scaleout/geometry_winner|{win_us:.1f}|'
      f'T={T} B={B} 123->421x3 measured geometry winner '
      f'{entry.stages}x({entry.rows}x{entry.cols}) blocks={entry.blocks} '
      f'Tc={entry.tc} {entry.in_stage} within bit-equality class '
      f'(n_h_p=425, bk=85); {base_us / win_us:.2f}x vs balanced ref '
      f'({len(records)} candidates shmooed, VMEM-pruned; margins sit at '
      f'the few-percent run-to-run drift level, so winners may flip '
      f'between runs -- dispatch trusts the separately measured '
      f'tuned_schedules.json entry, not this row)')
"""


def _run_snippet(snippet: str, n_devices: int):
    env = dict(os.environ)
    env['XLA_FLAGS'] = f'--xla_force_host_platform_device_count={n_devices}'
    env['PYTHONPATH'] = (str(REPO / 'src') + os.pathsep
                         + env.get('PYTHONPATH', ''))
    proc = subprocess.run([sys.executable, '-c', snippet], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f'scaleout subprocess failed\nSTDOUT:\n'
                           f'{proc.stdout}\nSTDERR:\n{proc.stderr}')
    rows = [l for l in proc.stdout.splitlines() if l.startswith('ROW|')]
    for row in rows:
        _, name, us, derived = row.split('|', 3)
        emit(name, float(us), derived)
    return rows


def run():
    rows = _run_snippet(_SNIPPET, N_DEVICES)
    rows += _run_snippet(_STAGED_SNIPPET, N_DEVICES_STAGED)
    rows += _run_snippet(_GEOMETRY_SNIPPET, N_DEVICES_STAGED)
    return rows
