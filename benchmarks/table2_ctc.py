"""Paper Table 2 — CTC-3L-421H-UNI under the 10 ms real-time constraint.

Execution time + peak/average power for the three tile configurations at both
voltage corners, from the two-point-calibrated cycle model (see
core/perf_model.py for the fit methodology: beta fit on the 3x(5x5) row,
load cycles/byte on the single row; 5x5 is a parameter-free prediction).
"""
from repro.core import perf_model as pm

from .common import emit


def run():
    worst = 0.0
    for row in pm.table2():
        key = (row['config'], row['voltage'])
        paper_ms = pm.PAPER_TABLE2_MS[key]
        err = (row['exec_time_ms'] - paper_ms) / paper_ms * 100
        worst = max(worst, abs(err))
        emit(f'table2/{row["config"].replace(" ", "_")}@{row["voltage"]}V',
             row['exec_time_ms'] * 1e3,
             f'exec={row["exec_time_ms"]:.3f}ms paper={paper_ms}ms '
             f'err={err:+.1f}% peak={row["peak_power_mw"]:.2f}mW '
             f'avg={row["avg_power_mw"]:.2f}mW '
             f'deadline={"MET" if row["meets_deadline"] else "MISS"}')
    emit('table2/worst_abs_err_pct', 0.0, f'{worst:.2f}')
    return worst
