"""Shared benchmark utilities: wall-clock timing of jitted callables."""
import time

import jax


def time_call(fn, *args, warmup=2, iters=5):
    """Median wall time (us) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str):
    print(f'{name},{us:.1f},{derived}')
