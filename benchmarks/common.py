"""Shared benchmark utilities: wall-clock timing of jitted callables.

Every ``emit`` both prints the CSV row and records it in ``RESULTS`` so the
driver's ``--json`` mode can persist the run (BENCH_*.json) for trajectory
tracking across PRs.
"""
import time

import jax

RESULTS = []


def time_call(fn, *args, warmup=2, iters=5):
    """Median wall time (us) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str):
    RESULTS.append({'name': name, 'us_per_call': round(us, 1),
                    'derived': derived})
    print(f'{name},{us:.1f},{derived}')
