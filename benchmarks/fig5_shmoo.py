"""Paper Fig. 5 — frequency / power / efficiency vs core voltage (shmoo).

Sweeps the calibrated silicon model over the functional range 0.75-1.24 V
and writes the curve to results/fig5_shmoo.csv IN THE SHARED SHMOO RECORD
FORMAT (``repro.tune.shmoo.ShmooRecord`` / ``write_shmoo_csv`` — the same
harness the schedule autotuner's candidate sweeps use), so the repo's two
shmoo paths cannot drift: one record type, one CSV writer, one header
convention (``suite`` column, then params, then metrics).
"""
import pathlib

from repro.core import perf_model as pm
from repro.tune import ShmooRecord, write_shmoo_csv

from .common import emit

OUT = pathlib.Path(__file__).resolve().parent.parent / 'results'


def sweep(points: int = 50):
    """The Fig. 5 voltage sweep as shared shmoo records."""
    records = []
    for i in range(points):
        v = 0.75 + (1.24 - 0.75) * i / (points - 1)
        records.append(ShmooRecord(
            suite='fig5_voltage',
            params={'voltage_v': round(v, 4)},
            metrics={'freq_mhz': pm.freq_hz(v) / 1e6,
                     'power_mw': pm.power_w(v) * 1e3,
                     'gops': pm.peak_gops(v),
                     'gops_per_mw': pm.efficiency_gops_per_mw(v)}))
    return records


def run():
    OUT.mkdir(exist_ok=True)
    records = sweep()
    write_shmoo_csv(OUT / 'fig5_shmoo.csv', records,
                    param_order=['voltage_v'],
                    metric_order=['freq_mhz', 'power_mw', 'gops',
                                  'gops_per_mw'])
    best = max(records, key=lambda r: r.metrics['gops_per_mw'])
    best_eff = best.metrics['gops_per_mw']
    emit('fig5/peak_efficiency', 0.0,
         f'{best_eff:.2f}Gop/s/mW@{best.params["voltage_v"]:.2f}V '
         f'(paper: 3.08@0.75V)')
    emit('fig5/points', 0.0, f'{len(records)} -> {OUT / "fig5_shmoo.csv"}')
    return best_eff
