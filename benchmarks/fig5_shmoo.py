"""Paper Fig. 5 — frequency / power / efficiency vs core voltage (shmoo).

Sweeps the calibrated silicon model over the functional range 0.75-1.24 V and
writes the curve to results/fig5_shmoo.csv.
"""
import pathlib

from repro.core import perf_model as pm

from .common import emit

OUT = pathlib.Path(__file__).resolve().parent.parent / 'results'


def run():
    OUT.mkdir(exist_ok=True)
    rows = ['voltage_v,freq_mhz,power_mw,gops,gops_per_mw']
    best_eff, best_v = 0.0, 0.0
    for i in range(50):
        v = 0.75 + (1.24 - 0.75) * i / 49
        f = pm.freq_hz(v)
        p = pm.power_w(v)
        g = pm.peak_gops(v)
        e = pm.efficiency_gops_per_mw(v)
        rows.append(f'{v:.4f},{f/1e6:.2f},{p*1e3:.3f},{g:.2f},{e:.3f}')
        if e > best_eff:
            best_eff, best_v = e, v
    (OUT / 'fig5_shmoo.csv').write_text('\n'.join(rows))
    emit('fig5/peak_efficiency', 0.0,
         f'{best_eff:.2f}Gop/s/mW@{best_v:.2f}V (paper: 3.08@0.75V)')
    emit('fig5/points', 0.0, f'50 -> {OUT / "fig5_shmoo.csv"}')
    return best_eff
