"""CTC loss: brute-force path enumeration oracle + properties."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ctc


def _collapse(path, blank=0):
    out = []
    prev = None
    for s in path:
        if s != prev and s != blank:
            out.append(s)
        prev = s
    return tuple(out)


def _brute_force_nll(log_probs, label, blank=0):
    """Sum probability over every alignment that collapses to `label`."""
    T, K = log_probs.shape
    total = -np.inf
    for path in itertools.product(range(K), repeat=T):
        if _collapse(path, blank) == tuple(label):
            lp = sum(log_probs[t, s] for t, s in enumerate(path))
            total = np.logaddexp(total, lp)
    return -total


def _rand_logprobs(key, T, B, K):
    logits = jax.random.normal(key, (T, B, K))
    return jax.nn.log_softmax(logits, axis=-1)


@pytest.mark.parametrize('T,K,label', [(3, 3, [1]), (4, 3, [1, 2]),
                                       (5, 4, [2, 2]), (4, 3, []),
                                       (5, 3, [1, 2, 1])])
def test_ctc_matches_brute_force(T, K, label):
    lp = _rand_logprobs(jax.random.PRNGKey(hash((T, K, len(label))) % 2**31), T, 1, K)
    L = max(len(label), 1)
    labels = jnp.zeros((1, L), jnp.int32).at[0, :len(label)].set(jnp.array(label, jnp.int32))
    nll = ctc.ctc_loss(lp, labels, jnp.array([T]), jnp.array([len(label)]))
    ref = _brute_force_nll(np.asarray(lp[:, 0]), label)
    np.testing.assert_allclose(nll[0], ref, rtol=1e-4, atol=1e-4)


def test_ctc_batch_consistency():
    """Batched loss == per-sequence loss (masking across ragged lengths)."""
    key = jax.random.PRNGKey(0)
    T, B, K, L = 8, 4, 5, 3
    lp = _rand_logprobs(key, T, B, K)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, L), 1, K)
    in_lens = jnp.array([8, 6, 7, 5])
    lab_lens = jnp.array([3, 2, 1, 3])
    batched = ctc.ctc_loss(lp, labels, in_lens, lab_lens)
    for b in range(B):
        single = ctc.ctc_loss(lp[:, b:b + 1], labels[b:b + 1],
                              in_lens[b:b + 1], lab_lens[b:b + 1])
        np.testing.assert_allclose(batched[b], single[0], rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(2, 4), st.integers(0, 2), st.integers(0, 2**31 - 1))
def test_ctc_loss_is_valid_nll(T, K, L, seed):
    """Property: loss is finite and positive whenever an alignment exists."""
    if 2 * L + 1 > T + L:  # need T >= L (+ repeats); keep feasible cases only
        return
    lp = _rand_logprobs(jax.random.PRNGKey(seed), T, 1, K + 1)
    label = (np.arange(L) % K) + 1
    labels = jnp.zeros((1, max(L, 1)), jnp.int32).at[0, :L].set(jnp.array(label, jnp.int32))
    nll = ctc.ctc_loss(lp, labels, jnp.array([T]), jnp.array([L]))
    assert np.isfinite(np.asarray(nll)).all()
    assert float(nll[0]) > 0  # -log p, p < 1


def test_ctc_gradient_flows():
    lp_logits = jax.random.normal(jax.random.PRNGKey(0), (6, 2, 4))
    labels = jnp.array([[1, 2], [3, 1]], jnp.int32)

    def loss_fn(logits):
        lp = jax.nn.log_softmax(logits, axis=-1)
        return ctc.ctc_loss(lp, labels, jnp.array([6, 6]), jnp.array([2, 2])).sum()

    g = jax.grad(loss_fn)(lp_logits)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0


def test_greedy_decode():
    # Construct log-probs where the argmax path is [1,1,0,2,2,0] -> [1,2].
    T, B, K = 6, 1, 3
    path = [1, 1, 0, 2, 2, 0]
    lp = np.full((T, B, K), -10.0)
    for t, s in enumerate(path):
        lp[t, 0, s] = 0.0
    seqs, lens = ctc.ctc_greedy_decode(jnp.asarray(lp))
    assert int(lens[0]) == 2
    assert list(np.asarray(seqs[0][:2])) == [1, 2]
