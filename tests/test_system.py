"""End-to-end system tests: training drivers, serving, dry-run machinery."""
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import REPO, SRC, run_with_devices


def _run_cli(args, timeout=900):
    import os
    env = dict(os.environ)
    env['PYTHONPATH'] = SRC + os.pathsep + env.get('PYTHONPATH', '')
    proc = subprocess.run([sys.executable] + args, env=env, cwd=str(REPO),
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f'{args}\n{proc.stdout}\n{proc.stderr}'
    return proc.stdout


def test_train_driver_end_to_end(tmp_path):
    """Full loop: data -> sharded step -> checkpoint -> resume."""
    out = _run_cli(['-m', 'repro.launch.train', '--arch', 'chipmunk-ctc',
                    '--smoke', '--steps', '8', '--batch', '4', '--seq', '32',
                    '--ckpt-every', '4', '--ckpt-dir', str(tmp_path)])
    assert 'done' in out
    out2 = _run_cli(['-m', 'repro.launch.train', '--arch', 'chipmunk-ctc',
                     '--smoke', '--steps', '12', '--batch', '4', '--seq', '32',
                     '--ckpt-dir', str(tmp_path), '--resume'])
    assert 'resumed at step 8' in out2


def test_serve_driver_end_to_end():
    out = _run_cli(['-m', 'repro.launch.serve', '--arch', 'qwen3-14b',
                    '--requests', '3', '--slots', '2', '--max-new', '3'])
    assert 'served 3 requests' in out


def test_lm_train_loss_decreases():
    """~1M-param transformer trains for 25 steps; loss must drop."""
    out = _run_cli(['examples/train_lm.py', '--tiny', '--steps', '25',
                    '--ckpt-dir', '/tmp/repro_test_lm'])
    lines = [l for l in out.splitlines() if l.startswith('step')]
    first = float(lines[0].split('loss')[1].split()[0])
    last = float(lines[-1].split('loss')[1].split()[0])
    assert last < first - 0.5, out


@pytest.mark.skipif(
    (os.cpu_count() or 1) < 8,
    reason='environment-gated: SPMD-compiling an LM train cell over the '
           '256-chip production mesh segfaults the XLA CPU compiler on small '
           'hosts (observed on 2-core CI boxes); the dry-run path itself is '
           'covered by test_dryrun_single_cell_small_host below')
def test_dryrun_single_cell_multidevice():
    """Lower+compile one (arch x shape) cell on the production mesh in a
    subprocess with 512 placeholder devices; checks the full dry-run path."""
    out = run_with_devices("""
from repro.launch.dryrun import lower_cell
rec = lower_cell('whisper-base', 'train_4k', multi_pod=False)
assert rec['status'] == 'ok', rec
assert rec['roofline']['flops'] > 0
assert rec['roofline']['bottleneck'] in ('compute', 'memory', 'collective')
print('OK', rec['roofline']['bottleneck'])
""", n_devices=512, timeout=900)
    assert 'OK' in out


def test_dryrun_single_cell_small_host():
    """Same dry-run path (lower+compile+roofline on the production mesh) with
    the paper's own CTC cell — small enough to SPMD-compile on any host."""
    out = run_with_devices("""
from repro.launch.dryrun import lower_cell
rec = lower_cell('chipmunk-ctc', 'train_4k', multi_pod=False)
assert rec['status'] == 'ok', rec
assert rec['roofline']['flops'] > 0
assert rec['roofline']['bottleneck'] in ('compute', 'memory', 'collective')
print('OK', rec['roofline']['bottleneck'])
""", n_devices=512, timeout=900)
    assert 'OK' in out


def test_dryrun_multipod_cell():
    out = run_with_devices("""
from repro.launch.dryrun import lower_cell
rec = lower_cell('xlstm-1.3b', 'decode_32k', multi_pod=True)
assert rec['status'] == 'ok', rec
assert rec['n_chips'] == 512
print('OK')
""", n_devices=512, timeout=900)
    assert 'OK' in out


def test_production_mesh_shapes():
    out = run_with_devices("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
assert dict(zip(m1.axis_names, m1.devices.shape)) == {'data': 16, 'model': 16}
assert dict(zip(m2.axis_names, m2.devices.shape)) == {
    'pod': 2, 'data': 16, 'model': 16}
print('OK')
""", n_devices=512)
    assert 'OK' in out


def test_long_context_skip_policy():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4a)."""
    from repro import configs
    runnable = {a for a in configs.ASSIGNED_ARCHS
                if any(s.name == 'long_500k'
                       for s in configs.shapes_for(configs.get_config(a)))}
    assert runnable == {'xlstm-1.3b', 'hymba-1.5b', 'mixtral-8x22b'}


def test_cell_count():
    """10 assigned archs x shapes = 33 runnable cells (40 minus 7 documented
    long_500k skips) + 3 chipmunk-ctc cells."""
    from repro.launch.dryrun import all_cells
    cells = all_cells()
    assert len(cells) == 36
    assert len([c for c in cells if c[0] != 'chipmunk-ctc']) == 33
