"""Schedule strategies + replay harness for the serving conformance suite.

The property-based tests (tests/test_serving_async.py) draw randomized
serving schedules — utterance lengths, priorities, staggered submissions,
preempt/evict/resume control ops, engine-failure and slot-poison injections
— and replay the SAME schedule against a synchronous engine, an async
double-buffered engine, and the monolithic forward, asserting bit-equal
outputs.  Works with real ``hypothesis`` and with the deterministic stub
(tests/_hypothesis_stub.py) the CI image falls back to.

Replay is keyed on the engine's COMMITTED step counter (``_step_idx``), not
the host loop iteration: both dispatch modes pass through every committed
step index in order, so each control op fires exactly once at the same
logical point in both replays.  The async engine may have one more chunk in
flight when an op fires (its control-plane barrier commits it first) — that
moves a chunk boundary, which the §7 masking contract makes output-invariant
— but which streams exist, which frames they carry, and every injected fault
index are identical across modes by construction.

Two schedule families, because their conformance arguments differ:

  * **control-op schedules** (``op_schedules``): preempt/evict/resume and
    priority admission interleave with serving; no poison (a moved chunk
    boundary legally changes which SLOT a given stream occupies at a given
    step, so slot-keyed poison could pick different victims per mode).
  * **fault schedules** (``fault_schedules``): deterministic engine-failure
    and slot-poison injections, no control ops (scheduling is then
    bit-reproducible across modes, so the quarantine victim is too).
"""
from __future__ import annotations

import numpy as np

try:
    from hypothesis import strategies as st
except ImportError:          # subprocess replays skip conftest's stub install
    from _hypothesis_stub import strategies as st

N_IN_FALLBACK = 13          # smoke config input width (overridden by caller)


def make_utts(lens, n_in):
    """Deterministic utterances for a drawn length list: stream i's frames
    depend only on (i, lens[i], n_in), so every replay — sync, async,
    monolithic — sees identical inputs."""
    return [np.random.RandomState(1000 + 7 * i + L)
            .randn(L, n_in).astype(np.float32) * 0.5
            for i, L in enumerate(lens)]


class _StubMapped:
    """``.map`` shim for the hypothesis stub's bare strategy objects."""

    def __init__(self, inner, fn):
        self.draw = lambda rnd: fn(inner.draw(rnd))


def _mapped(raw, fn):
    return raw.map(fn) if hasattr(raw, 'map') else _StubMapped(raw, fn)


def op_schedules(max_ops: int = 4):
    """Strategy for control-op schedules: staggered priority submissions
    plus preempt / evict+resume ops keyed on committed step indices.
    Targets are drawn as raw integers and taken mod the stream count at
    replay, so the strategy needs no dependent draws (stub-compatible)."""
    raw = st.tuples(
        st.lists(st.integers(1, 26), min_size=2, max_size=5),    # lens
        st.lists(st.integers(0, 1), min_size=5, max_size=5),     # priorities
        st.lists(st.integers(0, 4), min_size=5, max_size=5),     # submit_at
        st.lists(st.tuples(st.integers(0, 8),                    # ops: at
                           st.sampled_from(('preempt', 'evict_resume')),
                           st.integers(0, 7)),                   # raw target
                 min_size=0, max_size=max_ops),
    )
    return _mapped(raw, _normalize_op_schedule)


def _normalize_op_schedule(raw):
    lens, priorities, submit_at, ops = raw
    n = len(lens)
    return {
        'lens': list(lens),
        'priorities': [priorities[i % len(priorities)] for i in range(n)],
        'submit_at': [submit_at[i % len(submit_at)] for i in range(n)],
        'ops': [(at, kind, tgt % n) for at, kind, tgt in ops],
        'fail_at': {},
        'poison_at': {},
    }


def fault_schedules():
    """Strategy for fault-injection schedules: engine failures (degradation
    + retry of the same chunk) and slot poisons (quarantine), with plain
    FIFO submissions and no control ops."""
    raw = st.tuples(
        st.lists(st.integers(1, 26), min_size=2, max_size=5),    # lens
        st.lists(st.integers(1, 6), min_size=0, max_size=2),     # fail steps
        st.lists(st.tuples(st.integers(1, 6), st.integers(0, 2)),
                 min_size=0, max_size=1),                        # poisons
    )
    return _mapped(raw, _normalize_fault_schedule)


def _normalize_fault_schedule(raw):
    lens, fail_steps, poisons = raw
    return {
        'lens': list(lens),
        'priorities': [0] * len(lens),
        'submit_at': [0] * len(lens),
        'ops': [],
        'fail_at': {s: 1 for s in fail_steps},
        'poison_at': dict(poisons),
    }


def recovery_schedules():
    """Strategy for fail -> recover -> fail schedules (§14): permanent
    engine failures followed by scheduled heals, with the promotion
    hysteresis drawn too, so replays exercise degrade / heal /
    promote_canary / promote / promote_rejected / flap paths.  Utterances
    are long enough that the committed step counter reaches every drawn
    recovery step (heals are polled at the top of ``step``, keyed on
    committed steps — a drained engine never heals)."""
    raw = st.tuples(
        st.lists(st.integers(48, 96), min_size=2, max_size=3),   # lens
        st.integers(1, 3),                                       # first fail
        st.integers(1, 3),                                       # heal gap
        st.integers(0, 1),                                       # re-fail?
        st.integers(2, 4),                                       # re-fail gap
        st.integers(1, 3),                                       # hysteresis
    )
    return _mapped(raw, _normalize_recovery_schedule)


def _normalize_recovery_schedule(raw):
    lens, fail1, heal_gap, refail, refail_gap, hysteresis = raw
    fail_at = {fail1: 1}
    recover_at = {fail1 + heal_gap: 1}
    if refail:
        f2 = fail1 + heal_gap + refail_gap
        fail_at[f2] = 1
        recover_at[f2 + heal_gap] = 1
    return {
        'lens': list(lens),
        'priorities': [0] * len(lens),
        'submit_at': [0] * len(lens),
        'ops': [],
        'fail_at': fail_at,
        'poison_at': {},
        'recover_at': recover_at,
        'promote_hysteresis': hysteresis,
    }


def run_schedule(eng, utts, sched, max_steps: int = 400):
    """Replay one schedule to completion; returns ``{sid: (log_probs,
    errored)}``.  Submissions and ops trigger when the engine's committed
    step counter reaches their ``at`` (or immediately once the engine goes
    idle — 'no earlier than' semantics, identical in both modes because
    idleness is a function of committed scheduler state)."""
    n = len(utts)
    submitted = [False] * n
    ops_left = sorted(enumerate(sched['ops']),
                      key=lambda kv: (kv[1][0], kv[0]))
    sessions = {}
    for _ in range(max_steps):
        idx = eng._step_idx
        idle = not eng.sched.busy and eng._pending is None
        for i in range(n):
            if not submitted[i] and (sched['submit_at'][i] <= idx or idle):
                sessions[i] = eng.submit(utts[i], sid=i,
                                         priority=sched['priorities'][i])
                submitted[i] = True
                idle = False
        fired = []
        for key, (at, kind, tgt) in ops_left:
            if at <= idx:
                fired.append((key, (at, kind, tgt)))
                if kind == 'preempt':
                    eng.preempt(tgt)
                else:                        # evict_resume
                    sess = eng.evict(tgt)
                    if sess is not None and sess.error is None:
                        eng.resume(sess)
        for f in fired:
            ops_left.remove(f)
        if not eng.step():
            # fully idle: every remaining op would be a no-op (nothing is
            # active or queued), so only unsubmitted streams matter
            if all(submitted):
                break
    else:
        raise AssertionError('schedule did not drain within max_steps')
    eng.run()
    out = {}
    for i in range(n):
        sess = sessions[i]
        out[i] = (sess.full_log_probs(), sess.error is not None)
    return out


def assert_outputs_equal(a, b, context=''):
    """Bit-equality of two ``run_schedule`` results: same streams, same
    quarantine verdicts, identical log-prob bits."""
    assert set(a) == set(b), (context, sorted(a), sorted(b))
    for sid in a:
        lp_a, err_a = a[sid]
        lp_b, err_b = b[sid]
        assert err_a == err_b, (context, sid, err_a, err_b)
        np.testing.assert_array_equal(
            lp_a, lp_b, err_msg=f'{context} sid={sid}')
