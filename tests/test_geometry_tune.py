"""Geometry-aware autotuning (DESIGN.md §13).

Pins the geometry tuner's contracts: the candidate space is budget- and
VMEM-pruned and replays deterministically; `stage_layer_blocks` validates
its inputs and honours the explicit `blocks=` override; uneven stage
splits are BIT-EQUAL to the balanced default on a fixed (rows, cols) grid
— in BOTH in-stage orders, which also pins the macro-step dispatch fix
(per-stage layer COUNTS, not tuple arity, pick the batched branch);
`resolve_staged_blocks` consults the cache with the admission guards
staying authoritative; and the CLI fails fast with an actionable message
when the requested mesh exceeds the device budget (S2).
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

from _subproc import REPO, SRC, run_with_devices


# ---------------------------------------------------------------------------
# stage_layer_blocks: validation + override (S1)
# ---------------------------------------------------------------------------

def test_stage_layer_blocks_validates_and_overrides():
    from repro.core.systolic import stage_layer_blocks
    # balanced default: ceil-sized blocks first
    assert stage_layer_blocks(3, 2) == ((0, 2), (2, 3))
    # n_stages > n_layers: TRAILING empty blocks (passthrough delay)
    assert stage_layer_blocks(3, 5) == (
        (0, 1), (1, 2), (2, 3), (3, 3), (3, 3))
    # explicit override
    assert stage_layer_blocks(3, 2, blocks=(1, 2)) == ((0, 1), (1, 3))
    assert stage_layer_blocks(4, 3, blocks=(1, 2, 1)) == (
        (0, 1), (1, 3), (3, 4))
    with pytest.raises(ValueError):
        stage_layer_blocks(0, 2)
    with pytest.raises(ValueError):
        stage_layer_blocks(3, 0)
    with pytest.raises(ValueError):
        stage_layer_blocks(3, 2, blocks=(1, 1))       # sum != n_layers
    with pytest.raises(ValueError):
        stage_layer_blocks(3, 2, blocks=(1, 1, 1))    # len != n_stages
    with pytest.raises(ValueError):
        stage_layer_blocks(3, 2, blocks=(4, -1))      # negative entry


def test_perf_model_blocks_override():
    from repro.core import perf_model as pm
    layers = [pm.LayerDims(48, 96)] + [pm.LayerDims(96, 96)] * 3
    cfg = pm.TileConfig(2, 2, 2)
    bal = pm.staged_wavefront_cycles(layers, cfg, 32, chunk=8)
    # balanced (2, 2) passed explicitly is the same schedule
    assert pm.staged_wavefront_cycles(layers, cfg, 32, chunk=8,
                                      blocks=(2, 2)) == bal
    # (1, 3) grows the bottleneck stage: strictly slower in the model
    uneven = pm.staged_wavefront_cycles(layers, cfg, 32, chunk=8,
                                        blocks=(1, 3))
    assert uneven > bal
    with pytest.raises(ValueError):
        pm.staged_wavefront_cycles(layers, cfg, 32, chunk=8, blocks=(3, 2))


# ---------------------------------------------------------------------------
# Candidate space: pruning + determinism
# ---------------------------------------------------------------------------

def test_geometry_enumeration_prunes_and_replays():
    from repro.tune.shmoo import (_stage_splits,
                                  enumerate_geometry_candidates,
                                  rank_geometry_candidates)
    assert _stage_splits(3, 2) == [(1, 2), (2, 1)]
    assert _stage_splits(3, 3) == [(1, 1, 1)]
    assert _stage_splits(4, 2) == [(1, 3), (2, 2), (3, 1)]
    cands = enumerate_geometry_candidates(123, 421, 3, 128, 8, devices=50)
    assert cands
    for c in cands:
        assert 2 <= c.stages <= 3                      # [2, n_layers]
        assert c.stages * c.rows * c.cols <= 50        # device budget
        assert sum(c.blocks) == 3 and min(c.blocks) >= 1
        assert c.lb == max(c.blocks)
    # the flagship balanced 2x(5x5) default is a member
    assert any(c.stages == 2 and c.rows == 5 and c.cols == 5
               and c.blocks == (2, 1) for c in cands)
    # pure functions: identical space + ranking on a second call
    again = enumerate_geometry_candidates(123, 421, 3, 128, 8, devices=50)
    assert again == cands
    assert (rank_geometry_candidates(cands, 123, 421, 3, 128)
            == rank_geometry_candidates(again, 123, 421, 3, 128))
    # a 1-device budget admits no multi-stage geometry at all
    assert enumerate_geometry_candidates(123, 421, 3, 128, 8,
                                         devices=1) == []


def test_arith_signature_partitions_column_splits():
    from repro.tune.shmoo import enumerate_geometry_candidates
    cands = enumerate_geometry_candidates(123, 421, 3, 128, 8, devices=50)
    by_cols = {}
    for c in cands:
        by_cols.setdefault((c.cols, c.rows), set()).add(c.arith_signature)
    # one signature per (cols, rows) pad class; rows-only changes with the
    # same lcm keep the signature (e.g. 5x5 and 1x5 both pad 421 -> 425,
    # bk=85 — the bit-equal class the measured trial stays inside)
    sig_5x5 = next(iter(by_cols[(5, 5)]))
    sig_1x5 = next(iter(by_cols[(5, 1)]))
    assert sig_5x5 == sig_1x5 == (425, 85)
    assert next(iter(by_cols[(5, 2)])) == (430, 86)   # different class


def test_lb_candidates_and_ranking():
    from repro.tune.shmoo import enumerate_lb_candidates, rank_lb_candidates
    cands = enumerate_lb_candidates(48, 96, 4, 4)
    assert cands == [1, 2, 4]                 # divisors, all VMEM-admissible
    ranked = rank_lb_candidates(cands, 4)
    assert ranked[0][0] == 4                  # fewest re-stream passes
    # the flagship 421-hidden stack: only lb=1 fits the budget
    assert enumerate_lb_candidates(123, 421, 3, 8) == [1]


# ---------------------------------------------------------------------------
# Numerics: uneven splits bit-equal (incl. the batched-order counts fix)
# ---------------------------------------------------------------------------

_UNEVEN_SNIPPET = r"""
import jax, numpy as np
from repro.core import lstm, systolic
from repro.tune.schedule import ScheduleCache, ScheduleEntry, \
    using_schedule_cache

stack = lstm.init_lstm_stack(jax.random.PRNGKey(0), 24, 48, 3)
xs = jax.random.normal(jax.random.PRNGKey(1), (32, 4, 24)) * 0.5
mesh = systolic.make_systolic_mesh(1, 1, stage=2)

def run(**kw):
    return np.asarray(systolic.systolic_lstm_stack_seq(
        stack, mesh, xs, **kw)[0])

ref = run(in_stage='sequential')                       # balanced (2, 1)
for blocks in ((2, 1), (1, 2)):
    for mode in ('sequential', 'batched'):
        out = run(blocks=blocks, in_stage=mode)
        np.testing.assert_array_equal(out, ref)

# cache-driven split: a stack_f32 entry carrying blocks='1,2' must be
# consumed by resolve_staged_blocks and leave the numerics bit-identical
ent = ScheduleEntry(kind='stack_f32', n_x=24, n_h=48, n_layers=3, T=32,
                    B=4, mesh='stage:2,row:1,col:1', tc=8,
                    in_stage='sequential', blocks='1,2')
with using_schedule_cache(ScheduleCache([ent])):
    got = systolic.resolve_staged_blocks(3, 32, 2, n_h=48, n_x=24,
                                         batch=4, mesh=mesh)
    assert got == (1, 2), got
    np.testing.assert_array_equal(run(), ref)
print('UNEVEN-OK')
"""


def test_uneven_split_bit_equal_2dev():
    out = run_with_devices(_UNEVEN_SNIPPET, 2, timeout=900)
    assert 'UNEVEN-OK' in out


# ---------------------------------------------------------------------------
# Cache consumption: guards stay authoritative
# ---------------------------------------------------------------------------

def test_resolve_staged_blocks_guards():
    from repro.core.systolic import resolve_staged_blocks
    from repro.tune.schedule import (ScheduleCache, ScheduleEntry,
                                    using_schedule_cache)

    def entry(blocks):
        return ScheduleEntry(kind='stack_f32', n_x=24, n_h=48, n_layers=3,
                             T=32, B=4, mesh='any', tc=8, blocks=blocks)

    # no cache -> no tuned split
    assert resolve_staged_blocks(3, 32, 2, n_h=48, n_x=24, batch=4) is None
    with using_schedule_cache(ScheduleCache([entry('1,2')])):
        assert resolve_staged_blocks(3, 32, 2, n_h=48, n_x=24,
                                     batch=4) == (1, 2)
    # malformed / inconsistent entries are ignored, never propagated
    for bad in ('', '1,1', '1,1,1', '4,-1', 'x,y'):
        with using_schedule_cache(ScheduleCache([entry(bad)])):
            assert resolve_staged_blocks(3, 32, 2, n_h=48, n_x=24,
                                         batch=4) is None, bad


def test_admission_stricter_with_tuned_bottleneck_2dev():
    # a tuned split that concentrates layers can only make VMEM admission
    # stricter: balanced lb=ceil(4/2)=2 fits at n_h=400, the tuned '3,1'
    # bottleneck (3 layers resident) does not
    snippet = r"""
from repro.core import systolic
from repro.tune.schedule import ScheduleCache, ScheduleEntry, \
    using_schedule_cache
mesh = systolic.make_systolic_mesh(1, 1, stage=2)
assert systolic.seq_scaleout_admissible(400, mesh, n_layers=4,
                                        n_x=48, T=32, batch=4)
ent = ScheduleEntry(kind='stack_f32', n_x=48, n_h=400, n_layers=4, T=32,
                    B=4, mesh='stage:2,row:1,col:1', tc=8, blocks='3,1')
with using_schedule_cache(ScheduleCache([ent])):
    assert not systolic.seq_scaleout_admissible(400, mesh, n_layers=4,
                                                n_x=48, T=32, batch=4)
print('ADMISSION-OK')
"""
    out = run_with_devices(snippet, 2, timeout=900)
    assert 'ADMISSION-OK' in out


# ---------------------------------------------------------------------------
# Measured geometry trial + replay (small forced-device run)
# ---------------------------------------------------------------------------

_MEASURED_SNIPPET = r"""
import jax, numpy as np
from repro.core import lstm
from repro.tune import ScheduleCache
from repro.tune.autotune import replay_check, tune_geometry

stack = lstm.init_lstm_stack(jax.random.PRNGKey(0), 24, 48, 3)
xs = jax.random.normal(jax.random.PRNGKey(1), (32, 4, 24)) * 0.5
cache = ScheduleCache()
entry, records, base = tune_geometry(stack, xs, devices=4, ref=(2, 1, 2),
                                     cache=cache, iters=2, warmup=1)
assert entry.source == 'measured' and entry.measured_us > 0
assert entry.mesh == 'devices:4'
assert base > 0
kinds = sorted(e.kind for e in cache.entries())
assert kinds == ['geometry', 'stack_f32'], kinds
assert replay_check(cache) >= 1
roundtrip = ScheduleCache.from_json(cache.to_json())
assert roundtrip.to_json() == cache.to_json()
print('GEOTUNE-OK')
"""


def test_tune_geometry_measured_4dev():
    out = run_with_devices(_MEASURED_SNIPPET, 4, timeout=900)
    assert 'GEOTUNE-OK' in out


# ---------------------------------------------------------------------------
# CLI: actionable device-budget errors (S2)
# ---------------------------------------------------------------------------

def _run_cli(*argv):
    env = dict(os.environ)
    env['PYTHONPATH'] = SRC + os.pathsep + env.get('PYTHONPATH', '')
    return subprocess.run([sys.executable, '-m', 'repro.tune', *argv],
                          env=env, capture_output=True, text=True,
                          timeout=900)


def test_cli_over_budget_fails_fast(tmp_path):
    proc = _run_cli('--staged-devices', '2', '--stages', '2', '--rows',
                    '5', '--cols', '5', '--out', str(tmp_path / 'c.json'))
    assert proc.returncode != 0
    msg = proc.stderr + proc.stdout
    assert 'needs 50 devices' in msg and '--staged-devices' in msg
    # fail-fast: no tuning ran, nothing was written
    assert not (tmp_path / 'c.json').exists()
    # raw shard_map internals must not leak
    assert 'shard_map' not in msg


def test_cli_geometry_predicted_deterministic(tmp_path):
    a, b = tmp_path / 'a.json', tmp_path / 'b.json'
    for out in (a, b):
        proc = _run_cli('--geometry', '--devices', '4', '--out', str(out),
                        '--csv', str(out.with_suffix('.csv')))
        assert proc.returncode == 0, proc.stderr
        assert 'geometry ->' in proc.stdout
    assert a.read_bytes() == b.read_bytes()
    assert (a.with_suffix('.csv').read_bytes()
            == b.with_suffix('.csv').read_bytes())
    doc = json.loads(a.read_text())
    geo = [e for e in doc['entries'] if e['kind'] == 'geometry']
    assert len(geo) == 1 and geo[0]['mesh'] == 'devices:4'
    assert geo[0]['source'] == 'predicted'
