"""Silicon model must reproduce the paper's published numbers (Tables 1, 2, Fig. 5)."""
import pytest

from repro.core import perf_model as pm


def test_peak_performance_matches_table1():
    assert pm.peak_gops(1.24) == pytest.approx(32.2, rel=0.01)   # 32.3 Gop/s row
    assert pm.peak_gops(0.75) == pytest.approx(3.8, rel=0.02)


def test_energy_efficiency_matches_abstract():
    # 3.08 Gop/s/mW at the 0.75 V corner (abstract + Table 1).
    assert pm.efficiency_gops_per_mw(0.75) == pytest.approx(3.08, rel=0.02)
    assert pm.efficiency_gops_per_mw(1.24) == pytest.approx(1.11, rel=0.01)


def test_area_efficiency():
    assert pm.area_efficiency_gops_per_mm2() == pytest.approx(34.4, rel=0.01)


def test_power_model_predicts_low_corner():
    # C_eff fit at 1.24 V predicts the 0.75 V measurement within 2.5 %.
    assert pm.power_w(0.75) * 1e3 == pytest.approx(1.24, rel=0.025)
    assert pm.power_w(1.24) * 1e3 == pytest.approx(29.03, rel=1e-6)


def test_shmoo_monotone():
    vs = [0.75 + 0.05 * i for i in range(10)]
    fs = [pm.freq_hz(v) for v in vs]
    ps = [pm.power_w(v) for v in vs]
    assert all(b > a for a, b in zip(fs, fs[1:]))
    assert all(b > a for a, b in zip(ps, ps[1:]))


def test_network_size_matches_paper():
    total = sum(l.weight_bytes() for l in pm.CTC_3L_421H)
    assert 3.7e6 < total < 3.9e6  # "~3.8e6 weights"


def test_table2_reproduction():
    """Every execution-time cell within 4 % of the paper; powers within 3 %."""
    paper_power = {  # (config, V) -> (peak mW, avg mW or None)
        ('systolic 3x5x5', 1.24): (1833.75, 16.53),
        ('systolic 5x5', 1.24): (611.25, 96.89),
        ('single', 1.24): (24.45, None),
        ('systolic 3x5x5', 0.75): (165.75, 12.55),
        ('systolic 5x5', 0.75): (55.25, None),
        ('single', 0.75): (2.21, None),
    }
    rows = pm.table2()
    assert len(rows) == 6
    for row in rows:
        key = (row['config'], row['voltage'])
        want_ms = pm.PAPER_TABLE2_MS[key]
        assert row['exec_time_ms'] == pytest.approx(want_ms, rel=0.04), key
        peak, avg = paper_power[key]
        assert row['peak_power_mw'] == pytest.approx(peak, rel=0.01), key
        if avg is not None and row['meets_deadline']:
            assert row['avg_power_mw'] == pytest.approx(avg, rel=0.03), key


def test_deadline_verdicts_match_paper_bold():
    """Paper bolds configs meeting the 10 ms deadline: 3x5x5 @both V, 5x5 @1.24 V."""
    verdicts = {(r['config'], r['voltage']): r['meets_deadline'] for r in pm.table2()}
    assert verdicts[('systolic 3x5x5', 1.24)]
    assert verdicts[('systolic 3x5x5', 0.75)]
    assert verdicts[('systolic 5x5', 1.24)]
    assert not verdicts[('systolic 5x5', 0.75)]
    assert not verdicts[('single', 1.24)]
    assert not verdicts[('single', 0.75)]


def test_calibration_is_two_point_fit():
    beta, cpb = pm.fit_calibration()
    assert beta == pytest.approx(pm.BETA, rel=1e-6)
    assert cpb == pytest.approx(pm.LOAD_CPB, rel=1e-4)


def test_wavefront_pipelines_long_utterances():
    """With one array per layer, the wavefront schedule approaches a
    bottleneck-layer-per-step steady state: for the CTC stack (whose three
    layers have near-equal step cycles on 5x5 arrays) that is ~3x the
    sequential model at T=128, degraded only by the (L-1)/(T+L-1)
    fill/drain bubbles."""
    cfg = pm.TileConfig(3, 5, 5)
    T = 128
    wf = pm.wavefront_cycles(pm.CTC_3L_421H, cfg, T)
    seq = pm.sequential_cycles(pm.CTC_3L_421H, cfg, T)
    per = [pm.layer_step_cycles(ld, cfg) for ld in pm.CTC_3L_421H]
    # exact identity of the model, then the headline ratio
    assert wf == pytest.approx((T + 2) * max(per))
    assert seq == pytest.approx(T * sum(per))
    assert 2.5 < seq / wf < 3.0
    assert pm.pipeline_fill_drain_overhead(pm.CTC_3L_421H, T) == \
        pytest.approx(2 / 130)


def test_wavefront_fill_drain_dominates_single_frame():
    """At T=1 (the Table-2 per-frame deadline workload) the pipeline is all
    fill/drain: the wavefront model must NOT beat the sequential one —
    exactly why table2() keeps charging frames sequentially."""
    cfg = pm.TileConfig(3, 5, 5)
    wf = pm.wavefront_cycles(pm.CTC_3L_421H, cfg, 1)
    seq = pm.sequential_cycles(pm.CTC_3L_421H, cfg, 1)
    assert wf >= seq * 0.99
    assert pm.pipeline_fill_drain_overhead(pm.CTC_3L_421H, 1) == \
        pytest.approx(2 / 3)


def test_wavefront_degenerates_without_layer_arrays():
    """Fewer arrays than layers cannot overlap layers: the wavefront model
    collapses to the sequential one (including weight re-streaming)."""
    for cfg in (pm.TileConfig(1, 5, 5), pm.TileConfig(1, 1, 1)):
        assert pm.wavefront_cycles(pm.CTC_3L_421H, cfg, 16) == \
            pytest.approx(pm.sequential_cycles(pm.CTC_3L_421H, cfg, 16))


def test_staged_schedule_identities():
    """The staged cycle model's exact identities: one layer per stage at
    chunk=1 IS the per-diagonal wavefront schedule; a 2-stage placement of
    the 3-layer stack pays the ceil-sized (2-layer) bottleneck block per
    macro-step; chunking trades handover count for fill/drain depth."""
    T = 128
    cfg3 = pm.TileConfig(3, 5, 5)
    per = [pm.layer_step_cycles(ld, cfg3) for ld in pm.CTC_3L_421H]
    assert pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg3, T, chunk=1) == \
        pytest.approx(pm.wavefront_cycles(pm.CTC_3L_421H, cfg3, T))
    cfg2 = pm.TileConfig(2, 5, 5)
    per2 = [pm.layer_step_cycles(ld, cfg2) for ld in pm.CTC_3L_421H]
    st2 = pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg2, T, chunk=1)
    assert st2 == pytest.approx((T + 1) * (per2[0] + per2[1]))
    # more stages pipeline deeper; any staging beats the sequential charge
    st3 = pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg3, T, chunk=1)
    seq = pm.sequential_cycles(pm.CTC_3L_421H, cfg2, T)
    assert st3 < st2 < seq
    # chunked: K + S - 1 macro-steps of chunk * bottleneck
    st_c = pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg3, T, chunk=16)
    assert st_c == pytest.approx((8 + 2) * 16 * max(per))
    assert pm.staged_fill_drain_overhead(3, T, 1) == pytest.approx(2 / 130)
    assert pm.staged_fill_drain_overhead(3, T, 16) == pytest.approx(2 / 10)
    # one array cannot pipeline: degenerates to the sequential model
    assert pm.staged_wavefront_cycles(pm.CTC_3L_421H,
                                      pm.TileConfig(1, 5, 5), 16) == \
        pytest.approx(pm.sequential_cycles(pm.CTC_3L_421H,
                                           pm.TileConfig(1, 5, 5), 16))


def test_graves75_staged_estimate_meets_table2_realtime_claim():
    """The graves-75 staged estimate against the paper's Table-2 real-time
    claim: 3x(5x5) executes a frame in 0.09 ms @1.24 V / 0.76 ms @0.75 V,
    well inside the 10 ms MFCC deadline — the staged steady state pays only
    the bottleneck layer per frame, so it must come in at ~1/3 of the
    Table-2 sum-of-layers row (and a fortiori meet the deadline)."""
    for v in (pm.V_MAX, pm.V_MIN):
        per_frame = pm.staged_realtime_frame_s(v=v, T=100)
        table2_s = pm.PAPER_TABLE2_MS[('systolic 3x5x5', round(v, 2))] * 1e-3
        assert per_frame < pm.FRAME_PERIOD_S          # real time
        assert per_frame < table2_s                    # beats sum-of-layers
        # steady state ~ bottleneck/3 of the (near-balanced) 3-layer stack
        assert per_frame == pytest.approx(table2_s / 3, rel=0.10)


def test_wavefront_gops_bounded_by_peak():
    """Sustained Gop/s under the fused schedule: above the sequential
    estimate, below the 75-engine peak."""
    cfg = pm.TileConfig(3, 5, 5)
    got = pm.wavefront_gops(pm.CTC_3L_421H, cfg, 1.24, T=128)
    seq_secs = pm.sequential_cycles(pm.CTC_3L_421H, cfg, 128) / pm.freq_hz(1.24)
    ops = 2 * 128 * sum(4 * ld.n_h * (ld.n_x + ld.n_h)
                        for ld in pm.CTC_3L_421H)
    seq_gops = ops / seq_secs / 1e9
    assert got > seq_gops * 2.5
    assert got < pm.peak_gops(1.24) * cfg.n_engines


def test_staged_in_stage_batched_identities():
    """``in_stage_batched=True``: each macro-step retires its stage's layer
    block as one diagonal wavefront — (chunk + Lb - 1) rounds of the block
    bottleneck instead of chunk * sum(block).  Exact identities: one layer
    per stage coincides with the sequential form (nothing to batch); the
    2-stage CTC placement's seq/batched ratio sits in (1, Lb]."""
    T, chunk = 128, 16
    cfg2 = pm.TileConfig(2, 5, 5)
    per2 = [pm.layer_step_cycles(ld, cfg2) for ld in pm.CTC_3L_421H]
    seq = pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg2, T, chunk=chunk)
    bat = pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg2, T, chunk=chunk,
                                     in_stage_batched=True)
    # stage 0 = layers {0,1} (the bottleneck block, Lb=2), stage 1 = {2}
    K = T // chunk
    assert seq == pytest.approx(
        (K + 1) * chunk * (per2[0] + per2[1]))
    assert bat == pytest.approx(
        (K + 1) * (chunk + 1) * max(per2[0], per2[1]))
    assert 1.0 < seq / bat <= 2.0          # in (1, Lb], Lb = 2
    assert seq / bat == pytest.approx(1.882, rel=0.01)   # the tuner's input
    # one layer per stage: Lb = 1 everywhere -> the two orders coincide
    cfg3 = pm.TileConfig(3, 5, 5)
    assert pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg3, T, chunk=chunk,
                                      in_stage_batched=True) == \
        pytest.approx(pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg3, T,
                                                 chunk=chunk))


def test_staged_in_stage_measured_bracket():
    """The committed BENCH row vs the model: the silicon model predicts the
    batched diagonals win ~1.9x (concurrent block slots), but the CPU
    emulation time-slices every "device" onto one core — FLOP-bound, so
    the measured ratio may land BELOW 1 (the sequential order's hoisted
    full-width below-GEMMs are FLOP-optimal).  What must ALWAYS hold: the
    measured ratio stays inside [1/(Lb+1), predicted] — worse than the
    full serialization floor or better than the concurrency ceiling would
    mean the benchmark is measuring something else.  The per-host decision
    itself belongs to repro.tune (see tuned_schedules.json)."""
    import json
    import pathlib
    bench = pathlib.Path(__file__).resolve().parents[1] / 'BENCH_systolic.json'
    rows = {r['name']: r['us_per_call']
            for r in json.loads(bench.read_text())['results']}
    us_seq = rows['scaleout/stack_fused_systolic']
    us_bat = rows['scaleout/stack_fused_systolic_batched']
    measured = us_seq / us_bat
    cfg2 = pm.TileConfig(2, 5, 5)
    pred = (pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg2, 128, chunk=16)
            / pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg2, 128, chunk=16,
                                         in_stage_batched=True))
    Lb = 2
    assert 1.0 / (Lb + 1) <= measured <= pred, (measured, pred)


# ---------------------------------------------- die-aware ladder rungs (§14)
def test_die_staged_reduces_to_staged_without_die_boundary():
    """``dies<=1`` (or a zero hop charge) is exactly the single-die staged
    model — the die generalisation adds ONLY the boundary hop term."""
    T = 128
    cfg3 = pm.TileConfig(3, 5, 5)
    base = pm.staged_wavefront_cycles(pm.CTC_3L_421H, cfg3, T, chunk=16)
    assert pm.die_staged_wavefront_cycles(pm.CTC_3L_421H, cfg3, T, dies=1,
                                          chunk=16) == pytest.approx(base)
    assert pm.die_staged_wavefront_cycles(pm.CTC_3L_421H, cfg3, T, dies=3,
                                          chunk=16, hop_cpb=0.0) == \
        pytest.approx(base)
    # a real hop charge can only slow the pipeline down (bottleneck max)
    with_hop = pm.die_staged_wavefront_cycles(pm.CTC_3L_421H, cfg3, T,
                                              dies=3, chunk=16)
    assert with_hop >= base
    with pytest.raises(ValueError):
        pm.die_staged_wavefront_cycles(pm.CTC_3L_421H, cfg3, T, dies=2)


def test_die_rung_frame_estimates_are_monotone():
    """The graves-3x25 ladder has REAL intermediate rungs: per-frame time
    grows monotonically as dies fail (75 -> 50 -> 25 engines), every
    multi-die rung still beats the paper deadline at V_MAX, and the hop
    charge never inverts the ordering."""
    frames = [pm.die_rung_frame_s(topology=(3, 1, 5, 5), healthy_dies=k)
              for k in (3, 2, 1)]
    assert frames[0] < frames[1] < frames[2], frames
    assert frames[0] < pm.FRAME_PERIOD_S and frames[1] < pm.FRAME_PERIOD_S
    # all-dies-healthy at stage_per_die=1 is the classic staged estimate
    # plus only the die-boundary hops
    assert pm.die_rung_frame_s(healthy_dies=3, hop_cpb=0.0) == \
        pytest.approx(pm.staged_realtime_frame_s(v=pm.V_MAX, T=100))
