"""Fused whole-stack wavefront LSTM kernel (DESIGN.md §8).

Contracts:

  * f32: ONE wavefront launch over all layers is allclose to the layerwise
    composition (forward AND gradients via the cross-layer gate-recompute
    VJP), for zero and carried initial state;
  * int8: bit-identical to chaining the layerwise silicon-datapath
    reference layer by layer, including the opaque per-layer ``(h_q, c_q)``
    chunk carry over ≥3 ragged masked chunks;
  * dispatch: stack-level auto-selection admits the fused kernel only when
    the whole stack's resident weights fit the VMEM budget; structurally
    incompatible (heterogeneous) stacks silently fall back to the layerwise
    path with identical results;
  * serving: the streaming engine's packed slot grid rides the fused
    backend end to end — chunked ragged streams equal the monolithic
    forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lstm, quant, systolic
from repro.core.lstm import (lstm_stack_apply, lstm_stack_chunk,
                             select_stack_backend)
from repro.kernels.lstm_seq import (lstm_stack_seq, lstm_stack_seq_quantized,
                                    stack_fused_compatible,
                                    stack_vmem_bytes_estimate)


def _stack(key, n_x, n_h, n_layers, n_out=None):
    return lstm.init_lstm_stack(jax.random.PRNGKey(key), n_x, n_h, n_layers,
                                n_out)


def _chunk_plan(total, chunk):
    spans = []
    lo = 0
    while lo < total:
        spans.append((lo, min(lo + chunk, total)))
        lo += chunk
    return spans


# ------------------------------------------------------------------ f32 path
@pytest.mark.parametrize('n_x,n_h,L,T,B', [
    (24, 32, 3, 5, 2),      # ragged widths, odd T
    (32, 32, 2, 6, 3),      # n_x == n_h
    (16, 48, 4, 4, 1),      # deeper stack, B=1 decode shape
])
def test_fused_matches_layerwise_forward(n_x, n_h, L, T, B):
    p = _stack(n_x + n_h + L, n_x, n_h, L, n_out=None)
    xs = jax.random.normal(jax.random.PRNGKey(1), (T, B, n_x)) * 0.5
    ys_ref, fin_ref = lstm_stack_apply(p, xs, backend='xla_scan')
    ys, fin = lstm_stack_apply(p, xs, backend='pallas_seq_fused')
    np.testing.assert_allclose(ys, ys_ref, rtol=1e-5, atol=1e-6)
    for l in range(L):
        np.testing.assert_allclose(fin[l][0], fin_ref[l][0],
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(fin[l][1], fin_ref[l][1],
                                   rtol=1e-5, atol=1e-6)


def test_fused_with_readout_and_carried_state():
    p = _stack(7, 16, 32, 2, n_out=8)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 2, 16)) * 0.5
    states = tuple(
        (jax.random.normal(jax.random.PRNGKey(10 + l), (2, 32)) * 0.3,
         jax.random.normal(jax.random.PRNGKey(20 + l), (2, 32)) * 0.3)
        for l in range(2))
    ys_ref, fin_ref = lstm_stack_apply(p, xs, states, backend='xla_scan')
    ys, fin = lstm_stack_apply(p, xs, states, backend='pallas_seq_fused')
    np.testing.assert_allclose(ys, ys_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fin[1][1], fin_ref[1][1], rtol=1e-5, atol=1e-6)


def test_fused_partial_states_match_layerwise():
    """A per-layer state list with SOME None entries zeroes only those
    layers' carries — exactly what the layerwise loop does — never the
    provided neighbours' (backends must stay numerically interchangeable)."""
    p = _stack(3, 8, 8, 3)
    xs = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 8)) * 0.5
    h0 = jax.random.normal(jax.random.PRNGKey(2), (2, 8)) * 0.3
    c0 = jax.random.normal(jax.random.PRNGKey(3), (2, 8)) * 0.3
    states = [(h0, c0), (None, None), (None, None)]
    ys_ref, fin_ref = lstm_stack_apply(p, xs, states, backend='xla_scan')
    ys, fin = lstm_stack_apply(p, xs, states, backend='pallas_seq_fused')
    np.testing.assert_allclose(ys, ys_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fin[0][1], fin_ref[0][1], rtol=1e-5, atol=1e-6)


def test_fused_vjp_matches_layerwise_vjp():
    """The cross-layer gate-recompute VJP == differentiating the layerwise
    composition: training must be backend-agnostic whichever the stack-level
    auto-selection picks."""
    p = _stack(9, 16, 16, 2)
    xs = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 16)) * 0.5

    def loss(params, be):
        ys, fin = lstm_stack_apply(params, xs, backend=be)
        return jnp.sum(ys ** 2) + sum(jnp.sum(h * c) for h, c in fin)

    g_ref = jax.grad(lambda q: loss(q, 'xla_scan'))(p)
    g_fus = jax.grad(lambda q: loss(q, 'pallas_seq_fused'))(p)
    flat_r, _ = jax.tree_util.tree_flatten(g_ref)
    flat_f, _ = jax.tree_util.tree_flatten(g_fus)
    for a, b in zip(flat_r, flat_f):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_fused_chunked_equals_monolithic_bit_equal():
    """≥3 ragged masked chunks with per-layer carried state reproduce the
    monolithic fused call bit for bit (the §7 contract on the §8 kernel)."""
    p = _stack(3, 16, 16, 2)
    xs = jax.random.normal(jax.random.PRNGKey(2), (9, 3, 16)) * 0.5
    lens = np.array([9, 5, 7])
    mono, (mono_fin) = lstm_stack_chunk(p, xs, None,
                                        valid_len=jnp.asarray(lens),
                                        backend='pallas_seq_fused')
    st = None
    outs = []
    for lo, hi in _chunk_plan(9, 3):           # 3 chunks
        vl = jnp.asarray(np.clip(lens - lo, 0, hi - lo), jnp.int32)
        o, st = lstm_stack_chunk(p, xs[lo:hi], st, valid_len=vl,
                                 backend='pallas_seq_fused')
        outs.append(o)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(outs)),
                                  np.asarray(mono))
    for l in range(2):
        np.testing.assert_array_equal(np.asarray(st[l][0]),
                                      np.asarray(mono_fin[l][0]))
    # and the masked fused path tracks the masked layerwise path
    ref, _ = lstm_stack_chunk(p, xs, None, valid_len=jnp.asarray(lens),
                              backend='xla_scan')
    for b, L in enumerate(lens):
        np.testing.assert_allclose(np.asarray(mono)[:L, b],
                                   np.asarray(ref)[:L, b],
                                   rtol=1e-5, atol=1e-6)


def test_fused_batch_and_layer_blocking_grids():
    """The bb (serving slots) and lb (layer blocks; lb < L = partial
    residency, one layer block re-streamed per diagonal) grid dimensions
    never change numerics — including the tail-bubble slot discipline that
    only multi-block schedules exercise (a tail bubble must be identity on
    its WRITE slot, or it clobbers h_{T-1} while the layer above still
    needs it on the same diagonal)."""
    from repro.kernels.lstm_seq import lstm_stack_seq
    p = _stack(11, 24, 32, 3)
    xs = jax.random.normal(jax.random.PRNGKey(1), (7, 16, 24)) * 0.5
    ys_ref, fin_ref = lstm_stack_apply(p, xs, backend='xla_scan')
    for kw in ({'bb': 8}, {'lb': 1}, {'bb': 8, 'lb': 1}):
        ys, fin = lstm_stack_seq(p, xs, **kw)
        np.testing.assert_allclose(ys, ys_ref, rtol=1e-5, atol=1e-6,
                                   err_msg=str(kw))
        np.testing.assert_allclose(fin[2][1], fin_ref[2][1],
                                   rtol=1e-5, atol=1e-6, err_msg=str(kw))


# ------------------------------------------------------------------ int8 path
def _quantized_stack(key, n_x, n_h, L, tile):
    stack = _stack(key, n_x, n_h, L)
    qps = []
    for l, lp in enumerate(stack.layers):
        plan = systolic.SystolicPlan(n_x if l == 0 else n_h, n_h, tile)
        qps.append(systolic.quantize_packed(systolic.pack_lstm(lp, plan)))
    return qps


@pytest.mark.parametrize('n_x,n_h,tile,L,T,B', [
    (24, 32, 16, 3, 6, 2),   # x-region narrower than h-region
    (16, 16, 16, 2, 5, 1),   # single tile per region
])
def test_fused_quantized_bit_identical(n_x, n_h, tile, L, T, B):
    """Fused int8 wavefront == chaining the silicon-reference scan layer by
    layer, bit for bit."""
    qps = _quantized_stack(n_x * 13 + n_h, n_x, n_h, L, tile)
    xs = jax.random.normal(jax.random.PRNGKey(1), (T, B, n_x)) * 0.5
    h = quant.quantize(xs, quant.STATE_FMT)
    xs_q = h
    for qp in qps:
        h = systolic.systolic_layer_quantized(qp, h)
    out = lstm_stack_seq_quantized(qps, xs_q, interpret=True)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), np.asarray(h))


def test_fused_quantized_chunked_carry_bit_identical():
    """int8 chunked serving on the fused stack: ≥3 ragged masked chunks with
    the opaque per-layer (h_q, c_q) carry == the monolithic layerwise
    reference, and the carried codes == codes after exactly valid_len
    steps."""
    n_x, n_h, tile, L = 24, 32, 16, 2
    qps = _quantized_stack(5, n_x, n_h, L, tile)
    xs = jax.random.normal(jax.random.PRNGKey(1), (9, 3, n_x)) * 0.5
    xs_q = quant.quantize(xs, quant.STATE_FMT)
    h = xs_q
    for qp in qps:
        h = systolic.systolic_layer_quantized(qp, h)
    ref = np.asarray(h)

    lens = np.array([9, 4, 6])
    st = None
    outs = []
    for lo, hi in _chunk_plan(9, 3):           # 3 chunks
        vl = jnp.asarray(np.clip(lens - lo, 0, hi - lo), jnp.int32)
        o, st = lstm_stack_seq_quantized(qps, xs_q[lo:hi], state=st,
                                         valid_len=vl, return_state=True,
                                         interpret=True)
        outs.append(np.asarray(o))
    hs = np.concatenate(outs)
    for b, L_v in enumerate(lens):
        np.testing.assert_array_equal(hs[:L_v, b], ref[:L_v, b])
        np.testing.assert_array_equal(np.asarray(st[0])[-1, b, :n_h],
                                      ref[L_v - 1, b])


# ---------------------------------------------------------- distributed int8
def test_distributed_quantized_chunked_carry_bit_identical():
    """§6 scale-out now honours the same opaque-state chunk carry as the
    single-engine int8 kernel (the PR-3 ROADMAP deferral), bit for bit —
    including a mid-sequence handoff of the distributed state INTO the
    single-engine kernel."""
    from _subproc import run_with_devices
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, quant, systolic
from repro.kernels.lstm_seq import lstm_layer_seq_quantized
p = lstm.init_lstm_params(jax.random.PRNGKey(0), 16, 32)
qp = systolic.quantize_packed(
    systolic.pack_lstm(p, systolic.SystolicPlan(16, 32, 16)))
xs = jax.random.normal(jax.random.PRNGKey(1), (9, 3, 16)) * 0.5
xs_q = quant.quantize(xs, quant.STATE_FMT)
ref = np.asarray(systolic.systolic_layer_quantized(qp, xs_q))
lens = np.array([9, 4, 6])
for rows, cols in ((1, 2), (2, 1)):
    mesh = systolic.make_systolic_mesh(rows, cols)
    state = None; outs = []
    for lo, hi in ((0, 3), (3, 6), (6, 9)):
        vl = jnp.asarray(np.clip(lens - lo, 0, hi - lo), jnp.int32)
        o, state = systolic.systolic_lstm_seq_quantized(
            qp, mesh, xs_q[lo:hi], state=state, valid_len=vl,
            return_state=True)
        outs.append(np.asarray(o))
    hs = np.concatenate(outs)
    for b, L in enumerate(lens):
        np.testing.assert_array_equal(hs[:L, b], ref[:L, b])
        np.testing.assert_array_equal(np.asarray(state[0])[b, :32],
                                      ref[L - 1, b])
    o1, st1 = systolic.systolic_lstm_seq_quantized(qp, mesh, xs_q[:4],
                                                   return_state=True)
    o2 = lstm_layer_seq_quantized(qp, xs_q[4:], state=st1, interpret=True)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(o1), np.asarray(o2)]), ref)
print('OK')
""", n_devices=2)
    assert 'OK' in out


# ------------------------------------------------------------------ dispatch
def test_stack_backend_vmem_admission_on_tpu():
    # a small homogeneous stack fits -> fused
    assert select_stack_backend(64, 128, 3, 128, 8,
                                platform='tpu') == 'pallas_seq_fused'
    # the paper stack's f32 resident set (3 layers x 2 weight families at
    # 512-padded width ~ 25 MB) blows the 12 MB budget -> layerwise seq
    assert select_stack_backend(123, 421, 3, 128, 8,
                                platform='tpu') == 'pallas_seq'
    assert stack_vmem_bytes_estimate(123, 421, 3, 8) > 12 * 1024 * 1024
    # single layer: nothing to pipeline -> per-layer rules
    assert select_stack_backend(64, 128, 1, 128, 8,
                                platform='tpu') == 'pallas_seq'
    # short sequences don't amortise residency -> per-layer rules
    assert select_stack_backend(64, 128, 3, 2, 8,
                                platform='tpu') == 'pallas_step'
    # never auto-picked on CPU (interpret mode is emulation, not speed)
    assert select_stack_backend(64, 128, 3, 128, 8,
                                platform='cpu') == 'xla_scan'


def test_heterogeneous_stack_falls_back_to_layerwise():
    """An hourglass stack (mixed widths) cannot ride the wavefront scratch;
    explicit ``pallas_seq_fused`` degrades to the layerwise ``pallas_seq``
    path with identical results."""
    l0 = lstm.init_lstm_params(jax.random.PRNGKey(0), 12, 32)
    l1 = lstm.init_lstm_params(jax.random.PRNGKey(1), 32, 16)
    p = lstm.LSTMStackParams(layers=(l0, l1), w_out=None, b_out=None)
    assert not stack_fused_compatible(p)
    xs = jax.random.normal(jax.random.PRNGKey(2), (5, 2, 12)) * 0.5
    ys_seq, _ = lstm_stack_apply(p, xs, backend='pallas_seq')
    ys_fused, _ = lstm_stack_apply(p, xs, backend='pallas_seq_fused')
    np.testing.assert_array_equal(np.asarray(ys_fused), np.asarray(ys_seq))


def test_single_layer_fused_degenerates_to_seq_kernel():
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), 16, 32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 2, 16)) * 0.5
    hs_seq, _ = lstm.lstm_layer_fused(p, xs, backend='pallas_seq')
    hs_fused, _ = lstm.lstm_layer_fused(p, xs, backend='pallas_seq_fused')
    np.testing.assert_array_equal(np.asarray(hs_fused), np.asarray(hs_seq))


# ----------------------------------------------------------------- serving
def test_streaming_engine_rides_fused_backend():
    """Ragged streams served by the packed engine on the fused stack
    backend (state carried across ≥3 chunks in the slot cache) reproduce
    the monolithic fused forward."""
    from repro import configs
    from repro.models import chipmunk_net, get_bundle
    from repro.serving import StreamingEngine
    cfg = configs.get_smoke_config('chipmunk-ctc').replace(
        lstm_backend='pallas_seq_fused')
    params, _ = get_bundle(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    lens = [13, 7, 5]                          # 13/4 -> 4 chunks for stream 0
    utts = [rng.randn(L, cfg.lstm_inputs).astype(np.float32) * 0.5
            for L in lens]
    eng = StreamingEngine(cfg, params, max_streams=2, chunk=4)
    sessions = [eng.submit(u) for u in utts]
    eng.run()
    assert len(eng.sched.done) == len(utts)
    for sess, u in zip(sessions, utts):
        lp = chipmunk_net.forward(cfg, params, jnp.asarray(u)[None])
        ref = np.asarray(jnp.moveaxis(lp, 0, 1))[0]
        np.testing.assert_allclose(sess.full_log_probs(), ref,
                                   rtol=1e-5, atol=1e-6)
