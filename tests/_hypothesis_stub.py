"""Minimal deterministic stand-in for ``hypothesis`` (not installed in CI image).

Implements just the surface the test-suite uses — ``given``, ``settings`` and
the ``integers`` / ``floats`` / ``lists`` strategies — drawing a fixed number
of pseudo-random examples from a seeded RNG.  No shrinking, no database; a
failing example reproduces every run because the seed is constant.
"""
from __future__ import annotations

import random
import types


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(min_value, max_value, allow_nan=True, allow_infinity=True, **_kw):
    return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(lambda rnd: [elements.draw(rnd) for _ in
                                  range(rnd.randint(min_size, max_size))])


def booleans():
    return _Strategy(lambda rnd: rnd.random() < 0.5)


def sampled_from(values):
    values = list(values)
    return _Strategy(lambda rnd: values[rnd.randrange(len(values))])


def tuples(*strats):
    return _Strategy(lambda rnd: tuple(s.draw(rnd) for s in strats))


def builds(fn, *strats, **kw_strats):
    return _Strategy(lambda rnd: fn(*[s.draw(rnd) for s in strats],
                                    **{k: s.draw(rnd)
                                       for k, s in kw_strats.items()}))


def given(*strats):
    def deco(fn):
        # NB: no functools.wraps — pytest must see the zero-arg signature of
        # the wrapper, not the strategy parameters of the wrapped test.
        def wrapper():
            rnd = random.Random(0)
            for _ in range(getattr(wrapper, '_max_examples', 10)):
                fn(*[s.draw(rnd) for s in strats])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._max_examples = 10
        return wrapper
    return deco


def settings(max_examples=10, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


strategies = types.ModuleType('hypothesis.strategies')
strategies.integers = integers
strategies.floats = floats
strategies.lists = lists
strategies.booleans = booleans
strategies.sampled_from = sampled_from
strategies.tuples = tuples
strategies.builds = builds
