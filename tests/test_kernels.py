"""Per-kernel shape/dtype sweeps: pallas_call (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import lstm, quant
from repro.kernels.flash_attention import attention_ref, flash_attention, mha
from repro.kernels.lstm_gates import lstm_cell_fused, lstm_gates, lstm_gates_ref
from repro.kernels.quant_matmul import (quant_matmul, quant_matmul_ref,
                                        quantize_weights, quantized_linear)


# ---------------------------------------------------------------- quant_matmul
@pytest.mark.parametrize('m,k,n,bm,bn,bk', [
    (128, 128, 128, 128, 128, 128),
    (256, 384, 128, 128, 128, 128),
    (8, 256, 512, 8, 128, 64),
    (64, 64, 64, 32, 32, 32),
])
def test_quant_matmul_sweep(m, k, n, bm, bn, bk):
    key = jax.random.PRNGKey(m * 7 + n)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    xs = quant.abs_max_scale(x, axis=-1)
    ws = quant.abs_max_scale(w, axis=0)
    xq, wq = quant.quantize_scaled(x, xs), quant.quantize_scaled(w, ws)
    out = quant_matmul(xq, wq, xs, ws, bm=bm, bn=bn, bk=bk, interpret=True)
    ref = quant_matmul_ref(xq, wq, xs, ws)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize('out_dtype', [jnp.float32, jnp.bfloat16])
def test_quant_matmul_dtypes(out_dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
    xs, ws = quant.abs_max_scale(x, -1), quant.abs_max_scale(w, 0)
    xq, wq = quant.quantize_scaled(x, xs), quant.quantize_scaled(w, ws)
    out = quant_matmul(xq, wq, xs, ws, bm=64, bn=64, bk=64,
                       out_dtype=out_dtype, interpret=True)
    assert out.dtype == out_dtype
    ref = quant_matmul_ref(xq, wq, xs, ws, out_dtype)
    np.testing.assert_allclose(out.astype(jnp.float32), ref.astype(jnp.float32),
                               rtol=1e-2, atol=1e-2)


def test_quantized_linear_unaligned_and_batched():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 100))  # ragged M, K
    w = jax.random.normal(jax.random.PRNGKey(1), (100, 75))
    wq, ws = quantize_weights(w)
    out = quantized_linear(x, wq, ws)
    assert out.shape == (3, 5, 75)
    rel = float(jnp.max(jnp.abs(out - x @ w)) / jnp.max(jnp.abs(x @ w)))
    assert rel < 0.03, rel


# ------------------------------------------------------------------ lstm_gates
@pytest.mark.parametrize('n_x,n_h,b,bn,bk', [
    (128, 128, 8, 128, 128),
    (100, 150, 4, 64, 64),
    (96, 421, 2, 128, 128),   # the paper's CTC layer width
    (32, 32, 1, 32, 32),
])
def test_lstm_gates_sweep(n_x, n_h, b, bn, bk):
    p = lstm.init_lstm_params(jax.random.PRNGKey(n_x + n_h), n_x, n_h)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, n_x))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (b, n_h)) * 0.3
    c0 = jax.random.normal(jax.random.PRNGKey(3), (b, n_h)) * 0.3
    h_ref, c_ref = lstm.lstm_cell(p, x, h0, c0)
    h_k, c_k = lstm_cell_fused(p, x, h0, c0, bn=bn, bk=bk)
    np.testing.assert_allclose(h_k, h_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_k, c_ref, rtol=1e-5, atol=1e-6)


def test_lstm_gates_oracle_matches_core():
    """ref.py (packed-weight oracle) must equal the canonical equations."""
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), 11, 13)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 11))
    h0 = jnp.zeros((5, 13))
    c0 = jnp.zeros((5, 13))
    xh = jnp.concatenate([x, h0], -1)
    w = jnp.concatenate([p.w_x, p.w_h], -1)
    h_r, c_r = lstm_gates_ref(xh, w, p.w_peep, p.b, c0)
    h_c, c_c = lstm.lstm_cell(p, x, h0, c0)
    np.testing.assert_allclose(h_r, h_c, rtol=1e-6)
    np.testing.assert_allclose(c_r, c_c, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_lstm_gates_property_random_shapes(seed):
    rng = np.random.RandomState(seed)
    n_x = int(rng.randint(8, 200))
    n_h = int(rng.randint(8, 200))
    b = int(rng.randint(1, 6))
    p = lstm.init_lstm_params(jax.random.PRNGKey(seed), n_x, n_h)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, n_x))
    h0 = jnp.zeros((b, n_h))
    c0 = jnp.zeros((b, n_h))
    h_ref, c_ref = lstm.lstm_cell(p, x, h0, c0)
    h_k, c_k = lstm_cell_fused(p, x, h0, c0, bn=64, bk=64)
    np.testing.assert_allclose(h_k, h_ref, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------- flash_attention
@pytest.mark.parametrize('causal,window', [(True, None), (False, None),
                                           (True, 16), (True, 64)])
@pytest.mark.parametrize('sq,sk', [(64, 64), (128, 128), (1, 128), (80, 80)])
def test_flash_attention_sweep(causal, window, sq, sk):
    if sq > sk:
        pytest.skip('query longer than keys undefined here')
    B, H, Hk, D = 2, 4, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, sq, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Hk, sk, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Hk, sk, D))
    out = mha(q, k, v, causal=causal, window=window, bq=32, bk=32)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize('dtype', [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    B, H, S, D = 1, 2, 64, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D), dtype)
    out = mha(q, k, v, bq=32, bk=32)
    assert out.dtype == dtype
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32))
    tol = 1e-3 if dtype == jnp.float32 else 2e-2  # bf16: taxonomy Part E
    np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=tol, atol=tol)


def test_flash_attention_fully_masked_rows_are_zero():
    """Sliding window so small that early KV blocks are fully masked."""
    B, H, S, D = 1, 1, 128, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))
    out = mha(q, k, v, causal=True, window=8, bq=32, bk=32)
    ref = attention_ref(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
    assert np.isfinite(np.asarray(out)).all()


def test_flash_attention_decode_offset():
    """Decode: 1 query against a 96-entry cache, absolute position = 95."""
    B, H, D = 2, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, 96, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, 96, D))
    out = mha(q, k, v, causal=True, bq=8, bk=32)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
