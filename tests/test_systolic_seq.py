"""Multi-engine systolic scale-out of the persistent LSTM sequence kernel.

The float path must be allclose to scanning ``systolic_cell_tiled`` (and to
``core.lstm.lstm_layer``); the int8 path must be *bit-identical* to
``systolic_layer_quantized`` (the silicon datapath) — on real multi-device
meshes.  The STAGED scale-out (DESIGN.md §9, backend
``pallas_seq_fused_systolic``) additionally pins contiguous layer blocks to
a live ``stage`` axis and must match the layerwise composition (f32
allclose + grads) and the single-engine fused stack (int8 bit-identical,
including the chunked code carry).  Multi-device cases run in subprocesses
with a forced host platform device count (see tests/_subproc.py); 2 devices
keeps them safe on the 2-core CI boxes (the cpu_count skip-gate only
applies to the 256-chip LM compile, not to these small meshes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_with_devices
from repro.core import lstm, quant, systolic
from repro.kernels.lstm_seq import lstm_layer_seq, lstm_layer_seq_quantized


# ----------------------------------------------------------- 2-device meshes
def test_scaleout_float_matches_tiled_and_dense_2dev():
    """systolic_lstm_seq == scanned systolic_cell_tiled == lstm_layer on both
    2-device orientations (row scale-out and col scale-out)."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, systolic
p = lstm.init_lstm_params(jax.random.PRNGKey(0), 23, 37)
xs = jax.random.normal(jax.random.PRNGKey(1), (7, 3, 23)) * 0.5
hs_ref, (hT_ref, cT_ref) = lstm.lstm_layer(p, xs)
hs_tiled = systolic.systolic_layer_tiled(
    systolic.pack_lstm(p, systolic.SystolicPlan(23, 37, 16)), xs)
np.testing.assert_allclose(hs_tiled, hs_ref, rtol=1e-5, atol=1e-6)
for rows, cols in ((2, 1), (1, 2)):
    mesh = systolic.make_systolic_mesh(rows, cols)
    hs, (h_T, c_T) = systolic.systolic_lstm_seq(p, mesh, xs)
    np.testing.assert_allclose(hs, hs_tiled, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(hs, hs_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h_T, hT_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_T, cT_ref, rtol=1e-5, atol=1e-6)
print('OK')
""", n_devices=2)
    assert 'OK' in out


def test_scaleout_nonzero_state_and_paper_width_2dev():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, systolic
p = lstm.init_lstm_params(jax.random.PRNGKey(0), 123, 421)
xs = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 123)) * 0.5
h0 = jax.random.normal(jax.random.PRNGKey(2), (2, 421)) * 0.3
c0 = jax.random.normal(jax.random.PRNGKey(3), (2, 421)) * 0.3
hs_ref, (hT_ref, cT_ref) = lstm.lstm_layer(p, xs, h0, c0)
mesh = systolic.make_systolic_mesh(1, 2)
hs, (h_T, c_T) = systolic.systolic_lstm_seq(p, mesh, xs, h0, c0)
np.testing.assert_allclose(hs, hs_ref, rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(c_T, cT_ref, rtol=1e-5, atol=1e-6)
print('OK')
""", n_devices=2)
    assert 'OK' in out


def test_scaleout_grad_matches_scan_vjp_2dev():
    """The scale-out custom VJP (gate recompute) == the hand-written scan VJP
    — training must work when auto-selection picks the distributed backend."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, systolic
p = lstm.init_lstm_params(jax.random.PRNGKey(9), 24, 32)
xs = jax.random.normal(jax.random.PRNGKey(1), (5, 2, 24)) * 0.5
mesh = systolic.make_systolic_mesh(2, 1)
def loss(q):
    hs, (hT, cT) = systolic.systolic_lstm_seq(q, mesh, xs)
    return jnp.sum(hs ** 2) + jnp.sum(hT * cT)
def loss_ref(q):
    hs, (hT, cT) = lstm.lstm_layer_fused(q, xs, backend='xla_scan')
    return jnp.sum(hs ** 2) + jnp.sum(hT * cT)
g = jax.grad(loss)(p)
g_ref = jax.grad(loss_ref)(p)
for name, a, b in zip(p._fields, g_ref, g):
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5, err_msg=name)
print('OK')
""", n_devices=2)
    assert 'OK' in out


def test_scaleout_quantized_bit_identical_2dev():
    """int8 scale-out == systolic_layer_quantized bit for bit: the gathered
    hop replay must reproduce the chip's saturation order exactly."""
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.core import lstm, quant, systolic
p = lstm.init_lstm_params(jax.random.PRNGKey(5), 48, 64)
xs = jax.random.normal(jax.random.PRNGKey(6), (6, 3, 48)) * 0.5
qp = systolic.quantize_packed(
    systolic.pack_lstm(p, systolic.SystolicPlan(48, 64, 16)))
xs_q = quant.quantize(xs, quant.STATE_FMT)
hs_ref = systolic.systolic_layer_quantized(qp, xs_q)
for rows, cols in ((2, 1), (1, 2)):
    mesh = systolic.make_systolic_mesh(rows, cols)
    hs = systolic.systolic_lstm_seq_quantized(qp, mesh, xs_q)
    assert hs.dtype == jnp.int8
    assert bool(jnp.all(hs == hs_ref)), (rows, cols)
# an engine grid that does not divide the mesh is rejected (R=3 over 2 rows)
qp3 = systolic.quantize_packed(
    systolic.pack_lstm(lstm.init_lstm_params(jax.random.PRNGKey(7), 16, 48),
                       systolic.SystolicPlan(16, 48, 16)))
try:
    systolic.systolic_lstm_seq_quantized(
        qp3, systolic.make_systolic_mesh(2, 1), jnp.zeros((3, 2, 16), jnp.int8))
    raise SystemExit('expected ValueError')
except ValueError:
    pass
print('OK')
""", n_devices=2)
    assert 'OK' in out


def test_scaleout_auto_dispatch_2dev():
    """Installing a topology makes ``auto`` pick the scale-out backend and the
    full dispatch path (lstm_layer_fused) stays allclose to the scan."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, systolic
from repro.launch.mesh import install_systolic_topology
mesh = install_systolic_topology('1x2')
assert systolic.current_mesh() is mesh
assert systolic.seq_scaleout_admissible(421, mesh)
# a per-device block that cannot fit the budget is rejected
assert not systolic.seq_scaleout_admissible(1 << 14, mesh, vmem_budget=1 << 20)
assert lstm.select_lstm_backend(23, 37, 16, 3) == 'pallas_seq_systolic'
p = lstm.init_lstm_params(jax.random.PRNGKey(0), 23, 37)
xs = jax.random.normal(jax.random.PRNGKey(1), (16, 3, 23)) * 0.5
hs, _ = lstm.lstm_layer_fused(p, xs, backend='auto')
hs_ref, _ = lstm.lstm_layer_fused(p, xs, backend='xla_scan')
np.testing.assert_allclose(hs, hs_ref, rtol=1e-5, atol=1e-6)
systolic.clear_mesh()
assert lstm.select_lstm_backend(23, 37, 16, 3, platform='cpu') == 'xla_scan'
# a live non-systolic mesh is rejected, not silently misplaced
from repro.compat import make_mesh
dm = make_mesh((1, 2), ('data', 'model'))
assert not systolic.seq_scaleout_admissible(37, dm)
try:
    systolic.systolic_lstm_seq(p, dm, xs)
    raise SystemExit('expected ValueError')
except ValueError:
    pass
print('OK')
""", n_devices=2)
    assert 'OK' in out


# ----------------------------------------------- single-device degenerations
def test_scaleout_none_mesh_delegates_to_seq_kernel():
    """mesh=None (and all-1 meshes) degenerate to the PR-1 persistent kernel
    — the composition the scale-out generalises."""
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), 24, 32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 2, 24)) * 0.5
    hs_ref, (hT_ref, cT_ref) = lstm.lstm_layer(p, xs)
    hs, (h_T, c_T) = systolic.systolic_lstm_seq(p, None, xs)
    np.testing.assert_allclose(hs, hs_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h_T, hT_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_T, cT_ref, rtol=1e-5, atol=1e-6)


def test_scaleout_quantized_none_mesh_delegates():
    """mesh=None degenerates to the whole-sequence int8 kernel (bit-identical
    to the reference scan by the kernel's own contract)."""
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), 16, 48)
    qp = systolic.quantize_packed(
        systolic.pack_lstm(p, systolic.SystolicPlan(16, 48, 16)))
    xs = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 16)) * 0.5
    xs_q = quant.quantize(xs, quant.STATE_FMT)
    hs_ref = systolic.systolic_layer_quantized(qp, xs_q)
    hs = systolic.systolic_lstm_seq_quantized(qp, None, xs_q)
    assert hs.dtype == jnp.int8
    assert bool(jnp.all(hs == hs_ref))


def test_admission_rules():
    assert not systolic.seq_scaleout_admissible(421, None)
    # all-1 meshes are degenerate: the single-engine §3.3 platform/shape
    # rules keep deciding (never auto-pick interpret emulation on CPU)
    assert not systolic.seq_scaleout_admissible(
        421, systolic.make_systolic_mesh(1, 1))
    # axis names must match
    from repro.launch.train import local_mesh
    assert not systolic.seq_scaleout_admissible(421, local_mesh())
    # positive + VMEM-budget cases run on a real 2-device mesh in
    # test_scaleout_auto_dispatch_2dev (admissibility needs a live axis)


# ------------------------------------------------------- batched grid (bb)
def test_seq_kernel_batch_grid_matches_core():
    """bb < B: batch blocks iterate outermost over the resident weights."""
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), 32, 48)
    xs = jax.random.normal(jax.random.PRNGKey(1), (4, 20, 32)) * 0.5
    hs_ref, (hT_ref, cT_ref) = lstm.lstm_layer(p, xs)
    hs, (h_T, c_T) = lstm_layer_seq(p, xs, bn=64, bk=64, bb=8, interpret=True)
    np.testing.assert_allclose(hs, hs_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h_T, hT_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_T, cT_ref, rtol=1e-5, atol=1e-6)


def test_seq_kernel_batch_grid_quantized_bit_identical():
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), 32, 48)
    xs = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 32)) * 0.5
    qp = systolic.quantize_packed(
        systolic.pack_lstm(p, systolic.SystolicPlan(32, 48, 16)))
    xs_q = quant.quantize(xs, quant.STATE_FMT)
    hs_ref = systolic.systolic_layer_quantized(qp, xs_q)
    hs = lstm_layer_seq_quantized(qp, xs_q, bb=4, interpret=True)  # pads B->8
    assert hs.dtype == jnp.int8
    assert bool(jnp.all(hs == hs_ref))


# ------------------------------------------ staged fused-systolic (DESIGN §9)
def test_staged_stack_matches_layerwise_and_grads_2dev():
    """The staged scale-out on a live ('stage','row','col') mesh (2 stages,
    uneven 2+1 layer blocks) == the layerwise composition, forward, finals
    AND gradients (the cross-layer gate-recompute VJP composed across the
    stage boundary)."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, systolic
p = lstm.init_lstm_stack(jax.random.PRNGKey(0), 16, 24, 3)
xs = jax.random.normal(jax.random.PRNGKey(1), (7, 2, 16)) * 0.5
mesh = systolic.make_systolic_mesh(1, 1, stage=2)
assert systolic.stage_layer_blocks(3, 2) == ((0, 2), (2, 3))
ys_ref, fin_ref = lstm.lstm_stack_apply(p, xs, backend='xla_scan')
ys, fin = systolic.systolic_lstm_stack_seq(p, mesh, xs, chunk=2)
np.testing.assert_allclose(ys, ys_ref, rtol=1e-5, atol=1e-6)
for l in range(3):
    np.testing.assert_allclose(fin[l][0], fin_ref[l][0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fin[l][1], fin_ref[l][1], rtol=1e-5, atol=1e-6)
def loss(q, staged):
    ys, fin = (systolic.systolic_lstm_stack_seq(q, mesh, xs, chunk=2)
               if staged else lstm.lstm_stack_apply(q, xs, backend='xla_scan'))
    return jnp.sum(ys ** 2) + sum(jnp.sum(h * c) for h, c in fin)
g = jax.grad(lambda q: loss(q, True))(p)
g_ref = jax.grad(lambda q: loss(q, False))(p)
for a, b in zip(jax.tree_util.tree_flatten(g_ref)[0],
                jax.tree_util.tree_flatten(g)[0]):
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
# a live stage axis with an intra-stage col axis (2 devices as (2,1,1) only;
# the col orientation runs in the scale-out bench on 4 devices)
print('OK')
""", n_devices=2)
    assert 'OK' in out


def test_staged_quantized_bit_identical_and_chunk_carry_2dev():
    """int8 staged path == the silicon reference chain AND the single-engine
    fused stack, bit for bit — including ≥3 ragged masked chunks with the
    opaque per-layer (h_q, c_q) carry and a mid-sequence handoff of the
    staged state INTO the single-engine fused stack (cross-engine state
    handoff for the streaming engine)."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, quant, systolic
from repro.kernels.lstm_seq import lstm_stack_seq_quantized
n_x, n_h, tile, L = 24, 32, 16, 3
st = lstm.init_lstm_stack(jax.random.PRNGKey(5), n_x, n_h, L)
qps = []
for l, lp in enumerate(st.layers):
    plan = systolic.SystolicPlan(n_x if l == 0 else n_h, n_h, tile)
    qps.append(systolic.quantize_packed(systolic.pack_lstm(lp, plan)))
xs = jax.random.normal(jax.random.PRNGKey(6), (6, 2, n_x)) * 0.5
xs_q = quant.quantize(xs, quant.STATE_FMT)
h = xs_q
for qp in qps:
    h = systolic.systolic_layer_quantized(qp, h)
ref = np.asarray(h)
mesh = systolic.make_systolic_mesh(1, 1, stage=2)
out = systolic.systolic_lstm_stack_seq_quantized(qps, mesh, xs_q, chunk=2)
assert out.dtype == jnp.int8
np.testing.assert_array_equal(np.asarray(out), ref)
# == the single-engine fused stack on the same inputs (bit-identical)
fused = lstm_stack_seq_quantized(qps, xs_q, interpret=True)
np.testing.assert_array_equal(np.asarray(out), np.asarray(fused))
# >=3 ragged masked chunks with the opaque per-layer code carry
lens = np.array([6, 3])
stt = None; outs = []
for lo, hi in ((0, 2), (2, 4), (4, 6)):
    vl = jnp.asarray(np.clip(lens - lo, 0, hi - lo), jnp.int32)
    o, stt = systolic.systolic_lstm_stack_seq_quantized(
        qps, mesh, xs_q[lo:hi], state=stt, valid_len=vl, return_state=True,
        chunk=1)
    outs.append(np.asarray(o))
hs = np.concatenate(outs)
for b, Lv in enumerate(lens):
    np.testing.assert_array_equal(hs[:Lv, b], ref[:Lv, b])
    np.testing.assert_array_equal(np.asarray(stt[0])[-1, b, :n_h],
                                  ref[Lv - 1, b])
# cross-engine handoff: staged state -> single-engine fused stack
o1, st1 = systolic.systolic_lstm_stack_seq_quantized(
    qps, mesh, xs_q[:3], return_state=True, chunk=1)
o2 = lstm_stack_seq_quantized(qps, xs_q[3:], state=st1, interpret=True)
np.testing.assert_array_equal(
    np.concatenate([np.asarray(o1), np.asarray(o2)]), ref)
print('OK')
""", n_devices=2)
    assert 'OK' in out


def test_staged_auto_dispatch_and_f32_chunk_carry_2dev():
    """Installing a stage>1 topology makes stack-level ``auto`` resolve to
    the staged backend (stage-aware admission), the full dispatch path
    stays allclose to the scan, and f32 chunked serving with per-layer
    carried state is bit-equal to the monolithic staged call."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, systolic
mesh = systolic.install_mesh(systolic.make_systolic_mesh(1, 1, stage=2))
assert systolic.seq_scaleout_admissible(24, mesh, n_layers=3)
assert not systolic.seq_scaleout_admissible(24, mesh)       # per-layer form
assert not systolic.seq_scaleout_admissible(24, mesh, n_layers=1)  # S > L
assert not systolic.seq_scaleout_admissible(          # VMEM budget rejection
    1 << 13, mesh, n_layers=3, vmem_budget=1 << 20)
assert lstm.select_stack_backend(16, 24, 3, 16, 2) == 'pallas_seq_fused_systolic'
assert lstm.select_stack_backend(16, 24, 3, 2, 2) != 'pallas_seq_fused_systolic'
assert lstm.select_lstm_backend(16, 24, 16, 2, platform='cpu') == 'xla_scan'
p = lstm.init_lstm_stack(jax.random.PRNGKey(0), 16, 24, 3)
xs = jax.random.normal(jax.random.PRNGKey(3), (16, 2, 16)) * 0.5
ys_a, _ = lstm.lstm_stack_apply(p, xs, backend='auto')
ys_x, _ = lstm.lstm_stack_apply(p, xs, backend='xla_scan')
np.testing.assert_allclose(ys_a, ys_x, rtol=1e-5, atol=1e-6)
lens = np.array([9, 5])
mono, mono_fin = lstm.lstm_stack_chunk(
    p, xs[:9], None, valid_len=jnp.asarray(lens),
    backend='pallas_seq_fused_systolic')
stt = None; outs = []
for lo, hi in ((0, 3), (3, 6), (6, 9)):
    vl = jnp.asarray(np.clip(lens - lo, 0, hi - lo), jnp.int32)
    o, stt = lstm.lstm_stack_chunk(p, xs[lo:hi], stt, valid_len=vl,
                                   backend='pallas_seq_fused_systolic')
    outs.append(np.asarray(o))
np.testing.assert_array_equal(np.concatenate(outs), np.asarray(mono))
for l in range(3):
    np.testing.assert_array_equal(np.asarray(stt[l][0]),
                                  np.asarray(mono_fin[l][0]))
systolic.clear_mesh()
print('OK')
""", n_devices=2)
    assert 'OK' in out


def test_staged_none_mesh_degenerates_to_fused_stack():
    """mesh=None (and all-1 meshes) degenerate to the single-engine §8
    fused stack — the composition the staged scale-out pipelines."""
    p = lstm.init_lstm_stack(jax.random.PRNGKey(0), 16, 24, 2)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 2, 16)) * 0.5
    ys_ref, _ = lstm.lstm_stack_apply(p, xs, backend='pallas_seq_fused')
    ys, _ = systolic.systolic_lstm_stack_seq(p, None, xs)
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(ys_ref))


def test_staged_admission_and_blocks():
    assert systolic.stage_layer_blocks(3, 3) == ((0, 1), (1, 2), (2, 3))
    assert systolic.stage_layer_blocks(3, 2) == ((0, 2), (2, 3))
    # stages beyond the stack get empty passthrough blocks
    assert systolic.stage_layer_blocks(2, 3) == ((0, 1), (1, 2), (2, 2))
    # stage-aware admission needs a real mesh with the three axes
    assert not systolic.seq_scaleout_admissible(421, None, n_layers=3)
    from repro.launch.train import local_mesh
    assert not systolic.seq_scaleout_admissible(421, local_mesh(), n_layers=3)
    # a stage-1 mesh belongs to the layerwise §6 rule, never the staged one
    assert not systolic.seq_scaleout_admissible(
        421, systolic.make_systolic_mesh(1, 1), n_layers=3)


# ----------------------------------------------------------- topology presets
def test_topology_presets_geometry():
    from repro.launch.mesh import SYSTOLIC_TOPOLOGIES
    # graves-75: the 75-tile 3x(5x5) real-time phoneme configuration
    assert SYSTOLIC_TOPOLOGIES['graves-75'] == (3, 5, 5)
    stage, rows, cols = SYSTOLIC_TOPOLOGIES['graves-75']
    assert stage * rows * cols == 75
    # the CTC layer plan at tile=96 matches the '5x7' preset
    plan = systolic.SystolicPlan(123, 421, 96)
    assert SYSTOLIC_TOPOLOGIES['5x7'] == (1, plan.rows, plan.cols)
    # every stage-1 preset is admissible for the paper layer once built
    for name, (stage, rows, cols) in SYSTOLIC_TOPOLOGIES.items():
        assert stage >= 1 and rows >= 1 and cols >= 1


# ------------------------------------------- in-stage schedule equivalence
def test_staged_in_stage_modes_bit_equal_f32_2dev():
    """Both in-stage round orders (diagonal-batched wavefront vs the
    layer-sequential hoisted form) are BITWISE-equal schedules of the same
    arithmetic: forward outputs, per-layer finals, and grads through the
    gate-recompute VJP, at several chunk sizes including a ragged one."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, systolic
assert systolic.IN_STAGE_MODES == ('batched', 'sequential')
p = lstm.init_lstm_stack(jax.random.PRNGKey(0), 16, 24, 3)
xs = jax.random.normal(jax.random.PRNGKey(1), (9, 2, 16)) * 0.5
mesh = systolic.make_systolic_mesh(1, 1, stage=2)
for chunk in (1, 2, 4, 9):           # 9/2 and 9/4 exercise ragged tails
    ys_b, fin_b = systolic.systolic_lstm_stack_seq(
        p, mesh, xs, chunk=chunk, in_stage='batched')
    ys_s, fin_s = systolic.systolic_lstm_stack_seq(
        p, mesh, xs, chunk=chunk, in_stage='sequential')
    np.testing.assert_array_equal(np.asarray(ys_b), np.asarray(ys_s))
    for l in range(3):
        np.testing.assert_array_equal(np.asarray(fin_b[l][0]),
                                      np.asarray(fin_s[l][0]))
        np.testing.assert_array_equal(np.asarray(fin_b[l][1]),
                                      np.asarray(fin_s[l][1]))
def loss(q, mode):
    ys, fin = systolic.systolic_lstm_stack_seq(q, mesh, xs, chunk=2,
                                               in_stage=mode)
    return jnp.sum(ys ** 2) + sum(jnp.sum(h * c) for h, c in fin)
g_b = jax.grad(lambda q: loss(q, 'batched'))(p)
g_s = jax.grad(lambda q: loss(q, 'sequential'))(p)
for a, b in zip(jax.tree_util.tree_flatten(g_b)[0],
                jax.tree_util.tree_flatten(g_s)[0]):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print('OK')
""", n_devices=2)
    assert 'OK' in out


def test_staged_in_stage_modes_bit_identical_int8_2dev():
    """int8: both in-stage orders == the silicon reference chain bit for
    bit, AND a >=3-ragged-chunk masked carry stream under EACH mode equals
    the other mode's stream exactly (the serving engine may flip modes
    between deployments without perturbing a single code)."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, quant, systolic
n_x, n_h, tile, L = 24, 32, 16, 3
st = lstm.init_lstm_stack(jax.random.PRNGKey(5), n_x, n_h, L)
qps = []
for l, lp in enumerate(st.layers):
    plan = systolic.SystolicPlan(n_x if l == 0 else n_h, n_h, tile)
    qps.append(systolic.quantize_packed(systolic.pack_lstm(lp, plan)))
xs = jax.random.normal(jax.random.PRNGKey(6), (6, 2, n_x)) * 0.5
xs_q = quant.quantize(xs, quant.STATE_FMT)
h = xs_q
for qp in qps:
    h = systolic.systolic_layer_quantized(qp, h)
ref = np.asarray(h)
mesh = systolic.make_systolic_mesh(1, 1, stage=2)
for mode in systolic.IN_STAGE_MODES:
    o = systolic.systolic_lstm_stack_seq_quantized(qps, mesh, xs_q, chunk=2,
                                                   in_stage=mode)
    np.testing.assert_array_equal(np.asarray(o), ref)
lens = np.array([6, 3])
streams = {}
for mode in systolic.IN_STAGE_MODES:
    stt = None; outs = []
    for lo, hi in ((0, 2), (2, 4), (4, 6)):
        vl = jnp.asarray(np.clip(lens - lo, 0, hi - lo), jnp.int32)
        o, stt = systolic.systolic_lstm_stack_seq_quantized(
            qps, mesh, xs_q[lo:hi], state=stt, valid_len=vl,
            return_state=True, chunk=1, in_stage=mode)
        outs.append(np.asarray(o))
    streams[mode] = (np.concatenate(outs), np.asarray(stt[0]))
np.testing.assert_array_equal(streams['batched'][0],
                              streams['sequential'][0])
np.testing.assert_array_equal(streams['batched'][1],
                              streams['sequential'][1])
for b, Lv in enumerate(lens):
    np.testing.assert_array_equal(streams['batched'][0][:Lv, b], ref[:Lv, b])
print('OK')
""", n_devices=2)
    assert 'OK' in out


def test_staged_in_stage_modes_graves75_scaled_2dev():
    """A scaled-down graves-75 shape (3 stages, live row+col sharding is
    covered by the scale-out bench; here 6 devices as (3,2,1)): 5 layers
    over 3 stages gives uneven (2,2,1) blocks — the wavefront diagonals hit
    both a 2-layer block (real batching) and a 1-layer block (degenerate),
    and both orders stay bit-equal."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, systolic
p = lstm.init_lstm_stack(jax.random.PRNGKey(7), 16, 24, 5)
xs = jax.random.normal(jax.random.PRNGKey(8), (8, 2, 16)) * 0.5
mesh = systolic.make_systolic_mesh(2, 1, stage=3)
assert systolic.stage_layer_blocks(5, 3) == ((0, 2), (2, 4), (4, 5))
ys_ref, _ = lstm.lstm_stack_apply(p, xs, backend='xla_scan')
ys_b, _ = systolic.systolic_lstm_stack_seq(p, mesh, xs, chunk=2,
                                           in_stage='batched')
ys_s, _ = systolic.systolic_lstm_stack_seq(p, mesh, xs, chunk=2,
                                           in_stage='sequential')
np.testing.assert_array_equal(np.asarray(ys_b), np.asarray(ys_s))
np.testing.assert_allclose(ys_b, ys_ref, rtol=1e-5, atol=1e-6)
print('OK')
""", n_devices=6)
    assert 'OK' in out
