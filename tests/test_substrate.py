"""Substrate tests: optimizers, data pipeline, checkpointing, fault tolerance,
gradient compression, sharding rules."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs, sharding as shd
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticCTC, SyntheticLM, source_for
from repro.optim import (adafactor, adamw, apply_updates, clip_by_global_norm,
                         compress_with_feedback, cosine_schedule,
                         decompress_tensor, init_error_state, global_norm,
                         make_optimizer, optimizer_state_axes, sgd,
                         wsd_schedule)
from repro.runtime import FaultConfig, FaultTolerantRunner, StepTimer


# ------------------------------------------------------------- optimizers
def _quadratic_params():
    return {'w': jnp.array([3.0, -2.0]), 'b': jnp.array([[1.0, 1.0], [1.0, 1.0]])}


@pytest.mark.parametrize('name', ['adamw', 'adafactor', 'sgd'])
def test_optimizer_converges_on_quadratic(name):
    opt = make_optimizer(name, lambda step: 0.1)
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p['w'] ** 2) + jnp.sum(p['b'] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        updates, state = opt.update(g, state, params)
        params = apply_updates(params, updates)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    opt = adafactor(lambda s: 1e-2)
    params = {'w': jnp.zeros((64, 32)), 'v': jnp.zeros((7,))}
    state = opt.init(params)
    assert state.vr['w'].shape == (64,)      # row stats
    assert state.vc['w'].shape == (32,)      # col stats
    assert state.vr['v'].shape == (7,)       # unfactored vector
    # memory: factored state is O(n+m), not O(n*m)
    assert state.vr['w'].size + state.vc['w'].size < params['w'].size


def test_grad_clip():
    tree = {'a': jnp.ones((4,)) * 3.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(6.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    cos = cosine_schedule(1.0, warmup=10, total=100)
    assert float(cos(5)) == pytest.approx(0.5)
    assert float(cos(10)) == pytest.approx(1.0)
    assert float(cos(100)) == pytest.approx(0.1, rel=1e-2)
    wsd = wsd_schedule(1.0, warmup=10, total=100, decay_frac=0.2)
    assert float(wsd(50)) == pytest.approx(1.0)      # stable plateau
    assert float(wsd(99)) < 0.05                     # sharp decay tail


def test_optimizer_state_axes_match_structure():
    opt = adamw(lambda s: 1e-3)
    params = {'w': jnp.zeros((8, 4))}
    axes = {'w': ('embed', 'mlp')}
    st_ = opt.init(params)
    ax = optimizer_state_axes('adamw', axes)
    assert jax.tree.structure(st_, is_leaf=lambda x: isinstance(x, jnp.ndarray)) \
        .num_leaves == len(jax.tree.leaves(ax, is_leaf=shd._is_axes_leaf))


# ------------------------------------------------- gradient compression
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_compression_error_feedback_reduces_bias(seed):
    """With error feedback, the *accumulated* compressed sum tracks the true
    sum far better than independent rounding (the 1-bit-Adam property)."""
    rng = np.random.RandomState(seed)
    g_true = jnp.asarray(rng.randn(64).astype(np.float32)) * 0.01
    err = init_error_state({'g': g_true})['g']
    acc_c, acc_t = np.zeros(64), np.zeros(64)
    for _ in range(30):
        (q, s, err2) = compress_with_feedback({'g': g_true}, {'g': err})
        err = err2['g']
        acc_c += np.asarray(decompress_tensor(q['g'], s['g']))
        acc_t += np.asarray(g_true)
    # residual bounded by one quantum, independent of number of steps
    quantum = float(np.abs(np.asarray(g_true)).max()) / 127 * 1.5 + 1e-12
    assert np.abs(acc_c - acc_t).max() < quantum * 2


# -------------------------------------------------------------- pipeline
def test_pipeline_deterministic_resume():
    cfg = configs.get_smoke_config('qwen3-14b')
    shape = configs.ShapeConfig('t', 'train', 16, 4)
    src = SyntheticLM(cfg, shape, seed=7)
    a = src.host_batch(5, 0, 4)
    b = src.host_batch(5, 0, 4)          # same step -> identical batch
    np.testing.assert_array_equal(a['tokens'], b['tokens'])
    c = src.host_batch(6, 0, 4)
    assert not np.array_equal(a['tokens'], c['tokens'])


def test_pipeline_host_sharding_partitions_batch():
    cfg = configs.get_smoke_config('qwen3-14b')
    shape = configs.ShapeConfig('t', 'train', 16, 8)
    src = SyntheticLM(cfg, shape, seed=0)
    full = src.host_batch(0, 0, 8)
    lo = src.host_batch(0, 0, 4)
    hi = src.host_batch(0, 4, 8)
    np.testing.assert_array_equal(full['tokens'][:4], lo['tokens'])
    np.testing.assert_array_equal(full['tokens'][4:], hi['tokens'])


def test_ctc_source_valid():
    cfg = configs.get_smoke_config('chipmunk-ctc')
    shape = configs.ShapeConfig('t', 'train', 32, 4)
    b = SyntheticCTC(cfg, shape).host_batch(0, 0, 4)
    assert b['frames'].shape == (4, 32, cfg.lstm_inputs)
    assert (b['labels'] >= 1).all() and (b['labels'] < cfg.n_outputs).all()
    assert (b['label_len'] * 2 <= b['frame_len']).all()   # CTC-feasible


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    state = {'w': jnp.arange(12.0).reshape(3, 4), 'step': jnp.int32(7),
             'nested': {'b': jnp.ones((2,))}}
    for s in (1, 2, 3):
        m.save(s, state, blocking=True)
    assert m.all_steps() == [2, 3]                 # gc keeps last 2
    got = m.restore(jax.tree.map(jnp.zeros_like, state))
    np.testing.assert_array_equal(got['w'], state['w'])
    assert int(got['step']) == 7


def test_checkpoint_async_and_validation(tmp_path):
    m = CheckpointManager(tmp_path)
    state = {'w': jnp.ones((128, 128))}
    m.save(10, state, blocking=False)
    m.wait()
    # corrupt a leaf -> restore must fail checksum
    d = pathlib.Path(tmp_path) / 'step_00000010'
    leaf = next(d.glob('leaf_*.npy'))
    arr = np.load(leaf)
    arr[0, 0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError):
        m.restore(state)


def test_checkpoint_elastic_reshard(tmp_path):
    """Save under one sharding, restore under another (topology change)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.train import local_mesh
    mesh = local_mesh()
    m = CheckpointManager(tmp_path)
    x = jnp.arange(64.0).reshape(8, 8)
    m.save(1, {'x': jax.device_put(x, NamedSharding(mesh, P('data')))},
           blocking=True)
    out = m.restore({'x': jnp.zeros((8, 8))},
                    shardings={'x': NamedSharding(mesh, P(None, 'data'))})
    np.testing.assert_array_equal(out['x'], x)
    assert out['x'].sharding.spec == P(None, 'data')


# ------------------------------------------------------- fault tolerance
def test_fault_runner_retries_and_restores():
    calls = {'n': 0}

    def step(state, batch):
        calls['n'] += 1
        return state + 1, {'loss': 0.0}

    restored = {'n': 0}

    def restore():
        restored['n'] += 1
        return jnp.int32(100)

    runner = FaultTolerantRunner(
        step, cfg=FaultConfig(max_retries=2, backoff_s=0.0),
        restore_fn=restore,
        fail_schedule=lambda s: s == 3)
    state = jnp.int32(0)
    for s in range(5):
        state, _ = runner.run_step(s, state, None)
    assert restored['n'] == 1                          # one injected fault
    kinds = [e['kind'] for e in runner.events]
    assert 'fault' in kinds and 'restore' in kinds
    assert int(state) >= 100                           # resumed from restore


def test_straggler_detection():
    t = StepTimer(alpha=0.5, factor=2.0)
    assert not t.observe(0, 1.0)
    assert not t.observe(1, 1.1)
    assert t.observe(2, 5.0)                           # 5x slower
    assert len(t.stragglers) == 1
    assert not t.observe(3, 1.0)                       # baseline unpoisoned


def test_fault_runner_raises_after_max_retries():
    def step(state, batch):
        raise RuntimeError('permafail')

    runner = FaultTolerantRunner(step,
                                 cfg=FaultConfig(max_retries=1, backoff_s=0.0))
    with pytest.raises(RuntimeError):
        runner.run_step(0, None, None)


# ---------------------------------------------------------------- sharding
def test_sharding_divisibility_fallback():
    """40 heads don't divide a 16-way axis -> head_dim takes the TP axis."""
    from repro.launch.mesh import make_production_mesh, resolve_rules
    out = __import__('subprocess')  # noqa — only to document intent; real
    # multi-device check below runs in-process against an abstract mesh:
    rules = shd.ShardingRules(None, shd.TRAIN_RULES)
    # mesh=None path returns specs without divisibility info
    spec = rules.spec(('embed', 'heads', 'head_dim'))
    assert spec is not None


def test_sharding_spec_dedup_and_fallback_multidevice():
    from _subproc import run_with_devices
    out = run_with_devices("""
import jax
from repro import sharding as shd
from repro.launch.mesh import make_production_mesh, resolve_rules
mesh = make_production_mesh(multi_pod=True)
rules = shd.ShardingRules(mesh, resolve_rules(shd.TRAIN_RULES, mesh))
# 40 q-heads don't divide 16 -> falls back to head_dim
s = rules.spec(('embed','heads','head_dim'), (5120, 40, 128))
assert s == jax.sharding.PartitionSpec(('pod','data'), None, 'model'), s
# divisible head count claims model; head_dim then stays unsharded
s = rules.spec(('embed','heads','head_dim'), (7168, 64, 112))
assert s == jax.sharding.PartitionSpec(('pod','data'), 'model', None), s
# 8 experts on a 32-way EP axis -> prefix (pod=2) only; embed picks data
s = rules.spec(('experts','embed','expert_mlp'), (8, 6144, 16384))
assert s == jax.sharding.PartitionSpec('pod', 'data', 'model'), s
# batch=1 (long_500k): nothing divides -> replicated
s = rules.spec(('batch','seq'), (1, 524288))
assert s == jax.sharding.PartitionSpec(None, None), s
print('OK')
""", n_devices=512)
    assert 'OK' in out


# ------------------------------------------- PR 6: fault/checkpoint hardening
def test_fault_runner_cfg_default_not_shared():
    """Each runner must own its FaultConfig — a shared mutable default
    instance would leak per-runner deadline/backoff mutations globally."""
    import inspect
    sig = inspect.signature(FaultTolerantRunner.__init__)
    assert sig.parameters['cfg'].default is None       # never an instance
    a, b = FaultTolerantRunner(), FaultTolerantRunner()
    assert a.cfg is not b.cfg
    a.cfg.max_retries = 99
    assert b.cfg.max_retries != 99


def test_fault_runner_generalized_run_and_deadline():
    runner = FaultTolerantRunner(
        cfg=FaultConfig(max_retries=2, backoff_s=0.0, deadline_s=1e-9),
        fail_schedule=lambda s: s == 1)
    seen = []
    out = runner.run(0, lambda: 'ok')
    assert out == 'ok'
    out = runner.run(1, lambda: 'ok2',
                     on_fault=lambda e, n: seen.append((repr(e), n)))
    assert out == 'ok2' and len(seen) == 1             # injected once, retried
    assert runner.deadline_misses >= 2                 # 1 ns deadline: all miss
    kinds = [e['kind'] for e in runner.events]
    assert 'deadline_miss' in kinds and 'fault' in kinds
    assert runner.last_heartbeat['deadline_misses'] == runner.deadline_misses


def test_checkpoint_restore_validates_tree_paths(tmp_path):
    """Restoring into a structurally different tree (renamed key) must fail
    loudly naming the mismatched leaf — not silently load positionally."""
    m = CheckpointManager(tmp_path)
    m.save(1, {'a': jnp.ones((2,)), 'b': jnp.zeros((3,))}, blocking=True)
    with pytest.raises(ValueError, match=r"\['b'\]"):
        m.restore({'a': jnp.zeros((2,)), 'c': jnp.zeros((3,))})
    # explicit opt-out loads positionally (deliberate remapping)
    out = m.restore({'a': jnp.zeros((2,)), 'c': jnp.zeros((3,))},
                    match_paths=False)
    np.testing.assert_array_equal(out['a'], np.ones((2,)))


def test_checkpoint_async_save_failure_surfaced_by_wait(tmp_path,
                                                       monkeypatch):
    """A background save that raises must surface on the next wait(), and
    the manager must be usable again afterwards (error cleared)."""
    import repro.checkpoint.manager as mgr_mod
    m = CheckpointManager(tmp_path)
    real_save = mgr_mod.np.save

    def boom(*a, **k):
        raise OSError('disk full')

    monkeypatch.setattr(mgr_mod.np, 'save', boom)
    m.save(1, {'x': jnp.ones((4,))}, blocking=False)
    with pytest.raises(RuntimeError, match='async checkpoint write failed'):
        m.wait()
    monkeypatch.setattr(mgr_mod.np, 'save', real_save)
    m.wait()                                           # error cleared
    m.save(2, {'x': jnp.ones((4,))}, blocking=True)
    assert m.latest_step() == 2


def test_checkpoint_elastic_restore_different_mesh_shape():
    """Save under a 1-D 4-way mesh, restore under a 2x2 mesh — the elastic
    full-array layout must re-place leaves on the new topology bit-exactly."""
    from _subproc import run_with_devices
    out = run_with_devices("""
import jax, numpy as np, tempfile
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

devs = np.array(jax.devices())
td = tempfile.mkdtemp()
m = CheckpointManager(td)
x = jnp.arange(64.0).reshape(8, 8)
mesh1 = Mesh(devs.reshape(4), ('data',))
m.save(1, {'x': jax.device_put(x, NamedSharding(mesh1, P('data')))},
       blocking=True)
mesh2 = Mesh(devs.reshape(2, 2), ('row', 'col'))
out = m.restore({'x': jnp.zeros((8, 8))},
                shardings={'x': NamedSharding(mesh2, P('row', 'col'))})
np.testing.assert_array_equal(np.asarray(out['x']), np.asarray(x))
assert out['x'].sharding.mesh.shape == {'row': 2, 'col': 2}
print('OK')
""", n_devices=4)
    assert 'OK' in out
