"""Fault-tolerant serving runtime (DESIGN.md §10).

The contracts:

  * a preempted/evicted stream resumes **bit-equal** to an uninterrupted run
    on the same backend — in-engine (saved rows on the session) and across
    engine restarts (per-stream disk checkpoints via ``CheckpointManager``),
    for f32 ``(h, c)`` rows and the int8 kernels' opaque ``(h_q, c_q)``
    carries alike;
  * an injected ``EngineFailure`` degrades the backend down
    ``core.lstm.DEGRADATION_LADDER`` and re-places the packed state — every
    stream still completes (no stream loss), and the degradation composes
    with checkpoint/resume without breaking bit-equality;
  * a poisoned slot is quarantined exactly: its session gets a terminal
    error and never retires into ``done``; every neighbouring stream's
    outputs are bit-untouched;
  * the deadline watchdog records misses against the paper-derived
    per-chunk budget; the clean guard path changes no numerics.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import lstm, quant, systolic
from repro.kernels.lstm_seq import lstm_layer_seq_quantized
from repro.models import chipmunk_net
from repro.runtime import (EngineFailure, ServingFaultConfig,
                           StreamStateCheckpointer, chunk_deadline_s)
from repro.serving import SlotScheduler, StreamingEngine


CFG = configs.get_smoke_config('chipmunk-ctc')
PARAMS, _ = chipmunk_net.init(CFG, jax.random.PRNGKey(0))


def _utts(n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((30 + 7 * i, CFG.lstm_inputs))
            .astype(np.float32) * 0.5 for i in range(n)]


def _drain(eng, utts, sids=None):
    for i, u in enumerate(utts):
        eng.submit(u, sid=None if sids is None else sids[i])
    done = eng.run()
    return {s.sid: s.full_log_probs() for s in done}


# ------------------------------------------------- checkpoint/resume
def test_preempt_resume_bit_equal_in_engine():
    base = _drain(StreamingEngine(CFG, PARAMS, max_streams=2, chunk=8),
                  _utts())
    eng = StreamingEngine(CFG, PARAMS, max_streams=2, chunk=8,
                          faults=ServingFaultConfig())
    ss = [eng.submit(u) for u in _utts()]
    eng.step(); eng.step()
    sess = eng.preempt(ss[0].sid)            # mid-stream, state snapshotted
    assert sess is sess and sess.saved_state is not None
    assert eng.sched.pending[0] is sess      # requeued at the FRONT
    eng.run()
    got = {s.sid: s.full_log_probs() for s in eng.sched.done}
    assert set(got) == set(base)
    for sid in base:
        np.testing.assert_array_equal(base[sid], got[sid])
    kinds = [e['kind'] for e in eng.events]
    assert 'preempt' in kinds and 'resume' in kinds


def test_evict_then_resume_bit_equal():
    """evict() no longer discards state: the abandoned session can be
    resubmitted via resume() and still finishes bit-equal."""
    base = _drain(StreamingEngine(CFG, PARAMS, max_streams=2, chunk=8),
                  _utts())
    eng = StreamingEngine(CFG, PARAMS, max_streams=2, chunk=8,
                          faults=ServingFaultConfig())
    ss = [eng.submit(u) for u in _utts()]
    eng.step()
    sess = eng.evict(ss[1].sid)
    assert sess not in eng.sched.pending     # abandonment: not requeued
    eng.resume(sess)
    eng.run()
    got = {s.sid: s.full_log_probs() for s in eng.sched.done}
    for sid in base:
        np.testing.assert_array_equal(base[sid], got[sid])


def test_cross_engine_checkpoint_resume_bit_equal(tmp_path):
    """Preempt to disk, rebuild a FRESH engine, resume from the checkpoint:
    the suffix continues bit-equal to the uninterrupted run."""
    utts = _utts()
    base = _drain(StreamingEngine(CFG, PARAMS, max_streams=2, chunk=8), utts)
    fc = ServingFaultConfig(checkpoint_dir=str(tmp_path))
    eng = StreamingEngine(CFG, PARAMS, max_streams=2, chunk=8, faults=fc)
    ss = [eng.submit(u) for u in utts[:2]]
    eng.step(); eng.step()                   # 16 frames consumed per slot
    eng.evict(ss[0].sid)                     # snapshots rows+cursor to disk
    eng.run()

    eng2 = StreamingEngine(CFG, PARAMS, max_streams=2, chunk=8, faults=fc)
    assert eng2._ckpt.has(ss[0].sid)
    sess = eng2.resume_from_checkpoint(utts[0], ss[0].sid)
    assert sess.cursor == 16
    eng2.run()
    np.testing.assert_array_equal(base[ss[0].sid][16:],
                                  sess.full_log_probs())


def test_int8_opaque_state_checkpoint_bit_identical(tmp_path):
    """The checkpointer is pytree-generic: the int8 kernel's opaque
    (h_q, c_q) carry round-trips through disk and the resumed chunked run
    is bit-identical to the uninterrupted one."""
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), 16, 48)
    qp = systolic.quantize_packed(
        systolic.pack_lstm(p, systolic.SystolicPlan(16, 48, 16)))
    xs = jax.random.normal(jax.random.PRNGKey(1), (9, 3, 16)) * 0.5
    xs_q = quant.quantize(xs, quant.STATE_FMT)

    def run_chunks(spans, state):
        outs = []
        for lo, hi in spans:
            o, state = lstm_layer_seq_quantized(
                qp, xs_q[lo:hi], state=state, return_state=True,
                interpret=True)
            outs.append(np.asarray(o))
        return np.concatenate(outs), state

    ref, _ = run_chunks([(0, 3), (3, 6), (6, 9)], None)
    head, mid_state = run_chunks([(0, 3), (3, 6)], None)

    ckpt = StreamStateCheckpointer(str(tmp_path))
    ckpt.save(7, (tuple(np.asarray(s) for s in mid_state),), cursor=6)
    like = (tuple(np.zeros_like(np.asarray(s)) for s in mid_state),)
    (restored,), cursor = ckpt.load(7, like)
    assert cursor == 6
    for a, b in zip(restored, mid_state):
        assert np.asarray(a).dtype == np.asarray(b).dtype  # int8 preserved
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tail, _ = run_chunks([(6, 9)], tuple(jnp.asarray(s) for s in restored))
    np.testing.assert_array_equal(np.concatenate([head, tail]), ref)


# ------------------------------------------------- degradation ladder
def test_degradation_ladder_order():
    assert lstm.next_backend_down('pallas_seq_fused_systolic') == \
        'pallas_seq_fused'
    assert lstm.next_backend_down('pallas_seq_systolic') == 'pallas_seq_fused'
    assert lstm.next_backend_down('pallas_seq_fused') == 'pallas_seq'
    assert lstm.next_backend_down('pallas_seq') == 'xla_scan'
    assert lstm.next_backend_down('pallas_step') == 'xla_scan'
    assert lstm.next_backend_down('xla_scan') is None


def test_promotion_ladder_order():
    """next_backend_up is the exact inverse of next_backend_down within
    DEGRADATION_LADDER, and None at the top."""
    assert lstm.next_backend_up('pallas_seq_fused_systolic') is None
    assert lstm.next_backend_up('pallas_seq_fused') == \
        'pallas_seq_fused_systolic'
    assert lstm.next_backend_up('pallas_seq') == 'pallas_seq_fused'
    assert lstm.next_backend_up('xla_scan') == 'pallas_seq'
    for b in lstm.DEGRADATION_LADDER[1:]:
        assert lstm.next_backend_down(lstm.next_backend_up(b)) == b


def test_transient_failure_retries_without_degrading():
    """EngineFailure(transient=True) is a recoverable glitch: the runner
    retries in place, the backend never degrades, and outputs stay
    bit-equal to a clean run on the SAME backend."""
    cfg = CFG.replace(lstm_backend='pallas_seq')
    utts = _utts(3)
    fc = ServingFaultConfig(fail_at={1: {'n_dead': 1, 'transient': True}},
                            backoff_s=0.0)
    eng = StreamingEngine(cfg, PARAMS, max_streams=2, chunk=8, faults=fc)
    got = _drain(eng, utts)
    st = eng.stats()
    assert st['backend'] == 'pallas_seq'          # no degradation
    assert st['event_counts']['fault'] == 1
    assert st['event_counts'].get('degrade', 0) == 0
    faults = [e for e in st['events'] if e['kind'] == 'fault']
    assert faults[0]['transient'] is True
    ref = _drain(StreamingEngine(cfg, PARAMS, max_streams=2, chunk=8), utts)
    for sid in ref:
        np.testing.assert_array_equal(ref[sid], got[sid])


def test_permanent_failures_do_not_burn_retry_budget():
    """Permanent EngineFailures are charged to the separate max_permanent
    cap, never to max_retries: with max_retries=0 a permanent failure
    still degrades and the chunk still completes on the retry."""
    cfg = CFG.replace(lstm_backend='pallas_seq')
    fc = ServingFaultConfig(fail_at={1: 1}, max_retries=0, backoff_s=0.0)
    eng = StreamingEngine(cfg, PARAMS, max_streams=2, chunk=8, faults=fc)
    got = _drain(eng, _utts(3))
    assert len(got) == 3
    st = eng.stats()
    assert st['backend'] == 'xla_scan'
    assert st['event_counts']['degrade'] == 1
    faults = [e for e in st['events'] if e['kind'] == 'fault']
    assert faults[0]['transient'] is False
    # ...while a transient fault with max_retries=0 is terminal
    fc2 = ServingFaultConfig(fail_at={1: {'transient': True}},
                             max_retries=0, backoff_s=0.0)
    eng2 = StreamingEngine(cfg, PARAMS, max_streams=2, chunk=8, faults=fc2)
    for u in _utts(2):
        eng2.submit(u)
    with pytest.raises(EngineFailure):
        eng2.run()


def test_fail_schedule_dict_specs_and_domain_heartbeat():
    """Dict fail_at specs carry the taxonomy; the heartbeat records the
    last-seen fault domain."""
    sched = ServingFaultConfig(
        fail_at={3: {'n_dead': 2, 'transient': True, 'domain': 1}}
    ).make_fail_schedule()
    exc = sched(3)
    assert isinstance(exc, EngineFailure)
    assert exc.n_dead == 2 and exc.transient and exc.domain == 1
    cfg = CFG.replace(lstm_backend='pallas_seq')
    fc = ServingFaultConfig(fail_at={1: {'n_dead': 1, 'domain': 0}},
                            backoff_s=0.0)
    eng = StreamingEngine(cfg, PARAMS, max_streams=2, chunk=8, faults=fc)
    _drain(eng, _utts(3))
    assert eng.stats()['heartbeat']['fault_domain'] == 0


def test_engine_failure_degrades_without_stream_loss():
    cfg = CFG.replace(lstm_backend='pallas_seq')
    utts = _utts(5)
    fc = ServingFaultConfig(fail_at={2: 1}, backoff_s=0.0)
    eng = StreamingEngine(cfg, PARAMS, max_streams=2, chunk=8, faults=fc)
    assert eng.backend == 'pallas_seq'
    got = _drain(eng, utts)
    assert len(got) == len(utts)             # no stream lost
    st = eng.stats()
    assert st['backend'] == 'xla_scan'
    deg = [e for e in st['events'] if e['kind'] == 'degrade']
    assert deg == [{'kind': 'degrade', 'step': 2,
                    'from_backend': 'pallas_seq', 'to_backend': 'xla_scan',
                    'n_dead': 1, 'domain': 0}]
    assert st['event_counts']['fault'] == 1

    # outputs agree with a clean pallas_seq run to float tolerance (the
    # ladder never changes the chunking/masking contract, only the engine)
    ref = _drain(StreamingEngine(cfg, PARAMS, max_streams=2, chunk=8), utts)
    for sid in ref:
        np.testing.assert_allclose(ref[sid], got[sid], atol=1e-5)


def test_degradation_exhausted_at_ladder_bottom():
    """At xla_scan an EngineFailure is retried, not degraded further."""
    fc = ServingFaultConfig(fail_at={1: 2}, backoff_s=0.0)
    eng = StreamingEngine(CFG.replace(lstm_backend='xla_scan'), PARAMS,
                          max_streams=2, chunk=8, faults=fc)
    got = _drain(eng, _utts(3))
    assert len(got) == 3
    st = eng.stats()
    assert st['backend'] == 'xla_scan'
    assert st['event_counts']['degrade_exhausted'] == 1


def test_degradation_preserves_resume_bit_equality():
    """Checkpoint/resume stays bit-equal ACROSS an injected degradation
    event: baseline and preempted run share the same fault schedule, so
    both compute the suffix on the degraded backend."""
    cfg = CFG.replace(lstm_backend='pallas_seq')
    utts = _utts()
    sched = {2: 1}
    base = _drain(StreamingEngine(
        cfg, PARAMS, max_streams=2, chunk=8,
        faults=ServingFaultConfig(fail_at=sched, backoff_s=0.0)), utts)
    eng = StreamingEngine(cfg, PARAMS, max_streams=2, chunk=8,
                          faults=ServingFaultConfig(fail_at=sched,
                                                    backoff_s=0.0))
    ss = [eng.submit(u) for u in utts]
    eng.step(); eng.step(); eng.step()       # degradation fired at step 2
    eng.preempt(ss[0].sid)
    eng.run()
    got = {s.sid: s.full_log_probs() for s in eng.sched.done}
    for sid in base:
        np.testing.assert_array_equal(base[sid], got[sid])


# ------------------------------------------------- quarantine
def test_quarantine_isolates_poisoned_slot():
    """Poisoning one slot quarantines exactly that stream; every
    neighbouring stream's outputs are bit-identical to a poison-free run
    of the SAME guard-on engine graph."""
    utts = _utts(5)
    base_eng = StreamingEngine(CFG, PARAMS, max_streams=3, chunk=8,
                               faults=ServingFaultConfig())
    base = _drain(base_eng, utts)

    eng = StreamingEngine(CFG, PARAMS, max_streams=3, chunk=8,
                          faults=ServingFaultConfig(poison_at={1: 1}))
    ss = [eng.submit(u) for u in utts]
    done = eng.run()
    done_sids = {s.sid for s in done}
    victim = [s for s in ss if s.error is not None]
    assert len(victim) == 1
    v = victim[0]
    assert 'quarantined' in v.error and v.sid not in done_sids
    st = eng.stats()
    assert st['event_counts']['quarantine'] == 1
    # neighbours (every non-victim stream) bit-untouched
    for s in done:
        np.testing.assert_array_equal(base[s.sid], s.full_log_probs())
    # the freed slot was recycled: all remaining streams completed
    assert done_sids == set(base) - {v.sid}


def test_quarantine_zeroes_only_poisoned_rows():
    """After quarantine the packed cache holds no non-finite values and
    the victim's rows are exactly zero."""
    eng = StreamingEngine(CFG, PARAMS, max_streams=3, chunk=8,
                          faults=ServingFaultConfig(poison_at={0: 2}))
    for u in _utts(3):
        eng.submit(u)
    eng.step()
    for h, c in eng.states:
        assert bool(jnp.isfinite(h).all()) and bool(jnp.isfinite(c).all())
        np.testing.assert_array_equal(np.asarray(h[2]), 0.0)
        np.testing.assert_array_equal(np.asarray(c[2]), 0.0)


# ------------------------------------------------- deadline watchdog
def test_chunk_deadline_derived_from_perf_model():
    from repro.core.perf_model import staged_realtime_frame_s
    assert chunk_deadline_s(16, 2.0) == \
        pytest.approx(16 * staged_realtime_frame_s() * 2.0)
    fc = ServingFaultConfig(deadline_factor=2.0)
    assert fc.resolve_deadline_s(16) == pytest.approx(chunk_deadline_s(16, 2.0))
    assert ServingFaultConfig(deadline_s=0.5).resolve_deadline_s(16) == 0.5
    assert ServingFaultConfig().resolve_deadline_s(16) is None


def test_deadline_watchdog_records_misses():
    """An impossible deadline flags every chunk as a miss — recorded as
    events and surfaced in stats(), never raised."""
    fc = ServingFaultConfig(deadline_s=1e-12)
    eng = StreamingEngine(CFG, PARAMS, max_streams=2, chunk=8, faults=fc)
    got = _drain(eng, _utts(3))
    assert len(got) == 3                     # misses never kill streams
    st = eng.stats()
    assert st['deadline_misses'] == st['steps'] > 0
    misses = [e for e in st['events'] if e['kind'] == 'deadline_miss']
    assert len(misses) == st['deadline_misses']
    assert all(m['deadline_s'] == 1e-12 for m in misses)
    assert st['heartbeat']['deadline_misses'] == st['deadline_misses']


# ------------------------------------------------- plumbing
def test_scheduler_evict_requeue_accounting():
    s = SlotScheduler(2)
    for item in 'abc':
        s.submit(item)
    s.refill()
    assert s.active() == [(0, 'a'), (1, 'b')]
    assert s.evict(0, requeue=True) == 'a'
    assert list(s.pending) == ['a', 'c']     # requeued at the FRONT
    assert s.busy and s.done == []
    s.refill()
    assert s.active() == [(0, 'a'), (1, 'b')]
    assert s.evict(1) == 'b'                 # abandonment: gone entirely
    assert 'b' not in s.pending and 'b' not in s.done
    s.refill()
    assert s.active() == [(0, 'a'), (1, 'c')]
    s.finish(0); s.finish(1)
    assert not s.busy and s.done == ['a', 'c']


def test_fail_schedule_and_resolve_backend():
    sched = ServingFaultConfig(fail_at={3: 2}).make_fail_schedule()
    assert sched(0) is None
    exc = sched(3)
    assert isinstance(exc, EngineFailure) and exc.n_dead == 2
    b = lstm.resolve_serving_backend(PARAMS, 'auto', 8, 4)
    assert b in lstm.BACKENDS and b != 'auto'
    assert lstm.resolve_serving_backend(PARAMS, 'pallas_seq', 8, 4) == \
        'pallas_seq'


def test_guard_on_engine_matches_plain_engine_bit_equal():
    """The fused non-finite guard must not change the clean path's
    numerics: guard-on output == no-fault-config output, bit for bit."""
    utts = _utts(4, seed=3)
    plain = _drain(StreamingEngine(CFG, PARAMS, max_streams=2, chunk=8),
                   utts)
    guarded = _drain(StreamingEngine(CFG, PARAMS, max_streams=2, chunk=8,
                                     faults=ServingFaultConfig()), utts)
    for sid in plain:
        np.testing.assert_array_equal(plain[sid], guarded[sid])
