"""Elastic recovery runtime (DESIGN.md §14).

The contracts under test:

  * ``MeshHealthTracker`` — deterministic LIFO fail/heal attribution,
    exponential-backoff hysteresis (flaps and rejected canaries double it,
    promotions re-arm it), never more than one promotion per window;
  * ``build_rungs`` — the materialised ladder: flat ladders walk
    ``DEGRADATION_LADDER``; a two-level die mesh contributes real
    intermediate rungs (same staged backend on fewer dies) above the flat
    tail, each checked against the real admission rule;
  * the engine round trip — degrade -> heal -> canary -> promote lands the
    serving engine back on its home rung with every stream's outputs
    BIT-EQUAL to an uninterrupted run, sync and async alike, and zero
    stream loss; promotions never land mid-flight; a rejected canary
    leaves engine state untouched and doubles the backoff;
  * checkpoint/resume composes with promotion: rows saved while degraded
    resume bit-equal after the engine has climbed back, and a
    ``CheckpointManager`` manifest written under the degraded placement
    validates (checksums) when restored under the promoted one;
  * the bounded event ring (``RingLog``) drops oldest-first and surfaces
    the drop count through ``StreamingEngine.stats()``.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _subproc import run_with_devices
from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.core import lstm, quant, systolic
from repro.kernels.lstm_seq import (lstm_stack_seq_quantized,
                                    lstm_stack_seq_quantized_auto)
from repro.models import chipmunk_net
from repro.runtime import (EngineFailure, MeshHealthTracker, RingLog, Rung,
                           ServingFaultConfig, build_rungs)
from repro.serving import StreamingEngine

CFG = configs.get_smoke_config('chipmunk-ctc')
PARAMS, _ = chipmunk_net.init(CFG, jax.random.PRNGKey(0))


def _utts(n=2, frames=100, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((frames, CFG.lstm_inputs))
            .astype(np.float32) * 0.5 for _ in range(n)]


def _engine(backend='pallas_seq', faults=None, async_mode=False, slots=2,
            chunk=8):
    cfg = CFG.replace(lstm_backend=backend)
    return StreamingEngine(cfg, PARAMS, max_streams=slots, chunk=chunk,
                           async_dispatch=async_mode, faults=faults)


def _drain(eng, utts):
    for i, u in enumerate(utts):
        eng.submit(u, sid=i)
    return {s.sid: s.full_log_probs() for s in eng.run()}


# ----------------------------------------------------- tracker unit contract
def test_tracker_fail_heal_lifo_and_attribution():
    tr = MeshHealthTracker(n_domains=3, hysteresis=2)
    assert tr.healthy == (0, 1, 2) and tr.n_healthy == 3
    assert tr.fail(0) == (2,)                  # unattributed: LIFO-highest
    assert tr.fail(1, domain=0) == (0,)        # attributed failure
    assert tr.healthy == (1,)
    assert tr.heal(2) == (0,)                  # LIFO: most recent first
    assert tr.heal(3) == (2,)
    assert tr.healthy == (0, 1, 2)
    assert tr.heal(4) == ()                    # nothing left to revive
    # n_dead spills from the attributed domain onto LIFO picks
    assert tr.fail(5, domain=1, n_dead=2) == (1, 2)
    assert tr.healthy == (0,)


def test_tracker_hysteresis_flap_and_reject_double_backoff():
    tr = MeshHealthTracker(n_domains=1, hysteresis=4, max_backoff=16)
    tr.fail(0)
    assert tr.backoff == 4 and not tr.can_promote(3) and tr.can_promote(4)
    tr.heal(4)
    tr.note_promote(4)
    assert not tr.can_promote(7)               # one promotion per window
    # failure INSIDE the post-promotion window is a flap: backoff doubles
    tr.fail(5)
    assert tr.backoff == 8 and not tr.can_promote(12) and tr.can_promote(13)
    tr.heal(13)
    # a rejected canary also doubles (the candidate is provably not ready)
    tr.note_reject(13)
    assert tr.backoff == 16 and not tr.can_promote(28)
    tr.note_reject(29)
    assert tr.backoff == 16, 'backoff must cap at max_backoff'
    # a failure OUTSIDE the window resets the backoff to the floor
    tr.note_promote(50)
    tr.fail(99)
    assert tr.backoff == 4


def test_tracker_best_rung_policy():
    rungs = (Rung('a', n_dies=2, need=2), Rung('b', n_dies=1, need=1),
             Rung('c', need=0))
    tr = MeshHealthTracker(n_domains=2, hysteresis=2)
    assert tr.best_rung(rungs, current=0) == 0
    tr.fail(0)
    assert tr.best_rung(rungs, current=0) == 1     # degraded direction
    tr.heal(1)
    assert tr.best_rung(rungs, current=1, step=1) == 1, 'window still shut'
    assert tr.best_rung(rungs, current=1, step=2) == 0
    tr.fail(3, n_dead=2)
    assert tr.best_rung(rungs, current=0) == 2
    tr.heal(9, n_healed=2)
    # promotions climb ONE rung at a time (each must canary individually)
    assert tr.best_rung(rungs, current=2, step=9) == 1


# -------------------------------------------------------- rung construction
def test_build_rungs_flat_ladders():
    rungs = build_rungs('pallas_seq_fused', n_layers=2, n_h=32)
    assert [r.backend for r in rungs] == \
        ['pallas_seq_fused', 'pallas_seq', 'xla_scan']
    assert [r.need for r in rungs] == [2, 1, 0]
    assert all(r.n_dies is None for r in rungs)
    assert rungs[0].label() == 'pallas_seq_fused'
    assert build_rungs('xla_scan', n_layers=2, n_h=32) == \
        (Rung('xla_scan', need=0),)
    top = build_rungs('pallas_seq_fused_systolic', n_layers=2, n_h=32)
    assert [r.backend for r in top] == list(lstm.DEGRADATION_LADDER)


def test_die_topology_requires_enough_devices():
    from repro.launch.mesh import make_die_topology
    with pytest.raises(ValueError, match='needs'):
        make_die_topology('graves-3x25')       # 75 engines > host devices


# ------------------------------------------------------- bounded event ring
def test_ringlog_bounds_drops_and_list_compat():
    log = RingLog(cap=3)
    log.extend([{'kind': 'a'}, {'kind': 'b'}, {'kind': 'c'}])
    assert log.dropped == 0 and len(log) == 3
    log.append({'kind': 'd'})
    assert log.dropped == 1
    assert log == [{'kind': 'b'}, {'kind': 'c'}, {'kind': 'd'}]
    assert log[0] == {'kind': 'b'} and log[-1] == {'kind': 'd'}
    assert log[1:] == [{'kind': 'c'}, {'kind': 'd'}]
    assert log + [{'kind': 'e'}] == [{'kind': 'b'}, {'kind': 'c'},
                                     {'kind': 'd'}, {'kind': 'e'}]
    assert [{'kind': 'z'}] + log == [{'kind': 'z'}, {'kind': 'b'},
                                     {'kind': 'c'}, {'kind': 'd'}]
    unbounded = RingLog(None)
    unbounded.extend(range(10_000))
    assert len(unbounded) == 10_000 and unbounded.dropped == 0
    with pytest.raises(ValueError):
        RingLog(cap=0)


def test_engine_stats_surface_ring_drops():
    fc = ServingFaultConfig(fail_at={1: 1}, recover_at={3: 1},
                            promote_hysteresis=2, backoff_s=0.0,
                            event_log_cap=2)
    eng = _engine(faults=fc)
    _drain(eng, _utts(2, frames=64))
    st = eng.stats()
    assert st['events_dropped'] > 0
    assert len(eng.events) <= 2
    # retained events are the NEWEST (oldest-first eviction)
    assert eng.events[-1]['kind'] == 'promote'


# ------------------------------------ tentpole: flat climb-back round trip
def test_promote_roundtrip_bit_equal_sync_and_async():
    """fail -> degrade -> heal -> promote_canary -> promote lands the engine
    back on its home rung; every stream's outputs are bit-equal to an
    uninterrupted run; sync and async replay the identical recovery trail."""
    utts = _utts(2, frames=100)
    ref = _drain(_engine(), utts)
    stats = {}
    for mode in (False, True):
        fc = ServingFaultConfig(fail_at={1: 1}, recover_at={4: 1},
                                promote_hysteresis=2, backoff_s=0.0)
        eng = _engine(faults=fc, async_mode=mode)
        got = _drain(eng, utts)
        assert len(got) == len(ref), 'zero stream loss'
        for sid in ref:
            np.testing.assert_array_equal(ref[sid], got[sid],
                                          err_msg=f'mode={mode} sid={sid}')
        st = eng.stats()
        assert st['backend'] == 'pallas_seq' and st['rung'] == 'pallas_seq'
        for kind in ('fault', 'degrade', 'heal', 'promote_canary', 'promote'):
            assert st['event_counts'].get(kind, 0) == 1, (mode, kind, st)
        trail = [e['kind'] for e in st['events']
                 if e['kind'] in ('degrade', 'heal', 'promote_canary',
                                  'promote')]
        assert trail == ['degrade', 'heal', 'promote_canary', 'promote']
        stats[mode] = st['event_counts']
    assert stats[False] == stats[True], 'async must replay the sync trail'


def test_promote_event_payload_and_healthy_capacity():
    fc = ServingFaultConfig(fail_at={1: 1}, recover_at={4: 1},
                            promote_hysteresis=2, backoff_s=0.0)
    eng = _engine(faults=fc)
    _drain(eng, _utts(2, frames=80))
    evs = {e['kind']: e for e in eng.stats()['events']
           if e['kind'] in ('degrade', 'heal', 'promote_canary', 'promote')}
    assert evs['degrade']['from_backend'] == 'pallas_seq'
    assert evs['degrade']['to_backend'] == 'xla_scan'
    assert evs['heal']['domains'] == [0] and evs['heal']['n_healed'] == 1
    assert evs['promote_canary']['to_backend'] == 'pallas_seq'
    assert evs['promote_canary']['chunk'] > 0
    assert evs['promote']['healthy'] == [0]
    assert eng.stats()['healthy_domains'] == [0]


def test_heal_without_hysteresis_window_defers_promotion():
    """Healed capacity alone is not enough: the promotion waits for the
    hysteresis window to elapse before the canary even runs."""
    fc = ServingFaultConfig(fail_at={1: 1}, recover_at={2: 1},
                            promote_hysteresis=6, backoff_s=0.0)
    eng = _engine(faults=fc)
    _drain(eng, _utts(2, frames=100))
    evs = [(e['kind'], e['step']) for e in eng.stats()['events']
           if e['kind'] in ('heal', 'promote')]
    heal_step = dict(evs)['heal']
    promote_step = dict(evs)['promote']
    assert heal_step == 2
    assert promote_step >= 1 + 6, 'window = fail step + hysteresis'


def test_flapping_engine_backs_off_geometrically():
    """An engine that dies right after each re-admission is a flap: the
    backoff doubles per flap, promotions are spaced at least one window
    apart, and the stream still completes bit-equal."""
    utts = _utts(2, frames=100)
    ref = _drain(_engine(), utts)
    fc = ServingFaultConfig(fail_at={1: 1, 4: 1, 9: 1},
                            recover_at={3: 1, 6: 1, 11: 1},
                            promote_hysteresis=2, backoff_s=0.0)
    eng = _engine(faults=fc)
    got = _drain(eng, utts)
    for sid in ref:
        np.testing.assert_array_equal(ref[sid], got[sid])
    st = eng.stats()
    promotes = [e['step'] for e in st['events'] if e['kind'] == 'promote']
    assert promotes == [3, 8], st['events']
    assert st['event_counts']['degrade'] == 3
    # the third flap pushed the window past the stream end: still degraded
    assert st['backend'] == 'xla_scan'
    assert eng._tracker.backoff == 8, 'two flaps: 2 -> 4 -> 8'
    gaps = np.diff(promotes)
    assert (gaps >= fc.promote_hysteresis).all(), \
        'never more than one promotion per hysteresis window'


def test_promotion_never_lands_mid_flight():
    """Async dispatch: every promote/canary/reject event fires only with
    the pipeline drained (the in-flight chunk committed first)."""
    fc = ServingFaultConfig(fail_at={1: 1}, recover_at={4: 1},
                            promote_hysteresis=2, backoff_s=0.0)
    eng = _engine(faults=fc, async_mode=True)
    seen = []
    orig = eng._record

    def checked(kind, **info):
        if kind in ('promote_canary', 'promote', 'promote_rejected'):
            assert eng._pending is None, f'{kind} fired mid-flight'
            seen.append(kind)
        orig(kind, **info)

    eng._record = checked
    _drain(eng, _utts(2, frames=100))
    assert 'promote' in seen


def test_rejected_canary_leaves_engine_untouched():
    """Force a canary mismatch (monkeypatched comparator): the engine stays
    on its degraded rung with backend/fwd/states untouched, emits
    ``promote_rejected``, doubles the backoff — and still finishes every
    stream bit-equal to the all-xla_scan suffix it actually ran."""
    fc = ServingFaultConfig(fail_at={1: 1}, recover_at={4: 1},
                            promote_hysteresis=2, backoff_s=0.0)
    eng = _engine(faults=fc)
    eng._canary_equal = lambda a, b: False
    got = _drain(eng, _utts(2, frames=100))
    assert len(got) == 2
    st = eng.stats()
    assert st['backend'] == 'xla_scan', 'reject must not promote'
    assert st['event_counts'].get('promote', 0) == 0
    rejects = [e for e in st['events'] if e['kind'] == 'promote_rejected']
    assert rejects, st['events']
    assert rejects[0]['backoff'] == 4, 'reject doubles the 2-step window'
    assert [r['backoff'] for r in rejects] == \
        sorted(r['backoff'] for r in rejects), 'monotone growth'


def test_canary_disabled_promotes_without_replay():
    """``canary=False`` opts out of the shadow replay: the promotion lands
    on capacity + hysteresis alone (no promote_canary event), and outputs
    remain bit-equal (the rungs agree on this path)."""
    utts = _utts(2, frames=100)
    ref = _drain(_engine(), utts)
    fc = ServingFaultConfig(fail_at={1: 1}, recover_at={4: 1},
                            promote_hysteresis=2, canary=False,
                            backoff_s=0.0)
    eng = _engine(faults=fc)
    got = _drain(eng, utts)
    for sid in ref:
        np.testing.assert_array_equal(ref[sid], got[sid])
    st = eng.stats()
    assert st['backend'] == 'pallas_seq'
    assert st['event_counts'].get('promote', 0) == 1
    assert st['event_counts'].get('promote_canary', 0) == 0


# ------------------------- satellite: checkpoint across promotion boundary
def test_checkpoint_resume_across_promotion_boundary(tmp_path):
    """Rows checkpointed while DEGRADED resume bit-equal in a fresh engine
    that never degraded: the §10 checkpoint contract is rung-independent,
    so preemption/restart composes with the climb-back."""
    utts = _utts(2, frames=100)
    ref = _drain(_engine(), utts)
    fc = ServingFaultConfig(fail_at={1: 1}, recover_at={4: 1},
                            promote_hysteresis=2, backoff_s=0.0,
                            checkpoint_dir=str(tmp_path))
    eng = _engine(faults=fc)
    for i, u in enumerate(utts):
        eng.submit(u, sid=i)
    for _ in range(3):
        eng.step()                      # degraded at step 1, still climbing
    assert eng.stats()['backend'] == 'xla_scan'
    sess = eng.preempt(0, requeue=False)
    cursor = sess.cursor
    assert cursor > 0
    eng.run()                           # stream 1 finishes; engine promotes
    assert eng.stats()['backend'] == 'pallas_seq'
    np.testing.assert_array_equal(ref[1],
                                  eng.sched.done[0].full_log_probs())
    # fresh engine on the HOME rung resumes the degraded-era checkpoint
    fresh = _engine(faults=ServingFaultConfig(checkpoint_dir=str(tmp_path),
                                              backoff_s=0.0))
    resumed = fresh.resume_from_checkpoint(utts[0], sid=0)
    assert resumed.cursor == cursor
    fresh.run()
    np.testing.assert_array_equal(ref[0][cursor:],
                                  resumed.full_log_probs())


def test_manifest_validates_across_placement_change(tmp_path):
    """A ``CheckpointManager`` manifest written under the degraded
    placement restores with checksum validation under the promoted one —
    the §5 elastic-restore contract applied to the packed serving cache."""
    fc = ServingFaultConfig(fail_at={1: 1}, recover_at={4: 1},
                            promote_hysteresis=2, backoff_s=0.0)
    eng = _engine(faults=fc)
    for i, u in enumerate(_utts(2, frames=100)):
        eng.submit(u, sid=i)
    for _ in range(3):
        eng.step()
    assert eng.stats()['backend'] == 'xla_scan'
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, eng.states, blocking=True)
    saved = jax.tree.map(np.asarray, eng.states)
    eng.run()
    assert eng.stats()['backend'] == 'pallas_seq'   # placement changed back
    restored = mgr.restore(eng.states, step=3, validate=True)
    for a, b in zip(jax.tree.leaves(saved), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(a, np.asarray(b))


# ----------------------------------- satellite: int8 opaque-carry climbing
def test_int8_opaque_carry_survives_rung_flips():
    """Degrade-then-promote at the kernel level: the quantized stack flips
    fused -> layerwise -> fused across chunk boundaries with a host
    round-trip of the opaque ``(h_q, c_q)`` carry at each flip; emitted
    codes stay bit-identical to the monolithic fused call (the int8 rungs
    are one arithmetic class, which is what lets a canary pass)."""
    n_x = n_h = 16
    stack = lstm.init_lstm_stack(jax.random.PRNGKey(5), n_x, n_h, 2,
                                 n_out=None)
    qps = [systolic.quantize_packed(systolic.pack_lstm(
        lp, systolic.SystolicPlan(n_x if l == 0 else n_h, n_h, 16)))
        for l, lp in enumerate(stack.layers)]
    T, B = 18, 2
    xs = jax.random.normal(jax.random.PRNGKey(3), (T, B, n_x)) * 0.5
    xs_q = quant.quantize(xs, quant.STATE_FMT)
    ref = np.asarray(lstm_stack_seq_quantized(qps, xs_q, interpret=True))
    bounds = [0, 6, 12, T]
    backends = ['fused', 'layerwise', 'fused']   # degrade, then promote
    st_c, outs = None, []
    for (lo, hi), backend in zip(zip(bounds[:-1], bounds[1:]), backends):
        o, st_c = lstm_stack_seq_quantized_auto(
            qps, xs_q[lo:hi], state=st_c, return_state=True,
            interpret=True, backend=backend)
        st_c = tuple(jnp.asarray(np.asarray(p)) for p in st_c)
        outs.append(np.asarray(o))
    np.testing.assert_array_equal(np.concatenate(outs), ref)


# --------------------------- tentpole: die-mesh chaos end to end (3 devices)
@pytest.mark.timeout(900)
def test_die_mesh_chaos_degrade_heal_promote_roundtrip():
    """Kill one die of a 3-die mesh mid-stream, heal it, climb back: the
    staged backend re-forms on 2 dies (an intermediate rung, not a flat
    fallback), the healed die is canary-validated back in, every stream is
    bit-equal to an uninterrupted 3-die run, and async replays the same
    trail."""
    out = run_with_devices("""
import numpy as np, jax
from repro import configs
from repro.launch import mesh as lmesh
from repro.models import chipmunk_net
from repro.runtime import ServingFaultConfig, build_rungs
from repro.serving import StreamingEngine

dm = lmesh.install_die_topology('die-3x1x1')
cfg = configs.get_smoke_config('chipmunk-ctc').replace(
    n_layers=3, lstm_backend='pallas_seq_fused_systolic')
params, _ = chipmunk_net.init(cfg, jax.random.PRNGKey(0))
rungs = build_rungs(cfg.lstm_backend, n_layers=3, n_h=cfg.lstm_hidden,
                    die_mesh=dm, n_x=cfg.lstm_inputs, T=8, batch=2)
assert [r.label() for r in rungs] == [
    'pallas_seq_fused_systolic@3d', 'pallas_seq_fused_systolic@2d',
    'pallas_seq_fused', 'pallas_seq', 'xla_scan'], rungs
assert [r.need for r in rungs] == [3, 2, 0, 0, 0]

rng = np.random.default_rng(0)
utts = [rng.standard_normal((88, cfg.lstm_inputs)).astype(np.float32) * 0.5
        for _ in range(2)]

def drain(faults, mode):
    lmesh.install_die_topology('die-3x1x1')
    eng = StreamingEngine(cfg, params, max_streams=2, chunk=8,
                          async_dispatch=mode, faults=faults)
    for i, u in enumerate(utts):
        eng.submit(u, sid=i)
    done = {s.sid: s.full_log_probs() for s in eng.run()}
    return eng, done

_, ref = drain(None, False)
counts = {}
for mode in (False, True):
    fc = ServingFaultConfig(fail_at={2: {'n_dead': 1, 'domain': 2}},
                            recover_at={5: 1}, promote_hysteresis=2,
                            backoff_s=0.0)
    eng, got = drain(fc, mode)
    assert len(got) == 2, 'zero stream loss'
    for sid in ref:
        np.testing.assert_array_equal(ref[sid], got[sid])
    st = eng.stats()
    assert st['rung'] == 'pallas_seq_fused_systolic@3d', st['rung']
    assert st['healthy_domains'] == [0, 1, 2]
    deg = [e for e in st['events'] if e['kind'] == 'degrade'][0]
    assert deg['domain'] == 2
    assert deg['to_backend'] == 'pallas_seq_fused_systolic', deg
    pro = [e for e in st['events'] if e['kind'] == 'promote'][0]
    assert pro['n_dies'] == 3 and pro['healthy'] == [0, 1, 2]
    trail = [e['kind'] for e in st['events'] if e['kind'] in
             ('degrade', 'heal', 'promote_canary', 'promote')]
    assert trail == ['degrade', 'heal', 'promote_canary', 'promote'], trail
    counts[mode] = st['event_counts']
assert counts[False] == counts[True], counts
print('CHAOS_OK')
""", n_devices=3, timeout=880)
    assert 'CHAOS_OK' in out


@pytest.mark.timeout(900)
def test_die_mesh_cross_class_promotion_rejected_with_backoff():
    """die-2x1x2: losing a die drops the staged 2-die rung to the
    LAYERWISE single-die mesh rung — a different arithmetic class, so the
    climb-back canary deterministically REJECTS (bitwise comparator), the
    backoff doubles per attempt, and the engine keeps serving on the
    degraded rung with zero stream loss."""
    out = run_with_devices("""
import numpy as np, jax
from repro import configs
from repro.launch import mesh as lmesh
from repro.models import chipmunk_net
from repro.runtime import ServingFaultConfig, build_rungs
from repro.serving import StreamingEngine

dm = lmesh.install_die_topology('die-2x1x2')
cfg = configs.get_smoke_config('chipmunk-ctc').replace(
    n_layers=3, lstm_backend='pallas_seq_fused_systolic')
params, _ = chipmunk_net.init(cfg, jax.random.PRNGKey(0))
rungs = build_rungs(cfg.lstm_backend, n_layers=3, n_h=cfg.lstm_hidden,
                    die_mesh=dm, n_x=cfg.lstm_inputs, T=8, batch=2)
assert [r.label() for r in rungs] == [
    'pallas_seq_fused_systolic@2d', 'pallas_seq_systolic@1d',
    'pallas_seq_fused', 'pallas_seq', 'xla_scan'], rungs

rng = np.random.default_rng(1)
utts = [rng.standard_normal((96, cfg.lstm_inputs)).astype(np.float32) * 0.5
        for _ in range(2)]
fc = ServingFaultConfig(fail_at={2: {'n_dead': 1, 'domain': 1}},
                        recover_at={5: 1}, promote_hysteresis=2,
                        backoff_s=0.0)
eng = StreamingEngine(cfg, params, max_streams=2, chunk=8, faults=fc)
for i, u in enumerate(utts):
    eng.submit(u, sid=i)
done = eng.run()
assert len(done) == 2, 'zero stream loss'
st = eng.stats()
assert st['backend'] == 'pallas_seq_systolic', st['backend']
assert st['rung'] == 'pallas_seq_systolic@1d'
rejects = [e for e in st['events'] if e['kind'] == 'promote_rejected']
assert len(rejects) >= 2, st['events']
assert [r['backoff'] for r in rejects][:2] == [4, 8], rejects
assert st['event_counts'].get('promote', 0) == 0
# the layerwise mesh rung still serves correct streams (allclose across
# the mid-stream arithmetic-class change)
import jax.numpy as jnp
for s in done:
    lp = chipmunk_net.forward(cfg.replace(lstm_backend='xla_scan'), params,
                              jnp.asarray(utts[s.sid])[None])
    mono = np.asarray(jnp.moveaxis(lp, 0, 1))[0]
    np.testing.assert_allclose(s.full_log_probs(), mono,
                               rtol=1e-5, atol=1e-6)
print('REJECT_OK')
""", n_devices=4, timeout=880)
    assert 'REJECT_OK' in out
