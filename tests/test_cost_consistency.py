"""Cross-checking the two ranking oracles (S3, DESIGN.md §13).

The dispatcher has two ways to rank a stack launch without a device
trial: the paper-calibrated CYCLE model (`core/perf_model.py`, silicon
semantics — Chipmunk arrays, weight reloads) and the HLO-derived COST
oracle (`repro/hlo_cost.py`, what XLA actually emitted on this host).
These answer different questions, so this suite deliberately does NOT
assert that they agree on cross-backend ordering: on the emulation host
the fused pallas path pays interpreter overheads the silicon model does
not charge, and PR8's dispatch work already documented the inversion
(measured host ordering != silicon-model ordering).  What CAN be pinned
honestly, and is pinned here:

  * both oracles are pure functions of the shape (byte-identical
    replays — the determinism the CI autotune smoke diffs);
  * within ONE backend, both agree on shape monotonicity (more layers /
    longer sequences never get cheaper);
  * the hlo_cost estimate (no-overlap SUM of roofline terms) brackets
    `roofline.analyze`'s `step_time_lower_bound_s` (perfect-overlap MAX
    term) from above, on the same compiled executable — wiring the two
    HLO walks together over a real lowering;
  * a measured wall-clock launch is never faster than the roofline
    lower bound scaled to claim plausibility (sanity only: the host is
    not the modeled chip, so only the *bound direction* is asserted).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import hlo_cost, roofline
from repro.core import perf_model as pm
from repro.core.lstm import init_lstm_stack, lstm_stack_apply

SMALL = (24, 48, 2, 16, 2)    # n_x, n_h, n_layers, T, B


def _cycle_cost(n_x, n_h, n_layers, T):
    """Cycle-model cost of the same stack, single engine (arrays=1)."""
    layers = [pm.LayerDims(n_x, n_h)] + \
        [pm.LayerDims(n_h, n_h)] * (n_layers - 1)
    return pm.sequential_cycles(layers, pm.TileConfig(1, 1, 1), T)


def test_rankings_deterministic():
    a = hlo_cost.rank_stack_backends(*SMALL)
    b = hlo_cost.rank_stack_backends(*SMALL)
    assert a and a == b
    names = [n for n, _ in a]
    assert names == sorted(names, key=dict(a).get)     # best first
    for _, us in a:
        assert us > 0 and np.isfinite(us)


@pytest.mark.parametrize('backend', hlo_cost.NON_STAGED_STACK_BACKENDS)
def test_shape_monotonicity_agreement(backend):
    """Per fixed backend, both oracles agree growth never gets cheaper."""
    n_x, n_h, n_layers, T, B = SMALL
    base = hlo_cost.estimate_backend_us(backend, n_x, n_h, n_layers, T, B)
    deeper = hlo_cost.estimate_backend_us(backend, n_x, n_h,
                                          n_layers + 2, T, B)
    longer = hlo_cost.estimate_backend_us(backend, n_x, n_h,
                                          n_layers, 2 * T, B)
    assert deeper >= base and longer >= base
    assert _cycle_cost(n_x, n_h, n_layers + 2, T) >= \
        _cycle_cost(n_x, n_h, n_layers, T)
    assert _cycle_cost(n_x, n_h, n_layers, 2 * T) >= \
        _cycle_cost(n_x, n_h, n_layers, T)


def test_estimate_brackets_roofline_lower_bound():
    n_x, n_h, n_layers, T, B = SMALL
    for backend in hlo_cost.NON_STAGED_STACK_BACKENDS:
        params = init_lstm_stack(jax.random.PRNGKey(0), n_x, n_h, n_layers)
        xs = jnp.zeros((T, B, n_x), jnp.float32)
        compiled = jax.jit(
            lambda p, x: lstm_stack_apply(p, x, backend=backend)[0]
        ).lower(params, xs).compile()
        terms = roofline.analyze(compiled)
        assert terms.bottleneck in ('compute', 'memory', 'collective')
        lower_us = terms.step_time_lower_bound_s * 1e6
        est_us = hlo_cost.estimate_backend_us(backend, n_x, n_h,
                                              n_layers, T, B)
        # MAX of the three terms can never exceed their SUM; both walks
        # must charge the same HLO, so the bracket is exact by math —
        # a divergence means the two modules walked different graphs.
        assert lower_us <= est_us * (1 + 1e-9), backend
        # and the sum is at most 3x the max (three nonnegative terms)
        assert est_us <= 3 * lower_us * (1 + 1e-9) or lower_us == 0


def test_measured_respects_lower_bound():
    """One real launch is no faster than the perfect-overlap bound."""
    n_x, n_h, n_layers, T, B = SMALL
    params = init_lstm_stack(jax.random.PRNGKey(0), n_x, n_h, n_layers)
    xs = jnp.zeros((T, B, n_x), jnp.float32)
    fn = jax.jit(lambda p, x: lstm_stack_apply(p, x,
                                               backend='xla_scan')[0])
    compiled = fn.lower(params, xs).compile()
    lower_us = roofline.analyze(compiled).step_time_lower_bound_s * 1e6
    fn(params, xs).block_until_ready()          # warm
    import time
    t0 = time.perf_counter()
    fn(params, xs).block_until_ready()
    measured_us = (time.perf_counter() - t0) * 1e6
    # the bound models the target accelerator; a host CPU is far slower,
    # so only the direction is meaningful — never a tight comparison
    assert measured_us > lower_us


def test_failed_lowerings_are_skipped_not_fatal():
    ranked = hlo_cost.rank_stack_backends(
        *SMALL, backends=('xla_scan', 'definitely_not_a_backend'))
    assert [n for n, _ in ranked] == ['xla_scan']


def test_cross_backend_ordering_is_not_pinned():
    """Document WHY: the host inverts the silicon ordering (PR8).

    The cycle model at a single engine ties the sequential and fused
    schedules (same MACs, same reloads), while hlo_cost sees genuinely
    different emitted graphs per backend.  Asserting agreement would pin
    host emulation artifacts as if they were silicon truth — so this
    test only checks both oracles yield a total order at all.
    """
    n_x, n_h, n_layers, T, _ = SMALL
    ranked = hlo_cost.rank_stack_backends(*SMALL)
    assert len(ranked) == len(hlo_cost.NON_STAGED_STACK_BACKENDS)
    assert _cycle_cost(n_x, n_h, n_layers, T) > 0
