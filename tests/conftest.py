"""Test bootstrap: provide a hypothesis stand-in when it isn't installed."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub
    sys.modules['hypothesis'] = _hypothesis_stub
    sys.modules['hypothesis.strategies'] = _hypothesis_stub.strategies
