"""Test bootstrap: hypothesis stand-in + a stub-compatible ``timeout`` marker.

When the real ``hypothesis`` / ``pytest-timeout`` packages are installed they
are used as-is; otherwise minimal local fallbacks keep the same test sources
running (deterministic example drawing, SIGALRM-based timeouts).  The
``timeout`` marker is what lets a deadlocked async serving step fail fast in
the serving-conformance CI job instead of hanging the runner.
"""
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub
    sys.modules['hypothesis'] = _hypothesis_stub
    sys.modules['hypothesis.strategies'] = _hypothesis_stub.strategies

try:
    import pytest_timeout  # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False


def pytest_configure(config):
    config.addinivalue_line(
        'markers',
        'timeout(seconds): fail the test if it runs longer than this '
        '(pytest-timeout when installed, SIGALRM fallback otherwise)')


if not _HAVE_PYTEST_TIMEOUT:
    import signal

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        marker = item.get_closest_marker('timeout')
        if marker is None or not hasattr(signal, 'SIGALRM'):
            yield
            return
        seconds = int(marker.args[0] if marker.args
                      else marker.kwargs.get('seconds', 60))

        def _alarm(signum, frame):
            raise TimeoutError(
                f'{item.nodeid} exceeded its {seconds}s timeout marker')

        old = signal.signal(signal.SIGALRM, _alarm)
        signal.alarm(seconds)
        try:
            yield
        finally:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, old)
