"""Doc-presence gate: every public entry point of the systolic/sequence-kernel
modules must carry a docstring that states its numerics contract (which
reference it is bit-identical or allclose to) — DESIGN.md §6's documentation
satellite.  This keeps the backend matrix in README.md honest: each backend's
equivalence claim is written at the definition site and asserted here.
"""
import inspect

import pytest

import repro.core.systolic as systolic_mod
import repro.kernels.lstm_seq.ops as ops_mod
import repro.kernels.lstm_seq.stack_ops as stack_ops_mod
import repro.launch.mesh as launch_mesh_mod
import repro.runtime.recovery as recovery_mod
import repro.runtime.serving_faults as serving_faults_mod
import repro.serving.engine as engine_mod
import repro.serving.scheduler as scheduler_mod
import repro.serving.session as session_mod
import repro.tune.autotune as autotune_mod
import repro.tune.schedule as schedule_mod
import repro.tune.shmoo as shmoo_mod
from repro.core import lstm as lstm_core
from repro.models import chipmunk_net

MODULES = (systolic_mod, ops_mod, stack_ops_mod, engine_mod, scheduler_mod,
           session_mod, serving_faults_mod, schedule_mod, shmoo_mod,
           autotune_mod, recovery_mod, launch_mesh_mod)

# Entry point -> substring its docstring must contain (the numerics contract:
# the reference the function is bit-identical / allclose to, or an explicit
# statement that it performs no arithmetic).
CONTRACTS = {
    systolic_mod.systolic_cell_tiled: 'lstm_cell',
    systolic_mod.systolic_layer_tiled: 'lstm_layer',
    systolic_mod.systolic_cell_quantized: 'bit-exact',
    systolic_mod.systolic_layer_quantized: 'systolic_cell_quantized',
    systolic_mod.systolic_lstm_shard_map: 'systolic_cell_tiled',
    systolic_mod.systolic_lstm_seq: 'systolic_cell_tiled',
    systolic_mod.systolic_lstm_seq_quantized: 'bit-identical',
    systolic_mod.systolic_seq_fused: 'lstm_scan_fused',
    systolic_mod.pack_lstm: 'lossless',
    systolic_mod.quantize_packed: 'quantization',
    # staged fused-systolic scale-out contracts (DESIGN.md §9)
    systolic_mod.systolic_lstm_stack_seq: 'lstm_stack_apply',
    systolic_mod.systolic_lstm_stack_seq_quantized: 'bit-identical',
    systolic_mod.systolic_stack_seq_fused: 'lstm_scan_fused',
    systolic_mod.stage_layer_blocks: 'geometry',
    lstm_core.lstm_stack_bwd_recompute_gates: 'lstm_bwd_recompute_gates',
    ops_mod.lstm_layer_seq: 'lstm_layer',
    ops_mod.lstm_layer_seq_quantized: 'bit-identical',
    ops_mod.lstm_seq_fused: 'lstm_scan_fused',
    ops_mod.vmem_bytes_estimate: 'selection',
    # fused whole-stack wavefront kernel contracts (DESIGN.md §8)
    stack_ops_mod.lstm_stack_seq: 'lstm_stack_apply',
    stack_ops_mod.lstm_stack_seq_fused: 'lstm_scan_fused',
    stack_ops_mod.lstm_stack_seq_quantized: 'bit-identical',
    stack_ops_mod.stack_vmem_bytes_estimate: 'selection',
    stack_ops_mod.stack_fused_compatible: 'dispatch',
    lstm_core.select_stack_backend: 'selection',
    # streaming-serving chunking/masking contracts (DESIGN.md §7)
    lstm_core.lstm_layer_chunk: 'bit-equal',
    lstm_core.lstm_stack_chunk: 'lstm_stack_apply',
    chipmunk_net.stream_forward: 'bit-equal',
    engine_mod.StreamingEngine: 'forward',
    session_mod.IncrementalCTCDecoder: 'ctc_greedy_decode',
    # serving fault-model contracts (DESIGN.md §10)
    lstm_core.next_backend_down: 'dispatch',
    lstm_core.resolve_serving_backend: 'dispatch',
    serving_faults_mod.StreamStateCheckpointer: 'CheckpointManager',
    serving_faults_mod.chunk_deadline_s: 'staged_realtime_frame_s',
    serving_faults_mod.finite_slots: 'no mutation',
    serving_faults_mod.elastic_replace: 'bit-preserved',
    engine_mod.StreamingEngine.preempt: 'bit-equal',
    engine_mod.StreamingEngine.resume_from_checkpoint: 'bit-equal',
    # async dispatch + deadline-aware chunk sizing contracts (DESIGN.md §11)
    serving_faults_mod.ChunkSizePolicy: 'realtime_chunk_budget_s',
    lstm_core.select_quantized_stack_backend: 'bit-identical',
    stack_ops_mod.lstm_stack_seq_quantized_auto: 'bit-identical',
    engine_mod.StreamingEngine.step: 'commit',
    scheduler_mod.SlotScheduler.preempt_candidate: 'priority',
    # measured-schedule autotuner contracts (DESIGN.md §12)
    schedule_mod.install_schedule_cache: 'dispatch',
    schedule_mod.mesh_signature: 'cache key',
    systolic_mod.resolve_staged_chunk: 'schedule',
    systolic_mod.resolve_staged_in_stage: 'bit-equal',
    autotune_mod.tune_staged_stack: 'bitwise',
    autotune_mod.tune_quantized_backend: 'bit-identical',
    autotune_mod.replay_check: 'deterministic',
    shmoo_mod.write_shmoo_csv: 'shared',
    engine_mod.tuned_chunk_ceiling: 'scheduling-only',
    # elastic recovery runtime contracts (DESIGN.md §14)
    lstm_core.next_backend_up: 'dispatch',
    recovery_mod.build_rungs: 'selection',
    recovery_mod.MeshHealthTracker: 'control-plane',
    launch_mesh_mod.DieMesh.submesh: 'bit-equal',
    launch_mesh_mod.install_die_topology: 'numerics are unchanged',
    engine_mod.StreamingEngine.stats: 'snapshot',
}


def _public_callables(mod):
    out = []
    for name in dir(mod):
        if name.startswith('_'):
            continue
        obj = getattr(mod, name)
        if not callable(obj):
            continue
        # only things defined in (or re-exported as part of) this module
        defined_in = getattr(obj, '__module__', None)
        if defined_in != mod.__name__:
            continue
        out.append((name, obj))
    return out


@pytest.mark.parametrize('mod', MODULES, ids=lambda m: m.__name__)
def test_module_docstring_present(mod):
    assert mod.__doc__ and len(mod.__doc__.strip()) > 80, mod.__name__


@pytest.mark.parametrize('mod', MODULES, ids=lambda m: m.__name__)
def test_every_public_entry_point_documented(mod):
    undocumented = [name for name, obj in _public_callables(mod)
                    if not (getattr(obj, '__doc__', None)
                            and len(obj.__doc__.strip()) > 40)]
    assert not undocumented, (
        f'{mod.__name__}: public entry points missing a substantive '
        f'docstring: {undocumented}')


@pytest.mark.parametrize('fn', list(CONTRACTS), ids=lambda f: f.__name__)
def test_numerics_contract_stated(fn):
    needle = CONTRACTS[fn]
    doc = fn.__doc__ or ''
    assert needle.lower() in doc.lower(), (
        f'{fn.__name__} docstring must state its numerics contract '
        f'(expected to mention {needle!r})')
