"""Fixed-point quantization properties (hypothesis) — contribution C2."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import quant


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-3.9, 3.9, allow_nan=False), min_size=1, max_size=64))
def test_quantize_roundtrip_error_bounded(vals):
    """|dequant(quant(x)) - x| <= scale/2 inside the representable range."""
    x = jnp.array(vals, jnp.float32)
    fmt = quant.STATE_FMT
    err = np.abs(np.asarray(quant.dequantize(quant.quantize(x, fmt), fmt) - x))
    assert (err <= fmt.scale / 2 + 1e-7).all()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2, max_size=32))
def test_quantize_monotone(vals):
    """Quantization preserves ordering (monotone non-decreasing)."""
    x = jnp.sort(jnp.array(vals, jnp.float32))
    q = np.asarray(quant.quantize(x, quant.STATE_FMT), np.int32)
    assert (np.diff(q) >= 0).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_int8_matmul_matches_float(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (4, 32))
    w = jax.random.normal(k2, (32, 16))
    xs, ws = quant.abs_max_scale(x), quant.abs_max_scale(w, axis=0)
    out = quant.int8_matmul(quant.quantize_scaled(x, xs),
                            quant.quantize_scaled(w, ws), xs, ws)
    ref = x @ w
    # int8 x int8 error: bounded relative to the operand magnitudes.
    tol = 32 * float(xs) * float(np.max(np.asarray(ws))) * 130
    assert float(jnp.max(jnp.abs(out - ref))) < tol


def test_fake_quant_straight_through_gradient():
    x = jnp.array([0.1, 3.0, -5.0])  # -5 is out of Q2.5 range -> grad masked
    g = jax.grad(lambda v: quant.fake_quant(v, quant.STATE_FMT).sum())(x)
    np.testing.assert_allclose(g, [1.0, 1.0, 0.0])


def test_lut_matches_quantized_activation():
    """The 256-entry LUT equals quantize(sigmoid(dequant(code))) for every code."""
    fmt, out_fmt = quant.STATE_FMT, quant.GATE_FMT
    lut = quant.build_act_lut(lambda z: 1 / (1 + np.exp(-z)), fmt, out_fmt)
    codes = jnp.arange(-128, 128, dtype=jnp.int8)
    got = quant.apply_lut(jnp.asarray(lut), codes, fmt)
    want = quant.quantize(jax.nn.sigmoid(quant.dequantize(codes, fmt)), out_fmt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_saturating_add():
    a = jnp.array([32760, -32760, 100], jnp.int32)
    b = jnp.array([100, -100, 200], jnp.int32)
    out = np.asarray(quant.saturating_add_int16(a, b))
    np.testing.assert_array_equal(out, [32767, -32768, 300])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_requantize_shift_matches_float_rescale(seed):
    rng = np.random.RandomState(seed)
    acc_fmt = quant.QFormat(5, 10)
    out_fmt = quant.STATE_FMT
    acc = jnp.asarray(rng.randint(-30000, 30000, size=(32,)), jnp.int32)
    got = quant.requantize(acc, acc_fmt, out_fmt)
    want = np.clip(np.round(np.asarray(acc) * acc_fmt.scale / out_fmt.scale
                            + 1e-9), -128, 127)  # round-half-up semantics
    # Allow off-by-one on exact .5 ties (hardware rounds half-up).
    assert (np.abs(np.asarray(got) - want) <= 1).all()
