"""Run a JAX snippet in a subprocess with a forced host device count.

Multi-device tests must not set XLA_FLAGS in this process (smoke tests and
benches must see 1 device), so each distributed test spawns a fresh interpreter.
"""
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = str(REPO / 'src')


def run_with_devices(snippet: str, n_devices: int, timeout: int = 600) -> str:
    env = dict(os.environ)
    env['XLA_FLAGS'] = f'--xla_force_host_platform_device_count={n_devices}'
    env['PYTHONPATH'] = SRC + os.pathsep + env.get('PYTHONPATH', '')
    proc = subprocess.run([sys.executable, '-c', snippet], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f'subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}')
    return proc.stdout
