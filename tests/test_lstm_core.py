"""Core LSTM + systolic execution: correctness against the paper's equations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lstm, quant, systolic
from _subproc import run_with_devices


def _rand_lstm(key, n_x, n_h):
    return lstm.init_lstm_params(key, n_x, n_h)


def test_lstm_cell_matches_equations():
    """Check Eqs. (1)-(5) element by element against a numpy transcription."""
    key = jax.random.PRNGKey(0)
    n_x, n_h, B = 5, 7, 3
    p = _rand_lstm(key, n_x, n_h)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (B, n_x)))
    h0 = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (B, n_h))) * 0.3
    c0 = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (B, n_h))) * 0.3

    w_x, w_h, w_p, b = map(np.asarray, p)
    sig = lambda z: 1 / (1 + np.exp(-z))
    pre = np.einsum('ghx,bx->bgh', w_x, x) + np.einsum('ghk,bk->bgh', w_h, h0)
    i = sig(pre[:, 0] + w_p[0] * c0 + b[0])
    f = sig(pre[:, 1] + w_p[1] * c0 + b[1])
    g = np.tanh(pre[:, 2] + b[2])
    c = f * c0 + i * g
    o = sig(pre[:, 3] + w_p[2] * c + b[3])
    h = o * np.tanh(c)

    h_j, c_j = lstm.lstm_cell(p, jnp.asarray(x), jnp.asarray(h0), jnp.asarray(c0))
    np.testing.assert_allclose(h_j, h, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_j, c, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize('n_x,n_h,tile', [(23, 37, 16), (96, 96, 96),
                                          (123, 421, 96), (8, 8, 8)])
def test_systolic_tiled_equals_dense(n_x, n_h, tile):
    p = _rand_lstm(jax.random.PRNGKey(0), n_x, n_h)
    xs = jax.random.normal(jax.random.PRNGKey(1), (6, 2, n_x)) * 0.5
    hs_ref, _ = lstm.lstm_layer(p, xs)
    packed = systolic.pack_lstm(p, systolic.SystolicPlan(n_x, n_h, tile))
    hs = systolic.systolic_layer_tiled(packed, xs)
    np.testing.assert_allclose(hs, hs_ref, rtol=1e-4, atol=1e-5)


def test_systolic_quantized_error_bounded():
    """8-bit storage / 16-bit accumulation path stays within a few LSBs of fp32."""
    p = _rand_lstm(jax.random.PRNGKey(0), 48, 64)
    xs = jax.random.normal(jax.random.PRNGKey(1), (12, 4, 48)) * 0.5
    hs_ref, _ = lstm.lstm_layer(p, xs)
    packed = systolic.pack_lstm(p, systolic.SystolicPlan(48, 64, 16))
    qp = systolic.quantize_packed(packed)
    hs_q = systolic.systolic_layer_quantized(qp, quant.quantize(xs, quant.STATE_FMT))
    hs = quant.dequantize(hs_q, quant.STATE_FMT)
    err = np.abs(np.asarray(hs) - np.asarray(hs_ref))
    lsb = quant.STATE_FMT.scale
    assert err.mean() < 2 * lsb, f'mean err {err.mean()} vs LSB {lsb}'
    assert err.max() < 8 * lsb, f'max err {err.max()}'


def test_quantized_is_pure_integer():
    """The quantized path must consume/produce int8 codes only (HW-faithful)."""
    p = _rand_lstm(jax.random.PRNGKey(0), 8, 8)
    packed = systolic.pack_lstm(p, systolic.SystolicPlan(8, 8, 8))
    qp = systolic.quantize_packed(packed)
    assert qp.tiles_q.dtype == jnp.int8
    assert qp.bias_q.dtype == jnp.int16
    xs_q = quant.quantize(jnp.ones((3, 2, 8)) * 0.25, quant.STATE_FMT)
    hs = systolic.systolic_layer_quantized(qp, xs_q)
    assert hs.dtype == jnp.int8


def test_systolic_shard_map_multi_device():
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.core import lstm, systolic
p = lstm.init_lstm_params(jax.random.PRNGKey(0), 23, 37)
xs = jax.random.normal(jax.random.PRNGKey(1), (7, 4, 23)) * 0.5
hs_ref, _ = lstm.lstm_layer(p, xs)
plan = systolic.SystolicPlan(23, 37, tile=16)
packed = systolic.shard_packed_lstm(
    systolic.pack_lstm(p, plan), systolic.make_systolic_mesh(plan.rows, plan.cols))
xs_pad = jnp.zeros((7, 4, plan.padded_in), xs.dtype).at[..., :23].set(xs)
hs = systolic.systolic_lstm_shard_map(
    packed, systolic.make_systolic_mesh(plan.rows, plan.cols), xs_pad)
err = float(jnp.max(jnp.abs(hs - hs_ref)))
assert err < 1e-5, err
print('OK', err)
""", n_devices=16)
    assert 'OK' in out


def test_systolic_pipeline_multi_device():
    """The paper's 3x(RxC) layer pipeline matches sequential execution."""
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.core import lstm, systolic, pipeline
keys = jax.random.split(jax.random.PRNGKey(0), 3)
layers = [lstm.init_lstm_params(keys[0], 13, 21)] + \\
         [lstm.init_lstm_params(k, 21, 21) for k in keys[1:]]
xs = jax.random.normal(jax.random.PRNGKey(1), (9, 3, 13)) * 0.5
h = xs
for lp in layers:
    h, _ = lstm.lstm_layer(lp, h)
packed, plan = pipeline.pack_pipeline(layers, tile=8)
mesh = systolic.make_systolic_mesh(plan.rows, plan.cols, stage=3)
packed = pipeline.shard_pipeline(packed, mesh)
xs_pad = jnp.zeros((9, 3, plan.padded_x), xs.dtype).at[..., :13].set(xs)
hs = pipeline.systolic_pipeline(packed, mesh, xs_pad)
err = float(jnp.max(jnp.abs(hs - h)))
assert err < 1e-5, err
print('OK', err)
""", n_devices=64)
    assert 'OK' in out


def test_plan_geometry_matches_paper():
    """CTC-3L-421H-UNI on 96-unit engines: 5 row chunks (421/96) as in Sec. 4.2."""
    plan = systolic.SystolicPlan(123, 421, 96)
    assert plan.rows == 5
    assert plan.cols_x == 2 and plan.cols_h == 5
    # 5x5 engines => 2 temporal passes per layer (paper: reconfig/multi-pass).
    import math
    passes = math.ceil(plan.rows / 5) * math.ceil(plan.cols / 5)
    assert passes == 2


def test_lstm_stack_shapes():
    params = lstm.init_lstm_stack(jax.random.PRNGKey(0), 123, 421, 3, n_out=62)
    xs = jnp.zeros((5, 2, 123))
    ys, finals = lstm.lstm_stack_apply(params, xs)
    assert ys.shape == (5, 2, 62)
    assert len(finals) == 3
    # ~3.8M weights, matching the paper's statement for CTC-3L-421H-UNI.
    n = params.num_params()
    assert 3.7e6 < n < 3.9e6, n
