"""Whole-sequence persistent LSTM kernel: interpret-mode equivalence sweeps.

The f32 path must match ``core.lstm.lstm_layer`` (same recurrence, one
kernel launch); the int8 path must be *bit-identical* to scanning
``core.systolic.systolic_cell_quantized`` (the silicon datapath).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lstm, quant, systolic
from repro.core.lstm import lstm_layer_fused, select_lstm_backend
from repro.kernels.lstm_gates import lstm_layer_fused as lstm_layer_step
from repro.kernels.lstm_seq import (lstm_layer_seq, lstm_layer_seq_quantized,
                                    lstm_seq_ref, vmem_bytes_estimate)


def _layer(key, n_x, n_h):
    return lstm.init_lstm_params(jax.random.PRNGKey(key), n_x, n_h)


# ------------------------------------------------------------------ f32 path
@pytest.mark.parametrize('n_x,n_h,T,B,bn,bk', [
    (64, 64, 4, 2, 64, 64),       # exact tiles
    (64, 128, 6, 3, 64, 128),     # mixed block sizes (lcm padding)
    (100, 150, 5, 3, 64, 64),     # ragged everything
    (123, 421, 3, 2, 128, 128),   # the paper's CTC layer width
    (32, 32, 1, 1, 32, 32),       # T=1, B=1 degenerate
])
def test_seq_matches_core_layer(n_x, n_h, T, B, bn, bk):
    p = _layer(n_x + n_h, n_x, n_h)
    xs = jax.random.normal(jax.random.PRNGKey(1), (T, B, n_x)) * 0.5
    hs_ref, (hT_ref, cT_ref) = lstm.lstm_layer(p, xs)
    hs, (h_T, c_T) = lstm_layer_seq(p, xs, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(hs, hs_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h_T, hT_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_T, cT_ref, rtol=1e-5, atol=1e-6)


def test_seq_nonzero_initial_state():
    p = _layer(0, 48, 80)
    xs = jax.random.normal(jax.random.PRNGKey(1), (7, 4, 48)) * 0.5
    h0 = jax.random.normal(jax.random.PRNGKey(2), (4, 80)) * 0.3
    c0 = jax.random.normal(jax.random.PRNGKey(3), (4, 80)) * 0.3
    hs_ref, (hT_ref, cT_ref) = lstm.lstm_layer(p, xs, h0, c0)
    hs, (h_T, c_T) = lstm_layer_seq(p, xs, h0, c0, bn=64, bk=64,
                                    interpret=True)
    np.testing.assert_allclose(hs, hs_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_T, cT_ref, rtol=1e-5, atol=1e-6)


def test_seq_ref_oracle_matches_core():
    p = _layer(7, 11, 13)
    xs = jax.random.normal(jax.random.PRNGKey(1), (6, 5, 11))
    pre_x = jnp.einsum('ghx,tbx->tbgh', p.w_x, xs)
    h0 = c0 = jnp.zeros((5, 13))
    hs_r, cs_r = lstm_seq_ref(p.w_h, p.w_peep, p.b, pre_x, h0, c0)
    hs_c, (_, c_T) = lstm.lstm_layer(p, xs)
    np.testing.assert_allclose(hs_r, hs_c, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(cs_r[-1], c_T, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize('backend', ['pallas_seq', 'pallas_step'])
def test_pallas_vjp_matches_scan_vjp(backend):
    """Both kernel VJPs (gate recompute) == the hand-written scan VJP —
    training must work whichever backend auto-selection picks."""
    p = _layer(9, 32, 32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 2, 32)) * 0.5

    def loss(params, be):
        hs, (h_T, c_T) = lstm_layer_fused(params, xs, backend=be)
        return jnp.sum(hs ** 2) + jnp.sum(h_T * c_T)

    g_ref = jax.grad(lambda q: loss(q, 'xla_scan'))(p)
    g_ker = jax.grad(lambda q: loss(q, backend))(p)
    for name, a, b in zip(p._fields, g_ref, g_ker):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5, err_msg=name)


# ------------------------------------------------------------------ int8 path
@pytest.mark.parametrize('n_x,n_h,tile,T,B', [
    (48, 64, 16, 12, 4),
    (23, 37, 16, 5, 2),      # ragged vs tile
    (96, 96, 96, 3, 2),      # single engine column/row pair
])
def test_seq_quantized_bit_identical(n_x, n_h, tile, T, B):
    p = _layer(n_x * 31 + n_h, n_x, n_h)
    xs = jax.random.normal(jax.random.PRNGKey(1), (T, B, n_x)) * 0.5
    qp = systolic.quantize_packed(
        systolic.pack_lstm(p, systolic.SystolicPlan(n_x, n_h, tile)))
    xs_q = quant.quantize(xs, quant.STATE_FMT)
    hs_ref = systolic.systolic_layer_quantized(qp, xs_q)
    hs = lstm_layer_seq_quantized(qp, xs_q, interpret=True)
    assert hs.dtype == jnp.int8
    assert bool(jnp.all(hs == hs_ref)), 'int8 sequence kernel diverged from ' \
        'the bit-accurate systolic scan'


# ------------------------------------------------- per-step kernel (hoisted)
def test_step_layer_hoisted_matches_core():
    p = _layer(3, 100, 150)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 3, 100)) * 0.5
    hs_ref, (hT_ref, cT_ref) = lstm.lstm_layer(p, xs)
    hs, (h_T, c_T) = lstm_layer_step(p, xs, bn=64, bk=64, interpret=True,
                                     return_state=True)
    np.testing.assert_allclose(hs, hs_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h_T, hT_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_T, cT_ref, rtol=1e-5, atol=1e-6)


def test_step_layer_initial_state():
    p = _layer(4, 40, 56)
    xs = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 40)) * 0.5
    h0 = jax.random.normal(jax.random.PRNGKey(2), (2, 56)) * 0.3
    c0 = jax.random.normal(jax.random.PRNGKey(3), (2, 56)) * 0.3
    hs_ref, _ = lstm.lstm_layer(p, xs, h0, c0)
    hs = lstm_layer_step(p, xs, h0=h0, c0=c0, bn=64, bk=64, interpret=True)
    np.testing.assert_allclose(hs, hs_ref, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------ backend select
def test_backend_auto_is_xla_on_cpu():
    assert select_lstm_backend(123, 421, 128, 8, platform='cpu') == 'xla_scan'


def test_backend_auto_rules_on_tpu():
    # the paper layer fits VMEM easily -> sequence kernel
    assert select_lstm_backend(123, 421, 128, 8, platform='tpu') == 'pallas_seq'
    # short sequences don't amortise residency -> per-step kernel
    assert select_lstm_backend(123, 421, 2, 8, platform='tpu') == 'pallas_step'
    # a hidden width whose resident weights blow VMEM -> never pallas_seq
    big = select_lstm_backend(1024, 4096, 128, 8, platform='tpu')
    assert big != 'pallas_seq'
    assert vmem_bytes_estimate(4096, 8) > 12 * 1024 * 1024


def test_all_backends_agree_forward():
    p = _layer(11, 64, 64)
    xs = jax.random.normal(jax.random.PRNGKey(1), (6, 2, 64)) * 0.5
    hs_scan, _ = lstm_layer_fused(p, xs, backend='xla_scan')
    hs_step, _ = lstm_layer_fused(p, xs, backend='pallas_step')
    hs_seq, _ = lstm_layer_fused(p, xs, backend='pallas_seq')
    np.testing.assert_allclose(hs_step, hs_scan, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(hs_seq, hs_scan, rtol=1e-5, atol=1e-6)
