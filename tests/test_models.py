"""Per-architecture smoke tests + train/decode consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_bundle
from repro.models.recurrent import mlstm_chunkwise


def _smoke_batch(cfg, key, B=2, S=16):
    if cfg.family == 'lstm':
        return {'frames': jax.random.normal(key, (B, S, cfg.lstm_inputs)) * 0.3,
                'labels': jax.random.randint(key, (B, 4), 1, cfg.n_outputs),
                'frame_len': jnp.full((B,), S), 'label_len': jnp.full((B,), 4)}
    ks = jax.random.split(key, 3)
    batch = {'tokens': jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
             'labels': jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.family in ('audio', 'vlm'):
        batch['source'] = jax.random.normal(
            ks[2], (B, cfg.n_source_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize('name', list(configs.ARCH_MODULES))
def test_arch_smoke_forward_and_grad(name):
    """Reduced config: one forward + one grad step; shapes + finiteness."""
    cfg = configs.get_smoke_config(name)
    bundle = get_bundle(cfg)
    params, axes = bundle.init(jax.random.PRNGKey(0))
    # param/axes trees must be congruent (needed for sharded placement)
    assert (jax.tree.structure(params)
            == jax.tree.structure(axes, is_leaf=lambda v: isinstance(v, tuple)
                                  and all(x is None or isinstance(x, str) for x in v)))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(lambda p: bundle.loss_fn(p, batch))(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()
    # logits shape
    logits = bundle.forward(params, batch)
    B = batch['frames'].shape[0] if cfg.family == 'lstm' else batch['tokens'].shape[0]
    if cfg.family == 'lstm':
        assert logits.shape == (16, B, cfg.n_outputs)
    else:
        assert logits.shape == (B, 16, cfg.vocab_size)


@pytest.mark.parametrize('name', list(configs.ARCH_MODULES))
def test_arch_loss_decreases(name):
    """Three SGD steps on a fixed batch must reduce the loss (trainability)."""
    cfg = configs.get_smoke_config(name)
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    lr = 0.5 if cfg.family == 'lstm' else 0.05

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: bundle.loss_fn(q, batch))(p)
        return loss, jax.tree.map(lambda a, b: a - lr * b.astype(a.dtype), p, g)

    first, params2 = step(params)
    losses = [float(first)]
    for _ in range(3):
        l, params2 = step(params2)
        losses.append(float(l))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize('name', ['qwen3-14b', 'mixtral-8x22b'])
def test_decode_matches_forward(name):
    """Token-by-token decode replays the full-sequence forward exactly.

    MoE uses a no-drop capacity factor here: with the production factor the
    full-sequence pass may drop tokens at expert capacity while the 1-token
    decode pass never does — a documented property of capacity-based routing,
    not an inconsistency.
    """
    cfg = configs.get_smoke_config(name).replace(activation_dtype='float32')
    if cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    B, T = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    full = bundle.forward(params, {'tokens': tokens})          # (B,T,V)
    cache, _ = bundle.init_cache(B, T)
    outs = []
    for t in range(T):
        logits, cache = bundle.decode_step(params, cache, tokens[:, t:t + 1],
                                           jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32), rtol=2e-3, atol=2e-3)


def test_xlstm_decode_matches_forward():
    cfg = configs.get_smoke_config('xlstm-1.3b').replace(activation_dtype='float32')
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    B, T = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    full = bundle.forward(params, {'tokens': tokens})
    state, _ = bundle.init_cache(B, T)
    outs = []
    for t in range(T):
        logits, state = bundle.decode_step(params, state, tokens[:, t:t + 1],
                                           jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32), rtol=2e-3, atol=2e-3)


def test_whisper_decode_with_cross_attention():
    from repro.models import transformer
    cfg = configs.get_smoke_config('whisper-base').replace(activation_dtype='float32')
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    B, T = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    source = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.n_source_tokens, cfg.d_model))
    full = bundle.forward(params, {'tokens': tokens, 'source': source})
    cache, _ = bundle.init_cache(B, T)
    cross_kv = transformer.precompute_cross_kv(cfg, params, source)
    outs = []
    for t in range(T):
        logits, cache = transformer.decode_step(
            cfg, params, cache, tokens[:, t:t + 1], jnp.int32(t),
            cross_kv=cross_kv)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32), rtol=2e-3, atol=2e-3)


def test_hymba_decode_runs_and_is_finite():
    """Hymba decode (heterogeneous per-layer caches: ring SWA + global + SSM)."""
    cfg = configs.get_smoke_config('hymba-1.5b')
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    B = 2
    cache, _ = bundle.init_cache(B, 64)
    tok = jnp.ones((B, 1), jnp.int32)
    for t in range(4):
        logits, cache = bundle.decode_step(params, cache, tok, jnp.int32(t))
        assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert logits.shape == (B, 1, cfg.vocab_size)


def test_mlstm_chunkwise_matches_recurrent_oracle():
    """The chunkwise-parallel mLSTM == step-by-step stabilised recurrence."""
    def ref(q, k, v, lf, li):
        b, h, s, dh = q.shape
        C = np.zeros((b, h, dh, dh)); n = np.zeros((b, h, dh))
        m = np.full((b, h), -1e30)
        q, k, v, lf, li = map(np.asarray, (q, k, v, lf, li))
        ys = []
        for t in range(s):
            m_new = np.maximum(lf[..., t] + m, li[..., t])
            fw, iw = np.exp(lf[..., t] + m - m_new), np.exp(li[..., t] - m_new)
            C = C * fw[..., None, None] + iw[..., None, None] * np.einsum(
                'bhd,bhe->bhde', k[..., t, :], v[..., t, :])
            n = n * fw[..., None] + iw[..., None] * k[..., t, :]
            m = m_new
            num = np.einsum('bhd,bhde->bhe', q[..., t, :], C)
            den = np.maximum(np.abs(np.einsum('bhd,bhd->bh', q[..., t, :], n)),
                             np.exp(-m))
            ys.append(num / den[..., None])
        return np.stack(ys, axis=2)

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    b, h, s, dh = 2, 3, 32, 8
    q = jax.random.normal(ks[0], (b, h, s, dh))
    k = jax.random.normal(ks[1], (b, h, s, dh)) * dh ** -0.5
    v = jax.random.normal(ks[2], (b, h, s, dh))
    li = jax.random.normal(ks[3], (b, h, s)) * 2.0
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, s)) * 2 + 2)
    want = ref(q, k, v, lf, li)
    for chunk in (4, 8, 16, 32):
        y, _ = mlstm_chunkwise(q, k, v, lf, li, chunk)
        np.testing.assert_allclose(np.asarray(y), want, rtol=1e-3, atol=1e-4)


def test_mlstm_chunkwise_state_carry():
    """Decode continuity: two half-sequences with carried state == one pass."""
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    b, h, s, dh = 1, 2, 16, 8
    q = jax.random.normal(ks[0], (b, h, s, dh))
    k = jax.random.normal(ks[1], (b, h, s, dh)) * dh ** -0.5
    v = jax.random.normal(ks[2], (b, h, s, dh))
    li = jax.random.normal(ks[3], (b, h, s))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, s)) + 2)
    y_full, _ = mlstm_chunkwise(q, k, v, lf, li, 8)
    y1, st = mlstm_chunkwise(q[:, :, :8], k[:, :, :8], v[:, :, :8],
                             lf[..., :8], li[..., :8], 8)
    y2, _ = mlstm_chunkwise(q[:, :, 8:], k[:, :, 8:], v[:, :, 8:],
                            lf[..., 8:], li[..., 8:], 8, state=st)
    got = jnp.concatenate([y1, y2], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=1e-4, atol=1e-5)


def test_moe_routes_to_multiple_experts():
    """Property: with random inputs, >1 expert receives tokens and the MoE
    output differs from any single-expert output (routing is effective)."""
    from repro.models import layers as L
    cfg = configs.get_smoke_config('mixtral-8x22b')
    gen = L.keygen(jax.random.PRNGKey(0))
    p, _ = L.init_moe(cfg, gen, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = L.moe_block(cfg, p, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    logits = x.reshape(-1, cfg.d_model) @ p['router']
    top1 = np.asarray(jnp.argmax(logits, -1))
    assert len(np.unique(top1)) > 1
