"""The measured-schedule autotuner's contracts (DESIGN.md §12).

Three pins: (1) the cache replays deterministically — canonical JSON round-
trips byte-for-byte and predicted winners are re-derivable from a fresh
enumeration; (2) a cache hit is dispatch-only — it can flip WHICH schedule
runs (the previously hand-calibrated ``_Q_FUSED_MIN_NH`` decision, the
staged ``Tc`` / in-stage order) but never the numerics; (3) admission stays
authoritative — a cache can never force an inadmissible launch.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import lstm
from repro.core import perf_model as pm
from repro.tune import (ANY_MESH, ScheduleCache, ScheduleEntry, ShmooRecord,
                        clear_schedule_cache, current_schedule_cache,
                        enumerate_staged_candidates, install_schedule_cache,
                        mesh_signature, rank_staged_candidates, replay_check,
                        staged_shmoo_records, tune_quantized_backend,
                        using_schedule_cache, write_shmoo_csv)

from _subproc import run_with_devices

REPO = pathlib.Path(__file__).resolve().parent.parent


def _cache(*entries):
    return ScheduleCache(entries)


# ------------------------------------------------------------ cache basics
def test_cache_roundtrip_is_byte_identical():
    c = _cache(
        ScheduleEntry(kind='q_stack_backend', n_x=96, n_h=96, n_layers=3,
                      backend='fused', source='measured', measured_us=1.5),
        ScheduleEntry(kind='stack_f32', n_x=123, n_h=421, n_layers=3, T=128,
                      B=8, mesh='stage:2,row:5,col:5', tc=16,
                      in_stage='sequential', source='measured'))
    j1 = c.to_json()
    c2 = ScheduleCache.from_json(j1)
    assert c2.to_json() == j1
    # canonical: entries sorted by key, keys sorted inside each entry
    doc = json.loads(j1)
    assert doc['version'] == 1 and len(doc['entries']) == 2
    assert j1 == ScheduleCache(reversed(c.entries())).to_json()


def test_lookup_precedence_exact_beats_wildcards():
    sig = 'stage:2,row:5,col:5'
    c = _cache(
        ScheduleEntry(kind='stack_f32', n_x=1, n_h=2, n_layers=3, T=0, B=0,
                      mesh=ANY_MESH, tc=4),
        ScheduleEntry(kind='stack_f32', n_x=1, n_h=2, n_layers=3, T=0, B=0,
                      mesh=sig, tc=8),
        ScheduleEntry(kind='stack_f32', n_x=1, n_h=2, n_layers=3, T=128,
                      B=0, mesh=sig, tc=16),
        ScheduleEntry(kind='stack_f32', n_x=1, n_h=2, n_layers=3, T=128,
                      B=8, mesh=sig, tc=32))
    q = dict(n_x=1, n_h=2, n_layers=3)
    assert c.lookup('stack_f32', T=128, B=8, mesh=sig, **q).tc == 32
    assert c.lookup('stack_f32', T=128, B=9, mesh=sig, **q).tc == 16
    assert c.lookup('stack_f32', T=64, B=8, mesh=sig, **q).tc == 8
    assert c.lookup('stack_f32', T=64, B=8, mesh='other', **q).tc == 4
    assert c.lookup('stack_f32', T=64, B=8, **q).tc == 4
    assert c.lookup('stack_int8', T=128, B=8, mesh=sig, **q) is None


def test_mesh_signature_forms():
    assert mesh_signature(None) == ANY_MESH
    assert mesh_signature('stage:2,row:5,col:5') == 'stage:2,row:5,col:5'


def test_registry_install_current_clear_and_scoped():
    clear_schedule_cache()
    assert current_schedule_cache() is None
    c = _cache()
    with using_schedule_cache(c) as got:
        assert got is c and current_schedule_cache() is c
    assert current_schedule_cache() is None


# ----------------------------------------- dispatch is cache-first (pinned)
def test_q_fused_min_nh_decision_is_cache_driven():
    """The previously hand-calibrated ``_Q_FUSED_MIN_NH=256`` decision: at
    96 hidden the constant says layerwise; a measured cache entry flips it
    to fused — and removing the cache restores the constant fallback."""
    assert lstm.select_quantized_stack_backend(96, 3, 32, 4) == 'layerwise'
    c = _cache(ScheduleEntry(kind='q_stack_backend', n_x=96, n_h=96,
                             n_layers=3, backend='fused', source='measured'))
    with using_schedule_cache(c):
        assert lstm.select_quantized_stack_backend(96, 3, 32, 4) == 'fused'
        # the constant is still the fallback on a key miss
        assert lstm.select_quantized_stack_backend(512, 3, 32, 4) == 'fused'
        assert (lstm.select_quantized_stack_backend(128, 3, 32, 4)
                == 'layerwise')
    assert lstm.select_quantized_stack_backend(96, 3, 32, 4) == 'layerwise'


def test_q_structural_guards_not_overridable():
    """Layer/sequence floors are correctness-of-purpose gates (nothing to
    pipeline / amortise), not preferences — a cache cannot bypass them."""
    c = _cache(ScheduleEntry(kind='q_stack_backend', n_x=96, n_h=96,
                             n_layers=1, backend='fused'),
               ScheduleEntry(kind='q_stack_backend', n_x=96, n_h=96,
                             n_layers=3, T=2, backend='fused'))
    with using_schedule_cache(c):
        assert lstm.select_quantized_stack_backend(96, 1, 32, 4) == 'layerwise'
        assert lstm.select_quantized_stack_backend(96, 3, 2, 4) == 'layerwise'


def test_stack_backend_cache_respects_admission():
    """A cached stack backend wins only where it is still admissible: a
    Pallas kernel entry cannot be forced onto a non-TPU platform, but
    ``xla_scan`` (admissible everywhere) is honoured."""
    args = dict(n_x=123, n_h=421, n_layers=3, T=128, batch=8)
    base = lstm.select_stack_backend(platform='cpu', **args)
    c = _cache(ScheduleEntry(kind='stack_backend', n_x=123, n_h=421,
                             n_layers=3, backend='pallas_seq_fused'))
    with using_schedule_cache(c):
        assert lstm.select_stack_backend(platform='cpu', **args) == base
        assert lstm.select_stack_backend(platform='tpu', **args) \
            == 'pallas_seq_fused'
    c2 = _cache(ScheduleEntry(kind='stack_backend', n_x=123, n_h=421,
                              n_layers=3, backend='xla_scan'))
    with using_schedule_cache(c2):
        assert lstm.select_stack_backend(platform='tpu', **args) == 'xla_scan'


def test_staged_tc_resolution_is_cache_driven():
    """``resolve_staged_chunk`` (what ``chunk=None`` uses): the hand-derived
    ``ceil(T / 4S)`` default on a miss, the cached winner on a hit —
    clamped to T, ignored when ``tc=0``."""
    from repro.core import systolic
    kw = dict(n_h=421, n_x=123, batch=8, mesh=None)
    default = systolic.resolve_staged_chunk(3, 128, 2, **kw)
    assert default == 16          # ceil(128 / (4*2))
    c = _cache(ScheduleEntry(kind='stack_f32', n_x=123, n_h=421,
                             n_layers=3, tc=4, in_stage='sequential',
                             source='measured'))
    with using_schedule_cache(c):
        assert systolic.resolve_staged_chunk(3, 128, 2, **kw) == 4
        assert systolic.resolve_staged_chunk(3, 2, 2, **kw) == 2  # clamp T
        assert systolic.resolve_staged_in_stage(3, 128, 2, **kw) \
            == 'sequential'
    assert systolic.resolve_staged_chunk(3, 128, 2, **kw) == default
    assert systolic.resolve_staged_in_stage(3, 128, 2, **kw) == 'batched'


def test_serving_chunk_ceiling_is_cache_driven():
    """The §11 chunk-size policy's ceiling consults the cache: a tuned
    staged ``Tc`` clamps how deep chunks may grow; a miss leaves the
    engine's packing width untouched (scheduling-only either way)."""
    import types

    from repro.serving.engine import tuned_chunk_ceiling
    cfg = types.SimpleNamespace(lstm_inputs=123, lstm_hidden=421, n_layers=3)
    clear_schedule_cache()
    assert tuned_chunk_ceiling(cfg, 16, 4) == 16
    c = _cache(ScheduleEntry(kind='stack_f32', n_x=123, n_h=421,
                             n_layers=3, tc=4, source='measured'))
    with using_schedule_cache(c):
        assert tuned_chunk_ceiling(cfg, 16, 4) == 4
        assert tuned_chunk_ceiling(cfg, 2, 4) == 2      # never grows chunk
    assert tuned_chunk_ceiling(cfg, 16, 4) == 16
    # the end-to-end serving-loop measurement outranks the kernel-level
    # prediction: with both kinds present, 'serving_chunk' wins
    both = _cache(
        ScheduleEntry(kind='stack_f32', n_x=123, n_h=421, n_layers=3,
                      tc=4, source='measured'),
        ScheduleEntry(kind='serving_chunk', n_x=123, n_h=421, n_layers=3,
                      T=16, B=4, tc=8, source='measured'))
    with using_schedule_cache(both):
        assert tuned_chunk_ceiling(cfg, 16, 4) == 8
    # a tc=0 serving entry is a recorded miss: falls back to stack_f32
    degenerate = _cache(
        ScheduleEntry(kind='stack_f32', n_x=123, n_h=421, n_layers=3,
                      tc=4, source='measured'),
        ScheduleEntry(kind='serving_chunk', n_x=123, n_h=421, n_layers=3,
                      T=16, B=4, tc=0, source='measured'))
    with using_schedule_cache(degenerate):
        assert tuned_chunk_ceiling(cfg, 16, 4) == 4


# ------------------------------------------------- numerics are unchanged
def test_cache_hit_changes_no_numerics_2dev():
    """The acceptance pin: the SAME staged call with a cache forcing a
    different (Tc, in_stage) schedule produces bitwise-identical outputs —
    a hit moves chunk boundaries and round order, never arithmetic."""
    out = run_with_devices("""
import jax, numpy as np
from repro.core import lstm, systolic
from repro.tune import ScheduleCache, ScheduleEntry, using_schedule_cache
p = lstm.init_lstm_stack(jax.random.PRNGKey(0), 16, 24, 3)
xs = jax.random.normal(jax.random.PRNGKey(1), (9, 2, 16)) * 0.5
mesh = systolic.make_systolic_mesh(1, 1, stage=2)
base, _ = systolic.systolic_lstm_stack_seq(p, mesh, xs)   # cold-cache path
sig = systolic.resolve_staged_chunk(3, 9, 2, n_h=24, n_x=16, batch=2,
                                    mesh=mesh)
c = ScheduleCache([ScheduleEntry(kind='stack_f32', n_x=16, n_h=24,
                                 n_layers=3, tc=2, in_stage='sequential',
                                 mesh='stage:2,row:1,col:1',
                                 source='measured')])
with using_schedule_cache(c):
    tuned, _ = systolic.systolic_lstm_stack_seq(p, mesh, xs)
np.testing.assert_array_equal(np.asarray(base), np.asarray(tuned))
print('OK')
""", n_devices=2)
    assert 'OK' in out


# ------------------------------------------------- deterministic replay
def test_predicted_tuning_is_deterministic():
    e1, _ = tune_quantized_backend(48, 96, 3, 32, 4, measure=False)
    e2, _ = tune_quantized_backend(48, 96, 3, 32, 4, measure=False)
    assert e1 == e2
    r1 = staged_shmoo_records(48, 96, 3, 32, 4, stages=2, rows=2, cols=2)
    r2 = staged_shmoo_records(48, 96, 3, 32, 4, stages=2, rows=2, cols=2)
    assert r1 == r2 and r1, 'predicted shmoo must be reproducible'


def test_replay_check_accepts_committed_cache_and_catches_drift():
    cache = ScheduleCache.load(REPO / 'tuned_schedules.json')
    assert len(cache) >= 2
    assert replay_check(cache) >= 1
    # an out-of-space winner must be caught
    bad = ScheduleCache([ScheduleEntry(
        kind='stack_f32', n_x=48, n_h=96, n_layers=3, T=32, B=4,
        mesh='stage:2,row:2,col:2', tc=999, in_stage='batched')])
    with pytest.raises(AssertionError):
        replay_check(bad)


def test_committed_cache_drives_flagship_dispatch():
    """The committed cache's Table-2 entry (measured on the 2x(5x5) mesh)
    actually lands: resolve_staged_chunk/in_stage return its winner for
    the matching (shape, mesh signature)."""
    from repro.core import systolic
    cache = ScheduleCache.load(REPO / 'tuned_schedules.json')
    ent = cache.lookup('stack_f32', n_x=123, n_h=421, n_layers=3, T=128,
                       B=8, mesh='stage:2,row:5,col:5')
    assert ent is not None and ent.source == 'measured' and ent.tc >= 1
    assert ent.in_stage in systolic.IN_STAGE_MODES
    with using_schedule_cache(cache):
        tc = systolic.resolve_staged_chunk(
            3, 128, 2, n_h=421, n_x=123, batch=8,
            mesh='stage:2,row:5,col:5')
        mode = systolic.resolve_staged_in_stage(
            3, 128, 2, n_h=421, n_x=123, batch=8,
            mesh='stage:2,row:5,col:5')
    assert (tc, mode) == (ent.tc, ent.in_stage)


# ------------------------------------------------- shmoo space + records
def test_enumeration_prunes_and_ranks():
    cands = enumerate_staged_candidates(123, 421, 3, 128, 8, stages=2,
                                        rows=5, cols=5)
    assert cands and all(c.bn == 85 and c.bk == 85 and c.lb == 2
                         for c in cands)
    assert not enumerate_staged_candidates(123, 421, 3, 128, 8, stages=4,
                                           rows=5, cols=5)  # stages > L
    assert not enumerate_staged_candidates(    # per-device block > budget
        123, 4096, 3, 128, 8, stages=2, rows=1, cols=1, vmem_budget=1 << 20)
    ranked = rank_staged_candidates(cands, 123, 421, 3, 128)
    us = [u for _, u in ranked]
    assert us == sorted(us)
    # the model prefers the batched order on (genuinely parallel) silicon
    best_bat = min(u for c, u in ranked if c.in_stage == 'batched')
    best_seq = min(u for c, u in ranked if c.in_stage == 'sequential')
    assert best_bat < best_seq


def test_shmoo_csv_shared_format_and_ragged_rejection(tmp_path):
    recs = [ShmooRecord(suite='s', params={'a': 1}, metrics={'m': 2.0}),
            ShmooRecord(suite='s', params={'a': 2}, metrics={'m': 3.0})]
    p = write_shmoo_csv(tmp_path / 'x.csv', recs)
    lines = p.read_text().splitlines()
    assert lines[0] == 'suite,a,m' and lines[1] == 's,1,2.0000'
    with pytest.raises(ValueError):
        write_shmoo_csv(tmp_path / 'y.csv', recs + [
            ShmooRecord(suite='s', params={'b': 1}, metrics={'m': 1.0})])


def test_fig5_sweep_uses_shared_records(tmp_path):
    """The Fig. 5 voltage shmoo emits the SAME record type through the SAME
    writer as the schedule tuner — the two shmoo paths cannot drift."""
    import sys
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks.fig5_shmoo import sweep
    finally:
        sys.path.pop(0)
    recs = sweep(points=5)
    assert all(isinstance(r, ShmooRecord) for r in recs)
    p = write_shmoo_csv(tmp_path / 'fig5.csv', recs,
                        param_order=['voltage_v'],
                        metric_order=['freq_mhz', 'power_mw', 'gops',
                                      'gops_per_mw'])
    head = p.read_text().splitlines()[0]
    assert head == 'suite,voltage_v,freq_mhz,power_mw,gops,gops_per_mw'


# ------------------------------------------------- measured trial smoke
def test_measured_quantized_trial_smoke():
    """A real (tiny) interleaved trial: records both candidates, asserts
    them bit-identical before timing, and the winner is one of them."""
    ent, recs = tune_quantized_backend(8, 16, 2, 8, 2, tile=8,
                                      measure=True, iters=1, warmup=0)
    assert ent.backend in ('fused', 'layerwise')
    assert ent.source == 'measured' and ent.measured_us > 0
    assert {r.params['backend'] for r in recs} == {'fused', 'layerwise'}
