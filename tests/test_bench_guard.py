"""Bench regression guard: pure-python row-diff semantics (scripts/)."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / 'scripts'))
import bench_guard  # noqa: E402


def test_uniform_host_drift_passes():
    """A uniformly 2x slower runner is host drift, not a regression."""
    committed = {'a': 100.0, 'b': 200.0, 'c': 400.0}
    fresh = {k: v * 2.0 for k, v in committed.items()}
    failures, drift = bench_guard.diff(committed, fresh, threshold=1.5)
    assert not failures and drift == 2.0


def test_single_row_regression_fails_despite_drift():
    """One row regressing 2x relative to its siblings fails even when the
    whole suite also drifted uniformly."""
    committed = {'a': 100.0, 'b': 200.0, 'c': 400.0, 'd': 50.0}
    fresh = {'a': 150.0, 'b': 300.0, 'c': 600.0, 'd': 150.0}  # d: 3x vs 1.5x
    failures, _ = bench_guard.diff(committed, fresh, threshold=1.5)
    assert len(failures) == 1 and failures[0].startswith('d:')


def test_missing_row_fails_and_new_row_allowed():
    committed = {'a': 100.0, 'b': 200.0}
    fresh = {'a': 100.0, 'new': 1.0}
    failures, _ = bench_guard.diff(committed, fresh, threshold=1.5)
    assert len(failures) == 1 and 'missing' in failures[0]


def test_absolute_mode_skips_normalization():
    committed = {'a': 100.0, 'b': 100.0}
    fresh = {'a': 200.0, 'b': 200.0}
    failures, _ = bench_guard.diff(committed, fresh, threshold=1.5,
                                   normalize=False)
    assert len(failures) == 2
