"""Property-based serving conformance suite (DESIGN.md §11).

The async double-buffered engine must be a pure scheduling optimisation:
for EVERY admission/eviction/preemption/resume/poison/engine-failure
schedule, its per-stream outputs are bit-equal to the synchronous engine's
and to the monolithic whole-utterance forward — f32 through the packed
engine, int8 through the quantized kernels' opaque carries.  Schedules are
drawn by hypothesis (or the deterministic stub in tests/_hypothesis_stub.py)
via tests/_serving_strategies.py and replayed against both dispatch modes.

Also here: the §11 chunk-size policy unit contract, commit-time deadline
accounting under async dispatch (fake clock), the degradation-ladder
differential sweep, and the int8 stack dispatch gate pins (ROADMAP item:
fused-vs-layerwise at small shapes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

import _serving_strategies as ss
from _subproc import run_with_devices
from repro import configs
from repro.core import lstm, quant, systolic
from repro.core.lstm import (DEGRADATION_LADDER,
                             select_quantized_stack_backend)
from repro.core.perf_model import FRAME_PERIOD_S, realtime_chunk_budget_s
from repro.kernels.lstm_seq import (lstm_stack_seq_quantized,
                                    lstm_stack_seq_quantized_auto)
from repro.models import chipmunk_net
from repro.models.registry import get_bundle
from repro.runtime import ChunkSizePolicy, ServingFaultConfig
from repro.runtime.fault import FaultConfig, FaultTolerantRunner
from repro.serving import SlotScheduler, StreamingEngine

CHUNK = 4
SLOTS = 3


def _setup(backend='xla_scan'):
    cfg = configs.get_smoke_config('chipmunk-ctc').replace(
        lstm_backend=backend)
    params, _ = get_bundle(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


_CFG, _PARAMS = _setup()


def _engine(async_mode, faults=None, policy=None, cfg=None, params=None,
            chunk=CHUNK):
    return StreamingEngine(cfg or _CFG, params if params is not None
                           else _PARAMS, max_streams=SLOTS, chunk=chunk,
                           async_dispatch=async_mode, faults=faults,
                           chunk_policy=policy)


def _mono(utt, cfg=None, params=None):
    lp = chipmunk_net.forward(cfg or _CFG, params if params is not None
                              else _PARAMS, jnp.asarray(utt)[None])
    return np.asarray(jnp.moveaxis(lp, 0, 1))[0]


# ------------------------------------------------- tentpole: conformance
@pytest.mark.timeout(600)
@settings(max_examples=8, deadline=None)
@given(ss.op_schedules())
def test_async_matches_sync_on_control_op_schedules(sched):
    """Randomized priority submissions + preempt/evict/resume interleaved
    with stepping: async outputs == sync outputs, bit for bit, and both
    == the monolithic forward of each utterance."""
    utts = ss.make_utts(sched['lens'], _CFG.lstm_inputs)
    sync_out = ss.run_schedule(_engine(False), utts, sched)
    async_out = ss.run_schedule(_engine(True), utts, sched)
    ss.assert_outputs_equal(sync_out, async_out, context=str(sched))
    for i, utt in enumerate(utts):
        lp, errored = sync_out[i]
        assert not errored, (i, sched)
        np.testing.assert_array_equal(lp, _mono(utt),
                                      err_msg=f'monolithic sid={i}')


@pytest.mark.timeout(600)
@settings(max_examples=8, deadline=None)
@given(ss.fault_schedules())
def test_async_matches_sync_on_fault_schedules(sched):
    """Randomized engine-failure + slot-poison injections: both modes
    degrade/retry/quarantine identically — same surviving outputs (bit for
    bit), same quarantined streams, and the async engine squashes rather
    than leaks any speculative chunk launched across a fault."""
    utts = ss.make_utts(sched['lens'], _CFG.lstm_inputs)

    def faults():
        return ServingFaultConfig(fail_at=dict(sched['fail_at']),
                                  poison_at=dict(sched['poison_at']),
                                  backoff_s=0.0)

    sync_eng = _engine(False, faults=faults())
    async_eng = _engine(True, faults=faults())
    sync_out = ss.run_schedule(sync_eng, utts, sched)
    async_out = ss.run_schedule(async_eng, utts, sched)
    ss.assert_outputs_equal(sync_out, async_out, context=str(sched))
    s_counts = sync_eng.stats()['event_counts']
    a_counts = async_eng.stats()['event_counts']
    for kind in ('quarantine', 'poison_injected', 'fault', 'degrade',
                 'degrade_exhausted'):
        assert s_counts.get(kind, 0) == a_counts.get(kind, 0), \
            (kind, s_counts, a_counts)
    for i, utt in enumerate(utts):
        lp, errored = sync_out[i]
        if not errored and len(lp):
            np.testing.assert_array_equal(
                lp, _mono(utt)[:len(lp)], err_msg=f'monolithic sid={i}')


@pytest.mark.timeout(900)
@settings(max_examples=4, deadline=None)
@given(ss.recovery_schedules())
def test_async_matches_sync_on_recovery_schedules(sched):
    """Randomized fail -> recover -> fail schedules (§14): both dispatch
    modes replay the identical degrade / heal / canary / promote /
    reject trail (same per-kind event counts), every stream completes
    (zero stream loss), outputs are bit-equal across modes and allclose
    to the monolithic forward regardless of which rungs served which
    chunks."""
    cfg, params = _setup('pallas_seq_fused')
    utts = ss.make_utts(sched['lens'], cfg.lstm_inputs)

    def faults():
        return ServingFaultConfig(
            fail_at=dict(sched['fail_at']),
            recover_at=dict(sched['recover_at']),
            promote_hysteresis=sched['promote_hysteresis'],
            backoff_s=0.0)

    sync_eng = _engine(False, faults=faults(), cfg=cfg, params=params)
    async_eng = _engine(True, faults=faults(), cfg=cfg, params=params)
    sync_out = ss.run_schedule(sync_eng, utts, sched)
    async_out = ss.run_schedule(async_eng, utts, sched)
    ss.assert_outputs_equal(sync_out, async_out, context=str(sched))
    s_counts = sync_eng.stats()['event_counts']
    a_counts = async_eng.stats()['event_counts']
    for kind in ('fault', 'degrade', 'degrade_exhausted', 'heal',
                 'promote_canary', 'promote', 'promote_rejected'):
        assert s_counts.get(kind, 0) == a_counts.get(kind, 0), \
            (kind, s_counts, a_counts, sched)
    assert sync_eng.stats()['rung'] == async_eng.stats()['rung']
    for i, utt in enumerate(utts):
        lp, errored = sync_out[i]
        assert not errored, (i, sched)
        np.testing.assert_allclose(lp, _mono(utt, cfg=cfg, params=params),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f'monolithic sid={i}')


@pytest.mark.timeout(600)
@settings(max_examples=5, deadline=None)
@given(ss.op_schedules(max_ops=2))
def test_async_matches_sync_with_chunk_policy(sched):
    """The chunk-size policy moves chunk boundaries (here: deterministic
    step-downs under an infinite budget, identical in both modes); the §7
    masking contract keeps every stream's outputs bit-invariant to it."""
    utts = ss.make_utts(sched['lens'], _CFG.lstm_inputs)
    mk = lambda: ChunkSizePolicy(chunk_max=CHUNK, slack=1e9, patience=2)
    sync_out = ss.run_schedule(_engine(False, policy=mk()), utts, sched)
    async_out = ss.run_schedule(_engine(True, policy=mk()), utts, sched)
    ss.assert_outputs_equal(sync_out, async_out, context=str(sched))
    for i, utt in enumerate(utts):
        np.testing.assert_array_equal(sync_out[i][0], _mono(utt))


def test_async_preempt_resume_checkpoint_roundtrip(tmp_path):
    """Control-plane barrier: preempting mid-flight under async dispatch
    commits the in-flight chunk first, so the checkpointed rows + cursor
    resume bit-equal — including across a fresh engine via the on-disk
    checkpoint."""
    faults = ServingFaultConfig(checkpoint_dir=str(tmp_path), backoff_s=0.0)
    utt = ss.make_utts([22], _CFG.lstm_inputs)[0]
    eng = _engine(True, faults=faults)
    eng.submit(utt, sid=0)
    eng.step()
    eng.step()                       # chunk 0 committed, chunk 1 in flight
    assert eng._pending is not None
    eng.preempt(0, requeue=False)    # barrier: commits chunk 1, snapshots
    assert eng._pending is None

    fresh = _engine(True, faults=ServingFaultConfig(
        checkpoint_dir=str(tmp_path), backoff_s=0.0))
    sess = fresh.resume_from_checkpoint(utt, sid=0)
    cursor = sess.cursor
    assert cursor == 8, 'preempt must have committed BOTH in-flight chunks'
    fresh.run()
    # the resumed stream emits the uninterrupted run's suffix, bit-equal
    np.testing.assert_array_equal(sess.full_log_probs(),
                                  _mono(utt)[cursor:])


def test_async_speculation_squashed_or_serialized_across_faults():
    """The two unclean-commit defenses: a SCHEDULED engine failure
    serializes (no speculative chunk is launched across it, so nothing to
    squash — the fault is handled by retry), while a quarantine the
    speculation could not see SQUASHES the already-launched successor
    (recorded as a ``squash`` event).  Outputs are unaffected either way."""
    # scheduled failure -> serialized: fault handled, zero squashes
    sched = {'lens': [20, 14], 'priorities': [0, 0], 'submit_at': [0, 0],
             'ops': [], 'fail_at': {1: 1}, 'poison_at': {}}
    utts = ss.make_utts(sched['lens'], _CFG.lstm_inputs)
    eng = _engine(True, faults=ServingFaultConfig(fail_at={1: 1},
                                                  backoff_s=0.0))
    out = ss.run_schedule(eng, utts, sched)
    counts = eng.stats()['event_counts']
    assert counts.get('fault', 0) == 1 and counts.get('squash', 0) == 0, \
        counts
    for i, utt in enumerate(utts):
        np.testing.assert_array_equal(out[i][0], _mono(utt))

    # poison -> quarantine at commit -> the speculative successor squashes
    sched = {'lens': [20, 14, 17], 'priorities': [0, 0, 0],
             'submit_at': [0, 0, 0], 'ops': [], 'fail_at': {},
             'poison_at': {1: 0}}
    utts = ss.make_utts(sched['lens'], _CFG.lstm_inputs)
    eng = _engine(True, faults=ServingFaultConfig(poison_at={1: 0},
                                                  backoff_s=0.0))
    out = ss.run_schedule(eng, utts, sched)
    counts = eng.stats()['event_counts']
    assert counts.get('quarantine', 0) == 1, counts
    assert counts.get('squash', 0) >= 1, counts
    assert out[0][1], 'poisoned stream must be quarantined'
    for i in (1, 2):
        assert not out[i][1]
        np.testing.assert_array_equal(out[i][0], _mono(utts[i]))


# --------------------------------------------------- int8 opaque carries
def _quantized_stack(n_x=16, n_h=16, L=2, tile=16, key=5):
    stack = lstm.init_lstm_stack(jax.random.PRNGKey(key), n_x, n_h, L,
                                 n_out=None)
    return [systolic.quantize_packed(systolic.pack_lstm(
        lp, systolic.SystolicPlan(n_x if l == 0 else n_h, n_h, tile)))
        for l, lp in enumerate(stack.layers)]


_QPS = _quantized_stack()


@pytest.mark.timeout(600)
@settings(max_examples=6, deadline=None)
@given(ss.fault_schedules())
def test_int8_opaque_carry_chunk_schedules_bit_identical(sched):
    """Int8 conformance: the schedule's utterance lengths drive randomized
    chunk boundaries with save/restore of the opaque ``(h_q, c_q)`` carries
    (a host numpy round-trip per boundary — the preempt/resume path) through
    the quantized stack kernels; the emitted codes are bit-identical to the
    monolithic call, on the fused wavefront AND the layerwise chain."""
    lens = sched['lens'][:3]
    B = len(lens)
    T = max(lens)
    xs = jax.random.normal(jax.random.PRNGKey(sum(lens)), (T, B, 16)) * 0.5
    xs_q = quant.quantize(xs, quant.STATE_FMT)
    vl = jnp.asarray(lens, jnp.int32)
    ref = np.asarray(lstm_stack_seq_quantized(_QPS, xs_q, valid_len=vl,
                                              interpret=True))
    # chunk plan from the schedule's fault steps (any cut points work)
    cuts = sorted({min(s, T - 1) for s in sched['fail_at']} - {0})
    bounds = [0] + cuts + [T]
    for backend in ('fused', 'layerwise'):
        st_c = None
        outs = []
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            v = jnp.asarray(np.clip(np.asarray(lens) - lo, 0, hi - lo),
                            jnp.int32)
            o, st_c = lstm_stack_seq_quantized_auto(
                _QPS, xs_q[lo:hi], state=st_c, valid_len=v,
                return_state=True, interpret=True, backend=backend)
            # preempt/resume: opaque carry round-trips through host numpy
            st_c = tuple(jnp.asarray(np.asarray(p)) for p in st_c)
            outs.append(np.asarray(o))
        hs = np.concatenate(outs)
        for b, L_v in enumerate(lens):
            np.testing.assert_array_equal(hs[:L_v, b], ref[:L_v, b],
                                          err_msg=f'{backend} b={b}')


# ------------------------------------- satellite: int8 stack dispatch gate
def test_quantized_stack_dispatch_pins():
    """The int8 stack gate pins the BENCH_kernels.json evidence: the
    measured losing shape (96 hidden) dispatches layerwise, the paper's
    421-hidden Table-2 stack dispatches fused; degenerate stacks (single
    layer, short T) always run layerwise."""
    assert select_quantized_stack_backend(96, 3, 32, 4) == 'layerwise'
    assert select_quantized_stack_backend(421, 3, 100, 8) == 'fused'
    assert select_quantized_stack_backend(512, 1, 100, 8) == 'layerwise'
    assert select_quantized_stack_backend(512, 3, 4, 8) == 'layerwise'
    # auto dispatch resolves through the gate and stays bit-identical
    xs = jax.random.normal(jax.random.PRNGKey(2), (9, 2, 16)) * 0.5
    xs_q = quant.quantize(xs, quant.STATE_FMT)
    ref = np.asarray(lstm_stack_seq_quantized(_QPS, xs_q, interpret=True))
    auto = np.asarray(lstm_stack_seq_quantized_auto(_QPS, xs_q,
                                                    interpret=True))
    np.testing.assert_array_equal(auto, ref)


# ------------------------------------------- satellite: degradation ladder
@pytest.mark.parametrize('backend', [b for b in DEGRADATION_LADDER
                                     if not b.endswith('_systolic')])
def test_ladder_backends_agree_on_same_streams(backend):
    """Differential backend sweep: every (non-mesh) DEGRADATION_LADDER rung
    serves the same random streams; outputs agree with the xla_scan
    reference to float tolerance, and each rung is self-consistent between
    async and sync dispatch (bit-equal)."""
    sched = {'lens': [13, 7, 19, 4], 'priorities': [0, 1, 0, 0],
             'submit_at': [0, 0, 1, 2], 'ops': [(2, 'preempt', 0)],
             'fail_at': {}, 'poison_at': {}}
    utts = ss.make_utts(sched['lens'], _CFG.lstm_inputs)
    cfg, params = _setup(backend)
    sync_out = ss.run_schedule(
        _engine(False, cfg=cfg, params=params), utts, sched)
    async_out = ss.run_schedule(
        _engine(True, cfg=cfg, params=params), utts, sched)
    ss.assert_outputs_equal(sync_out, async_out, context=backend)
    for i, utt in enumerate(utts):
        np.testing.assert_allclose(sync_out[i][0], _mono(utt),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f'{backend} sid={i}')


def test_ladder_systolic_rung_agrees():
    """The mesh rung of the ladder (pallas_seq_systolic) over 2 host
    devices serves the same streams as xla_scan, async == sync bit-equal,
    allclose to the single-engine reference."""
    import os
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    out = run_with_devices(
        f"import sys; sys.path.insert(0, {tests_dir!r})\n" + """
import numpy as np, jax, jax.numpy as jnp
import _serving_strategies as ss
from repro import configs
from repro.core import systolic
from repro.models import chipmunk_net
from repro.models.registry import get_bundle
from repro.serving import StreamingEngine

systolic.install_mesh(systolic.make_systolic_mesh(1, 2))
cfg = configs.get_smoke_config('chipmunk-ctc').replace(
    lstm_backend='pallas_seq_systolic')
params, _ = get_bundle(cfg).init(jax.random.PRNGKey(0))
sched = {'lens': [13, 7, 19], 'priorities': [0, 0, 0],
         'submit_at': [0, 0, 0], 'ops': [], 'fail_at': {}, 'poison_at': {}}
utts = ss.make_utts(sched['lens'], cfg.lstm_inputs)
outs = {}
for mode in (False, True):
    eng = StreamingEngine(cfg, params, max_streams=3, chunk=4,
                          async_dispatch=mode)
    outs[mode] = ss.run_schedule(eng, utts, sched)
ss.assert_outputs_equal(outs[False], outs[True], context='systolic')
for i, utt in enumerate(utts):
    lp = chipmunk_net.forward(cfg.replace(lstm_backend='xla_scan'), params,
                              jnp.asarray(utt)[None])
    mono = np.asarray(jnp.moveaxis(lp, 0, 1))[0]
    np.testing.assert_allclose(outs[False][i][0], mono,
                               rtol=1e-5, atol=1e-6)
print('OK')
""", n_devices=2)
    assert 'OK' in out


# --------------------------------------------- chunk-size policy contract
def test_chunk_policy_grows_on_miss_and_pins_floor():
    """A deadline miss doubles the chunk (amortising fixed per-chunk
    overhead) and pins a floor: the policy never returns to a size that
    already missed."""
    pol = ChunkSizePolicy(chunk_max=32, chunk_min=1, slack=1.0)
    assert pol.size == 32                      # starts fully amortised
    assert pol.budget_s(8) == realtime_chunk_budget_s(8)
    pol.size = 4                               # force a small current size
    pol.observe(4, dt=10.0)                    # way over 4*10ms
    assert pol.misses == 1 and pol.size == 8
    for _ in range(50):
        pol.observe(8, dt=0.0)                 # perfect from here on
    assert pol.size == 8, 'floor must pin the doubled size'


def test_chunk_policy_steps_down_only_when_provably_safe():
    """Step-down requires ``patience`` consecutive chunks already meeting
    the HALVED budget; observations that only meet the current budget keep
    the size."""
    pol = ChunkSizePolicy(chunk_max=16, chunk_min=2, slack=1.0, patience=3)
    half_budget = pol.budget_s(8)
    for _ in range(10):                        # meets 16's budget, not 8's
        pol.observe(16, dt=half_budget * 1.5)
    assert pol.size == 16
    for _ in range(2):
        pol.observe(16, dt=half_budget * 0.5)
    assert pol.size == 16, 'patience not yet reached'
    pol.observe(16, dt=half_budget * 0.5)
    assert pol.size == 8
    for _ in range(3 * 10):
        pol.observe(pol.size, dt=0.0)
    assert pol.size == 2, 'bounded below by chunk_min'
    assert pol.misses == 0


def test_chunk_policy_budget_is_table2_arrival_rate():
    """The policy budget is the paper's 10 ms MFCC frame-arrival contract:
    ``chunk * FRAME_PERIOD_S * slack`` exactly."""
    pol = ChunkSizePolicy(chunk_max=8, slack=2.5)
    assert pol.budget_s(5) == pytest.approx(5 * FRAME_PERIOD_S * 2.5)
    assert realtime_chunk_budget_s(5, 2.5) == pytest.approx(
        pol.budget_s(5))


# ------------------------- satellite: commit-time deadline under async
def test_deadline_charged_against_commit_not_launch(monkeypatch):
    """Fake clock: a chunk launched at t=0 whose commit resolves at t=5 is
    charged 5s of wall time even though the commit CALL itself was
    instantaneous — ``deadline_miss`` fires against launch-to-commit time
    (the arrival-rate contract), not time spent inside the resolve call."""
    from repro.runtime import fault as fault_mod
    clock = {'t': 100.0}
    monkeypatch.setattr(fault_mod.time, 'time', lambda: clock['t'])
    monkeypatch.setattr(fault_mod.time, 'sleep', lambda s: None)
    runner = FaultTolerantRunner(cfg=FaultConfig(deadline_s=None))

    t_launch = clock['t']
    clock['t'] += 5.0                      # device computed for 5s
    runner.run(0, lambda: 'x', launched_at=t_launch, deadline_s=1.0)
    assert runner.deadline_misses == 1
    miss = [e for e in runner.events if e['kind'] == 'deadline_miss'][0]
    assert miss['dt'] == pytest.approx(5.0)

    # without launched_at the same resolve is charged ~0s: no miss
    runner.run(1, lambda: 'x', deadline_s=1.0)
    assert runner.deadline_misses == 1


def test_engine_async_deadline_accounts_inflight_time(monkeypatch):
    """End to end on the engine: with async dispatch the chunk's wall time
    spans launch -> commit (one host step apart); the recorded per-chunk
    walls are launch-to-commit, not commit-call-only."""
    import repro.serving.engine as engine_mod
    real_time = engine_mod.time.time
    eng = _engine(True, faults=ServingFaultConfig(deadline_s=1e9,
                                                  backoff_s=0.0))
    utt = ss.make_utts([12], _CFG.lstm_inputs)[0]
    eng.submit(utt, sid=0)
    eng.step()                               # launch only
    t_between = real_time()
    eng.step()                               # commits chunk 0
    assert eng.chunk_walls, 'commit must record a wall time'
    rec_launch_to_commit = eng.chunk_walls[0]
    # the recorded span covers the inter-step host time, so it must be at
    # least the time that passed between the two step() calls' bracket
    assert rec_launch_to_commit >= 0
    eng.run()
    np.testing.assert_array_equal(
        eng.sched.done[0].full_log_probs(), _mono(utt))


# --------------------------------------------- scheduler priority contract
def test_scheduler_priority_admission_and_preempt_candidate():
    """Priority ordering: higher classes admit first (FIFO within a class),
    preempted items re-enter at the front of their class, and
    ``preempt_candidate`` fires only when a waiter strictly outranks the
    lowest-priority occupant of a full grid."""

    class Item:
        def __init__(self, name, priority=0):
            self.name, self.priority = name, priority

    sched = SlotScheduler(2)
    a, b = Item('a'), Item('b')
    slo = Item('slo', priority=2)
    bulk = Item('bulk')
    for it in (a, b, bulk, slo):
        sched.submit(it)
    # slo jumps the whole class-0 FIFO (a, b, bulk); class 0 keeps FIFO order
    assert [q.name for q in sched.pending] == ['slo', 'a', 'b', 'bulk']
    admitted = sched.refill()
    assert [it.name for _, it in admitted] == ['slo', 'a']
    assert sched.preempt_candidate() is None     # 'b' does not outrank 'a'
    urgent = Item('urgent', priority=3)
    sched.submit(urgent)
    cand = sched.preempt_candidate()
    assert cand is not None and sched.slots[cand].name == 'a'
    evicted = sched.evict(cand, requeue=True)
    assert evicted.name == 'a'
    # re-enters the FRONT of class 0: before 'b' and 'bulk'
    assert [q.name for q in sched.pending] == ['urgent', 'a', 'b', 'bulk']
    admitted = sched.refill()
    assert [it.name for _, it in admitted] == ['urgent']


def test_engine_priority_preempts_bulk_for_slo_stream():
    """A priority-1 stream submitted while every slot serves bulk streams
    displaces one bulk stream (preempt + checkpoint + requeue) and is
    admitted next step; every stream still completes with monolithic
    outputs (the displaced one resumes bit-equal)."""
    sched = {'lens': [24, 24, 24, 6], 'priorities': [0, 0, 0, 1],
             'submit_at': [0, 0, 0, 2], 'ops': [],
             'fail_at': {}, 'poison_at': {}}
    utts = ss.make_utts(sched['lens'], _CFG.lstm_inputs)
    for mode in (False, True):
        eng = _engine(mode)
        out = ss.run_schedule(eng, utts, sched)
        counts = eng.stats()['event_counts']
        assert counts.get('preempt', 0) >= 1, (mode, counts)
        for i, utt in enumerate(utts):
            np.testing.assert_array_equal(out[i][0], _mono(utt),
                                          err_msg=f'mode={mode} sid={i}')
        # the SLO stream must not wait for a full bulk drain
        slo_done = [e for e in eng.events if e['kind'] == 'preempt']
        assert slo_done, 'bulk stream should have been preempted'
