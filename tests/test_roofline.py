"""HLO cost model: trip-count weighting, in-place semantics, collective parse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.hlo_cost import HloCostModel
from repro.roofline import RooflineTerms


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_weighted_by_trip_count():
    def body(x, _):
        return x @ x, None

    def scanned(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def unrolled(x):
        for _ in range(10):
            x = x @ x
        return x

    x = jnp.zeros((256, 256))
    want = 2 * 256 ** 3 * 10
    for fn in (scanned, unrolled):
        cost = HloCostModel(_compile(fn, x).as_text()).entry_cost()
        assert cost.flops == pytest.approx(want, rel=0.01), fn.__name__


def test_unrolled_matches_xla_cost_analysis():
    def unrolled(x):
        for _ in range(6):
            x = jnp.tanh(x @ x)
        return x

    x = jnp.zeros((128, 128))
    c = _compile(unrolled, x)
    ours = HloCostModel(c.as_text()).entry_cost()
    xla = c.cost_analysis()
    if isinstance(xla, (list, tuple)):  # pre-0.6 jax: one dict per device
        xla = xla[0]
    assert ours.flops == pytest.approx(float(xla['flops']), rel=0.05)
    assert ours.bytes == pytest.approx(float(xla['bytes accessed']), rel=0.25)


def test_scan_stacking_not_charged_full_buffer():
    """dynamic-update-slice (scan output stacking) must be charged at slice
    granularity — the whole-buffer reading would inflate memory by O(T)."""
    T, N = 64, 128

    def scanned(x):
        def body(c, _):
            c = jnp.tanh(c)
            return c, c            # stacks (T, N, N) via in-place DUS

        _, ys = jax.lax.scan(body, x, None, length=T)
        return ys

    x = jnp.zeros((N, N))
    cost = HloCostModel(_compile(scanned, x).as_text()).entry_cost()
    buffer_bytes = T * N * N * 4
    # naive accounting would charge ~T * full-buffer = T^2 N^2 * 4
    assert cost.bytes < 10 * buffer_bytes, cost.bytes


def test_collective_parse_multidevice():
    from _subproc import run_with_devices
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.hlo_cost import HloCostModel
from repro.compat import make_mesh
mesh = make_mesh((8,), ('d',))
sh = NamedSharding(mesh, P('d'))
repl = NamedSharding(mesh, P())

def f(x):   # psum -> all-reduce
    return jax.lax.with_sharding_constraint(
        jnp.broadcast_to(x.sum(0), (64, 64)), repl)

x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
c = jax.jit(f, in_shardings=(sh,), out_shardings=repl).lower(x).compile()
cost = HloCostModel(c.as_text()).entry_cost()
total = sum(cost.coll.values())
assert total > 0, c.as_text()[:500]
print('OK', cost.coll)
""", n_devices=8)
    assert 'OK' in out


def test_roofline_terms_math():
    t = RooflineTerms(flops=197e12, hbm_bytes=819e9 / 2,
                      collective_bytes=50e9 * 2, per_collective={},
                      model_flops=197e12 / 2)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.collective_s == pytest.approx(2.0)
    assert t.bottleneck == 'collective'
    assert t.step_time_lower_bound_s == pytest.approx(2.0)
    assert t.useful_flops_fraction == pytest.approx(0.5)
    assert t.roofline_fraction == pytest.approx(0.25)
