"""Streaming engine: chunked stateful serving on the persistent LSTM kernels.

The DESIGN.md §7 contracts:

  * feeding a sequence chunk by chunk (state carried via h0/c0, ragged tails
    masked by ``valid_len``) is BIT-EQUAL to the monolithic whole-sequence
    call on the same backend code path — for the masked XLA scan, the
    persistent Pallas kernel (f32), the int8 systolic kernel (bit-identical
    codes), and the 2-device distributed scale-out;
  * a masked step is identity on the carried state, so ragged
    admission/eviction in the packed engine never perturbs neighbouring
    streams;
  * the engine's per-stream output equals the monolithic model forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from _subproc import run_with_devices
from repro import configs
from repro.core import lstm, quant, systolic
from repro.kernels.lstm_seq import lstm_layer_seq, lstm_layer_seq_quantized
from repro.models import chipmunk_net, get_bundle
from repro.serving import (IncrementalCTCDecoder, SlotScheduler,
                           StreamingEngine)


def _chunk_plan(total, chunk):
    spans = []
    lo = 0
    while lo < total:
        spans.append((lo, min(lo + chunk, total)))
        lo += chunk
    return spans


# ------------------------------------------------ chunked == monolithic
def test_chunked_equals_monolithic_xla_scan_bit_equal():
    """≥3 chunks with ragged valid lengths reproduce the monolithic masked
    scan bit for bit, and stay allclose to the canonical lstm_layer."""
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), 24, 32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (12, 3, 24)) * 0.5
    lens = np.array([12, 7, 9])
    mono, (hT_m, cT_m) = lstm.lstm_layer_chunk(
        p, xs, valid_len=jnp.asarray(lens), backend='xla_scan')
    hs_ref, _ = lstm.lstm_layer(p, xs)

    h = c = None
    outs = []
    for lo, hi in _chunk_plan(12, 4):          # 3 chunks
        vl = jnp.asarray(np.clip(lens - lo, 0, hi - lo), jnp.int32)
        o, (h, c) = lstm.lstm_layer_chunk(p, xs[lo:hi], h, c, valid_len=vl,
                                          backend='xla_scan')
        outs.append(o)
    hs = jnp.concatenate(outs)
    np.testing.assert_array_equal(np.asarray(hs), np.asarray(mono))
    np.testing.assert_array_equal(np.asarray(h), np.asarray(hT_m))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cT_m))
    for b, L in enumerate(lens):
        np.testing.assert_allclose(hs[:L, b], hs_ref[:L, b],
                                   rtol=1e-5, atol=1e-6)
        # final state == state after exactly L valid steps
        np.testing.assert_allclose(h[b], hs_ref[L - 1, b],
                                   rtol=1e-5, atol=1e-6)


def test_chunked_equals_monolithic_pallas_seq_bit_equal():
    """The persistent kernel with h0/c0 carry + valid-length mask: chunked ==
    monolithic kernel call, bit for bit (interpret mode)."""
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), 24, 32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (9, 3, 24)) * 0.5
    lens = np.array([9, 5, 7])
    mono, _ = lstm_layer_seq(p, xs, bn=64, bk=64, interpret=True)

    h = c = None
    outs = []
    for lo, hi in _chunk_plan(9, 3):           # 3 chunks
        vl = jnp.asarray(np.clip(lens - lo, 0, hi - lo), jnp.int32)
        o, (h, c) = lstm_layer_seq(p, xs[lo:hi], h, c, valid_len=vl,
                                   bn=64, bk=64, interpret=True)
        outs.append(o)
    hs = np.asarray(jnp.concatenate(outs))
    ref = np.asarray(mono)
    for b, L in enumerate(lens):
        np.testing.assert_array_equal(hs[:L, b], ref[:L, b])
        np.testing.assert_array_equal(np.asarray(h)[b], ref[L - 1, b])
        # masked tail re-emits the carried h (identity steps)
        if L < 9:
            np.testing.assert_array_equal(hs[-1, b], ref[L - 1, b])


def test_chunked_quantized_bit_identical():
    """int8 path: chunked calls with opaque (h_q, c_q) state carry and ragged
    masks are bit-identical to the monolithic silicon-datapath scan."""
    p = lstm.init_lstm_params(jax.random.PRNGKey(0), 16, 48)
    qp = systolic.quantize_packed(
        systolic.pack_lstm(p, systolic.SystolicPlan(16, 48, 16)))
    xs = jax.random.normal(jax.random.PRNGKey(1), (9, 3, 16)) * 0.5
    xs_q = quant.quantize(xs, quant.STATE_FMT)
    hs_ref = np.asarray(systolic.systolic_layer_quantized(qp, xs_q))

    lens = np.array([9, 4, 6])
    state = None
    outs = []
    for lo, hi in _chunk_plan(9, 3):           # 3 chunks
        vl = jnp.asarray(np.clip(lens - lo, 0, hi - lo), jnp.int32)
        o, state = lstm_layer_seq_quantized(
            qp, xs_q[lo:hi], state=state, valid_len=vl, return_state=True,
            interpret=True)
        outs.append(o)
    hs = np.asarray(jnp.concatenate(outs))
    for b, L in enumerate(lens):
        np.testing.assert_array_equal(hs[:L, b], hs_ref[:L, b])
        # carried h codes == codes after exactly L valid steps
        np.testing.assert_array_equal(
            np.asarray(state[0])[b, :qp.plan.n_h], hs_ref[L - 1, b])


def test_chunked_equals_monolithic_systolic_2dev():
    """The distributed scale-out backend honours the same chunking/masking
    contract on a real 2-device mesh (subprocess, forced device count)."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lstm, systolic
p = lstm.init_lstm_params(jax.random.PRNGKey(0), 23, 37)
xs = jax.random.normal(jax.random.PRNGKey(1), (8, 3, 23)) * 0.5
lens = np.array([8, 5, 3])
for rows, cols in ((1, 2), (2, 1)):
    mesh = systolic.make_systolic_mesh(rows, cols)
    mono, _ = systolic.systolic_lstm_seq(p, mesh, xs)
    h = c = None; outs = []
    for lo, hi in ((0, 3), (3, 6), (6, 8)):
        vl = jnp.asarray(np.clip(lens - lo, 0, hi - lo), jnp.int32)
        o, (h, c) = systolic.systolic_lstm_seq(p, mesh, xs[lo:hi], h, c,
                                               valid_len=vl)
        outs.append(o)
    hs = np.asarray(jnp.concatenate(outs))
    ref = np.asarray(mono)
    for b, L in enumerate(lens):
        np.testing.assert_array_equal(hs[:L, b], ref[:L, b])
        np.testing.assert_array_equal(np.asarray(h)[b], ref[L - 1, b])
print('OK')
""", n_devices=2)
    assert 'OK' in out


# --------------------------------------------------------- packed engine
def _smoke_setup():
    cfg = configs.get_smoke_config('chipmunk-ctc')
    params, _ = get_bundle(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _mono_log_probs(cfg, params, frames):
    lp = chipmunk_net.forward(cfg, params, jnp.asarray(frames)[None])
    return np.asarray(jnp.moveaxis(lp, 0, 1))[0]


def test_engine_streams_match_monolithic_forward():
    """Ragged streams served in packed chunks (state carried across ≥3
    chunks) reproduce the monolithic whole-utterance forward."""
    cfg, params = _smoke_setup()
    rng = np.random.RandomState(0)
    lens = [13, 7, 19, 4, 11]                  # 13/4 -> 4 chunks for stream 0
    utts = [rng.randn(L, cfg.lstm_inputs).astype(np.float32) * 0.5
            for L in lens]
    eng = StreamingEngine(cfg, params, max_streams=3, chunk=4)
    sessions = [eng.submit(u) for u in utts]
    eng.run()
    assert len(eng.sched.done) == len(utts)
    for sess, u in zip(sessions, utts):
        np.testing.assert_allclose(sess.full_log_probs(),
                                   _mono_log_probs(cfg, params, u),
                                   rtol=1e-5, atol=1e-6)


def test_engine_neighbours_unperturbed_by_admission_eviction():
    """A stream's outputs must not depend on what shares its batch: solo run
    vs a run with ragged neighbours admitted and evicted mid-flight."""
    cfg, params = _smoke_setup()
    rng = np.random.RandomState(1)
    probe = rng.randn(17, cfg.lstm_inputs).astype(np.float32) * 0.5

    solo = StreamingEngine(cfg, params, max_streams=3, chunk=4)
    s_solo = solo.submit(probe)
    solo.run()

    shared = StreamingEngine(cfg, params, max_streams=3, chunk=4)
    s_probe = shared.submit(probe)
    noisy = shared.submit(rng.randn(6, cfg.lstm_inputs).astype(np.float32))
    shared.submit(rng.randn(9, cfg.lstm_inputs).astype(np.float32))
    shared.step()                               # all three active
    shared.evict(noisy.sid)                     # evict a neighbour mid-flight
    shared.submit(rng.randn(5, cfg.lstm_inputs).astype(np.float32))  # refill
    shared.run()

    # same packed call shape both runs -> identical fp schedule per row
    np.testing.assert_array_equal(s_probe.full_log_probs(),
                                  s_solo.full_log_probs())
    assert len(shared.sched.done) == 3          # evicted stream not retired
    assert noisy.remaining > 0


def test_engine_slot_recycling_zeroes_state():
    """A stream admitted into a recycled slot starts from zero state: its
    output equals a fresh engine's."""
    cfg, params = _smoke_setup()
    rng = np.random.RandomState(2)
    first = rng.randn(9, cfg.lstm_inputs).astype(np.float32) * 0.5
    second = rng.randn(8, cfg.lstm_inputs).astype(np.float32) * 0.5

    eng = StreamingEngine(cfg, params, max_streams=1, chunk=4)
    eng.submit(first)
    s2 = eng.submit(second)                     # queued until slot 0 frees
    eng.run()

    fresh = StreamingEngine(cfg, params, max_streams=1, chunk=4)
    s2_fresh = fresh.submit(second)
    fresh.run()
    np.testing.assert_array_equal(s2.full_log_probs(),
                                  s2_fresh.full_log_probs())


def test_incremental_ctc_equals_monolithic_decode():
    """Chunked incremental emission == core.ctc.ctc_greedy_decode."""
    from repro.core import ctc
    rng = np.random.RandomState(3)
    lp = rng.randn(23, 7).astype(np.float32)
    ref, ref_len = ctc.ctc_greedy_decode(jnp.asarray(lp)[:, None, :])
    ref_syms = np.asarray(ref[0][:int(ref_len[0])]).tolist()
    dec = IncrementalCTCDecoder()
    for lo, hi in _chunk_plan(23, 5):
        dec.feed(lp[lo:hi])
    assert dec.symbols == ref_syms


# ------------------------------------------------------- scheduler / serve
def test_slot_scheduler_admission_order_and_eviction():
    sched = SlotScheduler(2)
    for item in 'abc':
        sched.submit(item)
    admitted = sched.refill()
    assert admitted == [(0, 'a'), (1, 'b')] and sched.busy
    assert sched.evict(0) == 'a' and sched.done == []
    assert sched.refill() == [(0, 'c')]
    sched.finish(0)
    sched.finish(1)
    assert [x for x in sched.done] == ['c', 'b'] and not sched.busy


def test_serve_request_prefill_is_declared_field():
    """The prefill queue is a declared dataclass field, not monkey-patched."""
    from repro.launch.serve import Request
    names = {f.name for f in dataclasses.fields(Request)}
    assert '_prefill_left' in names
    assert Request(rid=0, prompt=[1, 2])._prefill_left == []


def test_stream_forward_single_frame_matches_cell():
    """stream_forward's one-frame case (the registry decode_step) matches
    stepping lstm_cell — the old stream_step contract."""
    cfg, params = _smoke_setup()
    rng = np.random.RandomState(4)
    frames = rng.randn(2, 6, cfg.lstm_inputs).astype(np.float32) * 0.5
    states, _ = chipmunk_net.init_state(cfg, 2)
    outs = []
    for t in range(6):
        lp, states = chipmunk_net.stream_forward(
            cfg, params, states, jnp.asarray(frames[:, t:t + 1]))
        outs.append(np.asarray(lp)[:, 0])
    got = np.stack(outs, axis=1)                       # (B, T, K)
    ref = np.asarray(jnp.moveaxis(
        chipmunk_net.forward(cfg, params, jnp.asarray(frames)), 0, 1))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
