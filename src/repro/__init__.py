"""repro: Chipmunk (systolically-scalable RNN acceleration) as a JAX framework."""
__version__ = '0.1.0'
