"""Packed multi-stream stateful streaming engine (DESIGN.md §7).

The deployment story of the paper — weights stay resident while audio frames
stream through — turned into a serving substrate: every active stream's
``(h, c)`` LSTM state lives in one packed per-layer state cache of shape
``(max_streams, N_h)``, and each engine step runs ONE batched chunked call to
the whole-sequence LSTM path (``core.lstm.lstm_stack_chunk``) for ALL active
streams.  On the ``pallas_seq`` backend the slot dimension maps onto the
batch-block (``bb``) grid of the persistent kernel, so every stream shares a
single weight DMA per chunk instead of paying one per slot (the E-PUR
amortisation); on ``pallas_seq_systolic`` the same call scales out over the
installed mesh.  Ragged streams are handled by the §7 valid-length masking
contract: a slot's padded tail steps are identity on its carried state, so
admission/eviction/refill never perturbs neighbouring streams.

Backend-agnostic by construction: the engine only speaks
``models.chipmunk_net.stream_forward``, which dispatches on
``cfg.lstm_backend`` (``xla_scan | pallas_seq | pallas_seq_fused |
pallas_seq_systolic | pallas_seq_fused_systolic`` via the installed mesh).
On ``pallas_seq_fused`` every engine step advances ALL active streams
through ALL stack layers in ONE wavefront kernel launch (DESIGN.md §8):
the per-layer slot states ride the kernel's ``(L, B, N_h)`` carries and
the ragged mask is shared by every layer, so a chunk costs one launch
total instead of one per layer.  On ``pallas_seq_fused_systolic`` the
same chunked call (same carries, same mask) runs the staged scale-out
over the installed (stage, row, col) mesh (DESIGN.md §9) — the engine's
slot states hand off across engines exactly as across chunks.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import chipmunk_net
from .scheduler import SlotScheduler
from .session import IncrementalCTCDecoder, StreamSession


class StreamingEngine:
    """Continuous streaming over a packed slot grid of recurrent state.

    One instance owns ``max_streams`` state slots; streams are admitted from
    a FIFO queue, advance ``chunk`` frames per ``step`` through one batched
    call, and are retired when their frames are exhausted.  Numerics
    contract: a stream's emitted log-probs equal the monolithic
    ``chipmunk_net.forward`` of its full utterance on the same backend
    (bit-equal on a fixed backend code path; allclose across backends),
    regardless of which streams shared its batch (tests/test_streaming.py).
    """

    def __init__(self, cfg, params, *, max_streams: int = 4, chunk: int = 16,
                 decode_ctc: bool = False):
        assert cfg.family == 'lstm', (
            'StreamingEngine serves the stateful recurrent family; token '
            'families keep the per-slot decode loop (launch/serve.py)')
        assert chunk >= 1 and max_streams >= 1
        self.cfg = cfg
        self.params = params
        self.chunk = chunk
        self.decode_ctc = decode_ctc
        self.sched: SlotScheduler[StreamSession] = SlotScheduler(max_streams)
        self.states = tuple(
            (jnp.zeros((max_streams, cfg.lstm_hidden), cfg.dtype()),
             jnp.zeros((max_streams, cfg.lstm_hidden), cfg.dtype()))
            for _ in range(cfg.n_layers))
        self._next_sid = 0
        self.chunk_walls: List[float] = []   # per-step wall times (latency)

        def fwd(params, states, frames, valid):
            return chipmunk_net.stream_forward(cfg, params, states, frames,
                                               valid_len=valid)

        self._fwd = jax.jit(fwd)

    # ------------------------------------------------------------ admission
    def submit(self, frames: np.ndarray, sid: Optional[int] = None
               ) -> StreamSession:
        """Queue an utterance ((L, n_in) host frames) for streaming."""
        frames = np.asarray(frames, np.float32)
        assert frames.ndim == 2 and frames.shape[1] == self.cfg.lstm_inputs, \
            frames.shape
        if sid is None:
            sid = self._next_sid
        self._next_sid = max(self._next_sid, sid + 1)
        dec = IncrementalCTCDecoder() if self.decode_ctc else None
        sess = StreamSession(sid=sid, frames=frames, decoder=dec,
                             t_enqueue=time.time())
        self.sched.submit(sess)
        return sess

    def _zero_slot(self, slot: int, _sess: StreamSession) -> None:
        # A recycled slot must never leak its previous occupant's state.
        self.states = jax.tree.map(
            lambda a: a.at[slot].set(0), self.states)

    def evict(self, sid: int) -> Optional[StreamSession]:
        """Abandon a stream mid-flight; its slot is freed for refill.

        Neighbouring streams are untouched — their state rows are separate
        slots of the packed cache and the freed row is zeroed on the next
        admission (``_zero_slot``).
        """
        for i, sess in self.sched.active():
            if sess.sid == sid:
                return self.sched.evict(i)
        return None

    # ------------------------------------------------------------- stepping
    def step(self) -> bool:
        """Advance every active stream by up to ``chunk`` frames.

        Admits pending streams into free slots, packs all active streams
        into ONE batched chunked call (padded slots masked out via
        ``valid_len``), scatters the valid output rows back to the sessions,
        and retires exhausted streams.  Returns False when there was nothing
        to do (the drain-loop exit condition).
        """
        self.sched.refill(self._zero_slot)
        active = self.sched.active()
        if not active:
            return False

        S, T = self.sched.num_slots, self.chunk
        frames = np.zeros((S, T, self.cfg.lstm_inputs), np.float32)
        valid = np.zeros((S,), np.int32)
        for i, sess in active:
            part = sess.next_chunk(T)
            frames[i, :len(part)] = part
            valid[i] = len(part)

        t0 = time.time()
        log_probs, self.states = self._fwd(
            self.params, self.states, jnp.asarray(frames),
            jnp.asarray(valid))
        host = np.asarray(jax.block_until_ready(log_probs))
        self.chunk_walls.append(time.time() - t0)

        for i, sess in active:
            sess.consume(host[i, :valid[i]])
            if sess.remaining == 0:
                sess.t_done = time.time()
                self.sched.finish(i)
        return True

    def run(self) -> List[StreamSession]:
        """Drain: step until every submitted stream has been served."""
        while self.sched.busy:
            self.step()
        return self.sched.done

    # ------------------------------------------------------------- metrics
    def stats(self) -> dict:
        """Throughput/latency summary over the completed streams."""
        done = self.sched.done
        frames = sum(s.length for s in done)
        lats = [s.t_done - s.t_enqueue for s in done if s.t_done]
        return {
            'streams': len(done),
            'frames': frames,
            'p50_latency_s': float(np.median(lats)) if lats else 0.0,
            'p50_chunk_s': (float(np.median(self.chunk_walls))
                            if self.chunk_walls else 0.0),
        }
