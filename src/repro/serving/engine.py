"""Packed multi-stream stateful streaming engine (DESIGN.md §7, §10).

The deployment story of the paper — weights stay resident while audio frames
stream through — turned into a serving substrate: every active stream's
``(h, c)`` LSTM state lives in one packed per-layer state cache of shape
``(max_streams, N_h)``, and each engine step runs ONE batched chunked call to
the whole-sequence LSTM path (``core.lstm.lstm_stack_chunk``) for ALL active
streams.  On the ``pallas_seq`` backend the slot dimension maps onto the
batch-block (``bb``) grid of the persistent kernel, so every stream shares a
single weight DMA per chunk instead of paying one per slot (the E-PUR
amortisation); on ``pallas_seq_systolic`` the same call scales out over the
installed mesh.  Ragged streams are handled by the §7 valid-length masking
contract: a slot's padded tail steps are identity on its carried state, so
admission/eviction/refill never perturbs neighbouring streams.

Backend-agnostic by construction: the engine only speaks
``models.chipmunk_net.stream_forward``, which dispatches on
``cfg.lstm_backend`` (``xla_scan | pallas_seq | pallas_seq_fused |
pallas_seq_systolic | pallas_seq_fused_systolic`` via the installed mesh).
On ``pallas_seq_fused`` every engine step advances ALL active streams
through ALL stack layers in ONE wavefront kernel launch (DESIGN.md §8);
on ``pallas_seq_fused_systolic`` the same chunked call runs the staged
scale-out over the installed (stage, row, col) mesh (DESIGN.md §9).

Fault tolerance (DESIGN.md §10, ``runtime/serving_faults.py``): with a
``ServingFaultConfig`` attached, every engine step is driven by the
generalized ``FaultTolerantRunner`` — injected/real engine failures degrade
the backend down ``core.lstm.DEGRADATION_LADDER`` and elastically re-place
the packed cache (no stream loss, a logged latency blip); per-chunk
deadlines derived from the paper's real-time model are watched; a fused
non-finite guard quarantines exactly the poisoned slot; and
preempted/evicted streams checkpoint their packed ``(h, c)`` rows + frame
cursor so a resubmitted stream resumes **bit-equal** to an uninterrupted
run.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import chipmunk_net
from ..runtime.fault import FaultConfig, FaultTolerantRunner
from ..runtime.serving_faults import (EngineFailure, ServingFaultConfig,
                                      StreamStateCheckpointer,
                                      elastic_replace, finite_slots)
from .scheduler import SlotScheduler
from .session import IncrementalCTCDecoder, StreamSession


class StreamingEngine:
    """Continuous streaming over a packed slot grid of recurrent state.

    One instance owns ``max_streams`` state slots; streams are admitted from
    a FIFO queue, advance ``chunk`` frames per ``step`` through one batched
    call, and are retired when their frames are exhausted.  Numerics
    contract: a stream's emitted log-probs equal the monolithic
    ``chipmunk_net.forward`` of its full utterance on the same backend
    (bit-equal on a fixed backend code path; allclose across backends),
    regardless of which streams shared its batch (tests/test_streaming.py).
    A preempted stream resumed from its checkpoint continues bit-equal to
    an uninterrupted run (tests/test_serving_faults.py).

    ``faults`` (a ``runtime.ServingFaultConfig``) opts into the §10 fault
    model: deterministic engine-failure injection + ladder degradation,
    per-chunk deadline watchdog, non-finite slot quarantine, and stream
    checkpoint/resume through ``CheckpointManager``.  Without it the engine
    behaves exactly as before (no guard, no runner — zero overhead).
    """

    def __init__(self, cfg, params, *, max_streams: int = 4, chunk: int = 16,
                 decode_ctc: bool = False,
                 faults: Optional[ServingFaultConfig] = None):
        assert cfg.family == 'lstm', (
            'StreamingEngine serves the stateful recurrent family; token '
            'families keep the per-slot decode loop (launch/serve.py)')
        assert chunk >= 1 and max_streams >= 1
        from ..core.lstm import resolve_serving_backend
        self.params = params
        self.chunk = chunk
        self.decode_ctc = decode_ctc
        # pin ONE concrete backend per engine (the §7 bit-equality contract
        # holds per backend code path; the ladder needs a known rung)
        self.backend = resolve_serving_backend(
            params, cfg.lstm_backend, chunk, max_streams)
        self.cfg = cfg.replace(lstm_backend=self.backend)
        self.sched: SlotScheduler[StreamSession] = SlotScheduler(max_streams)
        self.states = tuple(
            (jnp.zeros((max_streams, cfg.lstm_hidden), cfg.dtype()),
             jnp.zeros((max_streams, cfg.lstm_hidden), cfg.dtype()))
            for _ in range(cfg.n_layers))
        self._next_sid = 0
        self._step_idx = 0
        self.chunk_walls: List[float] = []   # per-step wall times (latency)
        self.events: List[dict] = []

        self.faults = faults
        if faults is not None:
            self._guard = faults.guard_nonfinite
            self._ckpt = (StreamStateCheckpointer(faults.checkpoint_dir)
                          if faults.checkpoint_dir else None)
            self._runner: Optional[FaultTolerantRunner] = FaultTolerantRunner(
                cfg=FaultConfig(max_retries=faults.max_retries,
                                backoff_s=faults.backoff_s,
                                deadline_s=faults.resolve_deadline_s(chunk),
                                heartbeat_path=faults.heartbeat_path),
                fail_schedule=faults.make_fail_schedule())
        else:
            self._guard = False
            self._ckpt = None
            self._runner = None
        self._build_fwd()

    def _build_fwd(self):
        """(Re)build the jitted packed chunk call for the CURRENT backend.

        Called at construction and after every ladder degradation.  The
        non-finite guard is fused into the same jit (one reduction over the
        new states, no extra dispatch); with the guard off an all-ones
        constant is returned, so the clean path's arithmetic is unchanged.
        """
        cfg, guard = self.cfg, self._guard

        def fwd(params, states, frames, valid):
            lp, new_states = chipmunk_net.stream_forward(
                cfg, params, states, frames, valid_len=valid)
            if guard:
                finite = finite_slots(new_states)
            else:
                finite = jnp.ones((frames.shape[0],), bool)
            return lp, new_states, finite

        self._fwd = jax.jit(fwd)

    # ------------------------------------------------------------ admission
    def submit(self, frames: np.ndarray, sid: Optional[int] = None
               ) -> StreamSession:
        """Queue an utterance ((L, n_in) host frames) for streaming."""
        frames = np.asarray(frames, np.float32)
        assert frames.ndim == 2 and frames.shape[1] == self.cfg.lstm_inputs, \
            frames.shape
        if sid is None:
            sid = self._next_sid
        self._next_sid = max(self._next_sid, sid + 1)
        dec = IncrementalCTCDecoder() if self.decode_ctc else None
        sess = StreamSession(sid=sid, frames=frames, decoder=dec,
                             t_enqueue=time.time())
        self.sched.submit(sess)
        return sess

    def _admit_slot(self, slot: int, sess: StreamSession) -> None:
        """Admission callback: a recycled slot must never leak its previous
        occupant's state — zero its packed rows, or, for a resumed session,
        scatter the saved per-layer ``(h, c)`` rows back in (an exact host
        round-trip, so resume is bit-equal to never having been evicted)."""
        if sess.saved_state is not None:
            self.states = tuple(
                (h.at[slot].set(jnp.asarray(rh)),
                 c.at[slot].set(jnp.asarray(rc)))
                for (h, c), (rh, rc) in zip(self.states, sess.saved_state))
            sess.saved_state = None
            self._record('resume', sid=sess.sid, slot=slot,
                         cursor=sess.cursor)
        else:
            self.states = jax.tree.map(
                lambda a: a.at[slot].set(0), self.states)

    def _snapshot_slot(self, slot: int) -> tuple:
        """Host copy of one slot's per-layer ``(h, c)`` rows — the stream's
        packed state, exactly as carried (bit-preserving numpy transfer, no
        arithmetic)."""
        return tuple((np.asarray(h[slot]), np.asarray(c[slot]))
                     for h, c in self.states)

    def preempt(self, sid: int, requeue: bool = True
                ) -> Optional[StreamSession]:
        """Preempt a stream: snapshot its packed per-layer ``(h, c)`` rows +
        frame cursor onto the session (and through the stream checkpointer
        when one is configured), free its slot, and — with ``requeue=True``
        — re-enter it at the front of the pending queue.  The resumed
        stream continues **bit-equal** to an uninterrupted run on the same
        backend (tests/test_serving_faults.py).  Returns the session, or
        None when ``sid`` is not active."""
        for slot, sess in self.sched.active():
            if sess.sid == sid:
                sess.saved_state = self._snapshot_slot(slot)
                if self._ckpt is not None:
                    self._ckpt.save(sess.sid, sess.saved_state, sess.cursor)
                    self._record('checkpoint', sid=sid, cursor=sess.cursor)
                self.sched.evict(slot, requeue=requeue)
                self._record('preempt', sid=sid, slot=slot, requeue=requeue)
                return sess
        return None

    def evict(self, sid: int) -> Optional[StreamSession]:
        """Abandon a stream mid-flight; its slot is freed for refill.

        Neighbouring streams are untouched — their state rows are separate
        slots of the packed cache and the freed row is zeroed on the next
        admission (``_admit_slot``).  The evicted stream's state is no
        longer silently discarded: its ``(h, c)`` rows + cursor are
        snapshotted onto the session (and to disk when a checkpointer is
        configured), so ``resume``/``resume_from_checkpoint`` can continue
        it later, bit-equal."""
        return self.preempt(sid, requeue=False)

    def resume(self, sess: StreamSession) -> StreamSession:
        """Resubmit a preempted/evicted session; it re-enters the pending
        queue and, on admission, restores its saved packed state and
        continues from its cursor — bit-equal to an uninterrupted run on
        the same backend."""
        assert sess.error is None, f'stream {sess.sid} was quarantined'
        self.sched.submit(sess)
        return sess

    def resume_from_checkpoint(self, frames: np.ndarray, sid: int
                               ) -> StreamSession:
        """Rebuild a stream from its on-disk checkpoint and submit it.

        ``frames`` is the full utterance (inputs are not checkpointed —
        only the packed per-layer ``(h, c)`` rows and the frame cursor);
        the session resumes at the checkpointed cursor and its emitted
        log-probs continue from there, bit-equal to the uninterrupted
        run's suffix on the same backend."""
        assert self._ckpt is not None, 'no checkpoint_dir configured'
        frames = np.asarray(frames, np.float32)
        n_h = self.cfg.lstm_hidden
        like = tuple(
            (np.zeros((n_h,), h.dtype), np.zeros((n_h,), c.dtype))
            for h, c in self.states)
        state_rows, cursor = self._ckpt.load(sid, like)
        dec = IncrementalCTCDecoder() if self.decode_ctc else None
        sess = StreamSession(sid=sid, frames=frames, decoder=dec,
                             cursor=cursor, t_enqueue=time.time())
        sess.saved_state = tuple(
            (np.asarray(rh), np.asarray(rc)) for rh, rc in state_rows)
        self._next_sid = max(self._next_sid, sid + 1)
        self.sched.submit(sess)
        self._record('resume_from_checkpoint', sid=sid, cursor=cursor)
        return sess

    # -------------------------------------------------------- fault hooks
    def _record(self, kind: str, **info) -> None:
        self.events.append({'kind': kind, 'step': self._step_idx, **info})

    def _inject_poison(self) -> None:
        """Deterministic state-poisoning hook (``faults.poison_at``): write
        NaN into the scheduled slot's packed rows before this step's chunk.
        Test/demo injection only — the guard + quarantine path downstream
        is what production exercises."""
        if self.faults is None:
            return
        slot = self.faults.poison_at.get(self._step_idx)
        if slot is not None:
            self.states = jax.tree.map(
                lambda a: a.at[slot].set(jnp.nan), self.states)
            self._record('poison_injected', slot=slot)

    def _on_engine_fault(self, exc: BaseException, attempt: int) -> None:
        """Between a failed chunk attempt and its retry: transient faults
        just retry; an ``EngineFailure`` degrades the backend one rung down
        ``core.lstm.DEGRADATION_LADDER``, uninstalls a broken mesh, and
        elastically re-places the packed state cache on the surviving
        topology (bit-preserving host round-trip) before the retry
        recomputes the SAME chunk — no stream loses state or frames."""
        if not isinstance(exc, EngineFailure):
            return                          # transient: plain retry
        from ..core.lstm import next_backend_down
        if self.backend.endswith('_systolic'):
            # dead engine invalidates the installed topology; dispatch must
            # not re-pick a mesh backend on the retry
            from ..core import systolic
            systolic.clear_mesh()
        nxt = next_backend_down(self.backend)
        if nxt is None:
            self._record('degrade_exhausted', backend=self.backend,
                         n_dead=exc.n_dead)
            return                          # bottom of the ladder: retry as-is
        prev, self.backend = self.backend, nxt
        self.cfg = self.cfg.replace(lstm_backend=nxt)
        self.states = elastic_replace(self.states)
        self._build_fwd()
        self._record('degrade', from_backend=prev, to_backend=nxt,
                     n_dead=exc.n_dead)

    def _quarantine(self, active, finite, new_states) -> tuple:
        """Quarantine every active slot whose new carried state went
        non-finite: zero exactly that slot's rows, evict the session with a
        terminal ``error`` (never retired into ``done``, never requeued),
        and leave every neighbouring slot's rows and outputs bit-untouched.
        Returns the scrubbed states."""
        for slot, sess in active:
            if not finite[slot]:
                new_states = jax.tree.map(
                    lambda a: a.at[slot].set(0), new_states)
                sess.error = (f'non-finite state quarantined at engine '
                              f'step {self._step_idx}')
                sess.saved_state = None
                self.sched.evict(slot)
                self._record('quarantine', sid=sess.sid, slot=slot)
        return new_states

    # ------------------------------------------------------------- stepping
    def step(self) -> bool:
        """Advance every active stream by up to ``chunk`` frames.

        Admits pending streams into free slots, packs all active streams
        into ONE batched chunked call (padded slots masked out via
        ``valid_len``), scatters the valid output rows back to the sessions,
        and retires exhausted streams.  With a fault config attached the
        call is driven by the generalized ``FaultTolerantRunner`` (injected
        failures degrade the backend and retry the SAME chunk; overruns of
        the per-chunk deadline are recorded), the packed cache is scrubbed
        by the non-finite quarantine before commit, and nothing — states,
        cursors, outputs — is committed unless the attempt succeeded, so a
        retried chunk is recomputed from unchanged state.  Returns False
        when there was nothing to do (the drain-loop exit condition).
        """
        self.sched.refill(self._admit_slot)
        active = self.sched.active()
        if not active:
            return False
        self._inject_poison()

        S, T = self.sched.num_slots, self.chunk
        frames = np.zeros((S, T, self.cfg.lstm_inputs), np.float32)
        valid = np.zeros((S,), np.int32)
        for i, sess in active:
            part = sess.next_chunk(T)
            frames[i, :len(part)] = part
            valid[i] = len(part)
        frames_j, valid_j = jnp.asarray(frames), jnp.asarray(valid)

        def attempt():
            lp, st, finite = self._fwd(self.params, self.states,
                                       frames_j, valid_j)
            return (np.asarray(jax.block_until_ready(lp)), st,
                    np.asarray(finite))

        t0 = time.time()
        if self._runner is not None:
            host, new_states, finite = self._runner.run(
                self._step_idx, attempt, on_fault=self._on_engine_fault)
        else:
            host, new_states, finite = attempt()
        self.chunk_walls.append(time.time() - t0)

        if not finite.all():
            new_states = self._quarantine(active, finite, new_states)
        self.states = new_states

        for i, sess in active:
            if sess.error is not None:      # quarantined this step
                continue
            sess.consume(host[i, :valid[i]])
            if sess.remaining == 0:
                sess.t_done = time.time()
                self.sched.finish(i)
        self._step_idx += 1
        return True

    def run(self) -> List[StreamSession]:
        """Drain: step until every submitted stream has been served."""
        while self.sched.busy:
            self.step()
        return self.sched.done

    # ------------------------------------------------------------- metrics
    def stats(self) -> dict:
        """Throughput/latency summary over the completed streams, plus the
        §10 fault telemetry: merged structured events (engine + runner),
        per-kind counts, deadline-miss total, the current (possibly
        degraded) backend, and the runner's last heartbeat."""
        done = self.sched.done
        frames = sum(s.length for s in done)
        lats = [s.t_done - s.t_enqueue for s in done if s.t_done]
        events = list(self.events)
        if self._runner is not None:
            events += self._runner.events
        counts: dict = {}
        for e in events:
            counts[e['kind']] = counts.get(e['kind'], 0) + 1
        return {
            'streams': len(done),
            'frames': frames,
            'p50_latency_s': float(np.median(lats)) if lats else 0.0,
            'p50_chunk_s': (float(np.median(self.chunk_walls))
                            if self.chunk_walls else 0.0),
            'backend': self.backend,
            'steps': self._step_idx,
            'events': events,
            'event_counts': counts,
            'deadline_misses': (self._runner.deadline_misses
                                if self._runner else 0),
            'heartbeat': (self._runner.last_heartbeat
                          if self._runner else None),
        }
