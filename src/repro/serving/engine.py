"""Packed multi-stream stateful streaming engine (DESIGN.md §7, §10, §11).

The deployment story of the paper — weights stay resident while audio frames
stream through — turned into a serving substrate: every active stream's
``(h, c)`` LSTM state lives in one packed per-layer state cache of shape
``(max_streams, N_h)``, and each engine step runs ONE batched chunked call to
the whole-sequence LSTM path (``core.lstm.lstm_stack_chunk``) for ALL active
streams.  On the ``pallas_seq`` backend the slot dimension maps onto the
batch-block (``bb``) grid of the persistent kernel, so every stream shares a
single weight DMA per chunk instead of paying one per slot (the E-PUR
amortisation); on ``pallas_seq_systolic`` the same call scales out over the
installed mesh.  Ragged streams are handled by the §7 valid-length masking
contract: a slot's padded tail steps are identity on its carried state, so
admission/eviction/refill never perturbs neighbouring streams.

Backend-agnostic by construction: the engine only speaks
``models.chipmunk_net.stream_forward``, which dispatches on
``cfg.lstm_backend`` (``xla_scan | pallas_seq | pallas_seq_fused |
pallas_seq_systolic | pallas_seq_fused_systolic`` via the installed mesh).
On ``pallas_seq_fused`` every engine step advances ALL active streams
through ALL stack layers in ONE wavefront kernel launch (DESIGN.md §8);
on ``pallas_seq_fused_systolic`` the same chunked call runs the staged
scale-out over the installed (stage, row, col) mesh (DESIGN.md §9).

Fault tolerance (DESIGN.md §10, ``runtime/serving_faults.py``): with a
``ServingFaultConfig`` attached, every engine step is driven by the
generalized ``FaultTolerantRunner`` — injected/real engine failures degrade
the backend down ``core.lstm.DEGRADATION_LADDER`` and elastically re-place
the packed cache (no stream loss, a logged latency blip); per-chunk
deadlines derived from the paper's real-time model are watched; a fused
non-finite guard quarantines exactly the poisoned slot; and
preempted/evicted streams checkpoint their packed ``(h, c)`` rows + frame
cursor so a resubmitted stream resumes **bit-equal** to an uninterrupted
run.

Async double-buffered dispatch (DESIGN.md §11): every step is split into a
non-blocking LAUNCH (the jitted chunk call is dispatched and its device
futures recorded) and a later COMMIT (``block_until_ready``, fault/deadline
handling, quarantine scrub, cursor advance, retirement, refill).  The
synchronous mode commits immediately after launching — a depth-0 pipeline —
so both modes share one code path and stay bit-equal by construction.  With
``async_dispatch=True`` the commit of chunk k is deferred: while the device
computes chunk k, the host speculatively plans, packs, and launches chunk
k+1 on top of chunk k's un-committed output-state futures (retirements and
FIFO/priority admissions are deterministic at launch time, so the
speculation is exact on the clean path).  If chunk k's commit deviates —
engine fault, quarantine, or a scheduler decision the speculation could not
see — the speculative launch is SQUASHED (its results are never observed)
and the next step relaunches from committed state, preserving PR 6's
commit-on-success discipline: nothing (states, cursors, outputs,
checkpoints) commits until the in-flight chunk resolves.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import chipmunk_net
from ..runtime.fault import FaultConfig, FaultTolerantRunner, RingLog
from ..runtime.recovery import MeshHealthTracker, build_rungs
from ..runtime.serving_faults import (ChunkSizePolicy, EngineFailure,
                                      ServingFaultConfig,
                                      StreamStateCheckpointer,
                                      elastic_replace, finite_slots)
from .scheduler import SlotScheduler
from .session import IncrementalCTCDecoder, StreamSession


def tuned_chunk_ceiling(cfg, chunk: int, max_streams: int) -> int:
    """Chunk-length ceiling for the deadline policy, cache-first.

    The ``ChunkSizePolicy`` adapts chunk length at runtime but needs a
    CEILING to grow back toward; historically that was just the engine's
    packing width.  With a schedule cache installed (``repro.tune``), a
    tuned chunk depth clamps it: chunks deeper than the measured-best
    ``Tc`` only add latency without throughput.  Two entry kinds, most
    trustworthy first: ``'serving_chunk'`` — the END-TO-END serving-loop
    measurement ``tune_serving_config`` records (the engine step with
    packing/masking/admission, exactly what this ceiling governs) — then
    the kernel-level ``'stack_f32'`` prediction as the fallback (exact
    ``(T, B)`` keys first, wildcards after).  Scheduling-only by the §11
    contract — outputs are bit-invariant to where chunk boundaries fall.
    Returns ``chunk`` unchanged on a cache miss (or ``tc=0`` entry).
    """
    from ..core.systolic import current_mesh
    from ..tune.schedule import current_schedule_cache, mesh_signature
    cache = current_schedule_cache()
    if cache is None:
        return chunk
    ent = cache.lookup('serving_chunk', n_x=cfg.lstm_inputs,
                       n_h=cfg.lstm_hidden, n_layers=cfg.n_layers,
                       T=chunk, B=max_streams,
                       mesh=mesh_signature(current_mesh()))
    if ent is None or not ent.tc:
        ent = cache.lookup('stack_f32', n_x=cfg.lstm_inputs,
                           n_h=cfg.lstm_hidden, n_layers=cfg.n_layers,
                           T=chunk, B=max_streams,
                           mesh=mesh_signature(current_mesh()))
    if ent is not None and ent.tc:
        return max(1, min(chunk, int(ent.tc)))
    return chunk


@dataclasses.dataclass
class _InFlight:
    """One launched-but-uncommitted chunk: the device futures of the jitted
    call plus everything a retry needs to recompute it from committed state
    (the frames/valid arrays and the poison edit).  Bookkeeping only — no
    arithmetic of its own."""

    chunk_idx: int                       # logical step index of this chunk
    active: List[Tuple[int, StreamSession]]   # (slot, session) at launch
    valid: np.ndarray                    # (S,) valid frame counts
    frames_j: jax.Array                  # (S, chunk_len, n_in) device input
    valid_j: jax.Array
    poison_slot: Optional[int]           # injected NaN edit to re-apply
    lp: jax.Array                        # device futures of the chunk call
    new_states: tuple
    finite: jax.Array
    t_launch: float
    chunk_len: int
    states_in: tuple = ()                # inputs as fed (incl. poison edit)


class StreamingEngine:
    """Continuous streaming over a packed slot grid of recurrent state.

    One instance owns ``max_streams`` state slots; streams are admitted from
    a priority/FIFO queue, advance up to ``chunk`` frames per ``step``
    through one batched call, and are retired when their frames are
    exhausted.  Numerics contract: a stream's emitted log-probs equal the
    monolithic ``chipmunk_net.forward`` of its full utterance on the same
    backend (bit-equal on a fixed backend code path; allclose across
    backends), regardless of which streams shared its batch
    (tests/test_streaming.py) — and regardless of ``async_dispatch`` and of
    where the chunk-size policy moves chunk boundaries: the async engine's
    outputs are bit-equal to the sync engine's for every admission/
    eviction/preemption/fault schedule (tests/test_serving_async.py).
    A preempted stream resumed from its checkpoint continues bit-equal to
    an uninterrupted run (tests/test_serving_faults.py).

    ``faults`` (a ``runtime.ServingFaultConfig``) opts into the §10 fault
    model: deterministic engine-failure injection + ladder degradation,
    per-chunk deadline watchdog, non-finite slot quarantine, and stream
    checkpoint/resume through ``CheckpointManager``.  Without it the engine
    behaves exactly as before (no guard, no runner — zero overhead).

    ``async_dispatch`` enables §11 double buffering (launch of chunk k+1
    overlapped with device compute of chunk k); ``chunk_policy`` (a
    ``runtime.ChunkSizePolicy``) makes the per-step chunk length adapt to
    the observed launch-to-commit wall time against the paper's 10 ms
    frame-arrival budget.  Both are scheduling-only: outputs are
    bit-invariant to them.
    """

    def __init__(self, cfg, params, *, max_streams: int = 4, chunk: int = 16,
                 decode_ctc: bool = False,
                 faults: Optional[ServingFaultConfig] = None,
                 async_dispatch: bool = False,
                 chunk_policy: Optional[ChunkSizePolicy] = None):
        assert cfg.family == 'lstm', (
            'StreamingEngine serves the stateful recurrent family; token '
            'families keep the per-slot decode loop (launch/serve.py)')
        assert chunk >= 1 and max_streams >= 1
        from ..core.lstm import resolve_serving_backend
        self.params = params
        self.chunk = chunk
        self.decode_ctc = decode_ctc
        self.async_dispatch = bool(async_dispatch)
        self._policy = chunk_policy
        if chunk_policy is not None:
            assert chunk_policy.chunk_max <= chunk, (
                'chunk_policy.chunk_max exceeds the engine packing width',
                chunk_policy.chunk_max, chunk)
        # pin ONE concrete backend per engine (the §7 bit-equality contract
        # holds per backend code path; the ladder needs a known rung)
        self.backend = resolve_serving_backend(
            params, cfg.lstm_backend, chunk, max_streams)
        self.cfg = cfg.replace(lstm_backend=self.backend)
        self.sched: SlotScheduler[StreamSession] = SlotScheduler(max_streams)
        self.states = tuple(
            (jnp.zeros((max_streams, cfg.lstm_hidden), cfg.dtype()),
             jnp.zeros((max_streams, cfg.lstm_hidden), cfg.dtype()))
            for _ in range(cfg.n_layers))
        self._next_sid = 0
        self._step_idx = 0
        self._pending: Optional[_InFlight] = None
        self._poison_recorded: set = set()
        self.chunk_walls: List[float] = []   # per-step wall times (latency)
        self.events = RingLog(faults.event_log_cap
                              if faults is not None else None)

        self.faults = faults
        if faults is not None:
            self._guard = faults.guard_nonfinite
            self._ckpt = (StreamStateCheckpointer(faults.checkpoint_dir)
                          if faults.checkpoint_dir else None)
            self._runner: Optional[FaultTolerantRunner] = FaultTolerantRunner(
                cfg=FaultConfig(max_retries=faults.max_retries,
                                backoff_s=faults.backoff_s,
                                deadline_s=faults.resolve_deadline_s(chunk),
                                heartbeat_path=faults.heartbeat_path,
                                event_log_cap=faults.event_log_cap),
                fail_schedule=faults.make_fail_schedule())
            # §14 recovery runtime: materialise the rung ladder for this
            # deployment (die-mesh rungs when a two-level mesh is installed)
            # and track fault-domain health against it
            from ..launch.mesh import current_die_mesh
            self._rungs = build_rungs(
                self.backend, n_layers=cfg.n_layers, n_h=cfg.lstm_hidden,
                die_mesh=current_die_mesh(), n_x=cfg.lstm_inputs,
                T=chunk, batch=max_streams)
            if self._rungs[0].backend != self.backend:
                self._rungs = build_rungs(
                    self.backend, n_layers=cfg.n_layers,
                    n_h=cfg.lstm_hidden, n_x=cfg.lstm_inputs,
                    T=chunk, batch=max_streams)
            self._tracker: Optional[MeshHealthTracker] = MeshHealthTracker(
                n_domains=self._rungs[0].need,
                hysteresis=faults.promote_hysteresis)
        else:
            self._guard = False
            self._ckpt = None
            self._runner = None
            self._rungs = ()
            self._tracker = None
        self._rung_idx = 0
        self._healed_steps: set = set()
        self._last_commit: Optional[dict] = None   # canary replay material
        from ..core.systolic import current_mesh
        self._home_mesh = current_mesh()   # re-installed on mesh promotions
        self._build_fwd()

    def _make_fwd(self, cfg):
        """Jitted packed chunk call for ``cfg``'s backend.  The non-finite
        guard is fused into the same jit (one reduction over the new
        states, no extra dispatch); with the guard off an all-ones constant
        is returned, so the clean path's arithmetic is unchanged.  Also the
        factory the promotion canary uses to build the CANDIDATE backend's
        call without touching the incumbent's."""
        guard = self._guard

        def fwd(params, states, frames, valid):
            lp, new_states = chipmunk_net.stream_forward(
                cfg, params, states, frames, valid_len=valid)
            if guard:
                finite = finite_slots(new_states)
            else:
                finite = jnp.ones((frames.shape[0],), bool)
            return lp, new_states, finite

        return jax.jit(fwd)

    def _build_fwd(self):
        """(Re)build the jitted packed chunk call for the CURRENT backend.
        Called at construction and after every rung change (degradation or
        promotion)."""
        self._fwd = self._make_fwd(self.cfg)

    # ------------------------------------------------------------ admission
    def submit(self, frames: np.ndarray, sid: Optional[int] = None,
               priority: int = 0) -> StreamSession:
        """Queue an utterance ((L, n_in) host frames) for streaming.
        ``priority`` > 0 marks a latency-SLO stream: it is admitted ahead
        of bulk streams and may displace one (§11 priority admission)."""
        frames = np.asarray(frames, np.float32)
        assert frames.ndim == 2 and frames.shape[1] == self.cfg.lstm_inputs, \
            frames.shape
        if sid is None:
            sid = self._next_sid
        self._next_sid = max(self._next_sid, sid + 1)
        dec = IncrementalCTCDecoder() if self.decode_ctc else None
        sess = StreamSession(sid=sid, frames=frames, decoder=dec,
                             priority=priority, t_enqueue=time.time())
        self.sched.submit(sess)
        return sess

    def _apply_admission(self, states: tuple, slot: int,
                         sess: StreamSession) -> tuple:
        """Functional slot initialisation, shared by the realized admission
        (``_admit_slot``) and the §11 speculative launch composition: a
        recycled slot must never leak its previous occupant's state — zero
        its packed rows, or, for a resumed session, scatter the saved
        per-layer ``(h, c)`` rows back in (an exact host round-trip, so
        resume is bit-equal to never having been evicted).  Pure functional
        update — both call sites perform the identical op sequence, which
        is what keeps the speculative input bit-equal to the committed
        one."""
        if sess.saved_state is not None:
            return tuple(
                (h.at[slot].set(jnp.asarray(rh)),
                 c.at[slot].set(jnp.asarray(rc)))
                for (h, c), (rh, rc) in zip(states, sess.saved_state))
        return jax.tree.map(lambda a: a.at[slot].set(0), states)

    def _admit_slot(self, slot: int, sess: StreamSession) -> None:
        """Admission callback (see ``_apply_admission`` for the numerics):
        applies the slot initialisation to the committed cache and clears
        the session's saved rows."""
        self.states = self._apply_admission(self.states, slot, sess)
        if sess.saved_state is not None:
            sess.saved_state = None
            self._record('resume', sid=sess.sid, slot=slot,
                         cursor=sess.cursor)

    def _snapshot_slot(self, slot: int) -> tuple:
        """Host copy of one slot's per-layer ``(h, c)`` rows — the stream's
        packed state, exactly as carried (bit-preserving numpy transfer, no
        arithmetic)."""
        return tuple((np.asarray(h[slot]), np.asarray(c[slot]))
                     for h, c in self.states)

    def preempt(self, sid: int, requeue: bool = True
                ) -> Optional[StreamSession]:
        """Preempt a stream: snapshot its packed per-layer ``(h, c)`` rows +
        frame cursor onto the session (and through the stream checkpointer
        when one is configured), free its slot, and — with ``requeue=True``
        — re-enter it at the front of its priority class in the pending
        queue.  The resumed stream continues **bit-equal** to an
        uninterrupted run on the same backend (tests/test_serving_faults.py,
        tests/test_serving_async.py).  A control-plane op: under async
        dispatch the in-flight chunk is committed first (``_sync``), so the
        snapshot always reads committed rows.  Returns the session, or
        None when ``sid`` is not active."""
        self._sync()
        for slot, sess in self.sched.active():
            if sess.sid == sid:
                sess.saved_state = self._snapshot_slot(slot)
                if self._ckpt is not None:
                    self._ckpt.save(sess.sid, sess.saved_state, sess.cursor)
                    self._record('checkpoint', sid=sid, cursor=sess.cursor)
                self.sched.evict(slot, requeue=requeue)
                self._record('preempt', sid=sid, slot=slot, requeue=requeue)
                return sess
        return None

    def evict(self, sid: int) -> Optional[StreamSession]:
        """Abandon a stream mid-flight; its slot is freed for refill.

        Neighbouring streams are untouched — their state rows are separate
        slots of the packed cache and the freed row is zeroed on the next
        admission (``_admit_slot``).  The evicted stream's state is no
        longer silently discarded: its ``(h, c)`` rows + cursor are
        snapshotted onto the session (and to disk when a checkpointer is
        configured), so ``resume``/``resume_from_checkpoint`` can continue
        it later, bit-equal."""
        return self.preempt(sid, requeue=False)

    def resume(self, sess: StreamSession) -> StreamSession:
        """Resubmit a preempted/evicted session; it re-enters the pending
        queue and, on admission, restores its saved packed state and
        continues from its cursor — bit-equal to an uninterrupted run on
        the same backend."""
        assert sess.error is None, f'stream {sess.sid} was quarantined'
        self.sched.submit(sess)
        return sess

    def resume_from_checkpoint(self, frames: np.ndarray, sid: int
                               ) -> StreamSession:
        """Rebuild a stream from its on-disk checkpoint and submit it.

        ``frames`` is the full utterance (inputs are not checkpointed —
        only the packed per-layer ``(h, c)`` rows and the frame cursor);
        the session resumes at the checkpointed cursor and its emitted
        log-probs continue from there, bit-equal to the uninterrupted
        run's suffix on the same backend."""
        assert self._ckpt is not None, 'no checkpoint_dir configured'
        frames = np.asarray(frames, np.float32)
        n_h = self.cfg.lstm_hidden
        like = tuple(
            (np.zeros((n_h,), h.dtype), np.zeros((n_h,), c.dtype))
            for h, c in self.states)
        state_rows, cursor = self._ckpt.load(sid, like)
        dec = IncrementalCTCDecoder() if self.decode_ctc else None
        sess = StreamSession(sid=sid, frames=frames, decoder=dec,
                             cursor=cursor, t_enqueue=time.time())
        sess.saved_state = tuple(
            (np.asarray(rh), np.asarray(rc)) for rh, rc in state_rows)
        self._next_sid = max(self._next_sid, sid + 1)
        self.sched.submit(sess)
        self._record('resume_from_checkpoint', sid=sid, cursor=cursor)
        return sess

    # -------------------------------------------------------- fault hooks
    def _record(self, kind: str, **info) -> None:
        self.events.append({'kind': kind, 'step': self._step_idx, **info})

    def _install_rung_mesh(self, rung) -> None:
        """Point the process mesh registry at ``rung``'s topology: the
        healthy dies' flattened submesh for a die rung, the construction-
        time home mesh for a meshless systolic rung, no mesh for a flat
        rung.  Placement only — the §7 contract keeps outputs bit-equal."""
        from ..core import systolic
        if rung.n_dies is not None:
            from ..launch.mesh import current_die_mesh
            dm = current_die_mesh()
            use = self._tracker.healthy[:rung.n_dies]
            systolic.install_mesh(dm.submesh(use))
        elif rung.backend.endswith('_systolic'):
            systolic.install_mesh(self._home_mesh)
        else:
            systolic.clear_mesh()

    def _on_engine_fault(self, exc: BaseException, attempt: int) -> None:
        """Between a failed chunk attempt and its retry: transient faults
        (including ``EngineFailure(transient=True)``) just retry; a
        permanent ``EngineFailure`` marks its fault domain dead in the
        health tracker and degrades to the highest rung the surviving
        capacity supports (at least one rung down) — re-forming the die
        mesh on the healthy dies, or uninstalling a broken flat mesh —
        and elastically re-places the packed state cache on the surviving
        topology (bit-preserving host round-trip) before the retry
        recomputes the SAME chunk.  No stream loses state or frames."""
        if not isinstance(exc, EngineFailure) or exc.transient:
            return                          # transient: plain retry
        killed = self._tracker.fail(self._step_idx, domain=exc.domain,
                                    n_dead=exc.n_dead)
        domain = killed[0] if killed else exc.domain
        n = self._tracker.n_healthy
        supported = next(
            (i for i, r in enumerate(self._rungs) if r.need <= n),
            len(self._rungs) - 1)
        target = max(self._rung_idx + 1, supported)
        if target >= len(self._rungs):
            self._record('degrade_exhausted', backend=self.backend,
                         n_dead=exc.n_dead)
            return                          # bottom of the ladder: retry as-is
        prev = self.backend
        rung = self._rungs[target]
        self._install_rung_mesh(rung)
        self.backend = rung.backend
        self.cfg = self.cfg.replace(lstm_backend=rung.backend)
        self.states = elastic_replace(self.states)
        self._build_fwd()
        self._rung_idx = target
        self._last_commit = None            # stale incumbent evidence
        self._record('degrade', from_backend=prev, to_backend=rung.backend,
                     n_dead=exc.n_dead, domain=domain)

    def _quarantine(self, active, finite, new_states) -> tuple:
        """Quarantine every active slot whose new carried state went
        non-finite: zero exactly that slot's rows, evict the session with a
        terminal ``error`` (never retired into ``done``, never requeued),
        and leave every neighbouring slot's rows and outputs bit-untouched.
        Returns the scrubbed states."""
        for slot, sess in active:
            if not finite[slot]:
                new_states = jax.tree.map(
                    lambda a: a.at[slot].set(0), new_states)
                sess.error = (f'non-finite state quarantined at engine '
                              f'step {self._step_idx}')
                sess.saved_state = None
                self.sched.evict(slot)
                self._record('quarantine', sid=sess.sid, slot=slot)
        return new_states

    # -------------------------------------------------- launch/commit core
    def _next_chunk_len(self) -> int:
        """Frames the next chunk should carry: the policy's current size
        (never above the engine packing width), else the fixed ``chunk``."""
        if self._policy is not None:
            return min(self._policy.size, self.chunk)
        return self.chunk

    def _deadline_for(self, chunk_len: int) -> Optional[float]:
        """Per-chunk deadline for the watchdog: the chunk-size policy's
        arrival-rate budget when one is attached (so ``deadline_miss``
        events and the policy's feedback agree), else the fault config's
        ``resolve_deadline_s`` for THIS chunk length."""
        if self._policy is not None:
            return self._policy.budget_s(chunk_len)
        if self.faults is not None:
            return self.faults.resolve_deadline_s(chunk_len)
        return None

    def _pack(self, plan, chunk_len: int):
        """Host-side packing of one chunk: gather each planned stream's next
        frames at its planned cursor into the (S, chunk_len, n_in) batch
        buffer.  ``plan`` rows are (slot, session, cursor) — the cursor is
        explicit so the async path can pack SPECULATIVELY (committed cursor
        + in-flight valid count) without mutating any session."""
        S = self.sched.num_slots
        frames = np.zeros((S, chunk_len, self.cfg.lstm_inputs), np.float32)
        valid = np.zeros((S,), np.int32)
        for slot, sess, cursor in plan:
            part = sess.frames[cursor:cursor + chunk_len]
            frames[slot, :len(part)] = part
            valid[slot] = len(part)
        return frames, valid

    def _launch(self, states_in: tuple, plan, chunk_idx: int,
                chunk_len: int) -> _InFlight:
        """Dispatch one chunk WITHOUT blocking: compose the injected poison
        edit (``faults.poison_at``) onto the input states, pack the planned
        streams' frames, and fire the jitted chunk call — its results stay
        device futures inside the returned ``_InFlight`` record until
        ``_commit`` resolves them.  Nothing engine-visible mutates here."""
        poison = (self.faults.poison_at.get(chunk_idx)
                  if self.faults is not None else None)
        if poison is not None:
            states_in = jax.tree.map(
                lambda a: a.at[poison].set(jnp.nan), states_in)
            if chunk_idx not in self._poison_recorded:
                self._poison_recorded.add(chunk_idx)
                self._record('poison_injected', slot=poison, step=chunk_idx)
        frames, valid = self._pack(plan, chunk_len)
        frames_j, valid_j = jnp.asarray(frames), jnp.asarray(valid)
        t0 = time.time()
        lp, st, fin = self._fwd(self.params, states_in, frames_j, valid_j)
        return _InFlight(chunk_idx=chunk_idx,
                         active=[(i, s) for i, s, _ in plan],
                         valid=valid, frames_j=frames_j, valid_j=valid_j,
                         poison_slot=poison, lp=lp, new_states=st,
                         finite=fin, t_launch=t0, chunk_len=chunk_len,
                         states_in=states_in)

    def _commit(self, rec: _InFlight) -> bool:
        """Resolve one in-flight chunk and commit it: block on the device
        futures (under the fault runner when configured — injected failures
        discard the launched futures and retry with a fresh synchronous
        recompute from COMMITTED state, after the ladder degradation), feed
        the launch-to-commit wall time to the deadline watchdog and the
        chunk-size policy, scrub quarantined slots, then — only now —
        advance states, cursors, outputs, and retirement.  Returns True iff
        the commit was clean (no fault, no quarantine): the async path may
        adopt its speculative launch only then."""
        deadline = self._deadline_for(rec.chunk_len)

        def resolve():
            return (np.asarray(jax.block_until_ready(rec.lp)),
                    rec.new_states, np.asarray(rec.finite))

        def retry():
            # the failed futures are dead; recompute synchronously from the
            # committed cache (admissions for this chunk are already
            # realized in it; only the injected poison edit is re-applied)
            states_in = self.states
            if rec.poison_slot is not None:
                states_in = jax.tree.map(
                    lambda a: a.at[rec.poison_slot].set(jnp.nan), states_in)
            lp, st, fin = self._fwd(self.params, states_in,
                                    rec.frames_j, rec.valid_j)
            return (np.asarray(jax.block_until_ready(lp)), st,
                    np.asarray(fin))

        faulted = False
        if self._runner is not None:
            n_before = sum(1 for e in self._runner.events
                           if e['kind'] == 'fault')
            host, new_states, finite = self._runner.run(
                rec.chunk_idx, resolve, on_fault=self._on_engine_fault,
                retry_fn=retry, launched_at=rec.t_launch,
                deadline_s=deadline)
            faulted = sum(1 for e in self._runner.events
                          if e['kind'] == 'fault') > n_before
        else:
            host, new_states, finite = resolve()
        dt = time.time() - rec.t_launch
        self.chunk_walls.append(dt)
        if self._policy is not None:
            self._policy.observe(rec.chunk_len, dt)
            if self._runner is None and dt > self._policy.budget_s(
                    rec.chunk_len):
                self._record('deadline_miss', dt=dt,
                             deadline_s=self._policy.budget_s(rec.chunk_len))

        quarantined = not bool(finite.all())
        if quarantined:
            new_states = self._quarantine(rec.active, finite, new_states)
        self.states = new_states
        for i, sess in rec.active:
            if sess.error is not None:      # quarantined this step
                continue
            sess.consume(host[i, :rec.valid[i]])
            if sess.remaining == 0:
                sess.t_done = time.time()
                self.sched.finish(i)
        self._step_idx += 1
        clean = not (quarantined or faulted)
        if clean and self._tracker is not None and self._rung_idx > 0:
            # canary replay material: host copies of exactly what this
            # commit consumed and produced (captured only while degraded —
            # the home rung pays nothing)
            self._last_commit = {
                'states_in': jax.tree.map(np.asarray, rec.states_in),
                'frames': np.asarray(rec.frames_j),
                'valid': np.asarray(rec.valid_j),
                'lp': host,
                'new_states': jax.tree.map(np.asarray, new_states),
            }
        return clean

    def _sync(self) -> None:
        """Async control-plane barrier: commit the in-flight chunk, if any.
        Preemption/eviction snapshots and checkpoint saves must read
        COMMITTED state, so every control op drains the pipeline first.
        No-op in sync mode (nothing is ever left in flight)."""
        if self._pending is not None:
            rec, self._pending = self._pending, None
            self._commit(rec)

    # ------------------------------------------------- recovery / promotion
    def _poll_recovery(self) -> None:
        """Top-of-step recovery poll (§14): apply any scheduled heals
        (``faults.recover_at``, each engine step at most once) to the
        health tracker, then attempt a canary-validated promotion when
        capacity and the hysteresis window allow.  Keyed on the COMMITTED
        step index in both dispatch modes, so sync and async replay the
        same recovery trail."""
        if self._tracker is None:
            return
        heal_n = self.faults.recover_at.get(self._step_idx)
        if heal_n and self._step_idx not in self._healed_steps:
            self._healed_steps.add(self._step_idx)
            revived = self._tracker.heal(self._step_idx, heal_n)
            self._record('heal', domains=list(revived),
                         n_healed=int(heal_n))
        if self._rung_idx > 0:
            self._maybe_promote()

    def _canary_equal(self, a, b) -> bool:
        """Canary comparison: bitwise by default (``np.array_equal`` on host
        copies — the §6/§9 rungs of one arithmetic class really are
        bit-equal), or allclose under an explicit ``canary_rtol`` opt-in
        for cross-class promotions."""
        rtol = self.faults.canary_rtol
        if rtol is None:
            return bool(np.array_equal(np.asarray(a), np.asarray(b)))
        return bool(np.allclose(np.asarray(a), np.asarray(b), rtol=rtol,
                                atol=0.0))

    def _maybe_promote(self) -> None:
        """Attempt one climb-back step up the rung ladder (§14).

        Preconditions: the candidate rung (one above current) is within the
        tracker's healthy capacity, the hysteresis window is open, and —
        when the canary is armed — a committed chunk exists to validate
        against.  The pipeline is drained first (``_sync``), so a promotion
        NEVER lands mid-flight; the drain may itself fault or move rungs,
        so every precondition is re-checked after it.

        Canary protocol: install the candidate topology, build the
        candidate backend's jitted call, replay the last committed chunk as
        a SHADOW against a copy of the committed input state, and compare
        the replayed log-probs AND new states against the incumbent's
        committed results on the host.  Equal -> promote: re-shard the
        packed session cache onto the (larger) candidate topology
        (``elastic_replace`` — the upward inverse of the degrade path's
        shrink), adopt the candidate call, and re-arm the hysteresis
        window.  Unequal -> reject: restore the incumbent topology
        untouched, emit ``promote_rejected``, and double the backoff.
        Commit-on-success end to end: no engine-visible state changes
        unless the canary passes."""
        step = self._step_idx
        if (self._tracker.n_healthy < self._rungs[self._rung_idx - 1].need
                or not self._tracker.can_promote(step)):
            return
        if self.faults.canary and self._last_commit is None:
            return                  # nothing committed to validate against
        self._sync()                # promotion never lands mid-flight
        if self._rung_idx == 0:
            return
        cand_idx = self._rung_idx - 1
        cand = self._rungs[cand_idx]
        if (self._tracker.n_healthy < cand.need
                or not self._tracker.can_promote(self._step_idx)):
            return
        lc = self._last_commit
        if self.faults.canary and lc is None:
            return
        from ..core import systolic
        prev_mesh = systolic.current_mesh()
        self._install_rung_mesh(cand)
        cand_cfg = self.cfg.replace(lstm_backend=cand.backend)
        cand_fwd = self._make_fwd(cand_cfg)
        if self.faults.canary:
            self._record('promote_canary', from_backend=self.backend,
                         to_backend=cand.backend, chunk=lc['lp'].shape[-2]
                         if lc['lp'].ndim >= 2 else 0)
            states_in = jax.tree.map(jnp.asarray, lc['states_in'])
            lp, st, _ = cand_fwd(self.params, states_in,
                                 jnp.asarray(lc['frames']),
                                 jnp.asarray(lc['valid']))
            ok = self._canary_equal(jax.block_until_ready(lp), lc['lp'])
            ref_leaves = jax.tree.leaves(lc['new_states'])
            got_leaves = jax.tree.leaves(st)
            ok = ok and len(ref_leaves) == len(got_leaves) and all(
                self._canary_equal(g, r)
                for g, r in zip(got_leaves, ref_leaves))
            if not ok:
                # squash: restore the incumbent topology, nothing committed
                if prev_mesh is not None:
                    systolic.install_mesh(prev_mesh)
                else:
                    systolic.clear_mesh()
                self._tracker.note_reject(self._step_idx)
                self._record('promote_rejected', from_backend=self.backend,
                             to_backend=cand.backend,
                             backoff=self._tracker.backoff)
                return
        prev = self.backend
        self.backend = cand.backend
        self.cfg = cand_cfg
        self.states = elastic_replace(self.states)
        self._fwd = cand_fwd
        self._rung_idx = cand_idx
        if cand_idx == 0:
            self._last_commit = None    # home rung: stop paying capture
        self._tracker.note_promote(self._step_idx)
        self._record('promote', from_backend=prev, to_backend=cand.backend,
                     n_dies=cand.n_dies,
                     healthy=list(self._tracker.healthy))

    def _maybe_priority_preempt(self) -> None:
        """§11 priority admission: when every slot is busy and a strictly
        higher-priority stream waits, preempt the scheduler's candidate
        (checkpoint rows + cursor, requeue within its class) so the next
        refill admits the SLO stream.  Scheduling only — the displaced
        stream later resumes bit-equal (§10)."""
        slot = self.sched.preempt_candidate()
        if slot is not None:
            sess = self.sched.slots[slot]
            self.preempt(sess.sid)

    def _speculate(self, rec: _InFlight):
        """Plan chunk k+1 while chunk k is still in flight (no mutation).

        Retirements and admissions are deterministic at launch time: a
        stream retires iff its remaining frames minus the in-flight valid
        count hit zero, and refill admits the pending queue (already in
        priority order) into free slots in slot order.  Returns ``(plan,
        admissions)`` — plan rows are (slot, session, speculative cursor) —
        or None when the next step cannot be speculated: nothing to run, a
        scheduled engine failure on the in-flight chunk (its commit will
        deviate), or a waiting stream that outranks an active one (the
        commit-time priority preemption must run serialized)."""
        if self.faults is not None and rec.chunk_idx in self.faults.fail_at:
            return None
        survivors, freeing = [], []
        for slot, sess in rec.active:
            if sess.remaining - int(rec.valid[slot]) > 0:
                survivors.append((slot, sess,
                                  sess.cursor + int(rec.valid[slot])))
            else:
                freeing.append(slot)
        free = sorted({i for i, s in enumerate(self.sched.slots)
                       if s is None} | set(freeing))
        queue = list(self.sched.pending)
        if queue and not free:
            low = min(s.priority for _, s, _ in survivors)
            if max(q.priority for q in queue) > low:
                return None
        admissions = []
        for slot in free:
            if not queue:
                break
            admissions.append((slot, queue.pop(0)))
        plan = survivors + [(slot, sess, sess.cursor)
                            for slot, sess in admissions]
        plan.sort(key=lambda row: row[0])
        if not plan:
            return None
        return plan, admissions

    # ------------------------------------------------------------- stepping
    def _step_async(self) -> bool:
        """One §11 double-buffered step: speculatively pack + launch chunk
        k+1 on the in-flight chunk k's output-state futures (host work and
        device compute overlap here), then commit chunk k; adopt the
        speculative launch only if the commit was clean AND the realized
        admissions match the speculation, else squash it."""
        if self._pending is None:
            # pipeline fill: plan from committed state, launch, don't commit
            self._maybe_priority_preempt()
            self.sched.refill(self._admit_slot)
            plan = [(i, s, s.cursor) for i, s in self.sched.active()]
            if not plan:
                return False
            self._pending = self._launch(self.states, plan, self._step_idx,
                                         self._next_chunk_len())
            return True
        rec, self._pending = self._pending, None
        spec = self._speculate(rec)
        spec_rec = None
        if spec is not None:
            plan, admissions = spec
            states_in = rec.new_states
            for slot, sess in admissions:
                states_in = self._apply_admission(states_in, slot, sess)
            spec_rec = self._launch(states_in, plan, rec.chunk_idx + 1,
                                    self._next_chunk_len())
        clean = self._commit(rec)
        self._maybe_priority_preempt()
        admitted = self.sched.refill(self._admit_slot)
        if (clean and spec_rec is not None
                and [(i, s.sid) for i, s in admitted]
                == [(i, s.sid) for i, s in spec[1]]):
            self._pending = spec_rec
        elif spec_rec is not None:
            # the commit deviated from the speculation: drop the launched
            # futures unobserved and relaunch from committed state next step
            self._record('squash', chunk=spec_rec.chunk_idx)
        return True

    def step(self) -> bool:
        """Advance every active stream by up to one chunk of frames.

        Admits pending streams into free slots (priority first), packs all
        active streams into ONE batched chunked call (padded slots masked
        out via ``valid_len``), scatters the valid output rows back to the
        sessions, and retires exhausted streams.  Synchronous mode launches
        and immediately commits (a depth-0 pipeline); ``async_dispatch``
        defers the commit one step so host packing overlaps device compute
        (``_step_async``).  With a fault config attached the commit is
        driven by the generalized ``FaultTolerantRunner`` (injected
        failures degrade the backend and retry the SAME chunk
        synchronously; overruns of the per-chunk deadline are recorded
        against launch-to-commit time), the packed cache is scrubbed by the
        non-finite quarantine before commit, and nothing — states, cursors,
        outputs — is committed unless the attempt succeeded, so a retried
        chunk is recomputed from unchanged state.  Returns False when there
        was nothing to do (the drain-loop exit condition).
        """
        self._poll_recovery()
        if self.async_dispatch:
            return self._step_async()
        self._maybe_priority_preempt()
        self.sched.refill(self._admit_slot)
        plan = [(i, s, s.cursor) for i, s in self.sched.active()]
        if not plan:
            return False
        rec = self._launch(self.states, plan, self._step_idx,
                           self._next_chunk_len())
        self._commit(rec)
        return True

    def run(self) -> List[StreamSession]:
        """Drain: step until every submitted stream has been served (the
        async pipeline is fully committed on exit)."""
        while self.sched.busy:
            self.step()
        self._sync()
        return self.sched.done

    # ------------------------------------------------------------- metrics
    def stats(self) -> dict:
        """Throughput/latency summary over the completed streams, plus the
        §10 fault telemetry: merged structured events (engine + runner),
        per-kind counts, deadline-miss total, the current (possibly
        degraded) backend, and the runner's last heartbeat.  §11 additions:
        the dispatch mode and the chunk-size policy's current size/miss
        count.  Read-only snapshot — an async in-flight chunk is NOT
        committed by this call."""
        done = self.sched.done
        frames = sum(s.length for s in done)
        lats = [s.t_done - s.t_enqueue for s in done if s.t_done]
        events = list(self.events)
        if self._runner is not None:
            events += self._runner.events
        counts: dict = {}
        for e in events:
            counts[e['kind']] = counts.get(e['kind'], 0) + 1
        if self._runner is not None:
            misses = self._runner.deadline_misses
        elif self._policy is not None:
            misses = self._policy.misses
        else:
            misses = 0
        dropped = self.events.dropped
        if self._runner is not None:
            dropped += self._runner.events.dropped
        return {
            'streams': len(done),
            'frames': frames,
            'p50_latency_s': float(np.median(lats)) if lats else 0.0,
            'p50_chunk_s': (float(np.median(self.chunk_walls))
                            if self.chunk_walls else 0.0),
            'backend': self.backend,
            'steps': self._step_idx,
            'async': self.async_dispatch,
            'chunk_len': self._next_chunk_len(),
            'events': events,
            'event_counts': counts,
            'events_dropped': dropped,
            'deadline_misses': misses,
            'rung': (self._rungs[self._rung_idx].label()
                     if self._rungs else self.backend),
            'healthy_domains': (list(self._tracker.healthy)
                                if self._tracker else None),
            'heartbeat': (self._runner.last_heartbeat
                          if self._runner else None),
        }
