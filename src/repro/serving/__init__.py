"""Streaming inference engine: packed multi-stream stateful serving.

The serving substrate between the persistent LSTM kernels and the CLIs
(DESIGN.md §7): per-stream ``(h, c)`` state in a packed session cache, one
batched chunked whole-sequence call per engine step, ragged streams masked
by the valid-length contract, slots admitted/evicted/refilled continuously.
"""
from .engine import StreamingEngine
from .scheduler import SlotScheduler
from .session import IncrementalCTCDecoder, StreamSession

__all__ = ['StreamingEngine', 'SlotScheduler', 'IncrementalCTCDecoder',
           'StreamSession']
