"""Slot scheduling: admission / eviction / refill over a fixed slot grid.

Both serving front-ends share this policy object: the token ``SlotServer``
(launch/serve.py) schedules decode requests onto cache slots, and the
``StreamingEngine`` (serving/engine.py) schedules frame streams onto rows of
the packed state cache.  The scheduler owns *which* item occupies *which*
slot and nothing else — state initialisation happens in the admission
callback, so the policy is reusable across workloads.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Deque, Generic, List, Optional, Tuple, TypeVar

T = TypeVar('T')


class SlotScheduler(Generic[T]):
    """FIFO continuous batching over ``num_slots`` slots.

    Items are ``submit``ted to a pending queue; ``refill`` admits them into
    free slots (continuous batching — finished slots are refilled without
    stopping the others); ``finish`` retires a slot into ``done``; ``evict``
    frees a slot without retiring the item — by default the item leaves the
    scheduler (abandonment), with ``requeue=True`` it re-enters the FRONT of
    ``pending`` (preemption: the stream resumes as soon as a slot frees).
    Pure bookkeeping: no JAX arrays live here.
    """

    def __init__(self, num_slots: int):
        assert num_slots >= 1, num_slots
        self.slots: List[Optional[T]] = [None] * num_slots
        self.pending: Deque[T] = deque()
        self.done: List[T] = []

    @property
    def num_slots(self) -> int:
        return len(self.slots)

    @property
    def busy(self) -> bool:
        """True while anything is active or queued (the drain condition)."""
        return bool(self.pending) or any(s is not None for s in self.slots)

    def submit(self, item: T) -> None:
        """Queue an item for admission at the next ``refill``."""
        self.pending.append(item)

    def refill(self, on_admit: Optional[Callable[[int, T], None]] = None
               ) -> List[Tuple[int, T]]:
        """Admit pending items into free slots (FIFO), oldest first.

        ``on_admit(slot, item)`` runs per admission — this is where callers
        reset per-slot state (caches, packed state rows) so a recycled slot
        can never leak its previous occupant's state.  Returns the
        admissions performed.
        """
        admitted = []
        for i, slot in enumerate(self.slots):
            if slot is None and self.pending:
                item = self.pending.popleft()
                self.slots[i] = item
                if on_admit is not None:
                    on_admit(i, item)
                admitted.append((i, item))
        return admitted

    def active(self) -> List[Tuple[int, T]]:
        """(slot index, item) for every occupied slot, in slot order."""
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def finish(self, slot: int) -> T:
        """Retire the slot's item into ``done`` and free the slot."""
        item = self.slots[slot]
        assert item is not None, f'slot {slot} is empty'
        self.done.append(item)
        self.slots[slot] = None
        return item

    def evict(self, slot: int, requeue: bool = False) -> T:
        """Free the slot WITHOUT retiring the item.

        ``requeue=False`` (default) is abandonment: the item leaves the
        scheduler entirely (never enters ``done``).  ``requeue=True`` is
        preemption: the item re-enters the FRONT of ``pending`` — a
        preempted stream resumes before newly submitted ones — and the
        ``busy``/``done`` accounting stays consistent (a pending item keeps
        the scheduler busy; nothing is retired either way).
        """
        item = self.slots[slot]
        assert item is not None, f'slot {slot} is empty'
        self.slots[slot] = None
        if requeue:
            self.pending.appendleft(item)
        return item
