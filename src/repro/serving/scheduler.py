"""Slot scheduling: admission / eviction / refill over a fixed slot grid.

Both serving front-ends share this policy object: the token ``SlotServer``
(launch/serve.py) schedules decode requests onto cache slots, and the
``StreamingEngine`` (serving/engine.py) schedules frame streams onto rows of
the packed state cache.  The scheduler owns *which* item occupies *which*
slot and nothing else — state initialisation happens in the admission
callback, so the policy is reusable across workloads.

Priority admission (DESIGN.md §11): items may carry an integer ``priority``
attribute (higher = more urgent; absent = 0, plain FIFO).  The pending queue
is kept ordered by priority, FIFO within a priority class, so ``refill``
admits latency-SLO items ahead of bulk ones; ``preempt_candidate`` names the
active item a higher-priority pending item should displace.  The scheduler
stays pure bookkeeping — the caller performs the actual preemption (it owns
the state snapshot).
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Deque, Generic, List, Optional, Tuple, TypeVar

T = TypeVar('T')


def _priority(item) -> int:
    """An item's admission priority (0 when it declares none)."""
    return int(getattr(item, 'priority', 0) or 0)


class SlotScheduler(Generic[T]):
    """Priority/FIFO continuous batching over ``num_slots`` slots.

    Items are ``submit``ted to a pending queue (ordered by priority, FIFO
    within a class); ``refill`` admits them into free slots (continuous
    batching — finished slots are refilled without stopping the others);
    ``finish`` retires a slot into ``done``; ``evict`` frees a slot without
    retiring the item — by default the item leaves the scheduler
    (abandonment), with ``requeue=True`` it re-enters the FRONT of its
    priority class in ``pending`` (preemption: the stream resumes as soon
    as a slot frees, but never jumps a strictly-higher-priority waiter).
    Pure bookkeeping: no JAX arrays live here.
    """

    def __init__(self, num_slots: int):
        assert num_slots >= 1, num_slots
        self.slots: List[Optional[T]] = [None] * num_slots
        self.pending: Deque[T] = deque()
        self.done: List[T] = []

    @property
    def num_slots(self) -> int:
        return len(self.slots)

    @property
    def busy(self) -> bool:
        """True while anything is active or queued (the drain condition)."""
        return bool(self.pending) or any(s is not None for s in self.slots)

    def _insert(self, item: T, front_of_class: bool) -> None:
        """Insert into ``pending`` keeping it priority-ordered: after the
        last strictly-higher-priority item, then after (``front_of_class``
        False: FIFO append) or before (True: preemption re-entry) its own
        class."""
        p = _priority(item)
        idx = 0
        for q in self.pending:
            if _priority(q) > p or (not front_of_class and _priority(q) == p):
                idx += 1
            else:
                break
        self.pending.insert(idx, item)

    def submit(self, item: T) -> None:
        """Queue an item for admission at the next ``refill`` — behind every
        pending item of the same or higher priority (FIFO within a class),
        ahead of strictly lower-priority ones."""
        self._insert(item, front_of_class=False)

    def refill(self, on_admit: Optional[Callable[[int, T], None]] = None
               ) -> List[Tuple[int, T]]:
        """Admit pending items into free slots, highest priority first
        (FIFO within a class — the queue is kept in admission order).

        ``on_admit(slot, item)`` runs per admission — this is where callers
        reset per-slot state (caches, packed state rows) so a recycled slot
        can never leak its previous occupant's state.  Returns the
        admissions performed.
        """
        admitted = []
        for i, slot in enumerate(self.slots):
            if slot is None and self.pending:
                item = self.pending.popleft()
                self.slots[i] = item
                if on_admit is not None:
                    on_admit(i, item)
                admitted.append((i, item))
        return admitted

    def active(self) -> List[Tuple[int, T]]:
        """(slot index, item) for every occupied slot, in slot order."""
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def finish(self, slot: int) -> T:
        """Retire the slot's item into ``done`` and free the slot."""
        item = self.slots[slot]
        assert item is not None, f'slot {slot} is empty'
        self.done.append(item)
        self.slots[slot] = None
        return item

    def evict(self, slot: int, requeue: bool = False) -> T:
        """Free the slot WITHOUT retiring the item.

        ``requeue=False`` (default) is abandonment: the item leaves the
        scheduler entirely (never enters ``done``).  ``requeue=True`` is
        preemption: the item re-enters the FRONT of its priority class in
        ``pending`` — a preempted stream resumes before newly submitted
        peers (but not before strictly-higher-priority waiters) — and the
        ``busy``/``done`` accounting stays consistent (a pending item keeps
        the scheduler busy; nothing is retired either way).
        """
        item = self.slots[slot]
        assert item is not None, f'slot {slot} is empty'
        self.slots[slot] = None
        if requeue:
            self._insert(item, front_of_class=True)
        return item

    def preempt_candidate(self) -> Optional[int]:
        """The slot a higher-priority pending item should displace, or None.

        Non-None only when every slot is occupied AND the highest-priority
        pending item strictly outranks the lowest-priority active one; the
        returned slot holds that lowest-priority occupant (highest slot
        index on ties, so slot 0 — the longest-resident under FIFO refill —
        is displaced last).  Query only: the caller decides whether to act
        (it owns the displaced item's state snapshot).
        """
        if not self.pending or any(s is None for s in self.slots):
            return None
        top = max(_priority(q) for q in self.pending)
        slot, low = None, None
        for i, item in enumerate(self.slots):
            p = _priority(item)
            if low is None or p <= low:
                slot, low = i, p
        return slot if low is not None and top > low else None
