"""Per-stream session state and incremental CTC emission.

A ``StreamSession`` is one utterance flowing through the engine: queued input
frames, the cursor of how many have been consumed, the log-probs emitted so
far, and latency timestamps.  ``IncrementalCTCDecoder`` folds the greedy
best-path collapse across chunk boundaries so phonemes are emitted as soon
as their frames are processed — the "partial hypothesis" a near-sensor
deployment streams out — and its accumulated output equals the monolithic
``core.ctc.ctc_greedy_decode`` of the full utterance.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np


class IncrementalCTCDecoder:
    """Greedy CTC best-path decode, emitted incrementally chunk by chunk.

    Feeding the per-chunk argmax frames reproduces, symbol for symbol, what
    ``core.ctc.ctc_greedy_decode`` returns on the concatenated sequence: a
    symbol is emitted when it is not blank and differs from the immediately
    preceding frame's best symbol, and that predecessor is carried across
    chunk boundaries (the collapse state is one integer).
    """

    def __init__(self, blank: int = 0):
        self.blank = blank
        self._prev = -1          # best symbol of the previous frame (any)
        self.symbols: List[int] = []

    def feed(self, log_probs: np.ndarray) -> List[int]:
        """Consume (T_chunk, K) log-probs; return newly emitted symbols."""
        best = np.asarray(log_probs).argmax(axis=-1)
        fresh = []
        for sym in best.tolist():
            if sym != self.blank and sym != self._prev:
                fresh.append(sym)
            self._prev = sym
        self.symbols.extend(fresh)
        return fresh


@dataclasses.dataclass
class StreamSession:
    """One utterance streaming through the engine.

    ``frames``: (L, n_in) host array of queued input frames; ``cursor``
    counts frames already consumed by the engine.  Outputs accumulate in
    ``log_probs`` (list of (t, K) chunks, valid rows only) and, when a
    decoder is attached, incrementally in ``decoder.symbols``.

    Fault-tolerance fields (DESIGN.md §10): ``saved_state`` holds the
    stream's preempted per-layer ``(h, c)`` rows between eviction and
    re-admission (scattered back into the packed cache by the engine's
    admission callback, then cleared); ``error`` is the terminal fault
    string set when the stream is quarantined — an errored session is never
    retired into ``done`` and must not be resubmitted.

    ``priority`` (DESIGN.md §11) is the admission class the scheduler
    orders the pending queue by: higher values are latency-SLO streams that
    are admitted first and may displace (preempt) an active bulk stream —
    scheduling only, a stream's outputs are bit-invariant to it (§7).
    """

    sid: int
    frames: np.ndarray
    decoder: Optional[IncrementalCTCDecoder] = None
    cursor: int = 0
    priority: int = 0
    log_probs: List[np.ndarray] = dataclasses.field(default_factory=list)
    t_enqueue: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    saved_state: Optional[tuple] = None
    error: Optional[str] = None

    @property
    def remaining(self) -> int:
        """Frames not yet consumed by the engine."""
        return len(self.frames) - self.cursor

    @property
    def length(self) -> int:
        """Total utterance length in frames."""
        return len(self.frames)

    def next_chunk(self, chunk: int) -> np.ndarray:
        """The next up-to-``chunk`` frames (does not advance the cursor)."""
        return self.frames[self.cursor:self.cursor + chunk]

    def consume(self, log_probs: np.ndarray) -> None:
        """Record one processed chunk's valid-row outputs and advance."""
        n = len(log_probs)
        assert n <= self.remaining, (n, self.remaining)
        self.cursor += n
        if n and self.t_first is None:
            self.t_first = time.time()
        if n:
            self.log_probs.append(np.asarray(log_probs))
            if self.decoder is not None:
                self.decoder.feed(log_probs)

    def full_log_probs(self) -> np.ndarray:
        """Concatenated (L_consumed, K) log-probs emitted so far."""
        if not self.log_probs:
            return np.zeros((0, 0), np.float32)
        return np.concatenate(self.log_probs, axis=0)
