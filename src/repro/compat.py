"""JAX version compatibility shims.

The repo targets the modern JAX API surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``) but must
also run on the 0.4.x line, where ``shard_map`` lives under
``jax.experimental``, replication checking is spelled ``check_rep`` instead of
``check_vma``, and meshes have no axis types.  Everything that touches those
APIs imports them from here.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

try:  # modern spelling (jax >= 0.6)
    from jax.sharding import AxisType
    _HAS_AXIS_TYPES = True
except ImportError:  # 0.4.x: axis types don't exist; Auto is the only behaviour
    _HAS_AXIS_TYPES = False

    class AxisType:  # type: ignore[no-redef]
        Auto = 'auto'
        Explicit = 'explicit'
        Manual = 'manual'

try:
    from jax import shard_map as _shard_map  # jax >= 0.6
    _CHECK_KW = 'check_vma'
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = 'check_rep'


def shard_map(f=None, /, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the replication-check kwarg renamed per version."""
    kwargs = {'mesh': mesh, 'in_specs': in_specs, 'out_specs': out_specs,
              _CHECK_KW: check_vma}
    if f is None:
        return functools.partial(_shard_map, **kwargs)
    return _shard_map(f, **kwargs)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              axis_types: Optional[Sequence] = None,
              devices=None) -> Mesh:
    """``jax.make_mesh`` accepting (and ignoring, pre-0.6) ``axis_types``."""
    if _HAS_AXIS_TYPES and axis_types is not None:
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types,
                             devices=devices)
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def mesh_with_axis_types(devices_array, axis_names, axis_types=None) -> Mesh:
    """``Mesh(...)`` constructor accepting (and ignoring, pre-0.6) axis types."""
    if _HAS_AXIS_TYPES and axis_types is not None:
        return Mesh(devices_array, axis_names, axis_types=axis_types)
    return Mesh(devices_array, axis_names)


__all__ = ['AxisType', 'shard_map', 'make_mesh', 'mesh_with_axis_types']
