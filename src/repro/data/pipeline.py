"""Deterministic, restartable data pipeline with host sharding + prefetch.

Design points required at 1000-node scale:
  * step-indexed randomness — batch t is a pure function of (seed, step), so a
    restarted/elastically-rescaled job resumes mid-epoch with no state to
    replicate (the checkpoint only stores the step counter);
  * per-host sharding — every host materialises only its slice of the global
    batch (``jax.process_index()`` addressing), then assembles the global
    jax.Array from local shards;
  * background prefetch — a bounded queue hides host-side generation latency
    behind device compute (compute/IO overlap).

Sources: synthetic LM token streams, a memory-mapped token-file reader, and a
synthetic MFCC/phoneme source for the paper's CTC workload.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ArchConfig, ShapeConfig


@dataclasses.dataclass
class PipelineConfig:
    seed: int = 0
    prefetch: int = 2


def _rng_for_step(seed: int, step: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=seed, counter=step))


class SyntheticLM:
    """Zipf-ish token stream; labels are next-token shifted."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, seed: int = 0):
        self.cfg, self.shape, self.seed = cfg, shape, seed

    def host_batch(self, step: int, lo: int, hi: int) -> Dict[str, np.ndarray]:
        rng = _rng_for_step(self.seed, step)
        b, s, v = self.shape.global_batch, self.shape.seq_len, self.cfg.vocab_size
        # draw the *global* batch deterministically, slice this host's rows —
        # cheap at synthetic speeds and keeps cross-host consistency trivial.
        zipf = np.minimum(rng.zipf(1.3, size=(b, s + 1)), v) - 1
        toks = zipf.astype(np.int32)[lo:hi]
        out = {'tokens': toks[:, :-1], 'labels': toks[:, 1:]}
        if self.cfg.family in ('audio', 'vlm'):
            out['source'] = rng.standard_normal(
                (hi - lo, self.cfg.n_source_tokens, self.cfg.d_model),
                dtype=np.float32)
        return out


class TokenFile:
    """Memory-mapped uint16/uint32 token corpus with random-window sampling."""

    def __init__(self, path: str, cfg: ArchConfig, shape: ShapeConfig,
                 seed: int = 0, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode='r')
        self.cfg, self.shape, self.seed = cfg, shape, seed

    def host_batch(self, step: int, lo: int, hi: int) -> Dict[str, np.ndarray]:
        rng = _rng_for_step(self.seed, step)
        s = self.shape.seq_len
        starts = rng.integers(0, len(self.tokens) - s - 1,
                              size=self.shape.global_batch)[lo:hi]
        rows = np.stack([self.tokens[st:st + s + 1] for st in starts])
        rows = rows.astype(np.int32) % self.cfg.vocab_size
        return {'tokens': rows[:, :-1], 'labels': rows[:, 1:]}


class SyntheticCTC:
    """MFCC-frame/phoneme-label pairs for CTC-3L-421H-UNI (paper Sec. 4.2)."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, seed: int = 0):
        self.cfg, self.shape, self.seed = cfg, shape, seed

    def host_batch(self, step: int, lo: int, hi: int) -> Dict[str, np.ndarray]:
        rng = _rng_for_step(self.seed, step)
        b = self.shape.global_batch
        t = self.shape.seq_len
        n_lab = max(t // 8, 1)
        frames = rng.standard_normal(
            (b, t, self.cfg.lstm_inputs), dtype=np.float32)
        labels = rng.integers(1, self.cfg.n_outputs, size=(b, n_lab),
                              dtype=np.int32)
        frame_len = rng.integers(t // 2, t + 1, size=(b,), dtype=np.int32)
        label_len = np.minimum(rng.integers(1, n_lab + 1, size=(b,)),
                               frame_len // 2).astype(np.int32)
        out = {'frames': frames[lo:hi], 'labels': labels[lo:hi],
               'frame_len': frame_len[lo:hi], 'label_len': label_len[lo:hi]}
        return out


def source_for(cfg: ArchConfig, shape: ShapeConfig, seed=0,
               token_file: Optional[str] = None):
    if cfg.family == 'lstm':
        return SyntheticCTC(cfg, shape, seed)
    if token_file:
        return TokenFile(token_file, cfg, shape, seed)
    return SyntheticLM(cfg, shape, seed)


class ShardedLoader:
    """Assemble global jax.Arrays from per-host shards, with prefetch."""

    def __init__(self, source, shape: ShapeConfig, shardings: Dict[str, Any],
                 start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.shape = shape
        self.shardings = shardings
        self.step = start_step
        n_proc = jax.process_count()
        per = shape.global_batch // n_proc
        self.lo = jax.process_index() * per
        self.hi = self.lo + per
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _assemble(self, host: Dict[str, np.ndarray], step: int):
        out = {}
        for k, v in host.items():
            sh = self.shardings.get(k)
            if sh is None:
                out[k] = jnp.asarray(v)
            else:
                gshape = (self.shape.global_batch,) + v.shape[1:]
                out[k] = jax.make_array_from_process_local_data(sh, v, gshape)
        return out

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            host = self.source.host_batch(step, self.lo, self.hi)
            try:
                self._q.put((step, host), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, host = self._q.get()
        return step, self._assemble(host, step)

    def close(self):
        self._stop.set()
