from .pipeline import ShardedLoader, SyntheticCTC, SyntheticLM, TokenFile, source_for
