"""The paper's own workload: CTC-3L-421H-UNI (Graves et al. [1]) — 3-layer
421-hidden-unit unidirectional LSTM over 123 MFCC features, 62 CTC outputs
(61 phonemes + blank), ~3.8M weights.  Runs on the chipmunk systolic core."""
from . import ArchConfig

CONFIG = ArchConfig(
    name='chipmunk-ctc', family='lstm',
    n_layers=3, d_model=421, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=62, lstm_hidden=421, lstm_inputs=123, n_outputs=62,
    param_dtype='float32', activation_dtype='float32',
    optimizer='adamw', remat='none',
)

SMOKE = CONFIG.replace(
    name='chipmunk-smoke', n_layers=2, d_model=32, lstm_hidden=32,
    lstm_inputs=13, vocab_size=16, n_outputs=16)
