"""Whisper-base [arXiv:2212.04356]: enc-dec, 6+6L, d_model 512, 8H, d_ff 2048,
vocab 51865.  Conv frontend is a STUB per assignment: input_specs() provides
precomputed frame embeddings (B, 1500, 512)."""
from . import ArchConfig

CONFIG = ArchConfig(
    name='whisper-base', family='audio',
    n_layers=6, n_encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865, n_source_tokens=1500,
    norm='layernorm', act='gelu',
    param_dtype='float32', optimizer='adamw', remat='none',
)

SMOKE = CONFIG.replace(
    name='whisper-smoke', n_layers=2, n_encoder_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, n_source_tokens=32)
