"""MiniCPM-2B [arXiv:2404.06395; hf]: 40L, d_model 2304, 36H MHA (kv=36),
d_ff 5760, vocab 122753, llama-like arch, WSD schedule (see optim/)."""
from . import ArchConfig

CONFIG = ArchConfig(
    name='minicpm-2b', family='dense',
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab_size=122753, tie_embeddings=True,
    param_dtype='float32', optimizer='adamw', remat='full',
)

SMOKE = CONFIG.replace(
    name='minicpm-smoke', n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, remat='none')
