"""Architecture + shape configuration registry.

One module per assigned architecture (public-literature configs, see each file's
citation) plus the paper's own CTC-3L-421H-UNI LSTM.  ``get_config(name)`` returns
the full config; ``get_smoke_config(name)`` returns a reduced same-family config
for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    capacity_factor: float = 1.25
    router_dtype: str = 'float32'


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm | lstm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None    # SWA width (mixtral, hymba)
    global_layer_ids: Tuple[int, ...] = ()  # full-attn layers in SWA models
    cross_attn_every: Optional[int] = None  # vlm: 1 cross layer per N
    n_source_tokens: int = 0                # audio/vlm stub frontend length
    rope_theta: float = 10_000.0
    norm: str = 'rmsnorm'                   # rmsnorm | layernorm
    act: str = 'silu'                       # silu (SwiGLU) | gelu
    tie_embeddings: bool = False
    # recurrent families
    ssm_state: int = 0                      # mamba state dim (hybrid)
    xlstm_slstm_every: int = 0              # ssm family: 1 sLSTM per N blocks
    conv_kernel: int = 4
    # encoder-decoder (audio)
    n_encoder_layers: int = 0
    # paper-native LSTM family
    lstm_hidden: int = 0
    lstm_inputs: int = 0
    n_outputs: int = 0
    # numerics / execution
    param_dtype: str = 'float32'
    activation_dtype: str = 'bfloat16'
    remat: str = 'full'                     # none | full | dots
    attn_chunk: int = 512                   # kv blocking for chunked attention
    use_pallas: bool = False                # TPU path; off for CPU/dry-run
    # auto | xla_scan | pallas_step | pallas_seq | pallas_seq_fused |
    # pallas_seq_systolic | pallas_seq_fused_systolic (core.lstm.BACKENDS;
    # 'auto' also consults the installed systolic mesh — stage-aware for
    # stacks — and the stack-level fused-kernel admission)
    lstm_backend: str = 'auto'
    optimizer: str = 'adamw'                # adamw | adafactor | sgd
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def adtype(self):
        return jnp.dtype(self.activation_dtype)

    def replace(self, **kw) -> 'ArchConfig':
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == 'train'


# The four assigned LM shapes (identical across the 10 LM-family archs).
SHAPES: Dict[str, ShapeConfig] = {
    'train_4k': ShapeConfig('train_4k', 'train', 4_096, 256),
    'prefill_32k': ShapeConfig('prefill_32k', 'prefill', 32_768, 32),
    'decode_32k': ShapeConfig('decode_32k', 'decode', 32_768, 128),
    'long_500k': ShapeConfig('long_500k', 'decode', 524_288, 1),
}

ARCH_MODULES = {
    'xlstm-1.3b': 'xlstm_1_3b',
    'kimi-k2-1t-a32b': 'kimi_k2_1t_a32b',
    'mixtral-8x22b': 'mixtral_8x22b',
    'qwen3-14b': 'qwen3_14b',
    'minicpm-2b': 'minicpm_2b',
    'codeqwen1.5-7b': 'codeqwen15_7b',
    'qwen2.5-14b': 'qwen25_14b',
    'whisper-base': 'whisper_base',
    'llama-3.2-vision-90b': 'llama32_vision_90b',
    'hymba-1.5b': 'hymba_1_5b',
    'chipmunk-ctc': 'chipmunk_ctc',
}

ASSIGNED_ARCHS = [a for a in ARCH_MODULES if a != 'chipmunk-ctc']


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f'.{ARCH_MODULES[name]}', __package__)
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f'.{ARCH_MODULES[name]}', __package__)
    return mod.SMOKE


def long_context_supported(cfg: ArchConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (see DESIGN.md §4a)."""
    return (cfg.family in ('ssm', 'hybrid')
            or (cfg.sliding_window is not None and not cfg.global_layer_ids))


def shapes_for(cfg: ArchConfig):
    out = []
    for s in SHAPES.values():
        if s.name == 'long_500k' and not long_context_supported(cfg):
            continue  # documented skip: quadratic KV at 524k is not runnable
        out.append(s)
    return out
