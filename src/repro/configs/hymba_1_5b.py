"""Hymba-1.5B [arXiv:2411.13676; hf]: 32L, d_model 1600, 25H/5KV, d_ff 5504,
vocab 32001, parallel attention + Mamba heads per layer, ssm_state 16,
SWA everywhere except 3 global full-attention layers (first/middle/last)."""
from . import ArchConfig

CONFIG = ArchConfig(
    name='hymba-1.5b', family='hybrid',
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, ssm_state=16, sliding_window=1024,
    global_layer_ids=(0, 15, 31), conv_kernel=4,
    param_dtype='float32', optimizer='adamw', remat='full',
)

SMOKE = CONFIG.replace(
    name='hymba-smoke', n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, ssm_state=8, sliding_window=16,
    global_layer_ids=(0, 3), remat='none')
