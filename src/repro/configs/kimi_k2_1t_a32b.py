"""Kimi K2 (trillion-param MoE, paper-table config) [arXiv:2501.kimi2]:
61L, d_model 7168, 64 q-heads / 8 kv (GQA), 384 experts top-8, expert d_ff 2048,
vocab 163840.  Active ~32B/token.  Weight-stationarity at pod scale (EP) is the
Chipmunk thesis applied to 10^6x larger weights."""
from . import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name='kimi-k2-1t-a32b', family='moe',
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048),
    param_dtype='bfloat16', optimizer='adafactor', remat='full',
)

SMOKE = CONFIG.replace(
    name='kimi-smoke', n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, moe=MoEConfig(n_experts=8, top_k=2, d_ff=128),
    param_dtype='float32', remat='none')
