"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B]: 32L, d_model 4096, 32H MHA,
d_ff 13440, vocab 92416, QKV bias (qwen1.5 arch)."""
from . import ArchConfig

CONFIG = ArchConfig(
    name='codeqwen1.5-7b', family='dense',
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
    vocab_size=92416, qkv_bias=True,
    param_dtype='bfloat16', optimizer='adamw', remat='full',
)

SMOKE = CONFIG.replace(
    name='codeqwen-smoke', n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, param_dtype='float32', remat='none')
