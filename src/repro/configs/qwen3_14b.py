"""Qwen3-14B [hf:Qwen/Qwen3-8B family]: 40L, d_model 5120, 40H/8KV GQA,
d_ff 17408, vocab 151936, qk-norm (per-head RMSNorm on q,k)."""
from . import ArchConfig

CONFIG = ArchConfig(
    name='qwen3-14b', family='dense',
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=17408,
    vocab_size=151936, qk_norm=True, head_dim=128, rope_theta=1e6,
    param_dtype='bfloat16', optimizer='adamw', remat='full',
)

SMOKE = CONFIG.replace(
    name='qwen3-smoke', n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=256, param_dtype='float32', remat='none')
