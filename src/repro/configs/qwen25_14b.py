"""Qwen2.5-14B [hf:Qwen/Qwen2.5 family]: 48L, d_model 5120, 40H/8KV GQA,
d_ff 13824, vocab 152064, QKV bias."""
from . import ArchConfig

CONFIG = ArchConfig(
    name='qwen2.5-14b', family='dense',
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    param_dtype='bfloat16', optimizer='adamw', remat='full',
)

SMOKE = CONFIG.replace(
    name='qwen25-smoke', n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, param_dtype='float32', remat='none')
