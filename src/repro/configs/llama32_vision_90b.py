"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-*-Vision]: 100L total,
d_model 8192, 64H/8KV GQA, d_ff 28672, vocab 128256; cross-attention image
layers interleaved 1-per-5.  Vision frontend is a STUB: input_specs() provides
pre-projected patch embeddings (B, 1024, 8192)."""
from . import ArchConfig

CONFIG = ArchConfig(
    name='llama-3.2-vision-90b', family='vlm',
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, cross_attn_every=5, n_source_tokens=1024,
    rope_theta=5e5,
    param_dtype='bfloat16', optimizer='adafactor', remat='full',
)

SMOKE = CONFIG.replace(
    name='llama-vision-smoke', n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, n_source_tokens=16,
    param_dtype='float32', remat='none')
