"""Mixtral 8x22B [arXiv:2401.04088; hf]: 56L, d_model 6144, 48H/8KV GQA,
8 experts top-2 (d_ff 16384), sliding-window attention, vocab 32768.
SWA bounds the KV cache, so long_500k is runnable."""
from . import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name='mixtral-8x22b', family='moe',
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768, sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=16384),
    param_dtype='bfloat16', optimizer='adafactor', remat='full',
)

SMOKE = CONFIG.replace(
    name='mixtral-smoke', n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, sliding_window=16,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=128),
    param_dtype='float32', remat='none')
