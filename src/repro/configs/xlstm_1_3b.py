"""xLSTM-1.3B [arXiv:2405.04517]: 48 blocks, d_model 2048, 4 mLSTM heads,
sLSTM interleaved 1-per-8 (paper ratio 7:1).  d_ff=0: projections live inside
the m/sLSTM blocks.  The sLSTM recurrence is the Chipmunk-native workload."""
from . import ArchConfig

CONFIG = ArchConfig(
    name='xlstm-1.3b', family='ssm',
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, xlstm_slstm_every=8, conv_kernel=4,
    param_dtype='float32', optimizer='adamw',
)

SMOKE = CONFIG.replace(
    name='xlstm-smoke', n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
    vocab_size=256, xlstm_slstm_every=2, remat='none')
