"""Sharded, async, elastic checkpointing (hand-rolled; no orbax offline).

Layout (per step)::

    <dir>/step_000100.tmp/        # written first, renamed on commit (atomic)
    <dir>/step_000100/
        manifest.json             # tree structure, shapes, dtypes, checksums
        leaf_00000.npy ...        # one file per pytree leaf

Properties needed at scale:
  * async — ``save()`` snapshots to host memory synchronously (cheap), then a
    background thread writes files; training never blocks on the filesystem;
  * atomic — partially-written checkpoints can never be restored (tmp+rename);
  * elastic — leaves are stored as *full* logical arrays; ``restore`` places
    them under any mesh/sharding, so a job can restart on a different
    topology (node failures, pod resizes) — DESIGN.md §5;
  * self-validating — manifest carries per-leaf checksums.

At true 1000-node scale the full-array gather is replaced by per-shard files
(each host writes ``jax.Array.addressable_shards``); the manifest format
already records shard metadata to allow that layout (``sharded=True``).
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, blocking: bool = False):
        """Snapshot now, write in the background (unless blocking)."""
        self.wait()                                     # one in flight max
        host_leaves = self._snapshot(state)
        if blocking:
            self._write(step, host_leaves)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host_leaves),
                daemon=True)
            self._thread.start()

    def _snapshot(self, state):
        flat, _ = _flatten_with_paths(state)
        # device -> host gather; full logical value per leaf (elastic layout)
        return [(path, np.asarray(jax.device_get(leaf))) for path, leaf in flat]

    def _write_guarded(self, step, leaves):
        try:
            self._write(step, leaves)
        except BaseException as e:                      # surfaced by wait()
            self._error = e

    def _write(self, step, leaves):
        final = self.dir / f'step_{step:08d}'
        tmp = self.dir / f'step_{step:08d}.tmp'
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {'step': step, 'time': time.time(), 'sharded': False,
                    'leaves': []}
        for i, (path, arr) in enumerate(leaves):
            fname = f'leaf_{i:05d}.npy'
            np.save(tmp / fname, arr)
            manifest['leaves'].append({
                'path': path, 'file': fname, 'shape': list(arr.shape),
                'dtype': str(arr.dtype),
                'sha1': hashlib.sha1(arr.tobytes()).hexdigest()[:16],
            })
        (tmp / 'manifest.json').write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                               # commit point
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError('async checkpoint write failed') from err

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f'step_{s:08d}', ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        return sorted(int(p.name.split('_')[1]) for p in self.dir.glob('step_*')
                      if p.is_dir() and not p.name.endswith('.tmp'))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, state_like, step: Optional[int] = None,
                shardings=None, validate: bool = True,
                match_paths: bool = True):
        """Restore into the structure of ``state_like`` (arrays or SDS).

        ``shardings``: optional matching pytree of NamedShardings — pass the
        *new* topology's shardings to re-shard elastically on restore.

        ``match_paths``: validate each manifest leaf's recorded tree path
        against the target pytree's path (not just leaf COUNT) — restoring a
        checkpoint into a structurally different state (renamed field,
        reordered dict keys, wrong model) fails loudly, naming the first
        mismatched leaf, instead of silently loading arrays positionally.
        Set False only when deliberately remapping structures.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f'no checkpoints under {self.dir}')
        d = self.dir / f'step_{step:08d}'
        manifest = json.loads((d / 'manifest.json').read_text())
        pathed, treedef = _flatten_with_paths(state_like)
        flat = [leaf for _, leaf in pathed]
        sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
                   if shardings is not None else [None] * len(flat))
        if len(manifest['leaves']) != len(flat):
            raise ValueError(
                f'checkpoint has {len(manifest["leaves"])} leaves, '
                f'target has {len(flat)}')
        if match_paths:
            for i, (meta, (path, _)) in enumerate(zip(manifest['leaves'],
                                                      pathed)):
                if meta.get('path') is not None and meta['path'] != path:
                    raise ValueError(
                        f'checkpoint/target tree mismatch at leaf {i}: '
                        f'checkpoint has {meta["path"]!r}, target has '
                        f'{path!r} (pass match_paths=False to load '
                        f'positionally)')
        out = []
        for meta, target, sh in zip(manifest['leaves'], flat, sh_flat):
            arr = np.load(d / meta['file'])
            if validate:
                got = hashlib.sha1(arr.tobytes()).hexdigest()[:16]
                if got != meta['sha1']:
                    raise IOError(f'checksum mismatch for {meta["path"]}')
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
