from .manager import CheckpointManager
