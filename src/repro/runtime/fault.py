"""Fault tolerance + straggler mitigation for the training AND serving loops.

What a 1000+ node deployment needs and what we implement:

  * **checkpoint/restart** — every failure path funnels into "restore latest
    checkpoint and continue"; combined with the elastic restore in
    checkpoint/manager.py this also covers topology changes after node loss.
  * **retry with backoff** — transient faults (preemption notices, flaky
    interconnect RPCs) retry the step before escalating to restore.
  * **deadline watchdog** — an optional per-step deadline; steps that
    overrun it are recorded as ``deadline_miss`` events (an in-process
    watchdog observes overruns post-hoc — it cannot preempt a running XLA
    dispatch — so the sound reaction is to log, count, and let the caller's
    policy decide: the serving layer shrinks the next chunk or sheds load,
    a supervisor kills a persistently-late job).
  * **heartbeat** — a progress record external supervisors watch (kept
    in-memory as ``last_heartbeat`` and optionally mirrored to a file); a
    stuck job (no heartbeat for k x step-time) is killed+rescheduled by the
    supervisor, which is the only sound cross-host action (in-process
    watchdogs cannot observe a wedged XLA collective).
  * **straggler detection** — per-step EWMA of step time; steps slower than
    ``threshold x`` EWMA are logged as straggler events.  On real pods the
    mitigation is re-sharding around the slow host (elastic restore) — here we
    record the decision so the policy is testable.
  * **failure injection** — deterministic fault schedule for tests.  The
    schedule may return/raise a *specific* exception instance (e.g.
    ``runtime.serving_faults.EngineFailure``) so handlers can react by type.

The runner is deliberately workload-agnostic: ``run_step`` drives the
training ``(state, batch) -> (state, metrics)`` contract, and the
generalized ``run`` drives ANY zero-arg attempt (the serving engine's
packed chunk dispatch, ``serving/engine.py``) under the same
injection/retry/deadline/heartbeat machinery.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import pathlib
import time
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class FaultConfig:
    max_retries: int = 3
    backoff_s: float = 0.1
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    heartbeat_path: Optional[str] = None
    # optional per-step deadline (seconds); overruns are recorded as
    # ``deadline_miss`` events, never raised (see module docstring)
    deadline_s: Optional[float] = None
    # permanent (non-transient) faults get their own budget: they must not
    # burn the transient retry budget before the fault hook fires, but an
    # unbounded degrade loop is still a bug — cap it well above any ladder
    max_permanent: int = 8
    # bound the event log (None = unbounded); see RingLog
    event_log_cap: Optional[int] = None


class RingLog:
    """Bounded append-only event log for long-lived serving processes.

    A fixed-capacity ring over structured event dicts: appends past the cap
    silently evict the OLDEST entries and bump ``dropped`` (the operator's
    truncation signal, surfaced by ``StreamingEngine.stats()`` as
    ``events_dropped``).  ``cap=None`` is unbounded (the historical list
    behaviour).  List-compatible where the test/stats surface needs it:
    iteration, ``len``, indexing, ``==`` against lists, and ``+``
    concatenation all behave like the equivalent list of retained events.
    """

    def __init__(self, cap: Optional[int] = None):
        self.cap = None if cap is None else int(cap)
        if self.cap is not None and self.cap < 1:
            raise ValueError(f'event log cap must be >= 1, got {self.cap}')
        self._d: collections.deque = collections.deque(maxlen=self.cap)
        self.dropped = 0

    def append(self, item) -> None:
        """Append one event, evicting the oldest (and counting the drop)
        when the ring is full."""
        if self.cap is not None and len(self._d) == self.cap:
            self.dropped += 1
        self._d.append(item)

    def extend(self, items) -> None:
        """Append every event of ``items`` in order (ring semantics each)."""
        for item in items:
            self.append(item)

    def clear(self) -> None:
        """Drop all retained events (does not reset ``dropped``)."""
        self._d.clear()

    def __len__(self) -> int:
        return len(self._d)

    def __iter__(self):
        return iter(self._d)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return list(self._d)[i]
        return self._d[i]

    def __add__(self, other):
        return list(self._d) + list(other)

    def __radd__(self, other):
        return list(other) + list(self._d)

    def __eq__(self, other):
        return list(self._d) == list(other)

    def __repr__(self) -> str:
        return (f'RingLog(cap={self.cap}, n={len(self._d)}, '
                f'dropped={self.dropped})')


class StepTimer:
    """EWMA step-time tracker + straggler classifier."""

    def __init__(self, alpha: float, factor: float):
        self.alpha, self.factor = alpha, factor
        self.ewma: Optional[float] = None
        self.stragglers: List[Dict] = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = (self.ewma is not None
                        and dt > self.factor * self.ewma)
        if is_straggler:
            self.stragglers.append({'step': step, 'dt': dt, 'ewma': self.ewma})
        # slow steps do not poison the baseline
        if self.ewma is None:
            self.ewma = dt
        elif not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class FaultTolerantRunner:
    """Retry/restore/deadline driver for any repeated step-shaped workload.

    Two entry points share one loop (``run``):

      * ``run_step(step, state, batch)`` — the training contract
        ``(state, batch) -> (state, metrics)``; on a fault the optional
        ``restore_fn`` replaces ``state`` before the retry (checkpoint
        restart).
      * ``run(step, fn, on_fault=...)`` — the generalized contract: drive
        any zero-arg attempt with injection/retry/backoff, the deadline
        watchdog, straggler tracking, and heartbeats.  ``on_fault(exc,
        attempt)`` runs between a failed attempt and its retry — the
        serving engine uses it to degrade its backend down the ladder
        (``runtime/serving_faults.py``) before recomputing the chunk.

    Every runner constructs its own ``FaultConfig`` when none is given
    (``cfg=None`` default — never a shared mutable default instance).
    """

    def __init__(self, step_fn: Optional[Callable] = None, ckpt_manager=None,
                 cfg: Optional[FaultConfig] = None,
                 restore_fn: Optional[Callable] = None,
                 fail_schedule: Optional[Callable[[int], Any]] = None,
                 on_fault: Optional[Callable[[BaseException, int],
                                             None]] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.cfg = cfg if cfg is not None else FaultConfig()
        self.restore_fn = restore_fn
        self.fail_schedule = fail_schedule
        self.on_fault = on_fault
        self.timer = StepTimer(self.cfg.ewma_alpha, self.cfg.straggler_factor)
        self.events = RingLog(self.cfg.event_log_cap)
        self.deadline_misses = 0
        self.last_heartbeat: Optional[Dict] = None
        self.last_fault_domain: Optional[int] = None

    def _heartbeat(self, step: int):
        payload = {'step': step, 'time': time.time(),
                   'ewma_step_s': self.timer.ewma,
                   'deadline_misses': self.deadline_misses,
                   'fault_domain': self.last_fault_domain}
        self.last_heartbeat = payload
        if self.cfg.heartbeat_path:
            pathlib.Path(self.cfg.heartbeat_path).write_text(
                json.dumps(payload))

    def _injected(self, step: int) -> Optional[BaseException]:
        """Consult the fault schedule; promote truthy results to exceptions."""
        if self.fail_schedule is None:
            return None
        fault = self.fail_schedule(step)
        if not fault:
            return None
        if isinstance(fault, BaseException):
            return fault
        return RuntimeError(f'injected fault at step {step}')

    def run(self, step: int, fn: Callable[[], Any],
            on_fault: Optional[Callable[[BaseException, int], None]] = None,
            retry_fn: Optional[Callable[[], Any]] = None,
            launched_at: Optional[float] = None,
            deadline_s: Optional[float] = None):
        """Drive one attempt of ``fn`` to success under the fault machinery.

        Injects scheduled faults (first attempt only), retries with linear
        backoff up to ``cfg.max_retries`` (then re-raises), records
        straggler and ``deadline_miss`` events, and emits a heartbeat on
        success.  ``on_fault`` (per-call, else the constructor's) runs
        between a failed attempt and the retry.  Returns ``fn()``'s result.

        Async dispatch support (DESIGN.md §11): when the work was launched
        non-blocking BEFORE this call and ``fn`` merely resolves it,
        ``launched_at`` pins the step's start time, so the deadline/straggler
        duration is charged from launch to COMMIT (resolution), never just
        the resolve wait — an async chunk that comes back late is a
        ``deadline_miss`` even though its launch returned instantly.
        ``retry_fn``, when given, replaces ``fn`` from the second attempt on:
        a resolved-future attempt cannot be replayed, so retries run a fresh
        synchronous recompute (timed from their own start).  ``deadline_s``
        overrides ``cfg.deadline_s`` per call — the serving engine derives
        it per chunk when the chunk length varies under a size policy.

        Fault taxonomy (§14): an exception whose ``transient`` attribute is
        ``False`` (a permanent ``EngineFailure``) does NOT burn the
        transient retry budget — the fault hook fires on its first attempt
        and the loop keeps retrying under the separate ``max_permanent``
        cap (a safety backstop, not a policy knob: the hook's degradation
        ladder bottoms out long before it).  Exceptions without the
        attribute default to transient — the historical retry behaviour.
        Fault events carry ``transient`` and ``domain``; the heartbeat
        carries the last-seen ``fault_domain``.
        """
        on_fault = on_fault if on_fault is not None else self.on_fault
        deadline = deadline_s if deadline_s is not None else self.cfg.deadline_s
        attempts = 0       # transient faults charged to the retry budget
        permanent = 0      # permanent faults (degrade path, separate cap)
        while True:
            total = attempts + permanent
            try:
                if total == 0:
                    injected = self._injected(step)
                    if injected is not None:
                        raise injected
                t0 = time.time()
                if total == 0 and launched_at is not None:
                    t0 = launched_at
                out = fn() if (total == 0 or retry_fn is None) \
                    else retry_fn()
                dt = time.time() - t0
                if self.timer.observe(step, dt):
                    self.events.append({'kind': 'straggler', 'step': step,
                                        'dt': dt})
                if deadline is not None and dt > deadline:
                    self.deadline_misses += 1
                    self.events.append({'kind': 'deadline_miss', 'step': step,
                                        'dt': dt,
                                        'deadline_s': deadline})
                self._heartbeat(step)
                return out
            except Exception as e:           # noqa: BLE001 — retry any fault
                transient = bool(getattr(e, 'transient', True))
                domain = getattr(e, 'domain', None)
                if transient:
                    attempts += 1
                else:
                    permanent += 1
                if domain is not None:
                    self.last_fault_domain = domain
                self.events.append({'kind': 'fault', 'step': step,
                                    'attempt': attempts + permanent,
                                    'error': repr(e),
                                    'transient': transient,
                                    'domain': domain})
                if attempts > self.cfg.max_retries \
                        or permanent > self.cfg.max_permanent:
                    raise
                if transient:
                    time.sleep(self.cfg.backoff_s * attempts)
                if on_fault is not None:
                    on_fault(e, attempts + permanent)

    def run_step(self, step: int, state, batch):
        """Training-loop contract: ``(state, batch) -> (state, metrics)``
        with retry + checkpoint-restore semantics (``restore_fn`` replaces
        the carried state before a retry)."""
        box = [state]

        def attempt():
            return self.step_fn(box[0], batch)

        def restore(e, attempts):
            if self.restore_fn is not None:
                box[0] = self.restore_fn()
                self.events.append({'kind': 'restore', 'step': step})

        return self.run(step, attempt, on_fault=restore)
