"""Fault tolerance + straggler mitigation for the training loop.

What a 1000+ node deployment needs and what we implement:

  * **checkpoint/restart** — every failure path funnels into "restore latest
    checkpoint and continue"; combined with the elastic restore in
    checkpoint/manager.py this also covers topology changes after node loss.
  * **retry with backoff** — transient faults (preemption notices, flaky
    interconnect RPCs) retry the step before escalating to restore.
  * **heartbeat** — a progress file external supervisors watch; a stuck job
    (no heartbeat for k x step-time) is killed+rescheduled by the supervisor,
    which is the only sound cross-host action (in-process watchdogs cannot
    observe a wedged XLA collective).
  * **straggler detection** — per-step EWMA of step time; steps slower than
    ``threshold x`` EWMA are logged as straggler events.  On real pods the
    mitigation is re-sharding around the slow host (elastic restore) — here we
    record the decision so the policy is testable.
  * **failure injection** — deterministic fault schedule for tests.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class FaultConfig:
    max_retries: int = 3
    backoff_s: float = 0.1
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    heartbeat_path: Optional[str] = None


class StepTimer:
    """EWMA step-time tracker + straggler classifier."""

    def __init__(self, alpha: float, factor: float):
        self.alpha, self.factor = alpha, factor
        self.ewma: Optional[float] = None
        self.stragglers: List[Dict] = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = (self.ewma is not None
                        and dt > self.factor * self.ewma)
        if is_straggler:
            self.stragglers.append({'step': step, 'dt': dt, 'ewma': self.ewma})
        # slow steps do not poison the baseline
        if self.ewma is None:
            self.ewma = dt
        elif not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class FaultTolerantRunner:
    """Drives (state, batch) -> (state, metrics) with retry/restore semantics."""

    def __init__(self, step_fn: Callable, ckpt_manager=None,
                 cfg: FaultConfig = FaultConfig(),
                 restore_fn: Optional[Callable] = None,
                 fail_schedule: Optional[Callable[[int], bool]] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.cfg = cfg
        self.restore_fn = restore_fn
        self.fail_schedule = fail_schedule
        self.timer = StepTimer(cfg.ewma_alpha, cfg.straggler_factor)
        self.events: List[Dict] = []

    def _heartbeat(self, step: int, metrics):
        if self.cfg.heartbeat_path:
            payload = {'step': step, 'time': time.time(),
                       'ewma_step_s': self.timer.ewma}
            pathlib.Path(self.cfg.heartbeat_path).write_text(
                json.dumps(payload))

    def run_step(self, step: int, state, batch):
        attempts = 0
        while True:
            try:
                if self.fail_schedule and self.fail_schedule(step) \
                        and attempts == 0:
                    raise RuntimeError(f'injected fault at step {step}')
                t0 = time.time()
                state, metrics = self.step_fn(state, batch)
                dt = time.time() - t0
                if self.timer.observe(step, dt):
                    self.events.append({'kind': 'straggler', 'step': step,
                                        'dt': dt})
                self._heartbeat(step, metrics)
                return state, metrics
            except Exception as e:           # noqa: BLE001 — retry any fault
                attempts += 1
                self.events.append({'kind': 'fault', 'step': step,
                                    'attempt': attempts, 'error': repr(e)})
                if attempts > self.cfg.max_retries:
                    raise
                time.sleep(self.cfg.backoff_s * attempts)
                if self.restore_fn is not None:
                    state = self.restore_fn()
                    self.events.append({'kind': 'restore', 'step': step})
