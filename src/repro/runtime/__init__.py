from .fault import FaultConfig, FaultTolerantRunner, StepTimer
from .serving_faults import (ChunkSizePolicy, EngineFailure,
                             ServingFaultConfig, StreamStateCheckpointer,
                             chunk_deadline_s, elastic_replace, finite_slots)
