from .fault import FaultConfig, FaultTolerantRunner, StepTimer
