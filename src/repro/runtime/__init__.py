from .fault import FaultConfig, FaultTolerantRunner, RingLog, StepTimer
from .recovery import MeshHealthTracker, Rung, build_rungs
from .serving_faults import (ChunkSizePolicy, EngineFailure,
                             ServingFaultConfig, StreamStateCheckpointer,
                             chunk_deadline_s, elastic_replace, finite_slots)
