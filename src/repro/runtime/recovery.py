"""Elastic recovery runtime: health-tracked fault domains and the canary-
validated climb BACK UP the degradation ladder (DESIGN.md §14).

PR 6 built the failure-*reaction* half of fault tolerance: injected
``EngineFailure``s step serving down ``core.lstm.DEGRADATION_LADDER`` with
elastic state re-placement and no stream loss — but the fleet could only
get slower, because a recovered mesh was never re-admitted.  This module is
the *recovery* half, per the Chipmunk follow-up "Vau da Muntanialas"
(PAPERS.md), where fault domains are DIES of a two-level mesh and the
systolic array re-forms as dies come and go:

  * ``Rung`` / ``build_rungs`` — the degradation ladder materialised as an
    explicit rung list: on a two-level ``launch.mesh.DieMesh`` the top
    rungs are the same staged backend on progressively fewer dies (real
    intermediate rungs: graves-3x25 runs 75 -> 50 -> 25 engines), below
    which the ladder continues through the flat single-host backends down
    to ``xla_scan``.  Every rung records how many healthy fault domains it
    needs, which is what makes capacity a pure function of tracker state.
  * ``MeshHealthTracker`` — per-domain health fed by the injection
    schedules (``ServingFaultConfig.fail_at`` / ``recover_at``), with
    exponential-backoff hysteresis: a failure landing inside the
    post-promotion window doubles the backoff, as does a rejected canary,
    so a flapping engine settles at the hysteresis floor instead of
    oscillating the backend (never more than one promotion per window).
  * the **canary protocol** lives in ``serving/engine.py`` on top of the
    PR 7 launch/commit core: when the tracker reports capacity for a
    higher rung, the engine drains in-flight work, replays the last
    committed chunk as a SHADOW on the candidate backend against a copy of
    the committed packed state, and promotes only on bit-equality with the
    incumbent's committed result — a failed canary squashes un-committed
    with a ``promote_rejected`` event and a longer backoff.

Pure control-plane code: nothing here touches numerics — rungs select
*which* engine executes, the §7 masking contract keeps outputs bit-equal
across chunk boundaries, and promotion is refused unless the canary proves
the candidate agrees bit-for-bit (or within an explicit ``canary_rtol``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Rung:
    """One rung of the materialised degradation/recovery ladder.

    ``backend`` is the ``core.lstm`` dispatch name that executes on this
    rung; ``n_dies`` is the number of healthy dies the rung's mesh spans
    (None for flat single-host rungs that use no mesh); ``need`` is the
    number of healthy fault domains required to OCCUPY the rung — the
    tracker compares it against ``len(healthy)`` to decide both where a
    failure lands and when capacity exists for a promotion.
    """

    backend: str
    n_dies: Optional[int] = None
    need: int = 0

    def label(self) -> str:
        """Human-readable rung name for event/CLI surfaces."""
        if self.n_dies is None:
            return self.backend
        return f'{self.backend}@{self.n_dies}d'


def build_rungs(home_backend: str, *, n_layers: int, n_h: int,
                die_mesh=None, n_x: int = 0, T: int = 0,
                batch: int = 0) -> Tuple[Rung, ...]:
    """Materialise the degradation ladder for one serving deployment.

    Without a die mesh this is ``DEGRADATION_LADDER`` from ``home_backend``
    down to ``xla_scan``, one fault domain per rung transition (rung ``i``
    needs ``len - 1 - i`` healthy domains, the bottom needs none).  With a
    two-level ``launch.mesh.DieMesh`` and a mesh home backend, the top of
    the ladder is the same systolic dispatch on progressively fewer dies —
    each die-rung checked against the real admission rule
    (``seq_scaleout_admissible``) on its flattened submesh, so only rungs
    that would actually dispatch are materialised (a one-die submesh whose
    single-stage mesh only admits the layerwise form becomes a
    ``pallas_seq_systolic`` rung, etc.) — and the flat ladder continues
    below the smallest admissible mesh rung.  Pure selection: every rung
    runs the same chunking/masking contract, so rung changes never change
    what a stream computes, only which engine computes it.
    """
    from ..core.lstm import DEGRADATION_LADDER, next_backend_down
    rungs: List[Rung] = []
    tail_home = home_backend
    if die_mesh is not None and home_backend.endswith('_systolic'):
        from ..core.systolic import seq_scaleout_admissible
        for k in range(die_mesh.dies, 0, -1):
            sub = die_mesh.submesh(range(k))
            stages = k * die_mesh.stage
            if stages >= 2 and seq_scaleout_admissible(
                    n_h, sub, n_layers=n_layers, n_x=n_x, T=T, batch=batch):
                rungs.append(Rung('pallas_seq_fused_systolic',
                                  n_dies=k, need=k))
            elif stages == 1 and seq_scaleout_admissible(n_h, sub):
                rungs.append(Rung('pallas_seq_systolic', n_dies=k, need=k))
        if rungs:
            tail_home = next_backend_down(rungs[-1].backend)
        else:
            tail_home = next_backend_down(home_backend)
    if tail_home is not None:
        flat = [tail_home]
        while True:
            nxt = next_backend_down(flat[-1])
            if nxt is None:
                break
            flat.append(nxt)
        if not rungs:
            # flat-only ladder: one domain per transition, bottom needs none
            rungs = [Rung(b, need=len(flat) - 1 - i)
                     for i, b in enumerate(flat)]
        else:
            # flat tail below the mesh rungs: reachable with zero dies
            rungs.extend(Rung(b, need=0) for b in flat)
    assert rungs and rungs[-1].backend in DEGRADATION_LADDER, rungs
    return tuple(rungs)


class MeshHealthTracker:
    """Per-fault-domain health with exponential-backoff promotion hysteresis.

    Tracks which of ``n_domains`` fault domains (dies on a two-level mesh,
    virtual engine groups on a flat ladder) are healthy, and *when* the
    engine is allowed to attempt a promotion:

      * ``fail`` marks domains dead (attributed by id, else LIFO from the
        highest-numbered healthy domain — matching ``heal``'s revival
        order so fail/heal schedules compose deterministically).  A
        failure landing within one hysteresis window of the last promotion
        is a FLAP: the backoff doubles (capped) instead of resetting, so
        an engine that keeps dying right after re-admission waits
        geometrically longer each round.
      * ``heal`` revives domains LIFO (most recently failed first).
      * ``can_promote`` is the hysteresis gate: promotions are barred
        until the backoff window since the last fail/promote/reject has
        passed — never more than one promotion per window.
      * ``note_promote`` / ``note_reject`` feed the outcome back: a
        successful promotion re-arms a plain window; a rejected canary
        doubles the backoff (the candidate is provably not ready).

    Deterministic given the fed (step, event) sequence — tests replay
    schedules exactly.  Control-plane only: the tracker never touches
    state or numerics, it only gates *when* the engine may try to climb.
    """

    def __init__(self, n_domains: int, hysteresis: int = 4,
                 max_backoff: int = 64):
        assert n_domains >= 0 and hysteresis >= 1, (n_domains, hysteresis)
        self.n_domains = int(n_domains)
        self.hysteresis = int(hysteresis)
        self.max_backoff = int(max_backoff)
        self._dead: List[int] = []          # LIFO order of failed domains
        self._backoff = self.hysteresis
        self._not_before = 0                # first step a promotion may land
        self._last_promote: Optional[int] = None

    @property
    def healthy(self) -> Tuple[int, ...]:
        """Sorted ids of the currently healthy fault domains."""
        dead = set(self._dead)
        return tuple(d for d in range(self.n_domains) if d not in dead)

    @property
    def n_healthy(self) -> int:
        """Number of healthy fault domains (the capacity the rung ``need``
        fields are compared against)."""
        return self.n_domains - len(self._dead)

    @property
    def backoff(self) -> int:
        """The current hysteresis window length in engine steps (doubles on
        flaps and rejected canaries, capped at ``max_backoff``)."""
        return self._backoff

    def fail(self, step: int, domain: Optional[int] = None,
             n_dead: int = 1) -> Tuple[int, ...]:
        """Mark ``n_dead`` domains dead at ``step`` (attributed to
        ``domain`` when given, else LIFO from the highest healthy id);
        returns the ids actually killed.  Arms/extends the promotion
        backoff; a failure inside the post-promotion window is a flap and
        doubles it."""
        killed: List[int] = []
        for _ in range(max(1, int(n_dead))):
            alive = [d for d in range(self.n_domains) if d not in self._dead]
            if not alive:
                break
            pick = domain if (domain is not None and domain in alive) \
                else alive[-1]
            self._dead.append(pick)
            killed.append(pick)
            domain = None      # n_dead > 1 spills onto LIFO picks
        flap = (self._last_promote is not None
                and step - self._last_promote < self._backoff)
        if flap:
            self._backoff = min(2 * self._backoff, self.max_backoff)
        else:
            self._backoff = self.hysteresis
        self._not_before = step + self._backoff
        return tuple(killed)

    def heal(self, step: int, n_healed: int = 1) -> Tuple[int, ...]:
        """Revive ``n_healed`` domains at ``step`` (LIFO: most recently
        failed first); returns the ids revived.  Healing restores CAPACITY
        only — the promotion still waits for the hysteresis gate and must
        pass the canary."""
        revived: List[int] = []
        for _ in range(max(1, int(n_healed))):
            if not self._dead:
                break
            revived.append(self._dead.pop())
        return tuple(revived)

    def can_promote(self, step: int) -> bool:
        """The hysteresis gate: True iff the backoff window since the last
        fail/promote/reject has fully elapsed at ``step``."""
        return step >= self._not_before

    def note_promote(self, step: int) -> None:
        """Record a landed promotion: re-arms one plain hysteresis window
        (so at most one promotion per window) and marks the flap
        reference point."""
        self._last_promote = step
        self._not_before = step + self._backoff

    def note_reject(self, step: int) -> None:
        """Record a rejected canary: the candidate is provably not ready,
        so the backoff doubles (capped) and the window re-arms."""
        self._backoff = min(2 * self._backoff, self.max_backoff)
        self._not_before = step + self._backoff

    def best_rung(self, rungs: Sequence[Rung], current: int,
                  step: Optional[int] = None) -> int:
        """The rung index the fleet's health supports right now.

        Degraded direction: the first (highest) rung whose ``need`` is
        within capacity, but never above ``current`` unless the hysteresis
        gate is open — and promotions climb ONE rung at a time (each must
        canary-validate individually).  Pure policy arithmetic; the engine
        owns the actual rebuild."""
        n = self.n_healthy
        supported = next((i for i, r in enumerate(rungs) if r.need <= n),
                         len(rungs) - 1)
        if supported >= current:
            return supported
        if step is not None and not self.can_promote(step):
            return current
        return current - 1
