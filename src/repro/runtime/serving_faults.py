"""Serving-side fault tolerance: the fleet-operation layer (DESIGN.md §10).

The paper's deployment story is an always-on near-sensor engine; its
multi-die follow-up ("Vau da Muntanialas", PAPERS.md) makes fleet-scale
operation — engines failing, stalling, or being re-tiled under load — the
explicit next step.  This module is the policy/state side of that story for
the packed streaming engine (``serving/engine.py``); the mechanism side is
the generalized ``FaultTolerantRunner`` (``runtime/fault.py``).  Four
capabilities, one config object:

  * **stream-state checkpoint/resume** — ``StreamStateCheckpointer``
    snapshots a preempted/evicted stream's packed per-layer ``(h, c)`` rows
    (f32 — or the int8 opaque ``(h_q, c_q)`` carries; the checkpointer is
    pytree-generic) plus its frame cursor through ``CheckpointManager``, so
    a resubmitted stream restores and continues **bit-equal** to an
    uninterrupted run instead of being dropped;
  * **engine-failure injection + graceful degradation** — a deterministic
    ``fail_at`` schedule raises ``EngineFailure`` mid-serve; the engine
    reacts by re-dispatching down ``core.lstm.DEGRADATION_LADDER`` and
    re-placing its packed state cache on the surviving topology
    (``elastic_replace`` — the in-memory form of the checkpoint manager's
    elastic restore), with only a logged latency blip and no stream loss;
  * **deadline watchdog** — per-chunk deadlines derived from the paper's
    real-time model (``chunk_deadline_s`` on
    ``core.perf_model.staged_realtime_frame_s``), recorded as structured
    events by the runner and exposed via ``StreamingEngine.stats()``;
  * **poisoned-slot quarantine** — a non-finite guard over the packed state
    cache (``finite_slots``, fused into the engine's jitted chunk call)
    detects a slot whose carried state went NaN/Inf so the engine can
    quarantine exactly that slot — zero its rows, evict the session with a
    terminal error — while neighbouring slots' outputs stay bit-untouched.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager


class EngineFailure(RuntimeError):
    """A mesh engine (or group of engines) declared dead mid-serve.

    Raised by the deterministic fault schedule (``ServingFaultConfig.fail_at``
    via ``FaultTolerantRunner``'s injection hook) — or, on real hardware, by
    the dispatch layer when a device stops answering.  Handlers react by
    type AND taxonomy (§14):

      * ``transient=False`` (default) — a PERMANENT loss: the serving engine
        degrades its backend down the ladder (or drops a die from the mesh)
        and re-places its packed state cache before retrying the chunk.
        Permanent failures do not burn the runner's transient retry budget —
        the fault hook fires on the first attempt.
      * ``transient=True`` — a recoverable glitch (link hiccup, watchdog
        blip): the runner retries in place under the ordinary backoff
        budget; no degradation happens.

    ``domain`` carries the fault-domain id (the DIE index on a two-level
    ``launch.mesh.DieMesh``); None means "unattributed", which the engine
    maps to the highest-numbered healthy domain (LIFO — matching the
    tracker's heal order, so fail/heal schedules compose deterministically).
    """

    def __init__(self, n_dead: int = 1,
                 message: Optional[str] = None, *,
                 transient: bool = False,
                 domain: Optional[int] = None):
        self.n_dead = int(n_dead)
        self.transient = bool(transient)
        self.domain = None if domain is None else int(domain)
        kind = 'transient fault on' if transient else 'declared dead'
        super().__init__(message or f'{n_dead} mesh engine(s) {kind}')


@dataclasses.dataclass
class ServingFaultConfig:
    """Fault policy for one ``StreamingEngine`` (all features opt-in).

    ``fail_at`` maps engine step -> number of engines lost at that step (the
    deterministic failure-injection schedule); ``poison_at`` maps engine
    step -> slot index whose packed state rows are overwritten with NaN
    before that step's chunk (the quarantine-path injection hook).  The
    non-finite guard (``guard_nonfinite``) is fused into the engine's jitted
    chunk call; its clean-path overhead is tracked as a
    ``BENCH_streaming.json`` row (<5% required).  ``deadline_s`` pins an
    explicit per-chunk deadline; ``deadline_factor`` instead derives one
    from the paper's real-time model (``chunk_deadline_s``).
    ``checkpoint_dir`` enables stream-state checkpoint/resume through
    ``StreamStateCheckpointer``.

    Recovery-side knobs (§14): ``fail_at`` values may also be dict specs
    ``{'n_dead': int, 'transient': bool, 'domain': int}`` to inject the
    taxonomy; ``recover_at`` maps engine step -> number of fault domains
    healed at that step (fed to the ``MeshHealthTracker``, which then arms
    the canary-validated promotion path); ``promote_hysteresis`` is the
    tracker's base backoff window in engine steps; ``canary`` gates
    promotion on a bit-equality shadow-chunk replay (``canary_rtol`` relaxes
    the comparison to allclose for cross-arithmetic-class rungs);
    ``event_log_cap`` bounds the engine + runner event logs with a ring
    buffer (``runtime.fault.RingLog``).
    """

    fail_at: Dict[int, object] = dataclasses.field(default_factory=dict)
    poison_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    recover_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    guard_nonfinite: bool = True
    max_retries: int = 3
    backoff_s: float = 0.05
    deadline_s: Optional[float] = None
    deadline_factor: Optional[float] = None
    checkpoint_dir: Optional[str] = None
    heartbeat_path: Optional[str] = None
    promote_hysteresis: int = 4
    canary: bool = True
    canary_rtol: Optional[float] = None
    event_log_cap: int = 1024

    def resolve_deadline_s(self, chunk: int) -> Optional[float]:
        """The per-chunk deadline this config implies: the explicit
        ``deadline_s`` when set, else ``chunk_deadline_s(chunk,
        deadline_factor)`` (the paper's staged real-time frame budget times
        the slack factor), else None (watchdog disabled)."""
        if self.deadline_s is not None:
            return self.deadline_s
        if self.deadline_factor is not None:
            return chunk_deadline_s(chunk, self.deadline_factor)
        return None

    def make_fail_schedule(self):
        """The ``FaultTolerantRunner`` injection hook for this config:
        ``step -> EngineFailure`` on scheduled steps, else None.  A plain
        int value is ``n_dead`` (a permanent unattributed loss, the PR 6
        form); a dict value ``{'n_dead', 'transient', 'domain'}`` injects
        the full §14 taxonomy.  Deterministic by construction — tests and
        CI replay it exactly."""
        fail_at = dict(self.fail_at)

        def schedule(step: int):
            if step not in fail_at:
                return None
            spec = fail_at[step]
            if isinstance(spec, dict):
                return EngineFailure(spec.get('n_dead', 1),
                                     transient=spec.get('transient', False),
                                     domain=spec.get('domain'))
            return EngineFailure(spec)

        return schedule


def chunk_deadline_s(chunk: int, factor: float = 1.0, **kw) -> float:
    """Per-chunk serving deadline from the paper's real-time model: ``chunk``
    frames times ``core.perf_model.staged_realtime_frame_s`` (the graves-75
    steady-state per-frame execution time), scaled by ``factor`` — the
    slack multiplier a host-emulated deployment needs over the silicon
    budget.  Extra ``kw`` pass through to ``staged_realtime_frame_s``."""
    from ..core.perf_model import staged_realtime_frame_s
    return chunk * staged_realtime_frame_s(**kw) * factor


class ChunkSizePolicy:
    """Deadline-aware serving chunk sizing on a halving ladder (DESIGN.md
    §11).  Pure host-side control policy — no numerics of its own: the §7
    masking contract makes a stream's outputs bit-invariant to where chunk
    boundaries fall, so the policy may move them freely.

    The budget is the paper's REAL-TIME arrival deadline: a chunk of ``c``
    frames represents ``c * FRAME_PERIOD_S`` of sensor time
    (``core.perf_model.realtime_chunk_budget_s``), scaled by ``slack``.
    Feedback comes from the observed launch-to-commit wall time of each
    committed chunk (the same ``dt`` the §10 watchdog records as
    ``deadline_miss``):

      * **miss** (``dt > budget(c)``) — the chunk fell behind the frame
        arrival rate.  Per-chunk cost on a host is ``a + b*c`` (fixed
        dispatch overhead plus per-frame compute) while the budget is
        ``c * budget_per_frame``, so small chunks are the ones that miss:
        the policy GROWS the chunk (doubles, up to ``chunk_max``) to
        amortise ``a``, and pins a floor so it never returns to a size that
        already missed.
      * **provably-safe step-down** — when ``patience`` consecutive chunks
        finish within ``budget(c/2)`` (i.e. the observed wall time already
        meets the HALVED chunk's budget), the policy halves the chunk to
        cut per-symbol emission latency.  The step-down can never introduce
        a miss that the observations did not already rule out.

    Deterministic given the fed ``(chunk_len, dt)`` sequence, so tests
    drive it with synthetic durations.
    """

    def __init__(self, chunk_max: int, chunk_min: int = 1,
                 slack: float = 1.0, patience: int = 3):
        assert 1 <= chunk_min <= chunk_max, (chunk_min, chunk_max)
        from ..core.perf_model import FRAME_PERIOD_S
        self.chunk_max = int(chunk_max)
        self.chunk_min = int(chunk_min)
        self.frame_budget_s = FRAME_PERIOD_S * slack
        self.patience = int(patience)
        self.size = int(chunk_max)      # start fully amortised (and safest)
        self.misses = 0
        self.history: list = []         # (chunk_len, dt) per committed chunk
        self._floor = int(chunk_min)    # sizes below this are known too small
        self._streak = 0

    def budget_s(self, chunk_len: int) -> float:
        """The arrival-rate deadline of one ``chunk_len``-frame chunk:
        ``core.perf_model.realtime_chunk_budget_s`` with the policy's slack
        folded into ``frame_budget_s``."""
        return chunk_len * self.frame_budget_s

    def observe(self, chunk_len: int, dt: float) -> None:
        """Feed one committed chunk's launch-to-commit wall time."""
        self.history.append((int(chunk_len), float(dt)))
        if dt > self.budget_s(chunk_len):
            self.misses += 1
            self._streak = 0
            self._floor = max(self._floor, min(2 * chunk_len, self.chunk_max))
            self.size = max(self._floor,
                            min(2 * chunk_len, self.chunk_max))
        elif (self.size > max(self.chunk_min, self._floor)
              and dt <= self.budget_s(max(chunk_len // 2, 1))):
            self._streak += 1
            if self._streak >= self.patience:
                self._streak = 0
                self.size = max(self.size // 2, self.chunk_min, self._floor)
        else:
            self._streak = 0


def finite_slots(states) -> jax.Array:
    """Per-slot finiteness of a packed state cache: ``(S,) bool``, True iff
    every layer's ``(h, c)`` row for that slot is entirely finite.  Jit-safe
    (the engine fuses it into the chunk call, so the clean-path guard costs
    one fused reduction, no extra dispatch); a False entry is the quarantine
    trigger — the guard itself performs no mutation."""
    flat = [x for pair in states for x in pair]
    finite = jnp.ones((flat[0].shape[0],), bool)
    for x in flat:
        ok = jnp.isfinite(x) if jnp.issubdtype(x.dtype, jnp.floating) \
            else jnp.ones(x.shape, bool)
        finite = finite & ok.reshape(x.shape[0], -1).all(axis=-1)
    return finite


def elastic_replace(tree, sharding=None):
    """Re-place every leaf of ``tree`` on the (possibly changed) topology
    via an exact host round-trip — the in-memory form of
    ``CheckpointManager.restore``'s elastic re-placement.  Both elasticity
    directions run through here: DOWNWARD, when a mesh engine dies and the
    packed state cache must move to the surviving devices (PR 6), and
    UPWARD (§14), when a healed die is re-admitted and the cache re-shards
    from the small degraded mesh onto the larger promoted one mid-stream —
    the caller re-installs the mesh first, then re-places, then rebuilds
    its jitted fwd so the next chunk consumes the new placement.  Values
    are bit-preserved (numpy round-trip, no arithmetic) in either
    direction.  ``sharding`` optionally pins an explicit target
    ``jax.sharding.Sharding`` (or a per-leaf callable ``leaf -> Sharding``)
    instead of the default device."""
    if sharding is None:
        return jax.tree.map(
            lambda a: jax.device_put(np.asarray(jax.device_get(a))), tree)
    place = sharding if callable(sharding) else (lambda a: sharding)
    return jax.tree.map(
        lambda a: jax.device_put(np.asarray(jax.device_get(a)), place(a)),
        tree)


class StreamStateCheckpointer:
    """Per-stream ``(h, c)`` + cursor snapshots through ``CheckpointManager``.

    One checkpoint directory per stream id (``<dir>/stream_<sid>``), each
    written via the manager's atomic tmp+rename layout with per-leaf
    checksums and manifest-path validation, keyed by the stream's frame
    cursor.  The payload is pytree-generic: f32 ``(h, c)`` rows and the int8
    kernels' opaque ``(h_q, c_q)`` carries round-trip equally (bit-exact
    numpy serialization), so resume is bit-equal / bit-identical on a fixed
    backend.  ``keep=1``: only a stream's latest preemption point matters.
    """

    def __init__(self, directory: str):
        self.dir = pathlib.Path(directory)

    def _manager(self, sid: int) -> CheckpointManager:
        return CheckpointManager(self.dir / f'stream_{sid:08d}', keep=1)

    def save(self, sid: int, state_rows, cursor: int) -> None:
        """Checkpoint one stream's packed state rows + frame cursor
        (blocking — preemption is on the control path, not the hot path)."""
        payload = {'cursor': np.int64(cursor), 'state': state_rows}
        self._manager(sid).save(int(cursor), payload, blocking=True)

    def load(self, sid: int, state_like) -> Tuple[tuple, int]:
        """Restore the latest snapshot of stream ``sid`` into the structure
        of ``state_like`` (per-layer ``(h, c)`` rows); returns
        ``(state_rows, cursor)``.  Manifest paths are validated against the
        target tree, so loading the wrong stream shape fails loudly."""
        out = self._manager(sid).restore(
            {'cursor': np.int64(0), 'state': state_like})
        return out['state'], int(out['cursor'])

    def has(self, sid: int) -> bool:
        """True iff a committed checkpoint exists for stream ``sid``."""
        mgr = CheckpointManager.__new__(CheckpointManager)  # no mkdir probe
        mgr.dir = self.dir / f'stream_{sid:08d}'
        return mgr.dir.is_dir() and mgr.latest_step() is not None
