"""Logical-axis sharding rules (MaxText-style) + parameter placement.

Model code annotates activations with *logical* axis names via ``logical(x, ...)``
and parameters carry logical axes in their initializers.  A ``ShardingRules``
context maps logical names to mesh axes; the dry-run / train / serve drivers
install the rules for their mesh and shape-kind.

The LM stack uses jit + sharding constraints (GSPMD), which tolerates non-divisible
dims by padding (40 heads on a 16-way axis, vocab 122753, 8 experts on 32-way EP).
The chipmunk systolic core instead uses exact-tiled shard_map (core/systolic.py).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

# Logical axis vocabulary used across the model zoo.
#   batch      — global batch                   (DP: pod+data)
#   seq        — sequence/time                  (SP when enabled)
#   embed      — d_model residual stream        (FSDP dim for weights)
#   heads      — attention query heads          (TP)
#   kv_heads   — attention kv heads             (TP)
#   head_dim   — per-head feature dim
#   mlp        — FFN hidden dim                 (TP)
#   vocab      — embedding/logits vocabulary    (TP)
#   experts    — MoE expert dim                 (EP: pod+data)
#   expert_mlp — expert FFN hidden              (TP)
#   state      — recurrent state dim            (TP)
#   frames     — audio/image source positions
#   stage      — pipeline stage (core/pipeline.py only)

TRAIN_RULES: Dict[str, MeshAxes] = {
    'batch': ('pod', 'data'),
    'seq': None,
    'embed': ('pod', 'data'),       # FSDP shard of params on the embed dim
    'heads': 'model',
    'kv_heads': 'model',
    'head_dim': 'model',            # fallback TP dim when head counts don't divide
    'mlp': 'model',
    'vocab': 'model',
    'experts': ('pod', 'data'),     # expert parallelism
    'expert_mlp': 'model',
    'state': 'model',
    'frames': None,
    'lstm_row': 'model',            # chipmunk systolic: output-row tiling
    'lstm_col': ('pod', 'data'),    # chipmunk systolic: input-column tiling
    # Attention activation policy (set per-arch by rules_for_arch):
    #   kv-heads divide TP  -> classic head-sharded attention
    #   otherwise           -> context parallelism: q seq sharded, K/V
    #                          replicated, scores local (no all-reduce)
    'seq_q': None,
    'kv_seq': None,
    'head_dim_act': None,           # NEVER shard the score contraction dim
    # MoE expert-buffer capacity dim: sharding it over TP keeps every
    # expert GEMM contraction local (no Megatron down-proj all-reduce) and
    # divides the dispatch all-to-all by the TP degree.
    'moe_cap': 'model',
}


def rules_for_arch(base: Dict[str, MeshAxes], n_kv_heads: int,
                   tp_size: int = 16, family: str = '') -> Dict[str, MeshAxes]:
    """Specialise the policy for an architecture (see above)."""
    r = dict(base)
    if n_kv_heads % tp_size != 0:
        r['seq_q'] = 'model'        # context-parallel scores
        r['kv_seq'] = 'model'       # flash-decoding-style cache split
    if family == 'lstm':
        # A 3.8M-param LSTM cannot use 16-way TP on a production mesh
        # (421 hidden units shard nowhere) — without this, all 16 model
        # ranks redundantly compute the same batch (measured useful-flops
        # fraction 0.062).  Run pure DP over the whole mesh; the paper's
        # C3 tiling runs on the exact-geometry mesh (dryrun --systolic).
        r['batch'] = ('pod', 'data', 'model')
    return r

# Inference: no FSDP on embed (weights stay TP-sharded; gathering weights per
# token would dominate decode), batch over DP, experts over EP.
SERVE_RULES: Dict[str, MeshAxes] = {
    **TRAIN_RULES,
    'embed': None,
}

# Serving very large models (kimi-k2 1T, llama-90b-vision): weights must also
# shard over the data axes or they cannot fit (2 TB bf16 / 16-way TP = 128 GB).
SERVE_BIG_RULES: Dict[str, MeshAxes] = {
    **SERVE_RULES,
    'embed': ('pod', 'data'),
}


class ShardingRules:
    def __init__(self, mesh: Optional[Mesh], rules: Dict[str, MeshAxes]):
        self.mesh = mesh
        self.rules = dict(rules)

    def spec(self, axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """Logical axes -> PartitionSpec.

        Greedy left-to-right assignment with two constraints jit arguments
        demand: (a) each mesh axis used at most once per spec; (b) when
        ``shape`` is given, a dim only claims the longest *prefix* of its
        candidate mesh axes whose size product divides the dim.  Combined with
        fallback rules (e.g. head_dim -> model) this shards 40-head GQA,
        odd vocabularies, 8-expert MoE etc. without manual per-arch specs.
        """
        used = set()
        out = []
        dims = list(shape) if shape is not None else [None] * len(axes)
        for a, dim in zip(axes, dims):
            v = self.rules.get(a) if a else None
            if v is None:
                out.append(None)
                continue
            cand = [(v,) if isinstance(v, str) else tuple(v)][0]
            avail = [m for m in cand if m not in used]
            if self.mesh is not None:
                sizes = dict(zip(self.mesh.axis_names,
                                 self.mesh.devices.shape))
            else:
                sizes = {}
            best: Tuple[str, ...] = ()
            prod = 1
            cur = []
            for m in avail:
                cur.append(m)
                prod *= sizes.get(m, 1)
                if dim is None or (prod > 0 and dim % prod == 0):
                    best = tuple(cur)
            if best:
                used.update(best)
                out.append(best if len(best) > 1 else best[0])
            else:
                out.append(None)
        return P(*out)

    def sharding(self, axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None
                 ) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(axes, shape))


_CTX = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_CTX, 'rules', None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = current_rules()
    _CTX.rules = rules
    try:
        yield rules
    finally:
        _CTX.rules = prev


def logical(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain activation sharding by logical axis names (no-op w/o rules)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    return jax.lax.with_sharding_constraint(x, r.sharding(axes, x.shape))


def _is_axes_leaf(v) -> bool:
    return isinstance(v, tuple) and all(
        a is None or isinstance(a, str) for a in v)


def param_sharding_tree(param_axes, params_shaped, mesh: Mesh,
                        rules: Dict[str, MeshAxes]):
    """Map pytrees of (logical axes, shaped arrays) to NamedShardings.

    Shapes are needed for the divisibility-aware assignment (jit argument
    shardings must divide exactly — GSPMD padding applies only to internal
    constraints)."""
    r = ShardingRules(mesh, rules)
    flat_axes = jax.tree.leaves(param_axes, is_leaf=_is_axes_leaf)
    flat_shapes = jax.tree.leaves(params_shaped)
    assert len(flat_axes) == len(flat_shapes), 'axes/param tree mismatch'
    shardings = [r.sharding(a, s.shape) for a, s in zip(flat_axes, flat_shapes)]
    treedef = jax.tree.structure(params_shaped)
    return jax.tree.unflatten(treedef, shardings)
