"""Self-contained optimizers (no optax in this environment): SGD-momentum,
AdamW, and Adafactor (factored second moments — required to fit the 1T-param
MoE's optimizer state on 512 chips), plus LR schedules (cosine + the WSD
schedule MiniCPM trains with) and global-norm clipping.

API mirrors optax: ``opt.init(params) -> state``; ``opt.update(grads, state,
params) -> (updates, state)``; apply with ``apply_updates``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(f32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


# ------------------------------------------------------------------ schedules
def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, f32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr


def wsd_schedule(peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395): flat LR for most of
    training, then a sharp exponential-ish decay over the last decay_frac."""
    decay_start = int(total * (1 - decay_frac))

    def lr(step):
        step = jnp.asarray(step, f32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
        decay = peak_lr * jnp.exp(jnp.log(final_frac) * t)
        stable = jnp.where(step >= decay_start, decay, peak_lr)
        return jnp.where(step < warmup, warm, stable)
    return lr


# ----------------------------------------------------------------- optimizers
class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw(lr: Callable, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, f32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params), jax.tree.map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(f32),
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2)
                         * jnp.square(g.astype(f32)), state.v, grads)
        bc1 = 1 - b1 ** step.astype(f32)
        bc2 = 1 - b2 ** step.astype(f32)
        lr_t = lr(step)

        def upd(mm, vv, p):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            return -lr_t * (u + weight_decay * p.astype(f32))

        return jax.tree.map(upd, m, v, params), AdamWState(step, m, v)

    return Optimizer(init, update)


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any       # row second moments (or full v for <2D params)
    vc: Any


def adafactor(lr: Callable, eps=1e-30, clip_threshold=1.0,
              decay_rate=0.8, weight_decay=0.0) -> Optimizer:
    """Factored Adam (Shazeer & Stern): O(n+m) state for (n, m) matrices.

    Factors the *last two* dims of >=2-D params (stacked layer weights keep
    their leading dims unfactored, matching t5x behaviour).
    """
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vrow(p):
            return jnp.zeros(p.shape[:-1], f32) if _factored(p) \
                else jnp.zeros(p.shape, f32)

        def vcol(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], f32) if _factored(p) \
                else jnp.zeros((), f32)

        return AdafactorState(jnp.zeros((), jnp.int32),
                              jax.tree.map(vrow, params),
                              jax.tree.map(vcol, params))

    def update(grads, state, params):
        step = state.step + 1
        beta = 1.0 - step.astype(f32) ** -decay_rate
        lr_t = lr(step)

        def upd(g, vr, vc, p):
            g = g.astype(f32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr_n = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc_n = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr_n, axis=-1, keepdims=True), eps)
                row_factor = jax.lax.rsqrt(vr_n / denom)     # (..., R)
                col_factor = jax.lax.rsqrt(vc_n)             # (..., C)
                u = g * row_factor[..., None] * col_factor[..., None, :]
            else:
                vr_n = beta * vr + (1 - beta) * g2
                vc_n = vc
                u = g * jax.lax.rsqrt(vr_n)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            upd_ = -lr_t * u
            if weight_decay:
                upd_ = upd_ - lr_t * weight_decay * p.astype(f32)
            return upd_, vr_n, vc_n

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        # transpose tree-of-(u, vr, vc) -> (tree, tree, tree); robust to
        # NamedTuple param containers (plain is_leaf=tuple checks are not).
        outer = jax.tree.structure(params)
        inner = jax.tree.structure((0, 0, 0))
        updates, vr, vc = jax.tree.transpose(outer, inner, out)
        return updates, AdafactorState(step, vr, vc)

    return Optimizer(init, update)


class SGDState(NamedTuple):
    step: jax.Array
    mom: Any


def sgd(lr: Callable, momentum=0.9) -> Optimizer:
    def init(params):
        return SGDState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params))

    def update(grads, state, params):
        step = state.step + 1
        mom = jax.tree.map(lambda m, g: momentum * m + g.astype(f32),
                           state.mom, grads)
        lr_t = lr(step)
        return jax.tree.map(lambda m: -lr_t * m, mom), SGDState(step, mom)

    return Optimizer(init, update)


def make_optimizer(name: str, lr_fn: Callable) -> Optimizer:
    return {'adamw': adamw, 'adafactor': adafactor, 'sgd': sgd}[name](lr_fn)


def optimizer_state_axes(name: str, param_axes):
    """Logical axes for optimizer state (inherits the param sharding — ZeRO)."""
    scalar = ()
    if name == 'adamw':
        return AdamWState(scalar, param_axes, param_axes)
    if name == 'adafactor':
        drop_last = jax.tree.map(
            lambda a: a[:-1] if len(a) >= 2 else a, param_axes,
            is_leaf=lambda v: isinstance(v, tuple) and all(
                x is None or isinstance(x, str) for x in v))
        drop_row = jax.tree.map(
            lambda a: a[:-2] + a[-1:] if len(a) >= 2 else (), param_axes,
            is_leaf=lambda v: isinstance(v, tuple) and all(
                x is None or isinstance(x, str) for x in v))
        return AdafactorState(scalar, drop_last, drop_row)
    if name == 'sgd':
        return SGDState(scalar, param_axes)
    raise ValueError(name)
