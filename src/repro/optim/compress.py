"""Gradient compression for the data-parallel axis — int8 + error feedback.

Distributed-optimization trick for 1000+ node scale: the DP all-reduce of a
1T-param model moves 2 TB/step in bf16.  Quantizing gradients to int8 with
per-tensor scales quarters that; the residual (quantization error) is carried
into the next step (error feedback, 1-bit-Adam style) so convergence is
preserved.  Used inside shard_map on the ('pod','data') axes — see
launch/train.py.  Chipmunk analogy: 8-bit state exchange between engines (C2).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)


def compress_tensor(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """g -> (int8 codes, scale).  Symmetric per-tensor abs-max."""
    g = g.astype(f32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_tensor(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(f32) * scale


def compress_with_feedback(grads, err_state):
    """Returns (codes, scales, new_err).  new_err = (g + err) - dequant."""
    def one(g, e):
        corrected = g.astype(f32) + e
        q, s = compress_tensor(corrected)
        return q, s, corrected - decompress_tensor(q, s)

    out = jax.tree.map(one, grads, err_state)
    outer = jax.tree.structure(grads)
    inner = jax.tree.structure((0, 0, 0))
    return jax.tree.transpose(outer, inner, out)


def psum_compressed(grads, err_state, axis_names):
    """int8 all-reduce with error feedback, inside shard_map.

    The int32 sum of int8 codes is exact (no overflow below ~16M replicas),
    dequantised with the mean of scales — an unbiased contraction when
    per-replica scales are close, with the residual swallowed by feedback.
    """
    codes, scales, new_err = compress_with_feedback(grads, err_state)
    summed = jax.tree.map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis_names), codes)
    scale_sum = jax.tree.map(lambda s: jax.lax.psum(s, axis_names), scales)
    reduced = jax.tree.map(
        lambda q, s: q.astype(f32) * (s / _axis_size(axis_names)), summed,
        scale_sum)
    return reduced, new_err


def _axis_size(axis_names):
    import numpy as np
    if isinstance(axis_names, str):
        return jax.lax.axis_size(axis_names)
    return int(np.prod([jax.lax.axis_size(a) for a in axis_names]))
