"""Optimizers, LR schedules, gradient clipping + compression (from scratch)."""
from .compress import (compress_tensor, compress_with_feedback,
                       decompress_tensor, init_error_state, psum_compressed)
from .optimizers import (AdafactorState, AdamWState, Optimizer, SGDState,
                         adafactor, adamw, apply_updates, clip_by_global_norm,
                         cosine_schedule, global_norm, make_optimizer,
                         optimizer_state_axes, sgd, wsd_schedule)
