"""Trip-count-weighted cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so any scanned
program (layers, microbatches, KV chunks, LSTM time steps) under-reports
FLOPs, HBM bytes and — critically — collective traffic by the trip count.
This module re-derives the three roofline inputs by walking the HLO call
graph and multiplying loop bodies by their ``known_trip_count``:

  * flops — exact for dot (2 * prod(result) * prod(contracting)), 1/element
    for float elementwise ops (XLA's own convention);
  * bytes — per *top-level* op: operands + result (fusion internals excluded,
    matching post-fusion HBM traffic semantics; perfect reuse inside fusions);
  * collective operand bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute).

Validated against cost_analysis() on unrolled programs (tests/test_roofline.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    'pred': 1, 's8': 1, 'u8': 1, 'f8e4m3fn': 1, 'f8e5m2': 1,
    's16': 2, 'u16': 2, 'bf16': 2, 'f16': 2,
    's32': 4, 'u32': 4, 'f32': 4,
    's64': 8, 'u64': 8, 'f64': 8, 'c64': 8, 'c128': 16,
}

COLLECTIVES = ('all-gather', 'all-reduce', 'reduce-scatter', 'all-to-all',
               'collective-permute', 'ragged-all-to-all')

# float ops that cost ~1 flop per output element
_ELEMENTWISE = {
    'add', 'subtract', 'multiply', 'divide', 'maximum', 'minimum', 'abs',
    'negate', 'exponential', 'log', 'tanh', 'logistic', 'rsqrt', 'sqrt',
    'power', 'cosine', 'sine', 'floor', 'ceil', 'round-nearest-afz',
    'select', 'compare', 'and', 'or', 'not', 'xor', 'clamp',
}

_SHAPE_RE = re.compile(r'([a-z][a-z0-9]*)\[([0-9,]*)\]')
_INSTR_RE = re.compile(r'^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+'
                       r'([\w\-]+)\((.*)$')
_COMP_RE = re.compile(r'^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$')
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r'%([\w.\-]+)')


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(','):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(',') if d]


def _elem_count(type_str: str) -> int:
    dims = _first_shape_dims(type_str)
    if dims is None:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str        # text after the opening paren (operands + attrs)
    root: bool = False


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: 'CompCost', mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


def parse_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and ' -> ' in line:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == '}':
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(2), m.group(3), m.group(4),
                                    m.group(5), root=bool(m.group(1))))
    return comps


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_computations(text)
        # symbol table: instruction name -> result type (per computation,
        # names are globally unique in optimized HLO so one table suffices)
        self.types: Dict[str, str] = {}
        for instrs in self.comps.values():
            for ins in instrs:
                self.types[ins.name] = ins.type_str
        self._memo: Dict[str, CompCost] = {}
        self._param_access_memo: Dict[str, Dict[int, int]] = {}
        self.entry = self._find_entry(text)

    def _find_entry(self, text: str) -> str:
        for line in text.splitlines():
            if line.startswith('ENTRY'):
                m = _COMP_RE.match(line.strip())
                if m:
                    return m.group(1)
        return next(iter(self.comps))

    # ------------------------------------------------------------- per-op
    def _dot_flops(self, ins: Instr) -> float:
        out_elems = _elem_count(ins.type_str)
        ops = _OPERAND_RE.findall(ins.rest)
        lhs_dims = _first_shape_dims(self.types.get(ops[0], '')) if ops else None
        m = re.search(r'lhs_contracting_dims=\{([0-9,]*)\}', ins.rest)
        contracted = 1
        if lhs_dims and m:
            for d in m.group(1).split(','):
                if d:
                    contracted *= lhs_dims[int(d)]
        return 2.0 * out_elems * contracted

    def _operand_bytes(self, ins: Instr) -> int:
        # operands named before any attribute; look up their result types
        args = ins.rest.split(')')[0]
        total = 0
        for name in _OPERAND_RE.findall(args):
            total += _type_bytes(self.types.get(name, ''))
        return total

    def _operands(self, ins: Instr):
        return _OPERAND_RE.findall(ins.rest.split(')')[0])

    def root_op(self, comp: str) -> str:
        for ins in self.comps.get(comp, []):
            if ins.root:
                return ins.op
        return ''

    def _fusion_param_access(self, callee: str) -> Dict[int, int]:
        """param idx -> effective bytes, for params accessed via internal
        dynamic-slice / dynamic-update-slice (loop-invariant big buffers are
        only touched one slice per fusion execution)."""
        if callee in self._param_access_memo:
            return self._param_access_memo[callee]
        param_of: Dict[str, int] = {}
        out: Dict[int, int] = {}
        for ins in self.comps.get(callee, []):
            if ins.op == 'parameter':
                try:
                    param_of[ins.name] = int(ins.rest.split(')')[0])
                except ValueError:
                    pass
        for ins in self.comps.get(callee, []):
            ops = self._operands(ins)
            if ins.op == 'dynamic-slice' and ops and ops[0] in param_of:
                idx = param_of[ops[0]]
                out[idx] = min(out.get(idx, 1 << 62),
                               _type_bytes(ins.type_str))
            if ins.op == 'dynamic-update-slice' and ops and ops[0] in param_of:
                idx = param_of[ops[0]]
                upd = (_type_bytes(self.types.get(ops[1], ''))
                       if len(ops) > 1 else 0)
                out[idx] = min(out.get(idx, 1 << 62), upd)
        self._param_access_memo[callee] = out
        return out

    def _io_bytes(self, ins: Instr) -> int:
        """HBM bytes for one op execution, honouring in-place semantics:
        dynamic-update-slice writes only the update region (XLA aliases the
        buffer), dynamic-slice/gather read only the slice, and fusion params
        accessed via internal dynamic slicing count at slice granularity."""
        op = ins.op
        opnds = self._operands(ins)
        opnd_bytes = [_type_bytes(self.types.get(n, '')) for n in opnds]
        result = _type_bytes(ins.type_str)
        if op in ('fusion', 'call'):
            callee = re.search(r'calls=%?([\w.\-]+)', ins.rest)
            access = (self._fusion_param_access(callee.group(1))
                      if callee else {})
            root = self.root_op(callee.group(1)) if callee else ''
            total = 0
            for i, b in enumerate(opnd_bytes):
                total += min(access.get(i, b), b)
            if root == 'dynamic-update-slice':
                # written region = update size; aliased buffer not re-written
                upd = min([b for i, b in enumerate(opnd_bytes)
                           if access.get(i, b) == b] or [result])
                total += min(upd, result)
            else:
                total += result
            return total
        if op == 'dynamic-update-slice':
            upd = opnd_bytes[1] if len(opnd_bytes) > 1 else 0
            return 2 * upd + sum(opnd_bytes[2:])
        if op in ('dynamic-slice', 'gather'):
            return sum(opnd_bytes[1:]) + 2 * result
        return sum(opnd_bytes) + result

    def comp_cost(self, comp: str) -> CompCost:
        if comp in self._memo:
            return self._memo[comp]
        cost = CompCost()
        self._memo[comp] = cost  # break cycles defensively
        for ins in self.comps.get(comp, []):
            op = ins.op
            if op in ('parameter', 'constant', 'get-tuple-element', 'tuple',
                      'bitcast', 'after-all', 'iota', 'copy', 'copy-start',
                      'copy-done'):
                continue
            if op == 'while':
                body = re.search(r'body=%([\w.\-]+)', ins.rest)
                cond = re.search(r'condition=%([\w.\-]+)', ins.rest)
                trip = _TRIP_RE.search(ins.rest)
                n = int(trip.group(1)) if trip else 1
                if body:
                    cost.add(self.comp_cost(body.group(1)), n)
                if cond:
                    cost.add(self.comp_cost(cond.group(1)), n)
                continue
            if op in ('fusion', 'call', 'async-start'):
                callee = re.search(r'calls=%?([\w.\-]+)', ins.rest) or \
                    re.search(r'to_apply=%?([\w.\-]+)', ins.rest)
                if callee:
                    inner = self.comp_cost(callee.group(1))
                    cost.flops += inner.flops
                    for k, v in inner.coll.items():
                        cost.coll[k] = cost.coll.get(k, 0) + v
                # bytes: fusion boundary, in-place/slice-access aware
                cost.bytes += self._io_bytes(ins)
                continue
            if op == 'conditional':
                for br in re.findall(r'(?:true_computation|false_computation|'
                                     r'branch_computations)=\{?%?([\w.\-]+)',
                                     ins.rest):
                    cost.add(self.comp_cost(br))
                continue

            kind = None
            for c in COLLECTIVES:
                if op == c or op == c + '-start':
                    kind = c
                    break
            if kind:
                b = self._operand_bytes(ins)
                cost.coll[kind] = cost.coll.get(kind, 0.0) + b
                cost.bytes += b + _type_bytes(ins.type_str)
                continue
            if op.endswith('-done'):
                continue

            if op == 'dot':
                cost.flops += self._dot_flops(ins)
            elif op == 'convolution':
                # approx: 2 * out_elems * (kernel elems / out_channels)
                ops = _OPERAND_RE.findall(ins.rest)
                k_elems = (_elem_count(self.types.get(ops[1], ''))
                           if len(ops) > 1 else 1)
                out_dims = _first_shape_dims(ins.type_str) or [1]
                cost.flops += 2.0 * _elem_count(ins.type_str) \
                    * max(k_elems // max(out_dims[-1], 1), 1)
            elif op in _ELEMENTWISE:
                cost.flops += _elem_count(ins.type_str)
            elif op in ('reduce', 'reduce-window'):
                ops = _OPERAND_RE.findall(ins.rest.split(')')[0])
                cost.flops += (_elem_count(self.types.get(ops[0], ''))
                               if ops else 0)
            # memory: in-place/slice-aware operand + result traffic
            cost.bytes += self._io_bytes(ins)
        return cost

    def entry_cost(self) -> CompCost:
        return self.comp_cost(self.entry)


def top_contributors(text: str, k: int = 12):
    """(collectives, memory_ops) — trip-count-weighted per-op-site totals.

    The profiling view for the §Perf hillclimb: each entry is
    (bytes_per_chip, op, metadata op_name tail).
    """
    import collections
    m = HloCostModel(text)
    coll: collections.Counter = collections.Counter()
    mem: collections.Counter = collections.Counter()
    flops: collections.Counter = collections.Counter()

    def walk(comp_name, mult):
        for ins in m.comps.get(comp_name, []):
            op = ins.op
            if op == 'while':
                body = re.search(r'body=%([\w.\-]+)', ins.rest)
                trip = _TRIP_RE.search(ins.rest)
                n = int(trip.group(1)) if trip else 1
                if body:
                    walk(body.group(1), mult * n)
                continue
            if op in ('parameter', 'constant', 'get-tuple-element', 'tuple',
                      'bitcast', 'after-all', 'iota'):
                continue
            meta = re.search(r'op_name="([^"]+)"', ins.rest)
            tag = (meta.group(1)[-70:] if meta else ins.name)
            kind = None
            for c in COLLECTIVES:
                if op == c or op == c + '-start':
                    kind = c
                    break
            if kind:
                coll[(kind, tag)] += m._operand_bytes(ins) * mult
            else:
                mem[(op, tag)] += m._io_bytes(ins) * mult
                if op == 'dot':
                    flops[(op, tag)] += m._dot_flops(ins) * mult
                elif op in ('fusion', 'call'):
                    callee = re.search(r'calls=%?([\w.\-]+)', ins.rest)
                    if callee:
                        flops[(op, tag)] += m.comp_cost(
                            callee.group(1)).flops * mult

    walk(m.entry, 1)
    return coll.most_common(k), mem.most_common(k), flops.most_common(k)


# ---------------------------------------------------------------------------
# Backend ranking oracle (DESIGN.md §13)
# ---------------------------------------------------------------------------
# ``core.perf_model`` is a CYCLE model of the paper's silicon: it prices the
# staged/systolic schedules precisely but knows nothing about what XLA
# actually emits for the non-staged backends (scan overheads, fusion
# boundaries, interpret-mode expansion).  This oracle is the complement: it
# LOWERS each backend's real ``lstm_stack_apply`` launch, walks the
# optimized HLO with the trip-count-weighted cost model above, and converts
# the three roofline terms to a time estimate — so ``xla_scan`` /
# ``pallas_seq`` / ``pallas_seq_fused`` (and, given a mesh,
# ``pallas_seq_systolic``) rank against each other without a device trial.
# Lowering is deterministic for a fixed host + jax version, which keeps
# predicted-only tuner runs byte-for-byte replayable in CI.

#: Stack backends the oracle ranks by default: every backend whose launch
#: can lower WITHOUT a multi-device mesh.
NON_STAGED_STACK_BACKENDS = ('xla_scan', 'pallas_seq', 'pallas_seq_fused')


def lower_stack_hlo(backend: str, n_x: int, n_h: int, n_layers: int,
                    T: int, B: int, mesh=None) -> str:
    """Optimized HLO text of one ``lstm_stack_apply`` launch on ``backend``.

    Deterministic parameters (fixed PRNG key — only SHAPES matter to the
    cost walk), lowered/compiled but never executed.  ``mesh`` is installed
    for the lowering when given (the systolic backends read the process
    mesh); raises whatever the backend's admission/lowering raises — the
    ranking wrapper below treats that as "not rankable here".
    """
    import jax
    import jax.numpy as jnp
    from .core.lstm import init_lstm_stack, lstm_stack_apply
    from .core.systolic import clear_mesh, current_mesh, install_mesh

    params = init_lstm_stack(jax.random.PRNGKey(0), n_x, n_h, n_layers)
    xs = jnp.zeros((T, B, n_x), jnp.float32)

    def fn(p, x):
        return lstm_stack_apply(p, x, backend=backend)[0]

    prev = current_mesh()
    try:
        if mesh is not None:
            install_mesh(mesh)
        return jax.jit(fn).lower(params, xs).compile().as_text()
    finally:
        if mesh is not None:
            install_mesh(prev) if prev is not None else clear_mesh()


def estimate_backend_us(backend: str, n_x: int, n_h: int, n_layers: int,
                        T: int, B: int, mesh=None) -> float:
    """HLO-derived time estimate (us) for one backend's stack launch.

    The no-overlap roofline sum ``compute + memory + collective`` over the
    trip-count-weighted entry cost, against the ``launch.mesh`` peak
    constants.  An ESTIMATE for ranking, not a bound: the true time sits
    between ``roofline``'s ``step_time_lower_bound_s`` (perfect overlap,
    the max term) and this sum — the S3 consistency suite pins exactly
    that bracket.
    """
    from .launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    cost = HloCostModel(
        lower_stack_hlo(backend, n_x, n_h, n_layers, T, B,
                        mesh=mesh)).entry_cost()
    coll = float(sum(cost.coll.values()))
    return (cost.flops / PEAK_FLOPS_BF16 + cost.bytes / HBM_BW
            + coll / ICI_BW) * 1e6


def rank_stack_backends(n_x: int, n_h: int, n_layers: int, T: int, B: int,
                        backends: Optional[Tuple[str, ...]] = None,
                        mesh=None) -> List[Tuple[str, float]]:
    """Backends with their HLO-cost estimates, best first.

    A backend that fails to lower here (no mesh for a systolic backend, an
    admission error, a missing platform) is SKIPPED, not scored — the
    oracle ranks what can actually launch.  Ties break on the backend name
    so the ranking is a pure function of what lowered (the determinism the
    CI smoke diffs).
    """
    if backends is None:
        backends = NON_STAGED_STACK_BACKENDS
    scored = []
    for b in backends:
        try:
            scored.append((b, estimate_backend_us(b, n_x, n_h, n_layers,
                                                  T, B, mesh=mesh)))
        except Exception:
            continue
    return sorted(scored, key=lambda su: (su[1], su[0]))
