"""Measured-schedule autotuner: timed trials over the pruned shmoo space.

``shmoo`` enumerates + ranks candidates by the calibrated model; this module
graduates the top of each ranking to INTERLEAVED timed trials (candidate
A/B/A/B per iteration, the ``benchmarks/`` discipline — back-to-back medians
are biased by whichever candidate runs during a busy host window) and
records the winner in a ``schedule.ScheduleCache``.  Tuning is strictly
offline: serving and CI consult the persisted cache and never pay trial
cost at request time (``replay_check`` pins that the recorded predicted
winners are reproducible from the recorded space without running anything).

Every candidate a trial compares is numerics-equivalent by construction
(the §7/§9 contracts: chunk depth and in-stage order are schedule-only;
int8 fused vs layerwise is bit-identical), and ``tune_staged_stack``
re-asserts bitwise equality across its candidates before timing them — an
autotuner must never be able to trade correctness for speed.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .schedule import (ANY_MESH, ScheduleCache, ScheduleEntry,
                       devices_signature, host_fingerprint, mesh_signature)
from .shmoo import (GeometryCandidate, ShmooRecord, StagedCandidate, TC_GRID,
                    enumerate_geometry_candidates, enumerate_lb_candidates,
                    enumerate_staged_candidates, predict_geometry_us,
                    predict_staged_us, rank_geometry_candidates,
                    rank_lb_candidates, rank_staged_candidates)


def measure_interleaved(fns: Sequence[Callable[[], object]], *,
                        iters: int = 3, warmup: int = 1) -> List[float]:
    """Median wall-clock us for each thunk, interleaved A/B/C per iteration
    so host-load drift hits every candidate equally."""
    import jax
    for fn in fns:
        for _ in range(warmup):
            jax.block_until_ready(fn())
    walls: List[List[float]] = [[] for _ in fns]
    for _ in range(iters):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            walls[i].append(time.perf_counter() - t0)
    return [sorted(w)[len(w) // 2] * 1e6 for w in walls]


# ---------------------------------------------------------------------------
# Staged scale-out schedule (Tc, in-stage order) — needs the mesh's devices
# ---------------------------------------------------------------------------

def tune_staged_stack(stack, mesh, xs, *, cache: Optional[ScheduleCache]
                      = None, kind: str = 'stack_f32', top_k: int = 3,
                      iters: int = 3, warmup: int = 1, measure: bool = True
                      ) -> Tuple[ScheduleEntry, List[ShmooRecord]]:
    """Tune the staged backend's ``(Tc, in_stage)`` for one placement.

    ``stack``: ``LSTMStackParams``; ``mesh``: a live (stage, row, col)
    mesh; ``xs``: (T, B, n_x) representative input.  Enumerates the
    admissible grid, ranks by ``perf_model``, and (when ``measure``) times
    the ``top_k`` predicted-best candidates interleaved through the real
    ``systolic_lstm_stack_seq`` — after asserting their outputs bitwise
    equal, so a trial can only ever pick among proven-identical schedules.
    Records and returns the winner (``source='measured'`` or
    ``'predicted'``).
    """
    import jax
    from ..core import systolic
    T, B, n_x = xs.shape
    n_h = stack.layers[0].n_h
    L = len(stack.layers)
    S = mesh.shape['stage']
    rows, cols = mesh.shape['row'], mesh.shape['col']
    assert systolic.seq_scaleout_admissible(n_h, mesh, n_layers=L), (
        'placement not admissible for the staged scale-out', mesh.shape)
    cands = enumerate_staged_candidates(n_x, n_h, L, T, B, stages=S,
                                        rows=rows, cols=cols)
    assert cands, 'no admissible staged candidate for this placement'
    ranked = rank_staged_candidates(cands, n_x, n_h, L, T)
    records = [ShmooRecord(
        suite='staged_schedule',
        params={'n_x': n_x, 'n_h': n_h, 'n_layers': L, 'T': T, 'B': B,
                'stages': c.stages, 'rows': c.rows, 'cols': c.cols,
                'bn': c.bn, 'bk': c.bk, 'lb': c.lb, 'tc': c.tc,
                'in_stage': c.in_stage},
        metrics={'predicted_us': us, 'measured_us': 0.0})
        for c, us in ranked]

    if measure:
        # top of the predicted ranking, PLUS each in-stage mode's best: the
        # model charges concurrent slots for the batched order, which a
        # single-core emulation host cannot honour — the structural
        # dichotomy must always reach the timed trial, predictions only
        # order within it.
        trial = list(ranked[:top_k])
        for mode in systolic.IN_STAGE_MODES:
            best = next(((c, u) for c, u in ranked if c.in_stage == mode),
                        None)
            if best is not None and best not in trial:
                trial.append(best)
        fns = [jax.jit(lambda x, tc=c.tc, mode=c.in_stage:
                       systolic.systolic_lstm_stack_seq(
                           stack, mesh, x, chunk=tc, in_stage=mode)[0])
               for c, _ in trial]
        outs = [np.asarray(jax.block_until_ready(f(xs))) for f in fns]
        for o in outs[1:]:     # schedule-only: every candidate bit-equal
            np.testing.assert_array_equal(o, outs[0])
        meds = measure_interleaved([lambda f=f: f(xs) for f in fns],
                                   iters=iters, warmup=warmup)
        for (c, _), us in zip(trial, meds):
            for r in records:
                if r.params['tc'] == c.tc and r.params['in_stage'] == c.in_stage:
                    r.metrics['measured_us'] = us
        win_i = int(np.argmin(meds))
        winner, pred_us = trial[win_i]
        entry = ScheduleEntry(kind=kind, n_x=n_x, n_h=n_h, n_layers=L, T=T,
                              B=B, mesh=mesh_signature(mesh), tc=winner.tc,
                              in_stage=winner.in_stage, bn=winner.bn,
                              bk=winner.bk, lb=winner.lb,
                              predicted_us=pred_us,
                              measured_us=meds[win_i], source='measured',
                              host=host_fingerprint())
    else:
        winner, pred_us = ranked[0]
        entry = ScheduleEntry(kind=kind, n_x=n_x, n_h=n_h, n_layers=L, T=T,
                              B=B, mesh=mesh_signature(mesh), tc=winner.tc,
                              in_stage=winner.in_stage, bn=winner.bn,
                              bk=winner.bk, lb=winner.lb,
                              predicted_us=pred_us, source='predicted')
    if cache is not None:
        cache.record(entry)
    return entry, records


# ---------------------------------------------------------------------------
# Geometry (mesh shape + stage split + schedule) — needs the device budget
# ---------------------------------------------------------------------------

def tune_geometry(stack, xs, *, devices: int,
                  ref: Tuple[int, int, int],
                  cache: Optional[ScheduleCache] = None, top_k: int = 3,
                  iters: int = 3, warmup: int = 1, measure: bool = True,
                  allow_reassoc: bool = False
                  ) -> Tuple[ScheduleEntry, List[ShmooRecord], float]:
    """Tune the MESH GEOMETRY itself for a device budget (DESIGN.md §13).

    ``ref`` is the balanced-default placement dispatch would build today
    (e.g. the graves-75 preset's ``(2, 5, 5)``) — it anchors both the
    speedup baseline and the bit-equality class: by default only
    candidates in the reference's arithmetic class ``(n_h_p, bk)`` are
    trialed, and their outputs are asserted BITWISE equal to the
    reference's before any timing (geometry inside a class is
    schedule-only).  ``allow_reassoc=True`` additionally trials the
    predicted-best candidates from OTHER classes, gated by an allclose
    check (a different column split re-associates the hidden contraction —
    float-equal, not bit-equal; the cache entry records which class won).

    Returns ``(winner entry, shmoo records, baseline_us)`` where
    ``baseline_us`` is the measured reference time (0.0 in predicted-only
    mode) — the honest denominator for the BENCH speedup row.
    """
    import jax
    from ..core import systolic
    T, B, n_x = xs.shape
    n_h = stack.layers[0].n_h
    L = len(stack.layers)
    cands = enumerate_geometry_candidates(n_x, n_h, L, T, B, devices=devices)
    assert cands, 'no admissible geometry for this device budget'
    ranked = rank_geometry_candidates(cands, n_x, n_h, L, T)
    records = [ShmooRecord(
        suite='geometry',
        params={'n_x': n_x, 'n_h': n_h, 'n_layers': L, 'T': T, 'B': B,
                'devices': devices, 'stages': c.stages, 'rows': c.rows,
                'cols': c.cols, 'blocks': c.blocks_str().replace(',', '+'),
                'bn': c.bn, 'bk': c.bk, 'lb': c.lb, 'tc': c.tc,
                'in_stage': c.in_stage},
        metrics={'predicted_us': us, 'measured_us': 0.0})
        for c, us in ranked]

    def _entry(cand, pred_us, meas_us, source, mesh_sig, kind='geometry'):
        return ScheduleEntry(
            kind=kind, n_x=n_x, n_h=n_h, n_layers=L, T=T, B=B,
            mesh=mesh_sig, tc=cand.tc, in_stage=cand.in_stage,
            bn=cand.bn, bk=cand.bk, lb=cand.lb, stages=cand.stages,
            rows=cand.rows, cols=cand.cols, blocks=cand.blocks_str(),
            predicted_us=pred_us, measured_us=meas_us, source=source,
            host=host_fingerprint() if source == 'measured' else '')

    if not measure:
        winner, pred = ranked[0]
        entry = _entry(winner, pred, 0.0, 'predicted',
                       devices_signature(devices))
        if cache is not None:
            cache.record(entry)
        return entry, records, 0.0

    # The reference: balanced split on the ref mesh under dispatch's
    # cold-cache defaults (chunk = ceil(T/4S), sequential in-stage order).
    rs, rr, rc = ref
    assert rs * rr * rc <= devices, ('reference exceeds the budget', ref)
    ref_splits = [c.blocks for c, _ in ranked
                  if (c.stages, c.rows, c.cols) == (rs, rr, rc)]
    assert ref_splits, 'reference placement is not admissible'
    base, rem = divmod(L, rs)
    balanced = tuple(base + (1 if s < rem else 0) for s in range(rs))
    import math as _math
    blk = _math.lcm(rr, rc)
    n_h_p = -(-n_h // blk) * blk
    ref_cand = GeometryCandidate(
        stages=rs, rows=rr, cols=rc, blocks=balanced,
        tc=max(1, -(-T // (4 * rs))), in_stage='sequential',
        bn=n_h_p // rr, bk=n_h_p // rc, n_h_p=n_h_p)
    ref_sig = ref_cand.arith_signature

    # Trial set: the reference, the predicted top_k of its arithmetic
    # class, each in-stage mode's class-best (the structural dichotomy
    # must reach the trial — see tune_staged_stack), and, only with
    # allow_reassoc, the overall predicted top_k from other classes.
    same = [(c, u) for c, u in ranked if c.arith_signature == ref_sig]
    trial: List[Tuple[GeometryCandidate, float]] = [(ref_cand, 0.0)]
    for c, u in same[:top_k]:
        if c != ref_cand:
            trial.append((c, u))
    for mode in systolic.IN_STAGE_MODES:
        best = next(((c, u) for c, u in same if c.in_stage == mode), None)
        if best is not None and best[0] != ref_cand and best not in trial:
            trial.append(best)
    n_exact = len(trial)
    if allow_reassoc:
        for c, u in ranked[:top_k]:
            if c.arith_signature != ref_sig and (c, u) not in trial:
                trial.append((c, u))

    fns = []
    for c, _ in trial:
        mesh = systolic.make_systolic_mesh(c.rows, c.cols, stage=c.stages)
        fns.append(jax.jit(
            lambda x, m=mesh, tc=c.tc, mode=c.in_stage, blks=c.blocks:
            systolic.systolic_lstm_stack_seq(stack, m, x, chunk=tc,
                                             in_stage=mode,
                                             blocks=blks)[0]))
    outs = [np.asarray(jax.block_until_ready(f(xs))) for f in fns]
    for o in outs[1:n_exact]:       # same class: bit-equal, asserted
        np.testing.assert_array_equal(o, outs[0])
    for o in outs[n_exact:]:        # other classes: re-associated, allclose
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-6)
    meds = measure_interleaved([lambda f=f: f(xs) for f in fns],
                               iters=iters, warmup=warmup)
    for (c, _), us in zip(trial, meds):
        key = (c.stages, c.rows, c.cols, c.blocks_str().replace(',', '+'),
               c.tc, c.in_stage)
        for r in records:
            if (r.params['stages'], r.params['rows'], r.params['cols'],
                    r.params['blocks'], r.params['tc'],
                    r.params['in_stage']) == key:
                r.metrics['measured_us'] = us
    baseline_us = meds[0]
    win_i = int(np.argmin(meds))
    winner, pred = trial[win_i]
    entry = _entry(winner, pred, meds[win_i], 'measured',
                   devices_signature(devices))
    if cache is not None:
        cache.record(entry)
        # Also land the winner's SCHEDULE under its concrete mesh key so
        # resolve_staged_chunk / resolve_staged_in_stage /
        # resolve_staged_blocks consult it whenever that mesh runs.
        win_mesh = (f'stage:{winner.stages},row:{winner.rows},'
                    f'col:{winner.cols}')
        cache.record(_entry(winner, pred, meds[win_i], 'measured',
                            win_mesh, kind='stack_f32'))
    return entry, records, baseline_us


# ---------------------------------------------------------------------------
# Single-engine §8 lb streaming factor — single device
# ---------------------------------------------------------------------------

def tune_stack_lb(n_x: int, n_h: int, n_layers: int, T: int, B: int, *,
                  cache: Optional[ScheduleCache] = None, iters: int = 3,
                  warmup: int = 1, measure: bool = True
                  ) -> Tuple[Optional[ScheduleEntry], List[ShmooRecord]]:
    """Tune the §8 fused stack's layer-block streaming factor ``lb``.

    ``lstm_stack_seq`` streams ``lb`` layers at a time through VMEM; the
    factor is grid-only (bit-equal across candidates, asserted before
    timing).  The predicted preference is the largest admissible divisor
    (fewest weight re-streams); the measured trial decides per host.
    Returns ``(entry, records)`` — entry is None when no lb is admissible
    (the backend itself is then inadmissible; nothing to record).
    """
    cands = enumerate_lb_candidates(n_x, n_h, n_layers, B)
    if not cands:
        return None, []
    ranked = rank_lb_candidates(cands, n_layers)
    records = [ShmooRecord(
        suite='stack_lb',
        params={'n_x': n_x, 'n_h': n_h, 'n_layers': n_layers, 'T': T,
                'B': B, 'lb': lb},
        metrics={'passes': passes, 'measured_us': 0.0})
        for lb, passes in ranked]
    if not measure or len(cands) == 1:
        lb = ranked[0][0]
        entry = ScheduleEntry(kind='stack_lb', n_x=n_x, n_h=n_h,
                              n_layers=n_layers, T=T, B=B, mesh=ANY_MESH,
                              lb=lb, source='predicted')
        if cache is not None:
            cache.record(entry)
        return entry, records

    import jax
    from ..core.lstm import init_lstm_stack
    from ..kernels.lstm_seq import lstm_stack_seq
    stack = init_lstm_stack(jax.random.PRNGKey(7), n_x, n_h, n_layers)
    xs = jax.random.normal(jax.random.PRNGKey(8), (T, B, n_x)) * 0.5
    fns = [jax.jit(lambda x, lb=lb: lstm_stack_seq(stack, x, lb=lb)[0])
           for lb, _ in ranked]
    outs = [np.asarray(jax.block_until_ready(f(xs))) for f in fns]
    for o in outs[1:]:              # grid-only: bit-equal by contract
        np.testing.assert_array_equal(o, outs[0])
    meds = measure_interleaved([lambda f=f: f(xs) for f in fns],
                               iters=iters, warmup=warmup)
    for (lb, _), us in zip(ranked, meds):
        for r in records:
            if r.params['lb'] == lb:
                r.metrics['measured_us'] = us
    win_i = int(np.argmin(meds))
    entry = ScheduleEntry(kind='stack_lb', n_x=n_x, n_h=n_h,
                          n_layers=n_layers, T=T, B=B, mesh=ANY_MESH,
                          lb=ranked[win_i][0], measured_us=meds[win_i],
                          source='measured', host=host_fingerprint())
    if cache is not None:
        cache.record(entry)
    return entry, records


# ---------------------------------------------------------------------------
# Int8 stack backend (fused wavefront vs layerwise chain) — single device
# ---------------------------------------------------------------------------

def tune_quantized_backend(n_x: int, n_h: int, n_layers: int, T: int, B: int,
                           *, tile: Optional[int] = None,
                           cache: Optional[ScheduleCache] = None,
                           iters: int = 3, warmup: int = 1,
                           measure: bool = True
                           ) -> Tuple[ScheduleEntry, List[ShmooRecord]]:
    """Measure the ``'fused'`` vs ``'layerwise'`` int8 stack decision that
    ``select_quantized_stack_backend`` hand-calibrates with
    ``_Q_FUSED_MIN_NH`` — the two launch shapes are bit-identical, so the
    trial only picks the faster one.  ``measure=False`` records the
    heuristic's own answer (``source='predicted'``) so a cold CI can still
    materialise a cache deterministically.
    """
    import jax
    from ..core import lstm, quant, systolic
    from ..core.lstm import _Q_FUSED_MIN_NH, _SEQ_MIN_T
    heuristic = ('fused' if (n_layers >= 2 and T >= _SEQ_MIN_T
                             and n_h >= _Q_FUSED_MIN_NH) else 'layerwise')
    records: List[ShmooRecord] = []
    from ..core.lstm import _VMEM_BUDGET_BYTES
    from ..kernels.lstm_seq import stack_vmem_bytes_estimate
    if stack_vmem_bytes_estimate(n_x, n_h, n_layers, B) > _VMEM_BUDGET_BYTES:
        # the fused kernel's resident working set does not fit — prune the
        # trial, the chain is the only admissible candidate
        entry = ScheduleEntry(kind='q_stack_backend', n_x=n_x, n_h=n_h,
                              n_layers=n_layers, T=T, B=B, mesh=ANY_MESH,
                              backend='layerwise', source='predicted')
        if cache is not None:
            cache.record(entry)
        return entry, records
    if not measure:
        entry = ScheduleEntry(kind='q_stack_backend', n_x=n_x, n_h=n_h,
                              n_layers=n_layers, T=T, B=B, mesh=ANY_MESH,
                              backend=heuristic, source='predicted')
        if cache is not None:
            cache.record(entry)
        return entry, records

    from ..kernels.lstm_seq import (lstm_layer_seq_quantized,
                                    lstm_stack_seq_quantized)
    tile = tile or min(n_h, 128)
    stack = lstm.init_lstm_stack(jax.random.PRNGKey(7), n_x, n_h, n_layers)
    qps = [systolic.quantize_packed(systolic.pack_lstm(
        lp, systolic.SystolicPlan(n_x if l == 0 else n_h, n_h, tile)))
        for l, lp in enumerate(stack.layers)]
    xs = jax.random.normal(jax.random.PRNGKey(8), (T, B, n_x)) * 0.5
    xs_q = quant.quantize(xs, quant.STATE_FMT)

    def chain(x):
        h = x
        for qp in qps:
            h = lstm_layer_seq_quantized(qp, h, interpret=True)
        return h

    f_lw = jax.jit(chain)
    f_fu = jax.jit(lambda x: lstm_stack_seq_quantized(qps, x, interpret=True))
    r_lw = np.asarray(jax.block_until_ready(f_lw(xs_q)))
    r_fu = np.asarray(jax.block_until_ready(f_fu(xs_q)))
    np.testing.assert_array_equal(r_lw, r_fu)   # bit-identical by contract
    us_lw, us_fu = measure_interleaved(
        [lambda: f_lw(xs_q), lambda: f_fu(xs_q)], iters=iters, warmup=warmup)
    backend = 'layerwise' if us_lw <= us_fu else 'fused'
    for name, us in (('layerwise', us_lw), ('fused', us_fu)):
        records.append(ShmooRecord(
            suite='q_stack_backend',
            params={'n_x': n_x, 'n_h': n_h, 'n_layers': n_layers, 'T': T,
                    'B': B, 'tile': tile, 'backend': name},
            metrics={'measured_us': us}))
    entry = ScheduleEntry(kind='q_stack_backend', n_x=n_x, n_h=n_h,
                          n_layers=n_layers, T=T, B=B, mesh=ANY_MESH,
                          backend=backend,
                          measured_us=min(us_lw, us_fu), source='measured',
                          host=host_fingerprint())
    if cache is not None:
        cache.record(entry)
    return entry, records


# ---------------------------------------------------------------------------
# Serving: materialise the entries the engine consults
# ---------------------------------------------------------------------------

def _serving_workload(n_in: int, slots: int, chunk: int
                      ) -> List[np.ndarray]:
    """Deterministic per-stream frame arrays for the serving-loop trial:
    ``slots`` streams of ``2.5 * chunk`` frames — long enough that every
    candidate steps multiple chunks AND hits a ragged tail (the packing /
    masking / retirement paths all execute), short enough to trial fast."""
    rng = np.random.RandomState(1234)
    n = 2 * chunk + max(1, chunk // 2)
    return [(rng.randn(n, n_in) * 0.5).astype(np.float32)
            for _ in range(slots)]


def tune_serving_config(cfg, *, chunk: int, slots: int,
                        cache: Optional[ScheduleCache] = None,
                        measure: bool = True, iters: int = 2,
                        params=None) -> List[ScheduleEntry]:
    """The ``launch/serve.py --tune`` entry point: record the cache entries
    serving dispatch consults for ``cfg``'s LSTM stack.

    (1) the int8 backend decision at the serving chunk shape (measured
    interleaved when ``measure``); (2) a chunk-depth ceiling for the
    deadline policy (``kind='stack_f32'``): the predicted-best ``Tc <=
    chunk`` for the paper's staged Table-2 placement — model-driven until
    a real staged measurement shadows it (exact keys beat wildcards);
    (3) when ``measure``, the END-TO-END SERVING-LOOP ceiling
    (``kind='serving_chunk'``): each candidate chunk depth drives a real
    ``StreamingEngine`` — packing, valid-length masking, admission,
    retirement, the full §7 loop, not just the kernel it launches — over a
    fixed deterministic workload, outputs asserted bit-equal across
    candidates (chunk boundaries are scheduling-only by the §7 contract)
    before the interleaved timing.  ``tuned_chunk_ceiling`` consults the
    measured entry FIRST; the kernel-level (2) stays the predicted
    fallback.  ``params`` defaults to the same deterministic init
    ``launch/serve.py`` uses.
    """
    n_x, n_h, L = cfg.lstm_inputs, cfg.lstm_hidden, cfg.n_layers
    entries = []
    ent, _ = tune_quantized_backend(n_x, n_h, L, chunk, slots, cache=cache,
                                    measure=measure, iters=iters)
    entries.append(ent)
    tcs = [t for t in TC_GRID if t <= chunk] or [chunk]
    stages = min(L, 3)
    cands = enumerate_staged_candidates(n_x, n_h, L, chunk, slots,
                                        stages=stages, rows=5, cols=5)
    cands = [c for c in cands if c.tc in tcs]
    if cands:
        ranked = rank_staged_candidates(cands, n_x, n_h, L, chunk)
        win, pred = ranked[0]
        ent = ScheduleEntry(kind='stack_f32', n_x=n_x, n_h=n_h, n_layers=L,
                            T=0, B=slots, mesh=ANY_MESH, tc=win.tc,
                            in_stage=win.in_stage, predicted_us=pred,
                            source='predicted')
        if cache is not None:
            cache.record(ent)
        entries.append(ent)
    if not measure:
        return entries

    # (3) time the real engine loop per candidate chunk depth.
    import jax
    from ..serving.engine import StreamingEngine
    if params is None:
        from ..models import get_bundle
        params, _ = get_bundle(cfg).init(jax.random.PRNGKey(0))
    streams = _serving_workload(n_x, slots, chunk)
    depths = sorted({t for t in TC_GRID if t < chunk} | {chunk})
    engines = [StreamingEngine(cfg, params, max_streams=slots, chunk=d)
               for d in depths]

    def run_once(eng):
        before = len(eng.sched.done)
        for f in streams:
            eng.submit(f)
        done = eng.run()[before:]
        return done

    outs = []
    for eng in engines:
        done = sorted(run_once(eng), key=lambda s: s.sid)
        outs.append([np.concatenate(s.log_probs) for s in done])
    for o in outs[1:]:   # §7: chunk boundaries are scheduling-only
        assert len(o) == len(outs[0])
        for a, b in zip(outs[0], o):
            np.testing.assert_array_equal(a, b)
    meds = measure_interleaved([lambda e=e: run_once(e) for e in engines],
                               iters=iters, warmup=0)
    win_i = int(np.argmin(meds))
    ent = ScheduleEntry(kind='serving_chunk', n_x=n_x, n_h=n_h, n_layers=L,
                        T=chunk, B=slots, mesh=ANY_MESH, tc=depths[win_i],
                        measured_us=meds[win_i], source='measured',
                        host=host_fingerprint())
    if cache is not None:
        cache.record(ent)
    entries.append(ent)
    return entries


# ---------------------------------------------------------------------------
# Deterministic offline replay
# ---------------------------------------------------------------------------

def replay_check(cache: ScheduleCache) -> int:
    """Verify the cache replays deterministically: every ``predicted``
    staged-schedule entry's winner is re-derivable from a fresh enumeration
    + ranking (no clocks, no RNG — same inputs, same winner), and every
    staged entry (measured included) sits inside today's admissible space.
    Returns the number of entries checked; raises AssertionError on drift.

    ``geometry`` entries (keyed ``'devices:N'``) are checked against a
    fresh geometry enumeration of the same budget: the recorded winner's
    (stages, rows, cols, blocks) must still be in the admissible space,
    and a ``predicted`` winner must re-rank first.
    """
    checked = 0
    for e in cache.entries():
        if e.kind == 'geometry' and e.mesh.startswith('devices:'):
            devices = int(e.mesh.split(':')[1])
            cands = enumerate_geometry_candidates(
                e.n_x, e.n_h, e.n_layers, e.T or 128, e.B or 8,
                devices=devices)
            geo = (e.stages, e.rows, e.cols,
                   tuple(int(p) for p in e.blocks.split(',')))
            assert any((c.stages, c.rows, c.cols, c.blocks) == geo
                       and c.tc <= (e.T or 128) for c in cands), \
                f'cached geometry left the admissible space: {e}'
            if e.source == 'predicted':
                ranked = rank_geometry_candidates(cands, e.n_x, e.n_h,
                                                  e.n_layers, e.T or 128)
                w = ranked[0][0]
                assert ((w.stages, w.rows, w.cols, w.blocks, w.tc,
                         w.in_stage)
                        == (geo[0], geo[1], geo[2], geo[3], e.tc,
                            e.in_stage)), \
                    f'predicted geometry winner drifted: {w} vs {e}'
            checked += 1
            continue
        if e.kind not in ('stack_f32', 'stack_int8') or not e.tc:
            continue
        if e.mesh == ANY_MESH or ':' not in e.mesh:
            continue            # family-wide ceilings have no single space
        dims = dict(p.split(':') for p in e.mesh.split(','))
        cands = enumerate_staged_candidates(
            e.n_x, e.n_h, e.n_layers, e.T or 128, e.B or 8,
            stages=int(dims.get('stage', 1)), rows=int(dims.get('row', 1)),
            cols=int(dims.get('col', 1)))
        # the dispatch-default chunk (ceil(T/4S), the geometry trial's
        # reference schedule) is admissible by construction even when it
        # falls off the TC_GRID shmoo grid
        default_tc = max(1, -(-(e.T or 128) // (4 * int(dims.get('stage',
                                                                 1)))))
        assert (any(c.tc == e.tc and c.in_stage == e.in_stage
                    for c in cands)
                or (cands and e.tc == default_tc
                    and any(c.in_stage == e.in_stage for c in cands))), \
            f'cached winner left the admissible space: {e}'
        if e.source == 'predicted':
            ranked = rank_staged_candidates(cands, e.n_x, e.n_h,
                                            e.n_layers, e.T or 128)
            win = ranked[0][0]
            assert (win.tc, win.in_stage) == (e.tc, e.in_stage), \
                f'predicted winner drifted: {(win.tc, win.in_stage)} vs {e}'
        checked += 1
    return checked
