"""Measured-schedule cache: persisted winners for every dispatch decision.

The dispatch layer (``core.lstm.select_stack_backend`` /
``select_quantized_stack_backend`` / ``core.systolic.resolve_staged_chunk`` /
the serving chunk-size ceiling) historically made ESTIMATED choices: VMEM
admission rules, the hand-calibrated ``_Q_FUSED_MIN_NH`` hidden-width floor,
the ``ceil(T / 4S)`` staged chunk default.  This module makes those choices
MEASURED without ever re-measuring at request time: ``repro.tune.autotune``
shmoos the schedule space offline (pruned by the same admission rules,
ranked by ``perf_model`` predictions, decided by interleaved timed trials)
and records the winners here; dispatch consults the installed cache first
and falls back to the estimation rules on a miss.

Contract (pinned by tests/test_tune.py):

* **Dispatch-only.** A cache hit may change WHICH schedule runs (backend,
  chunk depth ``Tc``, in-stage order) but never the numerics — every
  schedule a cache entry can select is bit-equal f32 / bit-identical int8
  to the fallback choice (the §7/§9 equivalence contracts).
* **Deterministic replay.** ``save`` emits canonical JSON (sorted entries,
  sorted keys); ``load(save(c)) == c`` byte-for-byte, and re-ranking the
  recorded candidate space in predicted-only mode reproduces the recorded
  predicted winners (``autotune.replay_check``).
* **Keyed by shape AND placement.** The cache key is ``(kind, n_x, n_h,
  n_layers, T, B, mesh-signature)``; ``T=0`` / ``B=0`` are wildcards and
  ``mesh='any'`` matches every placement, so one tuning run can pin a
  whole family.  Lookup precedence is exact-first (see ``lookup``), so a
  specific measurement always beats a family-wide one.
* **Invalidation is by key, not by time.** Entries carry the host fingerprint
  they were measured on (``host``) for provenance; a cache measured on one
  host is VALID dispatch anywhere (numerics are schedule-invariant) but its
  winners are only claims about the host in the fingerprint.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import platform as _platform
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional, Tuple

#: Decision families the cache can answer.  ``stack_f32`` / ``stack_int8``
#: carry the staged scale-out schedule (``tc``, ``in_stage``, and — since
#: the geometry tuner — an optional uneven per-stage ``blocks`` split);
#: ``stack_backend`` / ``q_stack_backend`` carry a backend name;
#: ``geometry`` carries a full mesh geometry winner (``stages`` x ``rows``
#: x ``cols`` + ``blocks``) keyed by the DEVICE BUDGET signature
#: ``'devices:N'`` rather than a concrete mesh (the decision is "which
#: mesh to build", so it cannot be keyed by the mesh it produces);
#: ``serving_chunk`` carries the measured end-to-end serving-loop chunk
#: ceiling (``tc``); ``stack_lb`` carries the §8 single-engine
#: layer-block streaming factor (``lb``).
KINDS = ('stack_f32', 'stack_int8', 'stack_backend', 'q_stack_backend',
         'geometry', 'serving_chunk', 'stack_lb')


def devices_signature(n_devices: int) -> str:
    """Cache-key signature for a DEVICE-BUDGET-keyed decision (kind
    ``'geometry'``): the tuner answers "best mesh for N devices", so the
    key carries the budget, not any one mesh built from it."""
    return f'devices:{int(n_devices)}'

#: Wildcard mesh signature: matches any placement (including none).
ANY_MESH = 'any'


def mesh_signature(mesh) -> str:
    """Canonical placement signature for cache keys.

    ``None`` -> ``'any'`` (single-engine / no scale-out); a ``jax.sharding
    .Mesh`` -> its axis dims in name order, e.g. ``'stage:2,row:5,col:5'``.
    A string passes through unchanged (callers may pre-compute signatures).
    """
    if mesh is None:
        return ANY_MESH
    if isinstance(mesh, str):
        return mesh
    return ','.join(f'{name}:{dim}' for name, dim in mesh.shape.items())


def host_fingerprint() -> str:
    """Provenance stamp for measured entries (NOT part of the cache key)."""
    import jax
    return (f'{_platform.machine()}/{jax.default_backend()}'
            f'x{jax.device_count()}')


@dataclasses.dataclass
class ScheduleEntry:
    """One measured (or predicted) dispatch winner.

    Key fields: ``kind`` + the shape/placement tuple.  Decision fields —
    only the ones meaningful for the kind are non-default: ``tc`` /
    ``in_stage`` / ``blocks`` for the staged schedule kinds, ``backend``
    for the backend-choice kinds, ``stages``/``rows``/``cols``/``blocks``
    for ``geometry``, ``lb`` for ``stack_lb``, ``tc`` for
    ``serving_chunk``.  ``predicted_us`` / ``measured_us`` record the
    ranking evidence; ``source`` is ``'measured'`` when a timed trial
    decided, ``'predicted'`` when only the model ranking did.
    """
    kind: str
    n_x: int = 0
    n_h: int = 0
    n_layers: int = 0
    T: int = 0            # 0 = wildcard (any sequence length)
    B: int = 0            # 0 = wildcard (any batch)
    mesh: str = ANY_MESH
    tc: int = 0
    in_stage: str = ''
    backend: str = ''
    bn: int = 0
    bk: int = 0
    lb: int = 0
    stages: int = 0       # geometry winner: live stage count (0 = n/a)
    rows: int = 0         # geometry winner: engine-grid rows (0 = n/a)
    cols: int = 0         # geometry winner: engine-grid cols (0 = n/a)
    blocks: str = ''      # per-stage layer counts, e.g. '2,1' ('' = balanced)
    predicted_us: float = 0.0
    measured_us: float = 0.0
    source: str = 'predicted'
    host: str = ''

    def __post_init__(self):
        assert self.kind in KINDS, (self.kind, KINDS)

    def key(self) -> Tuple:
        return (self.kind, int(self.n_x), int(self.n_h), int(self.n_layers),
                int(self.T), int(self.B), self.mesh)


class ScheduleCache:
    """In-memory map of ``ScheduleEntry`` winners with wildcard lookup."""

    def __init__(self, entries: Iterable[ScheduleEntry] = ()):
        self._entries: Dict[Tuple, ScheduleEntry] = {}
        for e in entries:
            self.record(e)

    def __len__(self) -> int:
        return len(self._entries)

    def entries(self) -> List[ScheduleEntry]:
        return [self._entries[k] for k in sorted(self._entries)]

    def record(self, entry: ScheduleEntry) -> None:
        """Insert/replace the winner for ``entry.key()``."""
        self._entries[entry.key()] = entry

    def lookup(self, kind: str, *, n_x: int, n_h: int, n_layers: int,
               T: int, B: int, mesh: str = ANY_MESH
               ) -> Optional[ScheduleEntry]:
        """Most-specific matching entry, or None.

        Precedence: for each placement (the query's mesh signature first,
        then the ``'any'`` wildcard), try ``(T, B)`` exact, then ``T``
        exact / ``B`` wildcard, then ``T`` wildcard / ``B`` exact, then
        both wildcards.  A specific measurement therefore always shadows a
        family-wide one.
        """
        meshes = (mesh, ANY_MESH) if mesh != ANY_MESH else (ANY_MESH,)
        for m in meshes:
            for t, b in ((T, B), (T, 0), (0, B), (0, 0)):
                ent = self._entries.get(
                    (kind, int(n_x), int(n_h), int(n_layers), t, b, m))
                if ent is not None:
                    return ent
        return None

    # ------------------------------------------------------- persistence
    def to_json(self) -> str:
        """Canonical JSON: entries sorted by key, keys sorted — so equal
        caches serialise byte-identically (the replay-determinism pin)."""
        return json.dumps(
            {'version': 1,
             'entries': [dataclasses.asdict(e) for e in self.entries()]},
            indent=2, sort_keys=True) + '\n'

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(self.to_json())
        return path

    @classmethod
    def from_json(cls, text: str) -> 'ScheduleCache':
        doc = json.loads(text)
        assert doc.get('version') == 1, doc.get('version')
        return cls(ScheduleEntry(**e) for e in doc['entries'])

    @classmethod
    def load(cls, path) -> 'ScheduleCache':
        return cls.from_json(pathlib.Path(path).read_text())


# ---------------------------------------------------------------------------
# Process-wide registry (what dispatch consults)
# ---------------------------------------------------------------------------
_CURRENT: Optional[ScheduleCache] = None


def install_schedule_cache(cache) -> ScheduleCache:
    """Install ``cache`` (a ``ScheduleCache`` or a JSON path) as the cache
    dispatch consults.  Returns the installed object."""
    global _CURRENT
    if not isinstance(cache, ScheduleCache):
        cache = ScheduleCache.load(cache)
    _CURRENT = cache
    return cache


def current_schedule_cache() -> Optional[ScheduleCache]:
    """The installed cache, or None (dispatch then uses estimation rules)."""
    return _CURRENT


def clear_schedule_cache() -> None:
    """Uninstall the process-wide schedule cache: every consumer falls back
    to its hand-derived cold-cache default on the next lookup."""
    global _CURRENT
    _CURRENT = None


@contextmanager
def using_schedule_cache(cache):
    """Scoped install (tests): installs ``cache``, restores the previous
    cache on exit."""
    global _CURRENT
    prev = _CURRENT
    try:
        yield install_schedule_cache(cache)
    finally:
        _CURRENT = prev
