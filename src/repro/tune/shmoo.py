"""Shmoo harness: candidate spaces, admission pruning, predicted ranking.

One record format serves BOTH shmoo paths in the repo — the autotuner's
schedule sweep here and the Fig. 5 voltage sweep in
``benchmarks/fig5_shmoo.py`` — so the two cannot drift: a ``ShmooRecord``
is ``(suite, params, metrics)`` and ``write_shmoo_csv`` emits one canonical
CSV (``suite`` column, then the param columns, then the metric columns).

The schedule space is pruned BEFORE anything is timed, by the same rules
dispatch itself enforces (``core.systolic.seq_scaleout_admissible`` for
mesh placement, ``kernels.lstm_seq.stack_vmem_bytes_estimate`` against the
VMEM budget), then ranked by the calibrated silicon model
(``core.perf_model.staged_wavefront_cycles`` with the candidate's in-stage
order); only the top of the predicted ranking graduates to timed trials in
``autotune``.  Enumeration and ranking are pure functions of their inputs —
no clocks, no RNG — which is what makes offline replay deterministic.
"""
from __future__ import annotations

import dataclasses
import math
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

from ..core import perf_model as pm
from ..core.systolic import IN_STAGE_MODES

#: Chunk-depth grid for the staged schedule shmoo (clamped to T).
TC_GRID = (4, 8, 16, 32, 64)


@dataclasses.dataclass
class ShmooRecord:
    """One shmoo point: which sweep, where in the space, what it scored."""
    suite: str
    params: Dict[str, object]
    metrics: Dict[str, float]


def write_shmoo_csv(path, records: Sequence[ShmooRecord],
                    param_order: Optional[Sequence[str]] = None,
                    metric_order: Optional[Sequence[str]] = None
                    ) -> pathlib.Path:
    """Write the shared CSV: ``suite,<params...>,<metrics...>``.

    Column order defaults to the sorted keys of the first record (explicit
    orders let a sweep keep a stable, documented header).  Every record
    must cover the same columns — drift between shmoo producers is a
    ValueError here, not a silently ragged file.
    """
    assert records, 'empty shmoo'
    pcols = list(param_order or sorted(records[0].params))
    mcols = list(metric_order or sorted(records[0].metrics))
    lines = [','.join(['suite'] + pcols + mcols)]
    for r in records:
        if set(r.params) != set(pcols) or set(r.metrics) != set(mcols):
            raise ValueError(
                f'ragged shmoo record for suite {r.suite!r}: '
                f'{sorted(r.params)}/{sorted(r.metrics)} vs {pcols}/{mcols}')
        vals = ([r.suite] + [_fmt(r.params[c]) for c in pcols]
                + [_fmt(r.metrics[c]) for c in mcols])
        lines.append(','.join(vals))
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text('\n'.join(lines) + '\n')
    return path


def _fmt(v) -> str:
    if isinstance(v, float):
        return f'{v:.4f}'
    return str(v)


# ---------------------------------------------------------------------------
# Staged-schedule candidate space
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, order=True)
class StagedCandidate:
    """One point of the staged-schedule space: chunk depth, in-stage order,
    and the per-device block geometry the mesh implies (``bn x bk`` from the
    row/col split, ``lb`` the bottleneck stage's layer count)."""
    tc: int
    in_stage: str
    stages: int
    rows: int
    cols: int
    bn: int
    bk: int
    lb: int


def enumerate_staged_candidates(n_x: int, n_h: int, n_layers: int, T: int,
                                B: int, *, stages: int, rows: int, cols: int,
                                dtype_bytes: int = 4,
                                vmem_budget: Optional[int] = None
                                ) -> List[StagedCandidate]:
    """The admissible ``(Tc, in_stage)`` grid for one mesh placement.

    The stage/row/col split is fixed by the mesh (placement is the mesh
    preset's job — ``launch/mesh.py``); what the schedule can still choose
    is the chunk depth and the in-stage order.  Pruning mirrors dispatch:
    the stage count must not exceed the stack (idle stages only bubble —
    the stage-aware ``seq_scaleout_admissible`` rule, which
    ``autotune.tune_staged_stack`` re-checks against the real mesh), and
    the bottleneck stage's PER-DEVICE resident layer block — ``lb``
    layers' worth of both weight families at the ``bn x bk`` block the
    row/col split implies, plus their peephole/bias rows — must fit the
    VMEM budget.
    """
    from ..core.lstm import GATES, _VMEM_BUDGET_BYTES
    budget = _VMEM_BUDGET_BYTES if vmem_budget is None else vmem_budget
    if stages < 1 or stages > n_layers:
        return []
    blk = math.lcm(rows, cols)
    n_h_p = -(-n_h // blk) * blk            # pad so rows and cols divide
    bn, bk = n_h_p // rows, n_h_p // cols
    lb = -(-n_layers // stages)
    resident = (lb * 2 * GATES * bn * bk * dtype_bytes      # W_h + W_in
                + lb * (3 + GATES) * bn * dtype_bytes)      # peep + bias
    if resident > budget:
        return []
    out = []
    for tc in sorted({min(t, T) for t in TC_GRID if t <= T} or {T}):
        for mode in IN_STAGE_MODES:
            out.append(StagedCandidate(tc=tc, in_stage=mode, stages=stages,
                                       rows=rows, cols=cols, bn=bn, bk=bk,
                                       lb=lb))
    return sorted(out)


def predict_staged_us(cand: StagedCandidate, n_x: int, n_h: int,
                      n_layers: int, T: int, v: float = pm.V_MAX) -> float:
    """Model-predicted wall time (us) of one candidate on the calibrated
    silicon: ``staged_wavefront_cycles`` with the candidate's in-stage
    order, at the candidate's stage count, over the homogeneous stack."""
    layers = [pm.LayerDims(n_x, n_h)] + [pm.LayerDims(n_h, n_h)
                                         for _ in range(n_layers - 1)]
    cfg = pm.TileConfig(cand.stages, cand.rows, cand.cols)
    cyc = pm.staged_wavefront_cycles(
        layers, cfg, T, chunk=cand.tc,
        in_stage_batched=(cand.in_stage == 'batched'))
    return cyc / pm.freq_hz(v) * 1e6


def rank_staged_candidates(cands: Sequence[StagedCandidate], n_x: int,
                           n_h: int, n_layers: int, T: int
                           ) -> List[Tuple[StagedCandidate, float]]:
    """Candidates with their predicted us, best first.  Ties break on the
    candidate's own (total) order so ranking is a pure function of the
    space — the determinism the replay check pins."""
    scored = [(c, predict_staged_us(c, n_x, n_h, n_layers, T))
              for c in cands]
    return sorted(scored, key=lambda cu: (cu[1], cu[0]))


def staged_shmoo_records(n_x: int, n_h: int, n_layers: int, T: int, B: int,
                         *, stages: int, rows: int, cols: int,
                         suite: str = 'staged_schedule'
                         ) -> List[ShmooRecord]:
    """The predicted shmoo of one placement, in the shared record format."""
    cands = enumerate_staged_candidates(n_x, n_h, n_layers, T, B,
                                        stages=stages, rows=rows, cols=cols)
    recs = []
    for cand, us in rank_staged_candidates(cands, n_x, n_h, n_layers, T):
        recs.append(ShmooRecord(
            suite=suite,
            params={'n_x': n_x, 'n_h': n_h, 'n_layers': n_layers, 'T': T,
                    'B': B, 'stages': cand.stages, 'rows': cand.rows,
                    'cols': cand.cols, 'bn': cand.bn, 'bk': cand.bk,
                    'lb': cand.lb, 'tc': cand.tc, 'in_stage': cand.in_stage},
            metrics={'predicted_us': us}))
    return recs


# ---------------------------------------------------------------------------
# Geometry candidate space (DESIGN.md §13)
# ---------------------------------------------------------------------------
# The staged space above fixes the mesh and shmoos the schedule; the geometry
# space inverts that: given only a DEVICE BUDGET, it shmoos the mesh itself —
# the stage count, the (rows, cols) engine-grid factorization, the per-stage
# layer split (uneven compositions beyond stage_layer_blocks' balanced
# default) — jointly with the (tc, in_stage) schedule, because the best
# schedule depends on the geometry it runs on.

@dataclasses.dataclass(frozen=True, order=True)
class GeometryCandidate:
    """One point of the geometry space: a full mesh + split + schedule.

    ``blocks`` is the per-stage layer-count composition (every entry >= 1 —
    an empty stage only deepens the pipeline without shedding any compute,
    so the enumerator never proposes one); ``lb`` is the bottleneck stage's
    count ``max(blocks)``; ``n_h_p``/``bn``/``bk`` are the padded hidden
    width and per-device block the (rows, cols) split implies.
    """
    stages: int
    rows: int
    cols: int
    blocks: Tuple[int, ...]
    tc: int
    in_stage: str
    bn: int
    bk: int
    n_h_p: int

    @property
    def lb(self) -> int:
        return max(self.blocks)

    @property
    def devices(self) -> int:
        return self.stages * self.rows * self.cols

    @property
    def arith_signature(self) -> Tuple[int, int]:
        """The bit-equality class of this geometry (DESIGN.md §13).

        Staged outputs are bit-exact across stage counts, stage splits,
        ROW splits, tc, and in-stage order — those only reorder schedule,
        not arithmetic.  The COLUMN split changes the contraction: the
        hidden axis is padded to ``n_h_p = roundup(n_h, lcm(rows, cols))``
        and summed in ``cols`` partials of width ``bk = n_h_p / cols``, so
        two geometries reduce in the same association order (and are
        bit-equal) iff they share ``(n_h_p, bk)``.  Candidates in
        different classes are only allclose (float re-association).
        """
        return (self.n_h_p, self.bk)

    def blocks_str(self) -> str:
        return ','.join(str(b) for b in self.blocks)


def _stage_splits(n_layers: int, n_stages: int) -> List[Tuple[int, ...]]:
    """All positive compositions of ``n_layers`` into ``n_stages`` parts,
    lexicographic — the uneven-split space around ``stage_layer_blocks``'
    balanced default (which is always a member)."""
    if n_stages == 1:
        return [(n_layers,)]
    out = []
    for first in range(1, n_layers - n_stages + 2):
        for rest in _stage_splits(n_layers - first, n_stages - 1):
            out.append((first,) + rest)
    return out


def enumerate_geometry_candidates(n_x: int, n_h: int, n_layers: int, T: int,
                                  B: int, *, devices: int,
                                  dtype_bytes: int = 4,
                                  vmem_budget: Optional[int] = None
                                  ) -> List[GeometryCandidate]:
    """The admissible geometry space for a device budget.

    Enumerates every ``stages x (rows x cols)`` mesh with ``stages in
    [2, n_layers]`` and ``stages * rows * cols <= devices``, every positive
    per-stage split, and the full ``(tc, in_stage)`` schedule grid; prunes
    by the same VMEM rule dispatch enforces, sized by the BOTTLENECK
    stage's ``max(blocks)`` layers (an uneven split concentrates residency
    on its largest stage).  Pure function of its arguments — no clocks, no
    RNG — so predicted-only geometry runs replay byte-for-byte.
    """
    from ..core.lstm import GATES, _VMEM_BUDGET_BYTES
    budget = _VMEM_BUDGET_BYTES if vmem_budget is None else vmem_budget
    out = []
    for stages in range(2, min(n_layers, devices) + 1):
        grid_budget = devices // stages
        if grid_budget < 1:
            break
        splits = _stage_splits(n_layers, stages)
        for rows in range(1, grid_budget + 1):
            for cols in range(1, grid_budget // rows + 1):
                blk = math.lcm(rows, cols)
                n_h_p = -(-n_h // blk) * blk
                bn, bk = n_h_p // rows, n_h_p // cols
                for split in splits:
                    lb = max(split)
                    resident = (lb * 2 * GATES * bn * bk * dtype_bytes
                                + lb * (3 + GATES) * bn * dtype_bytes)
                    if resident > budget:
                        continue
                    for tc in sorted({min(t, T) for t in TC_GRID
                                      if t <= T} or {T}):
                        for mode in IN_STAGE_MODES:
                            out.append(GeometryCandidate(
                                stages=stages, rows=rows, cols=cols,
                                blocks=split, tc=tc, in_stage=mode,
                                bn=bn, bk=bk, n_h_p=n_h_p))
    return sorted(out)


def predict_geometry_us(cand: GeometryCandidate, n_x: int, n_h: int,
                        n_layers: int, T: int,
                        v: float = pm.V_MAX) -> float:
    """Model-predicted wall time (us) of one geometry candidate:
    ``staged_wavefront_cycles`` at the candidate's stage count with its
    (possibly uneven) per-stage split."""
    layers = [pm.LayerDims(n_x, n_h)] + [pm.LayerDims(n_h, n_h)
                                         for _ in range(n_layers - 1)]
    cfg = pm.TileConfig(cand.stages, cand.rows, cand.cols)
    cyc = pm.staged_wavefront_cycles(
        layers, cfg, T, chunk=cand.tc,
        in_stage_batched=(cand.in_stage == 'batched'),
        blocks=cand.blocks)
    return cyc / pm.freq_hz(v) * 1e6


def rank_geometry_candidates(cands: Sequence[GeometryCandidate], n_x: int,
                             n_h: int, n_layers: int, T: int
                             ) -> List[Tuple[GeometryCandidate, float]]:
    """Geometry candidates with predicted us, best first; ties break on the
    candidate's total order (the replay-determinism contract)."""
    scored = [(c, predict_geometry_us(c, n_x, n_h, n_layers, T))
              for c in cands]
    return sorted(scored, key=lambda cu: (cu[1], cu[0]))


def geometry_shmoo_records(n_x: int, n_h: int, n_layers: int, T: int, B: int,
                           *, devices: int, suite: str = 'geometry'
                           ) -> List[ShmooRecord]:
    """The predicted geometry shmoo for one device budget, in the shared
    record format (one row per candidate, ranked best first)."""
    cands = enumerate_geometry_candidates(n_x, n_h, n_layers, T, B,
                                          devices=devices)
    recs = []
    for cand, us in rank_geometry_candidates(cands, n_x, n_h, n_layers, T):
        recs.append(ShmooRecord(
            suite=suite,
            params={'n_x': n_x, 'n_h': n_h, 'n_layers': n_layers, 'T': T,
                    'B': B, 'devices': devices, 'stages': cand.stages,
                    'rows': cand.rows, 'cols': cand.cols,
                    'blocks': cand.blocks_str().replace(',', '+'),
                    'bn': cand.bn, 'bk': cand.bk, 'lb': cand.lb,
                    'tc': cand.tc, 'in_stage': cand.in_stage},
            metrics={'predicted_us': us}))
    return recs


# ---------------------------------------------------------------------------
# Single-engine lb streaming-factor space (§8)
# ---------------------------------------------------------------------------

def enumerate_lb_candidates(n_x: int, n_h: int, n_layers: int, batch: int,
                            vmem_budget: Optional[int] = None) -> List[int]:
    """Admissible §8 single-engine layer-block streaming factors.

    ``lstm_stack_seq`` streams the stack through VMEM ``lb`` layers at a
    time, so ``lb`` must divide ``n_layers`` and the ``lb``-layer slice
    must fit the budget (``stack_vmem_bytes_estimate``).  Ascending order;
    ``1`` (stream layer by layer) is always structurally legal but still
    budget-checked — an over-budget single layer has no admissible lb at
    all and the caller must not pick this backend.
    """
    from ..core.lstm import _VMEM_BUDGET_BYTES
    from ..kernels.lstm_seq import stack_vmem_bytes_estimate
    budget = _VMEM_BUDGET_BYTES if vmem_budget is None else vmem_budget
    out = []
    for lb in range(1, n_layers + 1):
        if n_layers % lb:
            continue
        if stack_vmem_bytes_estimate(n_x, n_h, lb, batch) <= budget:
            out.append(lb)
    return out


def rank_lb_candidates(cands: Sequence[int], n_layers: int
                       ) -> List[Tuple[int, float]]:
    """lb candidates scored by WEIGHT-STREAMING PASSES (``n_layers / lb``
    — each pass re-streams one layer group through VMEM), best first; ties
    (impossible among divisors, but kept for the contract) break on the
    larger lb.  The predicted preference is therefore the LARGEST
    admissible lb — fewest re-streams — which the measured trial in
    ``autotune.tune_stack_lb`` confirms or overturns per host."""
    scored = [(lb, n_layers / lb) for lb in cands]
    return sorted(scored, key=lambda cu: (cu[1], -cu[0]))
