"""Measured-schedule autotuning for backend dispatch (DESIGN.md §12).

``schedule``: the persisted JSON cache of dispatch winners and the
process-wide registry the dispatch layer consults.  ``shmoo``: candidate
enumeration, admission pruning, model ranking, and the shared shmoo record
format (also used by ``benchmarks/fig5_shmoo.py``).  ``autotune``:
interleaved timed trials and the deterministic replay check.  ``python -m
repro.tune`` runs the offline tuner.
"""
from .autotune import (measure_interleaved, replay_check,
                       tune_quantized_backend, tune_serving_config,
                       tune_staged_stack)
from .schedule import (ANY_MESH, ScheduleCache, ScheduleEntry,
                       clear_schedule_cache, current_schedule_cache,
                       host_fingerprint, install_schedule_cache,
                       mesh_signature, using_schedule_cache)
from .shmoo import (ShmooRecord, StagedCandidate, TC_GRID,
                    enumerate_staged_candidates, predict_staged_us,
                    rank_staged_candidates, staged_shmoo_records,
                    write_shmoo_csv)

__all__ = [
    'ANY_MESH', 'ScheduleCache', 'ScheduleEntry', 'ShmooRecord',
    'StagedCandidate', 'TC_GRID', 'clear_schedule_cache',
    'current_schedule_cache', 'enumerate_staged_candidates',
    'host_fingerprint', 'install_schedule_cache', 'measure_interleaved',
    'mesh_signature', 'predict_staged_us', 'rank_staged_candidates',
    'replay_check', 'staged_shmoo_records', 'tune_quantized_backend',
    'tune_serving_config', 'tune_staged_stack', 'using_schedule_cache',
    'write_shmoo_csv',
]
