"""Offline schedule tuner CLI.

``python -m repro.tune --out tuned_schedules.json`` shmoos the schedule
space, records the winners, and writes the cache + the shared-format shmoo
CSV.  Default is predicted-only (deterministic, no timing — what CI runs
twice to assert replay stability); ``--measure`` adds interleaved timed
trials for the single-device decisions, and ``--staged-devices N`` spawns a
subprocess with N forced host devices to measure the staged ``(Tc,
in_stage)`` schedule on a real mesh (the driver process must keep seeing
one device — same pattern as benchmarks/systolic_scaleout.py).
"""
import argparse
import json
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[3]

_STAGED_TUNE_SNIPPET = r"""
import json, sys
import jax
from repro.core import lstm, systolic
from repro.tune import ScheduleCache, tune_staged_stack

n_x, n_h, L, T, B = {n_x}, {n_h}, {L}, {T}, {B}
stack = lstm.init_lstm_stack(jax.random.PRNGKey(42), n_x, n_h, L)
xs = jax.random.normal(jax.random.PRNGKey(43), (T, B, n_x)) * 0.5
mesh = systolic.make_systolic_mesh({rows}, {cols}, stage={stages})
cache = ScheduleCache()
entry, _ = tune_staged_stack(stack, mesh, xs, cache=cache, iters={iters})
print('CACHE|' + json.dumps(cache.to_json()))
"""


def _measure_staged(args, cache):
    from .schedule import ScheduleCache
    snippet = _STAGED_TUNE_SNIPPET.format(
        n_x=args.n_x, n_h=args.n_h, L=args.layers, T=args.T, B=args.B,
        rows=args.rows, cols=args.cols, stages=args.stages,
        iters=args.iters)
    env = dict(os.environ)
    env['XLA_FLAGS'] = ('--xla_force_host_platform_device_count='
                        f'{args.staged_devices}')
    env['PYTHONPATH'] = (str(REPO / 'src') + os.pathsep
                         + env.get('PYTHONPATH', ''))
    proc = subprocess.run([sys.executable, '-c', snippet], env=env,
                          capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(f'staged tune subprocess failed\nSTDOUT:\n'
                           f'{proc.stdout}\nSTDERR:\n{proc.stderr}')
    for line in proc.stdout.splitlines():
        if line.startswith('CACHE|'):
            sub = ScheduleCache.from_json(json.loads(line[6:]))
            for e in sub.entries():
                cache.record(e)
    return cache


def main(argv=None):
    ap = argparse.ArgumentParser(prog='python -m repro.tune')
    ap.add_argument('--out', default='tuned_schedules.json',
                    help='schedule-cache JSON to write')
    ap.add_argument('--csv', default=None,
                    help='also write the shmoo records (shared CSV format)')
    ap.add_argument('--measure', action='store_true',
                    help='run interleaved timed trials for the '
                         'single-device decisions (default: predicted-only)')
    ap.add_argument('--staged-devices', type=int, default=0,
                    help='measure the staged schedule in a subprocess with '
                         'this many forced host devices (0 = predicted-only '
                         'staged shmoo)')
    ap.add_argument('--n-x', type=int, default=48)
    ap.add_argument('--n-h', type=int, default=96)
    ap.add_argument('--layers', type=int, default=3)
    ap.add_argument('--T', type=int, default=32)
    ap.add_argument('--B', type=int, default=4)
    ap.add_argument('--stages', type=int, default=2)
    ap.add_argument('--rows', type=int, default=2)
    ap.add_argument('--cols', type=int, default=2)
    ap.add_argument('--iters', type=int, default=3)
    ap.add_argument('--tile', type=int, default=None,
                    help='systolic plan tile for the int8 trial (default '
                         'min(n_h, 128))')
    args = ap.parse_args(argv)

    from .autotune import replay_check, tune_quantized_backend
    from .schedule import ANY_MESH, ScheduleCache, ScheduleEntry
    from .shmoo import (rank_staged_candidates, staged_shmoo_records,
                        write_shmoo_csv)

    cache = ScheduleCache()
    out = pathlib.Path(args.out)
    if out.exists():            # tuning refines, never forgets
        cache = ScheduleCache.load(out)

    # int8 backend decision at the requested shape
    entry, q_records = tune_quantized_backend(
        args.n_x, args.n_h, args.layers, args.T, args.B, cache=cache,
        tile=args.tile, measure=args.measure, iters=args.iters)
    print(f'q_stack_backend -> {entry.backend} ({entry.source})')

    # staged schedule: predicted shmoo always; measured when devices given
    records = staged_shmoo_records(args.n_x, args.n_h, args.layers, args.T,
                                   args.B, stages=args.stages,
                                   rows=args.rows, cols=args.cols)
    if records and not args.staged_devices:
        p = records[0].params
        cache.record(ScheduleEntry(
            kind='stack_f32', n_x=args.n_x, n_h=args.n_h,
            n_layers=args.layers, T=args.T, B=args.B,
            mesh=f'stage:{args.stages},row:{args.rows},col:{args.cols}',
            tc=int(p['tc']), in_stage=str(p['in_stage']),
            bn=int(p['bn']), bk=int(p['bk']), lb=int(p['lb']),
            predicted_us=records[0].metrics['predicted_us'],
            source='predicted'))
        print(f"staged schedule -> Tc={p['tc']} in_stage={p['in_stage']} "
              f"(predicted)")
    if args.staged_devices:
        _measure_staged(args, cache)
        ent = cache.lookup('stack_f32', n_x=args.n_x, n_h=args.n_h,
                           n_layers=args.layers, T=args.T, B=args.B,
                           mesh=f'stage:{args.stages},row:{args.rows},'
                                f'col:{args.cols}')
        print(f'staged schedule -> Tc={ent.tc} in_stage={ent.in_stage} '
              f'(measured, {ent.measured_us / 1e3:.1f} ms)')

    n = replay_check(cache)
    print(f'replay check: {n} staged entries stable')
    cache.save(out)
    print(f'wrote {len(cache)} entries -> {out}')
    if args.csv:
        for r in q_records:
            r.metrics.setdefault('predicted_us', 0.0)
        rows = records
        if q_records:
            write_shmoo_csv(pathlib.Path(args.csv).with_suffix('.q.csv'),
                            q_records)
        if rows:
            write_shmoo_csv(args.csv, rows)
            print(f'wrote {len(rows)} shmoo points -> {args.csv}')
    return 0


if __name__ == '__main__':
    sys.exit(main())
