"""Offline schedule tuner CLI.

``python -m repro.tune --out tuned_schedules.json`` shmoos the schedule
space, records the winners, and writes the cache + the shared-format shmoo
CSV.  Default is predicted-only (deterministic, no timing — what CI runs
twice to assert replay stability); ``--measure`` adds interleaved timed
trials for the single-device decisions, and ``--staged-devices N`` spawns a
subprocess with N forced host devices to measure the staged ``(Tc,
in_stage)`` schedule on a real mesh (the driver process must keep seeing
one device — same pattern as benchmarks/systolic_scaleout.py).

``--geometry`` switches from schedule tuning to GEOMETRY tuning (DESIGN.md
§13): instead of shmooing (Tc, in_stage) on the fixed ``--stages x (--rows
x --cols)`` placement, it shmoos the placement itself — every mesh shape
and per-stage layer split inside the ``--devices`` budget — with the fixed
placement as the balanced-default reference.  Predicted-only by default;
with ``--staged-devices`` the trial measures on forced host devices,
asserting bit-equality within the reference's arithmetic class first
(``--allow-reassoc`` opts the allclose-gated cross-class candidates in).

``--placements SxRxC[,SxRxC...]`` measures several staged placements in one
run; a placement that exceeds ``--staged-devices`` is SKIPPED with a
warning in this batch mode, while a single over-budget request is a hard
error — either way you get an actionable message, never a raw shard_map
failure from inside the subprocess.
"""
import argparse
import json
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[3]

_STAGED_TUNE_SNIPPET = r"""
import json, sys
import jax
from repro.core import lstm, systolic
from repro.tune import ScheduleCache, tune_staged_stack

n_x, n_h, L, T, B = {n_x}, {n_h}, {L}, {T}, {B}
stack = lstm.init_lstm_stack(jax.random.PRNGKey(42), n_x, n_h, L)
xs = jax.random.normal(jax.random.PRNGKey(43), (T, B, n_x)) * 0.5
mesh = systolic.make_systolic_mesh({rows}, {cols}, stage={stages})
cache = ScheduleCache()
entry, _ = tune_staged_stack(stack, mesh, xs, cache=cache, iters={iters})
print('CACHE|' + json.dumps(cache.to_json()))
"""

_GEOMETRY_TUNE_SNIPPET = r"""
import json, sys
import jax
from repro.core import lstm
from repro.tune import ScheduleCache
from repro.tune.autotune import tune_geometry

n_x, n_h, L, T, B = {n_x}, {n_h}, {L}, {T}, {B}
stack = lstm.init_lstm_stack(jax.random.PRNGKey(42), n_x, n_h, L)
xs = jax.random.normal(jax.random.PRNGKey(43), (T, B, n_x)) * 0.5
cache = ScheduleCache()
entry, records, base = tune_geometry(
    stack, xs, devices={devices}, ref=({stages}, {rows}, {cols}),
    cache=cache, iters={iters}, allow_reassoc={allow_reassoc})
print('CACHE|' + json.dumps(cache.to_json()))
print('GEO|' + json.dumps(
    {{'baseline_us': base, 'measured_us': entry.measured_us,
      'stages': entry.stages, 'rows': entry.rows, 'cols': entry.cols,
      'blocks': entry.blocks, 'tc': entry.tc,
      'in_stage': entry.in_stage}}))
"""


def _device_budget_error(stages: int, rows: int, cols: int,
                         devices: int) -> str:
    """Actionable message when a requested placement exceeds the forced
    device budget — the check runs BEFORE the subprocess so the user sees
    this instead of a raw shard_map error (None = placement fits)."""
    need = stages * rows * cols
    if devices >= need:
        return ''
    return (f'mesh stage:{stages} x (row:{rows} x col:{cols}) needs {need} '
            f'devices but --staged-devices={devices}; pass '
            f'--staged-devices >= {need} or shrink --stages/--rows/--cols')


def _run_tune_subprocess(snippet: str, devices: int):
    env = dict(os.environ)
    env['XLA_FLAGS'] = ('--xla_force_host_platform_device_count='
                        f'{devices}')
    env['PYTHONPATH'] = (str(REPO / 'src') + os.pathsep
                         + env.get('PYTHONPATH', ''))
    proc = subprocess.run([sys.executable, '-c', snippet], env=env,
                          capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(f'tune subprocess failed\nSTDOUT:\n'
                           f'{proc.stdout}\nSTDERR:\n{proc.stderr}')
    return proc.stdout


def _merge_cache_stdout(stdout: str, cache):
    from .schedule import ScheduleCache
    extra = {}
    for line in stdout.splitlines():
        if line.startswith('CACHE|'):
            sub = ScheduleCache.from_json(json.loads(line[6:]))
            for e in sub.entries():
                cache.record(e)
        elif line.startswith('GEO|'):
            extra = json.loads(line[4:])
    return extra


def _measure_staged(args, cache, stages: int, rows: int, cols: int):
    snippet = _STAGED_TUNE_SNIPPET.format(
        n_x=args.n_x, n_h=args.n_h, L=args.layers, T=args.T, B=args.B,
        rows=rows, cols=cols, stages=stages, iters=args.iters)
    _merge_cache_stdout(
        _run_tune_subprocess(snippet, args.staged_devices), cache)
    return cache


def _parse_placements(spec: str):
    out = []
    for part in spec.split(','):
        dims = part.lower().split('x')
        if len(dims) != 3 or not all(d.isdigit() and int(d) >= 1
                                     for d in dims):
            raise SystemExit(f'bad --placements entry {part!r}: expected '
                             f'SxRxC with positive integers, e.g. 2x5x5')
        out.append(tuple(int(d) for d in dims))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(prog='python -m repro.tune')
    ap.add_argument('--out', default='tuned_schedules.json',
                    help='schedule-cache JSON to write')
    ap.add_argument('--csv', default=None,
                    help='also write the shmoo records (shared CSV format)')
    ap.add_argument('--measure', action='store_true',
                    help='run interleaved timed trials for the '
                         'single-device decisions (default: predicted-only)')
    ap.add_argument('--staged-devices', type=int, default=0,
                    help='measure the staged/geometry schedule in a '
                         'subprocess with this many forced host devices '
                         '(0 = predicted-only)')
    ap.add_argument('--geometry', action='store_true',
                    help='tune the mesh GEOMETRY (stages x rows x cols + '
                         'stage split) for the --devices budget instead of '
                         'only the schedule of the fixed placement')
    ap.add_argument('--devices', type=int, default=0,
                    help='device budget for --geometry (default: '
                         '--staged-devices, else stages*rows*cols)')
    ap.add_argument('--allow-reassoc', action='store_true',
                    help='let the measured geometry trial cross arithmetic '
                         'classes (allclose-gated; default stays inside '
                         'the bit-equal class of the reference)')
    ap.add_argument('--placements', default=None,
                    help='comma-separated SxRxC staged placements to '
                         'measure in one run (over-budget entries are '
                         'skipped with a warning)')
    ap.add_argument('--n-x', type=int, default=48)
    ap.add_argument('--n-h', type=int, default=96)
    ap.add_argument('--layers', type=int, default=3)
    ap.add_argument('--T', type=int, default=32)
    ap.add_argument('--B', type=int, default=4)
    ap.add_argument('--stages', type=int, default=2)
    ap.add_argument('--rows', type=int, default=2)
    ap.add_argument('--cols', type=int, default=2)
    ap.add_argument('--iters', type=int, default=3)
    ap.add_argument('--tile', type=int, default=None,
                    help='systolic plan tile for the int8 trial (default '
                         'min(n_h, 128))')
    args = ap.parse_args(argv)

    from .autotune import (replay_check, tune_geometry,
                           tune_quantized_backend, tune_stack_lb)
    from .schedule import ANY_MESH, ScheduleCache, ScheduleEntry
    from .shmoo import (geometry_shmoo_records, staged_shmoo_records,
                        write_shmoo_csv)

    cache = ScheduleCache()
    out = pathlib.Path(args.out)
    if out.exists():            # tuning refines, never forgets
        cache = ScheduleCache.load(out)

    budget = args.devices or args.staged_devices \
        or args.stages * args.rows * args.cols

    # Fail fast on an impossible placement request (S2): the check runs
    # BEFORE any tuning so the user sees the actionable message, not a raw
    # shard_map error minutes in.  Batch (--placements) requests validate
    # per entry inside the loop — over-budget entries skip, not crash.
    if args.staged_devices:
        if args.geometry:
            if args.staged_devices < budget:
                raise SystemExit(
                    f'--devices={budget} exceeds '
                    f'--staged-devices={args.staged_devices}; the forced '
                    f'host must hold the whole budget')
            err = _device_budget_error(args.stages, args.rows, args.cols,
                                       budget)
            if err:
                raise SystemExit(f'reference placement over budget: {err}')
        elif not args.placements:
            err = _device_budget_error(args.stages, args.rows, args.cols,
                                       args.staged_devices)
            if err:
                raise SystemExit(err)

    # int8 backend decision at the requested shape
    entry, q_records = tune_quantized_backend(
        args.n_x, args.n_h, args.layers, args.T, args.B, cache=cache,
        tile=args.tile, measure=args.measure, iters=args.iters)
    print(f'q_stack_backend -> {entry.backend} ({entry.source})')

    # §8 single-engine lb streaming factor
    lb_ent, lb_records = tune_stack_lb(
        args.n_x, args.n_h, args.layers, args.T, args.B, cache=cache,
        measure=args.measure, iters=args.iters)
    if lb_ent is not None:
        print(f'stack_lb -> lb={lb_ent.lb} ({lb_ent.source})')

    if args.geometry:
        records = geometry_shmoo_records(args.n_x, args.n_h, args.layers,
                                         args.T, args.B, devices=budget)
        if not args.staged_devices:
            import jax
            import jax.numpy as jnp
            from ..core.lstm import init_lstm_stack
            stack = init_lstm_stack(jax.random.PRNGKey(42), args.n_x,
                                    args.n_h, args.layers)
            xs = jnp.zeros((args.T, args.B, args.n_x))
            ent, _, _ = tune_geometry(stack, xs, devices=budget,
                                      ref=(args.stages, args.rows,
                                           args.cols),
                                      cache=cache, measure=False)
            print(f'geometry -> {ent.stages}x({ent.rows}x{ent.cols}) '
                  f'blocks={ent.blocks} Tc={ent.tc} '
                  f'in_stage={ent.in_stage} (predicted)')
        else:
            snippet = _GEOMETRY_TUNE_SNIPPET.format(
                n_x=args.n_x, n_h=args.n_h, L=args.layers, T=args.T,
                B=args.B, devices=budget, stages=args.stages,
                rows=args.rows, cols=args.cols, iters=args.iters,
                allow_reassoc=bool(args.allow_reassoc))
            geo = _merge_cache_stdout(
                _run_tune_subprocess(snippet, args.staged_devices), cache)
            if geo:
                speedup = (geo['baseline_us'] / geo['measured_us']
                           if geo['measured_us'] else 0.0)
                print(f"geometry -> {geo['stages']}x({geo['rows']}x"
                      f"{geo['cols']}) blocks={geo['blocks']} "
                      f"Tc={geo['tc']} in_stage={geo['in_stage']} "
                      f"(measured, {geo['measured_us'] / 1e3:.1f} ms, "
                      f"{speedup:.2f}x balanced ref)")
    else:
        # staged schedule: predicted shmoo always; measured when devices
        records = staged_shmoo_records(args.n_x, args.n_h, args.layers,
                                       args.T, args.B, stages=args.stages,
                                       rows=args.rows, cols=args.cols)
        if records and not args.staged_devices:
            p = records[0].params
            cache.record(ScheduleEntry(
                kind='stack_f32', n_x=args.n_x, n_h=args.n_h,
                n_layers=args.layers, T=args.T, B=args.B,
                mesh=f'stage:{args.stages},row:{args.rows},'
                     f'col:{args.cols}',
                tc=int(p['tc']), in_stage=str(p['in_stage']),
                bn=int(p['bn']), bk=int(p['bk']), lb=int(p['lb']),
                predicted_us=records[0].metrics['predicted_us'],
                source='predicted'))
            print(f"staged schedule -> Tc={p['tc']} "
                  f"in_stage={p['in_stage']} (predicted)")
        if args.staged_devices:
            placements = (_parse_placements(args.placements)
                          if args.placements
                          else [(args.stages, args.rows, args.cols)])
            batch = len(placements) > 1
            for stages, rows, cols in placements:
                err = _device_budget_error(stages, rows, cols,
                                           args.staged_devices)
                if err:
                    if not batch:
                        raise SystemExit(err)
                    print(f'skipping {stages}x({rows}x{cols}): {err}',
                          file=sys.stderr)
                    continue
                _measure_staged(args, cache, stages, rows, cols)
                ent = cache.lookup(
                    'stack_f32', n_x=args.n_x, n_h=args.n_h,
                    n_layers=args.layers, T=args.T, B=args.B,
                    mesh=f'stage:{stages},row:{rows},col:{cols}')
                print(f'staged schedule {stages}x({rows}x{cols}) -> '
                      f'Tc={ent.tc} in_stage={ent.in_stage} (measured, '
                      f'{ent.measured_us / 1e3:.1f} ms)')

    n = replay_check(cache)
    print(f'replay check: {n} staged entries stable')
    cache.save(out)
    print(f'wrote {len(cache)} entries -> {out}')
    if args.csv:
        for r in q_records:
            r.metrics.setdefault('predicted_us', 0.0)
        if q_records:
            write_shmoo_csv(pathlib.Path(args.csv).with_suffix('.q.csv'),
                            q_records)
        if lb_records:
            write_shmoo_csv(pathlib.Path(args.csv).with_suffix('.lb.csv'),
                            lb_records)
        if records:
            write_shmoo_csv(args.csv, records)
            print(f'wrote {len(records)} shmoo points -> {args.csv}')
    return 0


if __name__ == '__main__':
    sys.exit(main())
