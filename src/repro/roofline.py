"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step *per chip*:

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_operand_bytes_per_chip / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (the post-SPMD per-device
module).  Collective bytes are NOT in cost_analysis: we parse the optimized
HLO text and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute / ragged-all-to-all op.
Ring-algorithm factors (~2x for all-reduce) are not modelled; terms are
lower bounds, consistent across configurations (what the hillclimb needs).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional

from .launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    'pred': 1, 's8': 1, 'u8': 1, 'f8e4m3fn': 1, 'f8e5m2': 1,
    's16': 2, 'u16': 2, 'bf16': 2, 'f16': 2,
    's32': 4, 'u32': 4, 'f32': 4,
    's64': 8, 'u64': 8, 'f64': 8, 'c64': 8, 'c128': 16,
}

COLLECTIVE_OPS = ('all-gather', 'all-reduce', 'reduce-scatter', 'all-to-all',
                  'collective-permute', 'ragged-all-to-all')

_SHAPE_RE = re.compile(r'\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]')


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(','):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from (post-SPMD) HLO text."""
    totals = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match only op definitions: "%name = type[shape] op-name(operands...)"
        m = re.match(r'%?[\w.\-]+\s*=\s*[^=]*?\b([a-z\-]+)\(', stripped)
        if not m:
            continue
        op = m.group(1)
        kind = None
        for c in COLLECTIVE_OPS:
            if op == c or op == c + '-start' or op == c + '-done':
                kind = c
                break
        if kind is None:
            continue
        if op.endswith('-done'):
            continue  # counted at -start
        # operand shapes appear inside the parens; result shape before the '='
        paren = stripped[stripped.index('('):]
        for dm in _SHAPE_RE.finditer(paren):
            totals[kind] += _shape_bytes(dm.group(1), dm.group(2))
    return totals


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip HLO bytes accessed
    collective_bytes: float      # per-chip collective operand bytes
    per_collective: Dict[str, int]
    model_flops: Optional[float] = None   # 6*N*D (dense) / 6*N_active*D (MoE)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {'compute': self.compute_s, 'memory': self.memory_s,
                 'collective': self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound_s(self) -> float:
        # no-overlap upper bound is the sum; perfect overlap is the max
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        if not self.model_flops or not self.flops:
            return None
        return self.model_flops / self.flops

    @property
    def roofline_fraction(self) -> Optional[float]:
        """MFU-like score: useful-compute time / achievable step time."""
        if not self.model_flops:
            return None
        ideal = self.model_flops / PEAK_FLOPS_BF16
        return ideal / self.step_time_lower_bound_s \
            if self.step_time_lower_bound_s else None

    def to_dict(self) -> Dict:
        return {
            'flops': self.flops, 'hbm_bytes': self.hbm_bytes,
            'collective_bytes': self.collective_bytes,
            'per_collective': self.per_collective,
            'model_flops': self.model_flops,
            'compute_s': self.compute_s, 'memory_s': self.memory_s,
            'collective_s': self.collective_s, 'bottleneck': self.bottleneck,
            'useful_flops_fraction': self.useful_flops_fraction,
            'roofline_fraction': self.roofline_fraction,
        }


def analyze(compiled, model_flops_per_chip: Optional[float] = None
            ) -> RooflineTerms:
    """Derive terms from a compiled (SPMD-partitioned) executable.

    Uses the trip-count-weighted HLO walker (repro.hlo_cost) because XLA's
    cost_analysis counts while-loop bodies once — scanned layers/microbatches
    would otherwise under-report FLOPs and collective bytes by 10-500x
    (validated in tests/test_roofline.py).
    """
    from .hlo_cost import HloCostModel
    model = HloCostModel(compiled.as_text())
    cost = model.entry_cost()
    per = {k: int(v) for k, v in cost.coll.items()}
    return RooflineTerms(
        flops=float(cost.flops), hbm_bytes=float(cost.bytes),
        collective_bytes=float(sum(per.values())),
        per_collective=per, model_flops=model_flops_per_chip)
