"""Public ops for the persistent whole-sequence LSTM kernel.

``lstm_layer_seq`` is a drop-in for ``core.lstm.lstm_layer`` (same contract,
same custom-VJP training semantics as ``lstm_layer_fused``): padding to MXU
tiles, the hoisted ``W_x @ x`` matmul, and un-padding all live here so call
sites never see kernel geometry.  ``lstm_layer_seq_quantized`` is the
whole-sequence form of ``core.systolic.systolic_layer_quantized`` —
bit-identical output, one kernel launch instead of T.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ...core.lstm import (GATES, LSTMParams, lstm_bwd_recompute_gates,
                          valid_len_mask)
from ...core.systolic import QuantizedPackedLSTM
from .._padding import pad_axis_to as _pad_to, round_up as _round_up
from .kernel import lstm_seq, lstm_seq_quantized


def vmem_bytes_estimate(n_h: int, batch: int, bn: int = 128,
                        bk: int = 128, dtype_bytes: int = 4,
                        bb: Optional[int] = None) -> int:
    """Resident VMEM working set of the f32 sequence kernel (for selection).

    A conservative upper bound (no numerics of its own): backend selection
    admits ``pallas_seq`` only when this estimate fits the VMEM budget, so
    auto-chosen blockings never exceed what the kernel actually allocates.
    ``bb`` models the batch-block grid dimension — scratch scales with the
    block, not the full batch.
    """
    n_h_p = _round_up(n_h, math.lcm(bn, bk))
    b_p = max(8, _round_up(batch, 8))
    b_s = b_p if bb is None else min(b_p, bb)       # scratch batch rows
    weights = GATES * n_h_p * n_h_p * dtype_bytes
    consts = (3 + GATES) * n_h_p * dtype_bytes
    state = 3 * b_s * n_h_p * 4 + 2 * b_s * n_h_p * dtype_bytes  # scratch + h0/c0
    stream = 2 * (GATES * b_s * bn * dtype_bytes + 2 * b_s * bn * dtype_bytes)
    return weights + consts + state + stream


# ---------------------------------------------------------------------------
# f32 path with the production training VJP
# ---------------------------------------------------------------------------

def _seq_forward(cfg, w_h, w_peep, b, pre_x, h0, c0, mask=None):
    """Pad, run the kernel, un-pad.  pre_x: (T, B, 4, N_h) core layout.

    Numerics-neutral wrapper: zero padding + layout transposes only, so the
    kernel output (un-padded) stays allclose to ``core.lstm.lstm_layer``.
    ``mask``: optional (T, B) validity mask; padded batch rows are masked out
    (zero), so they never leave the zero state.
    """
    bn, bk, bb, interpret = cfg
    T, B, _, n_h = pre_x.shape
    n_h_p = _round_up(n_h, math.lcm(bn, bk))
    b_p = max(8, _round_up(B, 8))
    if bb is not None:
        b_p = _round_up(b_p, bb)

    pre_k = jnp.transpose(pre_x, (0, 2, 1, 3))            # (T, 4, B, N_h)
    pre_k = _pad_to(_pad_to(pre_k, n_h_p, 3), b_p, 2)
    w_p = _pad_to(_pad_to(w_h, n_h_p, 1), n_h_p, 2)
    peep_p = _pad_to(w_peep, n_h_p, 1)
    bias_p = _pad_to(b, n_h_p, 1)
    h0_p = _pad_to(_pad_to(h0, n_h_p, 1), b_p, 0)
    c0_p = _pad_to(_pad_to(c0, n_h_p, 1), b_p, 0)
    mask_p = None if mask is None else _pad_to(
        mask.astype(pre_x.dtype), b_p, 1)

    hs, cs = lstm_seq(pre_k, w_p, peep_p, bias_p, h0_p, c0_p, mask_p,
                      bn=bn, bk=bk, bb=bb, interpret=interpret)
    return hs[:, :B, :n_h], cs[:, :B, :n_h]


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def lstm_seq_fused(cfg, w_h, w_peep, b, pre_x, h0, c0):
    """Same contract as ``core.lstm.lstm_scan_fused`` but one kernel launch:
    forward allclose to the scan, backward (gate recompute from the saved h/c
    trajectories) numerically equal to the hand-written scan VJP.

    cfg is the static (bn, bk, bb, interpret) tuple; pre_x: (T, B, 4, N_h).
    """
    hs, cs = _seq_forward(cfg, w_h, w_peep, b, pre_x, h0, c0)
    return hs, (hs[-1], cs[-1])


def _seq_fwd(cfg, w_h, w_peep, b, pre_x, h0, c0):
    hs, cs = _seq_forward(cfg, w_h, w_peep, b, pre_x, h0, c0)
    return (hs, (hs[-1], cs[-1])), (w_h, w_peep, b, pre_x, hs, cs, h0, c0)


def _seq_bwd(cfg, res, grads):
    w_h, w_peep, b, pre_x, hs, cs, h0, c0 = res
    return lstm_bwd_recompute_gates(w_h, w_peep, b, pre_x, hs, cs, h0, c0,
                                    grads)


lstm_seq_fused.defvjp(_seq_fwd, _seq_bwd)


def lstm_layer_seq(params: LSTMParams, xs: jax.Array,
                   h0: Optional[jax.Array] = None,
                   c0: Optional[jax.Array] = None, *,
                   valid_len: Optional[jax.Array] = None,
                   bn: Optional[int] = None, bk: Optional[int] = None,
                   bb: Optional[int] = None,
                   interpret: Optional[bool] = None
                   ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Drop-in for ``core.lstm.lstm_layer`` via the whole-sequence kernel:
    output allclose to the scan reference (same recurrence, one launch).

    xs: (T, B, N_x) -> (hs (T, B, N_h), (h_T, c_T)).  Differentiable (the VJP
    recomputes gates from the saved h/c trajectories).  ``bb`` selects the
    batch-block grid dimension (serving slots amortising weight residency);
    the padded batch is rounded up to a whole number of blocks.

    ``valid_len``: optional (B,) int32 per-stream valid lengths for ragged
    chunked serving — steps ``t >= valid_len[b]`` are identity on the state
    (DESIGN.md §7 masking contract), so ``(h_T, c_T)`` is the state after
    exactly ``valid_len[b]`` steps.  The masked path is inference-only (no
    custom VJP); training always runs the unmasked whole-sequence form.

    Default blocking is shape-aware: when the padded hidden row fits a single
    block (N_h <= 512) the whole row is one grid step — the weights are
    resident either way, and fewer grid steps means less per-step machinery.
    """
    assert bb is None or bb % 8 == 0, \
        f'bb={bb} must be a multiple of 8 (f32 sublane tiling)'
    if interpret is None:
        interpret = jax.default_backend() != 'tpu'
    if bn is None or bk is None:
        # Largest block that divides the 128-padded width, so auto blocking
        # never pads beyond what vmem_bytes_estimate (the backend-selection
        # admission test) assumed.
        n_h_p = _round_up(params.n_h, 128)
        auto = next(b for b in (512, 256, 128) if n_h_p % b == 0)
        bn = bn or auto
        bk = bk or auto
    n_h = params.n_h
    T = xs.shape[0]
    batch_shape = xs.shape[1:-1]
    B = int(math.prod(batch_shape)) if batch_shape else 1
    if h0 is None:
        h0 = jnp.zeros(batch_shape + (n_h,), xs.dtype)
    if c0 is None:
        c0 = jnp.zeros(batch_shape + (n_h,), xs.dtype)
    xs_flat = xs.reshape(T, B, params.n_x)
    pre_x = jnp.einsum('ghx,tbx->tbgh', params.w_x, xs_flat)  # hoisted matmul
    cfg = (bn, bk, bb, bool(interpret))
    if valid_len is not None:
        mask = valid_len_mask(T, valid_len, B)
        hs, cs = _seq_forward(cfg, params.w_h, params.w_peep, params.b,
                              pre_x, h0.reshape(B, n_h), c0.reshape(B, n_h),
                              mask)
        h_T, c_T = hs[-1], cs[-1]
    else:
        hs, (h_T, c_T) = lstm_seq_fused(
            cfg, params.w_h, params.w_peep, params.b,
            pre_x, h0.reshape(B, n_h), c0.reshape(B, n_h))
    hs = hs.reshape((T,) + batch_shape + (n_h,))
    return hs, (h_T.reshape(batch_shape + (n_h,)),
                c_T.reshape(batch_shape + (n_h,)))


# ---------------------------------------------------------------------------
# int8 path — whole-sequence systolic datapath
# ---------------------------------------------------------------------------

def _dense_from_tiles(qp: QuantizedPackedLSTM):
    """(R, C, 4, t, t) engine tiles -> dense (4, R*t, C*t) VMEM layout.

    Pure relayout of the already-quantized codes (no re-rounding), so the
    kernel consuming it sees bit-for-bit the same weights as the tiled scan.
    """
    r, c, g, t, _ = qp.tiles_q.shape
    w = jnp.transpose(qp.tiles_q, (2, 0, 3, 1, 4)).reshape(g, r * t, c * t)
    peep = jnp.transpose(qp.peep_q, (1, 0, 2)).reshape(3, r * t)
    bias = jnp.transpose(qp.bias_q, (1, 0, 2)).reshape(4, r * t)
    return w, peep, bias


def lstm_layer_seq_quantized(qp: QuantizedPackedLSTM, xs_q: jax.Array, *,
                             state: Optional[Tuple[jax.Array, jax.Array]] = None,
                             valid_len: Optional[jax.Array] = None,
                             return_state: bool = False,
                             bb: Optional[int] = None,
                             interpret: Optional[bool] = None):
    """Whole-sequence form of ``systolic_layer_quantized``: bit-identical int8
    hidden codes, one kernel launch instead of T.

    xs_q: (T, ..., n_x) int8 codes -> (T, ..., n_h) int8 hidden codes.  ``bb``
    selects the batch-block grid dimension (the batch is zero-padded to a
    whole number of blocks; padded rows carry zero codes and are dropped, so
    bit-identity is unaffected).

    Chunked streaming (DESIGN.md §7): ``state`` is an opaque carry of
    ``(h_q, c_q)`` padded-layout int8 codes as returned by a previous call
    with ``return_state=True`` (None = zero state); ``valid_len`` masks
    ragged tail steps per stream (identity on the carried codes), so feeding
    a sequence chunk by chunk is bit-identical to the monolithic call.  With
    ``return_state=True`` returns ``(hs, (h_q, c_q))``.
    """
    if interpret is None:
        interpret = jax.default_backend() != 'tpu'
    plan = qp.plan
    batch_shape = xs_q.shape[1:-1]
    T = xs_q.shape[0]
    b = int(math.prod(batch_shape)) if batch_shape else 1
    b_p = b if bb is None else _round_up(b, bb)
    xs_flat = xs_q.reshape(T, b, plan.n_x)
    xs_pad = jnp.zeros((T, b_p, plan.padded_x), jnp.int8
                       ).at[:, :b, :plan.n_x].set(xs_flat)
    h0_q = c0_q = mask = None
    if state is not None:
        h0_q = jnp.zeros((b_p, plan.padded_h), jnp.int8
                         ).at[:b].set(state[0].reshape(b, plan.padded_h))
        c0_q = jnp.zeros((b_p, plan.padded_h), jnp.int8
                         ).at[:b].set(state[1].reshape(b, plan.padded_h))
    if valid_len is not None:
        mask = jnp.zeros((T, b_p), jnp.int8).at[:, :b].set(
            valid_len_mask(T, valid_len, b).astype(jnp.int8))
    w_q, peep_q, bias_q = _dense_from_tiles(qp)
    hs, cs = lstm_seq_quantized(
        xs_pad, w_q, peep_q, bias_q,
        qp.sig_lut.reshape(1, 256), qp.tanh_lut.reshape(1, 256),
        h0_q, c0_q, mask,
        tile=plan.tile, cols_x=plan.cols_x, bb=bb, interpret=bool(interpret))
    out = hs[:, :b, :plan.n_h].reshape((T,) + batch_shape + (plan.n_h,))
    if not return_state:
        return out
    final = (hs[-1, :b].reshape(batch_shape + (plan.padded_h,)),
             cs[-1, :b].reshape(batch_shape + (plan.padded_h,)))
    return out, final
