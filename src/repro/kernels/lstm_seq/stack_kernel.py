"""Pallas TPU kernel: fused whole-stack wavefront LSTM (one launch, L layers).

The single-layer persistent kernel (``kernel.py``) already keeps one layer's
weights and state VMEM-resident for the whole sequence — but a *stack* of L
layers still pays L launches, writing the full ``(T, B, N_h)`` hidden
sequence to HBM after each layer and re-reading it as the next layer's
input.  Chipmunk's systolic scale-out exists precisely to avoid that at the
stack level: columns of engine tiles hold *different layers'* weights
stationary and the hidden state hops tile-to-tile instead of round-tripping
through memory (paper Fig. 3, Sec. 3.3 — the 3x(5x5) Graves configuration).

This kernel is the TPU analogue: ONE ``pallas_call`` whose grid carries a
(blocked) layer dimension and executes the stack as a **wavefront
pipeline**,

  * grid ``(NB, D, L/lb, J, K)`` with ``D = T + L - 1`` diagonals: at
    diagonal ``d`` layer ``l`` executes its timestep ``t = d - l``, so
    layer ``l`` consumes step ``t`` while layer ``l+1`` consumes step
    ``t-1`` — the paper's tile-column layer placement as a schedule.  The
    layer dimension is blocked like every other grid dimension: all layers
    of one block execute their (mutually independent — every dependency
    points at the previous diagonal) steps as batched MXU dots in one grid
    step, which is exactly the silicon picture of all tile columns firing
    concurrently within a cycle.  The default block is the whole stack;
  * with the whole stack in one block, EVERY layer's recurrent ``W_h`` and
    (for ``l > 0``) input ``W_in`` use constant index maps — DMAed into
    VMEM once, resident for the entire sequence.  Smaller layer blocks
    (``lb < L``) degrade gracefully to partial residency: layer blocks
    re-stream once per diagonal, the schedule is unchanged;
  * inter-layer handover lives in scratch: layer ``l`` reads layer
    ``l-1``'s ``h_t`` straight out of the t-parity double buffer written one
    diagonal earlier — the hidden sequence never touches HBM between layers;
  * layer 0's non-recurrent ``W_x @ x`` stream is hoisted out of the kernel
    (exactly like the single-layer kernel); inner layers' input matmuls
    cannot be hoisted (their inputs are produced in-kernel) and run against
    the resident ``W_in`` blocks (``W_in[0]`` is zero, so the batched
    below-layer dot is a no-op contribution for layer 0);
  * the 4 gate dots fuse into ONE ``(lb, B, bk) x (lb, bk, 4*bn)`` batched
    MXU dot per resident block (weights pre-transposed to ``(L, K, 4, N)``
    layout by the ops wrapper) — one dispatch per diagonal where the
    layerwise composition pays ``4 * L`` per timestep;
  * outputs are written diagonal-major — ``hs[d, l] = layer l's step
    d - l`` — so every grid step owns a distinct output block (fill/drain
    bubbles land on diagonals outside each layer's ``[l, l + T)`` band and
    are simply never gathered); the ops wrapper re-indexes to the
    layer-major ``(L, T, B, N_h)`` view.

Masking follows the DESIGN.md §7 contract verbatim: a masked step is a pure
``jnp.where`` identity on every layer's carried state (an all-ones mask is
bit-identical to the unmasked schedule), and ``h0/c0`` per layer plus the
emitted ``cs`` make the kernel chunk-carriable for the streaming engine.

The int8 variant replays the silicon datapath of
``core.systolic.systolic_cell_quantized`` layer by layer: layer 0's x-region
saturating-hop prefix is precomputed per step (bit-identical hoisting, as in
``systolic_lstm_seq_quantized``), inner layers consume the layer-below int8
``h`` codes from scratch as their x-region columns — exactly the codes the
layerwise composition would round-trip through HBM — so the fused stack is
bit-identical to chaining the layerwise kernel.  Like the f32 kernel, each
diagonal's layers execute TOGETHER: grid ``(NB, D, R, C)`` with one L-wide
batched ``dot_general`` per hop position — different layers' hop chains are
independent, so batching across layers never reorders any single chain's
saturations, while the serial hop replay stays per-layer inside each
accumulator row — and outputs written diagonal-major exactly as in f32
(bubbles outside each layer's band flush defined data, never gathered).
Cutting the grid from ``D·L·R·C`` to ``D·R·C`` steps removes the dominant
per-grid-step cost of interpret-mode emulation (and L launches' worth of
grid sequencing on hardware).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core import quant
from ...core.systolic import ACC_FMT, CELL_FMT

_sat16 = quant.saturate_int16
_rshift_round = quant.rshift_round


# ---------------------------------------------------------------------------
# f32 wavefront kernel
# ---------------------------------------------------------------------------

def _stack_kernel(pre_x_ref, w_in_ref, w_h_ref, peep_ref, bias_ref, h0_ref,
                  c0_ref, mask_ref, hs_ref, cs_ref, h_scr, c_scr, acc_ref, *,
                  T: int, L: int, lb: int, n_k: int, bn: int, bk: int):
    # Grid (NB, D, L/lb, J, K): batch blocks outermost (one weight DMA serves
    # all serving slots), then the wavefront diagonal, the layer blocks, the
    # output-row blocks and the reduction blocks.
    d = pl.program_id(1)
    m = pl.program_id(2)
    j = pl.program_id(3)
    k = pl.program_id(4)
    base = m * lb                      # first layer of this layer block

    @pl.when((d == 0) & (m == 0) & (j == 0) & (k == 0))
    def _load_state():
        # Both parity slots start defined (the below-layer batched dot reads
        # the off-parity slot of layer l-1 before it is first written; its
        # contribution is zeroed by w_in[0]=0 / discarded by the wavefront
        # select, but the read must not touch undefined memory).
        h_scr[:, 0] = h0_ref[...].astype(jnp.float32)
        h_scr[:, 1] = h0_ref[...].astype(jnp.float32)
        c_scr[...] = c0_ref[...].astype(jnp.float32)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The lb layers of this block run their diagonal steps TOGETHER: layer
    # base+i is at t = d - (base+i), and every operand it needs was written
    # on diagonal d-1 (its own h_{t-1} and the layer below's h_t), so the
    # steps are mutually independent — one batched MXU pass, the in-kernel
    # image of all tile columns firing concurrently (paper Fig. 3).
    ksl = pl.ds(k * bk, bk)
    own = jnp.stack(
        [h_scr[base + i, (d - (base + i)) % 2, :, ksl] for i in range(lb)])
    below = jnp.stack(
        [h_scr[jnp.maximum(base + i - 1, 0), (d - (base + i) + 1) % 2,
               :, ksl] for i in range(lb)])
    jsl = pl.ds(j * bn, bn)
    w_own = w_h_ref[:, ksl, :, jsl].reshape(lb, bk, 4 * bn)
    w_below = w_in_ref[:, ksl, :, jsl].reshape(lb, bk, 4 * bn)
    bdot = lambda x, w: jax.lax.dot_general(
        x, w, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    acc_ref[...] += (bdot(own, w_own)
                     + bdot(below, w_below)).reshape(*own.shape[:2], 4, bn)

    @pl.when(k == n_k - 1)
    def _elementwise():
        sl = pl.ds(j * bn, bn)
        pre_all = acc_ref[...]                                   # (lb,B,4,bn)
        for i in range(lb):
            l = base + i
            t = d - l
            tc = jnp.clip(t, 0, T - 1)
            pre = pre_all[i]
            if i == 0:
                # Layer 0's hoisted W_x @ x stream joins its block here.
                pre = pre + jnp.where(m == 0,
                                      pre_x_ref[0].astype(jnp.float32), 0.0)
            peep = peep_ref[i, :, sl].astype(jnp.float32)        # (3, bn)
            bias = bias_ref[i, :, sl].astype(jnp.float32)        # (4, bn)
            c_prev = c_scr[l, :, sl]                             # (B, bn)
            ig = jax.nn.sigmoid(pre[:, 0] + peep[0] * c_prev + bias[0])
            fg = jax.nn.sigmoid(pre[:, 1] + peep[1] * c_prev + bias[1])
            gg = jnp.tanh(pre[:, 2] + bias[2])
            c_new = fg * c_prev + ig * gg
            og = jax.nn.sigmoid(pre[:, 3] + peep[2] * c_new + bias[3])
            h_new = og * jnp.tanh(c_new)
            # Selects cover the §7 masking contract AND the wavefront
            # fill/drain bubbles: a masked or off-wavefront step is a pure
            # identity on the resident state (no arithmetic touches the
            # carried values, so an all-ones mask is bit-identical to the
            # unmasked schedule, and bubble output blocks — diagonals
            # outside [l, l+T), which the ops wrapper never gathers — still
            # flush defined data).  The keep value differs: a masked LIVE
            # step re-emits the carried h_{t-1} (slot t%2); a bubble must be
            # identity on its WRITE slot ((tc+1)%2) — a tail bubble that
            # copied slot t%2 instead would clobber h_{T-1}, which the layer
            # above still reads on this very diagonal when layer blocks run
            # in separate grid steps (lb < L).
            act = (t >= 0) & (t < T)
            keep = jnp.where(act, h_scr[l, tc % 2, :, sl],
                             h_scr[l, (tc + 1) % 2, :, sl])
            live = (act & (mask_ref[tc] > 0))[:, None]
            h_out = jnp.where(live, h_new, keep)
            c_out = jnp.where(live, c_new, c_prev)
            h_scr[l, (tc + 1) % 2, :, sl] = h_out
            c_scr[l, :, sl] = c_out
            hs_ref[0, i] = h_out.astype(hs_ref.dtype)
            cs_ref[0, i] = c_out.astype(cs_ref.dtype)


@functools.partial(jax.jit, static_argnames=('bn', 'bk', 'bb', 'lb',
                                             'interpret'))
def lstm_stack_seq_kernel(pre_x: jax.Array, w_in: jax.Array, w_h: jax.Array,
                          peep: jax.Array, bias: jax.Array, h0: jax.Array,
                          c0: jax.Array, mask: Optional[jax.Array] = None, *,
                          bn: int = 128, bk: int = 128,
                          bb: Optional[int] = None, lb: Optional[int] = None,
                          interpret: bool = False):
    """Whole-stack fused wavefront LSTM (raw kernel entry; padded shapes).

    pre_x: (T, B, 4, N_h) hoisted layer-0 ``W_x @ x`` pre-activations;
    w_in / w_h: (L, N_h, 4, N_h) resident blocks in ``(k, gate, n)`` layout
    (``w_in[0]`` must be ZERO — layer 0's input stream is ``pre_x``, and the
    zero block is what makes the batched below-layer dot a no-op for it);
    peep: (L, 3, N_h); bias: (L, 4, N_h); h0, c0: (L, B, N_h) per-layer
    carries; ``mask``: optional (T, B) validity mask shared by all layers
    (>0 = live; a masked step is identity on every layer's carried state,
    and ``None`` is bit-identical to an all-ones mask).  N_h must be a
    multiple of bn and bk; B a multiple of 8 and of ``bb``; L a multiple of
    the layer block ``lb`` (default: one block = the whole stack resident;
    ``lb < L`` re-streams layer blocks once per diagonal).

    Returns (hs, cs) in DIAGONAL-major layout, each (D, L, B, N_h) with
    ``D = T + L - 1``: ``hs[d, l]`` is layer ``l``'s step ``d - l``; entries
    outside each layer's ``[l, l + T)`` diagonal band are don't-care bubble
    flushes.  The ops wrapper gathers the layer-major ``(L, T, B, N_h)``
    view (layer ``L-1``'s band is the stack output; the full trajectories
    feed the cross-layer gate-recompute VJP and the chunked carry).
    """
    T, b, _, n_h = pre_x.shape
    L = w_h.shape[0]
    bb = b if bb is None else bb
    lb = L if lb is None else lb
    assert n_h % bn == 0 and n_h % bk == 0, (n_h, bn, bk)
    assert b % bb == 0, (b, bb)
    assert L % lb == 0, (L, lb)
    if mask is None:
        mask = jnp.ones((T, b), pre_x.dtype)
    n_k = n_h // bk
    D = T + L - 1

    hs, cs = pl.pallas_call(
        functools.partial(_stack_kernel, T=T, L=L, lb=lb, n_k=n_k, bn=bn,
                          bk=bk),
        grid=(b // bb, D, L // lb, n_h // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, bb, 4, bn),
                         lambda nb, d, m, j, k: (jnp.clip(d, 0, T - 1),
                                                 nb, 0, j)),
            # Layer-block index maps: with lb == L these are constant, so
            # the whole stack's weights are fetched once and stay resident
            # for the entire grid.
            pl.BlockSpec((lb, n_h, 4, n_h), lambda nb, d, m, j, k: (m, 0, 0, 0)),
            pl.BlockSpec((lb, n_h, 4, n_h), lambda nb, d, m, j, k: (m, 0, 0, 0)),
            pl.BlockSpec((lb, 3, n_h), lambda nb, d, m, j, k: (m, 0, 0)),
            pl.BlockSpec((lb, 4, n_h), lambda nb, d, m, j, k: (m, 0, 0)),
            pl.BlockSpec((L, bb, n_h), lambda nb, d, m, j, k: (0, nb, 0)),
            pl.BlockSpec((L, bb, n_h), lambda nb, d, m, j, k: (0, nb, 0)),
            pl.BlockSpec((T, bb), lambda nb, d, m, j, k: (0, nb)),
        ],
        out_specs=[
            pl.BlockSpec((1, lb, bb, bn), lambda nb, d, m, j, k: (d, m, nb, j)),
            pl.BlockSpec((1, lb, bb, bn), lambda nb, d, m, j, k: (d, m, nb, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((D, L, b, n_h), pre_x.dtype),
            jax.ShapeDtypeStruct((D, L, b, n_h), pre_x.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((L, 2, bb, n_h), jnp.float32),  # h double buffers
            pltpu.VMEM((L, bb, n_h), jnp.float32),     # c, updated in place
            pltpu.VMEM((lb, bb, 4, bn), jnp.float32),  # gate accumulator
        ],
        interpret=interpret,
    )(pre_x, w_in, w_h, peep, bias, h0, c0, mask)
    return hs, cs


# ---------------------------------------------------------------------------
# int8 wavefront kernel — bit-accurate systolic datapath across the stack
# ---------------------------------------------------------------------------

def _stack_kernel_q(accx_ref, w_ref, peep_ref, bias_ref, sig_ref, tanh_ref,
                    h0_ref, c0_ref, mask_ref, hs_ref, cs_ref, h_scr, c_scr,
                    acc_ref, *, T: int, L: int, cols_h: int, tile: int):
    # Grid (NB, D, R, C): wavefront diagonals with EVERY layer batched per
    # grid step, as in the f32 kernel — R row blocks, C = 2*cols_h column
    # hops (below-h region then own-h region; layer 0's x-region prefix is
    # hoisted into accx and its below-region weight columns are zero, so
    # those hops are exact no-ops on its accumulator row).  The saturating
    # hop chains of different layers are independent, so the L-wide batched
    # MAC never reorders any single chain's saturations.
    d = pl.program_id(1)
    r = pl.program_id(2)
    c = pl.program_id(3)
    n_c = 2 * cols_h

    @pl.when((d == 0) & (r == 0) & (c == 0))
    def _load_state():
        # Both parity slots start defined (the below-layer column read
        # touches the off-parity slot of layer l-1 before it is first
        # written; bubbles discard the value, but the read must not touch
        # undefined memory).
        h_scr[:, 0] = h0_ref[...]
        h_scr[:, 1] = h0_ref[...]
        c_scr[...] = c0_ref[...]

    @pl.when(c == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(c == cols_h)
    def _load_x_prefix():
        # Layer 0 resumes the saturating hop chain from the precomputed
        # x-region prefix (bit-identical hoisting, as in the §6 scale-out);
        # its below-region hops left the row at exactly zero.
        acc_ref[0] = accx_ref[0, :, 0]

    # Batched tile MAC: stack every layer's column input for this hop
    # position — below-h region columns read the layer below's h_t codes
    # (the chip's inter-column handover), own-h region columns this layer's
    # resident h_{t-1} — then ONE L-wide dot_general in int32 (exact),
    # saturated to the 16-bit value an engine hands to its row neighbour,
    # then the hop.
    off_b = jnp.clip(c, 0, cols_h - 1) * tile
    off_o = jnp.clip(c - cols_h, 0, cols_h - 1) * tile
    is_below = c < cols_h
    cols = []
    for l in range(L):
        tc = jnp.clip(d - l, 0, T - 1)
        below_col = h_scr[max(l - 1, 0), (tc + 1) % 2, :, pl.ds(off_b, tile)]
        own_col = h_scr[l, tc % 2, :, pl.ds(off_o, tile)]
        cols.append(jnp.where(is_below, below_col, own_col))
    col_in = jnp.stack(cols).astype(jnp.int32)              # (L, bb, tile)
    w_blk = w_ref[:, pl.ds(c * tile, tile), :, pl.ds(r * tile, tile)]
    partial = _sat16(jax.lax.dot_general(
        col_in, w_blk.astype(jnp.int32).reshape(L, tile, 4 * tile),
        (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.int32,
    ).reshape(L, col_in.shape[1], 4, tile))
    acc_ref[...] = _sat16(acc_ref[...] + partial)

    @pl.when(c == n_c - 1)
    def _elementwise():
        sl = pl.ds(r * tile, tile)
        sig_lut = sig_ref[0]
        tanh_lut = tanh_ref[0]
        shift8 = ACC_FMT.frac_bits - quant.STATE_FMT.frac_bits
        for l in range(L):
            t = d - l
            act = (t >= 0) & (t < T)
            tc = jnp.clip(t, 0, T - 1)
            c_prev32 = c_scr[l, :, sl].astype(jnp.int32)
            peep32 = peep_ref[l, :, sl].astype(jnp.int32)
            bias32 = bias_ref[l, :, sl].astype(jnp.int32)
            acc_l = acc_ref[l]

            def gate(idx, peep_idx, c_term, lut):
                a = acc_l[:, idx, :] + bias32[idx]
                if peep_idx is not None:
                    a = a + peep32[peep_idx] * c_term
                a = _sat16(a)
                a8 = jnp.clip(_rshift_round(a, shift8), -128, 127)
                return quant.apply_lut(lut, a8,
                                       quant.STATE_FMT).astype(jnp.int32)

            i = gate(0, 0, c_prev32, sig_lut)
            f = gate(1, 1, c_prev32, sig_lut)
            g = gate(2, None, None, tanh_lut)
            fc = f * c_prev32                    # Q0.7 * Q2.5 -> frac 12
            ig = _rshift_round(i * g, 2)         # frac 14 -> 12
            c_new = _sat16(fc + ig)              # Q3.12
            c_new8 = jnp.clip(
                _rshift_round(c_new,
                              CELL_FMT.frac_bits - quant.STATE_FMT.frac_bits),
                -128, 127)
            o = gate(3, 2, c_new8, sig_lut)
            tanh_c = quant.apply_lut(tanh_lut, c_new8,
                                     quant.STATE_FMT).astype(jnp.int32)
            h_new = _rshift_round(o * tanh_c, 14 - quant.STATE_FMT.frac_bits)
            h_new8 = jnp.clip(h_new, -128, 127).astype(jnp.int8)

            # Masked step / wavefront bubble = identity on the resident
            # codes (pure select), with the same write-slot discipline as
            # the f32 kernel: a masked LIVE step re-emits the carried
            # h_{t-1} (slot t%2), a bubble is identity on its WRITE slot.
            m = act & (mask_ref[tc] > 0)
            live = m[:, None]
            keep_h = jnp.where(act, h_scr[l, tc % 2, :, sl],
                               h_scr[l, (tc + 1) % 2, :, sl])
            h8 = jnp.where(live, h_new8, keep_h)
            c8 = jnp.where(live, c_new8.astype(jnp.int8), c_scr[l, :, sl])
            h_scr[l, (tc + 1) % 2, :, sl] = h8
            c_scr[l, :, sl] = c8
            hs_ref[0, l] = h8
            cs_ref[0, l] = c8


@functools.partial(jax.jit, static_argnames=('tile', 'cols_h', 'bb',
                                             'interpret'))
def lstm_stack_seq_kernel_q(acc_x: jax.Array, w: jax.Array, peep: jax.Array,
                            bias: jax.Array, sig_lut: jax.Array,
                            tanh_lut: jax.Array, h0: jax.Array,
                            c0: jax.Array, mask: Optional[jax.Array] = None,
                            *, tile: int, cols_h: int,
                            bb: Optional[int] = None,
                            interpret: bool = False):
    """Whole-stack bit-accurate int8 wavefront LSTM (raw kernel entry).

    acc_x: (T, B, R, 4, tile) int32 hoisted layer-0 x-region hop prefix (the
    first ``cols_x`` saturating hops, which depend only on the frame codes);
    w: (L, 2*cols_h*tile, 4, padded_h) int8 resident blocks in ``(k, gate,
    n)`` layout — columns ``[0, cols_h*tile)`` hold each inner layer's
    input-region tiles (zero for layer 0), columns ``[cols_h*tile, ...)``
    the own-h-region tiles; peep: (L, 3, padded_h) int8; bias: (L, 4,
    padded_h) int16 in ACC_FMT; sig/tanh LUTs (1, 256) int8; h0, c0: (L, B,
    padded_h) int8 carried codes; ``mask``: optional (T, B) int8 validity
    mask shared by all layers (a masked step carries every layer's codes
    through unchanged; ``None`` is bit-identical to all-ones).

    Returns (hs, cs) in DIAGONAL-major layout like the f32 kernel, each
    (D, L, B, padded_h) int8 with ``D = T + L - 1``: ``hs[d, l]`` is layer
    ``l``'s step ``d - l``; entries outside each layer's ``[l, l + T)``
    band are don't-care bubble flushes.  After the ops wrapper's
    re-indexing the codes are bit-identical, layer by layer, to chaining
    ``kernel.lstm_seq_quantized`` with each layer's hidden codes fed as
    the next layer's input codes.
    """
    T, b = acc_x.shape[0], acc_x.shape[1]
    L = w.shape[0]
    padded_h = w.shape[3]
    bb = b if bb is None else bb
    assert b % bb == 0, (b, bb)
    assert w.shape[1] == 2 * cols_h * tile, (w.shape, cols_h, tile)
    if mask is None:
        mask = jnp.ones((T, b), jnp.int8)
    R = padded_h // tile
    D = T + L - 1

    return pl.pallas_call(
        functools.partial(_stack_kernel_q, T=T, L=L, cols_h=cols_h,
                          tile=tile),
        grid=(b // bb, D, R, 2 * cols_h),
        in_specs=[
            pl.BlockSpec((1, bb, 1, 4, tile),
                         lambda nb, d, r, c: (jnp.clip(d, 0, T - 1),
                                              nb, r, 0, 0)),
            pl.BlockSpec((L, 2 * cols_h * tile, 4, padded_h),
                         lambda nb, d, r, c: (0, 0, 0, 0)),
            pl.BlockSpec((L, 3, padded_h), lambda nb, d, r, c: (0, 0, 0)),
            pl.BlockSpec((L, 4, padded_h), lambda nb, d, r, c: (0, 0, 0)),
            pl.BlockSpec((1, 256), lambda nb, d, r, c: (0, 0)),
            pl.BlockSpec((1, 256), lambda nb, d, r, c: (0, 0)),
            pl.BlockSpec((L, bb, padded_h), lambda nb, d, r, c: (0, nb, 0)),
            pl.BlockSpec((L, bb, padded_h), lambda nb, d, r, c: (0, nb, 0)),
            pl.BlockSpec((T, bb), lambda nb, d, r, c: (0, nb)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, bb, tile),
                         lambda nb, d, r, c: (d, 0, nb, r)),
            pl.BlockSpec((1, L, bb, tile),
                         lambda nb, d, r, c: (d, 0, nb, r)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((D, L, b, padded_h), jnp.int8),
            jax.ShapeDtypeStruct((D, L, b, padded_h), jnp.int8),
        ],
        scratch_shapes=[
            pltpu.VMEM((L, 2, bb, padded_h), jnp.int8),  # h codes, t parity
            pltpu.VMEM((L, bb, padded_h), jnp.int8),     # c codes
            pltpu.VMEM((L, bb, 4, tile), jnp.int32),     # saturating accs
        ],
        interpret=interpret,
    )(acc_x, w, peep, bias, sig_lut, tanh_lut, h0, c0, mask)
