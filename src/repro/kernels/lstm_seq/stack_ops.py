"""Public ops for the fused whole-stack wavefront LSTM kernel.

``lstm_stack_seq`` is the stack-level drop-in for looping
``core.lstm.lstm_layer_fused`` over the layers of ``lstm_stack_apply`` /
``lstm_stack_chunk`` (the dense read-out stays at the call site): one kernel
launch executes every layer, forward allclose to the layerwise composition
and backward through the cross-layer extension of the gate-recompute VJP.
``lstm_stack_seq_quantized`` is the whole-stack form of chaining
``lstm_layer_seq_quantized`` layer by layer — bit-identical int8 hidden
codes, one launch instead of L, including the opaque per-layer ``(h_q,
c_q)`` chunk carry and the §7 valid-length mask.  Padding to MXU tiles, the
hoisted layer-0 input matmul, the ``(k, gate, n)`` weight relayout, and
un-padding all live here so call sites never see kernel geometry.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ...core.lstm import (GATES, LSTMStackParams,
                          lstm_stack_bwd_recompute_gates, stack_carry_arrays,
                          valid_len_mask)
from ...core.systolic import QuantizedPackedLSTM, quantized_x_prefix
from .._padding import pad_axis_to as _pad_to, round_up as _round_up
from .ops import _dense_from_tiles
from .stack_kernel import lstm_stack_seq_kernel, lstm_stack_seq_kernel_q


def stack_vmem_bytes_estimate(n_x: int, n_h: int, n_layers: int, batch: int,
                              bn: int = 128, bk: int = 128,
                              dtype_bytes: int = 4,
                              bb: Optional[int] = None) -> int:
    """Resident VMEM working set of the fused f32 stack kernel (for selection).

    A conservative upper bound with no numerics of its own: stack-level
    backend selection admits ``pallas_seq_fused`` only when this fits the
    VMEM budget, falling back to the layerwise ``pallas_seq`` path
    otherwise.  Counts BOTH resident weight families (every layer's ``W_h``
    plus the inner layers' ``W_in``), the per-layer peephole/bias rows, the
    per-layer h/c scratch (double-buffered h), the per-layer carried
    ``h0/c0`` blocks, and the double-buffered streamed blocks.
    """
    n_h_p = _round_up(n_h, math.lcm(bn, bk))
    b_p = max(8, _round_up(batch, 8))
    b_s = b_p if bb is None else min(b_p, bb)
    weights = 2 * n_layers * GATES * n_h_p * n_h_p * dtype_bytes
    consts = n_layers * (3 + GATES) * n_h_p * dtype_bytes
    state = (n_layers * 3 * b_s * n_h_p * 4            # h (x2) + c scratch
             + 2 * n_layers * b_s * n_h_p * dtype_bytes)  # h0/c0 blocks
    stream = 2 * (GATES * b_s * bn * dtype_bytes       # pre_x block
                  + 2 * 2 * b_s * bn * dtype_bytes)    # hs/cs out blocks
    return weights + consts + state + stream


def stack_fused_compatible(params: LSTMStackParams) -> bool:
    """Structural admission for the fused stack kernel (no numerics of its
    own — pure dispatch): True iff every layer shares one hidden width and
    every inner layer's input width equals it, i.e. the stack is the
    homogeneous ``n_x -> n_h -> n_h -> ...`` shape whose inter-layer
    handover the wavefront scratch can carry.  Heterogeneous stacks fall
    back to the layerwise path."""
    layers = params.layers
    if not layers:
        return False
    n_h = layers[0].n_h
    return (all(l.n_h == n_h for l in layers)
            and all(l.n_x == n_h for l in layers[1:]))


# ---------------------------------------------------------------------------
# f32 path with the cross-layer production training VJP
# ---------------------------------------------------------------------------

def _stack_forward(cfg, w_in, w_h, peep, b, pre_x, h0s, c0s, mask=None):
    """Pad, relayout, run the wavefront kernel, un-pad.

    Numerics-neutral wrapper (zero padding + layout transposes only).
    w_in/w_h: (L, 4, N_h, N_h) core layout (``w_in[0]`` ignored); pre_x:
    (T, B, 4, N_h); h0s/c0s: (L, B, N_h); mask: optional (T, B).  Returns
    (hs, cs), each (L, T, B, N_h).
    """
    bn, bk, bb, lb, interpret = cfg
    T, B, _, n_h = pre_x.shape
    n_h_p = _round_up(n_h, math.lcm(bn, bk))
    b_p = max(8, _round_up(B, 8))
    if bb is not None:
        b_p = _round_up(b_p, bb)

    def relayout(w):  # (L, 4, N, K) -> resident (L, K, 4, N), padded
        w = _pad_to(_pad_to(w, n_h_p, 2), n_h_p, 3)
        return jnp.transpose(w, (0, 3, 1, 2))

    pre_k = _pad_to(_pad_to(pre_x, n_h_p, 3), b_p, 1)
    peep_p = _pad_to(peep, n_h_p, 2)
    bias_p = _pad_to(b, n_h_p, 2)
    h0_p = _pad_to(_pad_to(h0s, n_h_p, 2), b_p, 1)
    c0_p = _pad_to(_pad_to(c0s, n_h_p, 2), b_p, 1)
    mask_p = None if mask is None else _pad_to(
        mask.astype(pre_x.dtype), b_p, 1)

    hs_d, cs_d = lstm_stack_seq_kernel(
        pre_k, relayout(w_in), relayout(w_h), peep_p, bias_p, h0_p, c0_p,
        mask_p, bn=bn, bk=bk, bb=bb, lb=lb, interpret=interpret)
    # Diagonal-major -> layer-major: layer l's trajectory is its diagonal
    # band hs[l:l+T, l] (a pure re-indexing; bubble entries are dropped).
    L = w_h.shape[0]
    hs = jnp.stack([hs_d[l:l + T, l, :B, :n_h] for l in range(L)])
    cs = jnp.stack([cs_d[l:l + T, l, :B, :n_h] for l in range(L)])
    return hs, cs


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def lstm_stack_seq_fused(cfg, w_in, w_h, peep, b, pre_x, h0s, c0s):
    """Fused stack with the cross-layer gate-recompute VJP: forward allclose
    to looping ``core.lstm.lstm_scan_fused`` over the layers (each layer's
    output feeding the next), backward numerically equal to composing the
    per-layer ``lstm_bwd_recompute_gates`` down the stack — the inner
    layers' input-weight gradients and the handover cotangents are the only
    additions over the single-layer VJP.

    cfg is the static (bn, bk, bb, lb, interpret) tuple.  Returns (ys = top
    layer's hs (T, B, N_h), (h_T (L, B, N_h), c_T (L, B, N_h))).
    """
    hs, cs = _stack_forward(cfg, w_in, w_h, peep, b, pre_x, h0s, c0s)
    return hs[-1], (hs[:, -1], cs[:, -1])


def _stack_fwd(cfg, w_in, w_h, peep, b, pre_x, h0s, c0s):
    hs, cs = _stack_forward(cfg, w_in, w_h, peep, b, pre_x, h0s, c0s)
    return ((hs[-1], (hs[:, -1], cs[:, -1])),
            (w_in, w_h, peep, b, pre_x, hs, cs, h0s, c0s))


def _stack_bwd(cfg, res, grads):
    # Cross-layer gate recompute lives in core.lstm so the staged systolic
    # scale-out's VJP (core.systolic) composes the identical backward.
    w_in, w_h, peep, b, pre_x, hs, cs, h0s, c0s = res
    return lstm_stack_bwd_recompute_gates(w_in, w_h, peep, b, pre_x, hs, cs,
                                          h0s, c0s, grads)


lstm_stack_seq_fused.defvjp(_stack_fwd, _stack_bwd)


def _stack_arrays(params: LSTMStackParams):
    """Stack per-layer params into the (L, ...) kernel arrays (layer 0's
    input weights ride separately as the hoisted ``pre_x`` matmul)."""
    layers = params.layers
    w_h = jnp.stack([l.w_h for l in layers])
    w_in = jnp.stack([jnp.zeros_like(layers[0].w_h)]
                     + [l.w_x for l in layers[1:]])
    peep = jnp.stack([l.w_peep for l in layers])
    b = jnp.stack([l.b for l in layers])
    return w_in, w_h, peep, b


def _tuned_lb(n_x: int, n_h: int, n_layers: int, T: int,
              B: int) -> Optional[int]:
    """Tuned §8 layer-block streaming factor from the installed schedule
    cache (kind ``'stack_lb'``), or None on a miss.  Grid-only by contract
    (every legal ``lb`` is bit-equal), but the divisibility the grid needs
    is re-validated here — a stale entry can never break a launch."""
    from ...tune.schedule import current_schedule_cache
    cache = current_schedule_cache()
    if cache is None:
        return None
    ent = cache.lookup('stack_lb', n_x=n_x, n_h=n_h, n_layers=n_layers,
                       T=T, B=B)
    if ent is None or not ent.lb:
        return None
    lb = int(ent.lb)
    return lb if 1 <= lb <= n_layers and n_layers % lb == 0 else None


def lstm_stack_seq(params: LSTMStackParams, xs: jax.Array,
                   states: Optional[Sequence] = None, *,
                   valid_len: Optional[jax.Array] = None,
                   bn: Optional[int] = None, bk: Optional[int] = None,
                   bb: Optional[int] = None, lb: Optional[int] = None,
                   interpret: Optional[bool] = None
                   ) -> Tuple[jax.Array, Tuple]:
    """Fused drop-in for the layer loop of ``core.lstm.lstm_stack_apply`` /
    ``lstm_stack_chunk`` (everything except the dense read-out): ONE
    wavefront launch for all layers, output allclose to the layerwise
    composition on any backend, differentiable via the cross-layer
    gate-recompute VJP.

    xs: (T, B, N_x); states: optional per-layer ``((h, c), ...)`` carries
    from a previous chunk.  Requires ``stack_fused_compatible(params)``
    (homogeneous hidden widths) — dispatch falls back to the layerwise path
    otherwise.  ``valid_len``: optional (B,) ragged valid lengths shared by
    every layer (DESIGN.md §7 masking contract: a masked step is identity
    on each layer's carried state; inference-only, like the layerwise
    masked paths).  ``bb``/``lb`` select the batch-block and layer-block
    grid dimensions (defaults: one block each — all serving slots share one
    weight DMA, the whole stack stays resident; with a schedule cache
    installed, a tuned ``'stack_lb'`` winner fills ``lb=None`` first —
    grid-only by the §8 contract, bit-equal across every legal ``lb``).
    Returns (hs_top (T, B, N_h), per-layer ((h_T, c_T), ...)).
    """
    assert stack_fused_compatible(params), \
        'fused stack kernel needs homogeneous hidden widths'
    layers = params.layers
    n_h = layers[0].n_h
    T, B = xs.shape[0], xs.shape[1]
    assert xs.ndim == 3, 'lstm_stack_seq expects (T, B, N_x) input'
    if interpret is None:
        interpret = jax.default_backend() != 'tpu'
    if bn is None or bk is None:
        n_h_p = _round_up(n_h, 128)
        auto = next(b for b in (512, 256, 128) if n_h_p % b == 0)
        bn = bn or auto
        bk = bk or auto
    assert bb is None or bb % 8 == 0, \
        f'bb={bb} must be a multiple of 8 (f32 sublane tiling)'

    w_in, w_h, peep, b = _stack_arrays(params)
    pre_x = jnp.einsum('ghx,tbx->tbgh', layers[0].w_x, xs)    # hoisted

    h0s, c0s = stack_carry_arrays(states, len(layers), B, n_h, xs.dtype)
    if lb is None:
        lb = _tuned_lb(layers[0].n_x, n_h, len(layers), T, B)
    assert lb is None or len(layers) % lb == 0, (len(layers), lb)
    cfg = (bn, bk, bb, lb, bool(interpret))

    if valid_len is not None:
        mask = valid_len_mask(T, valid_len, B)
        hs, cs = _stack_forward(cfg, w_in, w_h, peep, b, pre_x, h0s, c0s,
                                mask)
        ys, h_T, c_T = hs[-1], hs[:, -1], cs[:, -1]
    else:
        ys, (h_T, c_T) = lstm_stack_seq_fused(cfg, w_in, w_h, peep, b,
                                              pre_x, h0s, c0s)
    finals = tuple((h_T[l], c_T[l]) for l in range(len(layers)))
    return ys, finals


# ---------------------------------------------------------------------------
# int8 path — whole-stack silicon datapath
# ---------------------------------------------------------------------------

def lstm_stack_seq_quantized(qps: Sequence[QuantizedPackedLSTM],
                             xs_q: jax.Array, *,
                             state: Optional[Tuple[jax.Array, jax.Array]] = None,
                             valid_len: Optional[jax.Array] = None,
                             return_state: bool = False,
                             bb: Optional[int] = None,
                             interpret: Optional[bool] = None):
    """Whole-stack int8 wavefront execution: bit-identical to chaining
    ``lstm_layer_seq_quantized`` (and hence the silicon reference scan
    ``systolic_cell_quantized``) layer by layer, with each layer's hidden
    codes fed as the next layer's input codes — one launch instead of L,
    the inter-layer codes never leaving VMEM scratch.

    qps: per-layer quantized packs sharing one ``tile`` and one hidden
    width (every inner layer's ``n_x`` == the stack's ``n_h``); xs_q:
    (T, B, n_x) int8 codes.  ``state``: opaque per-layer carry ``(h_q,
    c_q)``, each (L, B, padded_h) int8 as returned by a previous call with
    ``return_state=True`` (None = zero state); ``valid_len``: (B,) ragged
    mask shared by every layer.  Returns the top layer's (T, B, n_h) int8
    hidden codes, plus the state tuple when ``return_state``.
    """
    if interpret is None:
        interpret = jax.default_backend() != 'tpu'
    plans = [qp.plan for qp in qps]
    p0 = plans[0]
    L = len(qps)
    assert L >= 1
    assert all(p.tile == p0.tile for p in plans), 'mixed tiles'
    assert all(p.n_h == p0.n_h for p in plans), 'mixed hidden widths'
    assert all(p.n_x == p0.n_h for p in plans[1:]), \
        'inner layers must consume the stack hidden width'
    tile, cols_h, padded_h = p0.tile, p0.cols_h, p0.padded_h
    assert xs_q.ndim == 3, 'lstm_stack_seq_quantized expects (T, B, n_x)'
    T, B = xs_q.shape[0], xs_q.shape[1]
    b_p = B if bb is None else _round_up(B, bb)

    # Resident weight relayout: dense (4, padded_h, padded_in) per layer ->
    # (k, gate, n); inner layers fill the whole 2*cols_h*tile column span,
    # layer 0 only the own-h region (its x prefix is hoisted into acc_x).
    w_cols = 2 * cols_h * tile
    w_all = []
    peep_all, bias_all = [], []
    for l, qp in enumerate(qps):
        dense, peep, bias = _dense_from_tiles(qp)
        if l == 0:
            w_l = jnp.zeros((GATES, padded_h, w_cols), jnp.int8
                            ).at[:, :, cols_h * tile:].set(
                                dense[:, :, plans[0].padded_x:])
        else:
            w_l = dense                      # padded_in == 2*cols_h*tile
        w_all.append(jnp.transpose(w_l, (2, 0, 1)))
        peep_all.append(peep)
        bias_all.append(bias)
    w_all = jnp.stack(w_all)
    peep_all = jnp.stack(peep_all)
    bias_all = jnp.stack(bias_all)

    # Layer 0's x-region saturating-hop prefix, hoisted for the whole
    # sequence — the ONE shared implementation (core.systolic), so the §6
    # and §8 consumers cannot drift apart in saturation or hop order.
    xs_flat = jnp.zeros((T, b_p, p0.n_x), jnp.int8).at[:, :B].set(xs_q)
    acc_x = quantized_x_prefix(qps[0], xs_flat)
    if state is None:
        h0 = jnp.zeros((L, b_p, padded_h), jnp.int8)
        c0 = jnp.zeros((L, b_p, padded_h), jnp.int8)
    else:
        h0 = jnp.zeros((L, b_p, padded_h), jnp.int8).at[:, :B].set(state[0])
        c0 = jnp.zeros((L, b_p, padded_h), jnp.int8).at[:, :B].set(state[1])
    mask = None
    if valid_len is not None:
        mask = jnp.zeros((T, b_p), jnp.int8).at[:, :B].set(
            valid_len_mask(T, valid_len, B).astype(jnp.int8))

    hs_d, cs_d = lstm_stack_seq_kernel_q(
        acc_x, w_all, peep_all, bias_all,
        qps[0].sig_lut.reshape(1, 256), qps[0].tanh_lut.reshape(1, 256),
        h0, c0, mask, tile=tile, cols_h=cols_h, bb=bb,
        interpret=bool(interpret))
    # Diagonal-major -> layer-major, exactly as in the f32 wrapper: layer
    # l's trajectory is its diagonal band hs_d[l:l+T, l] (pure re-indexing;
    # bubble entries are dropped).
    hs = jnp.stack([hs_d[l:l + T, l] for l in range(L)])
    cs = jnp.stack([cs_d[l:l + T, l] for l in range(L)])
    out = hs[-1, :, :B, :p0.n_h]
    if not return_state:
        return out
    return out, (hs[:, -1, :B], cs[:, -1, :B])


def lstm_stack_seq_quantized_auto(qps: Sequence[QuantizedPackedLSTM],
                                  xs_q: jax.Array, *,
                                  state: Optional[Tuple[jax.Array,
                                                        jax.Array]] = None,
                                  valid_len: Optional[jax.Array] = None,
                                  return_state: bool = False,
                                  bb: Optional[int] = None,
                                  interpret: Optional[bool] = None,
                                  backend: str = 'auto'):
    """Shape-dispatched whole-stack int8 execution.

    Picks the fused wavefront (``lstm_stack_seq_quantized``) or the
    layerwise chain of ``lstm_layer_seq_quantized`` calls via
    ``core.lstm.select_quantized_stack_backend`` — since the §12 autotuner
    that decision consults the installed measured-schedule cache first
    (``repro.tune``), with the BENCH_kernels.json-calibrated width floor as
    the cold-cache fallback: the calibration pair shows the wavefront
    LOSING to the chain at small hidden widths (its fill/drain bubble and
    relayout overheads are fixed while the per-layer work shrinks), so
    small stacks run layerwise.  Bit-identical
    either way — that is the fused kernel's contract — and BOTH paths speak
    the STACK state layout (opaque ``(h_q, c_q)``, each ``(L, B, padded_h)``
    int8), so a chunked streaming caller can carry state across chunks
    regardless of which launch shape each chunk resolved to.  ``backend``
    forces ``'fused'``/``'layerwise'`` explicitly (tests pin both).
    """
    assert xs_q.ndim == 3, 'lstm_stack_seq_quantized_auto expects (T, B, n_x)'
    if backend == 'auto':
        from ...core.lstm import select_quantized_stack_backend
        backend = select_quantized_stack_backend(
            qps[0].plan.n_h, len(qps), xs_q.shape[0], xs_q.shape[1])
    assert backend in ('fused', 'layerwise'), backend
    if backend == 'fused':
        return lstm_stack_seq_quantized(
            qps, xs_q, state=state, valid_len=valid_len,
            return_state=return_state, bb=bb, interpret=interpret)
    from .ops import lstm_layer_seq_quantized
    out = xs_q
    h_fin, c_fin = [], []
    for l, qp in enumerate(qps):
        st_l = None if state is None else (state[0][l], state[1][l])
        out, (h_l, c_l) = lstm_layer_seq_quantized(
            qp, out, state=st_l, valid_len=valid_len, return_state=True,
            bb=bb, interpret=interpret)
        h_fin.append(h_l)
        c_fin.append(c_l)
    if not return_state:
        return out
    return out, (jnp.stack(h_fin), jnp.stack(c_fin))
