"""Pure-jnp oracle for the whole-sequence kernel (hoisted-pre_x layout)."""
import jax
import jax.numpy as jnp


def lstm_seq_ref(w_h, peep, bias, pre_x, h0, c0):
    """pre_x: (T, B, 4, N_h); returns (hs, cs) each (T, B, N_h)."""

    def step(carry, pre_x_t):
        h, c = carry
        pre = pre_x_t + jnp.einsum('ghk,bk->bgh', w_h, h)
        i = jax.nn.sigmoid(pre[:, 0] + peep[0] * c + bias[0])
        f = jax.nn.sigmoid(pre[:, 1] + peep[1] * c + bias[1])
        g = jnp.tanh(pre[:, 2] + bias[2])
        c = f * c + i * g
        o = jax.nn.sigmoid(pre[:, 3] + peep[2] * c + bias[3])
        h = o * jnp.tanh(c)
        return (h, c), (h, c)

    _, (hs, cs) = jax.lax.scan(step, (h0, c0), pre_x)
    return hs, cs
