"""Pallas TPU kernel: persistent whole-sequence LSTM (weight-stationary).

This is the TPU analogue of Chipmunk's core loop (Sec. 3.2): the packed gate
matrix stays resident in engine SRAM for the *entire* utterance and the
``h``/``c`` state never leaves the local register file between timesteps.  The
per-step kernel in ``kernels/lstm_gates`` re-streams ``W`` from HBM and
round-trips ``h``/``c`` through HBM on every timestep; here one ``pallas_call``
owns the whole sequence:

  * grid ``(T, N_h/bn, N_h/bk)`` — time outermost, then the output-row blocks,
    then the recurrent reduction blocks;
  * the recurrent weight ``W_h`` (4, N_h, N_h), peepholes, and biases use
    constant index maps, so Mosaic DMAs them into VMEM once and every grid step
    revisits the same resident copy (weight-stationary block residency);
  * ``h``/``c`` live in VMEM scratch across all T steps.  ``h`` is
    double-buffered on t-parity because step t+1's reduction reads *all* of
    ``h_t`` while step t is still writing it block by block; ``c`` is updated
    in place (block j of ``c_t`` depends only on block j of ``c_{t-1}``);
  * the non-recurrent contribution ``W_x @ x_t`` is hoisted out of the
    recurrence into one wide matmul (exactly like ``core.lstm.lstm_layer``)
    and streamed into the kernel per (t, j) block;
  * the elementwise phase (peepholes, nonlinearities, state update) fuses into
    the final K step, so gate pre-activations never touch HBM.

Both kernels take an optional batch-block size ``bb`` that adds an OUTERMOST
batch grid dimension: each block replays the full T-step recurrence against
the same resident weights, so a serving slot grid amortises a single weight
DMA across all slots instead of paying one per batch block.

Both kernels also take a per-(t, b) validity mask (the streaming-serving
contract of DESIGN.md §7): a masked step is an *identity* on the resident
state — ``h_t = h_{t-1}``, ``c_t = c_{t-1}`` via ``jnp.where`` (no arithmetic
on the carried values, so an all-ones mask is bit-identical to the unmasked
kernel) — which is what lets ragged streams share one batched launch without
padded tail steps corrupting the state carried into the next chunk.

The int8 variant (`lstm_seq_quantized`) runs the same persistent schedule over
the bit-accurate systolic datapath of ``core.systolic.systolic_cell_quantized``:
int8 weight tiles resident in VMEM, per-tile int32 MACs saturated to int16, a
sequential saturating hop over the column blocks (x-region columns streamed,
h-region columns read from the VMEM state), LUT nonlinearities, and the exact
shift/clip alignment of the silicon.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core import quant
from ...core.systolic import ACC_FMT, CELL_FMT


# ---------------------------------------------------------------------------
# f32 kernel
# ---------------------------------------------------------------------------

def _seq_kernel(pre_x_ref, w_ref, peep_ref, bias_ref, h0_ref, c0_ref,
                mask_ref, hs_ref, cs_ref, h_scr, c_scr, acc_ref, *, n_k: int,
                bn: int, bk: int):
    # Grid (NB, T, J, K): the batch-block dimension is OUTERMOST, so the
    # resident weights serve every batch block (serving slots) from one DMA.
    t = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when((t == 0) & (j == 0) & (k == 0))
    def _load_state():
        h_scr[0] = h0_ref[...].astype(jnp.float32)
        c_scr[...] = c0_ref[...].astype(jnp.float32)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Recurrent reduction: one (B, bk) x (bk, bn) MXU dot per gate against the
    # VMEM-resident weight block.  h_{t-1} comes from the t-parity scratch slot.
    h_prev = h_scr[t % 2, :, pl.ds(k * bk, bk)]                # (B, bk)
    for g in range(4):
        acc_ref[g] += jax.lax.dot_general(
            h_prev, w_ref[g, pl.ds(j * bn, bn), pl.ds(k * bk, bk)],
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _elementwise():
        sl = pl.ds(j * bn, bn)
        pre = acc_ref[...] + pre_x_ref[0].astype(jnp.float32)  # (4, B, bn)
        peep = peep_ref[:, sl].astype(jnp.float32)             # (3, bn)
        bias = bias_ref[:, sl].astype(jnp.float32)             # (4, bn)
        c_prev = c_scr[:, sl]                                  # (B, bn)
        i = jax.nn.sigmoid(pre[0] + peep[0] * c_prev + bias[0])
        f = jax.nn.sigmoid(pre[1] + peep[1] * c_prev + bias[1])
        g = jnp.tanh(pre[2] + bias[2])
        c_new = f * c_prev + i * g
        o = jax.nn.sigmoid(pre[3] + peep[2] * c_new + bias[3])
        h_new = o * jnp.tanh(c_new)
        # Masked step = identity on the resident state (select, no arithmetic
        # — the all-ones mask path stays bit-identical to the unmasked form).
        m = (mask_ref[0] > 0)[:, None]                         # (B, 1)
        h_new = jnp.where(m, h_new, h_scr[t % 2, :, sl])
        c_new = jnp.where(m, c_new, c_prev)
        h_scr[(t + 1) % 2, :, sl] = h_new
        c_scr[:, sl] = c_new
        hs_ref[0] = h_new.astype(hs_ref.dtype)
        cs_ref[0] = c_new.astype(cs_ref.dtype)


@functools.partial(jax.jit, static_argnames=('bn', 'bk', 'bb', 'interpret'))
def lstm_seq(pre_x: jax.Array, w_h: jax.Array, peep: jax.Array,
             bias: jax.Array, h0: jax.Array, c0: jax.Array,
             mask: Optional[jax.Array] = None, *,
             bn: int = 128, bk: int = 128, bb: Optional[int] = None,
             interpret: bool = False):
    """Whole-sequence fused LSTM.

    pre_x: (T, 4, B, N_h) hoisted ``W_x @ x_t + (0)`` pre-activations;
    w_h: (4, N_h, N_h); peep: (3, N_h); bias: (4, N_h); h0, c0: (B, N_h).
    N_h must be a multiple of both bn and bk; B a multiple of 8 and of the
    batch block ``bb`` (None = one block).  ``bb`` adds an outermost batch
    grid dimension: each block runs the full T-step recurrence against the
    same resident weights, so serving slots amortise one weight DMA.
    ``mask``: optional (T, B) validity mask (>0 = live step); a masked step
    carries h/c through unchanged and re-emits the carried values (None =
    all steps live, bit-identical to the masked call with an all-ones mask).
    Returns (hs, cs), each (T, B, N_h).
    """
    T, _, b, n_h = pre_x.shape
    bb = b if bb is None else bb
    assert n_h % bn == 0 and n_h % bk == 0, (n_h, bn, bk)
    assert b % bb == 0, (b, bb)
    if mask is None:
        mask = jnp.ones((T, b), pre_x.dtype)
    n_k = n_h // bk

    hs, cs = pl.pallas_call(
        functools.partial(_seq_kernel, n_k=n_k, bn=bn, bk=bk),
        grid=(b // bb, T, n_h // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, 4, bb, bn), lambda nb, t, j, k: (t, 0, nb, j)),
            # Constant index maps: fetched once, resident for the whole grid.
            pl.BlockSpec((4, n_h, n_h), lambda nb, t, j, k: (0, 0, 0)),
            pl.BlockSpec((3, n_h), lambda nb, t, j, k: (0, 0)),
            pl.BlockSpec((4, n_h), lambda nb, t, j, k: (0, 0)),
            pl.BlockSpec((bb, n_h), lambda nb, t, j, k: (nb, 0)),
            pl.BlockSpec((bb, n_h), lambda nb, t, j, k: (nb, 0)),
            pl.BlockSpec((1, bb), lambda nb, t, j, k: (t, nb)),
        ],
        out_specs=[
            pl.BlockSpec((1, bb, bn), lambda nb, t, j, k: (t, nb, j)),
            pl.BlockSpec((1, bb, bn), lambda nb, t, j, k: (t, nb, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, b, n_h), pre_x.dtype),
            jax.ShapeDtypeStruct((T, b, n_h), pre_x.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, bb, n_h), jnp.float32),  # h double buffer (t parity)
            pltpu.VMEM((bb, n_h), jnp.float32),     # c, updated in place
            pltpu.VMEM((4, bb, bn), jnp.float32),   # gate pre-act accumulator
        ],
        interpret=interpret,
    )(pre_x, w_h, peep, bias, h0, c0, mask)
    return hs, cs


# ---------------------------------------------------------------------------
# int8 kernel — bit-accurate systolic datapath (contribution C2)
# ---------------------------------------------------------------------------

_sat16 = quant.saturate_int16
_rshift_round = quant.rshift_round


def _seq_kernel_q(xs_ref, w_ref, peep_ref, bias_ref, sig_ref, tanh_ref,
                  h0_ref, c0_ref, mask_ref, hs_ref, cs_ref, h_scr, c_scr,
                  acc_ref, *, n_c: int, cols_x: int, tile: int):
    # Grid (NB, T, R, C) — batch blocks outermost, as in the f32 kernel.
    t = pl.program_id(1)
    r = pl.program_id(2)
    c = pl.program_id(3)

    @pl.when((t == 0) & (r == 0) & (c == 0))
    def _load_state():
        h_scr[0] = h0_ref[...]
        c_scr[...] = c0_ref[...]

    @pl.when(c == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Column input: x-region columns consume the streamed frame slice, h-region
    # columns read the resident hidden state (the chip's vertical re-broadcast).
    h_off = jnp.maximum(c - cols_x, 0) * tile
    h_col = jax.lax.dynamic_slice(h_scr[t % 2], (0, h_off),
                                  (h_scr.shape[1], tile))
    col_in = jnp.where(c < cols_x, xs_ref[0], h_col).astype(jnp.int32)

    # Per-engine tile MAC in wide arithmetic, saturated to the 16-bit value an
    # engine hands to its row neighbour, then the sequential saturating hop.
    for g in range(4):
        partial = _sat16(jax.lax.dot_general(
            col_in, w_ref[g, pl.ds(r * tile, tile),
                          pl.ds(c * tile, tile)].astype(jnp.int32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32))
        acc_ref[g] = _sat16(acc_ref[g] + partial)

    @pl.when(c == n_c - 1)
    def _elementwise():
        sl = pl.ds(r * tile, tile)
        c_prev32 = c_scr[:, sl].astype(jnp.int32)
        peep32 = peep_ref[:, sl].astype(jnp.int32)
        bias32 = bias_ref[:, sl].astype(jnp.int32)
        sig_lut = sig_ref[0]
        tanh_lut = tanh_ref[0]
        shift8 = ACC_FMT.frac_bits - quant.STATE_FMT.frac_bits

        def gate(idx, peep_idx, c_term, lut):
            a = acc_ref[idx] + bias32[idx]
            if peep_idx is not None:
                a = a + peep32[peep_idx] * c_term
            a = _sat16(a)
            a8 = jnp.clip(_rshift_round(a, shift8), -128, 127)
            return quant.apply_lut(lut, a8, quant.STATE_FMT).astype(jnp.int32)

        i = gate(0, 0, c_prev32, sig_lut)
        f = gate(1, 1, c_prev32, sig_lut)
        g = gate(2, None, None, tanh_lut)
        fc = f * c_prev32                        # Q0.7 * Q2.5 -> frac 12
        ig = _rshift_round(i * g, 2)             # frac 14 -> 12
        c_new = _sat16(fc + ig)                  # Q3.12
        c_new8 = jnp.clip(
            _rshift_round(c_new, CELL_FMT.frac_bits - quant.STATE_FMT.frac_bits),
            -128, 127)
        o = gate(3, 2, c_new8, sig_lut)
        tanh_c = quant.apply_lut(tanh_lut, c_new8,
                                 quant.STATE_FMT).astype(jnp.int32)
        h_new = _rshift_round(o * tanh_c, 14 - quant.STATE_FMT.frac_bits)
        h8 = jnp.clip(h_new, -128, 127).astype(jnp.int8)

        # Masked step = identity on the resident codes (pure select — the
        # all-ones mask path stays bit-identical to the unmasked datapath).
        m = (mask_ref[0] > 0)[:, None]
        h8 = jnp.where(m, h8, h_scr[t % 2, :, sl])
        c8 = jnp.where(m, c_new8.astype(jnp.int8), c_scr[:, sl])

        h_scr[(t + 1) % 2, :, sl] = h8
        c_scr[:, sl] = c8
        hs_ref[0] = h8
        cs_ref[0] = c8


@functools.partial(jax.jit, static_argnames=('tile', 'cols_x', 'bb',
                                             'interpret'))
def lstm_seq_quantized(xs_q: jax.Array, w_q: jax.Array, peep_q: jax.Array,
                       bias_q: jax.Array, sig_lut: jax.Array,
                       tanh_lut: jax.Array,
                       h0_q: Optional[jax.Array] = None,
                       c0_q: Optional[jax.Array] = None,
                       mask: Optional[jax.Array] = None, *, tile: int,
                       cols_x: int, bb: Optional[int] = None,
                       interpret: bool = False):
    """Whole-sequence bit-accurate int8 LSTM.

    xs_q: (T, B, padded_x) int8 frame codes; w_q: (4, padded_h, padded_in) int8
    dense engine-tile layout (``[W_x | W_h]`` with the x-region padded to whole
    tiles); peep_q: (3, padded_h) int8; bias_q: (4, padded_h) int16 in ACC_FMT;
    sig_lut/tanh_lut: (1, 256) int8; ``bb`` an optional batch block (B must
    divide by it; batch blocks iterate outermost so the resident weights are
    fetched once).  ``h0_q``/``c0_q``: optional (B, padded_h) int8 state codes
    carried in from a previous chunk (None = zero state); ``mask``: optional
    (T, B) int8 validity mask — a masked step carries the codes through
    unchanged (pure select, so the all-ones mask is bit-identical to None).
    Returns (hs_q, cs_q), each (T, B, padded_h) int8, bit-identical to
    scanning ``core.systolic.systolic_cell_quantized`` from the given state.
    """
    T, b, padded_x = xs_q.shape
    _, padded_h, padded_in = w_q.shape
    assert padded_x == cols_x * tile and padded_in % tile == 0
    bb = b if bb is None else bb
    assert b % bb == 0, (b, bb)
    if h0_q is None:
        h0_q = jnp.zeros((b, padded_h), jnp.int8)
    if c0_q is None:
        c0_q = jnp.zeros((b, padded_h), jnp.int8)
    if mask is None:
        mask = jnp.ones((T, b), jnp.int8)
    n_c = padded_in // tile

    return pl.pallas_call(
        functools.partial(_seq_kernel_q, n_c=n_c, cols_x=cols_x, tile=tile),
        grid=(b // bb, T, padded_h // tile, n_c),
        in_specs=[
            pl.BlockSpec((1, bb, tile),
                         lambda nb, t, r, c: (t, nb, jnp.minimum(c, cols_x - 1))),
            pl.BlockSpec((4, padded_h, padded_in),
                         lambda nb, t, r, c: (0, 0, 0)),
            pl.BlockSpec((3, padded_h), lambda nb, t, r, c: (0, 0)),
            pl.BlockSpec((4, padded_h), lambda nb, t, r, c: (0, 0)),
            pl.BlockSpec((1, 256), lambda nb, t, r, c: (0, 0)),
            pl.BlockSpec((1, 256), lambda nb, t, r, c: (0, 0)),
            pl.BlockSpec((bb, padded_h), lambda nb, t, r, c: (nb, 0)),
            pl.BlockSpec((bb, padded_h), lambda nb, t, r, c: (nb, 0)),
            pl.BlockSpec((1, bb), lambda nb, t, r, c: (t, nb)),
        ],
        out_specs=[
            pl.BlockSpec((1, bb, tile), lambda nb, t, r, c: (t, nb, r)),
            pl.BlockSpec((1, bb, tile), lambda nb, t, r, c: (t, nb, r)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, b, padded_h), jnp.int8),
            jax.ShapeDtypeStruct((T, b, padded_h), jnp.int8),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, bb, padded_h), jnp.int8),  # h codes, t parity
            pltpu.VMEM((bb, padded_h), jnp.int8),     # c codes
            pltpu.VMEM((4, bb, tile), jnp.int32),     # saturating accumulator
        ],
        interpret=interpret,
    )(xs_q, w_q, peep_q, bias_q, sig_lut, tanh_lut, h0_q, c0_q, mask)
