from .kernel import lstm_seq, lstm_seq_quantized
from .ops import (lstm_layer_seq, lstm_layer_seq_quantized, lstm_seq_fused,
                  vmem_bytes_estimate)
from .ref import lstm_seq_ref

__all__ = ['lstm_seq', 'lstm_seq_quantized', 'lstm_layer_seq',
           'lstm_layer_seq_quantized', 'lstm_seq_fused', 'lstm_seq_ref',
           'vmem_bytes_estimate']
