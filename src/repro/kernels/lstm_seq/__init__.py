from .kernel import lstm_seq, lstm_seq_quantized
from .ops import (lstm_layer_seq, lstm_layer_seq_quantized, lstm_seq_fused,
                  vmem_bytes_estimate)
from .ref import lstm_seq_ref
from .stack_kernel import lstm_stack_seq_kernel, lstm_stack_seq_kernel_q
from .stack_ops import (lstm_stack_seq, lstm_stack_seq_fused,
                        lstm_stack_seq_quantized,
                        lstm_stack_seq_quantized_auto,
                        stack_fused_compatible, stack_vmem_bytes_estimate)

__all__ = ['lstm_seq', 'lstm_seq_quantized', 'lstm_layer_seq',
           'lstm_layer_seq_quantized', 'lstm_seq_fused', 'lstm_seq_ref',
           'vmem_bytes_estimate', 'lstm_stack_seq', 'lstm_stack_seq_fused',
           'lstm_stack_seq_quantized', 'lstm_stack_seq_quantized_auto',
           'lstm_stack_seq_kernel', 'lstm_stack_seq_kernel_q',
           'stack_fused_compatible', 'stack_vmem_bytes_estimate']
