"""Public op: quantized linear with automatic padding + calibration helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import quant
from .kernel import quant_matmul
from .ref import quant_matmul_ref


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def quantized_linear(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                     *, use_pallas: bool = True, interpret: bool = True,
                     bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """f32/bf16 activations x pre-quantized int8 weights -> f32.

    Activations are dynamically quantized per-row (the Chipmunk x-stream is 8-bit
    too).  Shapes: x (..., K), w_q (K, N), w_scale (N,) or scalar.
    """
    lead = x.shape[:-1]
    k, n = w_q.shape
    x2 = x.reshape(-1, k)
    xs = quant.abs_max_scale(x2, axis=-1)          # (M, 1) per-row
    x_q = quant.quantize_scaled(x2, xs)
    ws = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (n,))[None, :]

    if not use_pallas:
        out = quant_matmul_ref(x_q, w_q, xs, ws)
    else:
        m = x_q.shape[0]
        bm_eff = min(bm, max(8, m))
        x_p = _pad_to(_pad_to(x_q, bm_eff, 0), bk, 1)
        w_p = _pad_to(_pad_to(w_q, bk, 0), bn, 1)
        xs_p = _pad_to(xs, bm_eff, 0)
        ws_p = _pad_to(ws, bn, 1)
        out = quant_matmul(x_p, w_p, xs_p, ws_p, bm=bm_eff, bn=bn, bk=bk,
                           interpret=interpret)[:m, :n]
    return out.reshape(lead + (n,))


def quantize_weights(w: jax.Array):
    """Per-output-channel symmetric int8 weights.  w: (K, N) -> (w_q, scale (N,))."""
    scale = quant.abs_max_scale(w, axis=0)         # (1, N)
    return quant.quantize_scaled(w, scale), scale[0]
