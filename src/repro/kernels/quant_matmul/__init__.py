from .kernel import quant_matmul
from .ops import quantized_linear, quantize_weights
from .ref import quant_matmul_ref

__all__ = ['quant_matmul', 'quantized_linear', 'quantize_weights', 'quant_matmul_ref']
