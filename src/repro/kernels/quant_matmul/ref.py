"""Pure-jnp oracle for the int8 x int8 -> int32 matmul with per-channel scales."""
import jax
import jax.numpy as jnp


def quant_matmul_ref(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                     w_scale: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """x_q: (M, K) int8; w_q: (K, N) int8; x_scale: scalar or (M, 1);
    w_scale: scalar or (1, N).  Returns (M, N) in out_dtype."""
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * (x_scale * w_scale)).astype(out_dtype)
