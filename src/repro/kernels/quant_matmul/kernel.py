"""Pallas TPU kernel: int8 x int8 -> int32 matmul with per-channel dequant epilogue.

This is Chipmunk's C2 arithmetic (8-bit storage, wide accumulation) mapped onto the
TPU MXU, which natively executes int8 x int8 -> int32 at 2x bf16 throughput on v5e.
Blocking: (bm x bk) @ (bk x bn) MXU tiles, K innermost in the grid so the int32
accumulator lives in a VMEM scratch and is revisited across K steps; the dequant
epilogue (per-row activation scale x per-column weight scale) runs on the final
K step only.

VMEM working set per step: bm*bk + bk*bn bytes (int8) + bm*bn*4 (acc) —
128x512x512 blocks => 64 kB + 256 kB + 256 kB, comfortably inside ~16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU int8 path: ask for an int32 accumulator explicitly.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        scaled = acc_ref[...].astype(jnp.float32) * xs_ref[...] * ws_ref[...]
        o_ref[...] = scaled.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=('bm', 'bn', 'bk', 'out_dtype',
                                             'interpret'))
def quant_matmul(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                 w_scale: jax.Array, *, bm: int = 128, bn: int = 128,
                 bk: int = 128, out_dtype=jnp.float32,
                 interpret: bool = False) -> jax.Array:
    """x_q: (M, K) int8; w_q: (K, N) int8; x_scale: (M, 1) f32; w_scale: (1, N) f32."""
    m, k = x_q.shape
    _, n = w_q.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    x_scale = jnp.broadcast_to(jnp.asarray(x_scale, jnp.float32), (m, 1))
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (1, n))
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
