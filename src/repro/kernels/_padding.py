"""Shared zero-padding helpers for the kernel ``ops`` wrappers.

One definition, so the per-step and whole-sequence LSTM paths can never
silently diverge in alignment semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def pad_axis_to(x: jax.Array, size: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` up to exactly ``size`` elements."""
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def pad_axis_to_multiple(x: jax.Array, mult: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple of ``mult``."""
    return pad_axis_to(x, round_up(x.shape[axis], mult), axis)
