"""Pure-jnp oracle for the fused LSTM gate kernel (Eqs. 1-5, packed weights)."""
import jax
import jax.numpy as jnp


def lstm_gates_ref(xh: jax.Array, w: jax.Array, peep: jax.Array, bias: jax.Array,
                   c_prev: jax.Array):
    """xh: (B, N_in); w: (4, N_h, N_in); peep: (3, N_h); bias: (4, N_h);
    c_prev: (B, N_h).  Returns (h, c) each (B, N_h).  Gate order i,f,g,o."""
    pre = jnp.einsum('ghk,bk->bgh', w, xh)
    i = jax.nn.sigmoid(pre[:, 0] + peep[0] * c_prev + bias[0])
    f = jax.nn.sigmoid(pre[:, 1] + peep[1] * c_prev + bias[1])
    g = jnp.tanh(pre[:, 2] + bias[2])
    c = f * c_prev + i * g
    o = jax.nn.sigmoid(pre[:, 3] + peep[2] * c + bias[3])
    h = o * jnp.tanh(c)
    return h, c
