from .kernel import lstm_gates, lstm_gates_rec
from .ops import lstm_cell_fused, lstm_layer_fused
from .ref import lstm_gates_ref

__all__ = ['lstm_gates', 'lstm_gates_rec', 'lstm_cell_fused',
           'lstm_layer_fused', 'lstm_gates_ref']
