"""Pallas TPU kernel: fused LSTM cell — 4 gate matmuls + nonlinearities + state update.

This is Chipmunk's engine datapath (C1) re-blocked for the TPU memory hierarchy:
instead of the silicon's 96 row-units x 1-element column loop, we tile the packed
gate matrix W (4, N_h, N_in) into (4, bn, bk) VMEM blocks and drive the 128x128 MXU
with one (B, bk) x (bk, bn) dot per gate per grid step.  The element-wise phase
(peepholes, LUT-equivalent nonlinearities, cell/hidden update) fuses into the final
K step, so pre-activations never round-trip to HBM — the VMEM-resident analogue of
the chip's local o/f/i/c registers.

Grid: (N_h/bn, N_in/bk) with the reduction axis innermost; the (B, 4, bn) f32
accumulator lives in VMEM scratch and is revisited across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gate_phase(pre, peep_ref, bias_ref, c_ref, h_out_ref, c_out_ref):
    """Fused elementwise epilogue shared by both step kernels.

    pre: (B, 4, bn) f32 accumulator; writes h/c output blocks."""
    peep = peep_ref[...].astype(jnp.float32)   # (3, bn)
    bias = bias_ref[...].astype(jnp.float32)   # (4, bn)
    c_prev = c_ref[...].astype(jnp.float32)    # (B, bn)
    i = jax.nn.sigmoid(pre[:, 0] + peep[0] * c_prev + bias[0])
    f = jax.nn.sigmoid(pre[:, 1] + peep[1] * c_prev + bias[1])
    g = jnp.tanh(pre[:, 2] + bias[2])
    c_new = f * c_prev + i * g
    o = jax.nn.sigmoid(pre[:, 3] + peep[2] * c_new + bias[3])
    h_out_ref[...] = (o * jnp.tanh(c_new)).astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


def _kernel(xh_ref, w_ref, peep_ref, bias_ref, c_ref, h_out_ref, c_out_ref,
            acc_ref, *, n_k: int):
    @pl.when(pl.program_id(1) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xh = xh_ref[...]                       # (B, bk)
    for g in range(4):                     # the four gate rows share the xh stream
        acc_ref[:, g, :] += jax.lax.dot_general(
            xh, w_ref[g], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == n_k - 1)
    def _elementwise():
        _gate_phase(acc_ref[...], peep_ref, bias_ref, c_ref,
                    h_out_ref, c_out_ref)


def _kernel_rec(h_ref, w_ref, pre_ref, peep_ref, bias_ref, c_ref, h_out_ref,
                c_out_ref, acc_ref, *, n_k: int):
    """Recurrent-only step: the accumulator starts from the hoisted W_x @ x_t
    pre-activations instead of zero, so the scan body only pays the W_h MACs."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = pre_ref[...].astype(jnp.float32)

    h = h_ref[...]                         # (B, bk)
    for g in range(4):
        acc_ref[:, g, :] += jax.lax.dot_general(
            h, w_ref[g], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == n_k - 1)
    def _elementwise():
        _gate_phase(acc_ref[...], peep_ref, bias_ref, c_ref,
                    h_out_ref, c_out_ref)


@functools.partial(jax.jit, static_argnames=('bn', 'bk', 'interpret'))
def lstm_gates_rec(h: jax.Array, w_h: jax.Array, pre: jax.Array,
                   peep: jax.Array, bias: jax.Array, c_prev: jax.Array, *,
                   bn: int = 128, bk: int = 128, interpret: bool = False):
    """Recurrent step with hoisted input contribution.

    h: (B, N_h); w_h: (4, N_h, N_h); pre: (B, 4, N_h) = W_x @ x_t;
    peep: (3, N_h); bias: (4, N_h); c_prev: (B, N_h)."""
    b, n_h = h.shape
    assert n_h % bn == 0 and n_h % bk == 0, (n_h, bn, bk)
    n_k = n_h // bk

    return pl.pallas_call(
        functools.partial(_kernel_rec, n_k=n_k),
        grid=(n_h // bn, n_k),
        in_specs=[
            pl.BlockSpec((b, bk), lambda j, kk: (0, kk)),
            pl.BlockSpec((4, bn, bk), lambda j, kk: (0, j, kk)),
            pl.BlockSpec((b, 4, bn), lambda j, kk: (0, 0, j)),
            pl.BlockSpec((3, bn), lambda j, kk: (0, j)),
            pl.BlockSpec((4, bn), lambda j, kk: (0, j)),
            pl.BlockSpec((b, bn), lambda j, kk: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((b, bn), lambda j, kk: (0, j)),
            pl.BlockSpec((b, bn), lambda j, kk: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_h), h.dtype),
            jax.ShapeDtypeStruct((b, n_h), h.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((b, 4, bn), jnp.float32)],
        interpret=interpret,
    )(h, w_h, pre, peep, bias, c_prev)


@functools.partial(jax.jit, static_argnames=('bn', 'bk', 'interpret'))
def lstm_gates(xh: jax.Array, w: jax.Array, peep: jax.Array, bias: jax.Array,
               c_prev: jax.Array, *, bn: int = 128, bk: int = 128,
               interpret: bool = False):
    """Fused LSTM cell.  xh: (B, N_in); w: (4, N_h, N_in); peep: (3, N_h);
    bias: (4, N_h); c_prev: (B, N_h).  Dims must be multiples of (bn, bk)."""
    b, n_in = xh.shape
    _, n_h, _ = w.shape
    assert n_h % bn == 0 and n_in % bk == 0, (n_h, n_in, bn, bk)
    n_k = n_in // bk

    h, c = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(n_h // bn, n_k),
        in_specs=[
            pl.BlockSpec((b, bk), lambda j, kk: (0, kk)),
            pl.BlockSpec((4, bn, bk), lambda j, kk: (0, j, kk)),
            pl.BlockSpec((3, bn), lambda j, kk: (0, j)),
            pl.BlockSpec((4, bn), lambda j, kk: (0, j)),
            pl.BlockSpec((b, bn), lambda j, kk: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((b, bn), lambda j, kk: (0, j)),
            pl.BlockSpec((b, bn), lambda j, kk: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_h), xh.dtype),
            jax.ShapeDtypeStruct((b, n_h), xh.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((b, 4, bn), jnp.float32)],
        interpret=interpret,
    )(xh, w, peep, bias, c_prev)
    return h, c
