"""Public op: fused LSTM cell / layer with padding; drop-in for core.lstm."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.lstm import LSTMParams
from .kernel import lstm_gates
from .ref import lstm_gates_ref


def _pad_axis(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def lstm_cell_fused(params: LSTMParams, x_t: jax.Array, h_prev: jax.Array,
                    c_prev: jax.Array, *, bn: int = 128, bk: int = 128,
                    use_pallas: bool = True, interpret: bool = True):
    """Same contract as core.lstm.lstm_cell, via the fused kernel."""
    n_h, n_x = params.n_h, params.n_x
    w = jnp.concatenate([params.w_x, params.w_h], axis=-1)  # (4, N_h, N_in)
    xh = jnp.concatenate([x_t, h_prev], axis=-1)
    if not use_pallas:
        h, c = lstm_gates_ref(xh, w, params.w_peep, params.b, c_prev)
        return h, c
    b = xh.shape[0]
    b_pad = max(8, b + (-b) % 8)
    xh_p = _pad_axis(_pad_axis(xh, bk, 1), b_pad, 0)[:b_pad]
    w_p = _pad_axis(_pad_axis(w, bn, 1), bk, 2)
    peep_p = _pad_axis(params.w_peep, bn, 1)
    bias_p = _pad_axis(params.b, bn, 1)
    c_p = _pad_axis(_pad_axis(c_prev, bn, 1), b_pad, 0)[:b_pad]
    h, c = lstm_gates(xh_p, w_p, peep_p, bias_p, c_p, bn=bn, bk=bk,
                      interpret=interpret)
    return h[:b, :n_h], c[:b, :n_h]


def lstm_layer_fused(params: LSTMParams, xs: jax.Array, *, bn: int = 128,
                     bk: int = 128, use_pallas: bool = True,
                     interpret: bool = True):
    """Scan the fused cell over time.  xs: (T, B, N_x)."""
    n_h = params.n_h
    B = xs.shape[1]
    h0 = jnp.zeros((B, n_h), xs.dtype)
    c0 = jnp.zeros((B, n_h), xs.dtype)

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell_fused(params, x_t, h, c, bn=bn, bk=bk,
                               use_pallas=use_pallas, interpret=interpret)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, (h0, c0), xs)
    return hs
