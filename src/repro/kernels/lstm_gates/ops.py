"""Public op: fused LSTM cell / layer with padding; drop-in for core.lstm."""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.lstm import LSTMParams, lstm_bwd_recompute_gates
from .._padding import pad_axis_to, pad_axis_to_multiple, round_up
from .kernel import lstm_gates, lstm_gates_rec
from .ref import lstm_gates_ref


def lstm_cell_fused(params: LSTMParams, x_t: jax.Array, h_prev: jax.Array,
                    c_prev: jax.Array, *, bn: int = 128, bk: int = 128,
                    use_pallas: bool = True, interpret: bool = True):
    """Same contract as core.lstm.lstm_cell, via the fused kernel."""
    n_h, n_x = params.n_h, params.n_x
    w = jnp.concatenate([params.w_x, params.w_h], axis=-1)  # (4, N_h, N_in)
    xh = jnp.concatenate([x_t, h_prev], axis=-1)
    if not use_pallas:
        h, c = lstm_gates_ref(xh, w, params.w_peep, params.b, c_prev)
        return h, c
    b = xh.shape[0]
    b_pad = max(8, round_up(b, 8))
    xh_p = pad_axis_to(pad_axis_to_multiple(xh, bk, 1), b_pad, 0)
    w_p = pad_axis_to_multiple(pad_axis_to_multiple(w, bn, 1), bk, 2)
    peep_p = pad_axis_to_multiple(params.w_peep, bn, 1)
    bias_p = pad_axis_to_multiple(params.b, bn, 1)
    c_p = pad_axis_to(pad_axis_to_multiple(c_prev, bn, 1), b_pad, 0)
    h, c = lstm_gates(xh_p, w_p, peep_p, bias_p, c_p, bn=bn, bk=bk,
                      interpret=interpret)
    return h[:b, :n_h], c[:b, :n_h]


# ---------------------------------------------------------------------------
# Layer: per-step kernel scanned over time, with the training VJP
# ---------------------------------------------------------------------------

def _step_forward(cfg, w_h, w_peep, b, pre_x, h0, c0):
    """Pad once, scan the recurrent-only kernel.  pre_x: (T, B, 4, N_h)."""
    bn, bk, interpret = cfg
    T, B, _, n_h = pre_x.shape
    n_h_p = round_up(n_h, math.lcm(bn, bk))
    b_pad = max(8, round_up(B, 8))

    # ---- hoisted, once per layer call -------------------------------------
    w_h_p = pad_axis_to(pad_axis_to(w_h, n_h_p, 1), n_h_p, 2)
    peep_p = pad_axis_to(w_peep, n_h_p, 1)
    bias_p = pad_axis_to(b, n_h_p, 1)
    pre_p = pad_axis_to(pad_axis_to(pre_x, n_h_p, 3), b_pad, 1)
    h0_p = pad_axis_to(pad_axis_to(h0, n_h_p, 1), b_pad, 0)
    c0_p = pad_axis_to(pad_axis_to(c0, n_h_p, 1), b_pad, 0)

    def step(carry, pre_t):
        h, c = carry
        h, c = lstm_gates_rec(h, w_h_p, pre_t, peep_p, bias_p, c,
                              bn=bn, bk=bk, interpret=interpret)
        return (h, c), (h, c)

    _, (hs, cs) = jax.lax.scan(step, (h0_p, c0_p), pre_p)
    return hs[:, :B, :n_h], cs[:, :B, :n_h]


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def lstm_step_fused(cfg, w_h, w_peep, b, pre_x, h0, c0):
    """Per-step kernel layer with the shared gate-recompute VJP, so the
    ``pallas_step`` backend is trainable just like ``pallas_seq``."""
    hs, cs = _step_forward(cfg, w_h, w_peep, b, pre_x, h0, c0)
    return hs, (hs[-1], cs[-1])


def _step_fwd(cfg, w_h, w_peep, b, pre_x, h0, c0):
    hs, cs = _step_forward(cfg, w_h, w_peep, b, pre_x, h0, c0)
    return (hs, (hs[-1], cs[-1])), (w_h, w_peep, b, pre_x, hs, cs, h0, c0)


def _step_bwd(cfg, res, grads):
    w_h, w_peep, b, pre_x, hs, cs, h0, c0 = res
    return lstm_bwd_recompute_gates(w_h, w_peep, b, pre_x, hs, cs, h0, c0,
                                    grads)


lstm_step_fused.defvjp(_step_fwd, _step_bwd)


def lstm_layer_fused(params: LSTMParams, xs: jax.Array, *,
                     h0: Optional[jax.Array] = None,
                     c0: Optional[jax.Array] = None,
                     bn: int = 128, bk: int = 128, use_pallas: bool = True,
                     interpret: bool = True, return_state: bool = False):
    """Scan the fused cell over time.  xs: (T, B, N_x).

    Everything per-step-invariant is hoisted out of the scan body: weight
    padding happens once, and the non-recurrent ``W_x @ x_t`` contribution is
    one wide matmul over the whole sequence — the scan body only pays the
    recurrent ``W_h @ h`` MACs through the recurrent-only kernel
    (``lstm_gates_rec``), the same hoisting ``core.lstm.lstm_layer`` does and
    what the silicon's weight-stationary streaming implies.
    """
    n_h = params.n_h
    B = xs.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, n_h), xs.dtype)
    if c0 is None:
        c0 = jnp.zeros((B, n_h), xs.dtype)

    if not use_pallas:
        w = jnp.concatenate([params.w_x, params.w_h], axis=-1)

        def step_ref(carry, x_t):
            h, c = carry
            xh = jnp.concatenate([x_t, h], axis=-1)
            h, c = lstm_gates_ref(xh, w, params.w_peep, params.b, c)
            return (h, c), h

        (h_T, c_T), hs = jax.lax.scan(step_ref, (h0, c0), xs)
        return (hs, (h_T, c_T)) if return_state else hs

    pre_x = jnp.einsum('ghx,tbx->tbgh', params.w_x, xs)      # wide matmul
    hs, state = lstm_step_fused((bn, bk, bool(interpret)), params.w_h,
                                params.w_peep, params.b, pre_x, h0, c0)
    return (hs, state) if return_state else hs
