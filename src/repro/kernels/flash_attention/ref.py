"""Pure-jnp oracle: naive attention with causal / sliding-window / GQA masking."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  sm_scale: Optional[float] = None,
                  kv_len: Optional[jax.Array] = None) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D); GQA via H % Hkv == 0.

    ``window``: sliding-window width w — query t attends keys (t-w, t].
    ``kv_len``: optional valid key length (decode with a padded cache).
    """
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = h // hkv
    scale = (d ** -0.5) if sm_scale is None else sm_scale
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum('bhqd,bhkd->bhqk', q, kr) * scale

    q_pos = jnp.arange(sq)[:, None] + (sk - sq if causal else 0)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    if kv_len is not None:
        mask &= k_pos < kv_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # Rows with no valid key (can happen under kv_len=0) produce uniform p; zero them.
    any_valid = mask.any(axis=-1, keepdims=True)
    p = jnp.where(any_valid, p, 0.0)
    return jnp.einsum('bhqk,bhkd->bhqd', p, vr)
