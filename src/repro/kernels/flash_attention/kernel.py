"""Pallas TPU kernel: blockwise online-softmax attention (flash-style).

Needed by the LM-family architectures' long-context shapes (prefill_32k): naive
attention materialises an Sq x Sk score matrix per head (32k x 32k x 4 B = 4 GB),
which cannot live in HBM, let alone VMEM.  Blocking: (bq x D) query tiles stay
resident; K/V stream through VMEM in (bk x D) tiles with running max/denominator
rescaling (Milakov-Gimelshein online softmax), so the working set is
O(bq*D + bk*D + bq*bk) regardless of sequence length.

Supports causal masking, sliding windows (Mixtral/Hymba) and GQA (all assigned
archs) via index-mapped KV heads.  Grid: (B*H, Sq/bq, Sk/bk), KV innermost.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_k: int, bq: int, bk: int, offset: int, scale: float,
            causal: bool, window: Optional[int], kv_len: Optional[int]):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _zero():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # Positional mask.  Query block rows map to absolute positions with the
    # causal offset sk - sq (decode: one new row attends the whole cache).
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    if kv_len is not None:
        mask &= k_pos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                                # (bq,)
    l_prev = l_ref[:, 0]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_ref[:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    'causal', 'window', 'sm_scale', 'kv_len', 'offset', 'bq', 'bk', 'interpret'))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    sm_scale: Optional[float] = None,
                    kv_len: Optional[int] = None, offset: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D).  Sq % bq == Sk % bk == 0.

    ``offset``: absolute position of query row 0 minus key row 0 (causal
    alignment); defaults to sk - sq so the last query sees every key.
    """
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert h % hkv == 0 and sq % bq == 0 and sk % bk == 0
    group = h // hkv
    scale = (d ** -0.5) if sm_scale is None else sm_scale
    if offset is None:
        offset = sk - sq if causal else 0

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)

    def kv_index(bh, iq, ik):
        return ((bh // h) * hkv + (bh % h) // group, ik, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=sk // bk, bq=bq, bk=bk, offset=offset,
                          scale=scale, causal=causal, window=window,
                          kv_len=kv_len),
        grid=(b * h, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
