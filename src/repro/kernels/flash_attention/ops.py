"""Public op: padded/validated flash attention entry point."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        window: Optional[int] = None, sm_scale: Optional[float] = None,
        use_pallas: bool = True, interpret: bool = True,
        bq: int = 128, bk: int = 128) -> jax.Array:
    """Multi-head attention, auto-padding sequence dims to block multiples."""
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal, window=window,
                             sm_scale=sm_scale)
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq_eff, bk_eff = min(bq, max(8, sq)), min(bk, max(8, sk))
    pad_q = (-sq) % bq_eff
    pad_k = (-sk) % bk_eff
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention(qp, kp, vp, causal=causal, window=window,
                          sm_scale=sm_scale, kv_len=sk if pad_k else None,
                          offset=(sk - sq) if causal else 0,
                          bq=bq_eff, bk=bk_eff, interpret=interpret)
    return out[:, :, :sq]
