"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel lives in its own subpackage with:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, calibration, layer helpers)
  ref.py    — pure-jnp oracle used by the test sweeps

Kernels are validated on CPU with interpret=True; the production dry-run uses
the pure-JAX equivalents (``use_pallas=False``) since the CPU backend cannot
lower Mosaic kernels.
"""
from . import flash_attention, lstm_gates, lstm_seq, quant_matmul

__all__ = ['flash_attention', 'lstm_gates', 'lstm_seq', 'quant_matmul']
