"""Train step factory + sharded train-state construction.

``make_train_step`` builds the jit-able update: loss -> grad (with microbatch
gradient accumulation — the compute/communication overlap lever at scale) ->
global-norm clip -> optimizer -> apply.  ``abstract_train_state`` builds
ShapeDtypeStructs + NamedShardings without allocating anything (the 1T-param
configs can never be materialised on the host).

The end-to-end training driver (data pipeline, checkpointing, fault tolerance)
lives in ``main()`` below; the dry-run imports only the step factory.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import sharding as shd
from ..configs import ArchConfig, ShapeConfig
from ..models import ModelBundle, batch_axes, input_specs
from ..optim import (apply_updates, clip_by_global_norm, cosine_schedule,
                     make_optimizer, optimizer_state_axes, wsd_schedule)
from .mesh import resolve_rules


def lr_schedule_for(cfg: ArchConfig, peak_lr=3e-4, warmup=100, total=10_000):
    if cfg.name.startswith('minicpm'):
        return wsd_schedule(peak_lr, warmup, total)   # MiniCPM trains with WSD
    return cosine_schedule(peak_lr, warmup, total)


def make_train_step(bundle: ModelBundle, optimizer, *, microbatches: int = 1,
                    grad_clip: float = 1.0) -> Callable:
    """(state, batch) -> (state, metrics).  state = {'params','opt','step'}."""

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: bundle.loss_fn(p, batch))(params)

    def train_step(state, batch):
        params = state['params']
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def mb_step(acc, b):
                loss_i, g = grads_of(params, b)
                acc = jax.tree.map(lambda a, x: a + x.astype(a.dtype), acc[0], g), \
                    acc[1] + loss_i
                return acc, None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            (gsum, lsum), _ = jax.lax.scan(mb_step, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, state['opt'], params)
        params = apply_updates(params, updates)
        new_state = {'params': params, 'opt': opt_state,
                     'step': state['step'] + 1}
        return new_state, {'loss': loss, 'grad_norm': gnorm}

    return train_step


def abstract_init(init_fn: Callable, *args) -> Tuple[Any, Any]:
    """eval_shape an init that returns (arrays, axes) — axes (a string pytree)
    cannot cross the tracer, so they are captured by side channel."""
    box = {}

    def arrays_only(*a):
        out, axes = init_fn(*a)
        box['axes'] = axes
        return out

    sds = jax.eval_shape(arrays_only, *args)
    return sds, box['axes']


def abstract_train_state(bundle: ModelBundle, mesh, rules_dict,
                         lr_fn=None) -> Tuple[Any, Any, Any]:
    """Returns (state_sds, state_shardings, optimizer) with zero allocation."""
    cfg = bundle.cfg
    lr_fn = lr_fn or lr_schedule_for(cfg)
    optimizer = make_optimizer(cfg.optimizer, lr_fn)

    params_sds, axes = abstract_init(bundle.init, jax.random.PRNGKey(0))
    opt_sds = jax.eval_shape(optimizer.init, params_sds)
    opt_axes = optimizer_state_axes(cfg.optimizer, axes)

    rules = shd.ShardingRules(mesh, resolve_rules(rules_dict, mesh))
    p_sh = shd.param_sharding_tree(axes, params_sds, mesh, rules.rules)
    o_sh = shd.param_sharding_tree(opt_axes, opt_sds, mesh, rules.rules)
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())
    state_sds = {'params': params_sds, 'opt': opt_sds,
                 'step': jax.ShapeDtypeStruct((), jnp.int32)}
    state_sh = {'params': p_sh, 'opt': o_sh, 'step': repl}
    return state_sds, state_sh, optimizer


def batch_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh, rules_dict):
    rules = shd.ShardingRules(mesh, resolve_rules(rules_dict, mesh))
    ax = batch_axes(cfg, shape)
    specs = input_specs(cfg, shape)
    return {k: rules.sharding(ax[k], specs[k].shape) for k in specs}


# ----------------------------------------------------------------- driver
def local_mesh():
    """Largest (data, model) mesh the local devices support."""
    n = len(jax.devices())
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and n >= m:
            model = m
            break
    import numpy as np
    from ..compat import AxisType, mesh_with_axis_types
    return mesh_with_axis_types(
        np.array(jax.devices()).reshape(n // model, model),
        ('data', 'model'), axis_types=(AxisType.Auto, AxisType.Auto))


def main(argv=None):
    """End-to-end training driver: data -> step -> checkpoint, fault-tolerant.

    python -m repro.launch.train --arch chipmunk-ctc --steps 50 --smoke
    """
    import argparse
    import time as _time

    from .. import configs
    from ..checkpoint import CheckpointManager
    from ..data import ShardedLoader, source_for
    from ..models import get_bundle
    from ..runtime import FaultConfig, FaultTolerantRunner

    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='chipmunk-ctc')
    ap.add_argument('--steps', type=int, default=20)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq', type=int, default=64)
    ap.add_argument('--lr', type=float, default=1e-3)
    ap.add_argument('--smoke', action='store_true',
                    help='use the reduced config (CPU-runnable)')
    ap.add_argument('--ckpt-dir', default='/tmp/repro_ckpt')
    ap.add_argument('--ckpt-every', type=int, default=20)
    ap.add_argument('--resume', action='store_true')
    ap.add_argument('--microbatches', type=int, default=1)
    ap.add_argument('--log-every', type=int, default=5)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    shape = configs.ShapeConfig('cli', 'train', args.seq, args.batch)
    bundle = get_bundle(cfg)
    mesh = local_mesh()
    rules_dict = shd.TRAIN_RULES

    rules = shd.ShardingRules(mesh, resolve_rules(rules_dict, mesh))
    with shd.use_rules(rules):
        state_sds, state_sh, optimizer = abstract_train_state(
            bundle, mesh, rules_dict,
            lr_fn=cosine_schedule(args.lr, warmup=10, total=args.steps))
        step_fn = jax.jit(
            make_train_step(bundle, optimizer,
                            microbatches=args.microbatches),
            in_shardings=(state_sh, None), donate_argnums=(0,))

        # real init (small configs only — big ones go through the dry-run)
        params, _ = bundle.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, state_sh['params'])
        opt_state = jax.device_put(optimizer.init(params), state_sh['opt'])
        state = {'params': params, 'opt': opt_state,
                 'step': jnp.zeros((), jnp.int32)}

        ckpt = CheckpointManager(args.ckpt_dir)
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            state = ckpt.restore(state, shardings=state_sh)
            start = int(state['step'])
            print(f'resumed at step {start}')

        loader = ShardedLoader(
            source_for(cfg, shape), shape,
            batch_shardings(cfg, shape, mesh, rules_dict), start_step=start)
        runner = FaultTolerantRunner(
            step_fn, ckpt_manager=ckpt,
            cfg=FaultConfig(heartbeat_path=f'{args.ckpt_dir}/heartbeat.json'),
            restore_fn=lambda: ckpt.restore(state_sds, shardings=state_sh))

        t0 = _time.time()
        for i, (step, batch) in zip(range(start, args.steps), loader):
            state, metrics = runner.run_step(step, state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f'step {step:5d} loss {float(metrics["loss"]):8.4f} '
                      f'gnorm {float(metrics["grad_norm"]):8.3f} '
                      f'({(_time.time()-t0)/(i-start+1):.2f}s/step)')
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state)
        ckpt.save(args.steps, state, blocking=True)
        loader.close()
        print(f'done; events: {runner.events[:5]}')


if __name__ == '__main__':
    main()
