"""Serving driver: continuous batching on the shared slot scheduler.

The serving analogue of the paper's deployment story: weights stay resident
(weight-stationary, C3), requests stream through.  Two front-ends share the
``serving.SlotScheduler`` admission/eviction/refill policy:

  * **Token families** (`SlotServer`): a fixed number of decode slots share
    one jit'd ``decode_step``; finished slots are refilled from the queue
    without stopping the others (continuous batching a la Orca/vLLM, minus
    paged KV — the ring/linear caches live in models/*).
  * **The LSTM family** (`StreamServer`): frame streams are served by the
    packed multi-stream ``serving.StreamingEngine`` (DESIGN.md §7) — all
    active utterances advance through ONE batched chunked call to the
    whole-sequence LSTM path per step, ragged tails masked, per-stream
    ``(h, c)`` state carried across chunks in the packed session cache.
    With ``--lstm-backend pallas_seq_fused`` that one call is additionally
    ONE kernel launch for the whole stack (the §8 wavefront kernel), so a
    chunk costs a single launch across all streams AND all layers.  With
    ``--systolic-topology graves-75 --lstm-backend pallas_seq_fused_systolic``
    the same call runs the paper's full 3x(5x5) Table-2 topology (§9):
    each 5x5 stage holds one layer's weights stationary and the chunk
    pipelines stage to stage via ppermute.

Works on CPU with the smoke configs:
  python -m repro.launch.serve --arch qwen3-14b --smoke --requests 6
  python -m repro.launch.serve --arch chipmunk-ctc --smoke --requests 6
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..models import get_bundle
from ..serving import SlotScheduler, StreamingEngine


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: Optional[List[int]] = None
    t_enqueue: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    # prompt tokens not yet prefetched into the slot's cache — a declared
    # field (reset on admission), not an attribute patched on from outside
    _prefill_left: List[int] = dataclasses.field(default_factory=list)


class SlotServer:
    """num_slots concurrent decodes; greedy sampling; per-slot refill.

    For simplicity each slot owns an independent cache (batch dim 1) — slot
    refill never perturbs neighbours.  Prefill reuses the decode path (token
    by token) for the smoke scale; the 32k-prefill path is exercised by the
    dry-run's ``forward`` lowering.  Slot bookkeeping lives in the shared
    ``serving.SlotScheduler``; this class owns only the caches.
    """

    def __init__(self, cfg, params, num_slots=4, max_seq=128):
        self.cfg = cfg
        self.bundle = get_bundle(cfg)
        self.params = params
        self.max_seq = max_seq
        self.sched: SlotScheduler[Request] = SlotScheduler(num_slots)
        self.caches = [self.bundle.init_cache(1, max_seq)[0]
                       for _ in range(num_slots)]
        self.pos = [0] * num_slots
        self._step = jax.jit(self.bundle.decode_step)

    @property
    def done(self) -> List[Request]:
        return self.sched.done

    def submit(self, req: Request):
        req.t_enqueue = time.time()
        req.out = []
        self.sched.submit(req)

    def _admit(self, i: int, req: Request):
        # fresh cache per admission: a recycled slot never leaks state
        self.caches[i] = self.bundle.init_cache(1, self.max_seq)[0]
        self.pos[i] = 0
        req._prefill_left = list(req.prompt)

    def step(self):
        """One decode step across all active slots."""
        self.sched.refill(self._admit)
        for i, req in self.sched.active():
            if req._prefill_left:
                tok = req._prefill_left.pop(0)
                emit = not req._prefill_left
            else:
                tok = req.out[-1]
                emit = True
            logits, self.caches[i] = self._step(
                self.params, self.caches[i],
                jnp.asarray([[tok]], jnp.int32), jnp.int32(self.pos[i]))
            self.pos[i] += 1
            if emit:
                nxt = int(jnp.argmax(logits[0, -1]))
                if req.t_first is None:
                    req.t_first = time.time()
                req.out.append(nxt)
                if len(req.out) >= req.max_new or self.pos[i] >= self.max_seq - 1:
                    req.t_done = time.time()
                    self.sched.finish(i)

    def drain(self):
        while self.sched.busy:
            self.step()


class StreamServer:
    """Frame-stream serving for the LSTM family on the packed engine.

    Thin front-end over ``serving.StreamingEngine``: utterances in, per-frame
    CTC log-probs (and incrementally decoded phonemes) out.  Unlike the
    token path there is no per-slot jit call — every engine step advances
    ALL active streams through one batched chunked whole-sequence call, so
    the resident weights are fetched once per chunk for the entire slot grid.
    """

    def __init__(self, cfg, params, num_slots=4, chunk=16, faults=None,
                 async_dispatch=False, deadline_slo=None):
        policy = None
        if deadline_slo is not None:
            from ..runtime import ChunkSizePolicy
            from ..serving.engine import tuned_chunk_ceiling
            # a tuned staged chunk depth (schedule cache, repro.tune) caps
            # how deep the policy may grow chunks; scheduling-only (§11)
            ceiling = tuned_chunk_ceiling(cfg, chunk, num_slots)
            policy = ChunkSizePolicy(chunk_max=ceiling, slack=deadline_slo)
        self.engine = StreamingEngine(cfg, params, max_streams=num_slots,
                                      chunk=chunk, decode_ctc=True,
                                      faults=faults,
                                      async_dispatch=async_dispatch,
                                      chunk_policy=policy)

    def submit(self, frames: np.ndarray, priority: int = 0):
        return self.engine.submit(frames, priority=priority)

    def drain(self):
        return self.engine.run()

    @property
    def done(self):
        return self.engine.sched.done


def _run_token_serving(cfg, args):
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    server = SlotServer(cfg, params, num_slots=args.slots)

    rng = np.random.RandomState(0)
    t0 = time.time()
    for r in range(args.requests):
        prompt = rng.randint(1, cfg.vocab_size, size=rng.randint(3, 8)).tolist()
        server.submit(Request(rid=r, prompt=prompt, max_new=args.max_new))
    server.drain()
    wall = time.time() - t0
    toks = sum(len(r.out) for r in server.done)
    lat = [r.t_done - r.t_enqueue for r in server.done]
    print(f'served {len(server.done)} requests, {toks} tokens in {wall:.2f}s '
          f'({toks / wall:.1f} tok/s); p50 latency {np.median(lat):.2f}s')
    for r in sorted(server.done, key=lambda r: r.rid)[:3]:
        print(f'  req {r.rid}: prompt {r.prompt[:4]}... -> {r.out}')


def _parse_at_spec(spec: str):
    """Parse a repeatable ``VALUE@STEP`` injection flag into (step, value)."""
    value, step = spec.split('@')
    return int(step), int(value)


def _build_fault_config(args):
    """Assemble a ``runtime.ServingFaultConfig`` from the CLI fault flags;
    returns None when no fault feature was requested (the engine then runs
    the zero-overhead non-fault path)."""
    from ..runtime import ServingFaultConfig
    fail_at = dict(_parse_at_spec(s) for s in (args.fail_engines or []))
    if args.fail_at_step is not None and args.fail_at_step not in fail_at:
        fail_at[args.fail_at_step] = 1
    poison_at = dict(_parse_at_spec(s) for s in (args.poison_slot or []))
    recover_at = dict(_parse_at_spec(s) for s in (args.recover_at or []))
    if not (fail_at or poison_at or recover_at or args.stream_ckpt_dir
            or args.deadline_factor is not None):
        return None
    return ServingFaultConfig(fail_at=fail_at, poison_at=poison_at,
                              recover_at=recover_at,
                              promote_hysteresis=args.promote_hysteresis,
                              canary=args.canary,
                              backoff_s=0.0,
                              deadline_factor=args.deadline_factor,
                              checkpoint_dir=args.stream_ckpt_dir)


def _run_stream_serving(cfg, args):
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    faults = _build_fault_config(args)
    server = StreamServer(cfg, params, num_slots=args.slots, chunk=args.chunk,
                          faults=faults, async_dispatch=args.async_dispatch,
                          deadline_slo=args.deadline_slo)

    rng = np.random.RandomState(0)
    t0 = time.time()
    for r in range(args.requests):
        frames = rng.randn(rng.randint(args.chunk, 4 * args.chunk),
                           cfg.lstm_inputs).astype(np.float32) * 0.5
        # every 3rd utterance is a latency-SLO stream (§11 priority demo)
        server.submit(frames, priority=1 if r % 3 == 2 else 0)
    server.drain()
    wall = time.time() - t0
    stats = server.engine.stats()
    mode = 'async' if stats['async'] else 'sync'
    print(f'streamed {stats["streams"]} utterances, {stats["frames"]} frames '
          f'in {wall:.2f}s ({stats["frames"] / wall:.1f} frames/s, {mode}); '
          f'p50 latency {stats["p50_latency_s"]:.3f}s, '
          f'p50 chunk {stats["p50_chunk_s"] * 1e3:.1f}ms')
    if args.deadline_slo is not None:
        print(f'deadline slo: chunk_len={stats["chunk_len"]} '
              f'deadline_misses={stats["deadline_misses"]}')
    for s in sorted(server.done, key=lambda s: s.sid)[:3]:
        print(f'  stream {s.sid}: {s.length} frames -> '
              f'phonemes {s.decoder.symbols[:8]}')
    if faults is not None:
        counts = stats['event_counts']
        print(f'fault summary: backend={stats["backend"]} '
              f'rung={stats["rung"]} '
              f'degrade={counts.get("degrade", 0)} '
              f'promote={counts.get("promote", 0)} '
              f'quarantine={counts.get("quarantine", 0)} '
              f'deadline_misses={stats["deadline_misses"]} '
              f'checkpoints={counts.get("checkpoint", 0)} '
              f'events_dropped={stats["events_dropped"]}')
        for e in stats['events']:
            if e['kind'] == 'degrade':
                print(f'  degrade @step {e["step"]}: {e["from_backend"]} -> '
                      f'{e["to_backend"]} ({e["n_dead"]} engine(s) dead, '
                      f'domain {e["domain"]})')
            elif e['kind'] == 'heal':
                print(f'  heal @step {e["step"]}: domains {e["domains"]} '
                      f'healed')
            elif e['kind'] == 'promote_canary':
                print(f'  promote_canary @step {e["step"]}: replaying '
                      f'committed chunk on {e["to_backend"]}')
            elif e['kind'] == 'promote':
                print(f'  promote @step {e["step"]}: {e["from_backend"]} -> '
                      f'{e["to_backend"]} (healthy domains {e["healthy"]})')
            elif e['kind'] == 'promote_rejected':
                print(f'  promote_rejected @step {e["step"]}: '
                      f'{e["to_backend"]} canary mismatch '
                      f'(backoff -> {e["backoff"]})')


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='qwen3-14b')
    ap.add_argument('--smoke', action='store_true', default=True)
    ap.add_argument('--requests', type=int, default=6)
    ap.add_argument('--slots', type=int, default=3)
    ap.add_argument('--max-new', type=int, default=8)
    ap.add_argument('--chunk', type=int, default=8,
                    help='frames per engine step (LSTM streaming only)')
    from ..core.lstm import BACKENDS
    from .mesh import SYSTOLIC_TOPOLOGIES
    ap.add_argument('--lstm-backend', default='auto', choices=BACKENDS,
                    help='LSTM execution engine (recurrent families)')
    ap.add_argument('--systolic-topology', default=None,
                    choices=sorted(SYSTOLIC_TOPOLOGIES),
                    help='install a systolic mesh preset before serving '
                         '(stage-1 presets enable/auto-select '
                         'pallas_seq_systolic, stage>1 presets the staged '
                         'pallas_seq_fused_systolic; multi-device presets '
                         'need that many JAX devices)')
    from .mesh import DIE_TOPOLOGIES
    ap.add_argument('--die-topology', default=None,
                    choices=sorted(DIE_TOPOLOGIES),
                    help='install a two-level die-mesh preset (§14): dies '
                         'are fault domains; an engine failure re-forms '
                         'the systolic mesh on the surviving dies (an '
                         'intermediate ladder rung) and a healed die is '
                         'canary-validated back in; needs dies*stage*rows*'
                         'cols JAX devices')
    ap.add_argument('--fail-at-step', type=int, default=None,
                    help='declare one mesh engine dead at this engine step '
                         '(LSTM streaming; exercises the degradation ladder)')
    ap.add_argument('--fail-engines', action='append', default=None,
                    metavar='N@STEP',
                    help='declare N engines dead at STEP (repeatable)')
    ap.add_argument('--poison-slot', action='append', default=None,
                    metavar='SLOT@STEP',
                    help='poison slot SLOT with NaN state before STEP '
                         '(repeatable; exercises quarantine)')
    ap.add_argument('--recover-at-step', dest='recover_at', action='append',
                    default=None, metavar='N@STEP',
                    help='heal N failed fault domains at engine step STEP '
                         '(repeatable; exercises the §14 canary-validated '
                         'climb back up the ladder)')
    ap.add_argument('--promote-hysteresis', type=int, default=4,
                    help='engine steps a promotion must wait after a '
                         'failure/promotion/rejection; flaps and rejected '
                         'canaries double it (exponential backoff)')
    ap.add_argument('--no-canary', dest='canary', action='store_false',
                    default=True,
                    help='promote on capacity + hysteresis alone, without '
                         'the shadow-replay canary validation')
    ap.add_argument('--stream-ckpt-dir', default=None,
                    help='directory for per-stream (h, c) + cursor '
                         'checkpoints (enables preempt/resume across runs)')
    ap.add_argument('--deadline-factor', type=float, default=None,
                    help='per-chunk deadline as a multiple of the paper '
                         'real-time frame budget (records deadline_miss '
                         'events)')
    ap.add_argument('--async', dest='async_dispatch', action='store_true',
                    help='double-buffered dispatch (DESIGN.md §11): the '
                         'next chunk is packed and launched while the '
                         'in-flight one computes; outputs stay bit-equal '
                         'to sync serving')
    ap.add_argument('--deadline-slo', type=float, default=None,
                    metavar='FACTOR',
                    help='attach the deadline-aware chunk-size policy: '
                         'budget = chunk * 10ms frame period * FACTOR '
                         '(the Table-2 arrival rate); chunk length adapts '
                         'to observed launch-to-commit wall times')
    ap.add_argument('--schedule-cache', default=None, metavar='PATH',
                    help='install a measured-schedule cache (repro.tune '
                         'JSON): dispatch decisions — int8 fused-vs-'
                         'layerwise, stack backend, staged Tc, the chunk-'
                         'policy ceiling — consult its winners before any '
                         'heuristic; dispatch-only, numerics unchanged')
    ap.add_argument('--tune', action='store_true',
                    help='run the offline autotuner for this serving '
                         'config before serving (LSTM family only): '
                         'measured int8 backend trial + the measured '
                         'end-to-end serving-loop chunk ceiling (the real '
                         'engine step, outputs bit-equal across depths by '
                         'the §7 contract) with the kernel-level predicted '
                         'ceiling as fallback, recorded to --schedule-cache '
                         'when given; serving itself never pays tuning cost')
    args = ap.parse_args(argv)

    if args.systolic_topology:
        from .mesh import install_systolic_topology
        mesh = install_systolic_topology(args.systolic_topology)
        print(f'installed systolic topology {args.systolic_topology}: '
              f'{dict(mesh.shape)}')
    if args.die_topology:
        from .mesh import install_die_topology
        dm = install_die_topology(args.die_topology)
        print(f'installed die topology {args.die_topology}: {dm.dies} dies '
              f'x {dm.engines_per_die} engines '
              f'({dm.dies}x{dm.stage}x{dm.rows}x{dm.cols})')

    if args.schedule_cache:
        import pathlib
        from ..tune import ScheduleCache, install_schedule_cache
        path = pathlib.Path(args.schedule_cache)
        cache = (ScheduleCache.load(path) if path.exists()
                 else ScheduleCache())
        install_schedule_cache(cache)
        print(f'installed schedule cache: {len(cache)} entries '
              f'from {path}' if path.exists()
              else f'installed empty schedule cache (will tune into {path})')

    cfg = configs.get_smoke_config(args.arch).replace(
        lstm_backend=args.lstm_backend)
    if args.tune and cfg.family == 'lstm':
        from ..tune import (ScheduleCache, current_schedule_cache,
                            install_schedule_cache, tune_serving_config)
        cache = current_schedule_cache()
        if cache is None:
            cache = install_schedule_cache(ScheduleCache())
        entries = tune_serving_config(cfg, chunk=args.chunk,
                                      slots=args.slots, cache=cache)
        for e in entries:
            what = e.backend or f'Tc={e.tc}'
            print(f'tuned {e.kind}: {what} ({e.source})')
        if args.schedule_cache:
            cache.save(args.schedule_cache)
            print(f'saved {len(cache)} entries -> {args.schedule_cache}')
    if cfg.family == 'lstm':
        _run_stream_serving(cfg, args)
    else:
        _run_token_serving(cfg, args)


if __name__ == '__main__':
    main()
