"""Batched serving driver: continuous batching over a fixed slot grid.

The serving analogue of the paper's deployment story: weights stay resident
(weight-stationary, C3), requests stream through.  A fixed number of decode
slots share one jit'd ``decode_step``; finished slots are refilled from the
queue without stopping the others (continuous batching a la Orca/vLLM, minus
paged KV — the ring/linear caches live in models/*).

Works on CPU with the smoke configs:
  python -m repro.launch.serve --arch qwen3-14b --smoke --requests 6
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..models import get_bundle


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: Optional[List[int]] = None
    t_enqueue: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None


class SlotServer:
    """num_slots concurrent decodes; greedy sampling; per-slot refill.

    For simplicity each slot owns an independent cache (batch dim 1) — slot
    refill never perturbs neighbours.  Prefill reuses the decode path (token
    by token) for the smoke scale; the 32k-prefill path is exercised by the
    dry-run's ``forward`` lowering.
    """

    def __init__(self, cfg, params, num_slots=4, max_seq=128):
        self.cfg = cfg
        self.bundle = get_bundle(cfg)
        self.params = params
        self.max_seq = max_seq
        self.slots: List[Optional[Request]] = [None] * num_slots
        self.caches = [self.bundle.init_cache(1, max_seq)[0]
                       for _ in range(num_slots)]
        self.pos = [0] * num_slots
        self.pending: List[Request] = []
        self.done: List[Request] = []
        self._step = jax.jit(self.bundle.decode_step)

    def submit(self, req: Request):
        req.t_enqueue = time.time()
        req.out = []
        self.pending.append(req)

    def _refill(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.pending:
                req = self.pending.pop(0)
                self.slots[i] = req
                self.caches[i] = self.bundle.init_cache(1, self.max_seq)[0]
                self.pos[i] = 0
                req._prefill_left = list(req.prompt)        # type: ignore

    def step(self):
        """One decode step across all active slots."""
        self._refill()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req._prefill_left:                           # type: ignore
                tok = req._prefill_left.pop(0)              # type: ignore
                emit = not req._prefill_left                # type: ignore
            else:
                tok = req.out[-1]
                emit = True
            logits, self.caches[i] = self._step(
                self.params, self.caches[i],
                jnp.asarray([[tok]], jnp.int32), jnp.int32(self.pos[i]))
            self.pos[i] += 1
            if emit:
                nxt = int(jnp.argmax(logits[0, -1]))
                if req.t_first is None:
                    req.t_first = time.time()
                req.out.append(nxt)
                if len(req.out) >= req.max_new or self.pos[i] >= self.max_seq - 1:
                    req.t_done = time.time()
                    self.done.append(req)
                    self.slots[i] = None

    def drain(self):
        while any(s is not None for s in self.slots) or self.pending:
            self.step()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='qwen3-14b')
    ap.add_argument('--smoke', action='store_true', default=True)
    ap.add_argument('--requests', type=int, default=6)
    ap.add_argument('--slots', type=int, default=3)
    ap.add_argument('--max-new', type=int, default=8)
    from ..core.lstm import BACKENDS
    from .mesh import SYSTOLIC_TOPOLOGIES
    ap.add_argument('--lstm-backend', default='auto', choices=BACKENDS,
                    help='LSTM execution engine (recurrent families)')
    ap.add_argument('--systolic-topology', default=None,
                    choices=sorted(SYSTOLIC_TOPOLOGIES),
                    help='install a systolic mesh preset before serving '
                         '(enables/auto-selects pallas_seq_systolic; '
                         'multi-device presets need that many JAX devices)')
    args = ap.parse_args(argv)

    if args.systolic_topology:
        from .mesh import install_systolic_topology
        mesh = install_systolic_topology(args.systolic_topology)
        print(f'installed systolic topology {args.systolic_topology}: '
              f'{dict(mesh.shape)}')

    cfg = configs.get_smoke_config(args.arch).replace(
        lstm_backend=args.lstm_backend)
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    server = SlotServer(cfg, params, num_slots=args.slots)

    rng = np.random.RandomState(0)
    t0 = time.time()
    for r in range(args.requests):
        prompt = rng.randint(1, cfg.vocab_size, size=rng.randint(3, 8)).tolist()
        server.submit(Request(rid=r, prompt=prompt, max_new=args.max_new))
    server.drain()
    wall = time.time() - t0
    toks = sum(len(r.out) for r in server.done)
    lat = [r.t_done - r.t_enqueue for r in server.done]
    print(f'served {len(server.done)} requests, {toks} tokens in {wall:.2f}s '
          f'({toks / wall:.1f} tok/s); p50 latency {np.median(lat):.2f}s')
    for r in sorted(server.done, key=lambda r: r.rid)[:3]:
        print(f'  req {r.rid}: prompt {r.prompt[:4]}... -> {r.out}')


if __name__ == '__main__':
    main()
