import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input shape) cell this lowers + compiles the real
step function (train_step / forward / decode_step) against the production mesh
with full sharding, prints memory_analysis() (proves it fits) and
cost_analysis() (FLOPs/bytes for the roofline), parses the collective traffic
out of the optimized HLO, and writes one JSON per cell to results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]   # every runnable cell
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from .. import sharding as shd
from ..configs import (ARCH_MODULES, SHAPES, get_config, long_context_supported,
                       shapes_for)
from ..models import batch_axes, get_bundle, input_specs
from ..roofline import analyze
from .mesh import make_production_mesh, resolve_rules
from .train import (abstract_init, abstract_train_state, batch_shardings,
                    make_train_step)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / 'results' / 'dryrun'

# Archs whose weights cannot fit TP-only at serve time (see DESIGN.md §5).
BIG_SERVE = {'kimi-k2-1t-a32b', 'llama-3.2-vision-90b', 'mixtral-8x22b'}

# Gradient-accumulation microbatches per (arch, train) — memory fit lever.
TRAIN_MICROBATCHES = {
    'kimi-k2-1t-a32b': 8, 'mixtral-8x22b': 8, 'llama-3.2-vision-90b': 8,
    'qwen3-14b': 4, 'qwen2.5-14b': 4, 'codeqwen1.5-7b': 4, 'minicpm-2b': 4,
    'hymba-1.5b': 8, 'xlstm-1.3b': 1, 'whisper-base': 1, 'chipmunk-ctc': 1,
}


def serve_rules_for(arch: str):
    return shd.SERVE_BIG_RULES if arch in BIG_SERVE else shd.SERVE_RULES


def model_flops_per_chip(bundle, params_sds, shape, n_chips: int) -> float:
    """MODEL_FLOPS = 6*N(_active)*D train / 2*N*D inference, per chip."""
    n_active = bundle.active_param_count(params_sds)
    if shape.kind == 'train':
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_chips
    if shape.kind == 'prefill':
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_chips
    tokens = shape.global_batch          # one token per sequence per step
    return 2.0 * n_active * tokens / n_chips


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               override_rules=None, save_hlo: bool = False):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    bundle = get_bundle(cfg)
    t0 = time.time()

    tp = 16
    if shape.kind == 'train':
        rules_dict = override_rules or shd.rules_for_arch(
            shd.TRAIN_RULES, cfg.n_kv_heads, tp, cfg.family)
        rules = shd.ShardingRules(mesh, resolve_rules(rules_dict, mesh))
        with shd.use_rules(rules):
            state_sds, state_sh, optimizer = abstract_train_state(
                bundle, mesh, rules_dict)
            step_fn = make_train_step(
                bundle, optimizer,
                microbatches=TRAIN_MICROBATCHES.get(arch, 1))
            batch_sds = input_specs(cfg, shape)
            batch_sh = batch_shardings(cfg, shape, mesh, rules_dict)
            lowered = jax.jit(
                step_fn, in_shardings=(state_sh, batch_sh),
                donate_argnums=(0,)).lower(state_sds, batch_sds)
    else:
        rules_dict = override_rules or shd.rules_for_arch(
            serve_rules_for(arch), cfg.n_kv_heads, tp, cfg.family)
        rules = shd.ShardingRules(mesh, resolve_rules(rules_dict, mesh))
        with shd.use_rules(rules):
            params_sds, axes = abstract_init(bundle.init, jax.random.PRNGKey(0))
            p_sh = shd.param_sharding_tree(axes, params_sds, mesh, rules.rules)
            batch_sds = input_specs(cfg, shape)
            batch_sh = batch_shardings(cfg, shape, mesh, rules_dict)
            if shape.kind == 'prefill':
                fwd = lambda p, b: bundle.forward(p, b)
                lowered = jax.jit(fwd, in_shardings=(p_sh, batch_sh)).lower(
                    params_sds, batch_sds)
            else:  # decode
                cache_sds, cache_axes = abstract_init(
                    lambda: bundle.init_cache(shape.global_batch, shape.seq_len))
                c_sh = shd.param_sharding_tree(cache_axes, cache_sds, mesh,
                                               rules.rules)
                extra_sds, extra_sh = (), ()
                decode = bundle.decode_step
                if cfg.family in ('audio', 'vlm'):
                    from ..models import transformer
                    src = jax.ShapeDtypeStruct(
                        (shape.global_batch, cfg.n_source_tokens, cfg.d_model),
                        jnp.float32)
                    ckv_sds = jax.eval_shape(
                        lambda p, s: transformer.precompute_cross_kv(cfg, p, s),
                        params_sds, src)
                    ckv_sh = jax.tree.map(
                        lambda a: rules.sharding(
                            ('layers', 'batch', 'frames', 'kv_heads',
                             'head_dim'), a.shape), ckv_sds)
                    extra_sds, extra_sh = (ckv_sds,), (ckv_sh,)

                    def decode(p, c, t, pos, ckv):
                        from ..models import transformer as tr
                        return tr.decode_step(cfg, p, c, t, pos, cross_kv=ckv)

                tok_key = 'frames' if cfg.family == 'lstm' else 'tokens'
                tok_sds = batch_sds[tok_key]
                tok_sh = batch_sh[tok_key]
                pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
                from jax.sharding import NamedSharding, PartitionSpec as P
                repl = NamedSharding(mesh, P())
                lowered = jax.jit(
                    decode,
                    in_shardings=(p_sh, c_sh, tok_sh, repl) + extra_sh,
                    donate_argnums=(1,)).lower(
                        params_sds, cache_sds, tok_sds, pos_sds, *extra_sds)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            'argument_size_bytes': getattr(mem, 'argument_size_in_bytes', None),
            'output_size_bytes': getattr(mem, 'output_size_in_bytes', None),
            'temp_size_bytes': getattr(mem, 'temp_size_in_bytes', None),
            'generated_code_size_bytes':
                getattr(mem, 'generated_code_size_in_bytes', None),
            'alias_size_bytes': getattr(mem, 'alias_size_in_bytes', None),
        }
    except Exception as e:                                   # pragma: no cover
        mem_rec = {'error': repr(e)}

    params_sds2, _ = abstract_init(bundle.init, jax.random.PRNGKey(0))
    mflops = model_flops_per_chip(bundle, params_sds2, shape, n_chips)
    terms = analyze(compiled, model_flops_per_chip=mflops)

    rec = {
        'arch': arch, 'shape': shape_name,
        'mesh': 'multi_pod_2x16x16' if multi_pod else 'single_pod_16x16',
        'kind': shape.kind, 'n_chips': n_chips,
        'params': bundle.param_count(params_sds2),
        'active_params': bundle.active_param_count(params_sds2),
        'lower_s': round(t_lower, 1), 'compile_s': round(t_compile, 1),
        'memory': mem_rec,
        'roofline': terms.to_dict(),
        'status': 'ok',
    }
    if save_hlo:
        hlo_path = RESULTS / f'{arch}_{shape_name}_hlo.txt'
        hlo_path.write_text(compiled.as_text())
        rec['hlo_path'] = str(hlo_path)
    return rec


def cell_path(arch, shape_name, multi_pod):
    mesh = 'mp' if multi_pod else 'sp'
    return RESULTS / f'{arch}__{shape_name}__{mesh}.json'


def run_cell(arch, shape_name, multi_pod, force=False, save_hlo=False):
    out = cell_path(arch, shape_name, multi_pod)
    if out.exists() and not force:
        rec = json.loads(out.read_text())
        if rec.get('status') == 'ok':
            print(f'[skip] {out.name} (cached)')
            return rec
    print(f'[run ] {arch} x {shape_name} '
          f'({"2x16x16" if multi_pod else "16x16"})', flush=True)
    try:
        rec = lower_cell(arch, shape_name, multi_pod, save_hlo=save_hlo)
        print(f'  ok: compile {rec["compile_s"]}s, '
              f'bottleneck={rec["roofline"]["bottleneck"]}, '
              f'fraction={rec["roofline"]["roofline_fraction"]}')
    except Exception as e:
        rec = {'arch': arch, 'shape': shape_name,
               'mesh': 'multi_pod_2x16x16' if multi_pod else 'single_pod_16x16',
               'status': 'fail', 'error': traceback.format_exc()}
        print(f'  FAIL: {type(e).__name__}: {e}')
    RESULTS.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    return rec


def all_cells(include_chipmunk=True):
    cells = []
    for arch in ARCH_MODULES:
        if arch == 'chipmunk-ctc' and not include_chipmunk:
            continue
        cfg = get_config(arch)
        for s in shapes_for(cfg):
            cells.append((arch, s.name))
    return cells


def run_systolic_geometry():
    """Dry-run the paper's own 3x(5x5) configuration as a device mesh.

    CTC-3L-421H-UNI is pipelined over a ('stage','row','col') = (3, 5, 10)
    mesh (one JAX device per engine tile position; the silicon multiplexes
    2 positions per engine — see core/perf_model.py).  Proves the shard_map
    collective schedule (psum over cols, all_gather over rows, ppermute
    between stages) lowers and compiles.
    """
    from ..core import lstm, pipeline, systolic
    cfg = get_config('chipmunk-ctc')
    keys = jax.random.split(jax.random.PRNGKey(0), cfg.n_layers)
    layers = [lstm.init_lstm_params(keys[0], cfg.lstm_inputs, cfg.lstm_hidden)]
    layers += [lstm.init_lstm_params(k, cfg.lstm_hidden, cfg.lstm_hidden)
               for k in keys[1:]]
    packed, plan = pipeline.pack_pipeline(layers, tile=96)
    mesh = systolic.make_systolic_mesh(plan.rows, plan.cols,
                                       stage=cfg.n_layers)
    print(f'[run ] chipmunk systolic geometry: stage={cfg.n_layers} x '
          f'{plan.rows} x {plan.cols} = {mesh.size} engines')
    packed = pipeline.shard_pipeline(packed, mesh)
    T, B = 16, 8
    xs = jax.ShapeDtypeStruct((T, B, plan.padded_x), jnp.float32)
    lowered = jax.jit(
        lambda x: pipeline.systolic_pipeline(packed, mesh, x)).lower(xs)
    compiled = lowered.compile()
    terms = analyze(compiled)
    rec = {
        'arch': 'chipmunk-ctc', 'shape': f'systolic_3x{plan.rows}x{plan.cols}',
        'mesh': f'stage3_row{plan.rows}_col{plan.cols}', 'status': 'ok',
        'n_chips': int(mesh.size),
        'roofline': terms.to_dict(),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / 'chipmunk-ctc__systolic_geometry.json').write_text(
        json.dumps(rec, indent=1))
    print(f'  ok: collective bytes/chip={terms.collective_bytes:,.0f} '
          f'({terms.per_collective})')
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch')
    ap.add_argument('--shape')
    ap.add_argument('--multi-pod', action='store_true')
    ap.add_argument('--all', action='store_true')
    ap.add_argument('--force', action='store_true')
    ap.add_argument('--save-hlo', action='store_true')
    ap.add_argument('--systolic', action='store_true',
                    help="dry-run the paper's 3x(RxC) geometry")
    args = ap.parse_args()

    if args.systolic:
        run_systolic_geometry()
        return

    assert len(jax.devices()) >= 512, 'XLA_FLAGS must force 512 host devices'
    if args.all:
        ok = fail = 0
        for arch, shape_name in all_cells():
            rec = run_cell(arch, shape_name, args.multi_pod, args.force)
            ok += rec['status'] == 'ok'
            fail += rec['status'] != 'ok'
        print(f'done: {ok} ok, {fail} failed')
    else:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.force,
                       save_hlo=args.save_hlo)
        print(json.dumps(rec, indent=2)[:2000])


if __name__ == '__main__':
    main()
