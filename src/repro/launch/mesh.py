"""Production mesh construction (TPU v5e pods; CPU placeholder devices OK).

Single pod: 16 x 16 = 256 chips ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips ("pod", "data", "model") — the "pod" axis
crosses DCI; sharding anything over it proves the config scales past one pod.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh

from ..compat import AxisType, make_mesh

# TPU v5e constants used for the roofline analysis (per assignment).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ('pod', 'data', 'model') if multi_pod else ('data', 'model')
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


# ---------------------------------------------------------------------------
# Systolic topology presets (DESIGN.md §6 and §9)
# ---------------------------------------------------------------------------
# name -> (stage, rows, cols) engine grids from the paper's scaling study.
# ``stage == 1`` presets drive the persistent scale-out kernel
# (core/systolic.systolic_lstm_seq); ``stage > 1`` presets drive the STAGED
# scale-out of the fused wavefront stack
# (core/systolic.systolic_lstm_stack_seq, backend
# ``pallas_seq_fused_systolic``): each stage holds one contiguous layer
# block, chunks pipeline stage to stage via ppermute.  'graves-75' is the
# 75-tile 3x(5x5) configuration that runs the Graves phoneme topology in
# real time (paper Sec. 4.2, Table 2) — runnable end to end with host
# devices via XLA_FLAGS=--xla_force_host_platform_device_count=75 (see the
# README serving command).
SYSTOLIC_TOPOLOGIES = {
    # degenerate single-engine preset: never auto-picked (an all-1 mesh is
    # inadmissible, §6.2) — use with an explicit backend= selection
    'single': (1, 1, 1),
    '1x2': (1, 1, 2),        # smallest col (partial-sum hop) scale-out
    '2x1': (1, 2, 1),        # smallest row (h re-broadcast) scale-out
    '2x2': (1, 2, 2),
    '5x5': (1, 5, 5),        # the paper's single-layer 25-tile config
    '5x7': (1, 5, 7),        # CTC-3L-421H layer plan at tile=96 (35 engines)
    'graves-75': (3, 5, 5),  # 3-stage pipeline of 5x5 grids = 75 tiles
}


def make_systolic_topology(name: str, devices=None) -> Mesh:
    """Build the named preset as a ('stage','row','col') mesh."""
    stage, rows, cols = SYSTOLIC_TOPOLOGIES[name]
    from ..core.systolic import make_systolic_mesh
    return make_systolic_mesh(rows, cols, stage=stage, devices=devices)


def install_systolic_topology(name: str, devices=None) -> Mesh:
    """Build the named preset and install it as the process systolic mesh.

    After installation, ``auto`` LSTM backend selection resolves to
    ``pallas_seq_systolic`` for layers a stage-1 mesh admits (DESIGN.md
    §6), and stack-level selection resolves to the staged
    ``pallas_seq_fused_systolic`` for stacks a ``stage > 1`` mesh admits
    (DESIGN.md §9 — ``graves-75`` runs the full 3x(5x5) Table-2 topology
    in one dispatch path).  Inadmissible presets are installed but never
    auto-picked (e.g. the all-1 ``single`` mesh: the single-engine §3.3
    rules keep deciding there; explicit ``backend=`` selection still
    works).
    """
    from ..core import systolic
    return systolic.install_mesh(make_systolic_topology(name, devices))


def resolve_rules(rules: Dict[str, object], mesh: Mesh) -> Dict[str, object]:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on single-pod)."""
    names = set(mesh.axis_names)
    out = {}
    for k, v in rules.items():
        if v is None:
            out[k] = None
        elif isinstance(v, str):
            out[k] = v if v in names else None
        else:
            kept = tuple(a for a in v if a in names)
            out[k] = kept if kept else None
    return out
