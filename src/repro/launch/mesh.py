"""Production mesh construction (TPU v5e pods; CPU placeholder devices OK).

Single pod: 16 x 16 = 256 chips ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips ("pod", "data", "model") — the "pod" axis
crosses DCI; sharding anything over it proves the config scales past one pod.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh

from ..compat import AxisType, make_mesh

# TPU v5e constants used for the roofline analysis (per assignment).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ('pod', 'data', 'model') if multi_pod else ('data', 'model')
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def resolve_rules(rules: Dict[str, object], mesh: Mesh) -> Dict[str, object]:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on single-pod)."""
    names = set(mesh.axis_names)
    out = {}
    for k, v in rules.items():
        if v is None:
            out[k] = None
        elif isinstance(v, str):
            out[k] = v if v in names else None
        else:
            kept = tuple(a for a in v if a in names)
            out[k] = kept if kept else None
    return out
