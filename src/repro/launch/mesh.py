"""Production mesh construction (TPU v5e pods; CPU placeholder devices OK).

Single pod: 16 x 16 = 256 chips ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips ("pod", "data", "model") — the "pod" axis
crosses DCI; sharding anything over it proves the config scales past one pod.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from ..compat import AxisType, make_mesh, mesh_with_axis_types

# TPU v5e constants used for the roofline analysis (per assignment).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The (data, model) training mesh shape the launch scripts assume —
    one 16x16 pod slice, or two pods under an extra leading 'pod' axis.
    Topology construction only; no placement or arithmetic happens here."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ('pod', 'data', 'model') if multi_pod else ('data', 'model')
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


# ---------------------------------------------------------------------------
# Systolic topology presets (DESIGN.md §6 and §9)
# ---------------------------------------------------------------------------
# name -> (stage, rows, cols) engine grids from the paper's scaling study.
# ``stage == 1`` presets drive the persistent scale-out kernel
# (core/systolic.systolic_lstm_seq); ``stage > 1`` presets drive the STAGED
# scale-out of the fused wavefront stack
# (core/systolic.systolic_lstm_stack_seq, backend
# ``pallas_seq_fused_systolic``): each stage holds one contiguous layer
# block, chunks pipeline stage to stage via ppermute.  'graves-75' is the
# 75-tile 3x(5x5) configuration that runs the Graves phoneme topology in
# real time (paper Sec. 4.2, Table 2) — runnable end to end with host
# devices via XLA_FLAGS=--xla_force_host_platform_device_count=75 (see the
# README serving command).
SYSTOLIC_TOPOLOGIES = {
    # degenerate single-engine preset: never auto-picked (an all-1 mesh is
    # inadmissible, §6.2) — use with an explicit backend= selection
    'single': (1, 1, 1),
    '1x2': (1, 1, 2),        # smallest col (partial-sum hop) scale-out
    '2x1': (1, 2, 1),        # smallest row (h re-broadcast) scale-out
    '2x2': (1, 2, 2),
    '5x5': (1, 5, 5),        # the paper's single-layer 25-tile config
    '5x7': (1, 5, 7),        # CTC-3L-421H layer plan at tile=96 (35 engines)
    'graves-75': (3, 5, 5),  # 3-stage pipeline of 5x5 grids = 75 tiles
}


def make_systolic_topology(name: str, devices=None) -> Mesh:
    """Build the named preset as a ('stage','row','col') mesh."""
    stage, rows, cols = SYSTOLIC_TOPOLOGIES[name]
    from ..core.systolic import make_systolic_mesh
    return make_systolic_mesh(rows, cols, stage=stage, devices=devices)


def install_systolic_topology(name: str, devices=None) -> Mesh:
    """Build the named preset and install it as the process systolic mesh.

    After installation, ``auto`` LSTM backend selection resolves to
    ``pallas_seq_systolic`` for layers a stage-1 mesh admits (DESIGN.md
    §6), and stack-level selection resolves to the staged
    ``pallas_seq_fused_systolic`` for stacks a ``stage > 1`` mesh admits
    (DESIGN.md §9 — ``graves-75`` runs the full 3x(5x5) Table-2 topology
    in one dispatch path).  Inadmissible presets are installed but never
    auto-picked (e.g. the all-1 ``single`` mesh: the single-engine §3.3
    rules keep deciding there; explicit ``backend=`` selection still
    works).
    """
    from ..core import systolic
    return systolic.install_mesh(make_systolic_topology(name, devices))


# ---------------------------------------------------------------------------
# Two-level die/tile fault-domain hierarchy (DESIGN.md §14)
# ---------------------------------------------------------------------------
# The Chipmunk follow-up ("Vau da Muntanialas", PAPERS.md) scales the same
# systolic idea across DIES with an explicit interconnect hierarchy: intra-die
# collectives are cheap, inter-die hops are chunk-granular.  ``DieMesh`` models
# that hierarchy as a ("die", "stage", "row", "col") fleet: each die owns
# ``stage`` pipeline stages of (rows x cols) engine grids, and the die axis is
# the FAULT-DOMAIN axis — a die failure kills exactly its sub-mesh, and the
# systolic array re-forms on the survivors.  Execution flattens the healthy
# dies onto the existing ("stage", "row", "col") staged dispatch path (the
# die and stage axes compose into one pipeline axis: total stages =
# healthy_dies * stage), so every degraded rung keeps the per-stage
# (rows x cols) grid geometry — the same arithmetic class (n_h_p, bk), which
# is what makes die-level degrade AND canary-validated promote bit-preserving
# (tests/test_recovery.py).


@dataclasses.dataclass(frozen=True)
class DieMesh:
    """Two-level ("die", "stage", "row", "col") fleet model.

    ``dies`` fault domains, each holding ``stage`` pipeline stages of
    (``rows`` x ``cols``) engine grids; ``devices`` is the row-major flat
    device tuple (die-major, so one die's devices are contiguous — a die
    failure maps to a contiguous device range).  Pure topology bookkeeping:
    no arithmetic of its own — execution goes through ``submesh``'s
    flattened projection onto the staged scale-out path.
    """

    dies: int
    stage: int
    rows: int
    cols: int
    devices: Tuple = ()

    @property
    def engines_per_die(self) -> int:
        """Engines one die contributes (= engines lost when it fails)."""
        return self.stage * self.rows * self.cols

    @property
    def n_engines(self) -> int:
        """Total fleet engines across all dies."""
        return self.dies * self.engines_per_die

    def die_devices(self, die: int) -> Tuple:
        """The contiguous device slice owned by fault domain ``die``."""
        k = self.engines_per_die
        return tuple(self.devices[die * k:(die + 1) * k])

    def submesh(self, healthy: Sequence[int]) -> Mesh:
        """Flatten the healthy dies onto one ('stage','row','col') execution
        mesh: total stage depth = ``len(healthy) * stage``, per-stage grid
        geometry unchanged.  Pure placement — the flattened mesh drives the
        SAME staged dispatch path as a hand-built ``make_systolic_mesh``,
        and because every rung keeps the (rows, cols) grid, re-forming on
        fewer (or re-admitted) dies stays within one arithmetic class:
        chunk outputs are bit-equal across die counts."""
        healthy = sorted(healthy)
        assert healthy and all(0 <= d < self.dies for d in healthy), healthy
        devs = [d for die in healthy for d in self.die_devices(die)]
        from ..core.systolic import make_systolic_mesh
        return make_systolic_mesh(self.rows, self.cols,
                                  stage=len(healthy) * self.stage,
                                  devices=devs)

    def full_mesh(self) -> Mesh:
        """The explicit 4-axis ('die','stage','row','col') mesh — the model
        the die-aware admission rule (``core.systolic.
        seq_scaleout_admissible``) and perf model reason over.  Execution
        uses ``submesh`` (die and stage fold into one pipeline axis); this
        form keeps the fault-domain boundary explicit."""
        arr = np.array(list(self.devices)).reshape(
            self.dies, self.stage, self.rows, self.cols)
        return mesh_with_axis_types(arr, ('die', 'stage', 'row', 'col'),
                                    axis_types=(AxisType.Auto,) * 4)


# name -> (dies, stage-per-die, rows, cols).  'graves-3x25' is the paper's
# 75-engine Table-2 topology refactored as THREE 25-engine dies: the
# degradation ladder then has real intermediate rungs (75 -> 50 -> 25
# engines) instead of jumping straight to single-host.  The small presets
# run on host devices (XLA_FLAGS=--xla_force_host_platform_device_count=N).
DIE_TOPOLOGIES = {
    'die-2x1x1': (2, 1, 1, 1),   # 2 dies of one engine each (2 devices)
    'die-3x1x1': (3, 1, 1, 1),   # 3 dies of one engine each (3 devices)
    'die-2x1x2': (2, 1, 1, 2),   # 2 dies of a 1x2 grid (4 devices)
    'graves-3x25': (3, 1, 5, 5),  # 3 dies of 5x5 = the Table-2 75 engines
}

_INSTALLED_DIE_MESH: Optional[DieMesh] = None


def make_die_topology(name: str, devices=None) -> DieMesh:
    """Build the named ``DIE_TOPOLOGIES`` preset as a ``DieMesh`` over the
    first ``dies * stage * rows * cols`` devices.  Pure topology
    construction — no placement happens until ``submesh`` is installed."""
    dies, stage, rows, cols = DIE_TOPOLOGIES[name]
    devices = list(jax.devices()) if devices is None else list(devices)
    need = dies * stage * rows * cols
    if len(devices) < need:
        raise ValueError(f'die topology {name!r} needs {need} devices, '
                         f'have {len(devices)}')
    return DieMesh(dies=dies, stage=stage, rows=rows, cols=cols,
                   devices=tuple(devices[:need]))


def install_die_topology(name: str, devices=None) -> DieMesh:
    """Build the named preset, register it as the process die-mesh model,
    and install its all-dies-healthy flattened submesh as the systolic
    execution mesh.  After installation the serving engine's recovery
    runtime (``runtime/recovery.py``) sees the die-level fault domains: an
    ``EngineFailure`` carrying a die id re-forms the mesh on the surviving
    dies (one ladder rung down) instead of abandoning the mesh, and a
    healed die is re-admitted by the canary-validated promotion path.
    Dispatch/placement only — numerics are unchanged on every rung."""
    global _INSTALLED_DIE_MESH
    dm = make_die_topology(name, devices)
    _INSTALLED_DIE_MESH = dm
    from ..core import systolic
    systolic.install_mesh(dm.submesh(range(dm.dies)))
    return dm


def current_die_mesh() -> Optional[DieMesh]:
    """The registered die-mesh model, or None (flat/no-mesh serving)."""
    return _INSTALLED_DIE_MESH


def clear_die_mesh() -> None:
    """Unregister the die-mesh model (the execution mesh is cleared
    separately via ``core.systolic.clear_mesh``)."""
    global _INSTALLED_DIE_MESH
    _INSTALLED_DIE_MESH = None


def resolve_rules(rules: Dict[str, object], mesh: Mesh) -> Dict[str, object]:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on single-pod)."""
    names = set(mesh.axis_names)
    out = {}
    for k, v in rules.items():
        if v is None:
            out[k] = None
        elif isinstance(v, str):
            out[k] = v if v in names else None
        else:
            kept = tuple(a for a in v if a in names)
            out[k] = kept if kept else None
    return out
