"""Chipmunk's contributions as composable JAX modules.

C1 datapath  -> lstm.py (Eqs. 1-5) + kernels/lstm_gates
C2 8/16-bit  -> quant.py (+ the quantized systolic path)
C3 systolic  -> systolic.py (tiled + shard_map dataflow)
C3b pipeline -> pipeline.py (stage-parallel layer pipeline)
C4 silicon   -> perf_model.py (Fig. 5 / Tables 1-2 analytical model)
CTC workload -> ctc.py (the paper's Sec. 4.2 target network's loss)
"""
from . import ctc, lstm, perf_model, pipeline, quant, systolic

__all__ = ['ctc', 'lstm', 'perf_model', 'pipeline', 'quant', 'systolic']
