"""Canonical peephole LSTM — the workload Chipmunk executes (paper Eqs. 1-5).

    i_t = sigma(W_xi x_t + W_hi h_{t-1} + w_ci . c_{t-1} + b_i)
    f_t = sigma(W_xf x_t + W_hf h_{t-1} + w_cf . c_{t-1} + b_f)
    c_t = f_t . c_{t-1} + i_t . tanh(W_xc x_t + W_hc h_{t-1} + b_c)
    o_t = sigma(W_xo x_t + W_ho h_{t-1} + w_co . c_t + b_o)
    h_t = o_t . tanh(c_t)

The peephole matrices are diagonal by construction (footnote 1 of the paper), so they
are stored as vectors and applied element-wise — exactly what the silicon implements.

Gate storage order throughout the package: (i, f, g, o) where g is the cell candidate.
Weights are packed as W[4, N_h, N_in] so the systolic tiler can block them uniformly.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

GATES = 4  # i, f, g, o
I, F, G, O = 0, 1, 2, 3
PEEP_I, PEEP_F, PEEP_O = 0, 1, 2


class LSTMParams(NamedTuple):
    w_x: jax.Array    # (4, N_h, N_x)
    w_h: jax.Array    # (4, N_h, N_h)
    w_peep: jax.Array  # (3, N_h)   diagonal peepholes for i, f, o
    b: jax.Array      # (4, N_h)

    @property
    def n_h(self) -> int:
        return self.w_h.shape[-1]

    @property
    def n_x(self) -> int:
        return self.w_x.shape[-1]

    def num_params(self) -> int:
        return sum(int(jnp.size(p)) for p in self)


def init_lstm_params(key: jax.Array, n_x: int, n_h: int,
                     dtype=jnp.float32, forget_bias: float = 1.0) -> LSTMParams:
    kx, kh, kp = jax.random.split(key, 3)
    sx = 1.0 / jnp.sqrt(n_x)
    sh = 1.0 / jnp.sqrt(n_h)
    b = jnp.zeros((GATES, n_h), dtype)
    b = b.at[F].set(forget_bias)  # standard LSTM trick; keeps early training stable
    return LSTMParams(
        w_x=(jax.random.uniform(kx, (GATES, n_h, n_x), dtype, -1, 1) * sx),
        w_h=(jax.random.uniform(kh, (GATES, n_h, n_h), dtype, -1, 1) * sh),
        w_peep=(jax.random.uniform(kp, (3, n_h), dtype, -1, 1) * 0.1),
        b=b,
    )


def lstm_cell(params: LSTMParams, x_t: jax.Array, h_prev: jax.Array,
              c_prev: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One LSTM timestep.  x_t: (..., N_x); h_prev, c_prev: (..., N_h)."""
    # (..., 4, N_h) pre-activations; the matrix-vector products of Fig. 1 (green).
    pre = (jnp.einsum('ghx,...x->...gh', params.w_x, x_t)
           + jnp.einsum('ghk,...k->...gh', params.w_h, h_prev))
    i = jax.nn.sigmoid(pre[..., I, :] + params.w_peep[PEEP_I] * c_prev + params.b[I])
    f = jax.nn.sigmoid(pre[..., F, :] + params.w_peep[PEEP_F] * c_prev + params.b[F])
    g = jnp.tanh(pre[..., G, :] + params.b[G])
    c_t = f * c_prev + i * g
    o = jax.nn.sigmoid(pre[..., O, :] + params.w_peep[PEEP_O] * c_t + params.b[O])
    h_t = o * jnp.tanh(c_t)
    return h_t, c_t


def lstm_layer(params: LSTMParams, xs: jax.Array,
               h0: Optional[jax.Array] = None,
               c0: Optional[jax.Array] = None) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Scan a layer over time.  xs: (T, ..., N_x) -> hs: (T, ..., N_h).

    The input-state contribution W_x @ x_t is hoisted out of the scan as one
    (T*B)-wide matmul — the sequential loop only carries the recurrent
    W_h @ h_{t-1} part.  Besides halving in-loop matmuls, this moves the
    dW_x reduction out of the time loop (one all-reduce instead of T under
    data parallelism).  The silicon streams x the same way (Sec. 3.2).
    """
    n_h = params.n_h
    batch_shape = xs.shape[1:-1]
    if h0 is None:
        h0 = jnp.zeros(batch_shape + (n_h,), xs.dtype)
    if c0 is None:
        c0 = jnp.zeros(batch_shape + (n_h,), xs.dtype)

    pre_x = jnp.einsum('ghx,t...x->t...gh', params.w_x, xs)   # hoisted

    def step(carry, pre_x_t):
        h, c = carry
        pre = pre_x_t + jnp.einsum('ghk,...k->...gh', params.w_h, h)
        i = jax.nn.sigmoid(pre[..., I, :] + params.w_peep[PEEP_I] * c + params.b[I])
        f = jax.nn.sigmoid(pre[..., F, :] + params.w_peep[PEEP_F] * c + params.b[F])
        g = jnp.tanh(pre[..., G, :] + params.b[G])
        c = f * c + i * g
        o = jax.nn.sigmoid(pre[..., O, :] + params.w_peep[PEEP_O] * c + params.b[O])
        h = o * jnp.tanh(c)
        return (h, c), h

    (h_T, c_T), hs = jax.lax.scan(step, (h0, c0), pre_x)
    return hs, (h_T, c_T)


# ---------------------------------------------------------------------------
# Hand-written layer VJP: weight gradients accumulate OUTSIDE the time loop
# (autodiff-of-scan reduces dW across data shards every step — measured
# 62 GB/chip/step on the chipmunk-ctc train cell; this does it once).
# ---------------------------------------------------------------------------

def _cell_body(w_h, w_peep, b, pre_x_t, h, c_prev):
    """Shared gate math of the scan-family step functions (`_lstm_scan` and
    the masked serving variant), so the two cannot silently diverge.  The
    spelled-out forms in ``lstm_cell``/``lstm_layer`` stay independent — they
    are the paper-equation oracles both scan paths are tested against.
    Returns (h_new, c_new, (i, f, g, o))."""
    pre = pre_x_t + jnp.einsum('ghk,...k->...gh', w_h, h)
    i = jax.nn.sigmoid(pre[..., I, :] + w_peep[PEEP_I] * c_prev + b[I])
    f = jax.nn.sigmoid(pre[..., F, :] + w_peep[PEEP_F] * c_prev + b[F])
    g = jnp.tanh(pre[..., G, :] + b[G])
    c = f * c_prev + i * g
    o = jax.nn.sigmoid(pre[..., O, :] + w_peep[PEEP_O] * c + b[O])
    h_new = o * jnp.tanh(c)
    return h_new, c, (i, f, g, o)


def _lstm_scan(w_h, w_peep, b, pre_x, h0, c0):
    def step(carry, pre_x_t):
        h, c_prev = carry
        h_new, c, (i, f, g, o) = _cell_body(w_h, w_peep, b, pre_x_t, h, c_prev)
        gates = jnp.stack([i, f, g, o], axis=-2)
        return (h_new, c), (h_new, c, gates)

    (h_T, c_T), (hs, cs, gates) = jax.lax.scan(step, (h0, c0), pre_x)
    return hs, cs, gates, h_T, c_T


@jax.custom_vjp
def lstm_scan_fused(w_h, w_peep, b, pre_x, h0, c0):
    hs, _, _, h_T, c_T = _lstm_scan(w_h, w_peep, b, pre_x, h0, c0)
    return hs, (h_T, c_T)


def _lsf_fwd(w_h, w_peep, b, pre_x, h0, c0):
    hs, cs, gates, h_T, c_T = _lstm_scan(w_h, w_peep, b, pre_x, h0, c0)
    return (hs, (h_T, c_T)), (w_h, w_peep, hs, cs, gates, h0, c0)


def lstm_bwd_core(w_h, w_peep, hs, cs, gates, h0, c0, dhs, dh_T, dc_T):
    """Shared reverse-time scan: used by the scan VJP and the Pallas-sequence
    kernel VJP (which recomputes ``gates`` instead of storing them)."""
    h_prevs = jnp.concatenate([h0[None], hs[:-1]], axis=0)
    c_prevs = jnp.concatenate([c0[None], cs[:-1]], axis=0)

    def step(carry, xs):
        dh_next, dc_next = carry
        dh_out, c_prev, c_t, gate_t = xs
        i, f, g, o = (gate_t[..., k, :] for k in range(4))
        dh = dh_out + dh_next
        tc = jnp.tanh(c_t)
        do = dh * tc
        da_o = do * o * (1 - o)
        dct = dh * o * (1 - tc * tc) + dc_next + da_o * w_peep[PEEP_O]
        da_i = dct * g * i * (1 - i)
        da_f = dct * c_prev * f * (1 - f)
        da_g = dct * i * (1 - g * g)
        dc_prev = dct * f + da_i * w_peep[PEEP_I] + da_f * w_peep[PEEP_F]
        da = jnp.stack([da_i, da_f, da_g, da_o], axis=-2)   # (..., 4, Nh)
        dh_prev = jnp.einsum('ghk,...gh->...k', w_h, da)
        return (dh_prev, dc_prev), da

    (dh0, dc0), das = jax.lax.scan(
        step, (dh_T, dc_T), (dhs, c_prevs, cs, gates), reverse=True)

    # weight gradients: single wide contractions outside the loop
    dw_h = jnp.einsum('t...gh,t...k->ghk', das, h_prevs)
    d_peep = jnp.stack([
        jnp.einsum('t...h,t...h->h', das[..., I, :], c_prevs),
        jnp.einsum('t...h,t...h->h', das[..., F, :], c_prevs),
        jnp.einsum('t...h,t...h->h', das[..., O, :], cs)])
    db = das.sum(axis=tuple(range(das.ndim - 2)))
    dpre_x = das
    return dw_h, d_peep, db, dpre_x, dh0, dc0


def _lsf_bwd(res, grads):
    w_h, w_peep, hs, cs, gates, h0, c0 = res
    dhs, (dh_T, dc_T) = grads
    return lstm_bwd_core(w_h, w_peep, hs, cs, gates, h0, c0, dhs, dh_T, dc_T)


lstm_scan_fused.defvjp(_lsf_fwd, _lsf_bwd)


def lstm_bwd_recompute_gates(w_h, w_peep, b, pre_x, hs, cs, h0, c0, grads):
    """Backward from the saved h/c trajectories only (no stored gates).

    The Pallas kernels keep gate values on-chip, so their VJPs recompute them
    with one wide matmul + elementwise — the same trade the scan VJP makes
    for dW accumulation — then run the shared reverse-time scan.  Returns
    (dw_h, d_peep, db, dpre_x, dh0, dc0).
    """
    dhs, (dh_T, dc_T) = grads
    h_prevs = jnp.concatenate([h0[None], hs[:-1]], axis=0)
    c_prevs = jnp.concatenate([c0[None], cs[:-1]], axis=0)
    pre = pre_x + jnp.einsum('ghk,t...k->t...gh', w_h, h_prevs)
    i = jax.nn.sigmoid(pre[..., I, :] + w_peep[PEEP_I] * c_prevs + b[I])
    f = jax.nn.sigmoid(pre[..., F, :] + w_peep[PEEP_F] * c_prevs + b[F])
    g = jnp.tanh(pre[..., G, :] + b[G])
    o = jax.nn.sigmoid(pre[..., O, :] + w_peep[PEEP_O] * cs + b[O])
    gates = jnp.stack([i, f, g, o], axis=-2)
    return lstm_bwd_core(w_h, w_peep, hs, cs, gates, h0, c0, dhs, dh_T, dc_T)


def lstm_stack_bwd_recompute_gates(w_in, w_h, peep, b, pre_x, hs, cs, h0s,
                                   c0s, grads):
    """Cross-layer gate-recompute backward for a homogeneous LSTM stack.

    Composes ``lstm_bwd_recompute_gates`` down the stack from the saved
    per-layer h/c trajectories: each layer's hoisted input stream is
    recomputed from the trajectory below it (layer 0's ``pre_x`` was a
    primal input), the inner layers' input-weight gradients and the
    handover cotangents being the only additions over the single-layer
    VJP.  Shared by the fused wavefront kernel's VJP
    (``kernels.lstm_seq.stack_ops``) and the staged systolic scale-out's
    VJP (``core.systolic.systolic_lstm_stack_seq``), so the two backward
    paths cannot diverge.

    w_in/w_h: (L, 4, N_h, N_h) with ``w_in[0]`` zero; pre_x: (T, B, 4,
    N_h); hs/cs: (L, T, B, N_h) saved trajectories; h0s/c0s: (L, B, N_h);
    grads: (d_ys, (d_hT (L, B, N_h), d_cT)).  Returns (dw_in, dw_h,
    d_peep, db, d_pre_x0, dh0s, dc0s).
    """
    d_ys, (d_hT, d_cT) = grads
    L = w_h.shape[0]
    dw_in, dw_h, d_peep, db, dh0, dc0 = [], [], [], [], [], []
    d_hs = d_ys                     # cotangent flowing into the top layer
    d_pre_x0 = None
    for l in range(L - 1, -1, -1):
        pre_l = pre_x if l == 0 else jnp.einsum('ghx,tbx->tbgh',
                                                w_in[l], hs[l - 1])
        dwh, dp, dbias, dpre, dh, dc = lstm_bwd_recompute_gates(
            w_h[l], peep[l], b[l], pre_l, hs[l], cs[l], h0s[l], c0s[l],
            (d_hs, (d_hT[l], d_cT[l])))
        dw_h.append(dwh)
        d_peep.append(dp)
        db.append(dbias)
        dh0.append(dh)
        dc0.append(dc)
        if l > 0:
            dw_in.append(jnp.einsum('tbgh,tbx->ghx', dpre, hs[l - 1]))
            d_hs = jnp.einsum('ghx,tbgh->tbx', w_in[l], dpre)
        else:
            dw_in.append(jnp.zeros_like(w_in[0]))
            d_pre_x0 = dpre
    stack = lambda xs: jnp.stack(xs[::-1])
    return (stack(dw_in), stack(dw_h), stack(d_peep), stack(db),
            d_pre_x0, stack(dh0), stack(dc0))


# ---------------------------------------------------------------------------
# Backend dispatch: xla_scan | pallas_step | pallas_seq | pallas_seq_fused |
# pallas_seq_systolic | pallas_seq_fused_systolic (DESIGN.md §3.3, §6, §8, §9)
# ---------------------------------------------------------------------------

BACKENDS = ('auto', 'xla_scan', 'pallas_step', 'pallas_seq',
            'pallas_seq_fused', 'pallas_seq_systolic',
            'pallas_seq_fused_systolic')

# Serving degradation ladder (DESIGN.md §10): when a mesh engine is declared
# dead mid-serve, the fault-tolerant serving runtime re-dispatches to the
# next backend DOWN this ladder — from the full staged scale-out through the
# single-host fused stack and the per-layer sequence kernel to the
# always-available XLA scan.  Backends not named on the ladder map onto the
# nearest rung (``_LADDER_RANK``): the layerwise systolic scale-out degrades
# like the staged one (both die with the mesh), the per-step kernel like the
# sequence kernel.
DEGRADATION_LADDER = ('pallas_seq_fused_systolic', 'pallas_seq_fused',
                      'pallas_seq', 'xla_scan')
_LADDER_RANK = {'pallas_seq_fused_systolic': 0, 'pallas_seq_systolic': 0,
                'pallas_seq_fused': 1, 'pallas_seq': 2, 'pallas_step': 2,
                'xla_scan': 3}


def next_backend_down(backend: str) -> Optional[str]:
    """The next backend down the serving ``DEGRADATION_LADDER``, or None at
    the bottom (``xla_scan`` has no fallback — a fault there is retried,
    not degraded).  Pure dispatch — selection never changes the chunking /
    masking contract, only which engine executes it; a degraded backend's
    outputs agree with the original to float tolerance (allclose), and
    bit-equality contracts continue to hold per backend code path."""
    rank = _LADDER_RANK.get(backend)
    if rank is None or rank + 1 >= len(DEGRADATION_LADDER):
        return None
    return DEGRADATION_LADDER[rank + 1]


def next_backend_up(backend: str) -> Optional[str]:
    """The next backend UP the serving ``DEGRADATION_LADDER``, or None at
    the top (the staged systolic rung has nothing above it) — the promotion
    inverse of ``next_backend_down``, consulted by the recovery runtime
    (``runtime/recovery.py``) when the mesh health tracker reports capacity
    for a higher rung.  Pure dispatch — promotion is canary-validated by
    the engine before it takes effect, and never changes the chunking /
    masking contract, only which engine executes it."""
    rank = _LADDER_RANK.get(backend)
    if rank is None or rank == 0:
        return None
    return DEGRADATION_LADDER[rank - 1]

# The sequence kernel keeps W_h + state resident in VMEM; leave headroom for
# Mosaic's double-buffered streams out of the ~16 MB budget.
_VMEM_BUDGET_BYTES = 12 * 1024 * 1024
_SEQ_MIN_T = 8  # below this, per-launch savings don't pay for residency setup


def select_lstm_backend(n_x: int, n_h: int, T: int, batch: int,
                        *, platform: Optional[str] = None,
                        mesh=None) -> str:
    """Shape-based backend selection (see DESIGN.md §3.3 and §6).

    When a systolic mesh is installed (``core.systolic.install_mesh`` /
    ``launch/mesh.py`` presets) and it admits the layer, ``auto`` resolves to
    the multi-engine scale-out backend ``pallas_seq_systolic`` on ANY
    platform — shard_map is real SPMD, not interpret-mode emulation, so it is
    meaningful on CPU host devices too.  Otherwise, on non-TPU platforms
    Pallas kernels only exist in interpret mode (an emulation for validation,
    not speed), so ``auto`` resolves to the XLA scan there; tests and
    benchmarks opt into the kernels explicitly.
    """
    if mesh is None:
        from .systolic import current_mesh
        mesh = current_mesh()
    if mesh is not None and T >= _SEQ_MIN_T:
        from .systolic import seq_scaleout_admissible
        if seq_scaleout_admissible(n_h, mesh):
            return 'pallas_seq_systolic'
    platform = platform or jax.default_backend()
    if platform != 'tpu':
        return 'xla_scan'
    from ..kernels.lstm_seq import vmem_bytes_estimate
    if T >= _SEQ_MIN_T and vmem_bytes_estimate(n_h, batch) <= _VMEM_BUDGET_BYTES:
        return 'pallas_seq'
    if n_h * (n_x + n_h) * 4 * GATES <= _VMEM_BUDGET_BYTES:
        return 'pallas_step'
    return 'xla_scan'


def _stack_backend_admissible(backend: str, n_x: int, n_h: int,
                              n_layers: int, T: int, batch: int, *,
                              platform: Optional[str] = None,
                              mesh=None) -> bool:
    """Whether a cached stack-backend winner may be honoured HERE.

    The schedule cache records measured winners, but admission stays with
    the live rules: the systolic backends need the (admissible) mesh they
    were measured on, and the raw Pallas kernels only exist as interpret-
    mode emulation off-TPU — a cache must never be able to force either.
    ``xla_scan`` is admissible everywhere.
    """
    if backend not in BACKENDS or backend == 'auto':
        return False
    if backend == 'xla_scan':
        return True
    if backend in ('pallas_seq_systolic', 'pallas_seq_fused_systolic'):
        from .systolic import seq_scaleout_admissible
        layers = n_layers if backend == 'pallas_seq_fused_systolic' else None
        return (mesh is not None and T >= _SEQ_MIN_T
                and seq_scaleout_admissible(n_h, mesh, n_layers=layers,
                                            n_x=n_x, T=T, batch=batch))
    return (platform or jax.default_backend()) == 'tpu'


def select_stack_backend(n_x: int, n_h: int, n_layers: int, T: int,
                         batch: int, *, platform: Optional[str] = None,
                         mesh=None) -> str:
    """Stack-level backend selection (DESIGN.md §8 and §9).

    The fused wavefront kernel is a STACK-level choice: it is admitted only
    when the whole stack's resident working set — every layer's recurrent
    AND input weight blocks (``stack_vmem_bytes_estimate``) — fits the VMEM
    budget, there are at least two layers to pipeline, and the sequence is
    long enough to amortise residency.  An installed systolic mesh takes
    precedence (the user asked for multi-engine scale-out): a mesh with a
    live ``stage`` axis that admits the stack (the stage-aware form of
    ``seq_scaleout_admissible``) resolves to the staged scale-out of the
    fused stack, ``pallas_seq_fused_systolic`` (§9 — the paper's 3×(5×5)
    Table-2 topology as one dispatch path); a stage-1 mesh resolves to the
    layerwise ``pallas_seq_systolic``.  Everything else falls back to the
    per-layer ``select_lstm_backend`` rules, i.e. the layerwise
    composition.  Selection never changes numerics — all backends are
    interchangeable.

    An installed schedule cache (``repro.tune``, kind ``'stack_backend'``)
    takes precedence over every heuristic below — a measured winner beats
    an estimated one — but only when the named backend is still admissible
    here (mesh present/admissible for the systolic backends, TPU for the
    raw Pallas kernels): admission guards are correctness/efficiency
    gates, not preferences, so a stale cache can never force an
    inadmissible launch.
    """
    if mesh is None:
        from .systolic import current_mesh
        mesh = current_mesh()
    tuned = _tuned_backend('stack_backend', n_x, n_h, n_layers, T, batch,
                           mesh=mesh)
    if tuned is not None and _stack_backend_admissible(
            tuned, n_x, n_h, n_layers, T, batch, platform=platform,
            mesh=mesh):
        return tuned
    if mesh is not None and T >= _SEQ_MIN_T:
        from .systolic import seq_scaleout_admissible
        if seq_scaleout_admissible(n_h, mesh, n_layers=n_layers,
                                   n_x=n_x, T=T, batch=batch):
            return 'pallas_seq_fused_systolic'
    per_layer = select_lstm_backend(n_x, n_h, T, batch,
                                    platform=platform, mesh=mesh)
    if per_layer == 'pallas_seq_systolic':
        return per_layer
    platform = platform or jax.default_backend()
    if platform != 'tpu':
        return per_layer
    from ..kernels.lstm_seq import stack_vmem_bytes_estimate
    if (n_layers >= 2 and T >= _SEQ_MIN_T
            and stack_vmem_bytes_estimate(n_x, n_h, n_layers, batch)
            <= _VMEM_BUDGET_BYTES):
        return 'pallas_seq_fused'
    return per_layer


# Cold-cache fallback for the int8 stack dispatch (BENCH_kernels.json pair
# "T=32 B=4 48->96x3 tile=48 int8"): the fused wavefront LOSES to the
# layerwise chain at 96 hidden (23.9 ms vs 14.0 ms) — its L-1-diagonal
# fill/drain bubble, stacked-weight relayout, and diagonal re-indexing are
# fixed costs, while the per-layer matmul work it amortises shrinks with the
# hidden width.  Without a measured schedule-cache entry (``repro.tune``),
# fused admission therefore requires a hidden width safely above that
# measured losing point; the paper's 421-hidden Table-2 stack clears it.
_Q_FUSED_MIN_NH = 256


def _tuned_backend(kind: str, n_x: int, n_h: int, n_layers: int, T: int,
                   batch: int, mesh=None) -> Optional[str]:
    """Measured winner for a backend decision from the installed schedule
    cache (``repro.tune.install_schedule_cache``), or None on a miss.
    Dispatch-only by the cache contract: every backend an entry can name is
    numerics-equivalent to the fallback choice, so a hit changes the launch
    shape, never the outputs."""
    from ..tune.schedule import current_schedule_cache, mesh_signature
    cache = current_schedule_cache()
    if cache is None:
        return None
    ent = cache.lookup(kind, n_x=n_x, n_h=n_h, n_layers=n_layers, T=T,
                       B=batch, mesh=mesh_signature(mesh))
    return ent.backend if ent is not None and ent.backend else None


def select_quantized_stack_backend(n_h: int, n_layers: int, T: int,
                                   batch: int) -> str:
    """Int8 stack dispatch: ``'fused'`` (the §8 wavefront
    ``lstm_stack_seq_quantized``) or ``'layerwise'`` (chained
    ``lstm_layer_seq_quantized``).  Both are bit-identical — this picks the
    faster launch shape only.  The structural guards are authoritative (the
    wavefront needs at least two layers to pipeline and a sequence long
    enough to amortise residency, ``_SEQ_MIN_T``); past them, a MEASURED
    winner from the installed schedule cache (``repro.tune``, kind
    ``'q_stack_backend'``) decides, and only on a cache miss does the
    hand-calibrated ``_Q_FUSED_MIN_NH`` hidden-width floor — below it the
    measured BENCH_kernels.json rows show the layerwise chain winning —
    remain as the cold-cache fallback."""
    if n_layers < 2 or T < _SEQ_MIN_T:
        return 'layerwise'
    tuned = _tuned_backend('q_stack_backend', n_h, n_h, n_layers, T, batch)
    if tuned in ('fused', 'layerwise'):
        return tuned
    return 'fused' if n_h >= _Q_FUSED_MIN_NH else 'layerwise'


def _degrade_staged_single_layer(n_h: int) -> str:
    """A single-layer call cannot stage-pipeline (nothing to place on the
    stage axis): ``pallas_seq_fused_systolic`` degrades to the layerwise
    scale-out when the installed mesh admits the layer on its row/col axes
    alone, and to the single-engine sequence kernel otherwise.  Pure
    dispatch — no numerics of its own."""
    from .systolic import current_mesh, seq_scaleout_admissible
    return ('pallas_seq_systolic'
            if seq_scaleout_admissible(n_h, current_mesh())
            else 'pallas_seq')


def lstm_layer_fused(params: LSTMParams, xs: jax.Array,
                     h0: Optional[jax.Array] = None,
                     c0: Optional[jax.Array] = None, *,
                     backend: str = 'auto'):
    """lstm_layer with the hand-written VJP (production training path).

    ``backend`` selects the execution engine: the XLA scan, the per-timestep
    Pallas kernel, the persistent whole-sequence Pallas kernel, or the
    multi-engine systolic scale-out of the sequence kernel (which reads the
    installed mesh — ``core.systolic.install_mesh``); ``auto`` picks by
    shape/platform/mesh (select_lstm_backend).  All backends are numerically
    interchangeable: forward allclose, backward through the same
    gate-recompute VJP family.
    """
    assert backend in BACKENDS, backend
    n_h = params.n_h
    batch_shape = xs.shape[1:-1]
    if backend == 'auto':
        backend = select_lstm_backend(params.n_x, n_h, xs.shape[0],
                                      math.prod(batch_shape))
    if backend == 'pallas_seq_fused':
        backend = 'pallas_seq'      # a 1-layer stack IS the sequence kernel
    if backend == 'pallas_seq_fused_systolic':
        backend = _degrade_staged_single_layer(n_h)
    if h0 is None:
        h0 = jnp.zeros(batch_shape + (n_h,), xs.dtype)
    if c0 is None:
        c0 = jnp.zeros(batch_shape + (n_h,), xs.dtype)
    if backend == 'pallas_seq_systolic':
        from .systolic import current_mesh, systolic_lstm_seq
        T = xs.shape[0]
        flat_b = math.prod(batch_shape)
        hs, (h_T, c_T) = systolic_lstm_seq(
            params, current_mesh(), xs.reshape(T, flat_b, params.n_x),
            h0.reshape(flat_b, n_h), c0.reshape(flat_b, n_h))
        return (hs.reshape((T,) + batch_shape + (n_h,)),
                (h_T.reshape(batch_shape + (n_h,)),
                 c_T.reshape(batch_shape + (n_h,))))
    if backend == 'pallas_seq':
        from ..kernels.lstm_seq import lstm_layer_seq
        return lstm_layer_seq(params, xs, h0, c0)
    if backend == 'pallas_step':
        from ..kernels.lstm_gates import lstm_layer_fused as step_layer
        T = xs.shape[0]
        flat_b = math.prod(batch_shape)
        hs, (h_T, c_T) = step_layer(
            params, xs.reshape(T, flat_b, params.n_x),
            h0=h0.reshape(flat_b, n_h), c0=c0.reshape(flat_b, n_h),
            return_state=True, interpret=jax.default_backend() != 'tpu')
        return (hs.reshape((T,) + batch_shape + (n_h,)),
                (h_T.reshape(batch_shape + (n_h,)),
                 c_T.reshape(batch_shape + (n_h,))))
    pre_x = jnp.einsum('ghx,t...x->t...gh', params.w_x, xs)
    return lstm_scan_fused(params.w_h, params.w_peep, params.b, pre_x, h0, c0)


# ---------------------------------------------------------------------------
# Chunked stateful serving entry points (DESIGN.md §7)
# ---------------------------------------------------------------------------

def valid_len_mask(T: int, valid_len: jax.Array, batch: int) -> jax.Array:
    """The §7 masking contract in one place: step ``t`` of stream ``b`` is
    live iff ``t < valid_len[b]``.  Returns a bool (T, B) mask — every
    masked backend (scan, Pallas kernels, distributed body) derives its
    mask from this single definition so the contract cannot silently
    diverge between them."""
    return (jnp.arange(T, dtype=jnp.int32)[:, None]
            < valid_len.reshape(batch).astype(jnp.int32)[None, :])


def _lstm_scan_masked(w_h, w_peep, b, pre_x, h0, c0, mask):
    """Masked scan: a masked step is identity on (h, c) and re-emits the
    carried ``h`` — the reference semantics every masked backend matches.
    The gate math is the shared ``_cell_body`` (same as ``_lstm_scan``)."""
    def step(carry, inp):
        h, c = carry
        pre_x_t, m = inp
        h_new, c_new, _ = _cell_body(w_h, w_peep, b, pre_x_t, h, c)
        m = m[..., None]
        h = jnp.where(m, h_new, h)
        c = jnp.where(m, c_new, c)
        return (h, c), h

    (h_T, c_T), hs = jax.lax.scan(step, (h0, c0), (pre_x, mask))
    return hs, (h_T, c_T)


def lstm_layer_chunk(params: LSTMParams, xs: jax.Array,
                     h0: Optional[jax.Array] = None,
                     c0: Optional[jax.Array] = None, *,
                     valid_len: Optional[jax.Array] = None,
                     backend: str = 'auto'
                     ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Stateful chunked layer step — the serving-engine primitive (§7).

    Same contract as ``lstm_layer`` / ``lstm_layer_fused`` on the live steps,
    plus ragged masking: ``valid_len`` (B,) marks steps ``t >= valid_len[b]``
    as identity on the state (the carried ``h`` is re-emitted), so the
    returned ``(h_T, c_T)`` is the state after exactly ``valid_len[b]`` steps
    and feeding a sequence chunk by chunk is bit-equal to one monolithic
    call on the same backend.  xs: (T, B, N_x).  With ``valid_len=None``
    this is exactly ``lstm_layer_fused`` (differentiable); the masked path
    is inference-only.  ``pallas_step`` has no masked form — masked chunks
    fall back to the (allclose) masked XLA scan.
    """
    if valid_len is None:
        return lstm_layer_fused(params, xs, h0, c0, backend=backend)
    assert backend in BACKENDS, backend
    assert xs.ndim == 3, 'lstm_layer_chunk expects (T, B, N_x) input'
    T, B = xs.shape[0], xs.shape[1]
    n_h = params.n_h
    if backend == 'auto':
        backend = select_lstm_backend(params.n_x, n_h, T, B)
    if backend == 'pallas_seq_fused':
        backend = 'pallas_seq'      # a 1-layer stack IS the sequence kernel
    if backend == 'pallas_seq_fused_systolic':
        backend = _degrade_staged_single_layer(n_h)
    if h0 is None:
        h0 = jnp.zeros((B, n_h), xs.dtype)
    if c0 is None:
        c0 = jnp.zeros((B, n_h), xs.dtype)
    if backend == 'pallas_seq':
        from ..kernels.lstm_seq import lstm_layer_seq
        return lstm_layer_seq(params, xs, h0, c0, valid_len=valid_len)
    if backend == 'pallas_seq_systolic':
        from .systolic import current_mesh, systolic_lstm_seq
        return systolic_lstm_seq(params, current_mesh(), xs, h0, c0,
                                 valid_len=valid_len)
    # xla_scan — and the masked fallback for pallas_step (no masked kernel).
    mask = valid_len_mask(T, valid_len, B)
    pre_x = jnp.einsum('ghx,tbx->tbgh', params.w_x, xs)
    return _lstm_scan_masked(params.w_h, params.w_peep, params.b, pre_x,
                             h0, c0, mask)


class LSTMStackParams(NamedTuple):
    layers: Tuple[LSTMParams, ...]
    w_out: Optional[jax.Array]  # (N_out, N_h) final dense layer (paper: y = sigma(W_hy h))
    b_out: Optional[jax.Array]

    def num_params(self) -> int:
        n = sum(l.num_params() for l in self.layers)
        if self.w_out is not None:
            n += int(jnp.size(self.w_out)) + int(jnp.size(self.b_out))
        return n


def init_lstm_stack(key: jax.Array, n_x: int, n_h: int, n_layers: int,
                    n_out: Optional[int] = None, dtype=jnp.float32) -> LSTMStackParams:
    keys = jax.random.split(key, n_layers + 1)
    layers = []
    for l in range(n_layers):
        layers.append(init_lstm_params(keys[l], n_x if l == 0 else n_h, n_h, dtype))
    w_out = b_out = None
    if n_out is not None:
        w_out = jax.random.uniform(keys[-1], (n_out, n_h), dtype, -1, 1) / jnp.sqrt(n_h)
        b_out = jnp.zeros((n_out,), dtype)
    return LSTMStackParams(tuple(layers), w_out, b_out)


def stack_carry_arrays(states, n_layers: int, batch: int, n_h: int,
                       dtype) -> Tuple[jax.Array, jax.Array]:
    """Stack per-layer serving carries into the (L, B, N_h) kernel arrays.

    The ONE defaulting rule for fused-stack entry points (the §8 kernel
    wrapper and the §9 staged scale-out): a missing state list, a missing
    layer entry, or a ``None`` half zeroes THAT layer's carry only, never
    its neighbours' — exactly what the layerwise loop does, so backends
    stay numerically interchangeable.  Returns (h0s, c0s).
    """
    zeros = jnp.zeros((batch, n_h), dtype)

    def gather(part):
        def one(l):
            st = None if states is None else states[l]
            v = None if st is None else st[part]
            return zeros if v is None else v

        return jnp.stack([one(l) for l in range(n_layers)])

    return gather(0), gather(1)


def _resolve_stack_backend(params: LSTMStackParams, backend: str,
                           xs: jax.Array) -> str:
    """Stack-level dispatch (DESIGN.md §8 and §9): resolve ``auto`` through
    ``select_stack_backend`` and degrade an (explicit or auto-picked)
    fused-stack backend when the stack is structurally incompatible with
    the wavefront schedule (heterogeneous widths, a single layer, or a
    non-(T, B, N_x) input): ``pallas_seq_fused`` falls back to the
    layerwise ``pallas_seq``, the staged ``pallas_seq_fused_systolic``
    likewise (a heterogeneous stack cannot share one stage-padded weight
    layout; the stage>1 installed mesh is not usable layerwise, so the
    single-engine composition decides).  Pure dispatch — the chosen
    backend never changes numerics beyond float re-association."""
    from ..kernels.lstm_seq import stack_fused_compatible
    compatible = (xs.ndim == 3 and len(params.layers) >= 2
                  and stack_fused_compatible(params))
    if backend == 'auto' and compatible:
        l0 = params.layers[0]
        backend = select_stack_backend(l0.n_x, l0.n_h, len(params.layers),
                                       xs.shape[0], xs.shape[1])
    if backend in ('pallas_seq_fused',
                   'pallas_seq_fused_systolic') and not compatible:
        backend = 'pallas_seq'
    return backend


def resolve_serving_backend(params: LSTMStackParams, backend: str,
                            T: int, B: int) -> str:
    """Resolve ``backend`` (incl. ``auto``) to the CONCRETE backend a
    ``(T, B, N_x)`` chunked serving call would dispatch to — the same
    ``_resolve_stack_backend`` selection ``lstm_stack_chunk`` applies, run
    ahead of time on a shape placeholder.  The fault-tolerant serving
    runtime pins this at engine construction so it knows its position on
    the ``DEGRADATION_LADDER`` before any fault occurs.  Pure dispatch —
    resolution never changes numerics."""
    l0 = params.layers[0]
    xs = jax.ShapeDtypeStruct((T, B, l0.n_x), jnp.float32)
    resolved = _resolve_stack_backend(params, backend, xs)
    if resolved == 'auto':           # structurally fused-incompatible stack:
        # the per-layer rules decide, exactly as lstm_layer_chunk would
        resolved = select_lstm_backend(l0.n_x, l0.n_h, T, B)
    return resolved


def lstm_stack_apply(params: LSTMStackParams, xs: jax.Array,
                     states: Optional[Sequence[Tuple[jax.Array, jax.Array]]] = None,
                     backend: str = 'auto') -> Tuple[jax.Array, list]:
    """Full network: stacked LSTM layers + optional dense read-out (logits, no sigma).

    xs: (T, B, N_x).  Returns (ys (T, B, N_out or N_h), final states per layer).

    ``backend='pallas_seq_fused'`` (or ``auto`` when the stack-level rules
    admit it) runs every layer in ONE fused wavefront launch
    (``kernels.lstm_seq.lstm_stack_seq``) instead of the per-layer loop —
    same contract, output allclose, hidden sequences never round-tripping
    through HBM between layers.  ``backend='pallas_seq_fused_systolic'``
    is the staged scale-out of the same composition
    (``core.systolic.systolic_lstm_stack_seq``, DESIGN.md §9): contiguous
    layer blocks pinned to the installed mesh's ``stage`` axis, the fused
    stack running tile-stationary inside each stage.
    """
    assert backend in BACKENDS, backend
    backend = _resolve_stack_backend(params, backend, xs)
    if backend == 'pallas_seq_fused_systolic':
        from .systolic import current_mesh, systolic_lstm_stack_seq
        h, finals = systolic_lstm_stack_seq(params, current_mesh(), xs,
                                            states)
        finals = list(finals)
    elif backend == 'pallas_seq_fused':
        from ..kernels.lstm_seq import lstm_stack_seq
        h, finals = lstm_stack_seq(params, xs, states)
        finals = list(finals)
    else:
        h = xs
        finals = []
        for l, lp in enumerate(params.layers):
            h0c0 = states[l] if states is not None else (None, None)
            h, (h_T, c_T) = lstm_layer_fused(lp, h, *h0c0, backend=backend)
            finals.append((h_T, c_T))
    if params.w_out is not None:
        h = jnp.einsum('oh,tbh->tbo', params.w_out, h) + params.b_out
    return h, finals


def lstm_stack_chunk(params: LSTMStackParams, xs: jax.Array, states,
                     *, valid_len: Optional[jax.Array] = None,
                     backend: str = 'auto') -> Tuple[jax.Array, tuple]:
    """Stateful chunked stack application — ``lstm_stack_apply`` for serving.

    One chunk of ``T`` frames through every layer, composing the per-layer
    ``(h, c)`` carries (the chip's retained internal state).  The same
    ``valid_len`` masks every layer: a masked step re-emits each layer's
    carried ``h``, so the garbage a padded input frame would produce never
    enters any layer's state and chunked output equals the monolithic
    ``lstm_stack_apply`` on the valid prefix (bit-equal on a fixed backend).
    xs: (T, B, N_x); states: per-layer ``((h, c), ...)`` from the previous
    chunk (or zeros).  Returns (ys (T, B, N_out or N_h), new states).

    On the ``pallas_seq_fused`` backend the whole chunk runs every layer in
    one wavefront launch with the per-layer carries and the shared
    ``valid_len`` mask threaded straight into the kernel — the serving
    engine's packed slot grid rides this path end to end.  On
    ``pallas_seq_fused_systolic`` the same chunked call (carries, shared
    mask) runs the staged scale-out over the installed
    (stage, row, col) mesh — the cross-engine state handoff of DESIGN.md
    §9.
    """
    assert backend in BACKENDS, backend
    backend = _resolve_stack_backend(params, backend, xs)
    if backend == 'pallas_seq_fused_systolic':
        from .systolic import current_mesh, systolic_lstm_stack_seq
        h, finals = systolic_lstm_stack_seq(params, current_mesh(), xs,
                                            states, valid_len=valid_len)
        finals = tuple(finals)
    elif backend == 'pallas_seq_fused':
        from ..kernels.lstm_seq import lstm_stack_seq
        h, finals = lstm_stack_seq(params, xs, states, valid_len=valid_len)
        finals = tuple(finals)
    else:
        h = xs
        finals = []
        for l, lp in enumerate(params.layers):
            h0c0 = states[l] if states is not None else (None, None)
            h, (h_T, c_T) = lstm_layer_chunk(lp, h, *h0c0,
                                             valid_len=valid_len,
                                             backend=backend)
            finals.append((h_T, c_T))
        finals = tuple(finals)
    if params.w_out is not None:
        h = jnp.einsum('oh,tbh->tbo', params.w_out, h) + params.b_out
    return h, finals
