"""Connectionist Temporal Classification loss, in JAX.

The paper's real-world workload (Sec. 4.2) is CTC-3L-421H-UNI from Graves et al. [1]:
a 3-layer, 421-hidden-unit LSTM trained with CTC to emit phonemes. We therefore build
CTC as a first-class substrate piece (log-semiring forward algorithm via ``lax.scan``)
so the end-to-end speech example trains the very network the paper deploys.

Conventions: ``log_probs`` is (T, B, K) log-softmax output, ``labels`` is (B, L) int32
(padded with ``pad_id``), blank index configurable (default 0).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _logaddexp3(a, b, c):
    m = jnp.maximum(jnp.maximum(a, b), c)
    m_safe = jnp.where(m == NEG_INF, 0.0, m)
    # double-where: keep the sum strictly positive on the dead branch so the
    # log's gradient never produces inf * 0 = NaN under the outer select.
    s = jnp.exp(a - m_safe) + jnp.exp(b - m_safe) + jnp.exp(c - m_safe)
    s = jnp.where(m == NEG_INF, 1.0, s)
    return jnp.where(m == NEG_INF, NEG_INF, m_safe + jnp.log(s))


def ctc_loss(log_probs: jax.Array, labels: jax.Array,
             input_lengths: jax.Array, label_lengths: jax.Array,
             blank: int = 0) -> jax.Array:
    """Negative log-likelihood per sequence, shape (B,).

    log_probs: (T, B, K) — log softmax over K classes (blank included).
    labels: (B, L) — no blanks; entries beyond label_lengths are ignored.
    """
    T, B, K = log_probs.shape
    L = labels.shape[1]
    S = 2 * L + 1

    # Extended label sequence: blank, l1, blank, l2, ..., lL, blank.
    ext = jnp.full((B, S), blank, labels.dtype)
    ext = ext.at[:, 1::2].set(labels)
    s_idx = jnp.arange(S)

    # Skip transition alpha[s-2] -> alpha[s] allowed iff ext[s] != blank and
    # ext[s] != ext[s-2] (i.e. distinct consecutive labels).
    ext_m2 = jnp.concatenate([jnp.full((B, 2), -1, ext.dtype), ext[:, :-2]], axis=1)
    can_skip = (ext != blank) & (ext != ext_m2)

    # Positions beyond the true extended length are invalid.
    ext_len = 2 * label_lengths + 1          # (B,)
    valid = s_idx[None, :] < ext_len[:, None]

    def emit(lp_t):  # (B, K) -> (B, S) log prob of each extended symbol at t
        return jnp.take_along_axis(lp_t, ext, axis=1)

    alpha0 = jnp.full((B, S), NEG_INF)
    alpha0 = alpha0.at[:, 0].set(log_probs[0, jnp.arange(B), blank])
    first_lbl = log_probs[0, jnp.arange(B), ext[:, 1]]
    alpha0 = alpha0.at[:, 1].set(jnp.where(label_lengths > 0, first_lbl, NEG_INF))
    alpha0 = jnp.where(valid, alpha0, NEG_INF)

    def step(alpha, t_and_lp):
        t, lp_t = t_and_lp
        shift1 = jnp.concatenate([jnp.full((B, 1), NEG_INF), alpha[:, :-1]], axis=1)
        shift2 = jnp.concatenate([jnp.full((B, 2), NEG_INF), alpha[:, :-2]], axis=1)
        shift2 = jnp.where(can_skip, shift2, NEG_INF)
        new = _logaddexp3(alpha, shift1, shift2) + emit(lp_t)
        new = jnp.where(valid, new, NEG_INF)
        # Freeze alpha for sequences already past their input length.
        active = (t < input_lengths)[:, None]
        return jnp.where(active, new, alpha), None

    ts = jnp.arange(1, T)
    alpha_T, _ = jax.lax.scan(step, alpha0, (ts, log_probs[1:]))

    # Total prob ends at the last blank or last label of each sequence.
    end_blank = jnp.take_along_axis(alpha_T, (ext_len - 1)[:, None], axis=1)[:, 0]
    end_label = jnp.take_along_axis(
        alpha_T, jnp.maximum(ext_len - 2, 0)[:, None], axis=1)[:, 0]
    end_label = jnp.where(label_lengths > 0, end_label, NEG_INF)
    m = jnp.maximum(end_blank, end_label)
    m_safe = jnp.where(m == NEG_INF, 0.0, m)
    s = jnp.exp(end_blank - m_safe) + jnp.exp(end_label - m_safe)
    s = jnp.where(m == NEG_INF, 1.0, s)
    log_z = jnp.where(m == NEG_INF, NEG_INF, m_safe + jnp.log(s))
    return -log_z


def ctc_greedy_decode(log_probs: jax.Array, blank: int = 0
                      ) -> Tuple[jax.Array, jax.Array]:
    """Best-path decode: (T, B, K) -> (collapsed (B, T) padded with -1, lengths)."""
    T, B, _ = log_probs.shape
    best = jnp.argmax(log_probs, axis=-1)          # (T, B)
    best = best.T                                   # (B, T)
    prev = jnp.concatenate([jnp.full((B, 1), -1, best.dtype), best[:, :-1]], axis=1)
    keep = (best != blank) & (best != prev)

    def collapse(row, keep_row):
        idx = jnp.cumsum(keep_row) - 1
        out = jnp.full((T,), -1, row.dtype).at[
            jnp.where(keep_row, idx, T)].set(row, mode='drop')
        return out, keep_row.sum()

    outs, lens = jax.vmap(collapse)(best, keep)
    return outs, lens
