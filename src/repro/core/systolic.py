"""Systolic LSTM execution — Chipmunk contributions C1 + C3.

The paper executes one LSTM on an R x C grid of engines.  Each engine holds a
``tile x tile`` block of the packed 4-gate weight matrix ``W = [W_x | W_h]`` in local
SRAM (weight-stationary).  Per timestep:

  1. the packed input vector ``xh = [x_t | h_{t-1}]`` is split into C column slices,
     each broadcast *down* a column of engines (paper Fig. 3a);
  2. every engine MACs its tile against its column slice (the sequential "column
     loop" of Sec. 3.2, run on 96 parallel row units);
  3. partial sums are accumulated *across* each row of engines in 16-bit saturating
     arithmetic (the systolic hop), finishing at the last column (Fig. 3b);
  4. the finishing column applies the LUT nonlinearities and the element-wise state
     update (Eqs. 1-5) for its row chunk of ``h_t``/``c_t``;
  5. the new ``h_t`` chunks are re-broadcast vertically for the next timestep
     (Fig. 3c).  Only O(N_h) bytes ever cross engine boundaries.

TPU adaptation (see DESIGN.md §2): engines -> mesh devices on ("row", "col") axes;
step 3 -> ``lax.psum`` over "col"; step 5 -> ``lax.all_gather`` over "row".  The
pure-JAX tiled forms below are numerically identical and are what the production
pjit path lowers (XLA emits the same collective schedule from sharding constraints).

Four execution paths, all validated against ``core.lstm.lstm_cell``:
  * ``systolic_cell_tiled``        — float, per-tile partials + row reduction.
  * ``systolic_cell_quantized``    — bit-accurate int8 storage / int16 saturating
                                     hops / LUT activations (contribution C2).
  * ``systolic_lstm_shard_map``    — per-step distributed baseline over an
                                     explicit ("row","col") mesh: one scan step
                                     per timestep, the packed ``[x|h]`` column
                                     re-assembled (and the x-region re-MACed)
                                     every step.
  * ``systolic_lstm_seq``          — the multi-engine scale-out of the
                                     persistent whole-sequence kernel
                                     (DESIGN.md §6): ``W_x @ x`` hoisted out of
                                     the time loop, each device's weight block
                                     tile-stationary for all T steps, per-step
                                     ``psum`` over "col" and ``all_gather`` of
                                     the ``h_t`` chunks over "row".  The int8
                                     form (``systolic_lstm_seq_quantized``)
                                     replays the 16-bit saturating hop in
                                     engine order and is bit-identical to
                                     ``systolic_cell_quantized``.

A process-level mesh registry (``install_mesh`` / ``current_mesh``) lets the
backend dispatch in ``core.lstm`` auto-select the scale-out path whenever a
systolic mesh is installed (``launch/mesh.py`` topology presets).

The STAGED scale-out (``systolic_lstm_stack_seq`` and its int8 twin,
DESIGN.md §9) composes the above with the §8 fused wavefront stack: each
stage of a ``(stage, row, col)`` mesh holds one contiguous layer block
weight-stationary and runs the fused composition with the §6 row/col
dataflow inside the stage, while the hidden-state sequence pipelines across
stages in chunks handed over by ``ppermute`` — the paper's full 3x(5x5)
Table-2 topology (``graves-75``) as one dispatch path.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import AxisType, mesh_with_axis_types, shard_map
from . import quant
from .lstm import GATES, I, F, G, O, PEEP_I, PEEP_F, PEEP_O, LSTMParams

N_LSTM_SILICON = 96  # rows per engine in the fabricated chip


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


# ---------------------------------------------------------------------------
# Process-level systolic mesh registry (DESIGN.md §6)
# ---------------------------------------------------------------------------

_INSTALLED_MESH: Optional[Mesh] = None


def install_mesh(mesh: Mesh) -> Mesh:
    """Register ``mesh`` as the process-wide systolic mesh and return it.

    ``core.lstm.select_lstm_backend`` consults this registry: when an
    installed mesh admits the layer (``seq_scaleout_admissible``), ``auto``
    resolves to the ``pallas_seq_systolic`` backend.  Numerics are unchanged
    by installation — only dispatch is affected.
    """
    global _INSTALLED_MESH
    _INSTALLED_MESH = mesh
    return mesh


def current_mesh() -> Optional[Mesh]:
    """The installed systolic mesh, or None (dispatch then never scales out)."""
    return _INSTALLED_MESH


def clear_mesh() -> None:
    """Uninstall the systolic mesh (dispatch reverts to single-engine rules)."""
    global _INSTALLED_MESH
    _INSTALLED_MESH = None


def seq_scaleout_admissible(n_h: int, mesh: Optional[Mesh], *,
                            n_layers: Optional[int] = None,
                            n_x: int = 0, T: int = 0, batch: int = 0,
                            row_axis: str = 'row', col_axis: str = 'col',
                            stage_axis: str = 'stage',
                            die_axis: str = 'die',
                            vmem_budget: Optional[int] = None) -> bool:
    """Tile-admission rule for the systolic scale-outs (DESIGN.md §6, §9).

    Single-layer form (``n_layers=None``, consulted by per-layer ``auto``
    dispatch for ``systolic_lstm_seq``): True iff ``mesh`` has the two
    systolic axes, no other axis is >1 (a live "stage" axis belongs to the
    stack-level rule below), at least one systolic axis is >1 (an all-1
    mesh degenerates to the single-engine kernel, whose §3.3
    platform/shape rules must keep deciding — interpret-mode emulation
    must never be auto-picked on CPU), and one device's resident block —
    4 gate ``bn x bk`` tiles plus the row slice of peepholes/biases, where
    ``bn = n_h_p/rows`` and ``bk = n_h_p/cols`` — fits the VMEM budget.

    Stage-aware fused form (``n_layers`` given, consulted by
    ``select_stack_backend`` for ``systolic_lstm_stack_seq``): admits the
    STAGED scale-out of the fused stack iff the mesh's ``stage`` axis is
    live (>=2 — a stage-1 mesh is the layerwise §6 rule's domain) but not
    deeper than the stack (an idle stage would only add pipeline bubbles),
    no axis beyond (stage, row, col) is >1, and one device's resident
    layer block — ``ceil(n_layers/stages)`` layers' worth of BOTH weight
    families (``W_h`` and ``W_in`` blocks) plus their peephole/bias rows —
    fits the VMEM budget.  Admission never changes numerics, only whether
    ``auto`` dispatch picks a scale-out backend.

    When shape context (``n_x``/``T``/``batch``) is supplied, the staged
    check sizes the bottleneck stage from a tuned uneven split
    (``resolve_staged_blocks``) instead of the balanced ceiling — the
    tuned ``max(counts)`` is >= the balanced ``ceil(L/S)``, so a tuned
    split can only make admission stricter, never admit a config the
    balanced default would reject on a colder cache.  The guard stays
    authoritative either way.

    Die-aware form (§14): a 4-axis ("die","stage","row","col") fleet mesh
    (``launch.mesh.DieMesh.full_mesh``) is admitted by the staged rule with
    the die axis FOLDED into the pipeline depth — execution always runs on
    the flattened healthy-dies submesh where ``stages = dies * stage``, so
    admission models exactly what dispatch will run.  The single-layer rule
    still rejects any live die axis (a fleet belongs to the staged path).
    """
    if mesh is None:
        return False
    names = mesh.axis_names
    if vmem_budget is None:
        from .lstm import _VMEM_BUDGET_BYTES as vmem_budget
    if n_layers is not None:
        if (row_axis not in names or col_axis not in names
                or stage_axis not in names):
            return False
        if any(mesh.shape[a] > 1 for a in names
               if a not in (row_axis, col_axis, stage_axis, die_axis)):
            return False
        stages = mesh.shape[stage_axis]
        if die_axis in names:
            stages *= mesh.shape[die_axis]
        if stages < 2 or stages > n_layers:
            return False
        mr, mc = mesh.shape[row_axis], mesh.shape[col_axis]
        n_h_p = _round_up(n_h, math.lcm(mr, mc))
        bn, bk = n_h_p // mr, n_h_p // mc
        lb = -(-n_layers // stages)
        tuned = resolve_staged_blocks(n_layers, T, stages, n_h=n_h,
                                      n_x=n_x, batch=batch, mesh=mesh)
        if tuned is not None:
            lb = max(max(tuned), lb)
        per_layer = 2 * GATES * bn * bk * 4 + (3 + GATES) * bn * 4
        return lb * per_layer <= vmem_budget
    try:
        mr, mc = _require_systolic_axes(mesh, row_axis, col_axis)
    except ValueError:
        return False
    if mr == 1 and mc == 1:
        return False
    n_h_p = _round_up(n_h, math.lcm(mr, mc))
    bn, bk = n_h_p // mr, n_h_p // mc
    return GATES * bn * bk * 4 + (3 + GATES) * bn * 4 <= vmem_budget


# ---------------------------------------------------------------------------
# Tiling plan + weight packing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SystolicPlan:
    """Block layout of one LSTM layer on an R x C engine grid.

    The x-region of the packed input is padded to a whole number of tiles so the
    h-region starts tile-aligned: column c < cols_x consumes input-state slices,
    column c >= cols_x consumes hidden-state slices (which is what makes step 5's
    vertical re-broadcast wiring static — "hard-wired" in the paper's words).
    """

    n_x: int
    n_h: int
    tile: int = N_LSTM_SILICON

    @property
    def rows(self) -> int:  # R: output (hidden) chunks
        return math.ceil(self.n_h / self.tile)

    @property
    def cols_x(self) -> int:
        return math.ceil(self.n_x / self.tile)

    @property
    def cols_h(self) -> int:
        return math.ceil(self.n_h / self.tile)

    @property
    def cols(self) -> int:  # C: input chunks
        return self.cols_x + self.cols_h

    @property
    def padded_h(self) -> int:
        return self.rows * self.tile

    @property
    def padded_x(self) -> int:
        return self.cols_x * self.tile

    @property
    def padded_in(self) -> int:
        return self.cols * self.tile

    @property
    def n_engines(self) -> int:
        return self.rows * self.cols

    def weight_bytes_per_engine(self) -> int:
        # 4 gate tiles + row slice of peepholes (3) and biases (4, 16-bit)
        return GATES * self.tile * self.tile + 3 * self.tile + 4 * 2 * self.tile


class PackedLSTM(NamedTuple):
    """Weight tiles in engine layout (a lossless relayout of LSTMParams)."""

    tiles: jax.Array   # (R, C, 4, tile, tile)
    peep: jax.Array    # (R, 3, tile)
    bias: jax.Array    # (R, 4, tile)
    plan_shape: Tuple[int, int, int, int]  # (n_x, n_h, tile, cols_x) — static metadata

    @property
    def plan(self) -> SystolicPlan:
        n_x, n_h, tile, _ = self.plan_shape
        return SystolicPlan(n_x, n_h, tile)


def pack_lstm(params: LSTMParams, plan: SystolicPlan) -> PackedLSTM:
    """Block [W_x | W_h] into (R, C, 4, t, t) engine tiles (zero padding).

    Layout-only and lossless: every downstream execution path over the packed
    form reproduces ``core.lstm.lstm_cell`` on the original parameters.
    """
    t = plan.tile
    w = jnp.zeros((GATES, plan.padded_h, plan.padded_in), params.w_x.dtype)
    w = w.at[:, :params.w_x.shape[1], :plan.n_x].set(params.w_x)
    w = w.at[:, :params.w_h.shape[1], plan.padded_x:plan.padded_x + plan.n_h].set(params.w_h)
    tiles = w.reshape(GATES, plan.rows, t, plan.cols, t).transpose(1, 3, 0, 2, 4)
    peep = jnp.zeros((3, plan.padded_h), params.w_peep.dtype
                     ).at[:, :plan.n_h].set(params.w_peep)
    bias = jnp.zeros((GATES, plan.padded_h), params.b.dtype
                     ).at[:, :plan.n_h].set(params.b)
    return PackedLSTM(
        tiles=tiles,
        peep=peep.reshape(3, plan.rows, t).transpose(1, 0, 2),
        bias=bias.reshape(GATES, plan.rows, t).transpose(1, 0, 2),
        plan_shape=(plan.n_x, plan.n_h, plan.tile, plan.cols_x),
    )


def pack_xh(x: jax.Array, h: jax.Array, plan: SystolicPlan) -> jax.Array:
    """(..., n_x), (..., n_h) -> column blocks (..., C, tile).

    Pure zero-padded relayout (exactly inverted by ``unpack_h`` on the
    h-region); introduces no arithmetic.
    """
    batch = x.shape[:-1]
    xh = jnp.zeros(batch + (plan.padded_in,), x.dtype)
    xh = xh.at[..., :plan.n_x].set(x)
    xh = xh.at[..., plan.padded_x:plan.padded_x + plan.n_h].set(h)
    return xh.reshape(batch + (plan.cols, plan.tile))


def unpack_h(h_blocks: jax.Array, plan: SystolicPlan) -> jax.Array:
    """(..., R, tile) -> (..., n_h): drops the zero padding, no arithmetic."""
    return h_blocks.reshape(h_blocks.shape[:-2] + (plan.padded_h,))[..., :plan.n_h]


# ---------------------------------------------------------------------------
# Float tiled execution (paper dataflow, fp arithmetic)
# ---------------------------------------------------------------------------

def systolic_cell_tiled(packed: PackedLSTM, x_t: jax.Array, h_prev: jax.Array,
                        c_prev_blocks: jax.Array
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One timestep in the systolic dataflow, float arithmetic.

    Numerics contract: allclose to ``core.lstm.lstm_cell`` on the unpacked
    parameters (same math, re-associated per tile).  c_prev_blocks:
    (..., R, tile).  Returns (h_full (..., n_h), h_blocks, c_blocks).
    """
    plan = packed.plan
    xh = pack_xh(x_t, h_prev, plan)                       # steps 1: column slices
    # step 2: per-engine MAC; step 3: row accumulation (sum over c).
    pre = jnp.einsum('rcgij,...cj->...rgi', packed.tiles, xh)
    peep, b = packed.peep, packed.bias
    # step 4: gate nonlinearities + element-wise state update per row chunk.
    i = jax.nn.sigmoid(pre[..., I, :] + peep[:, PEEP_I] * c_prev_blocks + b[:, I])
    f = jax.nn.sigmoid(pre[..., F, :] + peep[:, PEEP_F] * c_prev_blocks + b[:, F])
    g = jnp.tanh(pre[..., G, :] + b[:, G])
    c_t = f * c_prev_blocks + i * g
    o = jax.nn.sigmoid(pre[..., O, :] + peep[:, PEEP_O] * c_t + b[:, O])
    h_blocks = o * jnp.tanh(c_t)
    return unpack_h(h_blocks, plan), h_blocks, c_t       # step 5 done by caller


def systolic_layer_tiled(packed: PackedLSTM, xs: jax.Array) -> jax.Array:
    """Scan the tiled cell over time.  xs: (T, ..., n_x) -> (T, ..., n_h).

    Allclose to ``core.lstm.lstm_layer`` and the float reference for the
    distributed forms (``systolic_lstm_shard_map``, ``systolic_lstm_seq``).
    """
    plan = packed.plan
    batch = xs.shape[1:-1]
    h0 = jnp.zeros(batch + (plan.n_h,), xs.dtype)
    c0 = jnp.zeros(batch + (plan.rows, plan.tile), xs.dtype)

    def step(carry, x_t):
        h, c = carry
        h, _, c = systolic_cell_tiled(packed, x_t, h, c)
        return (h, c), h

    _, hs = jax.lax.scan(step, (h0, c0), xs)
    return hs


# ---------------------------------------------------------------------------
# Bit-accurate quantized execution (contribution C2)
# ---------------------------------------------------------------------------

# Fixed-point layout (see quant.py): weights/states Q2.5 (int8), gates Q0.7 (int8),
# accumulator Q5.10 (int16, saturating at every inter-engine hop).
ACC_FMT = quant.QFormat(int_bits=5, frac_bits=10)
CELL_FMT = quant.QFormat(int_bits=3, frac_bits=12)  # f*c / i*g alignment format


class QuantizedPackedLSTM(NamedTuple):
    """Engine tiles in the silicon's fixed-point formats (see quantize_packed)."""

    tiles_q: jax.Array  # int8 (R, C, 4, t, t)
    peep_q: jax.Array   # int8 (R, 3, t)
    bias_q: jax.Array   # int16 (R, 4, t)  in ACC_FMT
    sig_lut: jax.Array  # int8 (256,)
    tanh_lut: jax.Array  # int8 (256,)
    plan_shape: Tuple[int, int, int, int]

    @property
    def plan(self) -> SystolicPlan:
        n_x, n_h, tile, _ = self.plan_shape
        return SystolicPlan(n_x, n_h, tile)


def quantize_packed(packed: PackedLSTM) -> QuantizedPackedLSTM:
    """Quantize engine tiles to the silicon formats (weights/peep Q2.5 int8,
    biases Q5.10 int16, LUT tables for the activations).  Deterministic
    round-to-nearest; every int8 execution path below consumes exactly these
    codes, so they all share one quantization error budget."""
    wf, sf = quant.WEIGHT_FMT, quant.STATE_FMT
    bias_codes = jnp.clip(
        jnp.round(packed.bias / ACC_FMT.scale),
        -(2 ** 15), 2 ** 15 - 1).astype(jnp.int16)
    sig, tanh = quant.default_luts(sf)
    return QuantizedPackedLSTM(
        tiles_q=quant.quantize(packed.tiles, wf),
        peep_q=quant.quantize(packed.peep, wf),
        bias_q=bias_codes,
        sig_lut=sig, tanh_lut=tanh,
        plan_shape=packed.plan_shape,
    )


def _sat16(x):
    return quant.saturate_int16(x)


_rshift_round = quant.rshift_round


def _quantized_state_update(pre_acc, c_prev32, peep32, bias32, sig_lut,
                            tanh_lut):
    """Silicon elementwise epilogue: gates -> LUTs -> c_t -> h_t, int only.

    Single source of truth for the bit-exact datapath tail: called by the
    per-step ``systolic_cell_quantized`` AND replayed verbatim by the
    distributed ``systolic_lstm_seq_quantized``, so the two stay bit-identical
    by construction.  pre_acc: (..., R, 4, t) int32 in ACC_FMT; c_prev32:
    (..., R, t) int32 codes; peep32: (..., R, 3, t); bias32: (..., R, 4, t).
    Returns (h_blocks8, c_new8), both int8 codes in STATE_FMT.
    """
    def gate(idx, peep_idx, c_term, lut):
        a = pre_acc[..., idx, :] + bias32[..., idx, :]
        if peep_idx is not None:
            a = a + peep32[..., peep_idx, :] * c_term  # Q2.5 * Q2.5, aligned
        a = _sat16(a)
        a8 = _rshift_round(a, ACC_FMT.frac_bits - quant.STATE_FMT.frac_bits)
        a8 = jnp.clip(a8, -128, 127)
        return quant.apply_lut(lut, a8, quant.STATE_FMT).astype(jnp.int32)

    i = gate(I, PEEP_I, c_prev32, sig_lut)
    f = gate(F, PEEP_F, c_prev32, sig_lut)
    g = gate(G, None, None, tanh_lut)

    # c_t = f.c + i.g : align Q0.7*Q2.5 (frac 12) with Q0.7*Q0.7 (frac 14) >> 2.
    fc = f * c_prev32                       # frac 12
    ig = _rshift_round(i * g, 2)            # frac 14 -> 12
    c_new = _sat16(fc + ig)                 # Q3.12
    c_new8 = jnp.clip(_rshift_round(c_new, CELL_FMT.frac_bits -
                                    quant.STATE_FMT.frac_bits), -128, 127)

    o = gate(O, PEEP_O, c_new8, sig_lut)
    tanh_c = quant.apply_lut(tanh_lut, c_new8, quant.STATE_FMT).astype(jnp.int32)
    h_new = _rshift_round(o * tanh_c, 14 - quant.STATE_FMT.frac_bits)
    h_blocks8 = jnp.clip(h_new, -128, 127).astype(jnp.int8)
    return h_blocks8, c_new8.astype(jnp.int8)


def systolic_cell_quantized(qp: QuantizedPackedLSTM, x_q: jax.Array,
                            h_q: jax.Array, c_q_blocks: jax.Array
                            ) -> Tuple[jax.Array, jax.Array]:
    """One timestep in integer arithmetic, per the silicon datapath.

    This is the bit-exactness REFERENCE: ``systolic_layer_quantized``,
    ``kernels.lstm_seq.lstm_layer_seq_quantized`` and
    ``systolic_lstm_seq_quantized`` are all bit-identical to scanning it.
    x_q: (..., n_x) int8 codes (Q2.5); h_q: (..., n_h) int8; c_q_blocks:
    (..., R, t) int8.  Returns (h_q_new, c_q_blocks_new).  All intermediate
    semantics follow the 16-bit saturating accumulator of the chip.
    """
    plan = qp.plan
    xh_q = pack_xh(x_q, h_q, plan)  # (..., C, t) int8

    # Per-engine tile MAC in wide arithmetic (int32), then saturate to 16 bit —
    # the value an engine hands to its row neighbour.
    partials = jnp.einsum('rcgij,...cj->...rcgi', qp.tiles_q.astype(jnp.int32),
                          xh_q.astype(jnp.int32))
    partials = _sat16(partials)

    # Sequential saturating row accumulation (hop order matters for saturation).
    def hop(acc, p_c):
        return _sat16(acc + p_c), None

    partials_c_first = jnp.moveaxis(partials, -3, 0)  # (C, ..., R, 4, t)
    acc0 = jnp.zeros(partials_c_first.shape[1:], jnp.int32)
    pre_acc, _ = jax.lax.scan(hop, acc0, partials_c_first)  # (..., R, 4, t) Q5.10

    h_blocks8, c_new8 = _quantized_state_update(
        pre_acc, c_q_blocks.astype(jnp.int32), qp.peep_q.astype(jnp.int32),
        qp.bias_q.astype(jnp.int32), qp.sig_lut, qp.tanh_lut)
    return unpack_h(h_blocks8, plan), c_new8


def systolic_layer_quantized(qp: QuantizedPackedLSTM, xs_q: jax.Array) -> jax.Array:
    """Scan the integer cell over time.  xs_q: (T, ..., n_x) int8 -> int8 hidden.

    Bit-identical by construction to ``systolic_cell_quantized`` stepped with
    zero initial state; the whole-sequence and distributed int8 forms are
    tested against this function.
    """
    plan = qp.plan
    batch = xs_q.shape[1:-1]
    h0 = jnp.zeros(batch + (plan.n_h,), jnp.int8)
    c0 = jnp.zeros(batch + (plan.rows, plan.tile), jnp.int8)

    def step(carry, x_t):
        h, c = carry
        h, c = systolic_cell_quantized(qp, x_t, h, c)
        return (h, c), h

    _, hs = jax.lax.scan(step, (h0, c0), xs_q)
    return hs


# ---------------------------------------------------------------------------
# Distributed execution: shard_map over an explicit ("row","col") mesh
# ---------------------------------------------------------------------------

def make_systolic_mesh(rows: int, cols: int, stage: int = 1,
                       devices=None) -> Mesh:
    """Build a (stage, row, col) mesh from the first stage*rows*cols devices.

    This is how the paper's own geometries (5x5, 3x(5x5)) are laid onto a pod:
    a rectangular sub-grid of the available chips.  Device order is row-major,
    which is what makes the ``all_gather`` chunk order of the distributed
    paths line up with the engine-tile row order (a pure layout guarantee —
    no numerics of its own).  ``launch/mesh.py`` exposes named topology
    presets (including ``graves-75``) built on this constructor.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    need = stage * rows * cols
    if len(devices) < need:
        raise ValueError(f'need {need} devices, have {len(devices)}')
    arr = np.array(devices[:need]).reshape(stage, rows, cols)
    return mesh_with_axis_types(arr, ('stage', 'row', 'col'),
                                axis_types=(AxisType.Auto,) * 3)


def shard_packed_lstm(packed: PackedLSTM, mesh: Mesh) -> PackedLSTM:
    """Place weight tiles so engine (r, c) owns tile (r, c) — weight-stationary.

    Pure placement (device_put with a NamedSharding); values are unchanged.
    """
    from jax.sharding import NamedSharding
    tiles = jax.device_put(packed.tiles, NamedSharding(mesh, P('row', 'col')))
    peep = jax.device_put(packed.peep, NamedSharding(mesh, P('row')))
    bias = jax.device_put(packed.bias, NamedSharding(mesh, P('row')))
    return PackedLSTM(tiles, peep, bias, packed.plan_shape)


def systolic_lstm_shard_map(packed: PackedLSTM, mesh: Mesh, xs: jax.Array,
                            row_axis: str = 'row', col_axis: str = 'col'):
    """PER-STEP distributed baseline with the paper's communication pattern.

    Allclose to scanning ``systolic_cell_tiled`` (float re-association only).
    Every timestep re-assembles the packed ``[x|h]`` column and re-MACs the
    x-region against its weight columns — the per-step streaming cost the
    persistent ``systolic_lstm_seq`` (DESIGN.md §6) eliminates by hoisting
    ``W_x @ x`` out of the loop.  Kept as the scale-out benchmark baseline.

    xs: (T, B, padded_in) — the x-region columns carry data, h-region columns are
    zero (they are overwritten by the vertical h re-broadcast each step).
    Requires plan.rows == mesh row size and plan.cols == mesh col size.
    """
    plan = packed.plan
    t = plan.tile
    T, B = xs.shape[0], xs.shape[1]
    assert xs.shape[2] == plan.padded_in
    assert mesh.shape[row_axis] == plan.rows and mesh.shape[col_axis] == plan.cols

    def local_step(w_tile, peep_r, bias_r, xh_col, h_full, c_row):
        """SPMD body on engine (r, c).

        w_tile: (4, t, t); peep_r: (3, t); bias_r: (4, t); xh_col: (B, t);
        h_full: (B, padded_h) — replicated; c_row: (B, t).
        """
        c_idx = jax.lax.axis_index(col_axis)
        # h-region columns take their slice of the re-broadcast hidden state.
        h_off = jnp.maximum(c_idx - plan.cols_x, 0) * t
        h_slice = jax.lax.dynamic_slice(h_full, (0, h_off), (B, t))
        col_in = jnp.where(c_idx < plan.cols_x, xh_col, h_slice)

        partial = jnp.einsum('gij,bj->bgi', w_tile, col_in)       # column loop
        pre = jax.lax.psum(partial, col_axis)                      # row hops
        i = jax.nn.sigmoid(pre[:, I] + peep_r[PEEP_I] * c_row + bias_r[I])
        f = jax.nn.sigmoid(pre[:, F] + peep_r[PEEP_F] * c_row + bias_r[F])
        g = jnp.tanh(pre[:, G] + bias_r[G])
        c_new = f * c_row + i * g
        o = jax.nn.sigmoid(pre[:, O] + peep_r[PEEP_O] * c_new + bias_r[O])
        h_new = o * jnp.tanh(c_new)
        # Vertical re-broadcast of the updated hidden state (paper Fig. 3c).
        h_full_new = jax.lax.all_gather(h_new, row_axis, axis=1, tiled=True)
        return h_full_new, c_new

    def sharded_scan(tiles, peep, bias, xs_sharded):
        w_tile = tiles[0, 0]          # local block after sharding
        peep_r, bias_r = peep[0], bias[0]
        h0 = jnp.zeros((B, plan.padded_h), xs.dtype)
        c0 = jnp.zeros((B, t), xs.dtype)

        def step(carry, x_t):
            h_full, c_row = carry
            h_full, c_row = local_step(w_tile, peep_r, bias_r, x_t, h_full, c_row)
            return (h_full, c_row), h_full

        (_, _), hs = jax.lax.scan(step, (h0, c0), xs_sharded)
        return hs

    other_axes = tuple(n for n in mesh.axis_names if n not in (row_axis, col_axis))
    if any(mesh.shape[a] > 1 for a in other_axes):
        raise ValueError('use systolic_pipeline for meshes with a stage axis')
    fn = shard_map(
        sharded_scan, mesh=mesh,
        in_specs=(P(row_axis, col_axis), P(row_axis), P(row_axis),
                  P(None, None, col_axis)),
        out_specs=P(),
        check_vma=False,
    )
    hs = fn(packed.tiles, packed.peep, packed.bias, xs)
    return hs[..., :plan.n_h]


# ---------------------------------------------------------------------------
# Multi-engine scale-out of the persistent sequence kernel (DESIGN.md §6)
# ---------------------------------------------------------------------------

def _require_systolic_axes(mesh: Mesh, row_axis: str, col_axis: str) -> Tuple[int, int]:
    names = mesh.axis_names
    if row_axis not in names or col_axis not in names:
        raise ValueError(f'mesh axes {names} lack ({row_axis!r}, {col_axis!r})')
    if any(mesh.shape[a] > 1 for a in names if a not in (row_axis, col_axis)):
        raise ValueError('use systolic_pipeline for meshes with a stage axis')
    return mesh.shape[row_axis], mesh.shape[col_axis]


def _scaleout_blocks(n_h: int, mr: int, mc: int) -> Tuple[int, int, int]:
    """Pad N_h so both the row (output) and col (reduction) axes divide it."""
    n_h_p = _round_up(n_h, math.lcm(mr, mc))
    return n_h_p, n_h_p // mr, n_h_p // mc


def _scaleout_forward(static, w_h, w_peep, b, pre_x, h0, c0, mask=None):
    """Distributed whole-sequence forward (padded in, un-padded out).

    Numerics contract: allclose to scanning ``systolic_cell_tiled`` (and to
    ``core.lstm.lstm_layer``) — same per-block partial sums, with the "col"
    reduction performed by ``lax.psum`` instead of the einsum contraction.
    ``mask``: optional (T, B) validity mask (replicated); a masked step is
    identity on the carried state via ``jnp.where`` — no arithmetic on the
    carried values, so ``None`` and an all-ones mask are bit-identical.
    """
    mesh, row_axis, col_axis = static
    T, B, _, n_h = pre_x.shape
    mr, mc = mesh.shape[row_axis], mesh.shape[col_axis]
    n_h_p, bn, bk = _scaleout_blocks(n_h, mr, mc)
    pad = n_h_p - n_h

    w_p = jnp.pad(w_h, ((0, 0), (0, pad), (0, pad)))
    peep_p = jnp.pad(w_peep, ((0, 0), (0, pad)))
    bias_p = jnp.pad(b, ((0, 0), (0, pad)))
    pre_p = jnp.pad(pre_x, ((0, 0), (0, 0), (0, 0), (0, pad)))
    h0_p = jnp.pad(h0, ((0, 0), (0, pad)))
    c0_p = jnp.pad(c0, ((0, 0), (0, pad)))
    if mask is None:
        mask = jnp.ones((T, B), jnp.bool_)

    def body(w_blk, peep_blk, bias_blk, pre_blk, h0_full, c0_blk, mask_t):
        """SPMD body on engine-block (r, c).

        w_blk: (4, bn, bk) — tile-stationary for all T steps (the scan closes
        over it, so it is fetched once and revisited every timestep);
        pre_blk: (T, B, 4, bn) hoisted ``W_x @ x`` stream for row block r;
        mask_t: (T, B) replicated validity mask.
        """
        col = jax.lax.axis_index(col_axis)

        def step(carry, inp):
            h_full, c = carry
            pre_t, m = inp
            # Fig. 3a: this engine column consumes its static h-slice.
            h_k = jax.lax.dynamic_slice(h_full, (0, col * bk), (B, bk))
            part = jnp.einsum('gnk,bk->bgn', w_blk, h_k)
            # Fig. 3b: row accumulation of partial sums across engine columns.
            pre = jax.lax.psum(part, col_axis) + pre_t
            i = jax.nn.sigmoid(pre[:, I] + peep_blk[PEEP_I] * c + bias_blk[I])
            f = jax.nn.sigmoid(pre[:, F] + peep_blk[PEEP_F] * c + bias_blk[F])
            g = jnp.tanh(pre[:, G] + bias_blk[G])
            c_new = f * c + i * g
            o = jax.nn.sigmoid(pre[:, O] + peep_blk[PEEP_O] * c_new + bias_blk[O])
            h_new = o * jnp.tanh(c_new)
            # Fig. 3c: vertical re-broadcast of the updated hidden chunks.
            h_full_new = jax.lax.all_gather(h_new, row_axis, axis=1, tiled=True)
            # Masked step = identity on the carried state (ragged serving).
            m = m[:, None]
            h_full_new = jnp.where(m, h_full_new, h_full)
            c_new = jnp.where(m, c_new, c)
            return (h_full_new, c_new), (h_full_new, c_new)

        (h_T, c_T), (hs, cs) = jax.lax.scan(step, (h0_full, c0_blk),
                                            (pre_blk, mask_t))
        return hs, cs, h_T, c_T

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, row_axis, col_axis), P(None, row_axis),
                  P(None, row_axis), P(None, None, None, row_axis),
                  P(None, None), P(None, row_axis), P(None, None)),
        out_specs=(P(), P(None, None, row_axis), P(), P(None, row_axis)),
        check_vma=False,
    )
    hs, cs, h_T, c_T = fn(w_p, peep_p, bias_p, pre_p, h0_p, c0_p, mask)
    return hs[..., :n_h], cs[..., :n_h], h_T[..., :n_h], c_T[..., :n_h]


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def systolic_seq_fused(static, w_h, w_peep, b, pre_x, h0, c0):
    """Distributed whole-sequence LSTM with the production training VJP.

    Same contract as ``kernels.lstm_seq.lstm_seq_fused`` (forward allclose to
    ``core.lstm.lstm_scan_fused``; backward recomputes gates from the saved
    h/c trajectories via ``lstm_bwd_recompute_gates``), but the forward runs
    tile-stationary on the ``static = (mesh, row_axis, col_axis)`` grid.
    """
    hs, _, h_T, c_T = _scaleout_forward(static, w_h, w_peep, b, pre_x, h0, c0)
    return hs, (h_T, c_T)


def _sso_fwd(static, w_h, w_peep, b, pre_x, h0, c0):
    hs, cs, h_T, c_T = _scaleout_forward(static, w_h, w_peep, b, pre_x, h0, c0)
    return (hs, (h_T, c_T)), (w_h, w_peep, b, pre_x, hs, cs, h0, c0)


def _sso_bwd(static, res, grads):
    from .lstm import lstm_bwd_recompute_gates
    w_h, w_peep, b, pre_x, hs, cs, h0, c0 = res
    return lstm_bwd_recompute_gates(w_h, w_peep, b, pre_x, hs, cs, h0, c0,
                                    grads)


systolic_seq_fused.defvjp(_sso_fwd, _sso_bwd)


def systolic_lstm_seq(params: LSTMParams, mesh: Optional[Mesh], xs: jax.Array,
                      h0: Optional[jax.Array] = None,
                      c0: Optional[jax.Array] = None, *,
                      valid_len: Optional[jax.Array] = None,
                      row_axis: str = 'row', col_axis: str = 'col'
                      ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Whole-sequence persistent LSTM, scaled out over a systolic mesh.

    Drop-in for ``core.lstm.lstm_layer`` (xs: (T, B, N_x) -> (hs, (h_T, c_T)));
    output allclose to scanning ``systolic_cell_tiled`` and to ``lstm_layer``.
    Differentiable: the custom VJP recomputes gates from the h/c trajectories
    (identical to the ``pallas_seq`` backend's training path).

    The non-recurrent ``W_x @ x`` is hoisted out of the time loop as one wide
    matmul over the whole utterance; inside the loop each device MACs only its
    resident ``bn x bk`` recurrent block, row partials meet in a per-step
    ``psum`` over ``col_axis`` (Fig. 3b) and the updated ``h_t`` chunks are
    re-broadcast with ``all_gather`` over ``row_axis`` (Fig. 3c).  A ``None``
    or all-1 mesh degenerates to the single-engine Pallas sequence kernel
    (``kernels.lstm_seq.lstm_layer_seq``) — the composition this function
    scales out.

    ``valid_len``: optional (B,) per-stream valid lengths for ragged chunked
    serving (DESIGN.md §7) — steps ``t >= valid_len[b]`` are identity on the
    carried state, so ``(h_T, c_T)`` is the state after exactly
    ``valid_len[b]`` steps.  The masked path is inference-only (no VJP).
    """
    assert xs.ndim == 3, 'systolic_lstm_seq expects (T, B, N_x) input'
    T, B = xs.shape[0], xs.shape[1]
    n_h = params.n_h
    if h0 is None:
        h0 = jnp.zeros((B, n_h), xs.dtype)
    if c0 is None:
        c0 = jnp.zeros((B, n_h), xs.dtype)
    if mesh is None or all(s == 1 for s in mesh.shape.values()):
        from ..kernels.lstm_seq import lstm_layer_seq
        return lstm_layer_seq(params, xs, h0, c0, valid_len=valid_len)
    _require_systolic_axes(mesh, row_axis, col_axis)
    pre_x = jnp.einsum('ghx,tbx->tbgh', params.w_x, xs)   # hoisted input stream
    static = (mesh, row_axis, col_axis)
    if valid_len is not None:
        from .lstm import valid_len_mask
        mask = valid_len_mask(T, valid_len, B)
        hs, cs, h_T, c_T = _scaleout_forward(static, params.w_h,
                                             params.w_peep, params.b,
                                             pre_x, h0, c0, mask)
        return hs, (h_T, c_T)
    return systolic_seq_fused(static, params.w_h,
                              params.w_peep, params.b, pre_x, h0, c0)


def _x_prefix_fold(tiles_x: jax.Array, xcols: jax.Array) -> jax.Array:
    """Raw-array core of ``quantized_x_prefix``: per-tile int32 MACs
    saturated to int16, then the sequential engine-order hop over the
    x-region columns.  Single source of truth for the h-independent prefix
    of the saturating chain — ``quantized_x_prefix`` (host-side hoisting)
    and the staged scale-out's in-body below-region fold
    (``systolic_lstm_stack_seq_quantized``) both call it, so every
    consumer replays the identical saturation/hop order.  tiles_x:
    (R, C_x, 4, t, t) int8; xcols: (T, B, C_x, t) int8 ->
    (T, B, R, 4, t) int32 in ACC_FMT."""
    part_x = _sat16(jnp.einsum('rcgij,tbcj->ctbrgi',
                               tiles_x.astype(jnp.int32),
                               xcols.astype(jnp.int32)))

    def hop(acc, p):
        return _sat16(acc + p), None

    acc0 = jnp.zeros(part_x.shape[1:], jnp.int32)
    acc_x, _ = jax.lax.scan(hop, acc0, part_x)
    return acc_x


def quantized_x_prefix(qp: QuantizedPackedLSTM, xs_q: jax.Array) -> jax.Array:
    """Hoisted x-region prefix of the saturating hop chain — the first
    ``cols_x`` hops, which depend only on the frame stream, computed once
    for the whole sequence (the shared ``_x_prefix_fold``).  Bit-identical
    to folding those columns inside the step loop (the same ops in the same
    order), so every consumer — the §6 distributed form, the §8
    fused-stack kernel's layer 0, AND the §9 staged scale-out — resumes
    the chain from exactly the state the silicon would hold.
    xs_q: (T, B, n_x) int8 codes -> (T, B, R, 4, tile) int32 in ACC_FMT."""
    plan = qp.plan
    T, B = xs_q.shape[0], xs_q.shape[1]
    if not plan.cols_x:
        return jnp.zeros((T, B, plan.rows, GATES, plan.tile), jnp.int32)
    xs_pad = jnp.zeros((T, B, plan.padded_x), jnp.int8
                       ).at[..., :plan.n_x].set(xs_q)
    xcols = xs_pad.reshape(T, B, plan.cols_x, plan.tile)
    return _x_prefix_fold(qp.tiles_q[:, :plan.cols_x], xcols)


def systolic_lstm_seq_quantized(qp: QuantizedPackedLSTM, mesh: Optional[Mesh],
                                xs_q: jax.Array, *,
                                state: Optional[Tuple[jax.Array, jax.Array]] = None,
                                valid_len: Optional[jax.Array] = None,
                                return_state: bool = False,
                                row_axis: str = 'row',
                                col_axis: str = 'col'):
    """Distributed whole-sequence int8 LSTM, bit-identical to the silicon scan.

    xs_q: (T, B, n_x) int8 codes -> (T, B, n_h) int8 hidden codes, exactly
    equal (bit-identical) to scanning ``systolic_cell_quantized`` — and hence
    to ``systolic_layer_quantized`` and ``lstm_layer_seq_quantized``.

    The 16-bit saturating row accumulation (Fig. 3b) is order-sensitive, so a
    plain ``psum`` cannot be used: the x-region prefix of the hop chain (which
    does not depend on ``h``) is precomputed once for the whole sequence, and
    per step each device's h-region tile partials are ``all_gather``ed over
    ``col_axis`` and the hop replayed in engine order — the exact saturation
    schedule of the chip.  Requires ``plan.rows % mesh rows == 0`` and
    ``plan.cols_h % mesh cols == 0``.  A ``None``/all-1 mesh degenerates to
    ``kernels.lstm_seq.lstm_layer_seq_quantized``.

    Chunked streaming (DESIGN.md §7, same contract as the single-engine int8
    kernel): ``state`` is an opaque carry of ``(h_q, c_q)`` padded-layout
    int8 codes from a previous call with ``return_state=True`` (None = zero
    state); ``valid_len`` (B,) masks ragged tail steps per stream — a masked
    step is a pure select identity on the carried codes — so feeding a
    sequence chunk by chunk over the mesh is bit-identical to the monolithic
    call, and the §6 scale-out composes with the streaming engine.  With
    ``return_state=True`` returns ``(hs, (h_q, c_q))``.
    """
    plan = qp.plan
    if mesh is None or all(s == 1 for s in mesh.shape.values()):
        from ..kernels.lstm_seq import lstm_layer_seq_quantized
        return lstm_layer_seq_quantized(qp, xs_q, state=state,
                                        valid_len=valid_len,
                                        return_state=return_state)
    mr, mc = _require_systolic_axes(mesh, row_axis, col_axis)
    R, c_h, t = plan.rows, plan.cols_h, plan.tile
    if R % mr or c_h % mc:
        raise ValueError(f'engine grid {R}x{c_h} (h-region) does not divide '
                         f'mesh {mr}x{mc}')
    assert xs_q.ndim == 3, 'systolic_lstm_seq_quantized expects (T, B, n_x)'
    T, B = xs_q.shape[0], xs_q.shape[1]
    r_l, c_l = R // mr, c_h // mc
    if state is None:
        h0_q = jnp.zeros((B, plan.padded_h), jnp.int8)
        c0_q = jnp.zeros((B, plan.padded_h), jnp.int8)
    else:
        h0_q = state[0].reshape(B, plan.padded_h)
        c0_q = state[1].reshape(B, plan.padded_h)
    if valid_len is None:
        mask = jnp.ones((T, B), jnp.int8)
    else:
        from .lstm import valid_len_mask
        mask = valid_len_mask(T, valid_len, B).astype(jnp.int8)

    def hop(acc, p):
        return _sat16(acc + p), None

    acc_x = quantized_x_prefix(qp, xs_q)
    tiles_h = qp.tiles_q[:, plan.cols_x:]            # (R, c_h, 4, t, t)

    def body(tiles_blk, peep_blk, bias_blk, accx_blk, sig_lut, tanh_lut,
             h0_full, c0_blk, mask_t):
        """SPMD body: tiles_blk (r_l, c_l, 4, t, t) stationary for all T.

        h0_full: (B, padded_h) replicated carried codes; c0_blk: (B, r_l*t)
        this row block's carried cell codes; mask_t: (T, B) replicated.
        """
        col = jax.lax.axis_index(col_axis)
        peep32 = peep_blk.astype(jnp.int32)
        bias32 = bias_blk.astype(jnp.int32)

        def step(carry, inp):
            h_full, c_blk = carry
            accx_t, m = inp
            h_cols = jax.lax.dynamic_slice(
                h_full, (0, col * (c_l * t)), (B, c_l * t)).reshape(B, c_l, t)
            parts = _sat16(jnp.einsum('rlgij,blj->lbrgi',
                                      tiles_blk.astype(jnp.int32),
                                      h_cols.astype(jnp.int32)))
            # Engine-order saturating hop replay: gather every column's
            # partials, then fold them sequentially from the x-prefix.
            parts_all = jax.lax.all_gather(parts, col_axis, axis=0, tiled=True)
            pre_acc, _ = jax.lax.scan(hop, accx_t, parts_all)
            h8, c8 = _quantized_state_update(pre_acc, c_blk.astype(jnp.int32),
                                             peep32, bias32, sig_lut, tanh_lut)
            h_flat = h8.reshape(B, r_l * t)
            h_full_new = jax.lax.all_gather(h_flat, row_axis, axis=1,
                                            tiled=True)
            # Masked step = identity on the carried codes (pure select, so
            # an all-ones mask is bit-identical to the unmasked chain).
            live = (m > 0)[:, None]
            h_full_new = jnp.where(live, h_full_new, h_full)
            c8 = jnp.where(live[:, :, None], c8, c_blk)
            return (h_full_new, c8), (h_full_new, c8)

        c0 = c0_blk.reshape(B, r_l, t)
        _, (hs, cs) = jax.lax.scan(step, (h0_full, c0), (accx_blk, mask_t))
        return hs, cs.reshape(T, B, r_l * t)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(row_axis, col_axis), P(row_axis), P(row_axis),
                  P(None, None, row_axis), P(None), P(None),
                  P(None, None), P(None, row_axis), P(None, None)),
        out_specs=(P(), P(None, None, row_axis)),
        check_vma=False,
    )
    hs, cs = fn(tiles_h, qp.peep_q, qp.bias_q, acc_x, qp.sig_lut,
                qp.tanh_lut, h0_q, c0_q, mask)
    if not return_state:
        return hs[..., :plan.n_h]
    return hs[..., :plan.n_h], (hs[-1], cs[-1])


# ---------------------------------------------------------------------------
# Staged systolic scale-out of the fused wavefront stack (DESIGN.md §9):
# contiguous layer blocks pinned to the mesh "stage" axis, chunks of the
# hidden-state sequence pipelined stage to stage via ppermute — the paper's
# 3x(5x5) Table-2 topology as ONE dispatch path.
# ---------------------------------------------------------------------------

def stage_layer_blocks(n_layers: int, n_stages: int,
                       blocks: Optional[Sequence[int]] = None
                       ) -> Tuple[Tuple[int, int], ...]:
    """Contiguous layer placement on the stage axis: stage ``s`` owns
    layers ``[lo, hi)``.

    Default (``blocks=None``) is the balanced split: block sizes differ by
    at most one, ceil-sized blocks first — 3 layers on 2 stages place
    layers {0, 1} on stage 0 and {2} on stage 1.  With ``n_stages >
    n_layers`` the TRAILING stages get empty blocks (the ceil-first order
    puts every layer before them); this is the passthrough-delay contract:
    an empty stage hands its input chunk through unchanged and carries no
    state — it adds one macro-step of pipeline delay per empty stage but
    no arithmetic, so trajectories are unchanged (pure schedule).

    ``blocks`` overrides the balanced split with explicit per-stage layer
    COUNTS (the uneven-split geometry the tuner shmoos): it must have
    exactly ``n_stages`` non-negative entries summing to ``n_layers``.
    Any valid split is schedule-only — same per-layer dataflow, same
    chunk handoffs — so uneven splits are bit-equal to the balanced
    default on a fixed (rows, cols) grid.

    Raises ``ValueError`` on non-positive ``n_layers``/``n_stages`` or an
    inconsistent override (silently accepting them used to produce
    nonsense geometry downstream).  Pure geometry; no numerics of its own.
    """
    if n_layers < 1 or n_stages < 1:
        raise ValueError(
            f'stage_layer_blocks needs n_layers >= 1 and n_stages >= 1, '
            f'got n_layers={n_layers}, n_stages={n_stages}')
    if blocks is None:
        base, rem = divmod(n_layers, n_stages)
        sizes = [base + (1 if s_i < rem else 0) for s_i in range(n_stages)]
    else:
        sizes = [int(s) for s in blocks]
        if len(sizes) != n_stages:
            raise ValueError(f'blocks override has {len(sizes)} entries '
                             f'for {n_stages} stages')
        if any(s < 0 for s in sizes):
            raise ValueError(f'blocks override has negative entries: {sizes}')
        if sum(sizes) != n_layers:
            raise ValueError(f'blocks override {sizes} places {sum(sizes)} '
                             f'layers, stack has {n_layers}')
    out, lo = [], 0
    for size in sizes:
        out.append((lo, lo + size))
        lo += size
    return tuple(out)


def _require_staged_axes(mesh: Mesh, stage_axis: str, row_axis: str,
                         col_axis: str) -> Tuple[int, int, int]:
    """Axis check for the staged scale-out: the three named axes must exist
    and every other axis must be 1.  Returns (stages, rows, cols)."""
    names = mesh.axis_names
    for a in (stage_axis, row_axis, col_axis):
        if a not in names:
            raise ValueError(f'mesh axes {names} lack {a!r}')
    if any(mesh.shape[a] > 1 for a in names
           if a not in (stage_axis, row_axis, col_axis)):
        raise ValueError('staged scale-out uses only (stage, row, col) axes')
    return (mesh.shape[stage_axis], mesh.shape[row_axis],
            mesh.shape[col_axis])


def _stage_stack(x: jax.Array, blocks, n_stages: int, lb: int) -> jax.Array:
    """Relayout per-layer arrays (L, ...) into per-stage slots
    (S, Lb, ...), zero-padding slots past each stage's block (their live
    flags mask them to pure passthrough).  Layout only — no arithmetic."""
    out = jnp.zeros((n_stages, lb) + x.shape[1:], x.dtype)
    for s_i, (lo, hi) in enumerate(blocks):
        if hi > lo:
            out = out.at[s_i, :hi - lo].set(x[lo:hi])
    return out


def _stage_live(blocks, n_stages: int, lb: int) -> jax.Array:
    """Per-(stage, slot) liveness flags matching ``_stage_stack``'s
    padding (1.0 = a real layer, 0.0 = a passthrough slot)."""
    live = np.zeros((n_stages, lb), np.float32)
    for s_i, (lo, hi) in enumerate(blocks):
        live[s_i, :hi - lo] = 1.0
    return jnp.asarray(live)


def _stage_of(blocks, layer: int) -> Tuple[int, int]:
    """(stage index, slot index) of a global layer under ``blocks``."""
    for s_i, (lo, hi) in enumerate(blocks):
        if lo <= layer < hi:
            return s_i, layer - lo
    raise ValueError(f'layer {layer} outside {blocks}')


def _staged_schedule(n_layers: int, T: int, n_stages: int,
                     chunk: Optional[int],
                     blocks: Optional[Sequence[int]] = None):
    """The one source of the staged pipeline geometry, shared by the f32
    and int8 wrappers so their schedules (and hence the cross-engine state
    handoff) cannot desynchronize: chunk default ``ceil(T / (4*stages))``
    (fill/drain stays under ~1/4 of macro-steps; chunk=1 is the paper's
    frame-by-frame handover), ``K`` chunks padding T to ``T_p``, ``M = K +
    S - 1`` macro-steps, the contiguous layer blocks (balanced, or the
    explicit per-stage counts of an uneven split — schedule-only either
    way) and the slot count.  Returns (Tc, K, T_p, M, blocks, Lb)."""
    if chunk is None:
        chunk = max(1, -(-T // (4 * n_stages)))
    Tc = min(int(chunk), T)
    K = -(-T // Tc)
    blocks = stage_layer_blocks(n_layers, n_stages, blocks)
    Lb = max(1, max(hi - lo for lo, hi in blocks))
    return Tc, K, K * Tc, K + n_stages - 1, blocks, Lb


#: Legal in-stage schedules for the staged backend: ``'batched'`` walks the
#: chunk's (slot, step) grid diagonal-major — one slot-batched dot per
#: diagonal, ``Tc + Lb - 1`` rounds per macro-step — while ``'sequential'``
#: (the PR 5 dataflow) runs the layer block slot by slot, ``Lb * Tc`` rounds.
#: Both orders produce bit-equal f32 / bit-identical int8 trajectories; the
#: choice is schedule-only.
IN_STAGE_MODES = ('batched', 'sequential')


def resolve_staged_chunk(n_layers: int, T: int, n_stages: int, *,
                         n_h: int = 0, n_x: int = 0, batch: int = 0,
                         mesh: Optional[Mesh] = None,
                         kind: str = 'stack_f32') -> int:
    """Chunk depth ``Tc`` the staged wrappers will use when the caller
    passes ``chunk=None``: a measured winner from the installed schedule
    cache (``repro.tune``) when one matches this ``(shape, mesh)``, else
    the hand-derived ``_staged_schedule`` default ``ceil(T / (4*stages))``.
    Selection only — the returned depth changes the pipeline schedule, not
    the numerics (chunked and monolithic trajectories are bit-equal, see
    ``systolic_lstm_stack_seq``)."""
    from ..tune.schedule import current_schedule_cache, mesh_signature
    cache = current_schedule_cache()
    if cache is not None:
        ent = cache.lookup(kind, n_x=n_x, n_h=n_h, n_layers=n_layers,
                           T=T, B=batch, mesh=mesh_signature(mesh))
        if ent is not None and ent.tc:
            return min(int(ent.tc), T)
    return _staged_schedule(n_layers, T, n_stages, None)[0]


def resolve_staged_in_stage(n_layers: int, T: int, n_stages: int, *,
                            n_h: int = 0, n_x: int = 0, batch: int = 0,
                            mesh: Optional[Mesh] = None,
                            kind: str = 'stack_f32') -> str:
    """In-stage round order the staged wrappers use when the caller passes
    ``in_stage=None``: the measured winner from the installed schedule
    cache for this ``(shape, mesh)`` when one exists, else ``'batched'``
    (the ``Tc + Lb - 1``-round diagonal order — the silicon's dataflow).
    Selection only: both orders are bit-equal f32 / bit-identical int8
    (``IN_STAGE_MODES``), so the cache can only pick among proven-
    identical schedules.  The measured choice matters because the orders
    optimise for different hosts: batched wins where stages' slots truly
    run concurrently (real multi-core / the silicon), sequential's hoisted
    wide below-GEMMs win on FLOP-bound single-core emulation."""
    from ..tune.schedule import current_schedule_cache, mesh_signature
    cache = current_schedule_cache()
    if cache is not None:
        ent = cache.lookup(kind, n_x=n_x, n_h=n_h, n_layers=n_layers,
                           T=T, B=batch, mesh=mesh_signature(mesh))
        if ent is not None and ent.in_stage in IN_STAGE_MODES:
            return ent.in_stage
    return 'batched'


def resolve_staged_blocks(n_layers: int, T: int, n_stages: int, *,
                          n_h: int = 0, n_x: int = 0, batch: int = 0,
                          mesh: Optional[Mesh] = None,
                          kind: str = 'stack_f32'
                          ) -> Optional[Tuple[int, ...]]:
    """Per-stage layer COUNTS the staged wrappers use when the caller
    passes ``blocks=None``: the tuned uneven split from the installed
    schedule cache for this ``(shape, mesh)`` when one exists (the
    geometry tuner's ``blocks='2,1'``-style field), else None (the
    balanced ``stage_layer_blocks`` default).  Selection only — any valid
    split runs the same per-layer dataflow on the same (rows, cols) grid,
    so splits are bit-equal schedules (tests/test_geometry_tune.py).
    A cached split that does not fit THIS call (wrong stage count, wrong
    layer total, negative entries) is ignored, never trusted: the
    structural guards stay authoritative over the cache."""
    from ..tune.schedule import current_schedule_cache, mesh_signature
    cache = current_schedule_cache()
    if cache is None:
        return None
    ent = cache.lookup(kind, n_x=n_x, n_h=n_h, n_layers=n_layers,
                       T=T, B=batch, mesh=mesh_signature(mesh))
    if ent is None or not getattr(ent, 'blocks', ''):
        return None
    try:
        counts = tuple(int(p) for p in str(ent.blocks).split(','))
    except ValueError:
        return None
    if (len(counts) != n_stages or any(c < 0 for c in counts)
            or sum(counts) != n_layers):
        return None
    return counts


def _staged_forward(static, w_in, w_h, peep, b, pre_x, h0s, c0s, mask=None):
    """Staged distributed whole-stack forward (padded in, un-padded out).

    Numerics contract: allclose to the layerwise composition (chaining
    ``core.lstm.lstm_layer`` / the §8 fused stack) — inside a stage each
    layer of the block runs the §6 per-step dataflow (resident ``bn x bk``
    recurrent block, per-step ``psum`` over ``col``, ``all_gather`` of the
    h chunks over ``row``) over one Tc-step chunk at a time, the chunk's
    below-layer input stream hoisted into one wide matmul; chunks pipeline
    across stages via ``ppermute`` — at macro-step m, stage s computes
    chunk ``m - s`` while stage s+1 consumes chunk ``m - s - 1`` — so
    inter-stage activations never fan through a host gather.  ``mask``:
    optional (T, B) validity mask; a masked step is identity on every
    layer's carried state via ``jnp.where`` (pure select, so ``None`` and
    an all-ones mask are bit-identical).  Returns (hs, cs), each
    (L, T, B, n_h) — the full trajectories feed the cross-layer VJP and
    the chunked serving carry.

    ``static[5]`` selects the in-stage schedule (``IN_STAGE_MODES``):
    ``'sequential'`` runs the stage's layer block slot by slot over the
    chunk (``Lb * Tc`` collective rounds per macro-step); ``'batched'``
    walks the same (slot, step) grid diagonal-major like the §8 stack
    kernel — slot i executes step ``d - i`` at diagonal d, all live slots
    in ONE ``(Lb, B, bk) x (Lb, bk, 4*bn)`` dot per diagonal, ``Tc + Lb -
    1`` rounds — with identical per-element arithmetic and addition order
    (separate own/below psums, ``pre = psum(own) + (psum(below) +
    pre_x)``), so the two orders are bit-equal.

    ``static[6]`` (optional, ``None`` = balanced) carries the per-stage
    layer counts of an uneven stage split (``stage_layer_blocks``'
    ``blocks`` override) — schedule-only like the in-stage order.
    """
    mesh, stage_axis, row_axis, col_axis, chunk, in_stage = static[:6]
    split = static[6] if len(static) > 6 else None
    assert in_stage in IN_STAGE_MODES, in_stage
    T, B, _, n_h = pre_x.shape
    L = w_h.shape[0]
    S, mr, mc = (mesh.shape[stage_axis], mesh.shape[row_axis],
                 mesh.shape[col_axis])
    n_h_p, bn, bk = _scaleout_blocks(n_h, mr, mc)
    pad = n_h_p - n_h
    Tc, K, T_p, M, blocks, Lb = _staged_schedule(L, T, S, chunk, split)

    if mask is None:
        mask = jnp.ones((T, B), jnp.bool_)
    mask_k = jnp.zeros((T_p, B), jnp.bool_).at[:T].set(mask).reshape(K, Tc, B)
    pre_p = jnp.pad(pre_x, ((0, T_p - T), (0, 0), (0, 0), (0, pad))
                    ).reshape(K, Tc, B, GATES, n_h_p)

    pad_w = ((0, 0), (0, 0), (0, pad), (0, pad))
    w_in_s = _stage_stack(jnp.pad(w_in, pad_w), blocks, S, Lb)
    w_h_s = _stage_stack(jnp.pad(w_h, pad_w), blocks, S, Lb)
    peep_s = _stage_stack(jnp.pad(peep, ((0, 0), (0, 0), (0, pad))),
                          blocks, S, Lb)
    bias_s = _stage_stack(jnp.pad(b, ((0, 0), (0, 0), (0, pad))),
                          blocks, S, Lb)
    h0_s = _stage_stack(jnp.pad(h0s, ((0, 0), (0, 0), (0, pad))),
                        blocks, S, Lb)
    c0_s = _stage_stack(jnp.pad(c0s, ((0, 0), (0, 0), (0, pad))),
                        blocks, S, Lb)
    live = _stage_live(blocks, S, Lb)
    fwd_perm = [(i, (i + 1) % S) for i in range(S)]

    def body(w_in_l, w_h_l, peep_l, bias_l, h0_l, c0_l, live_l, pre_l,
             mask_l):
        """SPMD body on device (s, r, c).

        w_in_l/w_h_l: (1, Lb, 4, bn, bk) — the stage's resident layer
        block, tile-stationary for the whole utterance; pre_l:
        (K, Tc, B, 4, bn) hoisted layer-0 stream (consumed by stage 0
        only); mask_l: (K, Tc, B) replicated validity chunks.
        """
        s_idx = jax.lax.axis_index(stage_axis)
        col = jax.lax.axis_index(col_axis)
        w_in_l, w_h_l = w_in_l[0], w_h_l[0]
        peep_l, bias_l, live_l = peep_l[0], bias_l[0], live_l[0]

        def layer_chunk(w4, peep4, bias4, pre_stream, h0f, c0b, m_chunk):
            # One slot's Tc-step scan — exactly the §6 step dataflow.
            def step(carry_i, inp):
                h_full, c = carry_i
                pre_t, m = inp
                h_k = jax.lax.dynamic_slice(h_full, (0, col * bk), (B, bk))
                part = jnp.einsum('gnk,bk->bgn', w4, h_k)
                pre = jax.lax.psum(part, col_axis) + pre_t
                i = jax.nn.sigmoid(pre[:, I] + peep4[PEEP_I] * c + bias4[I])
                f = jax.nn.sigmoid(pre[:, F] + peep4[PEEP_F] * c + bias4[F])
                g = jnp.tanh(pre[:, G] + bias4[G])
                c_new = f * c + i * g
                o = jax.nn.sigmoid(pre[:, O] + peep4[PEEP_O] * c_new
                                   + bias4[O])
                h_new = o * jnp.tanh(c_new)
                h_full_new = jax.lax.all_gather(h_new, row_axis, axis=1,
                                                tiled=True)
                # Masked step = identity on the carried state (pure select).
                keep = m[:, None]
                h_full_new = jnp.where(keep, h_full_new, h_full)
                c_new = jnp.where(keep, c_new, c)
                return (h_full_new, c_new), (h_full_new, c_new)

            (h_T, c_T), (hs_c, cs_c) = jax.lax.scan(
                step, (h0f, c0b), (pre_stream, m_chunk))
            return hs_c, cs_c, h_T, c_T

        def macro(carry_m, m_idx):
            h_state, c_state, out_prev = carry_m
            k = m_idx - s_idx
            act = (k >= 0) & (k < K)
            kc = jnp.clip(k, 0, K - 1)
            # Inter-stage handover: stage s-1's chunk from macro-step m-1.
            handed = (out_prev if S == 1 else
                      jax.lax.ppermute(out_prev, stage_axis, fwd_perm))
            pre_chunk = jax.lax.dynamic_index_in_dim(pre_l, kc, 0,
                                                     keepdims=False)
            m_chunk = jax.lax.dynamic_index_in_dim(mask_l, kc, 0,
                                                   keepdims=False) & act
            below = handed
            hs_slots, cs_slots, new_h, new_c = [], [], [], []
            for i in range(Lb):
                def run_slot(ops, i=i):
                    below_i, h0f, c0b = ops
                    # Chunk-hoisted input stream: this slot's W_in block
                    # MACs the below trajectory, partials meeting in a psum
                    # over "col" — one wide matmul per chunk instead of
                    # per step.
                    below_k = jax.lax.dynamic_slice(
                        below_i, (0, 0, col * bk), (Tc, B, bk))
                    pre_stream = jax.lax.psum(
                        jnp.einsum('gnk,tbk->tbgn', w_in_l[i], below_k),
                        col_axis)
                    if i == 0:
                        # Stage 0's first slot streams the hoisted pre_x
                        # (its W_in block is zero, so the handed term
                        # vanishes).
                        pre_stream = pre_stream + jnp.where(s_idx == 0,
                                                            pre_chunk, 0.0)
                    return layer_chunk(w_h_l[i], peep_l[i], bias_l[i],
                                       pre_stream, h0f, c0b, m_chunk)

                def skip_slot(ops):
                    # Fill/drain bubble or passthrough slot: hand the input
                    # straight through, carry state untouched, no compute.
                    # The emitted trajectory entries of a skipped macro-step
                    # are never gathered (collection takes m = k + s only).
                    below_i, h0f, c0b = ops
                    return (below_i, jnp.zeros((Tc, B, bn), below_i.dtype),
                            h0f, c0b)

                # The predicate is uniform across the stage's (row, col)
                # group — `act` depends only on the stage index and
                # `live` is per-stage data — so the collectives inside the
                # taken branch always match up within their groups.
                hs_c, cs_c, h_T, c_T = jax.lax.cond(
                    act & (live_l[i] > 0), run_slot, skip_slot,
                    (below, h_state[i], c_state[i]))
                below = hs_c
                hs_slots.append(hs_c)
                cs_slots.append(cs_c)
                new_h.append(h_T)
                new_c.append(c_T)
            return ((jnp.stack(new_h), jnp.stack(new_c), below),
                    (jnp.stack(hs_slots), jnp.stack(cs_slots)))

        nl = jnp.sum((live_l > 0).astype(jnp.int32))
        # Per-stage live-slot counts are static data (``blocks``), so the
        # batched macro dispatches each stage — via a stage-uniform switch —
        # to a branch specialized to its own count: single-layer stages
        # reuse the sequential chunk scan verbatim (zero dead-slot work),
        # and cnt-layer stages walk Tc + cnt - 1 diagonals with ONE fused
        # slot-batched dot and ONE psum per diagonal.
        counts = sorted({hi - lo for lo, hi in blocks if hi > lo})

        def macro_batched(carry_m, m_idx):
            # Same chunk pipeline as `macro`, but each stage's (slot, step)
            # grid is walked diagonal-major: at diagonal d, slot i executes
            # its step t = d - i (out-of-window diagonals are
            # select-identity bubbles).  Slot i's below input at diagonal d
            # is slot i-1's carried post-step h from diagonal d-1 — exactly
            # its step-t output — so the state stack itself is the
            # diagonal-major inter-layer buffer.
            h_state, c_state, out_prev = carry_m
            k = m_idx - s_idx
            act = (k >= 0) & (k < K)
            kc = jnp.clip(k, 0, K - 1)
            handed = (out_prev if S == 1 else
                      jax.lax.ppermute(out_prev, stage_axis, fwd_perm))
            pre_chunk = jax.lax.dynamic_index_in_dim(pre_l, kc, 0,
                                                     keepdims=False)
            m_chunk = jax.lax.dynamic_index_in_dim(mask_l, kc, 0,
                                                   keepdims=False) & act

            def hoist_stream0(handed_c):
                # Slot 0's below stream (the handed chunk) is fully known
                # at macro start: hoist its W_in MAC into ONE wide matmul +
                # psum — the very ops (and addition association) of the
                # sequential slot loop.
                handed_k = jax.lax.dynamic_slice(
                    handed_c, (0, 0, col * bk), (Tc, B, bk))
                pre_stream0 = jax.lax.psum(
                    jnp.einsum('gnk,tbk->tbgn', w_in_l[0], handed_k),
                    col_axis)
                return pre_stream0 + jnp.where(s_idx == 0, pre_chunk, 0.0)

            def run_single(ops):
                # cnt == 1 stage: exactly the sequential single-slot chunk
                # scan — Tc one-slot rounds, nothing batched, no dead-slot
                # compute on the padding slots.
                handed_c, h0_all, c0_all = ops
                hs_c, cs_c, h_T0, c_T0 = layer_chunk(
                    w_h_l[0], peep_l[0], bias_l[0],
                    hoist_stream0(handed_c), h0_all[0], c0_all[0], m_chunk)
                h_T = jnp.concatenate([h_T0[None], h0_all[1:]], axis=0)
                c_T = jnp.concatenate([c_T0[None], c0_all[1:]], axis=0)
                pad_h = jnp.zeros((Lb - 1, Tc, B, n_h_p), hs_c.dtype)
                pad_c = jnp.zeros((Lb - 1, Tc, B, bn), cs_c.dtype)
                return (h_T, c_T, hs_c,
                        jnp.concatenate([hs_c[None], pad_h], axis=0),
                        jnp.concatenate([cs_c[None], pad_c], axis=0))

            def make_run(cnt):
                def run_cnt(ops):
                    handed_c, h0_all, c0_all = ops
                    pre_stream0 = hoist_stream0(handed_c)
                    D = Tc + cnt - 1
                    # Diagonal -> (slot, step) geometry and validity masks
                    # are index arithmetic on the schedule: precompute them
                    # (and the slot-0 stream replay) once per macro-step and
                    # feed the diagonal scan through its xs.
                    t_idx = (jnp.arange(D)[:, None]
                             - jnp.arange(cnt)[None, :])
                    valid = (t_idx >= 0) & (t_idx < Tc)
                    t_clip = jnp.clip(t_idx, 0, Tc - 1)
                    pre0_d = pre_stream0[jnp.clip(jnp.arange(D), 0, Tc - 1)]
                    keep_d = (jnp.take(m_chunk, t_clip, axis=0)
                              & valid[..., None])
                    # Own-h and below dots fuse into ONE slot-batched
                    # einsum + ONE psum: the weight stack [W_h | W_in[1:]]
                    # is loop-invariant, and a psum of concatenated
                    # operands is elementwise — splitting the result back
                    # recovers psum(own) and psum(below) bit for bit, so
                    # the addition association stays psum(own) +
                    # (psum(below) + pre_x), matching the sequential loop.
                    w_cat = jnp.concatenate([w_h_l[:cnt], w_in_l[1:cnt]],
                                            axis=0)
                    peep_c, bias_c = peep_l[:cnt], bias_l[:cnt]

                    def diag(carry_d, xs_d):
                        h_all, c_all = carry_d
                        pre0_t, keep_t = xs_d
                        h_k = jax.lax.dynamic_slice(
                            h_all, (0, 0, col * bk), (cnt, B, bk))
                        # Slot i>=1 reads slot i-1's post-step h — the same
                        # col slice just taken for the own-h dot.
                        in_cat = jnp.concatenate([h_k, h_k[:-1]], axis=0)
                        part = jnp.einsum('lgnk,lbk->lbgn', w_cat, in_cat)
                        psummed = jax.lax.psum(part, col_axis)
                        pre = psummed[:cnt] + jnp.concatenate(
                            [pre0_t[None], psummed[cnt:]], axis=0)
                        c = c_all
                        i = jax.nn.sigmoid(pre[:, :, I]
                                           + peep_c[:, PEEP_I][:, None] * c
                                           + bias_c[:, I][:, None])
                        f = jax.nn.sigmoid(pre[:, :, F]
                                           + peep_c[:, PEEP_F][:, None] * c
                                           + bias_c[:, F][:, None])
                        g = jnp.tanh(pre[:, :, G] + bias_c[:, G][:, None])
                        c_new = f * c + i * g
                        o = jax.nn.sigmoid(pre[:, :, O]
                                           + peep_c[:, PEEP_O][:, None]
                                           * c_new
                                           + bias_c[:, O][:, None])
                        h_new = o * jnp.tanh(c_new)
                        h_full_new = jax.lax.all_gather(
                            h_new, row_axis, axis=2, tiled=True)
                        keep = keep_t[:, :, None]
                        h_next = jnp.where(keep, h_full_new, h_all)
                        c_next = jnp.where(keep, c_new, c_all)
                        return (h_next, c_next), (h_next, c_next)

                    (h_Tc, c_Tc), (hs_d, cs_d) = jax.lax.scan(
                        diag, (h0_all[:cnt], c0_all[:cnt]),
                        (pre0_d, keep_d))
                    # Diagonal emissions (D, cnt, ...) -> the sequential
                    # layout (cnt, Tc, ...): slot i's step t is at d = i+t.
                    hs_sl = jnp.stack([hs_d[i:i + Tc, i]
                                       for i in range(cnt)])
                    cs_sl = jnp.stack([cs_d[i:i + Tc, i]
                                       for i in range(cnt)])
                    out = hs_sl[cnt - 1]
                    h_T = jnp.concatenate([h_Tc, h0_all[cnt:]], axis=0)
                    c_T = jnp.concatenate([c_Tc, c0_all[cnt:]], axis=0)
                    hs_sl = jnp.concatenate(
                        [hs_sl, jnp.zeros((Lb - cnt, Tc, B, n_h_p),
                                          hs_d.dtype)], axis=0)
                    cs_sl = jnp.concatenate(
                        [cs_sl, jnp.zeros((Lb - cnt, Tc, B, bn),
                                          cs_d.dtype)], axis=0)
                    return h_T, c_T, out, hs_sl, cs_sl
                return run_cnt

            def skip_macro(ops):
                # Fill/drain macro-step (or empty stage): passthrough +
                # untouched state, no compute; the zero emissions are never
                # gathered.
                handed_c, h0_all, c0_all = ops
                return (h0_all, c0_all, handed_c,
                        jnp.zeros((Lb, Tc, B, n_h_p), handed_c.dtype),
                        jnp.zeros((Lb, Tc, B, bn), handed_c.dtype))

            # The branch index depends only on s_idx/m_idx and per-stage
            # data (nl), so every device of a stage's (row, col) collective
            # groups takes the same branch and the collectives inside it
            # match up within their groups.
            branches = [skip_macro] + [
                (run_single if c == 1 else make_run(c)) for c in counts]
            idx = sum(((nl > c).astype(jnp.int32) for c in counts),
                      jnp.int32(0))
            branch = jnp.where(act & (nl > 0), 1 + idx, 0)
            h_T, c_T, out, hs_sl, cs_sl = jax.lax.switch(
                branch, branches, (handed, h_state, c_state))
            return (h_T, c_T, out), (hs_sl, cs_sl)

        macro_fn = (macro_batched
                    if in_stage == 'batched' and Lb > 1 else macro)
        out0 = jnp.zeros((Tc, B, n_h_p), pre_l.dtype)
        _, (hs_all, cs_all) = jax.lax.scan(
            macro_fn, (h0_l[0], c0_l[0], out0), jnp.arange(M))
        return hs_all, cs_all

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis, None, None, row_axis, col_axis),
                  P(stage_axis, None, None, row_axis, col_axis),
                  P(stage_axis, None, None, row_axis),
                  P(stage_axis, None, None, row_axis),
                  P(stage_axis, None, None, None),
                  P(stage_axis, None, None, row_axis),
                  P(stage_axis, None),
                  P(None, None, None, None, row_axis),
                  P(None, None, None)),
        out_specs=(P(None, stage_axis, None, None, None),
                   P(None, stage_axis, None, None, row_axis)),
        check_vma=False,
    )
    hs_g, cs_g = fn(w_in_s, w_h_s, peep_s, bias_s, h0_s, c0_s, live,
                    pre_p, mask_k)
    hs_g = hs_g.reshape(M, S, Lb, Tc, B, n_h_p)
    cs_g = cs_g.reshape(M, S, Lb, Tc, B, n_h_p)

    def layer_traj(g, layer):
        # Stage s emits chunk k at macro-step k + s: a pure re-indexing.
        s_i, slot = _stage_of(blocks, layer)
        return g[s_i:s_i + K, s_i, slot].reshape(T_p, B, n_h_p)[:T, :, :n_h]

    hs = jnp.stack([layer_traj(hs_g, l) for l in range(L)])
    cs = jnp.stack([layer_traj(cs_g, l) for l in range(L)])
    return hs, cs


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def systolic_stack_seq_fused(static, w_in, w_h, peep, b, pre_x, h0s, c0s):
    """Staged distributed whole-stack LSTM with the production training VJP.

    Same contract as ``kernels.lstm_seq.stack_ops.lstm_stack_seq_fused``
    (forward allclose to looping ``lstm_scan_fused`` over the layers;
    backward composes the cross-layer gate recompute across stage
    boundaries via the shared ``core.lstm.lstm_stack_bwd_recompute_gates``
    — the saved trajectories are already stage-gathered, so the backward
    is numerically identical to the single-engine fused stack's), but the
    forward runs stage-pipelined on the ``static = (mesh, stage_axis,
    row_axis, col_axis, chunk, in_stage[, blocks])`` grid.  The in-stage
    schedule (``IN_STAGE_MODES``) and the optional uneven stage split
    change only the round order / layer placement, not the trajectories,
    so gradients are bit-equal across schedules too.
    """
    hs, cs = _staged_forward(static, w_in, w_h, peep, b, pre_x, h0s, c0s)
    return hs[-1], (hs[:, -1], cs[:, -1])


def _ssf_fwd(static, w_in, w_h, peep, b, pre_x, h0s, c0s):
    hs, cs = _staged_forward(static, w_in, w_h, peep, b, pre_x, h0s, c0s)
    return ((hs[-1], (hs[:, -1], cs[:, -1])),
            (w_in, w_h, peep, b, pre_x, hs, cs, h0s, c0s))


def _ssf_bwd(static, res, grads):
    from .lstm import lstm_stack_bwd_recompute_gates
    w_in, w_h, peep, b, pre_x, hs, cs, h0s, c0s = res
    return lstm_stack_bwd_recompute_gates(w_in, w_h, peep, b, pre_x, hs, cs,
                                          h0s, c0s, grads)


systolic_stack_seq_fused.defvjp(_ssf_fwd, _ssf_bwd)


def systolic_lstm_stack_seq(params, mesh: Optional[Mesh], xs: jax.Array,
                            states=None, *,
                            valid_len: Optional[jax.Array] = None,
                            chunk: Optional[int] = None,
                            in_stage: Optional[str] = None,
                            blocks: Optional[Sequence[int]] = None,
                            stage_axis: str = 'stage',
                            row_axis: str = 'row', col_axis: str = 'col'
                            ) -> Tuple[jax.Array, Tuple]:
    """Staged scale-out of the fused wavefront stack — the
    ``pallas_seq_fused_systolic`` backend (DESIGN.md §9).

    Drop-in for the layer loop of ``core.lstm.lstm_stack_apply`` /
    ``lstm_stack_chunk`` (same signature family as
    ``kernels.lstm_seq.lstm_stack_seq``): each stage of the installed
    ``(stage, row, col)`` mesh holds ONE contiguous layer block
    weight-stationary (``stage_layer_blocks``; the paper's 3x(5x5) places
    one layer per 5x5 stage) and runs the fused-stack composition over it
    with the §6 row/col tile-stationary dataflow, while the hidden-state
    sequence pipelines across stages in ``chunk``-step slices handed over
    by ``ppermute`` — stage s computes chunk k while stage s+1 consumes
    chunk k-1, so a T-step utterance costs ``ceil(T/chunk) + S - 1``
    macro-steps of the bottleneck stage instead of every stage in
    sequence.

    Output allclose to the layerwise composition (``lstm_stack_apply`` on
    any backend); differentiable via the cross-layer gate-recompute VJP
    (``systolic_stack_seq_fused``).  ``valid_len`` follows the §7 masking
    contract (masked steps are identity on every layer's carried state;
    inference-only), and ``states`` carries the per-layer ``(h, c)`` for
    chunked serving.  A ``None`` or all-1 mesh degenerates to the
    single-engine §8 kernel (``lstm_stack_seq``) — the composition this
    function scales out.  ``chunk`` defaults to the installed schedule
    cache's measured winner for this (shape, mesh) when one exists
    (``resolve_staged_chunk``), else ``ceil(T / (4*stages))`` (fill/drain
    stays under ~1/4 of macro-steps; chunk=1 is the paper's frame-by-frame
    handover).  ``in_stage`` picks the in-stage round order
    (``IN_STAGE_MODES``): ``'batched'`` executes each stage's layer block
    diagonal-major — all live slots advance in one slot-batched dot per
    diagonal, ``Tc + Lb - 1`` rounds per macro-step instead of ``Lb * Tc``
    — and is bit-equal to ``'sequential'`` (the PR 5 slot loop), which
    remains as the measured baseline; ``None`` (default) takes the
    schedule cache's measured winner for this (shape, mesh), else
    ``'batched'`` (``resolve_staged_in_stage``).  ``blocks`` (per-stage
    layer counts) overrides ``stage_layer_blocks``' balanced split with a
    tuned uneven one; ``None`` takes the schedule cache's winner for this
    (shape, mesh) when one exists (``resolve_staged_blocks``), else the
    balanced default — any valid split is a bit-equal schedule on a fixed
    (rows, cols) grid.
    """
    from ..kernels.lstm_seq import lstm_stack_seq, stack_fused_compatible
    assert stack_fused_compatible(params), \
        'staged scale-out needs homogeneous hidden widths'
    assert xs.ndim == 3, 'systolic_lstm_stack_seq expects (T, B, N_x) input'
    if mesh is None or all(sz == 1 for sz in mesh.shape.values()):
        return lstm_stack_seq(params, xs, states, valid_len=valid_len)
    S, _, _ = _require_staged_axes(mesh, stage_axis, row_axis, col_axis)
    layers = params.layers
    n_h = layers[0].n_h
    T, B = xs.shape[0], xs.shape[1]
    if chunk is None:
        chunk = resolve_staged_chunk(len(layers), T, S, n_h=n_h,
                                     n_x=layers[0].n_x, batch=B, mesh=mesh)
    if in_stage is None:
        in_stage = resolve_staged_in_stage(len(layers), T, S, n_h=n_h,
                                           n_x=layers[0].n_x, batch=B,
                                           mesh=mesh)
    if blocks is None:
        blocks = resolve_staged_blocks(len(layers), T, S, n_h=n_h,
                                       n_x=layers[0].n_x, batch=B,
                                       mesh=mesh)
    split = tuple(int(s) for s in blocks) if blocks is not None else None
    Tc = _staged_schedule(len(layers), T, S, chunk, split)[0]

    from ..kernels.lstm_seq.stack_ops import _stack_arrays
    from .lstm import stack_carry_arrays
    w_in, w_h, peep, b = _stack_arrays(params)
    pre_x = jnp.einsum('ghx,tbx->tbgh', layers[0].w_x, xs)    # hoisted

    h0s, c0s = stack_carry_arrays(states, len(layers), B, n_h, xs.dtype)
    static = (mesh, stage_axis, row_axis, col_axis, Tc, in_stage, split)
    if valid_len is not None:
        from .lstm import valid_len_mask
        mask = valid_len_mask(T, valid_len, B)
        hs, cs = _staged_forward(static, w_in, w_h, peep, b, pre_x, h0s,
                                 c0s, mask)
        ys, h_T, c_T = hs[-1], hs[:, -1], cs[:, -1]
    else:
        ys, (h_T, c_T) = systolic_stack_seq_fused(static, w_in, w_h, peep,
                                                  b, pre_x, h0s, c0s)
    finals = tuple((h_T[l], c_T[l]) for l in range(len(layers)))
    return ys, finals


def systolic_lstm_stack_seq_quantized(qps, mesh: Optional[Mesh],
                                      xs_q: jax.Array, *,
                                      state=None,
                                      valid_len: Optional[jax.Array] = None,
                                      return_state: bool = False,
                                      chunk: Optional[int] = None,
                                      in_stage: Optional[str] = None,
                                      blocks: Optional[Sequence[int]] = None,
                                      stage_axis: str = 'stage',
                                      row_axis: str = 'row',
                                      col_axis: str = 'col'):
    """Staged distributed int8 stack, bit-identical to the silicon chain.

    The int8 form of ``systolic_lstm_stack_seq``: same stage placement and
    chunk pipelining, but every step replays the engine-order saturating
    datapath — each layer's below/x-region prefix of the hop chain is
    h-independent within the chunk and folds through the shared
    ``_x_prefix_fold`` (layer 0's whole-sequence prefix comes from
    ``quantized_x_prefix``, exactly as in §6/§8), the own-h region tile
    partials are ``all_gather``ed over ``col`` and hopped sequentially in
    engine order, and the elementwise tail is the shared
    ``_quantized_state_update``.  Output is therefore **bit-identical** to
    chaining the single-engine fused stack
    (``kernels.lstm_seq.lstm_stack_seq_quantized``) — and hence to
    chaining ``lstm_layer_seq_quantized`` / the reference
    ``systolic_cell_quantized`` scan — per layer block.

    qps: per-layer quantized packs (one tile, one hidden width, inner
    ``n_x == n_h``); xs_q: (T, B, n_x) int8 codes.  ``state`` /
    ``valid_len`` / ``return_state`` follow the §7 chunk-carry contract of
    ``lstm_stack_seq_quantized`` verbatim (opaque per-layer
    ``(h_q, c_q)`` codes, each (L, B, padded_h); masked steps are pure
    selects on the carried codes), so the staged mesh, the single-engine
    fused stack and the streaming engine can hand state to each other
    mid-sequence.  ``in_stage`` follows ``IN_STAGE_MODES`` (``None`` =
    the schedule cache's winner, else ``'batched'``, as in
    ``resolve_staged_in_stage``): the ``'batched'`` order advances every
    live slot of the stage's block per
    in-chunk diagonal (the below/x prefix folds through a slot-vmapped
    ``_x_prefix_fold``, the own-h hops replay in the same engine order),
    so the integer datapath — and hence the emitted codes — is unchanged
    from ``'sequential'`` op for op.  Requires ``plan.rows % mesh rows ==
    0`` and
    ``plan.cols_h % mesh cols == 0``; a ``None``/all-1 mesh degenerates to
    the single-engine fused stack.
    """
    from ..kernels.lstm_seq import lstm_stack_seq_quantized
    if mesh is None or all(sz == 1 for sz in mesh.shape.values()):
        return lstm_stack_seq_quantized(qps, xs_q, state=state,
                                        valid_len=valid_len,
                                        return_state=return_state)
    plans = [qp.plan for qp in qps]
    p0 = plans[0]
    L = len(qps)
    assert L >= 1
    assert all(p.tile == p0.tile for p in plans), 'mixed tiles'
    assert all(p.n_h == p0.n_h for p in plans), 'mixed hidden widths'
    assert all(p.n_x == p0.n_h for p in plans[1:]), \
        'inner layers must consume the stack hidden width'
    S, mr, mc = _require_staged_axes(mesh, stage_axis, row_axis, col_axis)
    t, R, c_h, padded_h = p0.tile, p0.rows, p0.cols_h, p0.padded_h
    if R % mr or c_h % mc:
        raise ValueError(f'engine grid {R}x{c_h} (h-region) does not divide '
                         f'mesh {mr}x{mc}')
    r_l, c_l = R // mr, c_h // mc
    assert xs_q.ndim == 3, \
        'systolic_lstm_stack_seq_quantized expects (T, B, n_x)'
    T, B = xs_q.shape[0], xs_q.shape[1]
    if chunk is None:
        chunk = resolve_staged_chunk(L, T, S, n_h=p0.n_h, n_x=p0.n_x,
                                     batch=B, mesh=mesh, kind='stack_int8')
    if in_stage is None:
        in_stage = resolve_staged_in_stage(L, T, S, n_h=p0.n_h, n_x=p0.n_x,
                                           batch=B, mesh=mesh,
                                           kind='stack_int8')
    assert in_stage in IN_STAGE_MODES, in_stage
    if blocks is None:
        blocks = resolve_staged_blocks(L, T, S, n_h=p0.n_h, n_x=p0.n_x,
                                       batch=B, mesh=mesh,
                                       kind='stack_int8')
    Tc, K, T_p, M, blocks, Lb = _staged_schedule(L, T, S, chunk, blocks)

    # Resident weights: own-h region tiles sharded (row, col); below/x
    # region tiles row-sharded (each row device folds its own prefix).
    # Layer 0's below slot is zero — its whole-sequence x prefix is
    # hoisted host-side through the one shared implementation.
    own_s = _stage_stack(
        jnp.stack([qp.tiles_q[:, p.cols_x:] for qp, p in zip(qps, plans)]),
        blocks, S, Lb)
    below_all = [jnp.zeros((R, c_h, GATES, t, t), jnp.int8)]
    for qp, p in zip(qps[1:], plans[1:]):
        below_all.append(qp.tiles_q[:, :p.cols_x])
    below_s = _stage_stack(jnp.stack(below_all), blocks, S, Lb)
    peep_s = _stage_stack(jnp.stack([qp.peep_q for qp in qps]), blocks, S, Lb)
    bias_s = _stage_stack(jnp.stack([qp.bias_q for qp in qps]), blocks, S, Lb)

    xs_flat = jnp.zeros((T_p, B, p0.n_x), jnp.int8).at[:T].set(xs_q)
    acc_x = quantized_x_prefix(qps[0], xs_flat).reshape(K, Tc, B, R, GATES, t)

    if state is None:
        h0 = jnp.zeros((L, B, padded_h), jnp.int8)
        c0 = jnp.zeros((L, B, padded_h), jnp.int8)
    else:
        h0 = state[0].reshape(L, B, padded_h)
        c0 = state[1].reshape(L, B, padded_h)
    h0_s = _stage_stack(h0, blocks, S, Lb)
    c0_s = _stage_stack(c0.reshape(L, B, R, t), blocks, S, Lb)
    if valid_len is None:
        mask = jnp.ones((T, B), jnp.int8)
    else:
        from .lstm import valid_len_mask
        mask = valid_len_mask(T, valid_len, B).astype(jnp.int8)
    mask_k = jnp.zeros((T_p, B), jnp.int8).at[:T].set(mask).reshape(K, Tc, B)
    live = _stage_live(blocks, S, Lb)
    fwd_perm = [(i, (i + 1) % S) for i in range(S)]

    def body(own_l, below_l, peep_l, bias_l, sig_lut, tanh_lut, accx_l,
             h0_l, c0_l, live_l, mask_l):
        """SPMD body on device (s, r, c): own_l (1, Lb, r_l, c_l, 4, t, t)
        stationary for the whole utterance; below_l (1, Lb, r_l, c_h, 4,
        t, t) feeds the per-chunk prefix fold; accx_l (K, Tc, B, r_l, 4,
        t) is layer 0's hoisted x prefix for this row block."""
        s_idx = jax.lax.axis_index(stage_axis)
        col = jax.lax.axis_index(col_axis)
        own_l, below_l = own_l[0], below_l[0]
        peep32 = peep_l[0].astype(jnp.int32)
        bias32 = bias_l[0].astype(jnp.int32)
        live_l = live_l[0]

        def hop(acc, p):
            return _sat16(acc + p), None

        def layer_chunk(own_i, peep_i, bias_i, acc_chunk, h0f, c0b,
                        m_chunk):
            def step(carry_i, inp):
                h_full, c_blk = carry_i
                acc_t, m = inp
                h_cols = jax.lax.dynamic_slice(
                    h_full, (0, col * (c_l * t)),
                    (B, c_l * t)).reshape(B, c_l, t)
                parts = _sat16(jnp.einsum('rlgij,blj->lbrgi',
                                          own_i.astype(jnp.int32),
                                          h_cols.astype(jnp.int32)))
                # Engine-order hop replay from the below-region prefix.
                parts_all = jax.lax.all_gather(parts, col_axis, axis=0,
                                               tiled=True)
                pre_acc, _ = jax.lax.scan(hop, acc_t, parts_all)
                h8, c8 = _quantized_state_update(
                    pre_acc, c_blk.astype(jnp.int32), peep_i, bias_i,
                    sig_lut[0], tanh_lut[0])
                h_full_new = jax.lax.all_gather(
                    h8.reshape(B, r_l * t), row_axis, axis=1, tiled=True)
                # Masked step = identity on the carried codes (pure select).
                live_step = (m > 0)[:, None]
                h_full_new = jnp.where(live_step, h_full_new, h_full)
                c8 = jnp.where(live_step[:, :, None], c8, c_blk)
                return (h_full_new, c8), (h_full_new, c8)

            (h_T, c_T), (hs_c, cs_c) = jax.lax.scan(step, (h0f, c0b),
                                                    (acc_chunk, m_chunk))
            return hs_c, cs_c, h_T, c_T

        def macro(carry_m, m_idx):
            h_state, c_state, out_prev = carry_m
            k = m_idx - s_idx
            act = (k >= 0) & (k < K)
            kc = jnp.clip(k, 0, K - 1)
            handed = (out_prev if S == 1 else
                      jax.lax.ppermute(out_prev, stage_axis, fwd_perm))
            accx_chunk = jax.lax.dynamic_index_in_dim(accx_l, kc, 0,
                                                      keepdims=False)
            m_chunk = jnp.where(
                act, jax.lax.dynamic_index_in_dim(mask_l, kc, 0,
                                                  keepdims=False),
                jnp.int8(0))
            below = handed
            hs_slots, cs_slots, new_h, new_c = [], [], [], []
            for i in range(Lb):
                def run_slot(ops, i=i):
                    below_i, h0f, c0b = ops
                    # Chunk-hoisted below/x-region prefix: h-independent
                    # within the step, so it folds once per chunk (the
                    # shared saturation/hop order of _x_prefix_fold —
                    # bit-identical to folding inside the step loop).
                    below_cols = below_i.reshape(Tc, B, c_h, t)
                    acc_chunk = _x_prefix_fold(below_l[i], below_cols)
                    if i == 0:
                        acc_chunk = acc_chunk + jnp.where(s_idx == 0,
                                                          accx_chunk, 0)
                    return layer_chunk(own_l[i], peep32[i], bias32[i],
                                       acc_chunk, h0f, c0b, m_chunk)

                def skip_slot(ops):
                    # Fill/drain bubble or passthrough slot: hand the input
                    # through, carry codes untouched, no compute (the
                    # emitted entries of a skipped macro-step are never
                    # gathered).
                    below_i, h0f, c0b = ops
                    return (below_i,
                            jnp.zeros((Tc, B, r_l, t), jnp.int8),
                            h0f, c0b)

                # Stage-uniform predicate, as in the f32 body: every
                # device of a stage's (row, col) collective groups takes
                # the same branch.
                hs_c, cs_c, h_T, c_T = jax.lax.cond(
                    act & (live_l[i] > 0), run_slot, skip_slot,
                    (below, h_state[i], c_state[i]))
                below = hs_c
                hs_slots.append(hs_c)
                cs_slots.append(cs_c.reshape(Tc, B, r_l * t))
                new_h.append(h_T)
                new_c.append(c_T)
            return ((jnp.stack(new_h), jnp.stack(new_c), below),
                    (jnp.stack(hs_slots), jnp.stack(cs_slots)))

        nl = jnp.sum((live_l > 0).astype(jnp.int32))
        # Static per-stage live counts drive the same stage-uniform branch
        # specialization as the f32 body: single-layer stages replay the
        # sequential chunk scan verbatim, cnt-layer stages walk the
        # Tc + cnt - 1 diagonals with cnt-sliced operands.
        counts = sorted({hi - lo for lo, hi in blocks if hi > lo})

        def macro_batched(carry_m, m_idx):
            # Diagonal-major in-stage order, mirroring the f32 body: slot i
            # runs step t = d - i at diagonal d, its below codes being slot
            # i-1's carried post-step h from diagonal d-1.  Every integer
            # op — the slot-vmapped below/x prefix fold, the engine-order
            # own-h hop scan, the LUT tail — replays in the sequential
            # order, so the emitted codes are bit-identical.
            h_state, c_state, out_prev = carry_m
            k = m_idx - s_idx
            act = (k >= 0) & (k < K)
            kc = jnp.clip(k, 0, K - 1)
            handed = (out_prev if S == 1 else
                      jax.lax.ppermute(out_prev, stage_axis, fwd_perm))
            accx_chunk = jax.lax.dynamic_index_in_dim(accx_l, kc, 0,
                                                      keepdims=False)
            m_chunk = jnp.where(
                act, jax.lax.dynamic_index_in_dim(mask_l, kc, 0,
                                                  keepdims=False),
                jnp.int8(0))

            def fold0(handed_c):
                # Slot 0's below/x prefix folds once per chunk from the
                # handed codes — the identical hoisted ops of the
                # sequential slot loop.
                acc0 = _x_prefix_fold(below_l[0],
                                      handed_c.reshape(Tc, B, c_h, t))
                return acc0 + jnp.where(s_idx == 0, accx_chunk, 0)

            def run_single(ops):
                # cnt == 1 stage: exactly the sequential single-slot chunk
                # scan, no dead-slot compute on the padding slots.
                handed_c, h0_all, c0_all = ops
                hs_c, cs_c, h_T0, c_T0 = layer_chunk(
                    own_l[0], peep32[0], bias32[0], fold0(handed_c),
                    h0_all[0], c0_all[0], m_chunk)
                h_T = jnp.concatenate([h_T0[None], h0_all[1:]], axis=0)
                c_T = jnp.concatenate([c_T0[None], c0_all[1:]], axis=0)
                pad_h = jnp.zeros((Lb - 1, Tc, B, R * t), jnp.int8)
                pad_c = jnp.zeros((Lb - 1, Tc, B, r_l * t), jnp.int8)
                return (h_T, c_T, hs_c,
                        jnp.concatenate([hs_c[None], pad_h], axis=0),
                        jnp.concatenate(
                            [cs_c.reshape(Tc, B, r_l * t)[None], pad_c],
                            axis=0))

            def make_run(cnt):
                def run_cnt(ops):
                    handed_c, h0_all, c0_all = ops
                    acc0 = fold0(handed_c)
                    D = Tc + cnt - 1
                    # Precompute the diagonal geometry, validity masks and
                    # the slot-0 prefix replay once per macro-step; the
                    # diagonal scan consumes them as xs.
                    t_idx = (jnp.arange(D)[:, None]
                             - jnp.arange(cnt)[None, :])
                    valid = (t_idx >= 0) & (t_idx < Tc)
                    t_clip = jnp.clip(t_idx, 0, Tc - 1)
                    acc0_d = acc0[jnp.clip(jnp.arange(D), 0, Tc - 1)]
                    keep_d = ((jnp.take(m_chunk, t_clip, axis=0) > 0)
                              & valid[..., None])
                    own_c = own_l[:cnt]
                    below_c = below_l[1:cnt]
                    peep_c, bias_c = peep32[:cnt], bias32[:cnt]

                    def diag(carry_d, xs_d):
                        h_all, c_all = carry_d
                        acc0_t, keep_t = xs_d
                        # Per-diagonal fold only covers slots 1..cnt-1
                        # (through the ONE shared ``_x_prefix_fold``,
                        # vmapped over slots; per-element hop order
                        # unchanged).
                        acc_rest = jax.vmap(_x_prefix_fold)(
                            below_c, h_all[:-1].reshape(cnt - 1, 1, B, c_h,
                                                        t))[:, 0]
                        acc_t = jnp.concatenate([acc0_t[None], acc_rest],
                                                axis=0)
                        h_cols = jax.lax.dynamic_slice(
                            h_all, (0, 0, col * (c_l * t)),
                            (cnt, B, c_l * t)).reshape(cnt, B, c_l, t)
                        parts = _sat16(jnp.einsum('zrlgij,zblj->lzbrgi',
                                                  own_c.astype(jnp.int32),
                                                  h_cols.astype(jnp.int32)))
                        parts_all = jax.lax.all_gather(parts, col_axis,
                                                       axis=0, tiled=True)
                        pre_acc, _ = jax.lax.scan(hop, acc_t, parts_all)
                        h8, c8 = _quantized_state_update(
                            pre_acc, c_all.astype(jnp.int32),
                            peep_c[:, None], bias_c[:, None], sig_lut[0],
                            tanh_lut[0])
                        h_full_new = jax.lax.all_gather(
                            h8.reshape(cnt, B, r_l * t), row_axis, axis=2,
                            tiled=True)
                        h_next = jnp.where(keep_t[:, :, None], h_full_new,
                                           h_all)
                        c_next = jnp.where(keep_t[:, :, None, None], c8,
                                           c_all)
                        return (h_next, c_next), (h_next, c_next)

                    (h_Tc, c_Tc), (hs_d, cs_d) = jax.lax.scan(
                        diag, (h0_all[:cnt], c0_all[:cnt]),
                        (acc0_d, keep_d))
                    hs_sl = jnp.stack([hs_d[i:i + Tc, i]
                                       for i in range(cnt)])
                    cs_sl = jnp.stack(
                        [cs_d[i:i + Tc, i] for i in range(cnt)]
                    ).reshape(cnt, Tc, B, r_l * t)
                    out = hs_sl[cnt - 1]
                    h_T = jnp.concatenate([h_Tc, h0_all[cnt:]], axis=0)
                    c_T = jnp.concatenate([c_Tc, c0_all[cnt:]], axis=0)
                    hs_sl = jnp.concatenate(
                        [hs_sl, jnp.zeros((Lb - cnt, Tc, B, R * t),
                                          jnp.int8)], axis=0)
                    cs_sl = jnp.concatenate(
                        [cs_sl, jnp.zeros((Lb - cnt, Tc, B, r_l * t),
                                          jnp.int8)], axis=0)
                    return h_T, c_T, out, hs_sl, cs_sl
                return run_cnt

            def skip_macro(ops):
                handed_c, h0_all, c0_all = ops
                return (h0_all, c0_all, handed_c,
                        jnp.zeros((Lb, Tc, B, R * t), jnp.int8),
                        jnp.zeros((Lb, Tc, B, r_l * t), jnp.int8))

            # Branch index is stage-uniform (s_idx/m_idx and per-stage
            # data), as in the sequential macro's predicates.
            branches = [skip_macro] + [
                (run_single if c == 1 else make_run(c)) for c in counts]
            idx = sum(((nl > c).astype(jnp.int32) for c in counts),
                      jnp.int32(0))
            branch = jnp.where(act & (nl > 0), 1 + idx, 0)
            h_T, c_T, out, hs_sl, cs_sl = jax.lax.switch(
                branch, branches, (handed, h_state, c_state))
            return (h_T, c_T, out), (hs_sl, cs_sl)

        macro_fn = (macro_batched
                    if in_stage == 'batched' and Lb > 1 else macro)
        out0 = jnp.zeros((Tc, B, R * t), jnp.int8)
        _, (hs_all, cs_all) = jax.lax.scan(
            macro_fn, (h0_l[0], c0_l[0], out0), jnp.arange(M))
        return hs_all, cs_all

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis, None, row_axis, col_axis),
                  P(stage_axis, None, row_axis),
                  P(stage_axis, None, row_axis),
                  P(stage_axis, None, row_axis),
                  P(None), P(None),
                  P(None, None, None, row_axis),
                  P(stage_axis),
                  P(stage_axis, None, None, row_axis),
                  P(stage_axis),
                  P(None)),
        out_specs=(P(None, stage_axis),
                   P(None, stage_axis, None, None, row_axis)),
        check_vma=False,
    )
    hs_g, cs_g = fn(own_s, below_s, peep_s, bias_s,
                    qps[0].sig_lut.reshape(1, 256),
                    qps[0].tanh_lut.reshape(1, 256),
                    acc_x, h0_s, c0_s, live, mask_k)
    hs_g = hs_g.reshape(M, S, Lb, Tc, B, padded_h)
    cs_g = cs_g.reshape(M, S, Lb, Tc, B, padded_h)

    def layer_traj(g, layer):
        s_i, slot = _stage_of(blocks, layer)
        return g[s_i:s_i + K, s_i, slot].reshape(T_p, B, padded_h)[:T]

    out = layer_traj(hs_g, L - 1)[:, :, :p0.n_h]
    if not return_state:
        return out
    h_q = jnp.stack([layer_traj(hs_g, l)[-1] for l in range(L)])
    c_q = jnp.stack([layer_traj(cs_g, l)[-1] for l in range(L)])
    return out, (h_q, c_q)
