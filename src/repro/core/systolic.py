"""Systolic LSTM execution — Chipmunk contributions C1 + C3.

The paper executes one LSTM on an R x C grid of engines.  Each engine holds a
``tile x tile`` block of the packed 4-gate weight matrix ``W = [W_x | W_h]`` in local
SRAM (weight-stationary).  Per timestep:

  1. the packed input vector ``xh = [x_t | h_{t-1}]`` is split into C column slices,
     each broadcast *down* a column of engines (paper Fig. 3a);
  2. every engine MACs its tile against its column slice (the sequential "column
     loop" of Sec. 3.2, run on 96 parallel row units);
  3. partial sums are accumulated *across* each row of engines in 16-bit saturating
     arithmetic (the systolic hop), finishing at the last column (Fig. 3b);
  4. the finishing column applies the LUT nonlinearities and the element-wise state
     update (Eqs. 1-5) for its row chunk of ``h_t``/``c_t``;
  5. the new ``h_t`` chunks are re-broadcast vertically for the next timestep
     (Fig. 3c).  Only O(N_h) bytes ever cross engine boundaries.

TPU adaptation (see DESIGN.md §2): engines -> mesh devices on ("row", "col") axes;
step 3 -> ``lax.psum`` over "col"; step 5 -> ``lax.all_gather`` over "row".  The
pure-JAX tiled forms below are numerically identical and are what the production
pjit path lowers (XLA emits the same collective schedule from sharding constraints).

Three execution paths, all validated against ``core.lstm.lstm_cell``:
  * ``systolic_cell_tiled``       — float, per-tile partials + row reduction.
  * ``systolic_cell_quantized``   — bit-accurate int8 storage / int16 saturating hops
                                    / LUT activations (contribution C2).
  * ``systolic_lstm_shard_map``   — distributed over an explicit ("row","col") mesh.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import AxisType, mesh_with_axis_types, shard_map
from . import quant
from .lstm import GATES, I, F, G, O, PEEP_I, PEEP_F, PEEP_O, LSTMParams

N_LSTM_SILICON = 96  # rows per engine in the fabricated chip


# ---------------------------------------------------------------------------
# Tiling plan + weight packing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SystolicPlan:
    """Block layout of one LSTM layer on an R x C engine grid.

    The x-region of the packed input is padded to a whole number of tiles so the
    h-region starts tile-aligned: column c < cols_x consumes input-state slices,
    column c >= cols_x consumes hidden-state slices (which is what makes step 5's
    vertical re-broadcast wiring static — "hard-wired" in the paper's words).
    """

    n_x: int
    n_h: int
    tile: int = N_LSTM_SILICON

    @property
    def rows(self) -> int:  # R: output (hidden) chunks
        return math.ceil(self.n_h / self.tile)

    @property
    def cols_x(self) -> int:
        return math.ceil(self.n_x / self.tile)

    @property
    def cols_h(self) -> int:
        return math.ceil(self.n_h / self.tile)

    @property
    def cols(self) -> int:  # C: input chunks
        return self.cols_x + self.cols_h

    @property
    def padded_h(self) -> int:
        return self.rows * self.tile

    @property
    def padded_x(self) -> int:
        return self.cols_x * self.tile

    @property
    def padded_in(self) -> int:
        return self.cols * self.tile

    @property
    def n_engines(self) -> int:
        return self.rows * self.cols

    def weight_bytes_per_engine(self) -> int:
        # 4 gate tiles + row slice of peepholes (3) and biases (4, 16-bit)
        return GATES * self.tile * self.tile + 3 * self.tile + 4 * 2 * self.tile


class PackedLSTM(NamedTuple):
    """Weight tiles in engine layout."""

    tiles: jax.Array   # (R, C, 4, tile, tile)
    peep: jax.Array    # (R, 3, tile)
    bias: jax.Array    # (R, 4, tile)
    plan_shape: Tuple[int, int, int, int]  # (n_x, n_h, tile, cols_x) — static metadata

    @property
    def plan(self) -> SystolicPlan:
        n_x, n_h, tile, _ = self.plan_shape
        return SystolicPlan(n_x, n_h, tile)


def pack_lstm(params: LSTMParams, plan: SystolicPlan) -> PackedLSTM:
    """Block [W_x | W_h] into (R, C, 4, t, t) engine tiles (zero padding)."""
    t = plan.tile
    w = jnp.zeros((GATES, plan.padded_h, plan.padded_in), params.w_x.dtype)
    w = w.at[:, :params.w_x.shape[1], :plan.n_x].set(params.w_x)
    w = w.at[:, :params.w_h.shape[1], plan.padded_x:plan.padded_x + plan.n_h].set(params.w_h)
    tiles = w.reshape(GATES, plan.rows, t, plan.cols, t).transpose(1, 3, 0, 2, 4)
    peep = jnp.zeros((3, plan.padded_h), params.w_peep.dtype
                     ).at[:, :plan.n_h].set(params.w_peep)
    bias = jnp.zeros((GATES, plan.padded_h), params.b.dtype
                     ).at[:, :plan.n_h].set(params.b)
    return PackedLSTM(
        tiles=tiles,
        peep=peep.reshape(3, plan.rows, t).transpose(1, 0, 2),
        bias=bias.reshape(GATES, plan.rows, t).transpose(1, 0, 2),
        plan_shape=(plan.n_x, plan.n_h, plan.tile, plan.cols_x),
    )


def pack_xh(x: jax.Array, h: jax.Array, plan: SystolicPlan) -> jax.Array:
    """(..., n_x), (..., n_h) -> column blocks (..., C, tile)."""
    batch = x.shape[:-1]
    xh = jnp.zeros(batch + (plan.padded_in,), x.dtype)
    xh = xh.at[..., :plan.n_x].set(x)
    xh = xh.at[..., plan.padded_x:plan.padded_x + plan.n_h].set(h)
    return xh.reshape(batch + (plan.cols, plan.tile))


def unpack_h(h_blocks: jax.Array, plan: SystolicPlan) -> jax.Array:
    """(..., R, tile) -> (..., n_h)."""
    return h_blocks.reshape(h_blocks.shape[:-2] + (plan.padded_h,))[..., :plan.n_h]


# ---------------------------------------------------------------------------
# Float tiled execution (paper dataflow, fp arithmetic)
# ---------------------------------------------------------------------------

def systolic_cell_tiled(packed: PackedLSTM, x_t: jax.Array, h_prev: jax.Array,
                        c_prev_blocks: jax.Array
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One timestep in the systolic dataflow, float arithmetic.

    c_prev_blocks: (..., R, tile).  Returns (h_full (..., n_h), h_blocks, c_blocks).
    """
    plan = packed.plan
    xh = pack_xh(x_t, h_prev, plan)                       # steps 1: column slices
    # step 2: per-engine MAC; step 3: row accumulation (sum over c).
    pre = jnp.einsum('rcgij,...cj->...rgi', packed.tiles, xh)
    peep, b = packed.peep, packed.bias
    # step 4: gate nonlinearities + element-wise state update per row chunk.
    i = jax.nn.sigmoid(pre[..., I, :] + peep[:, PEEP_I] * c_prev_blocks + b[:, I])
    f = jax.nn.sigmoid(pre[..., F, :] + peep[:, PEEP_F] * c_prev_blocks + b[:, F])
    g = jnp.tanh(pre[..., G, :] + b[:, G])
    c_t = f * c_prev_blocks + i * g
    o = jax.nn.sigmoid(pre[..., O, :] + peep[:, PEEP_O] * c_t + b[:, O])
    h_blocks = o * jnp.tanh(c_t)
    return unpack_h(h_blocks, plan), h_blocks, c_t       # step 5 done by caller


def systolic_layer_tiled(packed: PackedLSTM, xs: jax.Array) -> jax.Array:
    """Scan the tiled cell over time.  xs: (T, ..., n_x) -> (T, ..., n_h)."""
    plan = packed.plan
    batch = xs.shape[1:-1]
    h0 = jnp.zeros(batch + (plan.n_h,), xs.dtype)
    c0 = jnp.zeros(batch + (plan.rows, plan.tile), xs.dtype)

    def step(carry, x_t):
        h, c = carry
        h, _, c = systolic_cell_tiled(packed, x_t, h, c)
        return (h, c), h

    _, hs = jax.lax.scan(step, (h0, c0), xs)
    return hs


# ---------------------------------------------------------------------------
# Bit-accurate quantized execution (contribution C2)
# ---------------------------------------------------------------------------

# Fixed-point layout (see quant.py): weights/states Q2.5 (int8), gates Q0.7 (int8),
# accumulator Q5.10 (int16, saturating at every inter-engine hop).
ACC_FMT = quant.QFormat(int_bits=5, frac_bits=10)
CELL_FMT = quant.QFormat(int_bits=3, frac_bits=12)  # f*c / i*g alignment format


class QuantizedPackedLSTM(NamedTuple):
    tiles_q: jax.Array  # int8 (R, C, 4, t, t)
    peep_q: jax.Array   # int8 (R, 3, t)
    bias_q: jax.Array   # int16 (R, 4, t)  in ACC_FMT
    sig_lut: jax.Array  # int8 (256,)
    tanh_lut: jax.Array  # int8 (256,)
    plan_shape: Tuple[int, int, int, int]

    @property
    def plan(self) -> SystolicPlan:
        n_x, n_h, tile, _ = self.plan_shape
        return SystolicPlan(n_x, n_h, tile)


def quantize_packed(packed: PackedLSTM) -> QuantizedPackedLSTM:
    wf, sf = quant.WEIGHT_FMT, quant.STATE_FMT
    bias_codes = jnp.clip(
        jnp.round(packed.bias / ACC_FMT.scale),
        -(2 ** 15), 2 ** 15 - 1).astype(jnp.int16)
    sig, tanh = quant.default_luts(sf)
    return QuantizedPackedLSTM(
        tiles_q=quant.quantize(packed.tiles, wf),
        peep_q=quant.quantize(packed.peep, wf),
        bias_q=bias_codes,
        sig_lut=sig, tanh_lut=tanh,
        plan_shape=packed.plan_shape,
    )


def _sat16(x):
    return quant.saturate_int16(x)


_rshift_round = quant.rshift_round


def systolic_cell_quantized(qp: QuantizedPackedLSTM, x_q: jax.Array,
                            h_q: jax.Array, c_q_blocks: jax.Array
                            ) -> Tuple[jax.Array, jax.Array]:
    """One timestep in integer arithmetic, per the silicon datapath.

    x_q: (..., n_x) int8 codes (Q2.5); h_q: (..., n_h) int8; c_q_blocks: (..., R, t)
    int8.  Returns (h_q_new, c_q_blocks_new).  All intermediate semantics follow
    the 16-bit saturating accumulator of the chip.
    """
    plan = qp.plan
    xh_q = pack_xh(x_q, h_q, plan)  # (..., C, t) int8

    # Per-engine tile MAC in wide arithmetic (int32), then saturate to 16 bit —
    # the value an engine hands to its row neighbour.
    partials = jnp.einsum('rcgij,...cj->...rcgi', qp.tiles_q.astype(jnp.int32),
                          xh_q.astype(jnp.int32))
    partials = _sat16(partials)

    # Sequential saturating row accumulation (hop order matters for saturation).
    def hop(acc, p_c):
        return _sat16(acc + p_c), None

    partials_c_first = jnp.moveaxis(partials, -3, 0)  # (C, ..., R, 4, t)
    acc0 = jnp.zeros(partials_c_first.shape[1:], jnp.int32)
    pre_acc, _ = jax.lax.scan(hop, acc0, partials_c_first)  # (..., R, 4, t) Q5.10

    c_prev32 = c_q_blocks.astype(jnp.int32)
    peep32 = qp.peep_q.astype(jnp.int32)
    bias32 = qp.bias_q.astype(jnp.int32)

    def gate(idx, peep_idx, c_term, lut):
        a = pre_acc[..., idx, :] + bias32[:, idx]
        if peep_idx is not None:
            a = a + peep32[:, peep_idx] * c_term  # Q2.5 * Q2.5 -> Q*.10, aligned
        a = _sat16(a)
        a8 = _rshift_round(a, ACC_FMT.frac_bits - quant.STATE_FMT.frac_bits)
        a8 = jnp.clip(a8, -128, 127)
        return quant.apply_lut(lut, a8, quant.STATE_FMT).astype(jnp.int32)  # Q0.7

    i = gate(I, PEEP_I, c_prev32, qp.sig_lut)
    f = gate(F, PEEP_F, c_prev32, qp.sig_lut)
    g = gate(G, None, None, qp.tanh_lut)

    # c_t = f.c + i.g : align Q0.7*Q2.5 (frac 12) with Q0.7*Q0.7 (frac 14) >> 2.
    fc = f * c_prev32                       # frac 12
    ig = _rshift_round(i * g, 2)            # frac 14 -> 12
    c_new = _sat16(fc + ig)                 # Q3.12
    c_new8 = jnp.clip(_rshift_round(c_new, CELL_FMT.frac_bits -
                                    quant.STATE_FMT.frac_bits), -128, 127)

    o = gate(O, PEEP_O, c_new8, qp.sig_lut)
    tanh_c = quant.apply_lut(qp.tanh_lut, c_new8, quant.STATE_FMT).astype(jnp.int32)
    h_new = _rshift_round(o * tanh_c, 14 - quant.STATE_FMT.frac_bits)  # Q0.14 -> Q2.5
    h_blocks8 = jnp.clip(h_new, -128, 127).astype(jnp.int8)

    h_full = unpack_h(h_blocks8, plan)
    return h_full, c_new8.astype(jnp.int8)


def systolic_layer_quantized(qp: QuantizedPackedLSTM, xs_q: jax.Array) -> jax.Array:
    """Scan the integer cell over time.  xs_q: (T, ..., n_x) int8 -> int8 hidden."""
    plan = qp.plan
    batch = xs_q.shape[1:-1]
    h0 = jnp.zeros(batch + (plan.n_h,), jnp.int8)
    c0 = jnp.zeros(batch + (plan.rows, plan.tile), jnp.int8)

    def step(carry, x_t):
        h, c = carry
        h, c = systolic_cell_quantized(qp, x_t, h, c)
        return (h, c), h

    _, hs = jax.lax.scan(step, (h0, c0), xs_q)
    return hs


# ---------------------------------------------------------------------------
# Distributed execution: shard_map over an explicit ("row","col") mesh
# ---------------------------------------------------------------------------

def make_systolic_mesh(rows: int, cols: int, stage: int = 1,
                       devices=None) -> Mesh:
    """Build a (stage, row, col) mesh from the first stage*rows*cols devices.

    This is how the paper's own geometries (5x5, 3x(5x5)) are laid onto a pod:
    a rectangular sub-grid of the available chips.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    need = stage * rows * cols
    if len(devices) < need:
        raise ValueError(f'need {need} devices, have {len(devices)}')
    arr = np.array(devices[:need]).reshape(stage, rows, cols)
    return mesh_with_axis_types(arr, ('stage', 'row', 'col'),
                                axis_types=(AxisType.Auto,) * 3)


def shard_packed_lstm(packed: PackedLSTM, mesh: Mesh) -> PackedLSTM:
    """Place weight tiles so engine (r, c) owns tile (r, c) — weight-stationary."""
    from jax.sharding import NamedSharding
    tiles = jax.device_put(packed.tiles, NamedSharding(mesh, P('row', 'col')))
    peep = jax.device_put(packed.peep, NamedSharding(mesh, P('row')))
    bias = jax.device_put(packed.bias, NamedSharding(mesh, P('row')))
    return PackedLSTM(tiles, peep, bias, packed.plan_shape)


def systolic_lstm_shard_map(packed: PackedLSTM, mesh: Mesh, xs: jax.Array,
                            row_axis: str = 'row', col_axis: str = 'col'):
    """Distributed scan of one LSTM layer with the paper's communication pattern.

    xs: (T, B, padded_in) — the x-region columns carry data, h-region columns are
    zero (they are overwritten by the vertical h re-broadcast each step).
    Requires plan.rows == mesh row size and plan.cols == mesh col size.
    """
    plan = packed.plan
    t = plan.tile
    T, B = xs.shape[0], xs.shape[1]
    assert xs.shape[2] == plan.padded_in
    assert mesh.shape[row_axis] == plan.rows and mesh.shape[col_axis] == plan.cols

    def local_step(w_tile, peep_r, bias_r, xh_col, h_full, c_row):
        """SPMD body on engine (r, c).

        w_tile: (4, t, t); peep_r: (3, t); bias_r: (4, t); xh_col: (B, t);
        h_full: (B, padded_h) — replicated; c_row: (B, t).
        """
        c_idx = jax.lax.axis_index(col_axis)
        # h-region columns take their slice of the re-broadcast hidden state.
        h_off = jnp.maximum(c_idx - plan.cols_x, 0) * t
        h_slice = jax.lax.dynamic_slice(h_full, (0, h_off), (B, t))
        col_in = jnp.where(c_idx < plan.cols_x, xh_col, h_slice)

        partial = jnp.einsum('gij,bj->bgi', w_tile, col_in)       # column loop
        pre = jax.lax.psum(partial, col_axis)                      # row hops
        i = jax.nn.sigmoid(pre[:, I] + peep_r[PEEP_I] * c_row + bias_r[I])
        f = jax.nn.sigmoid(pre[:, F] + peep_r[PEEP_F] * c_row + bias_r[F])
        g = jnp.tanh(pre[:, G] + bias_r[G])
        c_new = f * c_row + i * g
        o = jax.nn.sigmoid(pre[:, O] + peep_r[PEEP_O] * c_new + bias_r[O])
        h_new = o * jnp.tanh(c_new)
        # Vertical re-broadcast of the updated hidden state (paper Fig. 3c).
        h_full_new = jax.lax.all_gather(h_new, row_axis, axis=1, tiled=True)
        return h_full_new, c_new

    def sharded_scan(tiles, peep, bias, xs_sharded):
        w_tile = tiles[0, 0]          # local block after sharding
        peep_r, bias_r = peep[0], bias[0]
        h0 = jnp.zeros((B, plan.padded_h), xs.dtype)
        c0 = jnp.zeros((B, t), xs.dtype)

        def step(carry, x_t):
            h_full, c_row = carry
            h_full, c_row = local_step(w_tile, peep_r, bias_r, x_t, h_full, c_row)
            return (h_full, c_row), h_full

        (_, _), hs = jax.lax.scan(step, (h0, c0), xs_sharded)
        return hs

    other_axes = tuple(n for n in mesh.axis_names if n not in (row_axis, col_axis))
    if any(mesh.shape[a] > 1 for a in other_axes):
        raise ValueError('use systolic_pipeline for meshes with a stage axis')
    fn = shard_map(
        sharded_scan, mesh=mesh,
        in_specs=(P(row_axis, col_axis), P(row_axis), P(row_axis),
                  P(None, None, col_axis)),
        out_specs=P(),
        check_vma=False,
    )
    hs = fn(packed.tiles, packed.peep, packed.bias, xs)
    return hs[..., :plan.n_h]
