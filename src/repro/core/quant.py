"""Fixed-point quantization — Chipmunk contribution C2.

The silicon stores every state variable (weights, x, h, c, gate values) as 8-bit
fixed point and accumulates multiply-adds in 16 bit.  We model that numerically:

* symmetric signed Q-format: value = int_val * 2**-frac_bits, int_val in [-2^(b-1), 2^(b-1)-1]
* straight-through-estimator fake-quant for quantization-aware training,
* 256-entry lookup-table activations (the hardware implements sigmoid/tanh as LUTs),
* saturating int16 partial-sum semantics for the systolic row accumulation.

Scales here are powers of two (true fixed point, as in the chip) by default, but the
API also accepts arbitrary float scales (per-tensor or per-channel) for the beyond-paper
int8 path used by the transformer architectures.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

INT8_MIN, INT8_MAX = -128, 127
INT16_MIN, INT16_MAX = -(2 ** 15), 2 ** 15 - 1


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Signed fixed-point format Q<int_bits>.<frac_bits> (sign bit implicit)."""

    int_bits: int
    frac_bits: int

    @property
    def bits(self) -> int:
        return 1 + self.int_bits + self.frac_bits

    @property
    def scale(self) -> float:
        return 2.0 ** (-self.frac_bits)

    @property
    def max_val(self) -> float:
        return (2 ** (self.bits - 1) - 1) * self.scale

    @property
    def min_val(self) -> float:
        return -(2 ** (self.bits - 1)) * self.scale


# The formats used by the Chipmunk datapath (8-bit storage, 16-bit accumulation).
# Weights/states live in Q2.5 by default: range [-4, 3.97], resolution 2^-5.
WEIGHT_FMT = QFormat(int_bits=2, frac_bits=5)
STATE_FMT = QFormat(int_bits=2, frac_bits=5)
GATE_FMT = QFormat(int_bits=0, frac_bits=7)  # gates are in (-1, 1)
ACCUM_BITS = 16


def quantize(x: jax.Array, fmt: QFormat = STATE_FMT) -> jax.Array:
    """Float -> integer code (int8 for 8-bit formats)."""
    q = jnp.round(x / fmt.scale)
    q = jnp.clip(q, -(2 ** (fmt.bits - 1)), 2 ** (fmt.bits - 1) - 1)
    dtype = jnp.int8 if fmt.bits <= 8 else jnp.int16
    return q.astype(dtype)


def dequantize(q: jax.Array, fmt: QFormat = STATE_FMT) -> jax.Array:
    return q.astype(jnp.float32) * fmt.scale


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x: jax.Array, fmt: QFormat = STATE_FMT) -> jax.Array:
    """Quantize-dequantize with a straight-through gradient (for QAT)."""
    return dequantize(quantize(x, fmt), fmt)


def _fake_quant_fwd(x, fmt):
    return fake_quant(x, fmt), x


def _fake_quant_bwd(fmt, res, g):
    x = res
    # Pass gradients only inside the representable range (clipped STE).
    mask = (x >= fmt.min_val) & (x <= fmt.max_val)
    return (g * mask.astype(g.dtype),)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


# ---------------------------------------------------------------------------
# Arbitrary-scale symmetric int8 (beyond-paper path used for the LM archs)
# ---------------------------------------------------------------------------

def abs_max_scale(x: jax.Array, axis: Optional[int] = None, eps: float = 1e-8) -> jax.Array:
    """Symmetric per-tensor (axis=None) or per-channel scale so x/scale fits int8."""
    amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
        jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, eps) / INT8_MAX


def quantize_scaled(x: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(x / scale), INT8_MIN, INT8_MAX).astype(jnp.int8)


def dequantize_scaled(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_matmul(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                w_scale: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 accumulate -> rescale to fp32.

    Mirrors the MXU's native int8 path (and Chipmunk's 8-bit MAC with wide
    accumulator).  ``w_scale`` may be per-channel of the output dim.
    """
    acc = jax.lax.dot_general(
        x_q, w_q,
        (((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * (x_scale * w_scale)


def saturating_add_int16(a: jax.Array, b: jax.Array) -> jax.Array:
    """Saturating 16-bit add — the semantics of Chipmunk's partial-sum hops."""
    s = a.astype(jnp.int32) + b.astype(jnp.int32)
    return jnp.clip(s, INT16_MIN, INT16_MAX).astype(jnp.int32)


def saturate_int16(x: jax.Array) -> jax.Array:
    return jnp.clip(x, INT16_MIN, INT16_MAX)


def rshift_round(x, shift: int):
    """Arithmetic right shift with round-to-nearest — the silicon's alignment
    step.  Single definition: the systolic cell and the sequence kernel must
    stay bit-identical to each other."""
    return (x + (1 << (shift - 1))) >> shift if shift > 0 else x


# ---------------------------------------------------------------------------
# LUT activations — the hardware's sigmoid/tanh
# ---------------------------------------------------------------------------

def build_act_lut(fn, in_fmt: QFormat, out_fmt: QFormat = GATE_FMT) -> np.ndarray:
    """256-entry table: input code (int8, offset by +128) -> output code (int8).

    Exactly what the silicon's activation LUT contains.
    """
    codes = np.arange(-(2 ** (in_fmt.bits - 1)), 2 ** (in_fmt.bits - 1))
    vals = fn(codes * in_fmt.scale)
    out = np.clip(np.round(vals / out_fmt.scale),
                  -(2 ** (out_fmt.bits - 1)), 2 ** (out_fmt.bits - 1) - 1)
    return out.astype(np.int8)


def apply_lut(lut: jax.Array, q: jax.Array, in_fmt: QFormat) -> jax.Array:
    """Apply a 2**bits entry LUT to integer codes ``q``."""
    idx = q.astype(jnp.int32) + 2 ** (in_fmt.bits - 1)
    return jnp.take(lut, idx, axis=0)


def requantize(acc: jax.Array, acc_fmt: QFormat, out_fmt: QFormat) -> jax.Array:
    """Shift an integer accumulator (acc_fmt) into out_fmt codes (round-to-nearest)."""
    shift = acc_fmt.frac_bits - out_fmt.frac_bits
    if shift >= 0:
        rounded = (acc + (1 << shift >> 1)) >> shift if shift > 0 else acc
    else:
        rounded = acc << (-shift)
    return jnp.clip(rounded, -(2 ** (out_fmt.bits - 1)),
                    2 ** (out_fmt.bits - 1) - 1)


_SIGMOID = lambda z: 1.0 / (1.0 + np.exp(-z))
_TANH = np.tanh


def default_luts(pre_fmt: QFormat = STATE_FMT):
    """(sigmoid_lut, tanh_lut) for gate computation at the given pre-act format."""
    return (jnp.asarray(build_act_lut(_SIGMOID, pre_fmt, GATE_FMT)),
            jnp.asarray(build_act_lut(_TANH, pre_fmt, GATE_FMT)))
