"""Analytical Chipmunk silicon model — contribution C4 (Fig. 5 + Tables 1 & 2).

This container is CPU-only, so the chip's measured voltage/frequency/power behaviour
is reproduced as a calibrated analytical model:

* **f(V)** — linear fit through the two measured shmoo corners
  (0.75 V -> 20 MHz, 1.24 V -> 168 MHz).  UMC 65 nm HVT near-threshold behaviour is
  close to linear in this range.
* **P(V)** — pure dynamic CMOS power P = C_eff * f(V) * V^2 with C_eff fit at the
  1.24 V corner (29.03 mW); predicts 1.26 mW at 0.75 V vs the measured 1.24 mW
  (+1.9 %), confirming leakage is negligible (HVT cells, as the paper states).
* **cycle model** — the paper gives no microarchitectural cycle counts, so we fit two
  constants on two rows of Table 2 and *predict* the third row as validation:
    - ``beta`` (cycles per tile-gate-pass / 96) absorbs the row-accumulation hops,
      LUT + element-wise phase and h re-broadcast.  Fit on the 3x(5x5) row
      (compute-bound, no reloads).
    - ``load_cpb`` (cycles per weight byte per engine, streams parallel across
      engines) absorbs the ready/valid stream protocol overhead.  Fit on the
      single-engine row (reload-bound).
    The 5x5 row is then predicted with no free parameters (-3 % vs paper).
* **Table 2 power** — the paper's own per-engine peak power in Table 2
  (24.45 mW @1.24 V, 2.21 mW @0.75 V) differs from the Fig. 5 chip corners
  (29.03 / 1.24 mW); we reproduce Table 2 with the paper's Table-2 constants and
  note the discrepancy (it is internal to the paper).  Average power follows the
  paper's duty-cycling rule: avg = peak * exec_time / frame_period.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

# --- measured corners (paper Sec. 4.1) -------------------------------------
V_MIN, F_MIN_HZ, P_MIN_W = 0.75, 20e6, 1.24e-3
V_MAX, F_MAX_HZ, P_MAX_W = 1.24, 168e6, 29.03e-3
N_LSTM = 96
CORE_AREA_MM2 = 0.93
DIE_AREA_MM2 = 1.57
SRAM_BYTES = 81_700

# Table 2 per-engine peak power (the paper's own constants for that table).
TABLE2_PEAK_W = {1.24: 24.45e-3, 0.75: 2.21e-3}
FRAME_PERIOD_S = 10e-3  # MFCC frame rate

# f(V) linear fit through the two corners.
_F_SLOPE = (F_MAX_HZ - F_MIN_HZ) / (V_MAX - V_MIN)     # Hz / V
_F_OFFSET = F_MIN_HZ - _F_SLOPE * V_MIN
# P = C_eff * f * V^2, C_eff fit at the 1.24 V corner.
C_EFF = P_MAX_W / (F_MAX_HZ * V_MAX ** 2)


def freq_hz(v: float) -> float:
    """Max clock frequency at core voltage v (valid 0.75..1.24 V)."""
    return _F_SLOPE * v + _F_OFFSET


def power_w(v: float, f_hz: float = None) -> float:
    """Core power at voltage v running at f_hz (defaults to max frequency)."""
    f = freq_hz(v) if f_hz is None else f_hz
    return C_EFF * f * v ** 2


def peak_gops(v: float) -> float:
    """1 MAC = 2 ops (paper footnote 2)."""
    return 2 * N_LSTM * freq_hz(v) / 1e9


def efficiency_gops_per_mw(v: float) -> float:
    return peak_gops(v) / (power_w(v) * 1e3)


def area_efficiency_gops_per_mm2(v: float = V_MAX) -> float:
    return peak_gops(v) / CORE_AREA_MM2


# ---------------------------------------------------------------------------
# Cycle-level model of LSTM execution on a tile configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerDims:
    n_x: int
    n_h: int

    def weight_bytes(self) -> int:
        # 4 gate matrices (8-bit) + 3 peephole vectors (8-bit) + 4 biases (16-bit)
        return (4 * self.n_h * (self.n_x + self.n_h)
                + 3 * self.n_h + 4 * 2 * self.n_h)

    def tile_positions(self, tile: int = N_LSTM) -> Tuple[int, int]:
        rows = math.ceil(self.n_h / tile)
        cols = math.ceil(self.n_x / tile) + math.ceil(self.n_h / tile)
        return rows, cols


# CTC-3L-421H-UNI (Graves et al.): 123 MFCC inputs, 3 layers of 421 hidden units.
CTC_3L_421H = [LayerDims(123, 421), LayerDims(421, 421), LayerDims(421, 421)]

# Calibrated constants (see fit_calibration below; values reproduced in tests).
BETA = 6.5625        # cycles per (tile-gate-pass * 96) — fit on the 3x(5x5) row
LOAD_CPB = 1.61516   # cycles per weight byte per engine — fit on the single row


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """arrays sub-arrays of rows x cols engines (arrays>1 => layer pipeline)."""
    arrays: int
    rows: int
    cols: int

    @property
    def n_engines(self) -> int:
        return self.arrays * self.rows * self.cols

    def label(self) -> str:
        if self.arrays > 1:
            return f'systolic {self.arrays}x{self.rows}x{self.cols}'
        if self.rows * self.cols > 1:
            return f'systolic {self.rows}x{self.cols}'
        return 'single'


def compute_cycles(layers: Sequence[LayerDims], cfg: TileConfig,
                   tile: int = N_LSTM, beta: float = BETA) -> float:
    """Pure compute cycles for one frame (all layers, one timestep each)."""
    total = 0.0
    for ld in layers:
        r, c = ld.tile_positions(tile)
        passes = math.ceil(r / cfg.rows) * math.ceil(c / cfg.cols)
        total += passes * 4 * tile * beta
    return total


def reload_cycles(layers: Sequence[LayerDims], cfg: TileConfig,
                  load_cpb: float = LOAD_CPB) -> float:
    """Weight-streaming cycles per frame.

    The paper: the 3x(5x5) configuration holds the whole network (no reloads);
    smaller configurations must re-stream every layer's weights each frame
    (engines load their shares in parallel).
    """
    if cfg.arrays >= len(layers):
        return 0.0
    total_bytes = sum(ld.weight_bytes() for ld in layers)
    engines = cfg.rows * cfg.cols * cfg.arrays
    return total_bytes / engines * load_cpb


def execution_time_s(layers: Sequence[LayerDims], cfg: TileConfig, v: float,
                     tile: int = N_LSTM) -> float:
    cycles = compute_cycles(layers, cfg, tile) + reload_cycles(layers, cfg)
    return cycles / freq_hz(v)


def table2_row(layers: Sequence[LayerDims], cfg: TileConfig, v: float) -> Dict:
    t_exec = execution_time_s(layers, cfg, v)
    peak_w = TABLE2_PEAK_W[round(v, 2)] * cfg.n_engines
    avg_w = peak_w * min(t_exec / FRAME_PERIOD_S, 1.0)
    return {
        'config': cfg.label(), 'voltage': v,
        'exec_time_ms': t_exec * 1e3,
        'peak_power_mw': peak_w * 1e3,
        'avg_power_mw': avg_w * 1e3,
        'meets_deadline': t_exec <= FRAME_PERIOD_S,
    }


def table2(layers: Sequence[LayerDims] = CTC_3L_421H) -> List[Dict]:
    cfgs = [TileConfig(3, 5, 5), TileConfig(1, 5, 5), TileConfig(1, 1, 1)]
    return [table2_row(layers, cfg, v) for v in (V_MAX, V_MIN) for cfg in cfgs]


# ---------------------------------------------------------------------------
# Stacked-layer wavefront pipelining (the fused-stack schedule, DESIGN.md §8)
# ---------------------------------------------------------------------------
# Table 2 charges a frame the SUM of its layers' cycles — correct for a
# single array re-used layer by layer, but pessimistic for the multi-array
# configurations (and the fused wavefront kernel), where layer l processes
# step t while layer l+1 processes step t-1: in steady state a T-frame
# utterance costs one *bottleneck* layer per diagonal, plus (L-1) fill/drain
# diagonals.  The functions below model that schedule; the calibrated
# ``table2`` path above is deliberately untouched (its per-frame convention
# is what the paper's own numbers encode).


def layer_step_cycles(ld: LayerDims, cfg: TileConfig, tile: int = N_LSTM,
                      beta: float = BETA) -> float:
    """Compute cycles for ONE layer's single timestep on one of ``cfg``'s
    arrays (the per-layer term of ``compute_cycles``)."""
    r, c = ld.tile_positions(tile)
    passes = math.ceil(r / cfg.rows) * math.ceil(c / cfg.cols)
    return passes * 4 * tile * beta


def wavefront_cycles(layers: Sequence[LayerDims], cfg: TileConfig, T: int,
                     tile: int = N_LSTM, beta: float = BETA) -> float:
    """Cycles for a T-step utterance under the wavefront schedule.

    With one array per layer (``cfg.arrays >= len(layers)`` — the paper's
    3x(5x5) placement, and what the fused Pallas kernel emulates on one
    core) the layers pipeline: ``(T + L - 1)`` diagonals, each costing the
    slowest layer's step cycles (fill/drain bubbles included as the
    ``L - 1`` extra diagonals).  Fewer arrays cannot overlap layers — the
    schedule degenerates to the sequential sum, plus the per-frame weight
    re-streaming of ``reload_cycles``.
    """
    per = [layer_step_cycles(ld, cfg, tile, beta) for ld in layers]
    if cfg.arrays >= len(layers):
        return (T + len(layers) - 1) * max(per)
    return T * (sum(per) + reload_cycles(layers, cfg))


def sequential_cycles(layers: Sequence[LayerDims], cfg: TileConfig, T: int,
                      tile: int = N_LSTM, beta: float = BETA) -> float:
    """The pre-pipelining model: every frame pays every layer in sequence
    (what ``compute_cycles`` charges, extended over T steps)."""
    return T * (compute_cycles(layers, cfg, tile, beta)
                + (reload_cycles(layers, cfg)
                   if cfg.arrays < len(layers) else 0.0))


def pipeline_fill_drain_overhead(layers: Sequence[LayerDims],
                                 T: int) -> float:
    """Fraction of wavefront diagonals that are fill/drain bubbles:
    ``(L - 1) / (T + L - 1)``.  At T=1 (the per-frame deadline workload)
    the pipeline is all bubble — sequential execution is optimal — while a
    whole utterance amortises the bubbles to ~L/T."""
    L = len(layers)
    return (L - 1) / (T + L - 1)


def wavefront_gops(layers: Sequence[LayerDims], cfg: TileConfig, v: float,
                   T: int, tile: int = N_LSTM) -> float:
    """Sustained Gop/s of a T-step utterance under the wavefront schedule
    (1 MAC = 2 ops, matrix work only — the convention of ``peak_gops``).
    This is what the fused stack kernel's schedule achieves; the sequential
    model under-reports it by the pipelining factor."""
    ops = 2 * T * sum(4 * ld.n_h * (ld.n_x + ld.n_h) for ld in layers)
    secs = wavefront_cycles(layers, cfg, T, tile) / freq_hz(v)
    return ops / secs / 1e9


# ---------------------------------------------------------------------------
# Stage-pipelined scale-out (the staged fused-systolic schedule, DESIGN.md §9)
# ---------------------------------------------------------------------------
# The wavefront model above pipelines at DIAGONAL granularity (one array per
# layer).  The staged backend pipelines at CHUNK granularity over a
# (stage, row, col) mesh: each of the S = cfg.arrays stages holds one
# contiguous layer block (core.systolic.stage_layer_blocks placement — sizes
# differ by at most one, ceil-sized blocks first) and the utterance streams
# through in K = ceil(T/chunk) chunks, stage s running chunk k while stage
# s+1 runs chunk k-1.  A macro-step costs the BOTTLENECK stage's block
# (its layers run back to back over the chunk), and fill/drain adds S-1
# macro-steps.


def staged_wavefront_cycles(layers: Sequence[LayerDims], cfg: TileConfig,
                            T: int, chunk: int = 1, tile: int = N_LSTM,
                            beta: float = BETA,
                            in_stage_batched: bool = False,
                            blocks: Optional[Sequence[int]] = None) -> float:
    """Cycles for a T-step utterance under the staged pipeline schedule.

    ``(K + S - 1) * max(macro cycles)`` with ``K = ceil(T/chunk)``: every
    macro-step costs the bottleneck stage's layer block over one chunk.
    The in-stage order decides what a macro-step costs (the §9
    ``in_stage`` knob — schedule-only, bit-equal either way):

    * sequential (default, the PR 5 dataflow): the block's layers run slot
      by slot over the chunk — ``Lb * Tc`` rounds, ``chunk * sum(layer
      step cycles)`` per macro-step;
    * ``in_stage_batched``: the (slot, step) grid walks diagonal-major
      with every live slot in one batched dot per diagonal — ``Tc + Lb -
      1`` rounds, each costing the block's WIDEST layer step (the slots
      execute concurrently across the array, so a round is bottlenecked,
      not summed).

    With one layer per stage the two orders coincide, and ``chunk=1``
    reduces exactly to ``wavefront_cycles`` (the per-diagonal schedule);
    fewer stages than layers grow the bottleneck block — trading pipeline
    depth for per-stage serialisation, which is what the Table-2 staged
    comparison quantifies.  ``arrays == 1`` degenerates to the sequential
    model (including per-frame weight re-streaming).

    NOTE the model charges CONCURRENT slots for the batched order — true
    of the silicon (one array per layer) and of any genuinely parallel
    mesh, but NOT of a single-core host emulating the mesh as threads:
    there the per-diagonal skinny GEMMs cost the same FLOPs on the same
    core as the sequential order's hoisted wide GEMMs (at worse GEMM
    efficiency), so the measured single-host ratio falls BELOW 1 while
    this model predicts above — tests/test_perf_model.py pins that
    bracket against BENCH_systolic.json.

    ``blocks`` overrides the balanced split with explicit per-stage layer
    counts (the geometry tuner's uneven-split candidates): ``len(blocks)
    == S`` and ``sum(blocks) == len(layers)``, zeros allowed (an empty
    stage is a pure passthrough delay — it still charges its macro-step
    slot to the pipeline depth but contributes 0 compute cycles).
    """
    S = cfg.arrays
    if S <= 1:
        return sequential_cycles(layers, cfg, T, tile, beta)
    if blocks is not None:
        sizes = [int(b) for b in blocks]
        if len(sizes) != S or sum(sizes) != len(layers) or min(sizes) < 0:
            raise ValueError(f'blocks {sizes!r} is not a {S}-stage split '
                             f'of {len(layers)} layers')
    else:
        base, rem = divmod(len(layers), S)
        sizes = [base + (1 if s < rem else 0) for s in range(S)]
    per_macro, lo = [], 0
    for s in range(S):
        size = sizes[s]
        blk = layers[lo:lo + size]
        lo += size
        steps = [layer_step_cycles(ld, cfg, tile, beta) for ld in blk]
        if in_stage_batched and blk:
            per_macro.append((chunk + len(blk) - 1) * max(steps))
        else:
            per_macro.append(chunk * sum(steps))
    K = math.ceil(T / chunk)
    return (K + S - 1) * max(per_macro)


# ---------------------------------------------------------------------------
# Two-level die hierarchy (§14, after "Vau da Muntanialas")
# ---------------------------------------------------------------------------
# The follow-up paper scales Chipmunk's array across DIES over a serial
# chip-to-chip link: intra-die stage handoffs ride the on-die interconnect
# (already inside the macro-step model above), but a chunk handoff that
# CROSSES a die boundary streams the boundary h chunk over the link.  The
# link is modelled as a cycles-per-byte cost, deliberately slower than the
# on-die weight-load path (LOAD_CPB) — chip-to-chip serial beats neither
# on-die wires nor the L2 port.
INTER_DIE_HOP_CPB = 2.0   # cycles per activation byte over the die link


def die_staged_wavefront_cycles(layers: Sequence[LayerDims],
                                cfg: TileConfig, T: int, *, dies: int,
                                chunk: int = 1, tile: int = N_LSTM,
                                beta: float = BETA,
                                hop_cpb: float = INTER_DIE_HOP_CPB,
                                blocks: Optional[Sequence[int]] = None
                                ) -> float:
    """Staged-pipeline cycles on a two-level die fleet (§14).

    ``cfg.arrays`` is the TOTAL pipeline depth across the healthy dies
    (the flattened ``DieMesh.submesh`` execution form: ``S = dies *
    stage_per_die``), with stages assigned to dies contiguously.  The
    schedule is ``staged_wavefront_cycles`` plus an inter-die hop charge:
    the last stage of every die but the final one streams its chunk's
    boundary h block (``chunk * n_h * 4`` bytes) over the chip-to-chip
    link at ``hop_cpb`` cycles/byte, added to THAT stage's macro-step
    before the bottleneck max — so a hop only costs wall-clock when the
    boundary stage is (or becomes) the pipeline bottleneck.  ``dies <= 1``
    reduces exactly to the single-die staged model, which is what makes
    the 75 → 50 → 25 ladder rungs comparable on one scale."""
    S = cfg.arrays
    if S <= 1:
        return sequential_cycles(layers, cfg, T, tile, beta)
    if dies <= 1:
        return staged_wavefront_cycles(layers, cfg, T, chunk, tile, beta,
                                       blocks=blocks)
    if S % dies:
        raise ValueError(f'{S} stages do not split over {dies} dies')
    if blocks is not None:
        sizes = [int(b) for b in blocks]
        if len(sizes) != S or sum(sizes) != len(layers) or min(sizes) < 0:
            raise ValueError(f'blocks {sizes!r} is not a {S}-stage split '
                             f'of {len(layers)} layers')
    else:
        base, rem = divmod(len(layers), S)
        sizes = [base + (1 if s < rem else 0) for s in range(S)]
    per_die = S // dies
    per_macro, lo = [], 0
    for s in range(S):
        blk = layers[lo:lo + sizes[s]]
        lo += sizes[s]
        macro = chunk * sum(layer_step_cycles(ld, cfg, tile, beta)
                            for ld in blk)
        die_boundary = ((s + 1) % per_die == 0) and s != S - 1
        if die_boundary:
            n_h = blk[-1].n_h if blk else (layers[lo - 1].n_h if lo else 0)
            macro += hop_cpb * chunk * n_h * 4
        per_macro.append(macro)
    K = math.ceil(T / chunk)
    return (K + S - 1) * max(per_macro)


def die_rung_frame_s(layers: Sequence[LayerDims] = CTC_3L_421H,
                     topology: Tuple[int, int, int, int] = (3, 1, 5, 5),
                     healthy_dies: Optional[int] = None,
                     v: float = V_MAX, T: int = 100, chunk: int = 1,
                     hop_cpb: float = INTER_DIE_HOP_CPB) -> float:
    """Modelled per-frame execution time of ONE degradation-ladder rung on
    a ``(dies, stage_per_die, rows, cols)`` die fleet with only
    ``healthy_dies`` dies alive (default: all) — the §14 generalisation of
    ``staged_realtime_frame_s`` that gives the ladder real intermediate
    estimates (graves-3x25: 75 -> 50 -> 25 engines) instead of one cliff.
    Each rung runs the flattened healthy submesh (pipeline depth =
    ``healthy * stage_per_die`` at the same per-stage grid), so rungs sit
    on one comparable scale; a rung whose depth exceeds the layer count is
    clamped to ``len(layers)`` stages (idle stages add bubbles, never
    compute)."""
    dies, stage_per_die, rows, cols = topology
    healthy = dies if healthy_dies is None else healthy_dies
    assert 1 <= healthy <= dies, (healthy, dies)
    depth = min(healthy * stage_per_die, len(layers))
    cyc = die_staged_wavefront_cycles(
        layers, TileConfig(depth, rows, cols), T,
        dies=max(1, depth // max(1, stage_per_die)), chunk=chunk,
        hop_cpb=hop_cpb)
    return cyc / T / freq_hz(v)


def staged_fill_drain_overhead(n_stages: int, T: int,
                               chunk: int = 1) -> float:
    """Fraction of staged macro-steps that are pipeline fill/drain:
    ``(S - 1) / (K + S - 1)`` with ``K = ceil(T/chunk)``.  Bigger chunks
    amortise per-chunk handover but deepen fill/drain (each bubble now
    costs a whole chunk); ``chunk=1`` recovers the §8 per-diagonal bubble
    fraction."""
    K = math.ceil(T / chunk)
    return (n_stages - 1) / (K + n_stages - 1)


def staged_realtime_frame_s(layers: Sequence[LayerDims] = CTC_3L_421H,
                            cfg: TileConfig = TileConfig(3, 5, 5),
                            v: float = V_MAX, T: int = 100,
                            chunk: int = 1) -> float:
    """Steady-state per-frame execution time of the staged schedule — the
    ``graves-75`` real-time estimate: a T-frame stream's staged cycles,
    amortised per frame.  Validated in tests against the paper's Table-2
    real-time claim (the 3x(5x5) configuration meets the 10 ms MFCC frame
    deadline at both measured voltages; the staged steady state needs only
    the bottleneck layer per frame, so it can only improve on the
    sum-of-layers row)."""
    return staged_wavefront_cycles(layers, cfg, T, chunk) / T / freq_hz(v)


def realtime_chunk_budget_s(chunk: int, slack: float = 1.0) -> float:
    """Wall-clock budget of one serving chunk under the paper's REAL-TIME
    contract: ``chunk`` MFCC frames arrive every ``FRAME_PERIOD_S`` (10 ms),
    so a chunk that takes longer than ``chunk * FRAME_PERIOD_S * slack``
    falls behind the sensor — the Table-2 deadline the serving layer's
    chunk-size policy enforces (DESIGN.md §11).  Distinct from
    ``staged_realtime_frame_s``: that is the modelled silicon EXECUTION time
    per frame (used by the §10 watchdog via ``chunk_deadline_s``); this is
    the arrival-rate deadline the stream must keep up with.  ``slack`` < 1
    demands headroom, > 1 tolerates a host-emulation handicap."""
    assert chunk >= 1 and slack > 0, (chunk, slack)
    return chunk * FRAME_PERIOD_S * slack


def staged_frames_within_s(budget_s: float, **kw) -> int:
    """How many frames the staged schedule can EXECUTE inside ``budget_s``
    (floor of budget over ``staged_realtime_frame_s(**kw)``) — the
    model-derived seed for the serving chunk-size policy: the largest chunk
    whose modelled execution fits the per-chunk budget.  Pure model
    arithmetic, no numerics of its own."""
    per_frame = staged_realtime_frame_s(**kw)
    return max(1, int(budget_s / per_frame))


# Published Table 2 values for validation: (config, voltage) -> exec ms.
PAPER_TABLE2_MS = {
    ('systolic 3x5x5', 1.24): 0.09, ('systolic 5x5', 1.24): 1.59,
    ('single', 1.24): 38.23,
    ('systolic 3x5x5', 0.75): 0.76, ('systolic 5x5', 0.75): 13.31,
    ('single', 0.75): 321.14,
}


def fit_calibration(layers: Sequence[LayerDims] = CTC_3L_421H
                    ) -> Tuple[float, float]:
    """Re-derive (beta, load_cpb) from the paper's Table 2, for documentation.

    beta from the reload-free 3x(5x5) row; load_cpb from the single-engine row
    after subtracting modelled compute.  Returns the constants baked in above.
    """
    target_3x55 = PAPER_TABLE2_MS[('systolic 3x5x5', 1.24)] * 1e-3 * F_MAX_HZ
    raw = compute_cycles(layers, TileConfig(3, 5, 5), beta=1.0)
    beta = target_3x55 / raw

    target_single = PAPER_TABLE2_MS[('single', 1.24)] * 1e-3 * F_MAX_HZ
    comp = compute_cycles(layers, TileConfig(1, 1, 1), beta=beta)
    total_bytes = sum(ld.weight_bytes() for ld in layers)
    load_cpb = (target_single - comp) / total_bytes
    return beta, load_cpb
