"""Layer pipelining over systolic sub-arrays — Chipmunk contribution C3b.

The paper's best configuration (3x(5x5)) dedicates one 5x5 sub-array per LSTM layer:
after the initial weight load, no reconfiguration ever happens and frames stream
through the three stages.  We map this to a ("stage", "row", "col") mesh: stage s
owns layer s's weight tiles; the hidden state of stage s-1 is handed to stage s via
``lax.ppermute`` (the board-level wiring between sub-arrays).

At global microstep k, stage s processes timestep k - s of layer s (a classic
1F pipeline with S-1 bubbles at fill/drain).  All layers are padded to a common
SystolicPlan so the mesh is rectangular; the silicon instead time-multiplexes tile
positions per engine — see core/perf_model.py for the cycle accounting of that.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map

from .lstm import GATES, I, F, G, O, PEEP_I, PEEP_F, PEEP_O, LSTMParams
from .systolic import PackedLSTM, SystolicPlan, pack_lstm


def pack_pipeline(layers: Sequence[LSTMParams], tile: int) -> Tuple[PackedLSTM, SystolicPlan]:
    """Pack S layers into stacked tiles (S, R, C, 4, t, t) under a common plan.

    Layers with a smaller input dim (e.g. layer 0: N_x=123 vs N_h=421 elsewhere)
    are zero-padded on the input-column side.
    """
    n_h = layers[0].n_h
    assert all(l.n_h == n_h for l in layers), 'pipeline layers must share N_h'
    n_x_max = max(l.n_x for l in layers)
    plan = SystolicPlan(n_x_max, n_h, tile)
    packs = []
    for l in layers:
        lp = LSTMParams(
            w_x=jnp.zeros((GATES, l.w_x.shape[1], n_x_max), l.w_x.dtype
                          ).at[:, :, :l.n_x].set(l.w_x),
            w_h=l.w_h, w_peep=l.w_peep, b=l.b)
        packs.append(pack_lstm(lp, plan))
    stacked = PackedLSTM(
        tiles=jnp.stack([p.tiles for p in packs]),
        peep=jnp.stack([p.peep for p in packs]),
        bias=jnp.stack([p.bias for p in packs]),
        plan_shape=packs[0].plan_shape)
    return stacked, plan


def shard_pipeline(packed: PackedLSTM, mesh: Mesh) -> PackedLSTM:
    return PackedLSTM(
        tiles=jax.device_put(packed.tiles, NamedSharding(mesh, P('stage', 'row', 'col'))),
        peep=jax.device_put(packed.peep, NamedSharding(mesh, P('stage', 'row'))),
        bias=jax.device_put(packed.bias, NamedSharding(mesh, P('stage', 'row'))),
        plan_shape=packed.plan_shape)


def systolic_pipeline(packed: PackedLSTM, mesh: Mesh, xs: jax.Array,
                      stage_axis: str = 'stage', row_axis: str = 'row',
                      col_axis: str = 'col') -> jax.Array:
    """Run S pipelined LSTM layers over xs: (T, B, padded_x) -> (T, B, n_h).

    Requires mesh sizes (S, plan.rows, plan.cols).
    """
    plan = packed.plan
    t = plan.tile
    S = mesh.shape[stage_axis]
    T, B = xs.shape[0], xs.shape[1]
    assert xs.shape[2] == plan.padded_x
    assert mesh.shape[row_axis] == plan.rows and mesh.shape[col_axis] == plan.cols
    K = T + S - 1  # fill + drain

    def body(tiles, peep, bias, xs_padded):
        w_tile = tiles[0, 0, 0]     # (4, t, t) local block
        peep_r, bias_r = peep[0, 0], bias[0, 0]
        s_idx = jax.lax.axis_index(stage_axis)
        c_idx = jax.lax.axis_index(col_axis)
        fwd = [(i, (i + 1) % S) for i in range(S)]  # stage s -> s+1 (ring; wrap ignored)

        h_own0 = jnp.zeros((B, plan.padded_h), xs.dtype)   # recurrent state h^l
        c_row0 = jnp.zeros((B, t), xs.dtype)

        def step(carry, k_and_x):
            k_idx, x_k = k_and_x
            h_own, c_row = carry
            # Hand the previous step's output of stage s-1 to stage s (Fig. 3 wiring).
            handed = jax.lax.ppermute(h_own, stage_axis, fwd)
            pad = jnp.zeros((B, plan.padded_x - plan.padded_h), xs.dtype) \
                if plan.padded_x > plan.padded_h else None
            handed_x = (jnp.concatenate([handed, pad], axis=1)[:, :plan.padded_x]
                        if pad is not None else handed[:, :plan.padded_x])
            stage_in = jnp.where(s_idx == 0, x_k, handed_x)

            # Column input: x-region columns slice stage_in, h-region columns
            # slice the locally re-broadcast h_own.
            x_off = jnp.minimum(c_idx, plan.cols_x - 1) * t
            x_slice = jax.lax.dynamic_slice(stage_in, (0, x_off), (B, t))
            h_off = jnp.maximum(c_idx - plan.cols_x, 0) * t
            h_slice = jax.lax.dynamic_slice(h_own, (0, h_off), (B, t))
            col_in = jnp.where(c_idx < plan.cols_x, x_slice, h_slice)

            partial = jnp.einsum('gij,bj->bgi', w_tile, col_in)
            pre = jax.lax.psum(partial, col_axis)
            i = jax.nn.sigmoid(pre[:, I] + peep_r[PEEP_I] * c_row + bias_r[I])
            f = jax.nn.sigmoid(pre[:, F] + peep_r[PEEP_F] * c_row + bias_r[F])
            g = jnp.tanh(pre[:, G] + bias_r[G])
            c_new = f * c_row + i * g
            o = jax.nn.sigmoid(pre[:, O] + peep_r[PEEP_O] * c_new + bias_r[O])
            h_new = o * jnp.tanh(c_new)
            h_full = jax.lax.all_gather(h_new, row_axis, axis=1, tiled=True)
            # Stages idle until their first real input arrives (pipeline fill).
            active = k_idx >= s_idx
            h_own = jnp.where(active, h_full, h_own)
            c_row = jnp.where(active, c_new, c_row)
            return (h_own, c_row), h_own

        ks = jnp.arange(K)
        (_, _), hs = jax.lax.scan(step, (h_own0, c_row0), (ks, xs_padded))
        # Keep only the last stage's view (identical across row/col by psum/gather).
        out = jnp.where(s_idx == S - 1, hs, jnp.zeros_like(hs))
        return jax.lax.psum(out, stage_axis)  # (K, B, padded_h), replicated

    xs_padded = jnp.concatenate(
        [xs, jnp.zeros((S - 1, B, plan.padded_x), xs.dtype)], axis=0)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis, row_axis, col_axis),
                  P(stage_axis, row_axis), P(stage_axis, row_axis),
                  P(None, None, None)),
        out_specs=P(None, None, None),
        check_vma=False,
    )
    hs = fn(packed.tiles, packed.peep, packed.bias, xs_padded)  # (K, B, Ph)
    return hs[S - 1:K, :, :plan.n_h]
