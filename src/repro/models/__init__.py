"""Model zoo: transformer (dense/MoE/VLM/enc-dec), xLSTM, Mamba/Hymba, and the
paper's CTC LSTM.  Select via configs + registry.get_bundle."""
from . import chipmunk_net, layers, recurrent, registry, transformer
from .registry import ModelBundle, batch_axes, get_bundle, input_specs

__all__ = ['chipmunk_net', 'layers', 'recurrent', 'registry', 'transformer',
           'ModelBundle', 'batch_axes', 'get_bundle', 'input_specs']
