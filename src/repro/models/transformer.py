"""Decoder-only LM (dense / MoE / VLM cross-attn) + encoder-decoder (audio).

Layers are scanned (stacked params) so 61-100 layer graphs lower quickly at
512 devices.  VLM interleaving (1 cross layer per N) scans over *groups* of
(N-1 self + 1 cross) layers, keeping the stack homogeneous.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import ArchConfig
from ..sharding import logical
from . import layers as L

f32 = jnp.float32


# ------------------------------------------------------------------ init
def _init_block(cfg: ArchConfig, key, cross: bool = False):
    gen = L.keygen(key)
    dtype = cfg.dtype()
    p, ax = {}, {}
    p['ln1'], ax['ln1'] = L.init_norm(cfg, dtype)
    p['attn'], ax['attn'] = L.init_attention(cfg, gen, dtype, cross=cross)
    p['ln2'], ax['ln2'] = L.init_norm(cfg, dtype)
    if cfg.moe is not None:
        p['moe'], ax['moe'] = L.init_moe(cfg, gen, dtype)
    else:
        p['mlp'], ax['mlp'] = L.init_mlp(cfg, gen, dtype)
    return p, ax


def _stack_init(init_fn, key, n):
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(keys[0])
    axes = jax.tree.map(lambda a: ('layers',) + a, axes,
                        is_leaf=lambda v: isinstance(v, tuple) and all(
                            x is None or isinstance(x, str) for x in v))
    return params, axes


def init_lm(cfg: ArchConfig, key):
    gen = L.keygen(key)
    dtype = cfg.dtype()
    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    params['embed'], axes['embed'] = L.init_embedding(cfg, gen, dtype)
    if cfg.cross_attn_every:                     # vlm: groups of (N-1 self + 1 cross)
        per, n_groups = cfg.cross_attn_every, cfg.n_layers // cfg.cross_attn_every
        params['blocks'], axes['blocks'] = _stack_init(
            lambda k: _stack_init(lambda kk: _init_block(cfg, kk), k, per - 1),
            gen(), n_groups)
        params['cross'], axes['cross'] = _stack_init(
            lambda k: _init_block(cfg, k, cross=True), gen(), n_groups)
    else:
        params['blocks'], axes['blocks'] = _stack_init(
            lambda k: _init_block(cfg, k), gen(), cfg.n_layers)
    if cfg.n_encoder_layers:                     # audio enc-dec
        params['enc_blocks'], axes['enc_blocks'] = _stack_init(
            lambda k: _init_block(cfg, k), gen(), cfg.n_encoder_layers)
        params['enc_cross'], axes['enc_cross'] = _stack_init(
            lambda k: _init_block(cfg, k, cross=True), gen(), cfg.n_layers)
        params['enc_norm'], axes['enc_norm'] = L.init_norm(cfg, dtype)
    params['final_norm'], axes['final_norm'] = L.init_norm(cfg, dtype)
    return params, axes


# ------------------------------------------------------------------ blocks
def _self_block(cfg, p, x, positions, window):
    dt = x.dtype
    h = L.apply_norm(cfg, p['ln1'], x)
    x = (x + L.attention_block(cfg, p['attn'], h, positions=positions,
                               causal=True, window=window)).astype(dt)
    h = L.apply_norm(cfg, p['ln2'], x)
    if cfg.moe is not None:
        y, aux = L.moe_block(cfg, p['moe'], h)
    else:
        y, aux = L.mlp_block(cfg, p['mlp'], h), 0.0
    return (x + y).astype(dt), aux


def _cross_block(cfg, p, x, source):
    dt = x.dtype
    h = L.apply_norm(cfg, p['ln1'], x)
    x = (x + L.attention_block(cfg, p['attn'], h, positions=None,
                               causal=False, kv_src=source, cross=True)
         ).astype(dt)
    h = L.apply_norm(cfg, p['ln2'], x)
    if cfg.moe is not None:
        y, aux = L.moe_block(cfg, p['moe'], h)
    else:
        y, aux = L.mlp_block(cfg, p['mlp'], h), 0.0
    return (x + y).astype(dt), aux


def _maybe_remat(cfg, fn):
    if cfg.remat == 'none':
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == 'dots' else None)
    return jax.checkpoint(fn, policy=policy)


def _encoder(cfg, params, frames):
    """Audio encoder over stub frame embeddings (B, F, D): bidirectional."""
    x = frames.astype(cfg.adtype())
    pos = jnp.arange(x.shape[1])

    def body(x, blk):
        dt = x.dtype
        h = L.apply_norm(cfg, blk['ln1'], x)
        x = (x + L.attention_block(cfg, blk['attn'], h, positions=pos,
                                   causal=False)).astype(dt)
        h = L.apply_norm(cfg, blk['ln2'], x)
        return (x + L.mlp_block(cfg, blk['mlp'], h)).astype(dt), None

    x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params['enc_blocks'])
    return L.apply_norm(cfg, params['enc_norm'], x)


def forward_lm(cfg: ArchConfig, params, tokens, source: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train / prefill).  Returns (logits, aux_loss).

    source: stub frontend embeddings — audio frames or image patches (B,F,D).
    """
    x = L.embed(cfg, params['embed'], tokens)
    positions = jnp.arange(tokens.shape[1])
    window = cfg.sliding_window
    aux_total = 0.0

    if cfg.n_encoder_layers:
        enc = _encoder(cfg, params, source)

        def body(x, blks):
            blk, xblk = blks
            x, aux = _self_block(cfg, blk, x, positions, window)
            x2, aux2 = _cross_block(cfg, xblk, x, enc)
            return x2, aux + aux2

        x, auxs = jax.lax.scan(_maybe_remat(cfg, body), x,
                               (params['blocks'], params['enc_cross']))
        aux_total = jnp.sum(auxs)
    elif cfg.cross_attn_every:
        src = source.astype(cfg.adtype())

        def body(x, blks):
            group, xblk = blks
            aux = 0.0
            for i in range(cfg.cross_attn_every - 1):
                blk_i = jax.tree.map(lambda a: a[i], group)
                x, a = _self_block(cfg, blk_i, x, positions, window)
                aux += a
            x, a = _cross_block(cfg, xblk, x, src)
            return x, aux + a

        x, auxs = jax.lax.scan(_maybe_remat(cfg, body), x,
                               (params['blocks'], params['cross']))
        aux_total = jnp.sum(auxs)
    else:
        def body(x, blk):
            return _self_block(cfg, blk, x, positions, window)

        x, auxs = jax.lax.scan(_maybe_remat(cfg, body), x, params['blocks'])
        aux_total = jnp.sum(auxs)

    x = L.apply_norm(cfg, params['final_norm'], x)
    return L.unembed(cfg, params['embed'], x), aux_total


def loss_fn(cfg: ArchConfig, params, batch):
    logits, aux = forward_lm(cfg, params, batch['tokens'],
                             source=batch.get('source'))
    return L.softmax_xent(logits, batch['labels']) + 0.01 * aux


# ------------------------------------------------------------------ serving
def _cache_window(cfg, layer_id, max_seq):
    if cfg.sliding_window is None:
        return max_seq
    return max_seq if layer_id in cfg.global_layer_ids \
        else min(cfg.sliding_window, max_seq)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    """KV cache pytree (+ logical axes).  Ring-buffered for SWA layers."""
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.adtype()
    w = min(cfg.sliding_window or max_seq, max_seq)
    n = cfg.n_layers
    if cfg.cross_attn_every:
        n = cfg.n_layers - cfg.n_layers // cfg.cross_attn_every
    cache = {
        'k': jnp.zeros((n, batch, w, hk, hd), dt),
        'v': jnp.zeros((n, batch, w, hk, hd), dt),
        'pos': jnp.full((n, w), -1, jnp.int32),
    }
    axes = {'k': ('layers', 'batch', 'kv_seq', 'kv_heads', 'head_dim_act'),
            'v': ('layers', 'batch', 'kv_seq', 'kv_heads', 'head_dim_act'),
            'pos': ('layers', 'kv_seq')}
    return cache, axes


def precompute_cross_kv(cfg, params, source):
    """Encoder/image K,V per cross layer — computed once per request."""
    if cfg.n_encoder_layers:
        src = _encoder(cfg, params, source)
        blocks = params['enc_cross']
    elif cfg.cross_attn_every:
        src = source.astype(cfg.adtype())
        blocks = params['cross']
    else:
        return None

    def kv_of(blk):
        k = jnp.einsum('bfd,dhk->bfhk', src, blk['attn']['wk'])
        v = jnp.einsum('bfd,dhk->bfhk', src, blk['attn']['wv'])
        return k, v

    return jax.lax.map(kv_of, blocks)


def decode_step(cfg: ArchConfig, params, cache, tokens, pos,
                cross_kv=None):
    """One decode step.  tokens: (B, 1); pos: scalar int32.  Returns
    (logits (B, 1, V), new cache)."""
    x = L.embed(cfg, params['embed'], tokens)
    window = cfg.sliding_window

    def self_body(x, blk_cache):
        blk, c = blk_cache
        h = L.apply_norm(cfg, blk['ln1'], x)
        o, c = L.attention_decode(cfg, blk['attn'], h, c, pos=pos,
                                  window=window)
        x = (x + o).astype(cfg.adtype())
        h = L.apply_norm(cfg, blk['ln2'], x)
        if cfg.moe is not None:
            y, _ = L.moe_block(cfg, blk['moe'], h)
        else:
            y = L.mlp_block(cfg, blk['mlp'], h)
        return (x + y).astype(cfg.adtype()), c

    if cfg.cross_attn_every:
        per = cfg.cross_attn_every
        n_groups = cfg.n_layers // per

        def body(x, xs):
            group, xblk, c_group, ckv = xs
            new_c = []
            for i in range(per - 1):
                blk_i = jax.tree.map(lambda a: a[i], group)
                c_i = jax.tree.map(lambda a: a[i], c_group)
                x, c_i = self_body(x, (blk_i, c_i))
                new_c.append(c_i)
            h = L.apply_norm(cfg, xblk['ln1'], x)
            o, _ = L.attention_decode(cfg, xblk['attn'], h, None, pos=pos,
                                      cross_kv=ckv)
            x = (x + o).astype(cfg.adtype())
            h = L.apply_norm(cfg, xblk['ln2'], x)
            x = (x + L.mlp_block(cfg, xblk['mlp'], h)).astype(cfg.adtype())
            c_group = jax.tree.map(lambda *xs_: jnp.stack(xs_), *new_c)
            return x, c_group

        c_grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, per - 1) + a.shape[1:]), cache)
        x, new_cache = jax.lax.scan(
            body, x, (params['blocks'], params['cross'], c_grouped, cross_kv))
        new_cache = jax.tree.map(
            lambda a: a.reshape((n_groups * (per - 1),) + a.shape[2:]), new_cache)
    elif cfg.n_encoder_layers:
        def body(x, xs):
            blk, xblk, c, ckv = xs
            x, c = self_body(x, (blk, c))
            h = L.apply_norm(cfg, xblk['ln1'], x)
            o, _ = L.attention_decode(cfg, xblk['attn'], h, None, pos=pos,
                                      cross_kv=ckv)
            x = (x + o).astype(cfg.adtype())
            h = L.apply_norm(cfg, xblk['ln2'], x)
            x = (x + L.mlp_block(cfg, xblk['mlp'], h)).astype(cfg.adtype())
            return x, c

        x, new_cache = jax.lax.scan(
            body, x, (params['blocks'], params['enc_cross'], cache, cross_kv))
    else:
        x, new_cache = jax.lax.scan(self_body, x, (params['blocks'], cache))

    x = L.apply_norm(cfg, params['final_norm'], x)
    return L.unembed(cfg, params['embed'], x), new_cache
