"""CTC-3L-421H-UNI — the paper's real-world workload as a trainable model.

123 MFCC features -> 3x421 peephole LSTM -> 62 CTC outputs.  Parameters carry
the systolic logical axes ('lstm_row' -> TP, 'lstm_col' -> DP/FSDP): under the
production mesh the weight matrices are 2-D block-tiled exactly like the paper's
engine grid, and XLA emits the systolic schedule (partial-sum reduce over cols,
hidden-state gather over rows) from the sharding constraints.

Inference additionally supports the bit-accurate int8 systolic path and the
pipelined 3x(RxC) execution (see core/).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs import ArchConfig
from ..core import ctc
from ..core.lstm import (LSTMParams, LSTMStackParams, init_lstm_stack,
                         lstm_stack_apply, lstm_stack_chunk)
from ..sharding import logical


def init(cfg: ArchConfig, key):
    params = init_lstm_stack(key, cfg.lstm_inputs, cfg.lstm_hidden,
                             cfg.n_layers, cfg.n_outputs, cfg.dtype())
    layer_axes = LSTMParams(
        w_x=(None, 'lstm_row', 'lstm_col'),
        w_h=(None, 'lstm_row', 'lstm_col'),
        w_peep=(None, 'lstm_row'),
        b=(None, 'lstm_row'))
    axes = LSTMStackParams(
        layers=tuple(layer_axes for _ in range(cfg.n_layers)),
        w_out=('lstm_row', 'lstm_col'), b_out=('lstm_row',))
    return params, axes


def forward(cfg: ArchConfig, params: LSTMStackParams, frames: jax.Array):
    """frames: (B, T, n_in) -> log-probs (T, B, n_out).

    The execution engine (XLA scan / per-step Pallas / whole-sequence Pallas /
    fused whole-stack wavefront / multi-engine systolic scale-out / staged
    fused-systolic pipeline) is selected by ``cfg.lstm_backend`` — call
    sites never change (DESIGN.md §3.3, §6, §8, §9).  With ``auto`` and an
    installed systolic mesh the stack runs tile-stationary across engines;
    on ``pallas_seq_fused`` all three layers run in ONE wavefront launch
    with the inter-layer hidden sequences never leaving on-chip scratch;
    on ``pallas_seq_fused_systolic`` with the ``graves-75`` preset each
    5x5 stage holds one layer stationary — the paper's Table-2 topology
    end to end.
    """
    xs = jnp.moveaxis(frames, 0, 1)                    # (T, B, n_in)
    xs = logical(xs, 'seq', 'batch', None)
    ys, _ = lstm_stack_apply(params, xs, backend=cfg.lstm_backend)
    return jax.nn.log_softmax(ys, axis=-1)


def loss_fn(cfg: ArchConfig, params, batch: Dict[str, jax.Array]):
    log_probs = forward(cfg, params, batch['frames'])
    nll = ctc.ctc_loss(log_probs, batch['labels'],
                       batch['frame_len'], batch['label_len'])
    return jnp.mean(nll)


def init_state(cfg: ArchConfig, batch: int):
    """Streaming state: (h, c) per layer — the chip's retained internal state."""
    n_h = cfg.lstm_hidden
    states = tuple(
        (jnp.zeros((batch, n_h), cfg.dtype()), jnp.zeros((batch, n_h), cfg.dtype()))
        for _ in range(cfg.n_layers))
    ax = tuple((('batch', 'lstm_row'), ('batch', 'lstm_row'))
               for _ in range(cfg.n_layers))
    return states, ax


def stream_forward(cfg: ArchConfig, params: LSTMStackParams, states, frames,
                   valid_len=None):
    """A chunk of streaming frames through the network — the generalisation
    of the old one-frame ``stream_step`` (the Table-2 deadline workload is
    ``frames.shape[1] == 1``) and the model half of the serving engine
    (``serving.StreamingEngine``, DESIGN.md §7).

    frames: (B, T, n_in); states: per-layer ``(h, c)`` from ``init_state`` /
    the previous chunk; ``valid_len``: optional (B,) per-stream valid frame
    counts — steps ``t >= valid_len[b]`` are identity on every layer's
    carried state, so ragged streams can share one packed call.  Returns
    (log-probs (B, T, n_out), new states).  Feeding chunks back to back is
    bit-equal to one whole-sequence call on the same backend, and the
    composition from zero state is allclose to ``forward``.
    """
    xs = jnp.moveaxis(frames, 0, 1)                    # (T, B, n_in)
    ys, new_states = lstm_stack_chunk(params, xs, states,
                                      valid_len=valid_len,
                                      backend=cfg.lstm_backend)
    log_probs = jax.nn.log_softmax(ys, axis=-1)        # (T, B, n_out)
    return jnp.moveaxis(log_probs, 0, 1), new_states
