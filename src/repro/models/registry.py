"""Model registry: one uniform bundle per architecture family.

The launchers (dryrun/train/serve), benchmarks and tests all go through
``get_bundle(cfg)`` — models are selected by ``--arch`` name.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ArchConfig, ShapeConfig
from . import chipmunk_net, recurrent, transformer

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable                      # key -> (params, axes)
    loss_fn: Callable                   # (params, batch) -> scalar
    forward: Callable                   # (params, batch) -> logits
    init_cache: Optional[Callable]      # (batch, max_seq) -> (cache, axes)
    decode_step: Optional[Callable]     # (params, cache, tokens, pos) -> (logits, cache)

    def param_count(self, params) -> int:
        return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))

    def active_param_count(self, params) -> int:
        """MoE: experts count at top_k/E weight; used for MODEL_FLOPS=6*N_active*D."""
        total = self.param_count(params)
        if self.cfg.moe is None:
            return total
        m = self.cfg.moe
        expert = 0
        if isinstance(params, dict) and 'blocks' in params:
            moe_p = params['blocks'].get('moe')
            if moe_p is not None:
                expert = sum(int(np.prod(p.shape)) for k, p in moe_p.items()
                             if k != 'router')
        return total - expert + int(expert * m.top_k / m.n_experts)


# ------------------------------------------------------------------- builders
def _lm_bundle(cfg: ArchConfig) -> ModelBundle:
    return ModelBundle(
        cfg=cfg,
        init=functools.partial(transformer.init_lm, cfg),
        loss_fn=functools.partial(transformer.loss_fn, cfg),
        forward=lambda p, batch: transformer.forward_lm(
            cfg, p, batch['tokens'], source=batch.get('source'))[0],
        init_cache=functools.partial(transformer.init_cache, cfg),
        decode_step=functools.partial(transformer.decode_step, cfg),
    )


def _xlstm_bundle(cfg: ArchConfig) -> ModelBundle:
    def loss(p, batch):
        logits, _ = recurrent.forward_xlstm(cfg, p, batch['tokens'])
        from .layers import softmax_xent
        return softmax_xent(logits, batch['labels'])

    def fwd(p, batch):
        return recurrent.forward_xlstm(cfg, p, batch['tokens'])[0]

    def decode(p, states, tokens, pos):
        logits, states = recurrent.forward_xlstm(cfg, p, tokens, states=states)
        return logits, states

    return ModelBundle(
        cfg=cfg,
        init=functools.partial(recurrent.init_xlstm, cfg),
        loss_fn=loss, forward=fwd,
        init_cache=lambda b, s: recurrent.init_xlstm_state(cfg, b),
        decode_step=decode,
    )


def _hymba_bundle(cfg: ArchConfig) -> ModelBundle:
    def loss(p, batch):
        logits = recurrent.forward_hymba(cfg, p, batch['tokens'])
        from .layers import softmax_xent
        return softmax_xent(logits, batch['labels'])

    return ModelBundle(
        cfg=cfg,
        init=functools.partial(recurrent.init_hymba, cfg),
        loss_fn=loss,
        forward=lambda p, batch: recurrent.forward_hymba(cfg, p, batch['tokens']),
        init_cache=functools.partial(recurrent.init_hymba_cache, cfg),
        decode_step=functools.partial(recurrent.hymba_decode_step, cfg),
    )


def _chipmunk_bundle(cfg: ArchConfig) -> ModelBundle:
    def decode(p, states, frames, pos):
        # one-frame special case of the chunked streaming forward
        return chipmunk_net.stream_forward(cfg, p, states, frames)

    return ModelBundle(
        cfg=cfg,
        init=functools.partial(chipmunk_net.init, cfg),
        loss_fn=functools.partial(chipmunk_net.loss_fn, cfg),
        forward=lambda p, batch: chipmunk_net.forward(cfg, p, batch['frames']),
        init_cache=lambda b, s: chipmunk_net.init_state(cfg, b),
        decode_step=decode,
    )


def get_bundle(cfg: ArchConfig) -> ModelBundle:
    if cfg.family == 'lstm':
        return _chipmunk_bundle(cfg)
    if cfg.family == 'ssm':
        return _xlstm_bundle(cfg)
    if cfg.family == 'hybrid':
        return _hymba_bundle(cfg)
    return _lm_bundle(cfg)          # dense | moe | audio | vlm


# --------------------------------------------------------------- input specs
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this (arch, shape).

    Train/prefill: token batch (+ stub frontend embeddings for audio/vlm).
    Decode: one new token (+ pos); the cache is produced by init_cache.
    """
    b = shape.global_batch
    sds = jax.ShapeDtypeStruct
    if shape.kind in ('train', 'prefill'):
        s = shape.seq_len
        batch = {'tokens': sds((b, s), jnp.int32)}
        if shape.kind == 'train':
            batch['labels'] = sds((b, s), jnp.int32)
        if cfg.family == 'audio':
            batch['source'] = sds((b, cfg.n_source_tokens, cfg.d_model), f32)
        if cfg.family == 'vlm':
            batch['source'] = sds((b, cfg.n_source_tokens, cfg.d_model), f32)
        if cfg.family == 'lstm':
            # 10 ms MFCC frames; seq_len frames of 123 coefficients
            batch = {'frames': sds((b, s, cfg.lstm_inputs), f32)}
            if shape.kind == 'train':
                batch.update({
                    'labels': sds((b, s // 8), jnp.int32),
                    'frame_len': sds((b,), jnp.int32),
                    'label_len': sds((b,), jnp.int32)})
        return batch
    # decode
    if cfg.family == 'lstm':
        return {'frames': sds((b, 1, cfg.lstm_inputs), f32),
                'pos': sds((), jnp.int32)}
    return {'tokens': sds((b, 1), jnp.int32), 'pos': sds((), jnp.int32)}


def batch_axes(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Logical sharding axes matching input_specs."""
    if cfg.family == 'lstm':
        if shape.kind == 'train':
            return {'frames': ('batch', 'seq', None),
                    'labels': ('batch', None),
                    'frame_len': ('batch',), 'label_len': ('batch',)}
        return {'frames': ('batch', None, None), 'pos': ()}
    ax: Dict[str, Any] = {'tokens': ('batch', 'seq')}
    if shape.kind == 'train':
        ax['labels'] = ('batch', 'seq')
    if cfg.family in ('audio', 'vlm') and shape.kind in ('train', 'prefill'):
        ax['source'] = ('batch', 'frames', 'embed')
    if shape.kind == 'decode':
        ax = {'tokens': ('batch', None), 'pos': ()}
    return ax
